//! API-compatible stub of the XLA/PJRT bindings used by `ada-dist`'s
//! `pjrt` feature.
//!
//! This crate exists so the dependency graph resolves offline: the real
//! bindings (`xla_extension` / xla-rs style) link libxla and are not
//! vendorable here. Every constructor returns [`Error::Unavailable`]
//! at runtime, so code paths that merely *compile* against the PJRT
//! surface work, and anything that tries to *execute* gets a clear
//! message. To run real artifacts, replace this path dependency in the
//! workspace `Cargo.toml` with a vendored checkout of the actual
//! bindings — the public surface below is the exact subset `ada-dist`
//! consumes.

use std::fmt;
use std::path::Path;

/// Stub error: always "XLA bindings unavailable".
#[derive(Debug)]
pub enum Error {
    /// The stub was invoked at runtime.
    Unavailable,
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "XLA/PJRT bindings unavailable: the `xla` dependency is the in-tree \
             stub (rust/xla-stub). Vendor the real bindings and point the \
             workspace `xla` path dependency at them to execute HLO artifacts."
        )
    }
}

impl std::error::Error for Error {}

/// Stub result alias.
pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>() -> Result<T> {
    Err(Error::Unavailable)
}

/// Host literal (stub).
#[derive(Debug, Clone)]
pub struct Literal;

impl Literal {
    /// Rank-1 literal from a slice (stub: carries no data).
    pub fn vec1<T>(_data: &[T]) -> Literal {
        Literal
    }

    /// Reshape (stub: always errors).
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable()
    }

    /// Tuple decomposition (stub: always errors).
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable()
    }

    /// Element extraction (stub: always errors).
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable()
    }
}

/// Device buffer handle (stub).
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Device-to-host copy (stub: always errors).
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

/// PJRT client (stub).
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    /// CPU client (stub: always errors, so nothing downstream runs).
    pub fn cpu() -> Result<PjRtClient> {
        unavailable()
    }

    /// Platform name (stub).
    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    /// Compile a computation (stub: always errors).
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }
}

/// Loaded executable (stub).
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execute (stub: always errors).
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

/// Parsed HLO module proto (stub).
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    /// Parse HLO text from a file (stub: always errors).
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto> {
        unavailable()
    }
}

/// XLA computation wrapper (stub).
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    /// Wrap a proto (stub).
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_entry_point_reports_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(Literal::vec1(&[1.0f32]).reshape(&[1]).is_err());
        assert!(HloModuleProto::from_text_file("/tmp/x.hlo.txt").is_err());
        let msg = Error::Unavailable.to_string();
        assert!(msg.contains("xla-stub"), "{msg}");
    }
}
