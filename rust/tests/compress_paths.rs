//! Integration tests for the compressed & variance-corrected gossip
//! subsystem: the degenerate bitwise equivalences the compression
//! contract promises (top-k with `k = p` ≡ dense gossip, `consensus
//! gossip` with `max_rounds = 1` ≡ one mix, `codec = f32` ≡ the f32
//! kernel), thread-count × SIMD-mode bit-identity of the codec
//! kernels, the D² transform against an all-f64 reference, and the
//! three strategies running end-to-end from spec TOML through
//! [`SessionPlan`] with reduced modeled wire bytes.
//!
//! This binary may flip `simd::force_scalar` freely: every kernel under
//! test is bitwise mode-invariant (the repo's determinism contract), so
//! concurrent tests observing a flipped mode still see identical
//! floats. The same sweep is unsafe in the library tests, where
//! `exec::simd` asserts on the dispatch mode itself.

use ada_dist::compress::{d2_transform, Codec};
use ada_dist::compress::topk::sparsify_row;
use ada_dist::dbench::{ExperimentSpec, SessionPlan, StrategyRef};
use ada_dist::exec::simd;
use ada_dist::gossip::GossipEngine;
use ada_dist::graph::{CommGraph, GraphKind};
use ada_dist::util::rng::Rng;
use ada_dist::ReplicaMatrix;

fn seeded_replicas(n: usize, p: usize, seed: u64) -> ReplicaMatrix {
    let mut rng = Rng::seed_from_u64(seed);
    let mut m = ReplicaMatrix::zeros(n, p);
    for w in 0..n {
        for v in m.row_mut(w) {
            *v = rng.range_f32(-1.0, 1.0);
        }
    }
    m
}

fn bits(m: &ReplicaMatrix) -> Vec<Vec<u32>> {
    (0..m.n())
        .map(|w| m.row(w).iter().map(|x| x.to_bits()).collect())
        .collect()
}

#[test]
fn top_k_equal_p_with_zero_residuals_is_dense_gossip_bitwise() {
    // The error-feedback path with k = p promotes every entry and
    // leaves the residual at zero, so mix_from over the messages must
    // reproduce engine.mix bit-for-bit. Non-complete graphs only: the
    // uniform-complete fast path folds in a different float order.
    let (n, p) = (8, 1003);
    for kind in [GraphKind::Ring, GraphKind::Exponential] {
        let g = CommGraph::build(kind, n).unwrap();
        for threads in [1, 4] {
            let mut dense = seeded_replicas(n, p, 11);
            let mut engine = GossipEngine::with_threads(threads);
            engine.mix(&g, &mut dense);

            let mut sparse = seeded_replicas(n, p, 11);
            let mut residuals = ReplicaMatrix::zeros(n, p);
            let mut messages = ReplicaMatrix::zeros(n, p);
            for w in 0..n {
                let idx = sparsify_row(
                    sparse.row(w),
                    residuals.row_mut(w),
                    messages.row_mut(w),
                    p,
                );
                assert_eq!(idx.len(), p, "k = p selects everything");
            }
            assert!(
                residuals.rows().all(|r| r.iter().all(|&x| x == 0.0)),
                "k = p leaves no residual"
            );
            let mut engine = GossipEngine::with_threads(threads);
            engine.mix_from(&g, &mut sparse, &messages, Codec::F32);
            assert_eq!(
                bits(&dense),
                bits(&sparse),
                "{kind:?} @ {threads} threads: k=p must equal dense gossip"
            );
        }
    }
}

#[test]
fn codec_kernels_are_bit_identical_across_threads_and_simd_modes() {
    let (n, p) = (8, 10_000);
    let g = CommGraph::build(GraphKind::Exponential, n).unwrap();
    for codec in [Codec::Bf16, Codec::F16] {
        let mut reference = seeded_replicas(n, p, 23);
        GossipEngine::with_threads(1).mix_codec(&g, &mut reference, codec);
        let want = bits(&reference);
        for threads in [1, 4, 8] {
            for scalar in [false, true] {
                simd::force_scalar(scalar);
                let mut m = seeded_replicas(n, p, 23);
                GossipEngine::with_threads(threads).mix_codec(&g, &mut m, codec);
                simd::force_scalar(false);
                assert_eq!(
                    want,
                    bits(&m),
                    "{codec:?} @ {threads} threads, scalar={scalar}"
                );
            }
        }
        // And the codec actually engaged: quantized peers change bits
        // vs the f32 round.
        let mut f32_round = seeded_replicas(n, p, 23);
        GossipEngine::with_threads(1).mix(&g, &mut f32_round);
        assert_ne!(want, bits(&f32_round), "{codec:?} must quantize");
    }
}

#[test]
fn d2_transform_then_mix_matches_an_f64_reference() {
    // Two D² iterations (first uses the z = x − γg branch, second the
    // previous-iterate correction) followed by a gossip round, checked
    // against the same recurrence computed entirely in f64. Small
    // values keep the f32 rounding budget under the 1e-6 bar.
    let (n, p) = (8, 257);
    let lr = 0.01f32;
    let g = CommGraph::build(GraphKind::Exponential, n).unwrap();
    let w = g.dense_mixing();

    let mut x = seeded_replicas(n, p, 5);
    let mut px = ReplicaMatrix::zeros(n, p);
    let mut pg = ReplicaMatrix::zeros(n, p);
    let grads0 = {
        let mut m = seeded_replicas(n, p, 6);
        m.rows_mut().into_iter().for_each(|r| r.iter_mut().for_each(|v| *v *= 0.5));
        m
    };
    let grads1 = {
        let mut m = seeded_replicas(n, p, 7);
        m.rows_mut().into_iter().for_each(|r| r.iter_mut().for_each(|v| *v *= 0.5));
        m
    };

    // f64 shadow state, seeded from the same f32 values.
    let tof64 = |m: &ReplicaMatrix| -> Vec<Vec<f64>> {
        (0..n).map(|i| m.row(i).iter().map(|&v| v as f64).collect()).collect()
    };
    let mix64 = |rows: &[Vec<f64>]| -> Vec<Vec<f64>> {
        (0..n)
            .map(|i| {
                (0..p)
                    .map(|c| (0..n).map(|j| w[i * n + j] as f64 * rows[j][c]).sum())
                    .collect()
            })
            .collect()
    };
    let mut x64 = tof64(&x);
    let (mut px64, mut pg64) = (tof64(&px), tof64(&pg));
    let (g064, g164) = (tof64(&grads0), tof64(&grads1));
    let lr64 = lr as f64;

    for (iter, grads, g64) in [(0usize, &grads0, &g064), (1, &grads1, &g164)] {
        d2_transform(&mut x, &mut px, &mut pg, grads, lr, iter == 0);
        let mut engine = GossipEngine::with_threads(1);
        engine.mix(&g, &mut x);

        let z64: Vec<Vec<f64>> = (0..n)
            .map(|i| {
                (0..p)
                    .map(|c| {
                        if iter == 0 {
                            x64[i][c] - lr64 * g64[i][c]
                        } else {
                            2.0 * x64[i][c] - px64[i][c] - lr64 * g64[i][c]
                                + lr64 * pg64[i][c]
                        }
                    })
                    .collect()
            })
            .collect();
        px64 = x64;
        pg64 = g64.clone();
        x64 = mix64(&z64);
    }
    for i in 0..n {
        for c in 0..p {
            let err = (x.row(i)[c] as f64 - x64[i][c]).abs();
            assert!(err <= 1e-6, "replica {i} param {c}: err {err}");
        }
    }
}

#[test]
fn consensus_gossip_single_round_matches_plain_gossip_end_to_end() {
    // max_rounds = 1 must be bitwise-identical to the D_exponential
    // flavor: same local step, same graph, exactly one mix, same bytes.
    // codec = f32 dense compressed_gossip joins the same equivalence
    // class — mix_codec(F32) delegates to mix.
    let mut spec = ExperimentSpec::resnet20_analog();
    spec.scales = vec![8];
    spec.epochs = 2;
    spec.max_iters_per_epoch = Some(4);
    spec.threads = 1;
    spec.flavors = vec![ada_dist::coordinator::SgdFlavor::DecentralizedExponential];
    spec.strategies = vec![
        StrategyRef::parse("consensus_gossip:max_rounds=1").unwrap(),
        StrategyRef::parse("compressed_gossip:codec=f32").unwrap(),
    ];
    let cells = SessionPlan::from_spec(&spec).run().unwrap();
    assert_eq!(cells.len(), 3);
    let losses = |i: usize| -> Vec<f64> {
        cells[i].recorder.records().iter().map(|r| r.train_loss).collect()
    };
    assert_eq!(cells[0].flavor, "D_exponential");
    assert_eq!(cells[1].flavor, "consensus_gossip");
    assert_eq!(cells[2].flavor, "compressed_gossip[f32]");
    for i in [1, 2] {
        assert_eq!(losses(0), losses(i), "{}: loss series", cells[i].flavor);
        assert_eq!(
            cells[0].summary.final_eval.metric, cells[i].summary.final_eval.metric,
            "{}: final metric",
            cells[i].flavor
        );
        assert_eq!(
            cells[0].summary.bytes_per_node, cells[i].summary.bytes_per_node,
            "{}: bytes",
            cells[i].flavor
        );
    }
}

#[test]
fn compressed_family_runs_from_spec_toml_and_reports_reduced_bytes() {
    let spec = ExperimentSpec::from_toml_str(
        r#"
        base = "resnet20"
        scales = [8]
        epochs = 2
        max_iters_per_epoch = 4
        threads = 1
        flavors = ["d_exponential"]
        strategies = ["compressed_gossip", "d2", "consensus_gossip"]

        [strategy.compressed_gossip]
        codec = "bf16"

        [strategy.consensus_gossip]
        target = 0.0
        max_rounds = 3
        "#,
    )
    .unwrap();
    let cells = SessionPlan::from_spec(&spec).run().unwrap();
    assert_eq!(cells.len(), 4);
    assert_eq!(cells[1].flavor, "compressed_gossip[bf16]");
    assert_eq!(cells[2].flavor, "d2");
    assert_eq!(cells[3].flavor, "consensus_gossip");
    for c in &cells {
        assert!(!c.summary.diverged, "{} diverged", c.flavor);
        assert!(!c.recorder.records().is_empty(), "{}: no records", c.flavor);
        assert!(c.summary.bytes_per_node > 0, "{}: no bytes", c.flavor);
    }
    let dense = cells[0].summary.bytes_per_node;
    // bf16 ships 2 of every 4 bytes.
    assert_eq!(cells[1].summary.bytes_per_node * 2, dense);
    // d2 sends full f32 rows — same wire cost as dense gossip.
    assert_eq!(cells[2].summary.bytes_per_node, dense);
    // target = 0 never undershoots, so consensus gossip spends all 3
    // rounds every iteration.
    assert_eq!(cells[3].summary.bytes_per_node, dense * 3);

    // A top-k cell through the plan API: degree · k · (4 + 2) bytes per
    // round beats even the bf16 dense path at k = p/8.
    let mut spec2 = ExperimentSpec::resnet20_analog();
    spec2.scales = vec![8];
    spec2.epochs = 2;
    spec2.max_iters_per_epoch = Some(4);
    spec2.threads = 1;
    spec2.flavors = vec![];
    let mut plan = SessionPlan::from_spec(&spec2);
    plan.push_cell(
        8,
        spec2.seed,
        StrategyRef::parse("compressed_gossip:codec=bf16,k=41").unwrap(),
        spec2.train_config(8),
    );
    let sparse = plan.run().unwrap();
    assert_eq!(sparse[0].flavor, "compressed_gossip[bf16,k=41]");
    assert!(!sparse[0].summary.diverged);
    assert!(
        sparse[0].summary.bytes_per_node < cells[1].summary.bytes_per_node,
        "top-k ({}) must undercut dense bf16 ({})",
        sparse[0].summary.bytes_per_node,
        cells[1].summary.bytes_per_node
    );
}

#[test]
fn error_feedback_recovers_dense_accuracy_over_rounds() {
    // Pure mixing (no gradients): repeated sparsified gossip with error
    // feedback must drive replicas toward the same consensus mean the
    // dense rounds reach, because dropped mass re-enters via residuals.
    let (n, p) = (8, 512);
    let g = CommGraph::build(GraphKind::Exponential, n).unwrap();
    let init = seeded_replicas(n, p, 99);
    let mean: Vec<f32> =
        (0..p).map(|c| (0..n).map(|w| init.row(w)[c]).sum::<f32>() / n as f32).collect();

    let spread = |m: &ReplicaMatrix| -> f64 {
        (0..n)
            .flat_map(|w| {
                (0..p).map(move |c| (m.row(w)[c] as f64 - mean[c] as f64).powi(2))
            })
            .sum::<f64>()
    };
    let mut m = init.clone();
    let mut residuals = ReplicaMatrix::zeros(n, p);
    let mut messages = ReplicaMatrix::zeros(n, p);
    let mut engine = GossipEngine::with_threads(1);
    let before = spread(&m);
    for _ in 0..40 {
        for w in 0..n {
            sparsify_row(m.row(w), residuals.row_mut(w), messages.row_mut(w), p / 4);
        }
        engine.mix_from(&g, &mut m, &messages, Codec::Bf16);
    }
    let after = spread(&m);
    assert!(
        after < before / 50.0,
        "sparse gossip must still contract toward consensus: {before} → {after}"
    );
}
