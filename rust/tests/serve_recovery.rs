//! Durability and self-healing tests of the experiment service, over
//! real loopback sockets and real process-visible state (journal files,
//! store objects):
//!
//! * a server stopped abruptly mid-sweep and restarted on the same
//!   store replays its journal, finishes the job under its original id,
//!   and serves results **byte-identical** to an uninterrupted run;
//! * a panicking cell fails only its own job — the worker pool survives
//!   and subsequent jobs complete;
//! * corrupted store objects are quarantined (`*.corrupt`) and
//!   recomputed, never served;
//! * transient cell failures retry with `cell_retry` events and a
//!   per-job budget; wedged cells die to the deadline watchdog;
//! * the HTTP edge sheds load with `503` + `Retry-After`, answers
//!   stalled uploads with `408`, and the client's `?from=` cursor
//!   resumes streams without duplicates.

use ada_dist::coordinator::strategy::{CombineStrategy, StepCtx, StrategyInstance};
use ada_dist::coordinator::SgdFlavor;
use ada_dist::dbench::{ExperimentSpec, SessionPlan, StrategyRef};
use ada_dist::error::AdaError;
use ada_dist::graph::{CommGraph, GraphKind};
use ada_dist::serve::{
    http_request, http_request_with, http_stream_lines, start, ClientConfig, ResultStore,
    Scheduler, ServeConfig, SubmitOptions,
};
use ada_dist::topology::FnSchedule;
use ada_dist::util::json::Value;
use ada_dist::ReplicaMatrix;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn server_cfg(dir: &Path, hold: bool) -> ServeConfig {
    ServeConfig {
        addr: "127.0.0.1:0".into(),
        store_dir: dir.to_string_lossy().into_owned(),
        workers: 1,
        hold,
        ..ServeConfig::default()
    }
}

/// A tiny JSON spec: `scales × flavors` cells on the softmax workload.
fn spec_json(
    seed: u64,
    scales: &[usize],
    flavors: &[&str],
    epochs: usize,
    max_iters: usize,
) -> String {
    format!(
        r#"{{"base": "resnet20", "name": "r{seed}", "seed": {seed},
            "scales": [{}], "flavors": [{}],
            "epochs": {epochs}, "max_iters_per_epoch": {max_iters},
            "threads": 1, "metrics_every": 1, "eval_every_epochs": 100}}"#,
        scales.iter().map(|s| s.to_string()).collect::<Vec<_>>().join(", "),
        flavors.iter().map(|f| format!("{f:?}")).collect::<Vec<_>>().join(", "),
    )
}

fn get_json(addr: &str, path: &str) -> (u16, Value) {
    let (code, body) = http_request(addr, "GET", path, None).unwrap();
    let text = String::from_utf8_lossy(&body).into_owned();
    (code, Value::parse(&text).unwrap_or(Value::Null))
}

fn post(addr: &str, path: &str, body: Option<&[u8]>) -> (u16, Value) {
    let (code, body) = http_request(addr, "POST", path, body).unwrap();
    let text = String::from_utf8_lossy(&body).into_owned();
    (code, Value::parse(&text).unwrap_or(Value::Null))
}

fn submit(addr: &str, spec: &str, query: &str) -> String {
    let path = if query.is_empty() {
        "/jobs".to_string()
    } else {
        format!("/jobs?{query}")
    };
    let (code, v) = post(addr, &path, Some(spec.as_bytes()));
    assert_eq!(code, 200, "submit failed: {v:?}");
    v.str_field("job").unwrap().to_string()
}

fn status(addr: &str, id: &str) -> Value {
    let (code, v) = get_json(addr, &format!("/jobs/{id}"));
    assert_eq!(code, 200, "status {id}: {v:?}");
    v
}

fn wait_done(addr: &str, id: &str) -> Value {
    let deadline = Instant::now() + Duration::from_secs(300);
    loop {
        let v = status(addr, id);
        let state = v.str_field("state").unwrap().to_string();
        if matches!(state.as_str(), "done" | "failed" | "cancelled")
            && v.usize_field("running").unwrap() == 0
        {
            return v;
        }
        assert!(Instant::now() < deadline, "timeout waiting on {id}: {v:?}");
        std::thread::sleep(Duration::from_millis(5));
    }
}

fn results_body(addr: &str, id: &str) -> Vec<u8> {
    let (code, body) =
        http_request(addr, "GET", &format!("/jobs/{id}/results"), None).unwrap();
    assert_eq!(code, 200);
    body
}

// ---------------------------------------------------------------------
// (a) crash/restart recovery to byte-identical results
// ---------------------------------------------------------------------

#[test]
fn killed_server_replays_journal_and_converges_to_identical_results() {
    let spec = spec_json(600, &[4, 8, 12], &["d_ring", "d_complete"], 4, 150);

    // Reference: the same sweep, uninterrupted, on its own store.
    let ref_dir = ada_dist::util::scratch_dir("recover_ref").unwrap();
    let mut ref_srv = start(&server_cfg(&ref_dir, false)).unwrap();
    let ref_addr = ref_srv.addr.to_string();
    let job = submit(&ref_addr, &spec, "");
    let done = wait_done(&ref_addr, &job);
    assert_eq!(done.str_field("state").unwrap(), "done");
    let body_ref = results_body(&ref_addr, &job);
    ref_srv.shutdown(true);
    ref_srv.join();
    drop(ref_srv);

    // Victim: identical submission, stopped abruptly (non-drain — the
    // in-flight cell is discarded exactly as a crash would lose it)
    // after some but not all cells finished.
    let dir = ada_dist::util::scratch_dir("recover_victim").unwrap();
    let mut srv = start(&server_cfg(&dir, true)).unwrap();
    let addr = srv.addr.to_string();
    let vjob = submit(&addr, &spec, "");
    assert_eq!(vjob, job, "deterministic ids across servers");
    post(&addr, "/scheduler/resume", None);
    let deadline = Instant::now() + Duration::from_secs(120);
    while status(&addr, &vjob).usize_field("done").unwrap() == 0 {
        assert!(Instant::now() < deadline, "first cell never finished");
        std::thread::sleep(Duration::from_millis(5));
    }
    post(&addr, "/scheduler/pause", None);
    while status(&addr, &vjob).usize_field("running").unwrap() > 0 {
        assert!(Instant::now() < deadline, "in-flight cell never drained");
        std::thread::sleep(Duration::from_millis(5));
    }
    let mid = status(&addr, &vjob);
    let finished_cells = mid.usize_field("done").unwrap();
    assert!(
        finished_cells < 6,
        "sweep drained before the stop landed ({finished_cells}/6)"
    );
    srv.shutdown(false);
    srv.join();
    drop(srv);

    // Restart on the same store (fresh port): the journal re-enqueues
    // the job under its original id, finished cells come back as cache
    // hits, the rest re-run, and the results document is byte-for-byte
    // the uninterrupted one.
    let mut srv2 = start(&server_cfg(&dir, false)).unwrap();
    let addr2 = srv2.addr.to_string();
    let recovered = wait_done(&addr2, &vjob);
    assert_eq!(recovered.str_field("state").unwrap(), "done", "{recovered:?}");
    assert_eq!(recovered.usize_field("done").unwrap(), 6);
    assert!(
        recovered.usize_field("cached").unwrap() >= finished_cells,
        "finished cells must be served from the store: {recovered:?}"
    );
    let body_rec = results_body(&addr2, &vjob);
    assert_eq!(
        body_ref, body_rec,
        "recovery must converge to byte-identical results"
    );
    srv2.shutdown(true);
    srv2.join();
    let _ = std::fs::remove_dir_all(&ref_dir);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn idempotent_resubmission_maps_to_the_same_job() {
    let dir = ada_dist::util::scratch_dir("recover_idem").unwrap();
    let srv = start(&server_cfg(&dir, true)).unwrap();
    let addr = srv.addr.to_string();
    let spec = spec_json(610, &[4], &["d_ring"], 1, 2);
    let first = submit(&addr, &spec, "idempotent=true");
    let second = submit(&addr, &spec, "idempotent=true");
    assert_eq!(first, second, "retry-safe resubmission");
    let third = submit(&addr, &spec, "");
    assert_ne!(third, first, "non-idempotent resubmission still dedups by suffix");
    srv.shutdown(true);
    drop(srv);
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------
// (b) panic containment and (d) retries — direct scheduler tests with
// misbehaving strategies registered on the plan
// ---------------------------------------------------------------------

/// The example local-SGD step, minus the failure injection — one honest
/// local step per worker, then a gossip round over the scheduled graph.
fn honest_local_phase(ctx: &mut StepCtx<'_>, replicas: &mut ReplicaMatrix) -> ada_dist::error::Result<f64> {
    let mut loss_sum = 0.0f64;
    for (w, loader) in ctx.loaders.iter().enumerate() {
        let batch = ctx.dataset.batch(&loader.batch_indices(ctx.epoch, ctx.batch));
        loss_sum += ctx.model.local_step(w, replicas.row_mut(w), &batch, ctx.lr)? as f64;
    }
    Ok(loss_sum / ctx.n as f64)
}

struct Panicking;

impl CombineStrategy for Panicking {
    fn name(&self) -> &str {
        "panicking"
    }

    fn local_phase(
        &mut self,
        _ctx: &mut StepCtx<'_>,
        _replicas: &mut ReplicaMatrix,
    ) -> ada_dist::error::Result<f64> {
        panic!("injected fault: model blew up");
    }

    fn combine_phase(
        &mut self,
        ctx: &mut StepCtx<'_>,
        replicas: &mut ReplicaMatrix,
    ) -> ada_dist::error::Result<(usize, u64)> {
        let g = ctx.graph.expect("schedule provides a graph");
        ctx.engine.mix(g, replicas);
        Ok((g.degree(), g.bytes_sent_per_node(ctx.param_count)))
    }
}

/// Fails `local_phase` with a transient error until the shared counter
/// reaches `fail_first` calls, then behaves honestly — the counter
/// survives across retry attempts because it lives in the registry
/// closure, while each attempt gets a fresh strategy instance.
struct Flaky {
    calls: Arc<AtomicUsize>,
    fail_first: usize,
}

impl CombineStrategy for Flaky {
    fn name(&self) -> &str {
        "flaky"
    }

    fn local_phase(
        &mut self,
        ctx: &mut StepCtx<'_>,
        replicas: &mut ReplicaMatrix,
    ) -> ada_dist::error::Result<f64> {
        if self.calls.fetch_add(1, Ordering::SeqCst) < self.fail_first {
            return Err(AdaError::Runtime("transient storage hiccup".into()));
        }
        honest_local_phase(ctx, replicas)
    }

    fn combine_phase(
        &mut self,
        ctx: &mut StepCtx<'_>,
        replicas: &mut ReplicaMatrix,
    ) -> ada_dist::error::Result<(usize, u64)> {
        let g = ctx.graph.expect("schedule provides a graph");
        ctx.engine.mix(g, replicas);
        Ok((g.degree(), g.bytes_sent_per_node(ctx.param_count)))
    }
}

/// A one-cell plan running the named strategy `key`, registered via
/// `make` (the out-of-crate registration path the example documents).
fn strategy_plan(
    seed: u64,
    key: &'static str,
    make: impl Fn() -> Box<dyn CombineStrategy> + Send + Sync + 'static,
) -> SessionPlan {
    let mut s = ExperimentSpec::resnet20_analog();
    s.scales = vec![4];
    s.epochs = 1;
    s.seed = seed;
    s.max_iters_per_epoch = Some(2);
    s.threads = 1;
    s.flavors = vec![SgdFlavor::DecentralizedRing];
    let mut plan = SessionPlan::from_spec(&s);
    plan.cells.clear();
    plan.registry.register(key, move |p| {
        let n = p.n_workers;
        Ok(StrategyInstance {
            label: key.into(),
            schedule: Some(Box::new(FnSchedule::new("complete", move |_| {
                CommGraph::build(GraphKind::Complete, n)
            }))),
            k_neighbors: n.saturating_sub(1),
            combine: Some(make()),
        })
    });
    plan.push_cell(4, seed, StrategyRef::named(key), s.train_config(4));
    plan
}

#[test]
fn a_panicking_cell_fails_its_job_and_the_pool_survives() {
    let dir = ada_dist::util::scratch_dir("recover_panic").unwrap();
    let store = Arc::new(ResultStore::open(&dir).unwrap());
    let sched = Scheduler::start(store, 1, false);

    let bad = sched
        .submit_plan(
            "bad".into(),
            strategy_plan(620, "panicking", || Box::new(Panicking)),
            &SubmitOptions::default(),
        )
        .unwrap();
    let st = sched
        .wait(&bad.id, Duration::from_secs(300))
        .expect("panicking job reaches a terminal state");
    assert_eq!(st.state, "failed");
    let err = st.error.expect("failed jobs carry the panic message");
    assert!(err.contains("panicked"), "{err}");
    assert!(err.contains("model blew up"), "{err}");

    // The worker thread survived the panic: a normal job completes on
    // the same (single-thread!) pool.
    let mut s = ExperimentSpec::resnet20_analog();
    s.scales = vec![4];
    s.epochs = 1;
    s.seed = 621;
    s.max_iters_per_epoch = Some(1);
    s.threads = 1;
    s.flavors = vec![SgdFlavor::DecentralizedRing];
    let good = sched
        .submit_plan("good".into(), SessionPlan::from_spec(&s), &SubmitOptions::default())
        .unwrap();
    let st = sched
        .wait(&good.id, Duration::from_secs(300))
        .expect("job after the panic completes");
    assert_eq!(st.state, "done", "{st:?}");
    sched.shutdown(true);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn transient_failures_retry_with_events_then_fail_past_the_budget() {
    let dir = ada_dist::util::scratch_dir("recover_retry").unwrap();
    let store = Arc::new(ResultStore::open(&dir).unwrap());
    let sched = Scheduler::start(store, 1, false);

    // Fails the first two attempts, succeeds on the third: exactly
    // within a retry budget of 2.
    let calls = Arc::new(AtomicUsize::new(0));
    let c = Arc::clone(&calls);
    let job = sched
        .submit_plan(
            "flaky".into(),
            strategy_plan(630, "flaky", move || {
                Box::new(Flaky { calls: Arc::clone(&c), fail_first: 2 })
            }),
            &SubmitOptions { retries: Some(2), ..SubmitOptions::default() },
        )
        .unwrap();
    let st = sched
        .wait(&job.id, Duration::from_secs(300))
        .expect("flaky job terminates");
    assert_eq!(st.state, "done", "{st:?}");
    let (lines, _) = job.events.read_from(0);
    let retries: Vec<_> = lines
        .iter()
        .filter(|l| l.contains("\"cell_retry\""))
        .collect();
    assert_eq!(retries.len(), 2, "{lines:?}");
    assert!(retries[0].contains("transient storage hiccup"), "{retries:?}");

    // A budget smaller than the failure streak fails the job with the
    // underlying error.
    let calls = Arc::new(AtomicUsize::new(0));
    let c = Arc::clone(&calls);
    let job = sched
        .submit_plan(
            "hopeless".into(),
            strategy_plan(631, "hopeless", move || {
                Box::new(Flaky { calls: Arc::clone(&c), fail_first: usize::MAX })
            }),
            &SubmitOptions { retries: Some(1), ..SubmitOptions::default() },
        )
        .unwrap();
    let st = sched
        .wait(&job.id, Duration::from_secs(300))
        .expect("hopeless job terminates");
    assert_eq!(st.state, "failed");
    assert!(st.error.unwrap().contains("transient storage hiccup"));
    let (lines, _) = job.events.read_from(0);
    assert_eq!(
        lines.iter().filter(|l| l.contains("\"cell_retry\"")).count(),
        1,
        "one retry, then the budget is spent: {lines:?}"
    );
    sched.shutdown(true);
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------
// (c) store corruption quarantine
// ---------------------------------------------------------------------

#[test]
fn corrupted_store_objects_are_quarantined_and_recomputed() {
    let dir = ada_dist::util::scratch_dir("recover_corrupt").unwrap();
    let mut srv = start(&server_cfg(&dir, false)).unwrap();
    let addr = srv.addr.to_string();
    let spec = spec_json(640, &[4], &["d_ring"], 1, 2);
    let a = submit(&addr, &spec, "");
    let done = wait_done(&addr, &a);
    assert_eq!(done.usize_field("cached").unwrap(), 0);
    let body_a = results_body(&addr, &a);

    // Smash the stored object.
    let mut objects = Vec::new();
    for shard in std::fs::read_dir(dir.join("objects")).unwrap().flatten() {
        for entry in std::fs::read_dir(shard.path()).unwrap().flatten() {
            objects.push(entry.path());
        }
    }
    assert_eq!(objects.len(), 1, "{objects:?}");
    std::fs::write(&objects[0], b"{ definitely not a result").unwrap();

    // The resubmitted job recomputes (no cache hit, never serves the
    // corrupt bytes) and converges to the same results document.
    let b = submit(&addr, &spec, "");
    assert_ne!(b, a);
    let done = wait_done(&addr, &b);
    assert_eq!(done.str_field("state").unwrap(), "done");
    assert_eq!(
        done.usize_field("cached").unwrap(),
        0,
        "a corrupt object must never count as a hit"
    );
    assert_eq!(results_body(&addr, &b), body_a, "recomputed bytes match");
    assert!(
        objects[0].with_extension("corrupt").exists(),
        "corrupt object is quarantined, not deleted"
    );
    let (_, store) = get_json(&addr, "/store");
    assert_eq!(store.usize_field("quarantined").unwrap(), 1, "{store:?}");
    assert_eq!(store.usize_field("objects").unwrap(), 1, "recomputed object stored");
    srv.shutdown(true);
    srv.join();
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------
// (e) deadline watchdog
// ---------------------------------------------------------------------

#[test]
fn the_watchdog_fails_cells_that_exceed_their_deadline() {
    let dir = ada_dist::util::scratch_dir("recover_deadline").unwrap();
    let mut srv = start(&server_cfg(&dir, false)).unwrap();
    let addr = srv.addr.to_string();
    // A cell that would run for many seconds, against a 50 ms deadline.
    let spec = spec_json(650, &[24], &["d_ring"], 9, 400);
    let id = submit(&addr, &spec, "deadline_s=0.05");
    let done = wait_done(&addr, &id);
    assert_eq!(done.str_field("state").unwrap(), "failed", "{done:?}");
    let err = done.str_field("error").unwrap();
    assert!(err.contains("deadline"), "{err}");

    // The stream cursor (`?from=`) replays exactly the suffix — the
    // re-attach contract the retrying client builds on.
    let mut all = Vec::new();
    http_stream_lines(&addr, &format!("/jobs/{id}/stream"), |l| {
        all.push(l.to_string());
    })
    .unwrap();
    assert!(all.len() >= 2, "{all:?}");
    assert!(all.last().unwrap().contains("job_done"));
    let mut tail = Vec::new();
    http_stream_lines(&addr, &format!("/jobs/{id}/stream?from={}", all.len() - 1), |l| {
        tail.push(l.to_string());
    })
    .unwrap();
    assert_eq!(tail, vec![all.last().unwrap().clone()]);
    srv.shutdown(true);
    srv.join();
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------
// (f)/(g) bounded HTTP edge: 408 on stalled uploads, 503 shedding
// ---------------------------------------------------------------------

#[test]
fn stalled_uploads_get_a_json_408() {
    let dir = ada_dist::util::scratch_dir("recover_408").unwrap();
    let cfg = ServeConfig { read_timeout_s: 0.2, ..server_cfg(&dir, true) };
    let srv = start(&cfg).unwrap();
    let addr = srv.addr.to_string();
    let mut conn = TcpStream::connect(&addr).unwrap();
    conn.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    // Promise a body, deliver half, stall.
    conn.write_all(b"POST /jobs HTTP/1.1\r\nContent-Length: 1000\r\n\r\npartial")
        .unwrap();
    conn.flush().unwrap();
    let mut resp = String::new();
    conn.read_to_string(&mut resp).unwrap();
    assert!(resp.starts_with("HTTP/1.1 408"), "{resp}");
    assert!(resp.contains("timed out"), "{resp}");
    srv.shutdown(true);
    drop(srv);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn connections_beyond_the_cap_are_shed_with_503_and_recover() {
    let dir = ada_dist::util::scratch_dir("recover_503").unwrap();
    let cfg = ServeConfig { max_conns: 1, read_timeout_s: 2.0, ..server_cfg(&dir, true) };
    let srv = start(&cfg).unwrap();
    let addr = srv.addr.to_string();

    // One idle connection occupies the only slot...
    let hog = TcpStream::connect(&addr).unwrap();
    // ...so the next one is shed before parsing, with a Retry-After.
    let mut conn = TcpStream::connect(&addr).unwrap();
    conn.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    conn.write_all(b"GET /healthz HTTP/1.1\r\n\r\n").unwrap();
    let mut resp = String::new();
    conn.read_to_string(&mut resp).unwrap();
    assert!(resp.starts_with("HTTP/1.1 503"), "{resp}");
    assert!(resp.contains("Retry-After: 1"), "{resp}");

    // The non-retrying client surfaces the 503 verbatim.
    let no_retry = ClientConfig { retries: 0, ..ClientConfig::default() };
    let (code, _) = http_request_with(&addr, "GET", "/healthz", None, &no_retry).unwrap();
    assert_eq!(code, 503);

    // Once the hog goes away the slot frees and the retrying default
    // client rides its backoff through to a 200.
    drop(hog);
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let (code, _) = http_request(&addr, "GET", "/healthz", None).unwrap();
        if code == 200 {
            break;
        }
        assert!(Instant::now() < deadline, "slot never freed");
        std::thread::sleep(Duration::from_millis(50));
    }
    srv.shutdown(true);
    drop(srv);
    let _ = std::fs::remove_dir_all(&dir);
}
