//! Integration tests for the open topology/control API: registry
//! round-trips against direct construction, the structured
//! [`TrainSignals`] feedback plumbing, observer-driven early stopping
//! through [`ControlFlow`], and the TOML topology/strategy param
//! tables.

use ada_dist::coordinator::strategy;
use ada_dist::coordinator::surrogate::SoftmaxRegression;
use ada_dist::coordinator::{
    CheckpointObserver, ControlFlow, Observer, SgdFlavor, TargetAccuracyStop, TrainConfig,
    TrainSession, Trainer,
};
use ada_dist::data::{ShardStrategy, SyntheticClassification};
use ada_dist::dbench::{ExperimentSpec, SessionPlan, StrategyRef, TopologyRef};
use ada_dist::error::Result;
use ada_dist::graph::{CommGraph, GraphKind};
use ada_dist::metrics::IterationRecord;
use ada_dist::topology::{
    self, AdaSchedule, CommBudget, ConsensusDecay, FnSchedule, OnePeerExponential,
    StaticSchedule, TopologyPolicy, TrainSignals, VarianceAdaptive,
};
use ada_dist::util::params::ParamTable;
use ada_dist::ReplicaMatrix;
use std::sync::{Arc, Mutex};

const N: usize = 8;

fn quick_cfg(epochs: usize) -> TrainConfig {
    let mut cfg = TrainConfig::quick(N, epochs);
    cfg.max_iters_per_epoch = Some(4);
    cfg.shard = ShardStrategy::Iid;
    cfg.threads = 1;
    cfg
}

/// The graph sequence a policy produces over a few epochs/iterations,
/// as dense mixing matrices — the bit-identity fingerprint.
fn graph_sequence(policy: &dyn TopologyPolicy, epochs: usize, iters: usize) -> Vec<Vec<f32>> {
    let mut out = Vec::new();
    for e in 0..epochs {
        for i in 0..iters {
            out.push(policy.graph_for(e, i).unwrap().dense_mixing());
        }
    }
    out
}

#[test]
fn registry_policies_match_direct_construction_bit_for_bit() {
    // Acceptance criterion: every builtin policy constructed by name
    // through the registry produces exactly the graphs its directly
    // constructed counterpart produces.
    let reg = topology::registry();
    let direct: Vec<(&str, &str, Box<dyn TopologyPolicy>)> = vec![
        ("ring", "", Box::new(StaticSchedule::new(GraphKind::Ring, N).unwrap())),
        ("torus", "", Box::new(StaticSchedule::new(GraphKind::Torus, N).unwrap())),
        (
            "exponential",
            "",
            Box::new(StaticSchedule::new(GraphKind::Exponential, N).unwrap()),
        ),
        ("complete", "", Box::new(StaticSchedule::new(GraphKind::Complete, N).unwrap())),
        ("ada", "k0=6,gamma_k=2.0", Box::new(AdaSchedule::new(N, 6, 2.0))),
        ("one_peer", "", Box::new(OnePeerExponential::new(N).unwrap())),
        (
            "var_adaptive",
            "k0=6,step=2,threshold=0.01,patience=2",
            Box::new(VarianceAdaptive::new(N, 6, 2, 0.01, 2)),
        ),
        (
            "consensus_decay",
            "k0=6,step=2,threshold=0.25,patience=1",
            Box::new(ConsensusDecay::new(N, 6, 2, 0.25, 1)),
        ),
        (
            "comm_budget",
            "budget_mb=1.0,k0=6",
            Box::new(CommBudget::with_budget_mb(N, 6, 1.0)),
        ),
    ];
    for (name, params, reference) in direct {
        let table = ParamTable::parse_kv(params).unwrap();
        let resolved = reg
            .resolve(name, N, &table)
            .unwrap_or_else(|e| panic!("{name} must resolve: {e}"));
        assert_eq!(
            graph_sequence(resolved.as_ref(), 4, 2),
            graph_sequence(reference.as_ref(), 4, 2),
            "{name}: registry and direct construction must emit identical graphs"
        );
        assert_eq!(resolved.k_hint(), reference.k_hint(), "{name}: k_hint");
    }
}

#[test]
fn registry_topology_trains_bit_identically_to_the_flavor_path() {
    // D_ring through the legacy flavor path vs the same strategy with a
    // registry-resolved `ring` policy swapped in: the ring's k_hint (2)
    // matches the flavor's k_neighbors, so the LR schedule — and every
    // float after it — must agree exactly.
    let data = SyntheticClassification::generate(1024, 8, 4, 3.0, 21);
    let cfg = quick_cfg(2);
    let run_flavor = || {
        let mut model = SoftmaxRegression::new(8, 4, 16, 32, N, 0.9);
        let (rec, s) = Trainer::new(&mut model, cfg.clone())
            .run(&data, &SgdFlavor::DecentralizedRing)
            .unwrap();
        (
            rec.records().iter().map(|r| r.train_loss).collect::<Vec<_>>(),
            s.final_eval.metric,
        )
    };
    let run_topology = || {
        let mut model = SoftmaxRegression::new(8, 4, 16, 32, N, 0.9);
        let inst = strategy::registry()
            .resolve("D_ring", &SgdFlavor::DecentralizedRing.params(N))
            .unwrap();
        let policy = topology::registry()
            .resolve("ring", N, &ParamTable::new())
            .unwrap();
        let (rec, s) = TrainSession::builder(&mut model, cfg.clone())
            .strategy(inst)
            .topology(policy)
            .build()
            .unwrap()
            .run(&data)
            .unwrap();
        (
            rec.records().iter().map(|r| r.train_loss).collect::<Vec<_>>(),
            s.final_eval.metric,
        )
    };
    let (la, ma) = run_flavor();
    let (lb, mb) = run_topology();
    assert_eq!(la, lb, "loss series must be bit-identical");
    assert_eq!(ma, mb, "final metric must be bit-identical");
}

/// Wraps a fixed ring graph and records every signals bundle the
/// session delivers.
struct RecordingPolicy {
    n: usize,
    seen: Arc<Mutex<Vec<TrainSignals>>>,
}

impl TopologyPolicy for RecordingPolicy {
    fn graph_for(&self, _epoch: usize, _iter: usize) -> Result<CommGraph> {
        CommGraph::build(GraphKind::Ring, self.n)
    }

    fn wants_consensus_distance(&self) -> bool {
        true // opt into the O(n·P) measurement so the test can see it
    }

    fn observe(&mut self, signals: &TrainSignals) {
        self.seen.lock().unwrap().push(signals.clone());
    }

    fn name(&self) -> String {
        "recording".into()
    }
}

#[test]
fn train_signals_carry_the_probe_series_and_comm_spend() {
    let data = SyntheticClassification::generate(1024, 8, 4, 3.0, 33);
    let seen = Arc::new(Mutex::new(Vec::new()));
    let epochs = 3;
    let cfg = quick_cfg(epochs);
    let mut model = SoftmaxRegression::new(8, 4, 16, 32, N, 0.9);
    let inst = strategy::registry()
        .resolve("D_ring", &SgdFlavor::DecentralizedRing.params(N))
        .unwrap();
    let session = TrainSession::builder(&mut model, cfg)
        .strategy(inst)
        .topology(Box::new(RecordingPolicy { n: N, seen: seen.clone() }))
        .build()
        .unwrap();
    let (rec, summary) = session.run(&data).unwrap();

    let seen = seen.lock().unwrap();
    assert_eq!(seen.len(), epochs, "one signals bundle per epoch");
    for (e, s) in seen.iter().enumerate() {
        assert_eq!(s.epoch, e);
        // metrics_every = 1: every epoch captured. The policy must see
        // exactly the per-epoch mean of the gini series the probe wrote
        // into the records — same captures, same accumulation.
        let epoch_ginis: Vec<f64> = rec
            .records()
            .iter()
            .filter(|r| r.epoch == e)
            .map(|r| r.variance.gini)
            .collect();
        assert!(!epoch_ginis.is_empty());
        let expected = epoch_ginis.iter().sum::<f64>() / epoch_ginis.len() as f64;
        assert_eq!(s.gini, Some(expected), "epoch {e}: gini mismatch");
        let var = s.l2_variance.expect("probe on ⇒ variance present");
        assert!(var.is_finite() && var >= 0.0);
        let dist = s.consensus_distance.expect("opted in ⇒ distance present");
        assert!(dist.is_finite() && dist >= 0.0);
        assert!(s.train_loss.is_finite());
        // Cumulative bytes: epoch e has seen (e+1) epochs of identical
        // ring rounds.
        let per_epoch = seen[0].comm_bytes_per_node;
        assert!(per_epoch > 0);
        assert_eq!(s.comm_bytes_per_node, per_epoch * (e as u64 + 1));
    }
    // The final bundle accounts for the whole run's communication.
    assert_eq!(
        seen.last().unwrap().comm_bytes_per_node,
        summary.bytes_per_node
    );
    // Eval runs every epoch here, so the metric signal is present.
    assert!(seen.iter().all(|s| s.test_metric.is_some()));
}

/// Stops the run after a fixed number of iterations.
struct StopAfter {
    at: usize,
}

impl Observer for StopAfter {
    fn on_iteration(
        &mut self,
        rec: &IterationRecord,
        _replicas: &ReplicaMatrix,
    ) -> Result<ControlFlow> {
        Ok(if rec.iteration >= self.at {
            ControlFlow::Stop
        } else {
            ControlFlow::Continue
        })
    }
}

#[test]
fn early_stop_halts_at_the_requested_iteration_and_checkpoints_still_fire() {
    let data = SyntheticClassification::generate(1024, 8, 4, 3.0, 7);
    let dir = std::env::temp_dir().join(format!("ada_topo_stop_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    // 4 iters/epoch × 5 epochs = 20 iterations; stop at iteration 9
    // (mid-epoch 2, after checkpoints for epochs 1 and 2 were written).
    let cfg = quick_cfg(5);
    assert!(cfg.max_iters_per_epoch == Some(4), "the epoch math below assumes 4");
    let mut model = SoftmaxRegression::new(8, 4, 16, 32, N, 0.9);
    let session = TrainSession::builder(&mut model, cfg)
        .flavor(&SgdFlavor::DecentralizedRing)
        .unwrap()
        .observer(Box::new(CheckpointObserver::new(&dir, 1)))
        .observer(Box::new(StopAfter { at: 9 }))
        .build()
        .unwrap();
    let (rec, summary) = session.run(&data).unwrap();
    assert_eq!(rec.records().len(), 10, "iterations 0..=9, then stop");
    assert_eq!(rec.records().last().unwrap().iteration, 9);
    assert!(!summary.diverged);
    // The checkpoint observer fired on the epochs that completed.
    assert!(dir.join("D_ring_epoch0001.ckpt").exists());
    assert!(dir.join("D_ring_epoch0002.ckpt").exists());
    assert!(
        !dir.join("D_ring_epoch0003.ckpt").exists(),
        "epoch 3 never completed"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn target_accuracy_observer_stops_a_real_run_early() {
    let data = SyntheticClassification::generate(1024, 8, 4, 3.0, 21);
    let run = |target: Option<f64>| {
        let mut cfg = quick_cfg(8);
        cfg.max_iters_per_epoch = Some(8);
        let mut model = SoftmaxRegression::new(8, 4, 16, 32, N, 0.9);
        let mut builder = TrainSession::builder(&mut model, cfg)
            .flavor(&SgdFlavor::DecentralizedComplete)
            .unwrap();
        if let Some(t) = target {
            builder = builder.observer(Box::new(TargetAccuracyStop::new(t)));
        }
        let (rec, summary) = builder.build().unwrap().run(&data).unwrap();
        (rec.records().len(), summary)
    };
    let (full_len, full) = run(None);
    // An easy target just above chance (0.25): the strongly separable
    // workload clears it well before the final epoch.
    let (short_len, short) = run(Some(0.3));
    assert!(full.final_eval.metric > 0.3, "baseline must clear the bar");
    assert!(
        short_len < full_len,
        "early stop must cut iterations: {short_len} vs {full_len}"
    );
    assert!(
        short.bytes_per_node < full.bytes_per_node,
        "stopping early must save communication"
    );
    assert!(!short.diverged);
}

#[test]
fn signal_driven_policies_train_end_to_end_and_respect_their_dials() {
    // comm_budget with a tight budget vs a loose one, same everything
    // else: the tight run must send fewer bytes per node.
    let run = |params: &str| {
        let mut spec = ExperimentSpec::resnet20_analog();
        spec.scales = vec![N];
        spec.epochs = 3;
        spec.max_iters_per_epoch = Some(4);
        spec.threads = 1;
        spec.flavors = vec![SgdFlavor::DecentralizedComplete];
        spec.topology = Some(TopologyRef::parse(params).unwrap());
        let plan = SessionPlan::from_spec(&spec);
        let cells = plan.run().unwrap();
        assert_eq!(cells.len(), 1);
        assert!(!cells[0].summary.diverged, "{params} diverged");
        cells[0].summary.bytes_per_node
    };
    // resnet20's softmax analog has P = 330; a 0.002 MB budget floors
    // the lattice at k = 2 while 50 MB affords the k0 = 7 cap.
    let tight = run("comm_budget:budget_mb=0.002,k0=7");
    let loose = run("comm_budget:budget_mb=50.0,k0=7");
    assert!(
        tight < loose,
        "tight budget must spend less: {tight} vs {loose}"
    );

    // consensus_decay trains without divergence and (with an eager
    // threshold) ends sparser than it started.
    let mut spec = ExperimentSpec::resnet20_analog();
    spec.scales = vec![N];
    spec.epochs = 4;
    spec.max_iters_per_epoch = Some(4);
    spec.threads = 1;
    spec.flavors = vec![SgdFlavor::DecentralizedComplete];
    // k0 = 5 (not complete): complete mixing would equalize the
    // replicas and zero the consensus distance, blocking the trigger.
    spec.topology =
        Some(TopologyRef::parse("consensus_decay:k0=5,step=2,threshold=1.5").unwrap());
    let cells = SessionPlan::from_spec(&spec).run().unwrap();
    assert!(!cells[0].summary.diverged);
    assert_eq!(cells[0].flavor, "D_complete+consensus_decay");
    let degrees: Vec<usize> = cells[0]
        .recorder
        .records()
        .iter()
        .map(|r| r.graph_degree)
        .collect();
    // threshold > 1 relative to d0 means every epoch after the first
    // triggers a decay: the last round must be sparser than the first.
    assert!(
        degrees.last().unwrap() < degrees.first().unwrap(),
        "decay must engage: {degrees:?}"
    );
}

#[test]
fn per_iteration_one_peer_rotates_inside_an_epoch() {
    // The rotation itself is pinned at the unit level (one_peer.rs);
    // here: the per-iteration variant trains end-to-end through the
    // session and sends exactly the same bytes as the per-epoch one
    // (degree 1 every round either way).
    let data = SyntheticClassification::generate(1024, 8, 4, 3.0, 5);
    let run = |params: &str| {
        let mut model = SoftmaxRegression::new(8, 4, 16, 32, N, 0.9);
        let inst = strategy::registry()
            .resolve("D_one_peer", &SgdFlavor::OnePeer.params(N))
            .unwrap();
        let policy = topology::registry()
            .resolve("one_peer", N, &ParamTable::parse_kv(params).unwrap())
            .unwrap();
        let (rec, s) = TrainSession::builder(&mut model, quick_cfg(2))
            .strategy(inst)
            .topology(policy)
            .build()
            .unwrap()
            .run(&data)
            .unwrap();
        assert!(rec.records().iter().all(|r| r.graph_degree == 1));
        let losses: Vec<f64> = rec.records().iter().map(|r| r.train_loss).collect();
        (s.bytes_per_node, losses)
    };
    let (bytes_epoch, losses_epoch) = run("per_iter=false");
    let (bytes_iter, losses_iter) = run("per_iter=true");
    assert_eq!(bytes_epoch, bytes_iter, "degree-1 rounds cost the same");
    assert_eq!(losses_epoch[0], losses_iter[0], "pre-mixing step is shared");
    // Different mixing sequences must produce different floats — proof
    // the cadence actually changed the run.
    assert_ne!(losses_epoch, losses_iter, "rotation cadence must matter");
}

#[test]
fn topology_override_on_a_centralized_strategy_is_a_build_error() {
    let mut model = SoftmaxRegression::new(8, 4, 16, 32, N, 0.9);
    let inst = strategy::registry()
        .resolve("C_complete", &SgdFlavor::CentralizedComplete.params(N))
        .unwrap();
    let policy = topology::registry()
        .resolve("ring", N, &ParamTable::new())
        .unwrap();
    let err = TrainSession::builder(&mut model, quick_cfg(1))
        .strategy(inst)
        .topology(policy)
        .build()
        .map(|_| ())
        .unwrap_err()
        .to_string();
    assert!(err.contains("C_complete"), "{err}");
}

#[test]
fn toml_topology_and_strategy_tables_resolve_and_run() {
    let spec = ExperimentSpec::from_toml_str(
        r#"
        base = "resnet20"
        scales = [6]
        epochs = 2
        max_iters_per_epoch = 3
        threads = 1
        flavors = ["d_ring"]
        strategies = ["D_var_adaptive"]
        topology = "ada"

        [strategy.D_var_adaptive]
        k0 = 4
        step = 1

        [topology.ada]
        k0 = 4
        gamma_k = 2.0
        "#,
    )
    .unwrap();
    let plan = SessionPlan::from_spec(&spec);
    assert_eq!(plan.cells.len(), 2, "one flavor + one named strategy");
    let cells = plan.run().unwrap();
    assert_eq!(cells[0].flavor, "D_ring+ada");
    assert_eq!(cells[1].flavor, "D_var_adaptive+ada");
    for c in &cells {
        assert!(!c.summary.diverged, "{} diverged", c.flavor);
        assert!(!c.recorder.records().is_empty());
    }
    // The ada override really drove the graphs: epoch 0 at k0=4, epoch
    // 1 decayed to the k=2 ring floor (γk=2).
    let by_epoch: Vec<usize> = cells[0]
        .recorder
        .records()
        .iter()
        .map(|r| r.graph_degree)
        .collect();
    assert_eq!(by_epoch[0], 4, "{by_epoch:?}");
    assert_eq!(*by_epoch.last().unwrap(), 2, "{by_epoch:?}");
}

#[test]
fn custom_fn_policy_registers_and_trains_via_the_plan() {
    // An out-of-crate FnSchedule-backed policy: registered by name at
    // runtime, referenced from a cell, trained end-to-end.
    let mut spec = ExperimentSpec::resnet20_analog();
    spec.scales = vec![6];
    spec.epochs = 2;
    spec.max_iters_per_epoch = Some(3);
    spec.threads = 1;
    spec.flavors = vec![SgdFlavor::DecentralizedRing];
    let mut plan = SessionPlan::from_spec(&spec);
    plan.topologies.register("densify", |n, params| {
        let dense_epoch = params.usize_or("from", 1)?;
        Ok(Box::new(FnSchedule::new("densify", move |epoch| {
            CommGraph::build(
                if epoch >= dense_epoch { GraphKind::Complete } else { GraphKind::Ring },
                n,
            )
        })))
    });
    plan.push_cell_with_topology(
        6,
        spec.seed,
        StrategyRef::Flavor(SgdFlavor::DecentralizedRing),
        TopologyRef::parse("densify:from=1").unwrap(),
        spec.train_config(6),
    );
    let cells = plan.run().unwrap();
    assert_eq!(cells[1].flavor, "D_ring+densify");
    let degrees: Vec<usize> = cells[1]
        .recorder
        .records()
        .iter()
        .map(|r| r.graph_degree)
        .collect();
    assert_eq!(degrees[0], 2, "epoch 0: ring");
    assert_eq!(*degrees.last().unwrap(), 5, "epoch 1: complete over 6 nodes");
}
