//! End-to-end tests of the experiment service over real loopback
//! sockets: submission (JSON and TOML), JSONL metric streaming,
//! content-addressed caching with bitwise-identical results,
//! deterministic fair-share interleaving, priority preemption at cell
//! granularity, and cancellation within one cell boundary.
//!
//! All servers run one cell worker so dispatch order is an exact
//! function of the submission sequence — the interleaving assertions
//! are deterministic, not statistical.

use ada_dist::metrics::IterationRecord;
use ada_dist::serve::{http_request, http_stream_lines, start, ServeConfig, Server};
use ada_dist::util::json::Value;
use std::time::{Duration, Instant};

fn server(tag: &str, hold: bool) -> (Server, String, std::path::PathBuf) {
    let dir = ada_dist::util::scratch_dir(tag).unwrap();
    let srv = start(&ServeConfig {
        addr: "127.0.0.1:0".into(),
        store_dir: dir.to_string_lossy().into_owned(),
        workers: 1,
        hold,
        ..ServeConfig::default()
    })
    .unwrap();
    let addr = srv.addr.to_string();
    (srv, addr, dir)
}

/// A tiny JSON spec: `scales × flavors` cells on the softmax workload.
fn spec_json(seed: u64, scales: &[usize], flavors: &[&str], epochs: usize, max_iters: usize) -> String {
    format!(
        r#"{{"base": "resnet20", "name": "t{seed}", "seed": {seed},
            "scales": [{}], "flavors": [{}],
            "epochs": {epochs}, "max_iters_per_epoch": {max_iters},
            "threads": 1, "metrics_every": 1, "eval_every_epochs": 100}}"#,
        scales.iter().map(|s| s.to_string()).collect::<Vec<_>>().join(", "),
        flavors.iter().map(|f| format!("{f:?}")).collect::<Vec<_>>().join(", "),
    )
}

fn get_json(addr: &str, path: &str) -> (u16, Value) {
    let (code, body) = http_request(addr, "GET", path, None).unwrap();
    let text = String::from_utf8_lossy(&body).into_owned();
    (code, Value::parse(&text).unwrap_or(Value::Null))
}

fn post(addr: &str, path: &str, body: Option<&[u8]>) -> (u16, Value) {
    let (code, body) = http_request(addr, "POST", path, body).unwrap();
    let text = String::from_utf8_lossy(&body).into_owned();
    (code, Value::parse(&text).unwrap_or(Value::Null))
}

fn submit(addr: &str, spec: &str, query: &str) -> String {
    let path = if query.is_empty() {
        "/jobs".to_string()
    } else {
        format!("/jobs?{query}")
    };
    let (code, v) = post(addr, &path, Some(spec.as_bytes()));
    assert_eq!(code, 200, "submit failed: {v:?}");
    v.str_field("job").unwrap().to_string()
}

fn status(addr: &str, id: &str) -> Value {
    let (code, v) = get_json(addr, &format!("/jobs/{id}"));
    assert_eq!(code, 200, "status {id}: {v:?}");
    v
}

fn wait_done(addr: &str, id: &str) -> Value {
    let deadline = Instant::now() + Duration::from_secs(300);
    loop {
        let v = status(addr, id);
        let state = v.str_field("state").unwrap().to_string();
        if matches!(state.as_str(), "done" | "failed" | "cancelled")
            && v.usize_field("running").unwrap() == 0
        {
            return v;
        }
        assert!(Instant::now() < deadline, "timeout waiting on {id}: {v:?}");
        std::thread::sleep(Duration::from_millis(5));
    }
}

/// `(job id, cell index)` dispatch history via `GET /scheduler`.
fn dispatch_log(addr: &str) -> Vec<(String, usize)> {
    let (code, v) = get_json(addr, "/scheduler");
    assert_eq!(code, 200);
    v.arr_field("dispatched")
        .unwrap()
        .iter()
        .map(|e| {
            (
                e.str_field("job").unwrap().to_string(),
                e.usize_field("cell").unwrap(),
            )
        })
        .collect()
}

#[test]
fn submit_streams_and_caches_bitwise_identically() {
    let (mut srv, addr, dir) = server("serve_cache", false);
    let spec = spec_json(42, &[4], &["d_ring", "d_complete"], 1, 2);
    let first = submit(&addr, &spec, "");
    let done = wait_done(&addr, &first);
    assert_eq!(done.str_field("state").unwrap(), "done");
    assert_eq!(done.usize_field("done").unwrap(), 2);
    assert_eq!(done.usize_field("cached").unwrap(), 0, "cold store");

    // Results document: complete, one non-null entry per cell, records
    // parse back into iteration records.
    let (code, results) = get_json(&addr, &format!("/jobs/{first}/results"));
    assert_eq!(code, 200);
    assert_eq!(results.get("complete"), Some(&Value::Bool(true)));
    let cells = results.arr_field("cells").unwrap();
    assert_eq!(cells.len(), 2);
    for cell in cells {
        let records = cell.arr_field("records").unwrap();
        assert!(!records.is_empty());
        IterationRecord::from_json(&records[0]).unwrap();
    }

    // The JSONL stream replays the full history: cell_start /
    // iteration / epoch / cell_done per cell, then job_done last.
    let mut lines = Vec::new();
    let code = http_stream_lines(&addr, &format!("/jobs/{first}/stream"), |l| {
        lines.push(l.to_string());
    })
    .unwrap();
    assert_eq!(code, 200);
    let typed: Vec<(String, Value)> = lines
        .iter()
        .map(|l| {
            let v = Value::parse(l).unwrap();
            (v.str_field("type").unwrap().to_string(), v)
        })
        .collect();
    let count = |t: &str| typed.iter().filter(|(ty, _)| ty == t).count();
    assert_eq!(count("cell_start"), 2, "{lines:?}");
    assert_eq!(count("cell_done"), 2);
    assert_eq!(count("epoch"), 2, "one epoch per cell");
    assert!(count("iteration") >= 2);
    assert_eq!(typed.last().unwrap().0, "job_done");
    for (ty, v) in &typed {
        if ty == "iteration" {
            let rec = IterationRecord::from_json(v.get("record").unwrap()).unwrap();
            assert!(rec.train_loss.is_finite());
            assert!(v.usize_field("cell").unwrap() < 2);
        }
    }

    // Identical resubmission: fresh job id, zero re-execution, and a
    // results document that is byte-for-byte the first one.
    let second = submit(&addr, &spec, "");
    assert_ne!(second, first, "dedup suffix separates the ids");
    let done2 = wait_done(&addr, &second);
    assert_eq!(done2.usize_field("cached").unwrap(), 2, "100% cache hit");
    let (_, body1) = http_request(&addr, "GET", &format!("/jobs/{first}/results"), None).unwrap();
    let (_, body2) = http_request(&addr, "GET", &format!("/jobs/{second}/results"), None).unwrap();
    assert_eq!(body1, body2, "cached results must be bitwise identical");

    // The cached job's stream still carries cell_done (cached: true)
    // markers and a job_done terminator — no iteration lines.
    let mut cached_lines = Vec::new();
    http_stream_lines(&addr, &format!("/jobs/{second}/stream"), |l| {
        cached_lines.push(Value::parse(l).unwrap());
    })
    .unwrap();
    let cached_done: Vec<_> = cached_lines
        .iter()
        .filter(|v| v.str_field("type").unwrap() == "cell_done")
        .collect();
    assert_eq!(cached_done.len(), 2);
    for v in cached_done {
        assert_eq!(v.get("cached"), Some(&Value::Bool(true)));
    }

    let (_, store) = get_json(&addr, "/store");
    assert_eq!(store.usize_field("objects").unwrap(), 2);
    assert!(store.usize_field("hits").unwrap() >= 2);

    let (code, _) = post(&addr, "/shutdown", None);
    assert_eq!(code, 200);
    srv.join();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn fair_share_interleaves_jobs_by_weight() {
    let (srv, addr, dir) = server("serve_fair", true);
    // Both 4-cell jobs land while the dispatch gate is closed, so the
    // interleaving is a pure function of the scheduling rule.
    let a = submit(&addr, &spec_json(100, &[4, 8], &["d_ring", "d_complete"], 1, 2), "weight=1");
    let b = submit(&addr, &spec_json(200, &[4, 8], &["d_ring", "d_complete"], 1, 2), "weight=2");
    let (code, _) = post(&addr, "/scheduler/resume", None);
    assert_eq!(code, 200);
    wait_done(&addr, &a);
    wait_done(&addr, &b);
    let log = dispatch_log(&addr);
    let pattern: String = log
        .iter()
        .map(|(id, _)| if *id == a { 'a' } else { 'b' })
        .collect();
    // Weight 2 earns two cells per weight-1 cell; ties break by
    // submission order: a b b a b b a a.
    assert_eq!(pattern, "abbabbaa", "{log:?}");
    // Within each job, cells dispatch in enumeration order.
    for id in [&a, &b] {
        let cells: Vec<usize> =
            log.iter().filter(|(j, _)| j == id).map(|(_, c)| *c).collect();
        assert_eq!(cells, vec![0, 1, 2, 3]);
    }
    srv.shutdown(true);
    drop(srv);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn high_priority_job_preempts_a_running_sweep() {
    let (srv, addr, dir) = server("serve_prio", true);
    // A low-priority 6-cell sweep with slow-ish cells (so the pause
    // lands before the sweep drains).
    let a = submit(
        &addr,
        &spec_json(300, &[4, 8, 12], &["d_ring", "d_complete"], 4, 150),
        "",
    );
    post(&addr, "/scheduler/resume", None);
    // Let at least one cell dispatch, then close the gate mid-sweep.
    let deadline = Instant::now() + Duration::from_secs(60);
    while dispatch_log(&addr).is_empty() {
        assert!(Instant::now() < deadline, "first dispatch never happened");
        std::thread::sleep(Duration::from_millis(1));
    }
    post(&addr, "/scheduler/pause", None);
    // Drain the in-flight cell so the log is stable at the gate.
    while status(&addr, &a).usize_field("running").unwrap() > 0 {
        assert!(Instant::now() < deadline, "in-flight cell never finished");
        std::thread::sleep(Duration::from_millis(5));
    }
    let k = dispatch_log(&addr).len();
    assert!(k < 6, "sweep drained before the pause landed (k = {k})");
    // A higher-priority job arrives mid-sweep...
    let b = submit(&addr, &spec_json(400, &[4], &["d_ring", "d_complete"], 1, 2), "priority=5");
    post(&addr, "/scheduler/resume", None);
    wait_done(&addr, &b);
    wait_done(&addr, &a);
    // ...and its cells dispatch before every remaining low-priority cell.
    let log = dispatch_log(&addr);
    assert_eq!(log.len(), 8);
    assert_eq!(log[k].0, b, "{log:?}");
    assert_eq!(log[k + 1].0, b, "{log:?}");
    for (i, (id, _)) in log.iter().enumerate() {
        if i != k && i != k + 1 {
            assert_eq!(id, &a, "{log:?}");
        }
    }
    srv.shutdown(true);
    drop(srv);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn cancellation_stops_within_one_cell_and_never_poisons_the_store() {
    let (srv, addr, dir) = server("serve_cancel", true);
    // Slow cells at larger scales: cancellation reliably lands while
    // cell 0 is still running.
    let spec = spec_json(500, &[24], &["d_ring", "d_complete", "d_exponential", "one_peer"], 5, 120);
    let a = submit(&addr, &spec, "");
    post(&addr, "/scheduler/resume", None);
    let deadline = Instant::now() + Duration::from_secs(60);
    while dispatch_log(&addr).is_empty() {
        assert!(Instant::now() < deadline, "first dispatch never happened");
        std::thread::sleep(Duration::from_millis(1));
    }
    let (code, v) = post(&addr, &format!("/jobs/{a}/cancel"), None);
    assert_eq!(code, 200, "{v:?}");
    let done = wait_done(&addr, &a);
    assert_eq!(done.str_field("state").unwrap(), "cancelled");
    let after_cancel = dispatch_log(&addr);
    assert!(
        after_cancel.len() < 4,
        "cancel must stop dispatch within one cell: {after_cancel:?}"
    );
    // No further dispatches ever happen for the cancelled job.
    std::thread::sleep(Duration::from_millis(50));
    assert_eq!(dispatch_log(&addr), after_cancel);
    let a_done = done.usize_field("done").unwrap();
    // Resubmitting the identical spec proves the store holds exactly
    // the cells that *finished* — the interrupted cell's partial result
    // was discarded, so it re-runs rather than serving truncated data.
    let c = submit(&addr, &spec, "");
    let c_done = wait_done(&addr, &c);
    assert_eq!(c_done.str_field("state").unwrap(), "done");
    assert_eq!(c_done.usize_field("done").unwrap(), 4);
    assert_eq!(
        c_done.usize_field("cached").unwrap(),
        a_done,
        "cache hits must equal the cancelled job's finished cells"
    );
    srv.shutdown(true);
    drop(srv);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn toml_specs_bad_bodies_and_unknown_jobs() {
    let (mut srv, addr, dir) = server("serve_errors", false);
    // Malformed body → 400 with an error message.
    let (code, v) = post(&addr, "/jobs", Some(b"{not a spec"));
    assert_eq!(code, 400);
    assert!(v.str_field("error").is_ok(), "{v:?}");
    // A TOML body works through the same endpoint (sniffed encoding).
    let toml = "base = \"resnet20\"\nseed = 7\nscales = [4]\nepochs = 1\n\
                max_iters_per_epoch = 2\nthreads = 1\nflavors = [\"d_ring\"]\n";
    let id = submit(&addr, toml, "");
    let done = wait_done(&addr, &id);
    assert_eq!(done.str_field("state").unwrap(), "done");
    assert_eq!(done.usize_field("total").unwrap(), 1);
    // Unknown ids → 404 on every job route.
    for path in ["/jobs/nope", "/jobs/nope/results", "/jobs/nope/stream"] {
        let (code, _) = get_json(&addr, path);
        assert_eq!(code, 404, "{path}");
    }
    let (code, _) = post(&addr, "/jobs/nope/cancel", None);
    assert_eq!(code, 404);
    // Unknown routes → 404, unknown methods → 405.
    let (code, _) = get_json(&addr, "/definitely/not/a/route");
    assert_eq!(code, 404);
    let (code, _) = http_request(&addr, "PUT", "/jobs", None).unwrap();
    assert_eq!(code, 405);
    // Server info endpoints respond.
    let (code, v) = get_json(&addr, "/healthz");
    assert_eq!(code, 200);
    assert_eq!(v.get("ok"), Some(&Value::Bool(true)));
    let (code, _) = post(&addr, "/shutdown", None);
    assert_eq!(code, 200);
    srv.join();
    let _ = std::fs::remove_dir_all(&dir);
}
