//! Determinism property tests for the multi-threaded execution engine:
//! `mix`, `mix_active`, the fused `mix_step`/`mix_active_step`, and the
//! pooled reductions (`run_reduce`, the trainer's variance capture)
//! must produce **bit-identical** output for 1, 2, 4 and 8 threads on
//! every [`GraphKind`], and the fused kernels must agree with their
//! split sequences within 1e-6 (exactly, off the complete-graph fast
//! path). Also proves the persistent-pool lifecycle contract: workers
//! are spawned once, reused across calls without drift, and joined on
//! drop. This is the contract that makes `--threads` a pure wall-clock
//! knob — see `rust/src/exec/mod.rs` for the argument.

use ada_dist::exec::{ExecEngine, REDUCE_GRANULARITY};
use ada_dist::gossip::GossipEngine;
use ada_dist::graph::{CommGraph, GraphKind};
use ada_dist::metrics::per_replica_l2_norms_pooled;
use ada_dist::optim::SgdState;
use ada_dist::util::rng::Rng;
use std::sync::atomic::Ordering;
use std::sync::Mutex;

const THREAD_COUNTS: [usize; 4] = [1, 2, 4, 8];

/// Every graph family the crate can build, at an n that satisfies all
/// of their constraints (16 = power of two, 4×4 torus, 2k < n, …).
fn all_kinds() -> Vec<GraphKind> {
    vec![
        GraphKind::Ring,
        GraphKind::Torus,
        GraphKind::RingLattice { k: 3 },
        GraphKind::AdaLattice { k: 4 },
        GraphKind::Exponential,
        GraphKind::Complete,
        GraphKind::Hypercube,
        GraphKind::RandomRegular { d: 4, seed: 11 },
    ]
}

fn replicas(n: usize, p: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::seed_from_u64(seed);
    (0..n)
        .map(|_| (0..p).map(|_| rng.range_f32(-1.0, 1.0)).collect())
        .collect()
}

// P just above two tile widths so 4- and 8-thread runs split unevenly
// (the interesting case for tile-boundary bugs).
const P: usize = 2 * 4096 + 137;
const N: usize = 16;

#[test]
fn mix_is_bit_identical_for_every_thread_count_and_graph() {
    for (case, kind) in all_kinds().into_iter().enumerate() {
        let g = CommGraph::build(kind, N).unwrap();
        let src = replicas(N, P, 100 + case as u64);
        let mut reference: Option<Vec<Vec<f32>>> = None;
        for threads in THREAD_COUNTS {
            let mut reps = src.clone();
            let mut engine = GossipEngine::with_threads(threads);
            // Two rounds so scratch reuse is exercised too.
            engine.mix(&g, &mut reps);
            engine.mix(&g, &mut reps);
            match &reference {
                None => reference = Some(reps),
                Some(want) => assert_eq!(
                    want, &reps,
                    "{kind}: mix not bit-identical at {threads} threads"
                ),
            }
        }
    }
}

#[test]
fn mix_active_is_bit_identical_for_every_thread_count_and_graph() {
    for (case, kind) in all_kinds().into_iter().enumerate() {
        let g = CommGraph::build(kind, N).unwrap();
        let src = replicas(N, P, 200 + case as u64);
        // Deterministic mask with a mix of active and inactive rows.
        let active: Vec<bool> = (0..N).map(|i| i % 3 != 1).collect();
        let mut reference: Option<Vec<Vec<f32>>> = None;
        for threads in THREAD_COUNTS {
            let mut reps = src.clone();
            GossipEngine::with_threads(threads).mix_active(&g, &mut reps, &active);
            match &reference {
                None => reference = Some(reps),
                Some(want) => assert_eq!(
                    want, &reps,
                    "{kind}: mix_active not bit-identical at {threads} threads"
                ),
            }
        }
    }
}

#[test]
fn fused_step_is_bit_identical_for_every_thread_count_and_graph() {
    for (case, kind) in all_kinds().into_iter().enumerate() {
        let g = CommGraph::build(kind, N).unwrap();
        let src = replicas(N, P, 300 + case as u64);
        let grads = replicas(N, P, 400 + case as u64);
        let mut reference: Option<(Vec<Vec<f32>>, Vec<Vec<f32>>)> = None;
        for threads in THREAD_COUNTS {
            let mut reps = src.clone();
            let mut states: Vec<SgdState> =
                (0..N).map(|_| SgdState::new(P, 0.9, 1e-4)).collect();
            let mut engine = GossipEngine::with_threads(threads);
            // Two rounds so momentum accumulation is exercised.
            engine.mix_step(&g, &mut reps, &grads, &mut states, 0.05);
            engine.mix_step(&g, &mut reps, &grads, &mut states, 0.05);
            let vels: Vec<Vec<f32>> = states.iter().map(|s| s.velocity().to_vec()).collect();
            match &reference {
                None => reference = Some((reps, vels)),
                Some((want_p, want_v)) => {
                    assert_eq!(
                        want_p, &reps,
                        "{kind}: fused params not bit-identical at {threads} threads"
                    );
                    assert_eq!(
                        want_v, &vels,
                        "{kind}: fused velocity not bit-identical at {threads} threads"
                    );
                }
            }
        }
    }
}

#[test]
fn fused_equals_split_mix_then_step_within_1e6() {
    // The fused kernel's semantic contract: mix_step ≡ mix followed by
    // SgdState::step. Exact off the complete-graph fast path; within
    // float rounding (≪ 1e-6) on it.
    for (case, kind) in all_kinds().into_iter().enumerate() {
        let g = CommGraph::build(kind, N).unwrap();
        let src = replicas(N, P, 500 + case as u64);
        let grads = replicas(N, P, 600 + case as u64);
        let (mu, wd, lr) = (0.9f32, 1e-4f32, 0.05f32);

        let mut split = src.clone();
        let mut split_states: Vec<SgdState> =
            (0..N).map(|_| SgdState::new(P, mu, wd)).collect();
        let mut split_engine = GossipEngine::with_threads(4);
        let mut fused = src.clone();
        let mut fused_states: Vec<SgdState> =
            (0..N).map(|_| SgdState::new(P, mu, wd)).collect();
        let mut fused_engine = GossipEngine::with_threads(4);

        for _round in 0..3 {
            split_engine.mix(&g, &mut split);
            for (r, s) in split.iter_mut().zip(split_states.iter_mut()) {
                s.step(r, &grads[0], lr);
            }
            let gs: Vec<Vec<f32>> = (0..N).map(|_| grads[0].clone()).collect();
            fused_engine.mix_step(&g, &mut fused, &gs, &mut fused_states, lr);
        }
        for i in 0..N {
            for k in 0..P {
                let (a, b) = (split[i][k], fused[i][k]);
                assert!(
                    (a - b).abs() < 1e-6,
                    "{kind}: fused vs split diverge at [{i}][{k}]: {a} vs {b}"
                );
            }
        }
    }
}

#[test]
fn mix_active_with_full_mask_equals_mix() {
    // The all-active fast path must route to plain mix (same bits).
    let g = CommGraph::build(GraphKind::RingLattice { k: 3 }, N).unwrap();
    let src = replicas(N, P, 700);
    let mut via_mix = src.clone();
    GossipEngine::with_threads(4).mix(&g, &mut via_mix);
    let mut via_active = src.clone();
    GossipEngine::with_threads(4).mix_active(&g, &mut via_active, &vec![true; N]);
    assert_eq!(via_mix, via_active);
}

// ---------------------------------------------------------------------
// Deterministic reductions (PR 2): sum / L2 / variance partials over
// fixed-granularity tiles must not move with the worker count.
// ---------------------------------------------------------------------

#[test]
fn reductions_are_bit_identical_for_every_thread_count() {
    let data = replicas(1, P, 800).pop().unwrap();
    let run = |threads: usize| {
        let e = ExecEngine::new(threads);
        let sum = e.run_reduce(
            P,
            REDUCE_GRANULARITY,
            |t| data[t].iter().map(|&x| x as f64).sum::<f64>(),
            |a, b| a + b,
            0.0,
        );
        let l2 = e
            .run_reduce(
                P,
                REDUCE_GRANULARITY,
                |t| data[t].iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>(),
                |a, b| a + b,
                0.0,
            )
            .sqrt();
        // Population variance from (Σx, Σx², count) tile partials.
        let (s, ss, c) = e.run_reduce(
            P,
            REDUCE_GRANULARITY,
            |t| {
                let (mut s, mut ss) = (0.0f64, 0.0f64);
                let len = t.len() as f64;
                for &x in &data[t] {
                    let x = x as f64;
                    s += x;
                    ss += x * x;
                }
                (s, ss, len)
            },
            |a: (f64, f64, f64), b| (a.0 + b.0, a.1 + b.1, a.2 + b.2),
            (0.0, 0.0, 0.0),
        );
        let var = ss / c - (s / c) * (s / c);
        (sum.to_bits(), l2.to_bits(), var.to_bits())
    };
    let reference = run(1);
    for threads in THREAD_COUNTS {
        assert_eq!(
            reference,
            run(threads),
            "sum/L2/variance reduction differs at {threads} threads"
        );
    }
}

#[test]
fn pooled_variance_capture_is_bit_identical_across_thread_counts() {
    // The trainer's actual capture primitive, full-model and sliced.
    let reps = replicas(N, P, 850);
    let reference = per_replica_l2_norms_pooled(&ExecEngine::serial(), &reps, 0..P);
    let ref_slice = per_replica_l2_norms_pooled(&ExecEngine::serial(), &reps, 137..P - 99);
    for threads in THREAD_COUNTS {
        let e = ExecEngine::new(threads);
        assert_eq!(reference, per_replica_l2_norms_pooled(&e, &reps, 0..P));
        assert_eq!(ref_slice, per_replica_l2_norms_pooled(&e, &reps, 137..P - 99));
    }
}

// ---------------------------------------------------------------------
// Fused partial-participation kernel (PR 2).
// ---------------------------------------------------------------------

#[test]
fn fused_active_step_is_bit_identical_for_every_thread_count_and_graph() {
    for (case, kind) in all_kinds().into_iter().enumerate() {
        let g = CommGraph::build(kind, N).unwrap();
        let src = replicas(N, P, 900 + case as u64);
        let grads = replicas(N, P, 950 + case as u64);
        let active: Vec<bool> = (0..N).map(|i| i % 3 != 1).collect();
        let mut reference: Option<(Vec<Vec<f32>>, Vec<Vec<f32>>)> = None;
        for threads in THREAD_COUNTS {
            let mut reps = src.clone();
            let mut states: Vec<SgdState> =
                (0..N).map(|_| SgdState::new(P, 0.9, 1e-4)).collect();
            let mut engine = GossipEngine::with_threads(threads);
            engine.mix_active_step(&g, &mut reps, &grads, &mut states, 0.05, &active);
            engine.mix_active_step(&g, &mut reps, &grads, &mut states, 0.05, &active);
            let vels: Vec<Vec<f32>> = states.iter().map(|s| s.velocity().to_vec()).collect();
            match &reference {
                None => reference = Some((reps, vels)),
                Some((want_p, want_v)) => {
                    assert_eq!(
                        want_p, &reps,
                        "{kind}: fused active params not bit-identical at {threads} threads"
                    );
                    assert_eq!(
                        want_v, &vels,
                        "{kind}: fused active velocity not bit-identical at {threads} threads"
                    );
                }
            }
        }
    }
}

#[test]
fn fused_active_step_equals_split_within_1e6_under_partial_participation() {
    // mix_active_step ≡ mix_active followed by SgdState::step on every
    // replica (inactive rows miss the exchange but still step).
    for (case, kind) in all_kinds().into_iter().enumerate() {
        let g = CommGraph::build(kind, N).unwrap();
        let src = replicas(N, P, 1000 + case as u64);
        let grads = replicas(N, P, 1100 + case as u64);
        let active: Vec<bool> = (0..N).map(|i| i % 4 != 2).collect();
        let (mu, wd, lr) = (0.9f32, 1e-4f32, 0.05f32);

        let mut split = src.clone();
        let mut split_states: Vec<SgdState> =
            (0..N).map(|_| SgdState::new(P, mu, wd)).collect();
        let mut split_engine = GossipEngine::with_threads(4);
        let mut fused = src.clone();
        let mut fused_states: Vec<SgdState> =
            (0..N).map(|_| SgdState::new(P, mu, wd)).collect();
        let mut fused_engine = GossipEngine::with_threads(4);

        for _round in 0..3 {
            split_engine.mix_active(&g, &mut split, &active);
            for (w, s) in split_states.iter_mut().enumerate() {
                s.step(&mut split[w], &grads[w], lr);
            }
            fused_engine.mix_active_step(&g, &mut fused, &grads, &mut fused_states, lr, &active);
        }
        for i in 0..N {
            for k in 0..P {
                let (a, b) = (split[i][k], fused[i][k]);
                assert!(
                    (a - b).abs() < 1e-6,
                    "{kind}: fused active vs split diverge at [{i}][{k}]: {a} vs {b}"
                );
            }
        }
    }
}

// ---------------------------------------------------------------------
// Persistent-pool lifecycle (PR 2): spawn once, reuse without drift,
// join on drop.
// ---------------------------------------------------------------------

#[test]
fn pool_is_reused_across_100_calls_without_drift() {
    let engine = ExecEngine::new(4);
    let data = replicas(1, P, 1200).pop().unwrap();
    let observed = Mutex::new(std::collections::HashSet::new());
    let mut reference: Option<u64> = None;
    for call in 0..100 {
        // Record which threads execute jobs this call.
        {
            let ranges = engine.partition(P, 1);
            let observed = &observed;
            let jobs: Vec<_> = ranges
                .iter()
                .map(|_| {
                    move || {
                        observed.lock().unwrap().insert(std::thread::current().id());
                    }
                })
                .collect();
            engine.run_jobs(jobs);
        }
        // And that the reduction result never drifts.
        let sum = engine.run_reduce(
            P,
            REDUCE_GRANULARITY,
            |t| data[t].iter().map(|&x| x as f64).sum::<f64>(),
            |a, b| a + b,
            0.0,
        );
        match reference {
            None => reference = Some(sum.to_bits()),
            Some(want) => assert_eq!(want, sum.to_bits(), "drift at call {call}"),
        }
    }
    // 100 calls × 4 jobs ran on at most 4 distinct threads: the caller
    // plus the 3 pool workers spawned at construction — nothing was
    // spawned per call.
    let ids = observed.lock().unwrap().len();
    assert!(ids <= 4, "expected ≤ 4 executing threads over 100 calls, saw {ids}");
    // And the pool itself reports exactly the workers spawned once.
    let live = engine.pool_liveness().expect("pooled engine");
    assert_eq!(live.load(Ordering::SeqCst), 3);
}

#[test]
fn engine_drop_joins_all_workers() {
    let engine = ExecEngine::new(8);
    let live = engine.pool_liveness().expect("pooled engine");
    // Exercise the pool before dropping.
    let total = engine.run_reduce(
        10_000,
        64,
        |t| t.len() as f64,
        |a, b| a + b,
        0.0,
    );
    assert_eq!(total, 10_000.0);
    assert_eq!(live.load(Ordering::SeqCst), 7, "8-thread engine = 7 pool workers");
    drop(engine);
    assert_eq!(
        live.load(Ordering::SeqCst),
        0,
        "dropping the engine must join every worker (no thread leak)"
    );
}

#[test]
fn gossip_engine_spawns_workers_exactly_once() {
    // The acceptance criterion end to end: a GossipEngine's pool
    // survives (and is reused by) many mixed-kernel rounds.
    let g = CommGraph::build(GraphKind::RingLattice { k: 3 }, N).unwrap();
    let mut engine = GossipEngine::with_threads(4);
    let live = engine.exec().pool_liveness().expect("pooled engine");
    let mut reps = replicas(N, P, 1300);
    let grads = replicas(N, P, 1301);
    let mut states: Vec<SgdState> = (0..N).map(|_| SgdState::new(P, 0.9, 0.0)).collect();
    let active: Vec<bool> = (0..N).map(|i| i != 3).collect();
    for _ in 0..25 {
        engine.mix(&g, &mut reps);
        engine.mix_step(&g, &mut reps, &grads, &mut states, 0.01);
        engine.mix_active(&g, &mut reps, &active);
        engine.mix_active_step(&g, &mut reps, &grads, &mut states, 0.01, &active);
    }
    assert_eq!(
        live.load(Ordering::SeqCst),
        3,
        "100 kernel calls must reuse the 3 workers spawned at construction"
    );
}
