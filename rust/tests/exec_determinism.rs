//! Determinism property tests for the multi-threaded execution engine
//! and the explicit SIMD kernel layer: `mix`, `mix_active`, the fused
//! `mix_step`/`mix_active_step`, and the pooled reductions
//! (`run_reduce`, the trainer's variance capture) must produce
//! **bit-identical** output for 1, 2, 4 and 8 threads on every
//! [`GraphKind`] — and, since the flat-store refactor, for both the
//! AVX2 path and the fixed-8-lane scalar fallback
//! (`ada_dist::exec::simd`). The fused kernels must agree with their
//! split sequences within 1e-6 (exactly, off the complete-graph fast
//! path), and the engine must agree with the pre-refactor
//! `Vec<Vec<f32>>` dense reference (`mix_dense_reference`) within float
//! tolerance. Also proves the persistent-pool lifecycle contract:
//! workers are spawned once, reused across calls without drift, and
//! joined on drop. This is the contract that makes `--threads` a pure
//! wall-clock knob — see `rust/src/exec/mod.rs` and
//! `rust/src/exec/simd.rs` for the argument.

use ada_dist::exec::{simd, ExecEngine, REDUCE_GRANULARITY};
use ada_dist::gossip::{mix_dense_reference, GossipEngine};
use ada_dist::graph::{CommGraph, GraphKind};
use ada_dist::metrics::per_replica_l2_norms_pooled;
use ada_dist::optim::SgdState;
use ada_dist::util::rng::Rng;
use ada_dist::ReplicaMatrix;
use std::sync::atomic::Ordering;
use std::sync::Mutex;

const THREAD_COUNTS: [usize; 4] = [1, 2, 4, 8];

/// Every graph family the crate can build, at an n that satisfies all
/// of their constraints (16 = power of two, 4×4 torus, 2k < n, …).
fn all_kinds() -> Vec<GraphKind> {
    vec![
        GraphKind::Ring,
        GraphKind::Torus,
        GraphKind::RingLattice { k: 3 },
        GraphKind::AdaLattice { k: 4 },
        GraphKind::Exponential,
        GraphKind::Complete,
        GraphKind::Hypercube,
        GraphKind::RandomRegular { d: 4, seed: 11 },
    ]
}

fn replicas(n: usize, p: usize, seed: u64) -> ReplicaMatrix {
    let mut rng = Rng::seed_from_u64(seed);
    let rows: Vec<Vec<f32>> = (0..n)
        .map(|_| (0..p).map(|_| rng.range_f32(-1.0, 1.0)).collect())
        .collect();
    ReplicaMatrix::from_rows(&rows)
}

fn flat(p: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::seed_from_u64(seed);
    (0..p).map(|_| rng.range_f32(-1.0, 1.0)).collect()
}

// P just above two tile widths so 4- and 8-thread runs split unevenly
// (the interesting case for tile-boundary bugs), and not a multiple of
// 8 so the SIMD remainder lanes are exercised.
const P: usize = 2 * 4096 + 137;
const N: usize = 16;

#[test]
fn mix_is_bit_identical_for_every_thread_count_and_graph() {
    for (case, kind) in all_kinds().into_iter().enumerate() {
        let g = CommGraph::build(kind, N).unwrap();
        let src = replicas(N, P, 100 + case as u64);
        let mut reference: Option<ReplicaMatrix> = None;
        for threads in THREAD_COUNTS {
            let mut reps = src.clone();
            let mut engine = GossipEngine::with_threads(threads);
            // Two rounds so scratch reuse is exercised too.
            engine.mix(&g, &mut reps);
            engine.mix(&g, &mut reps);
            match &reference {
                None => reference = Some(reps),
                Some(want) => assert_eq!(
                    want, &reps,
                    "{kind}: mix not bit-identical at {threads} threads"
                ),
            }
        }
    }
}

#[test]
fn mix_active_is_bit_identical_for_every_thread_count_and_graph() {
    for (case, kind) in all_kinds().into_iter().enumerate() {
        let g = CommGraph::build(kind, N).unwrap();
        let src = replicas(N, P, 200 + case as u64);
        // Deterministic mask with a mix of active and inactive rows.
        let active: Vec<bool> = (0..N).map(|i| i % 3 != 1).collect();
        let mut reference: Option<ReplicaMatrix> = None;
        for threads in THREAD_COUNTS {
            let mut reps = src.clone();
            GossipEngine::with_threads(threads).mix_active(&g, &mut reps, &active);
            match &reference {
                None => reference = Some(reps),
                Some(want) => assert_eq!(
                    want, &reps,
                    "{kind}: mix_active not bit-identical at {threads} threads"
                ),
            }
        }
    }
}

#[test]
fn fused_step_is_bit_identical_for_every_thread_count_and_graph() {
    for (case, kind) in all_kinds().into_iter().enumerate() {
        let g = CommGraph::build(kind, N).unwrap();
        let src = replicas(N, P, 300 + case as u64);
        let grads = replicas(N, P, 400 + case as u64);
        let mut reference: Option<(ReplicaMatrix, Vec<Vec<f32>>)> = None;
        for threads in THREAD_COUNTS {
            let mut reps = src.clone();
            let mut states: Vec<SgdState> =
                (0..N).map(|_| SgdState::new(P, 0.9, 1e-4)).collect();
            let mut engine = GossipEngine::with_threads(threads);
            // Two rounds so momentum accumulation is exercised.
            engine.mix_step(&g, &mut reps, &grads, &mut states, 0.05);
            engine.mix_step(&g, &mut reps, &grads, &mut states, 0.05);
            let vels: Vec<Vec<f32>> = states.iter().map(|s| s.velocity().to_vec()).collect();
            match &reference {
                None => reference = Some((reps, vels)),
                Some((want_p, want_v)) => {
                    assert_eq!(
                        want_p, &reps,
                        "{kind}: fused params not bit-identical at {threads} threads"
                    );
                    assert_eq!(
                        want_v, &vels,
                        "{kind}: fused velocity not bit-identical at {threads} threads"
                    );
                }
            }
        }
    }
}

#[test]
fn fused_equals_split_mix_then_step_within_1e6() {
    // The fused kernel's semantic contract: mix_step ≡ mix followed by
    // SgdState::step. Exact off the complete-graph fast path; within
    // float rounding (≪ 1e-6) on it.
    for (case, kind) in all_kinds().into_iter().enumerate() {
        let g = CommGraph::build(kind, N).unwrap();
        let src = replicas(N, P, 500 + case as u64);
        let shared_grad = flat(P, 600 + case as u64);
        let (mu, wd, lr) = (0.9f32, 1e-4f32, 0.05f32);

        let mut split = src.clone();
        let mut split_states: Vec<SgdState> =
            (0..N).map(|_| SgdState::new(P, mu, wd)).collect();
        let mut split_engine = GossipEngine::with_threads(4);
        let mut fused = src.clone();
        let mut fused_states: Vec<SgdState> =
            (0..N).map(|_| SgdState::new(P, mu, wd)).collect();
        let mut fused_engine = GossipEngine::with_threads(4);
        let gs = ReplicaMatrix::broadcast(N, &shared_grad);

        for _round in 0..3 {
            split_engine.mix(&g, &mut split);
            for (w, s) in split_states.iter_mut().enumerate() {
                s.step(split.row_mut(w), &shared_grad, lr);
            }
            fused_engine.mix_step(&g, &mut fused, &gs, &mut fused_states, lr);
        }
        for i in 0..N {
            for k in 0..P {
                let (a, b) = (split[i][k], fused[i][k]);
                assert!(
                    (a - b).abs() < 1e-6,
                    "{kind}: fused vs split diverge at [{i}][{k}]: {a} vs {b}"
                );
            }
        }
    }
}

#[test]
fn mix_active_with_full_mask_equals_mix() {
    // The all-active fast path must route to plain mix (same bits).
    let g = CommGraph::build(GraphKind::RingLattice { k: 3 }, N).unwrap();
    let src = replicas(N, P, 700);
    let mut via_mix = src.clone();
    GossipEngine::with_threads(4).mix(&g, &mut via_mix);
    let mut via_active = src.clone();
    GossipEngine::with_threads(4).mix_active(&g, &mut via_active, &[true; N]);
    assert_eq!(via_mix, via_active);
}

// ---------------------------------------------------------------------
// Explicit SIMD layer (PR 4): the AVX2 path and the fixed-8-lane scalar
// fallback must be bit-identical — per kernel, and end-to-end through
// every gossip kernel on every graph at every thread count — and the
// engine must still match the pre-refactor Vec<Vec<f32>> dense
// reference.
// ---------------------------------------------------------------------

/// Serializes the tests that flip the process-global scalar override so
/// they cannot interleave with each other.
static SIMD_MODE_LOCK: Mutex<()> = Mutex::new(());

#[test]
fn simd_kernels_match_fixed_lane_scalar_bitwise() {
    // Remainder-heavy lengths included: the virtual-lane contract must
    // hold on partial final chunks too.
    for len in [0usize, 1, 7, 8, 9, 255, 4096, P] {
        let src = flat(len, 10);
        let mut a = flat(len, 11);
        let mut b = a.clone();
        simd::axpy(&mut a, &src, 0.731);
        simd::scalar::axpy(&mut b, &src, 0.731);
        assert_eq!(a, b, "axpy len {len}");

        let mut a = vec![0.0f32; len];
        let mut b = vec![0.0f32; len];
        simd::scale(&mut a, &src, -0.125);
        simd::scalar::scale(&mut b, &src, -0.125);
        assert_eq!(a, b, "scale len {len}");

        let g = flat(len, 12);
        let (mut pa, mut va) = (flat(len, 13), flat(len, 14));
        let (mut pb, mut vb) = (pa.clone(), va.clone());
        simd::sgd_step(&mut pa, &mut va, &g, 0.9, 1e-4, 0.05);
        simd::scalar::sgd_step(&mut pb, &mut vb, &g, 0.9, 1e-4, 0.05);
        assert_eq!(pa, pb, "sgd params len {len}");
        assert_eq!(va, vb, "sgd velocity len {len}");

        assert_eq!(
            simd::sumsq_f64(&src).to_bits(),
            simd::scalar::sumsq_f64(&src).to_bits(),
            "sumsq_f64 len {len}"
        );
        assert_eq!(
            simd::sumsq_f32(&src).to_bits(),
            simd::scalar::sumsq_f32(&src).to_bits(),
            "sumsq_f32 len {len}"
        );
    }
}

#[test]
fn gossip_kernels_are_bit_identical_between_simd_and_forced_scalar() {
    // End-to-end: every kernel × every graph × serial and 4-thread
    // engines, AVX2 dispatch vs the forced scalar fallback.
    let _guard = SIMD_MODE_LOCK.lock().unwrap();
    for (case, kind) in all_kinds().into_iter().enumerate() {
        let g = CommGraph::build(kind, N).unwrap();
        let src = replicas(N, P, 2000 + case as u64);
        let grads = replicas(N, P, 2100 + case as u64);
        let active: Vec<bool> = (0..N).map(|i| i % 3 != 1).collect();

        let run = |threads: usize| {
            let mut reps = src.clone();
            let mut states: Vec<SgdState> =
                (0..N).map(|_| SgdState::new(P, 0.9, 1e-4)).collect();
            let mut engine = GossipEngine::with_threads(threads);
            engine.mix(&g, &mut reps);
            engine.mix_active(&g, &mut reps, &active);
            engine.mix_step(&g, &mut reps, &grads, &mut states, 0.05);
            engine.mix_active_step(&g, &mut reps, &grads, &mut states, 0.05, &active);
            let norms =
                per_replica_l2_norms_pooled(engine.exec(), &reps, 0..P);
            (reps, norms)
        };

        simd::force_scalar(false);
        let auto_serial = run(1);
        let auto_pooled = run(4);
        simd::force_scalar(true);
        let scalar_serial = run(1);
        let scalar_pooled = run(4);
        simd::force_scalar(false);

        assert_eq!(auto_serial, scalar_serial, "{kind}: serial SIMD vs scalar");
        assert_eq!(auto_pooled, scalar_pooled, "{kind}: pooled SIMD vs scalar");
        assert_eq!(auto_serial, auto_pooled, "{kind}: serial vs 4 threads");
    }
}

#[test]
fn engine_matches_pre_refactor_dense_reference_on_every_graph() {
    // The flat-store engine vs the kept Vec<Vec<f32>> reference
    // implementation (different summation grouping ⇒ tolerance, not
    // bits), across thread counts.
    for (case, kind) in all_kinds().into_iter().enumerate() {
        let g = CommGraph::build(kind, N).unwrap();
        let src = replicas(N, 513, 2200 + case as u64);
        let want = mix_dense_reference(&g, &src.to_vecs());
        for threads in [1usize, 4] {
            let mut reps = src.clone();
            GossipEngine::with_threads(threads).mix(&g, &mut reps);
            for i in 0..N {
                for k in 0..513 {
                    assert!(
                        (reps[i][k] - want[i][k]).abs() < 1e-5,
                        "{kind} @ {threads}t: dense-reference mismatch at [{i}][{k}]"
                    );
                }
            }
        }
    }
}

#[test]
fn checkpoint_roundtrips_the_flat_replica_store() {
    // ReplicaMatrix → .ckpt → ReplicaMatrix is bit-exact, including a
    // padded stride (P is not a multiple of 16).
    use ada_dist::coordinator::Checkpoint;
    let dir = ada_dist::util::scratch_dir("exec_det_ckpt").unwrap();
    let path = dir.join("flat.ckpt");
    let ck = Checkpoint {
        epoch: 3,
        flavor: "D_ring".into(),
        seed: 99,
        replicas: replicas(N, P, 2300),
    };
    assert!(ck.replicas.stride() > P, "P must exercise stride padding");
    ck.save(&path).unwrap();
    let back = Checkpoint::load(&path).unwrap();
    assert_eq!(ck, back, "checkpoint roundtrip must be bit-exact");
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------
// Deterministic reductions (PR 2): sum / L2 / variance partials over
// fixed-granularity tiles must not move with the worker count.
// ---------------------------------------------------------------------

#[test]
fn reductions_are_bit_identical_for_every_thread_count() {
    let data = flat(P, 800);
    let run = |threads: usize| {
        let e = ExecEngine::new(threads);
        let sum = e.run_reduce(
            P,
            REDUCE_GRANULARITY,
            |t| data[t].iter().map(|&x| x as f64).sum::<f64>(),
            |a, b| a + b,
            0.0,
        );
        let l2 = e
            .run_reduce(
                P,
                REDUCE_GRANULARITY,
                |t| data[t].iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>(),
                |a, b| a + b,
                0.0,
            )
            .sqrt();
        // Population variance from (Σx, Σx², count) tile partials.
        let (s, ss, c) = e.run_reduce(
            P,
            REDUCE_GRANULARITY,
            |t| {
                let (mut s, mut ss) = (0.0f64, 0.0f64);
                let len = t.len() as f64;
                for &x in &data[t] {
                    let x = x as f64;
                    s += x;
                    ss += x * x;
                }
                (s, ss, len)
            },
            |a: (f64, f64, f64), b| (a.0 + b.0, a.1 + b.1, a.2 + b.2),
            (0.0, 0.0, 0.0),
        );
        let var = ss / c - (s / c) * (s / c);
        (sum.to_bits(), l2.to_bits(), var.to_bits())
    };
    let reference = run(1);
    for threads in THREAD_COUNTS {
        assert_eq!(
            reference,
            run(threads),
            "sum/L2/variance reduction differs at {threads} threads"
        );
    }
}

#[test]
fn pooled_variance_capture_is_bit_identical_across_thread_counts() {
    // The trainer's actual capture primitive, full-model and sliced.
    let reps = replicas(N, P, 850);
    let reference = per_replica_l2_norms_pooled(&ExecEngine::serial(), &reps, 0..P);
    let ref_slice = per_replica_l2_norms_pooled(&ExecEngine::serial(), &reps, 137..P - 99);
    for threads in THREAD_COUNTS {
        let e = ExecEngine::new(threads);
        assert_eq!(reference, per_replica_l2_norms_pooled(&e, &reps, 0..P));
        assert_eq!(ref_slice, per_replica_l2_norms_pooled(&e, &reps, 137..P - 99));
    }
}

// ---------------------------------------------------------------------
// Fused partial-participation kernel (PR 2).
// ---------------------------------------------------------------------

#[test]
fn fused_active_step_is_bit_identical_for_every_thread_count_and_graph() {
    for (case, kind) in all_kinds().into_iter().enumerate() {
        let g = CommGraph::build(kind, N).unwrap();
        let src = replicas(N, P, 900 + case as u64);
        let grads = replicas(N, P, 950 + case as u64);
        let active: Vec<bool> = (0..N).map(|i| i % 3 != 1).collect();
        let mut reference: Option<(ReplicaMatrix, Vec<Vec<f32>>)> = None;
        for threads in THREAD_COUNTS {
            let mut reps = src.clone();
            let mut states: Vec<SgdState> =
                (0..N).map(|_| SgdState::new(P, 0.9, 1e-4)).collect();
            let mut engine = GossipEngine::with_threads(threads);
            engine.mix_active_step(&g, &mut reps, &grads, &mut states, 0.05, &active);
            engine.mix_active_step(&g, &mut reps, &grads, &mut states, 0.05, &active);
            let vels: Vec<Vec<f32>> = states.iter().map(|s| s.velocity().to_vec()).collect();
            match &reference {
                None => reference = Some((reps, vels)),
                Some((want_p, want_v)) => {
                    assert_eq!(
                        want_p, &reps,
                        "{kind}: fused active params not bit-identical at {threads} threads"
                    );
                    assert_eq!(
                        want_v, &vels,
                        "{kind}: fused active velocity not bit-identical at {threads} threads"
                    );
                }
            }
        }
    }
}

#[test]
fn fused_active_step_equals_split_within_1e6_under_partial_participation() {
    // mix_active_step ≡ mix_active followed by SgdState::step on every
    // replica (inactive rows miss the exchange but still step).
    for (case, kind) in all_kinds().into_iter().enumerate() {
        let g = CommGraph::build(kind, N).unwrap();
        let src = replicas(N, P, 1000 + case as u64);
        let grads = replicas(N, P, 1100 + case as u64);
        let active: Vec<bool> = (0..N).map(|i| i % 4 != 2).collect();
        let (mu, wd, lr) = (0.9f32, 1e-4f32, 0.05f32);

        let mut split = src.clone();
        let mut split_states: Vec<SgdState> =
            (0..N).map(|_| SgdState::new(P, mu, wd)).collect();
        let mut split_engine = GossipEngine::with_threads(4);
        let mut fused = src.clone();
        let mut fused_states: Vec<SgdState> =
            (0..N).map(|_| SgdState::new(P, mu, wd)).collect();
        let mut fused_engine = GossipEngine::with_threads(4);

        for _round in 0..3 {
            split_engine.mix_active(&g, &mut split, &active);
            for (w, s) in split_states.iter_mut().enumerate() {
                s.step(split.row_mut(w), grads.row(w), lr);
            }
            fused_engine.mix_active_step(&g, &mut fused, &grads, &mut fused_states, lr, &active);
        }
        for i in 0..N {
            for k in 0..P {
                let (a, b) = (split[i][k], fused[i][k]);
                assert!(
                    (a - b).abs() < 1e-6,
                    "{kind}: fused active vs split diverge at [{i}][{k}]: {a} vs {b}"
                );
            }
        }
    }
}

// ---------------------------------------------------------------------
// Persistent-pool lifecycle (PR 2): spawn once, reuse without drift,
// join on drop.
// ---------------------------------------------------------------------

#[test]
fn pool_is_reused_across_100_calls_without_drift() {
    let engine = ExecEngine::new(4);
    let data = flat(P, 1200);
    let observed = Mutex::new(std::collections::HashSet::new());
    let mut reference: Option<u64> = None;
    for call in 0..100 {
        // Record which threads execute jobs this call.
        {
            let ranges = engine.partition(P, 1);
            let observed = &observed;
            let jobs: Vec<_> = ranges
                .iter()
                .map(|_| {
                    move || {
                        observed.lock().unwrap().insert(std::thread::current().id());
                    }
                })
                .collect();
            engine.run_jobs(jobs);
        }
        // And that the reduction result never drifts.
        let sum = engine.run_reduce(
            P,
            REDUCE_GRANULARITY,
            |t| data[t].iter().map(|&x| x as f64).sum::<f64>(),
            |a, b| a + b,
            0.0,
        );
        match reference {
            None => reference = Some(sum.to_bits()),
            Some(want) => assert_eq!(want, sum.to_bits(), "drift at call {call}"),
        }
    }
    // 100 calls × 4 jobs ran on at most 4 distinct threads: the caller
    // plus the 3 pool workers spawned at construction — nothing was
    // spawned per call.
    let ids = observed.lock().unwrap().len();
    assert!(ids <= 4, "expected ≤ 4 executing threads over 100 calls, saw {ids}");
    // And the pool itself reports exactly the workers spawned once.
    let live = engine.pool_liveness().expect("pooled engine");
    assert_eq!(live.load(Ordering::SeqCst), 3);
}

#[test]
fn engine_drop_joins_all_workers() {
    let engine = ExecEngine::new(8);
    let live = engine.pool_liveness().expect("pooled engine");
    // Exercise the pool before dropping.
    let total = engine.run_reduce(
        10_000,
        64,
        |t| t.len() as f64,
        |a, b| a + b,
        0.0,
    );
    assert_eq!(total, 10_000.0);
    assert_eq!(live.load(Ordering::SeqCst), 7, "8-thread engine = 7 pool workers");
    drop(engine);
    assert_eq!(
        live.load(Ordering::SeqCst),
        0,
        "dropping the engine must join every worker (no thread leak)"
    );
}

// ---------------------------------------------------------------------
// Overlapped bucketed pipeline (PR 6): the pipelined route must be
// **bit-identical** to the phase-ordered route — across thread counts,
// all four mix kernels, every graph family, and bucket sizes that do
// and do not divide P evenly. This is the determinism contract that
// makes `pipeline = true` (like `--threads`) a pure wall-clock knob;
// see `rust/src/exec/pipeline.rs` for the argument.
// ---------------------------------------------------------------------

const PIPELINE_THREADS: [usize; 3] = [1, 4, 8];
// 4096 leaves a short trailing bucket at P = 2·4096 + 137; 1000 cuts
// every tile off-alignment AND off the SIMD lane width.
const BUCKET_SIZES: [usize; 2] = [4096, 1000];

/// Deterministic stand-in for the local step: genuinely mutates the row
/// so the produce-while-mix interleaving is exercised, cheap enough to
/// run under every (graph × threads × bucket) combination.
fn sim_local_step(w: usize, row: &mut [f32]) {
    for (k, v) in row.iter_mut().enumerate() {
        *v += 0.01 * (w as f32 + 1.0) + 1e-4 * (k % 11) as f32;
    }
}

/// Deterministic stand-in for loss_and_grad at frozen θ_t.
fn sim_grad(w: usize, theta: &[f32], out: &mut [f32]) {
    for ((o, &t), k) in out.iter_mut().zip(theta).zip(0..) {
        *o = 0.1 * t + 1e-3 * ((w + k) % 7) as f32;
    }
}

#[test]
fn pipelined_mix_is_bit_identical_to_phased() {
    for (case, kind) in all_kinds().into_iter().enumerate() {
        let g = CommGraph::build(kind, N).unwrap();
        let src = replicas(N, P, 3000 + case as u64);

        let mut phased = src.clone();
        for w in 0..N {
            sim_local_step(w, phased.row_mut(w));
        }
        GossipEngine::with_threads(1).mix(&g, &mut phased);

        for threads in PIPELINE_THREADS {
            for bucket in BUCKET_SIZES {
                let mut piped = src.clone();
                let mut engine = GossipEngine::with_threads(threads);
                engine.set_bucket_elems(bucket);
                engine
                    .mix_overlapped(&g, &mut piped, None, |w, row| {
                        sim_local_step(w, row);
                        Ok(())
                    })
                    .unwrap();
                engine.publish_overlapped(&mut piped);
                assert_eq!(
                    phased, piped,
                    "{kind}: pipelined mix differs at {threads} threads, bucket {bucket}"
                );
            }
        }
    }
}

#[test]
fn pipelined_mix_active_is_bit_identical_to_phased() {
    for (case, kind) in all_kinds().into_iter().enumerate() {
        let g = CommGraph::build(kind, N).unwrap();
        let src = replicas(N, P, 3100 + case as u64);
        let active: Vec<bool> = (0..N).map(|i| i % 3 != 1).collect();

        let mut phased = src.clone();
        for w in 0..N {
            sim_local_step(w, phased.row_mut(w));
        }
        GossipEngine::with_threads(1).mix_active(&g, &mut phased, &active);

        for threads in PIPELINE_THREADS {
            for bucket in BUCKET_SIZES {
                let mut piped = src.clone();
                let mut engine = GossipEngine::with_threads(threads);
                engine.set_bucket_elems(bucket);
                engine
                    .mix_overlapped(&g, &mut piped, Some(&active), |w, row| {
                        sim_local_step(w, row);
                        Ok(())
                    })
                    .unwrap();
                engine.publish_overlapped(&mut piped);
                assert_eq!(
                    phased, piped,
                    "{kind}: pipelined mix_active differs at {threads} threads, bucket {bucket}"
                );
            }
        }
    }
}

#[test]
fn pipelined_fused_step_is_bit_identical_to_phased() {
    let (mu, wd, lr) = (0.9f32, 1e-4f32, 0.05f32);
    for (case, kind) in all_kinds().into_iter().enumerate() {
        let g = CommGraph::build(kind, N).unwrap();
        let src = replicas(N, P, 3200 + case as u64);

        let mut phased = src.clone();
        let mut phased_states: Vec<SgdState> =
            (0..N).map(|_| SgdState::new(P, mu, wd)).collect();
        let mut grads = ReplicaMatrix::zeros(N, P);
        for w in 0..N {
            let theta = phased.row(w).to_vec();
            sim_grad(w, &theta, grads.row_mut(w));
        }
        GossipEngine::with_threads(1).mix_step(&g, &mut phased, &grads, &mut phased_states, lr);

        for threads in PIPELINE_THREADS {
            for bucket in BUCKET_SIZES {
                let mut piped = src.clone();
                let mut states: Vec<SgdState> =
                    (0..N).map(|_| SgdState::new(P, mu, wd)).collect();
                let mut piped_grads = ReplicaMatrix::zeros(N, P);
                let mut engine = GossipEngine::with_threads(threads);
                engine.set_bucket_elems(bucket);
                engine
                    .mix_step_overlapped(
                        &g,
                        &piped,
                        &mut piped_grads,
                        &mut states,
                        lr,
                        None,
                        |w, theta, out| {
                            sim_grad(w, theta, out);
                            Ok(())
                        },
                    )
                    .unwrap();
                engine.publish_overlapped(&mut piped);
                assert_eq!(
                    phased, piped,
                    "{kind}: pipelined fused differs at {threads} threads, bucket {bucket}"
                );
                for (i, (a, b)) in phased_states.iter().zip(&states).enumerate() {
                    assert_eq!(
                        a.velocity(),
                        b.velocity(),
                        "{kind}: velocity {i} differs at {threads} threads, bucket {bucket}"
                    );
                }
            }
        }
    }
}

#[test]
fn pipelined_fused_active_step_is_bit_identical_to_phased() {
    let (mu, wd, lr) = (0.9f32, 1e-4f32, 0.05f32);
    for (case, kind) in all_kinds().into_iter().enumerate() {
        let g = CommGraph::build(kind, N).unwrap();
        let src = replicas(N, P, 3300 + case as u64);
        let active: Vec<bool> = (0..N).map(|i| i % 4 != 2).collect();

        let mut phased = src.clone();
        let mut phased_states: Vec<SgdState> =
            (0..N).map(|_| SgdState::new(P, mu, wd)).collect();
        let mut grads = ReplicaMatrix::zeros(N, P);
        for w in 0..N {
            let theta = phased.row(w).to_vec();
            sim_grad(w, &theta, grads.row_mut(w));
        }
        GossipEngine::with_threads(1).mix_active_step(
            &g,
            &mut phased,
            &grads,
            &mut phased_states,
            lr,
            &active,
        );

        for threads in PIPELINE_THREADS {
            for bucket in BUCKET_SIZES {
                let mut piped = src.clone();
                let mut states: Vec<SgdState> =
                    (0..N).map(|_| SgdState::new(P, mu, wd)).collect();
                let mut piped_grads = ReplicaMatrix::zeros(N, P);
                let mut engine = GossipEngine::with_threads(threads);
                engine.set_bucket_elems(bucket);
                engine
                    .mix_step_overlapped(
                        &g,
                        &piped,
                        &mut piped_grads,
                        &mut states,
                        lr,
                        Some(&active),
                        |w, theta, out| {
                            sim_grad(w, theta, out);
                            Ok(())
                        },
                    )
                    .unwrap();
                engine.publish_overlapped(&mut piped);
                assert_eq!(
                    phased, piped,
                    "{kind}: pipelined fused active differs at {threads} threads, bucket {bucket}"
                );
            }
        }
    }
}

#[test]
fn pipelined_rounds_interleave_with_phased_rounds_on_one_engine() {
    // Mode switches reuse the same scratch and cached descriptor
    // tables; neither direction may contaminate the other.
    let g = CommGraph::build(GraphKind::RingLattice { k: 3 }, N).unwrap();
    let src = replicas(N, P, 3400);

    let mut want = src.clone();
    let mut ref_engine = GossipEngine::with_threads(1);
    for round in 0..4 {
        for w in 0..N {
            sim_local_step(w + round, want.row_mut(w));
        }
        ref_engine.mix(&g, &mut want);
    }

    let mut got = src.clone();
    let mut engine = GossipEngine::with_threads(4);
    engine.set_bucket_elems(1000);
    for round in 0..4 {
        if round % 2 == 0 {
            engine
                .mix_overlapped(&g, &mut got, None, |w, row| {
                    sim_local_step(w + round, row);
                    Ok(())
                })
                .unwrap();
            engine.publish_overlapped(&mut got);
        } else {
            for w in 0..N {
                sim_local_step(w + round, got.row_mut(w));
            }
            engine.mix(&g, &mut got);
        }
    }
    assert_eq!(want, got, "phased and pipelined rounds must interleave cleanly");
}

#[test]
fn pipelined_is_bit_identical_between_simd_and_forced_scalar() {
    // The pipeline on both SIMD dispatch paths (the CI simd-paths job
    // runs this whole file under ADA_SIMD=scalar too; this test forces
    // the comparison within one process as well).
    let _guard = SIMD_MODE_LOCK.lock().unwrap();
    let g = CommGraph::build(GraphKind::AdaLattice { k: 4 }, N).unwrap();
    let src = replicas(N, P, 3500);
    let run = || {
        let mut reps = src.clone();
        let mut engine = GossipEngine::with_threads(4);
        engine.set_bucket_elems(1000);
        engine
            .mix_overlapped(&g, &mut reps, None, |w, row| {
                sim_local_step(w, row);
                Ok(())
            })
            .unwrap();
        engine.publish_overlapped(&mut reps);
        reps
    };
    simd::force_scalar(false);
    let auto = run();
    simd::force_scalar(true);
    let scalar = run();
    simd::force_scalar(false);
    assert_eq!(auto, scalar, "pipelined SIMD vs forced scalar");
}

#[test]
fn gossip_engine_spawns_workers_exactly_once() {
    // The acceptance criterion end to end: a GossipEngine's pool
    // survives (and is reused by) many mixed-kernel rounds.
    let g = CommGraph::build(GraphKind::RingLattice { k: 3 }, N).unwrap();
    let mut engine = GossipEngine::with_threads(4);
    let live = engine.exec().pool_liveness().expect("pooled engine");
    let mut reps = replicas(N, P, 1300);
    let grads = replicas(N, P, 1301);
    let mut states: Vec<SgdState> = (0..N).map(|_| SgdState::new(P, 0.9, 0.0)).collect();
    let active: Vec<bool> = (0..N).map(|i| i != 3).collect();
    for _ in 0..25 {
        engine.mix(&g, &mut reps);
        engine.mix_step(&g, &mut reps, &grads, &mut states, 0.01);
        engine.mix_active(&g, &mut reps, &active);
        engine.mix_active_step(&g, &mut reps, &grads, &mut states, 0.01, &active);
    }
    assert_eq!(
        live.load(Ordering::SeqCst),
        3,
        "100 kernel calls must reuse the 3 workers spawned at construction"
    );
}
