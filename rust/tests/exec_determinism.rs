//! Determinism property tests for the multi-threaded execution engine:
//! `mix`, `mix_active`, and the fused `mix_step` must produce
//! **bit-identical** output for 1, 2, 4 and 8 threads on every
//! [`GraphKind`], and the fused kernel must agree with the split
//! mix-then-step sequence within 1e-6 (exactly, off the complete-graph
//! fast path). This is the contract that makes `--threads` a pure
//! wall-clock knob — see `rust/src/exec/mod.rs` for the argument.

use ada_dist::gossip::GossipEngine;
use ada_dist::graph::{CommGraph, GraphKind};
use ada_dist::optim::SgdState;
use ada_dist::util::rng::Rng;

const THREAD_COUNTS: [usize; 4] = [1, 2, 4, 8];

/// Every graph family the crate can build, at an n that satisfies all
/// of their constraints (16 = power of two, 4×4 torus, 2k < n, …).
fn all_kinds() -> Vec<GraphKind> {
    vec![
        GraphKind::Ring,
        GraphKind::Torus,
        GraphKind::RingLattice { k: 3 },
        GraphKind::AdaLattice { k: 4 },
        GraphKind::Exponential,
        GraphKind::Complete,
        GraphKind::Hypercube,
        GraphKind::RandomRegular { d: 4, seed: 11 },
    ]
}

fn replicas(n: usize, p: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::seed_from_u64(seed);
    (0..n)
        .map(|_| (0..p).map(|_| rng.range_f32(-1.0, 1.0)).collect())
        .collect()
}

// P just above two tile widths so 4- and 8-thread runs split unevenly
// (the interesting case for tile-boundary bugs).
const P: usize = 2 * 4096 + 137;
const N: usize = 16;

#[test]
fn mix_is_bit_identical_for_every_thread_count_and_graph() {
    for (case, kind) in all_kinds().into_iter().enumerate() {
        let g = CommGraph::build(kind, N).unwrap();
        let src = replicas(N, P, 100 + case as u64);
        let mut reference: Option<Vec<Vec<f32>>> = None;
        for threads in THREAD_COUNTS {
            let mut reps = src.clone();
            let mut engine = GossipEngine::with_threads(threads);
            // Two rounds so scratch reuse is exercised too.
            engine.mix(&g, &mut reps);
            engine.mix(&g, &mut reps);
            match &reference {
                None => reference = Some(reps),
                Some(want) => assert_eq!(
                    want, &reps,
                    "{kind}: mix not bit-identical at {threads} threads"
                ),
            }
        }
    }
}

#[test]
fn mix_active_is_bit_identical_for_every_thread_count_and_graph() {
    for (case, kind) in all_kinds().into_iter().enumerate() {
        let g = CommGraph::build(kind, N).unwrap();
        let src = replicas(N, P, 200 + case as u64);
        // Deterministic mask with a mix of active and inactive rows.
        let active: Vec<bool> = (0..N).map(|i| i % 3 != 1).collect();
        let mut reference: Option<Vec<Vec<f32>>> = None;
        for threads in THREAD_COUNTS {
            let mut reps = src.clone();
            GossipEngine::with_threads(threads).mix_active(&g, &mut reps, &active);
            match &reference {
                None => reference = Some(reps),
                Some(want) => assert_eq!(
                    want, &reps,
                    "{kind}: mix_active not bit-identical at {threads} threads"
                ),
            }
        }
    }
}

#[test]
fn fused_step_is_bit_identical_for_every_thread_count_and_graph() {
    for (case, kind) in all_kinds().into_iter().enumerate() {
        let g = CommGraph::build(kind, N).unwrap();
        let src = replicas(N, P, 300 + case as u64);
        let grads = replicas(N, P, 400 + case as u64);
        let mut reference: Option<(Vec<Vec<f32>>, Vec<Vec<f32>>)> = None;
        for threads in THREAD_COUNTS {
            let mut reps = src.clone();
            let mut states: Vec<SgdState> =
                (0..N).map(|_| SgdState::new(P, 0.9, 1e-4)).collect();
            let mut engine = GossipEngine::with_threads(threads);
            // Two rounds so momentum accumulation is exercised.
            engine.mix_step(&g, &mut reps, &grads, &mut states, 0.05);
            engine.mix_step(&g, &mut reps, &grads, &mut states, 0.05);
            let vels: Vec<Vec<f32>> = states.iter().map(|s| s.velocity().to_vec()).collect();
            match &reference {
                None => reference = Some((reps, vels)),
                Some((want_p, want_v)) => {
                    assert_eq!(
                        want_p, &reps,
                        "{kind}: fused params not bit-identical at {threads} threads"
                    );
                    assert_eq!(
                        want_v, &vels,
                        "{kind}: fused velocity not bit-identical at {threads} threads"
                    );
                }
            }
        }
    }
}

#[test]
fn fused_equals_split_mix_then_step_within_1e6() {
    // The fused kernel's semantic contract: mix_step ≡ mix followed by
    // SgdState::step. Exact off the complete-graph fast path; within
    // float rounding (≪ 1e-6) on it.
    for (case, kind) in all_kinds().into_iter().enumerate() {
        let g = CommGraph::build(kind, N).unwrap();
        let src = replicas(N, P, 500 + case as u64);
        let grads = replicas(N, P, 600 + case as u64);
        let (mu, wd, lr) = (0.9f32, 1e-4f32, 0.05f32);

        let mut split = src.clone();
        let mut split_states: Vec<SgdState> =
            (0..N).map(|_| SgdState::new(P, mu, wd)).collect();
        let mut split_engine = GossipEngine::with_threads(4);
        let mut fused = src.clone();
        let mut fused_states: Vec<SgdState> =
            (0..N).map(|_| SgdState::new(P, mu, wd)).collect();
        let mut fused_engine = GossipEngine::with_threads(4);

        for _round in 0..3 {
            split_engine.mix(&g, &mut split);
            for (r, s) in split.iter_mut().zip(split_states.iter_mut()) {
                s.step(r, &grads[0], lr);
            }
            let gs: Vec<Vec<f32>> = (0..N).map(|_| grads[0].clone()).collect();
            fused_engine.mix_step(&g, &mut fused, &gs, &mut fused_states, lr);
        }
        for i in 0..N {
            for k in 0..P {
                let (a, b) = (split[i][k], fused[i][k]);
                assert!(
                    (a - b).abs() < 1e-6,
                    "{kind}: fused vs split diverge at [{i}][{k}]: {a} vs {b}"
                );
            }
        }
    }
}

#[test]
fn mix_active_with_full_mask_equals_mix() {
    // The all-active fast path must route to plain mix (same bits).
    let g = CommGraph::build(GraphKind::RingLattice { k: 3 }, N).unwrap();
    let src = replicas(N, P, 700);
    let mut via_mix = src.clone();
    GossipEngine::with_threads(4).mix(&g, &mut via_mix);
    let mut via_active = src.clone();
    GossipEngine::with_threads(4).mix_active(&g, &mut via_active, &vec![true; N]);
    assert_eq!(via_mix, via_active);
}
