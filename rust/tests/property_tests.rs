//! Randomized property tests over the coordinator invariants (the role
//! proptest would play; generators are seeded from our own RNG so runs
//! are reproducible and shrinking is replaced by printing the failing
//! case's seed).

use ada_dist::coordinator::{SgdFlavor, TrainConfig, Trainer};
use ada_dist::coordinator::surrogate::SoftmaxRegression;
use ada_dist::data::{shard_indices, ShardStrategy, SyntheticClassification};
use ada_dist::gossip::{mix_dense_reference, GossipEngine};
use ada_dist::graph::{CommGraph, GraphKind};
use ada_dist::metrics::{gini_coefficient, rank_ascending, VarianceReport};
use ada_dist::optim::LrSchedule;
use ada_dist::topology::{AdaSchedule, TopologyPolicy};
use ada_dist::util::rng::Rng;

const CASES: usize = 40;

fn random_kind(rng: &mut Rng, n: usize) -> GraphKind {
    loop {
        let k = match rng.below(6) {
            0 => GraphKind::Ring,
            1 => GraphKind::Torus,
            2 => GraphKind::RingLattice { k: 1 + rng.below(3) },
            3 => GraphKind::AdaLattice { k: 2 + rng.below(n - 2) },
            4 => GraphKind::Exponential,
            _ => GraphKind::Complete,
        };
        let ok = match k {
            GraphKind::Torus => n >= 4 && n % 2 == 0 || n == 9,
            GraphKind::RingLattice { k } => 2 * k < n,
            _ => true,
        };
        if ok {
            return k;
        }
    }
}

#[test]
fn prop_mixing_matrices_are_doubly_stochastic() {
    let mut rng = Rng::seed_from_u64(0xDA7A);
    for case in 0..CASES {
        let n = 4 + rng.below(28);
        let kind = random_kind(&mut rng, n);
        let g = match CommGraph::build(kind, n) {
            Ok(g) => g,
            Err(_) => continue, // torus factorization misses are fine
        };
        let w = g.dense_mixing();
        for i in 0..n {
            let row: f32 = (0..n).map(|j| w[i * n + j]).sum();
            let col: f32 = (0..n).map(|j| w[j * n + i]).sum();
            assert!((row - 1.0).abs() < 1e-5, "case {case} {kind} n={n} row {i}");
            assert!((col - 1.0).abs() < 1e-4, "case {case} {kind} n={n} col {i}");
            assert!((0..n).all(|j| w[i * n + j] >= 0.0), "nonneg weights");
        }
    }
}

#[test]
fn prop_gossip_preserves_mean_and_matches_dense() {
    let mut rng = Rng::seed_from_u64(0x60551);
    let mut engine = GossipEngine::new();
    for case in 0..CASES {
        let n = 4 + rng.below(12);
        let p = 1 + rng.below(200);
        let kind = random_kind(&mut rng, n);
        let g = match CommGraph::build(kind, n) {
            Ok(g) => g,
            Err(_) => continue,
        };
        let src_rows: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..p).map(|_| rng.range_f32(-2.0, 2.0)).collect())
            .collect();
        let src = ada_dist::ReplicaMatrix::from_rows(&src_rows);
        let want = mix_dense_reference(&g, &src_rows);
        let mut got = src.clone();
        engine.mix(&g, &mut got);
        for i in 0..n {
            for k in 0..p {
                assert!(
                    (got[i][k] - want[i][k]).abs() < 1e-4,
                    "case {case} {kind} [{i}][{k}]"
                );
            }
        }
        // Mean preservation.
        for k in 0..p {
            let before: f64 = src.rows().map(|r| r[k] as f64).sum();
            let after: f64 = got.rows().map(|r| r[k] as f64).sum();
            assert!((before - after).abs() < 1e-3, "case {case} mean drift col {k}");
        }
    }
}

#[test]
fn prop_gini_bounds_and_scale_invariance() {
    let mut rng = Rng::seed_from_u64(0x6121);
    for case in 0..CASES {
        let n = 2 + rng.below(40);
        let xs: Vec<f64> = (0..n).map(|_| rng.f64() * 10.0).collect();
        let g = gini_coefficient(&xs);
        assert!((0.0..1.0).contains(&g), "case {case}: gini {g} out of range");
        let scaled: Vec<f64> = xs.iter().map(|x| x * 1234.5).collect();
        let gs = gini_coefficient(&scaled);
        assert!((g - gs).abs() < 1e-9, "case {case}: scale variance {g} vs {gs}");
        // All four metrics agree that a constant sample has zero spread.
        let report = VarianceReport::of(&vec![3.7; n]);
        assert!(report.gini.abs() < 1e-12, "constant gini {}", report.gini);
        assert!(report.coeff_of_variation.abs() < 1e-12);
    }
}

#[test]
fn prop_ranks_are_a_permutation_with_ties() {
    let mut rng = Rng::seed_from_u64(0x7A9C);
    for case in 0..CASES {
        let n = 1 + rng.below(12);
        // Random values with deliberate duplicates.
        let vals: Vec<f64> = (0..n).map(|_| (rng.below(5) as f64) / 4.0).collect();
        let ranks = rank_ascending(&vals);
        assert_eq!(ranks.len(), n);
        assert!(ranks.iter().all(|&r| (1..=n).contains(&r)), "case {case}");
        // Ranks must respect ordering: vals[i] < vals[j] => rank[i] < rank[j].
        for i in 0..n {
            for j in 0..n {
                if vals[i] < vals[j] {
                    assert!(ranks[i] < ranks[j], "case {case}: order violated");
                }
                if vals[i] == vals[j] {
                    assert_eq!(ranks[i], ranks[j], "case {case}: tie rank differs");
                }
            }
        }
    }
}

#[test]
fn prop_ada_schedule_monotone_and_floored() {
    let mut rng = Rng::seed_from_u64(0xADA);
    for case in 0..CASES {
        let n = 5 + rng.below(60);
        let k0 = 2 + rng.below(n - 2);
        let gamma = rng.f64() * 3.0;
        let s = AdaSchedule::new(n, k0, gamma);
        let mut prev = usize::MAX;
        for e in 0..50 {
            let k = s.k_for_epoch(e);
            assert!(k >= 2, "case {case}: floor violated");
            assert!(k <= k0.max(2), "case {case}: k above k0");
            assert!(k <= prev, "case {case}: k increased at epoch {e}");
            prev = k;
            let g = s.graph_for_epoch(e).unwrap();
            assert!(g.is_connected(), "case {case}: disconnected lattice");
        }
    }
}

#[test]
fn prop_shards_partition_for_all_strategies() {
    let mut rng = Rng::seed_from_u64(0x5AAD);
    for case in 0..CASES {
        let n_workers = 2 + rng.below(14);
        let len = n_workers * (2 + rng.below(50));
        let classes = 2 + rng.below(9) as u32;
        let labels: Vec<u32> = (0..len).map(|i| (i as u32) % classes).collect();
        let strategy = match rng.below(3) {
            0 => ShardStrategy::Iid,
            1 => ShardStrategy::Contiguous,
            _ => ShardStrategy::LabelSkew { alpha: 0.05 + rng.f64() },
        };
        let shards =
            shard_indices(len, Some(&labels), n_workers, strategy, case as u64).unwrap();
        let mut all: Vec<usize> = shards.iter().flatten().copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..len).collect::<Vec<_>>(), "case {case} {strategy:?}");
        assert!(shards.iter().all(|s| !s.is_empty()), "case {case}: empty shard");
    }
}

#[test]
fn prop_lr_schedules_stay_positive_and_bounded() {
    let mut rng = Rng::seed_from_u64(0x112);
    for case in 0..CASES {
        let s = 0.1 + rng.f64() * 10.0;
        for sched in [
            LrSchedule::one_cycle_cifar(s),
            LrSchedule::warmup_multistep_imagenet(0.1, s),
            LrSchedule::warmup_multistep_lstm(s),
            LrSchedule::bench_default(0.05, s, 1.0, 10.0),
        ] {
            for i in 0..200 {
                let epoch = i as f64 * 2.0;
                let lr = sched.lr_at(epoch);
                assert!(lr > 0.0, "case {case}: non-positive LR at {epoch}");
                assert!(lr <= 3.0 * s.max(1.0) + 1e-9, "case {case}: LR blow-up {lr}");
            }
        }
    }
}

#[test]
fn prop_training_is_deterministic_across_repeats() {
    // The controlled-experiment guarantee DBench relies on.
    let mut rng = Rng::seed_from_u64(0xD00D);
    for case in 0..4 {
        let n = 4 + 2 * rng.below(3);
        let seed = rng.next_u64();
        let run = |seed: u64| {
            let data = SyntheticClassification::generate(512, 8, 4, 3.0, seed);
            let mut model = SoftmaxRegression::new(8, 4, 16, 32, n, 0.9);
            let mut cfg = TrainConfig::quick(n, 2);
            cfg.seed = seed;
            cfg.max_iters_per_epoch = Some(5);
            let mut t = Trainer::new(&mut model, cfg);
            let (rec, summary) = t.run(&data, &SgdFlavor::DecentralizedTorus).unwrap();
            (
                rec.records().iter().map(|r| r.train_loss).collect::<Vec<_>>(),
                summary.final_eval.metric,
            )
        };
        let (la, ma) = run(seed);
        let (lb, mb) = run(seed);
        assert_eq!(la, lb, "case {case}: loss series must be identical");
        assert_eq!(ma, mb, "case {case}: metric must be identical");
    }
}

#[test]
fn prop_topology_comm_bytes_match_degree_sum() {
    let mut rng = Rng::seed_from_u64(0xB17E5);
    for case in 0..CASES {
        let n = 6 + rng.below(20);
        let k0 = 2 + rng.below(n - 3);
        let s = AdaSchedule::new(n, k0, 1.0);
        let epochs = 1 + rng.below(8);
        let iters = 1 + rng.below(5);
        let p = 1 + rng.below(1000);
        let total = s.comm_bytes_per_node(epochs, iters, p).unwrap();
        let manual: u64 = (0..epochs)
            .map(|e| {
                let g = s.graph_for_epoch(e).unwrap();
                g.degree() as u64 * 4 * p as u64 * iters as u64
            })
            .sum();
        assert_eq!(total, manual, "case {case}");
    }
}
