//! Integration tests for the open training API: registry round-trips
//! against the legacy enum dispatch, observer ordering, checkpoint
//! observers feeding resume, and the SessionPlan pipeline (parallel ==
//! sequential, custom strategies end-to-end).

use ada_dist::coordinator::strategy::{self, CombineStrategy, StepCtx, StrategyInstance};
use ada_dist::coordinator::surrogate::SoftmaxRegression;
use ada_dist::coordinator::{
    Checkpoint, CheckpointObserver, ControlFlow, EpochInfo, Observer, RunSummary, SgdFlavor,
    TrainConfig, TrainSession, Trainer,
};
use ada_dist::data::{ShardStrategy, SyntheticClassification};
use ada_dist::dbench::{ExperimentSpec, SessionPlan, StrategyRef};
use ada_dist::error::Result;
use ada_dist::metrics::IterationRecord;
use ada_dist::ReplicaMatrix;
use std::sync::{Arc, Mutex};

fn all_flavors() -> Vec<SgdFlavor> {
    vec![
        SgdFlavor::CentralizedComplete,
        SgdFlavor::DecentralizedComplete,
        SgdFlavor::DecentralizedRing,
        SgdFlavor::DecentralizedTorus,
        SgdFlavor::DecentralizedExponential,
        SgdFlavor::Ada { k0: 5, gamma_k: 2.0 },
        SgdFlavor::OnePeer,
        SgdFlavor::VarianceAdaptive {
            k0: 5,
            step: 2,
            threshold: 0.01,
            patience: 1,
        },
    ]
}

const N: usize = 8;

fn loss_series_and_metric(
    run: impl FnOnce(&mut SoftmaxRegression) -> (Vec<f64>, f64),
) -> (Vec<f64>, f64) {
    let mut model = SoftmaxRegression::new(8, 4, 16, 32, N, 0.9);
    run(&mut model)
}

#[test]
fn registry_round_trip_is_bit_identical_to_enum_dispatch() {
    // Acceptance criterion: every SgdFlavor name resolves through the
    // registry and trains one epoch bit-identically to the enum path
    // (the Trainer facade).
    let data = SyntheticClassification::generate(1024, 8, 4, 3.0, 21);
    for flavor in all_flavors() {
        let cfg = TrainConfig::quick(N, 1);
        let (enum_losses, enum_metric) = loss_series_and_metric(|model| {
            let mut t = Trainer::new(model, cfg.clone());
            let (rec, s) = t.run(&data, &flavor).unwrap();
            (
                rec.records().iter().map(|r| r.train_loss).collect(),
                s.final_eval.metric,
            )
        });
        // The open path: resolve the paper name against the registry by
        // string, hand the instance to the session builder.
        let name = flavor.name();
        let (reg_losses, reg_metric) = loss_series_and_metric(|model| {
            let inst = strategy::registry()
                .resolve(&name, &flavor.params(N))
                .unwrap_or_else(|e| panic!("{name} must resolve: {e}"));
            let session = TrainSession::builder(model, cfg.clone())
                .strategy(inst)
                .build()
                .unwrap();
            let (rec, s) = session.run(&data).unwrap();
            (
                rec.records().iter().map(|r| r.train_loss).collect(),
                s.final_eval.metric,
            )
        });
        assert_eq!(enum_losses, reg_losses, "{name}: loss series must be bit-identical");
        assert_eq!(enum_metric, reg_metric, "{name}: final metric must be bit-identical");
        assert!(!enum_losses.is_empty(), "{name}: must have trained");
    }
}

#[test]
fn fused_mode_round_trips_through_the_registry_too() {
    let data = SyntheticClassification::generate(1024, 8, 4, 3.0, 23);
    let mut cfg = TrainConfig::quick(N, 2);
    cfg.fused = true;
    let flavor = SgdFlavor::DecentralizedRing;
    let (a, ma) = loss_series_and_metric(|model| {
        let (rec, s) = Trainer::new(model, cfg.clone()).run(&data, &flavor).unwrap();
        (rec.records().iter().map(|r| r.train_loss).collect(), s.final_eval.metric)
    });
    let (b, mb) = loss_series_and_metric(|model| {
        let inst = strategy::registry().resolve("D_ring", &flavor.params(N)).unwrap();
        let (rec, s) = TrainSession::builder(model, cfg.clone())
            .strategy(inst)
            .build()
            .unwrap()
            .run(&data)
            .unwrap();
        (rec.records().iter().map(|r| r.train_loss).collect(), s.final_eval.metric)
    });
    assert_eq!(a, b);
    assert_eq!(ma, mb);
}

/// Logs every hook invocation under a tag into a shared trace.
struct TraceObserver {
    tag: &'static str,
    log: Arc<Mutex<Vec<String>>>,
}

impl Observer for TraceObserver {
    fn on_iteration(
        &mut self,
        rec: &IterationRecord,
        replicas: &ReplicaMatrix,
    ) -> Result<ControlFlow> {
        assert!(!replicas.is_empty(), "observers see live replica state");
        self.log
            .lock()
            .unwrap()
            .push(format!("{}:iter:{}", self.tag, rec.iteration));
        Ok(ControlFlow::Continue)
    }

    fn on_epoch(&mut self, info: &EpochInfo<'_>) -> Result<ControlFlow> {
        self.log
            .lock()
            .unwrap()
            .push(format!("{}:epoch:{}", self.tag, info.epoch));
        Ok(ControlFlow::Continue)
    }

    fn on_complete(&mut self, summary: &RunSummary, _replicas: &ReplicaMatrix) -> Result<()> {
        self.log
            .lock()
            .unwrap()
            .push(format!("{}:done:{}", self.tag, summary.flavor));
        Ok(())
    }
}

#[test]
fn observers_fire_in_registration_order_with_epoch_and_completion_hooks() {
    let data = SyntheticClassification::generate(512, 8, 4, 3.0, 7);
    let log = Arc::new(Mutex::new(Vec::new()));
    let mut cfg = TrainConfig::quick(4, 2);
    cfg.max_iters_per_epoch = Some(3);
    // Equal shards so the capped 3 iterations/epoch are guaranteed (a
    // skewed Dirichlet shard could fall below 3 batches).
    cfg.shard = ShardStrategy::Iid;
    let mut model = SoftmaxRegression::new(8, 4, 16, 32, 4, 0.9);
    let session = TrainSession::builder(&mut model, cfg)
        .flavor(&SgdFlavor::DecentralizedRing)
        .unwrap()
        .observer(Box::new(TraceObserver { tag: "A", log: log.clone() }))
        .observer(Box::new(TraceObserver { tag: "B", log: log.clone() }))
        .build()
        .unwrap();
    let (rec, _) = session.run(&data).unwrap();
    assert_eq!(rec.records().len(), 6, "2 epochs × 3 capped iterations");

    let mut expected = Vec::new();
    for epoch in 0..2usize {
        for b in 0..3usize {
            let it = epoch * 3 + b;
            expected.push(format!("A:iter:{it}"));
            expected.push(format!("B:iter:{it}"));
        }
        expected.push(format!("A:epoch:{epoch}"));
        expected.push(format!("B:epoch:{epoch}"));
    }
    expected.push("A:done:D_ring".to_string());
    expected.push("B:done:D_ring".to_string());
    assert_eq!(*log.lock().unwrap(), expected);
}

#[test]
fn checkpoint_observer_feeds_trainer_resume() {
    let data = SyntheticClassification::generate(512, 8, 4, 3.0, 77);
    let dir = std::env::temp_dir().join(format!("ada_session_ckpt_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let flavor = SgdFlavor::DecentralizedTorus;
    let mut cfg = TrainConfig::quick(4, 3);
    cfg.max_iters_per_epoch = Some(4);
    cfg.shard = ShardStrategy::Iid;

    let mut model = SoftmaxRegression::new(8, 4, 16, 32, 4, 0.9);
    let session = TrainSession::builder(&mut model, cfg.clone())
        .flavor(&flavor)
        .unwrap()
        .observer(Box::new(CheckpointObserver::new(&dir, 2)))
        .build()
        .unwrap();
    let (_, s1) = session.run(&data).unwrap();
    assert!(!s1.diverged);

    let path = dir.join("D_torus_epoch0002.ckpt");
    let ckpt = Checkpoint::load(&path).expect("observer must have checkpointed epoch 2");
    assert_eq!(ckpt.epoch, 2);
    assert_eq!(ckpt.flavor, "D_torus");

    let mut cfg6 = cfg.clone();
    cfg6.epochs = 6;
    let mut model2 = SoftmaxRegression::new(8, 4, 16, 32, 4, 0.9);
    let (rec, s2) = Trainer::new(&mut model2, cfg6).resume(&data, &flavor, ckpt).unwrap();
    assert_eq!(rec.records().first().map(|r| r.epoch), Some(2), "resume starts at saved epoch");
    assert!(!s2.diverged);
    let _ = std::fs::remove_dir_all(&dir);
}

fn tiny_spec() -> ExperimentSpec {
    let mut s = ExperimentSpec::resnet20_analog();
    s.scales = vec![4, 6];
    s.epochs = 2;
    s.max_iters_per_epoch = Some(4);
    s.threads = 1;
    s.flavors = vec![SgdFlavor::DecentralizedRing, SgdFlavor::CentralizedComplete];
    s
}

#[test]
fn parallel_and_sequential_plans_produce_identical_cells() {
    let spec = tiny_spec();
    let sequential = {
        let plan = SessionPlan::from_spec(&spec);
        plan.run().unwrap()
    };
    let parallel = {
        let mut plan = SessionPlan::from_spec(&spec);
        plan.parallel = 4;
        plan.run().unwrap()
    };
    assert_eq!(sequential.len(), parallel.len());
    for (a, b) in sequential.iter().zip(&parallel) {
        assert_eq!(a.scale, b.scale);
        assert_eq!(a.flavor, b.flavor);
        assert_eq!(a.summary.final_eval.metric, b.summary.final_eval.metric);
        assert_eq!(a.summary.bytes_per_node, b.summary.bytes_per_node);
        let la: Vec<f64> = a.recorder.records().iter().map(|r| r.train_loss).collect();
        let lb: Vec<f64> = b.recorder.records().iter().map(|r| r.train_loss).collect();
        assert_eq!(la, lb, "{} @ {}: loss series must be bit-identical", a.flavor, a.scale);
    }
}

/// A genuinely new scenario defined entirely in this test file: local
/// SGD with periodic averaging (sync every `period` iterations).
struct PeriodicAverage {
    period: usize,
    rounds: usize,
}

impl CombineStrategy for PeriodicAverage {
    fn name(&self) -> &str {
        "periodic_average"
    }

    fn local_phase(&mut self, ctx: &mut StepCtx<'_>, replicas: &mut ReplicaMatrix) -> Result<f64> {
        let mut loss_sum = 0.0f64;
        for (w, loader) in ctx.loaders.iter().enumerate() {
            let batch = ctx.dataset.batch(&loader.batch_indices(ctx.epoch, ctx.batch));
            loss_sum += ctx.model.local_step(w, replicas.row_mut(w), &batch, ctx.lr)? as f64;
        }
        Ok(loss_sum / ctx.n as f64)
    }

    fn combine_phase(
        &mut self,
        ctx: &mut StepCtx<'_>,
        replicas: &mut ReplicaMatrix,
    ) -> Result<(usize, u64)> {
        self.rounds += 1;
        if self.rounds % self.period != 0 {
            return Ok((0, 0));
        }
        let g = ctx.graph.expect("schedule provides a graph");
        ctx.engine.mix(g, replicas);
        Ok((g.degree(), g.bytes_sent_per_node(ctx.param_count)))
    }
}

#[test]
fn custom_strategy_trains_end_to_end_from_dbench() {
    // Acceptance criterion: register a new CombineStrategy and train it
    // through the experiment pipeline without modifying coordinator/.
    let mut spec = tiny_spec();
    spec.scales = vec![6];
    spec.epochs = 4;
    spec.flavors = vec![SgdFlavor::DecentralizedComplete];
    let mut plan = SessionPlan::from_spec(&spec);
    plan.registry.register("D_periodic", |p| {
        Ok(StrategyInstance {
            label: "D_periodic".into(),
            schedule: ada_dist::coordinator::SgdFlavor::DecentralizedComplete
                .schedule(p.n_workers)?,
            k_neighbors: p.n_workers.saturating_sub(1),
            combine: Some(Box::new(PeriodicAverage { period: 2, rounds: 0 })),
        })
    });
    plan.push_cell(
        6,
        spec.seed,
        StrategyRef::named("D_periodic"),
        spec.train_config(6),
    );
    let cells = plan.run().unwrap();
    assert_eq!(cells.len(), 2);
    let baseline = &cells[0];
    let custom = &cells[1];
    assert_eq!(custom.flavor, "D_periodic");
    assert!(!custom.summary.diverged, "custom strategy must train stably");
    assert!(
        custom.summary.final_eval.metric > 0.15,
        "custom strategy must beat chance (0.1): {}",
        custom.summary.final_eval.metric
    );
    assert!(
        custom.summary.bytes_per_node < baseline.summary.bytes_per_node,
        "syncing every 2nd round must cut communication: {} vs {}",
        custom.summary.bytes_per_node,
        baseline.summary.bytes_per_node
    );
}

#[test]
fn plan_resumes_from_persisted_cells_even_in_parallel_mode() {
    let dir = std::env::temp_dir().join(format!("ada_plan_resume_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let spec = tiny_spec();
    let mut plan = SessionPlan::from_spec(&spec);
    plan.resume_dir = Some(dir.clone());
    let first = plan.run().unwrap();
    plan.parallel = 2;
    let second = plan.run().unwrap();
    for (a, b) in first.iter().zip(&second) {
        assert_eq!(a.summary.final_eval.metric, b.summary.final_eval.metric);
    }
    let _ = std::fs::remove_dir_all(&dir);
}
