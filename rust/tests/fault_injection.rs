//! The fault plane's acceptance tests: deterministic fault injection
//! end to end through the training loop.
//!
//! * the same [`FaultPlan`] seed produces bit-identical runs at any
//!   thread count (the engine's determinism contract survives faults);
//! * a quiet plan with staleness bound 0 reproduces the fault-free
//!   phased gossip bit for bit (the stale path's identity case);
//! * a mid-run crash/restart run completes with a finite metric within
//!   tolerance of the failure-free run — via neighbor-average cold join
//!   and via checkpoint recovery;
//! * a `[faults]` dbench spec runs end to end from TOML;
//! * checkpoint + resume replays the uninterrupted run bit for bit;
//! * a 1024-node ring survives a heavy churn table (crash/restart,
//!   permanent failures, late joins) bit-identically at any thread
//!   count and within tolerance of the failure-free run.

use ada_dist::coordinator::surrogate::SoftmaxRegression;
use ada_dist::coordinator::{
    Checkpoint, CheckpointObserver, LrPolicy, SgdFlavor, TrainConfig, TrainSession,
    Trainer,
};
use ada_dist::data::{ShardStrategy, SyntheticClassification};
use ada_dist::dbench::{ExperimentSpec, SessionPlan};
use ada_dist::optim::LrSchedule;
use ada_dist::simnet::{CrashEvent, FaultPlan};

const N: usize = 8;

/// A fixed-LR, iid, momentum-free config — every stochastic stream is
/// pinned so runs compare bitwise.
fn base_cfg(n: usize, epochs: usize) -> TrainConfig {
    let mut cfg = TrainConfig::quick(n, epochs);
    cfg.lr = LrPolicy::Fixed {
        schedule: LrSchedule::Constant { lr: 0.05 },
    };
    cfg.shard = ShardStrategy::Iid;
    cfg.max_iters_per_epoch = Some(5);
    cfg.threads = 1;
    cfg
}

/// Loss series + final metric of one run of `flavor` under `cfg`.
fn run(cfg: &TrainConfig, flavor: &SgdFlavor, momentum: f32) -> (Vec<f64>, f64) {
    let data = SyntheticClassification::generate(1024, 8, 4, 3.0, 21);
    let mut model = SoftmaxRegression::new(8, 4, 16, 32, cfg.n_workers, momentum);
    let session = TrainSession::builder(&mut model, cfg.clone())
        .flavor(flavor)
        .unwrap()
        .build()
        .unwrap();
    let (rec, summary) = session.run(&data).unwrap();
    (
        rec.records().iter().map(|r| r.train_loss).collect(),
        summary.final_eval.metric,
    )
}

fn stormy_plan() -> FaultPlan {
    let mut plan = FaultPlan::quiet();
    plan.seed = 11;
    plan.drop_prob = 0.25;
    plan.straggler_prob = 0.2;
    plan.straggler_iters = 2;
    plan.straggler_slowdown = 3.0;
    plan.link_jitter = 0.4;
    plan
}

#[test]
fn faulty_runs_are_bit_identical_at_any_thread_count() {
    // Acceptance (a): the fault plane is a pure function of (plan seed,
    // config) — stragglers, drops and stale mixing included — so the
    // per-iteration losses and the final metric must not move by one
    // bit when the worker pool is resized.
    for fused in [false, true] {
        let mut cfg = base_cfg(N, 2);
        cfg.faults = Some(stormy_plan());
        cfg.staleness_bound = 2;
        cfg.fused = fused;
        let mut reference: Option<(Vec<f64>, f64)> = None;
        for threads in [1usize, 4, 8] {
            cfg.threads = threads;
            let got = run(&cfg, &SgdFlavor::DecentralizedExponential, 0.9);
            match &reference {
                None => reference = Some(got),
                Some(want) => assert_eq!(
                    &got, want,
                    "fused={fused} threads={threads}: faulty run must be bit-identical"
                ),
            }
        }
    }
}

#[test]
fn quiet_plan_with_bound_zero_matches_the_fault_free_path_bitwise() {
    // Acceptance (b): a FaultPlan that injects nothing routes gossip
    // through the bounded-staleness kernels, whose all-fresh rounds
    // must reproduce the live-row path's floats exactly.
    for flavor in [SgdFlavor::DecentralizedRing, SgdFlavor::DecentralizedComplete] {
        let cfg_plain = base_cfg(N, 2);
        let mut cfg_quiet = cfg_plain.clone();
        cfg_quiet.faults = Some(FaultPlan::quiet());
        cfg_quiet.staleness_bound = 0;
        let plain = run(&cfg_plain, &flavor, 0.9);
        let quiet = run(&cfg_quiet, &flavor, 0.9);
        assert_eq!(plain, quiet, "{flavor:?}: quiet plan must be an identity");
    }
    // The identity also holds under the legacy drop stream (the stale
    // path must honor the participation mask exactly like mix_active).
    let mut cfg_plain = base_cfg(N, 2);
    cfg_plain.drop_prob = 0.3;
    let mut cfg_quiet = cfg_plain.clone();
    cfg_quiet.faults = Some(FaultPlan::quiet());
    cfg_quiet.staleness_bound = 0;
    let plain = run(&cfg_plain, &SgdFlavor::DecentralizedRing, 0.9);
    let quiet = run(&cfg_quiet, &SgdFlavor::DecentralizedRing, 0.9);
    assert_eq!(plain, quiet, "quiet plan must compose with drop_prob");
}

#[test]
fn crash_and_restart_stays_close_to_the_failure_free_run() {
    // Acceptance (c): node 2 crashes for epoch 1 and rejoins at epoch 2
    // from its neighbor average (no recover_dir). The run must complete
    // with a finite metric in the failure-free run's neighborhood.
    let cfg_ok = base_cfg(4, 4);
    let (_, metric_ok) = run(&cfg_ok, &SgdFlavor::DecentralizedRing, 0.0);
    let mut cfg_crash = cfg_ok.clone();
    let mut plan = FaultPlan::quiet();
    plan.crashes = vec![CrashEvent { node: 2, down_from: 1, restart_at: 2 }];
    cfg_crash.faults = Some(plan);
    cfg_crash.staleness_bound = 1;
    let (losses, metric_crash) = run(&cfg_crash, &SgdFlavor::DecentralizedRing, 0.0);
    assert!(losses.iter().all(|l| l.is_finite()), "no loss may diverge");
    assert!(metric_crash.is_finite());
    assert!(
        (metric_crash - metric_ok).abs() <= 0.15,
        "crash/restart must stay within tolerance: {metric_crash} vs {metric_ok}"
    );
}

#[test]
fn crashed_node_recovers_from_a_checkpoint_when_one_is_usable() {
    // Same outage, but a CheckpointObserver feeds `recover_dir`: the
    // rejoining node restores its row from the newest matching
    // checkpoint instead of the neighbor average.
    let dir = std::env::temp_dir().join(format!("ada_fault_recover_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mut cfg = base_cfg(4, 4);
    let mut plan = FaultPlan::quiet();
    plan.crashes = vec![CrashEvent { node: 1, down_from: 1, restart_at: 2 }];
    plan.recover_dir = Some(dir.clone());
    cfg.faults = Some(plan);
    cfg.staleness_bound = 1;
    let data = SyntheticClassification::generate(1024, 8, 4, 3.0, 21);
    let mut model = SoftmaxRegression::new(8, 4, 16, 32, 4, 0.0);
    let session = TrainSession::builder(&mut model, cfg)
        .flavor(&SgdFlavor::DecentralizedRing)
        .unwrap()
        .observer(Box::new(CheckpointObserver::new(&dir, 1)))
        .build()
        .unwrap();
    let (rec, summary) = session.run(&data).unwrap();
    assert!(!summary.diverged);
    assert!(summary.final_eval.metric.is_finite());
    assert!(
        rec.records().iter().all(|r| r.train_loss.is_finite()),
        "checkpoint recovery must keep every loss finite"
    );
    assert!(
        dir.join("D_ring_epoch0002.ckpt").exists(),
        "the observer must have written the checkpoint the recovery read"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn dbench_runs_a_faulted_cell_from_spec_toml() {
    // Acceptance (d): a `[faults]` spec drives the whole SessionPlan
    // pipeline — parse, plan, train — without any code.
    let spec = ExperimentSpec::from_toml_str(
        r#"
        base = "resnet20"
        scales = [4]
        epochs = 2
        max_iters_per_epoch = 4
        threads = 1
        staleness_bound = 1
        flavors = ["d_ring"]

        [faults]
        seed = 5
        drop_prob = 0.3
        straggler_prob = 0.2
        straggler_slowdown = 2.0
        "#,
    )
    .unwrap();
    let cells = SessionPlan::from_spec(&spec).run().unwrap();
    assert_eq!(cells.len(), 1);
    assert!(!cells[0].summary.diverged);
    assert!(cells[0].summary.final_eval.metric.is_finite());
    assert!(!cells[0].recorder.records().is_empty());
}

#[test]
fn straggler_aware_topology_trains_through_a_storm() {
    // The feedback consumer: straggler_aware reads the per-iteration
    // straggler factors the fault plane publishes and keeps training.
    let mut spec = ExperimentSpec::resnet20_analog();
    spec.scales = vec![6];
    spec.epochs = 3;
    spec.max_iters_per_epoch = Some(4);
    spec.threads = 1;
    spec.flavors = vec![SgdFlavor::DecentralizedComplete];
    spec.topology = Some(ada_dist::dbench::TopologyRef::parse(
        "straggler_aware:k0=5,step=2,ema=1.0,threshold=0.5,patience=1",
    ).unwrap());
    let mut plan = FaultPlan::quiet();
    plan.seed = 3;
    plan.straggler_prob = 0.9;
    plan.straggler_slowdown = 4.0;
    spec.faults = Some(plan);
    spec.staleness_bound = 1;
    let cells = SessionPlan::from_spec(&spec).run().unwrap();
    assert_eq!(cells.len(), 1);
    assert!(!cells[0].summary.diverged);
    assert!(cells[0].summary.final_eval.metric.is_finite());
}

#[test]
fn checkpoint_resume_replays_the_uninterrupted_run_bit_for_bit() {
    // Satellite: with every stateful stream pinned (momentum 0, fixed
    // LR, iid shards, no drops), pausing at epoch 3 and resuming must
    // reproduce the uninterrupted 6-epoch run exactly.
    let dir = std::env::temp_dir().join(format!("ada_fault_resume_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let flavor = SgdFlavor::DecentralizedTorus;
    let data = SyntheticClassification::generate(1024, 8, 4, 3.0, 21);

    let cfg6 = base_cfg(4, 6);
    let mut model_full = SoftmaxRegression::new(8, 4, 16, 32, 4, 0.0);
    let (rec_full, s_full) = TrainSession::builder(&mut model_full, cfg6.clone())
        .flavor(&flavor)
        .unwrap()
        .build()
        .unwrap()
        .run(&data)
        .unwrap();
    let losses_full: Vec<f64> =
        rec_full.records().iter().map(|r| r.train_loss).collect();

    // First half, checkpointed at its end (epoch 3 = resume point).
    let cfg3 = base_cfg(4, 3);
    let mut model_a = SoftmaxRegression::new(8, 4, 16, 32, 4, 0.0);
    let (rec_a, _) = TrainSession::builder(&mut model_a, cfg3)
        .flavor(&flavor)
        .unwrap()
        .observer(Box::new(CheckpointObserver::new(&dir, 3)))
        .build()
        .unwrap()
        .run(&data)
        .unwrap();
    let ckpt = Checkpoint::load(&dir.join("D_torus_epoch0003.ckpt"))
        .expect("the observer must have checkpointed epoch 3");
    assert_eq!(ckpt.epoch, 3);

    // Second half: resume from the checkpoint up to epoch 6.
    let mut model_b = SoftmaxRegression::new(8, 4, 16, 32, 4, 0.0);
    let (rec_b, s_b) = Trainer::new(&mut model_b, cfg6)
        .resume(&data, &flavor, ckpt)
        .unwrap();

    let mut losses_split: Vec<f64> =
        rec_a.records().iter().map(|r| r.train_loss).collect();
    losses_split.extend(rec_b.records().iter().map(|r| r.train_loss));
    assert_eq!(
        losses_full, losses_split,
        "resumed loss series must concatenate bit-identically"
    );
    assert_eq!(
        s_full.final_eval.metric, s_b.final_eval.metric,
        "final metrics must agree bitwise"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn thousand_node_churn_stays_bit_identical_and_bounded() {
    // Scale smoke at n = 1024 (ROADMAP: churn at *thousands* of nodes):
    // a small model (P = 36) on a 1024-node ring under a heavy churn
    // table — 16 crash/restart outages, 8 permanent failures and 4 late
    // joins, all in the first epochs — must (i) stay bit-identical
    // across thread counts, (ii) keep every loss finite, and (iii) land
    // within tolerance of the failure-free run: 28 disturbed nodes out
    // of 1024 cannot move the consensus metric far.
    const SCALE: usize = 1024;
    let data = SyntheticClassification::generate(4096, 8, 4, 3.0, 21);
    let mut crashes = Vec::new();
    // Strided node picks keep the three groups disjoint (< 1024 each).
    for i in 0..16 {
        crashes.push(CrashEvent { node: 13 + 61 * i, down_from: 1, restart_at: 2 });
    }
    for i in 0..8 {
        crashes.push(CrashEvent { node: 17 + 119 * i, down_from: 1, restart_at: usize::MAX });
    }
    for i in 0..4 {
        crashes.push(CrashEvent { node: 29 + 251 * i, down_from: 0, restart_at: 2 });
    }
    let run_churn = |threads: usize, faulted: bool| -> (Vec<f64>, f64) {
        let mut cfg = base_cfg(SCALE, 3);
        cfg.threads = threads;
        if faulted {
            let mut plan = FaultPlan::quiet();
            plan.seed = 17;
            plan.crashes = crashes.clone();
            cfg.faults = Some(plan);
            cfg.staleness_bound = 2;
        }
        let mut model = SoftmaxRegression::new(8, 4, 16, 32, SCALE, 0.0);
        let session = TrainSession::builder(&mut model, cfg)
            .flavor(&SgdFlavor::DecentralizedRing)
            .unwrap()
            .build()
            .unwrap();
        let (rec, summary) = session.run(&data).unwrap();
        (
            rec.records().iter().map(|r| r.train_loss).collect(),
            summary.final_eval.metric,
        )
    };
    let (_, metric_ok) = run_churn(1, false);
    let (losses, metric_churn) = run_churn(1, true);
    assert!(losses.iter().all(|l| l.is_finite()), "no loss may diverge under churn");
    assert!(metric_churn.is_finite());
    assert!(
        (metric_churn - metric_ok).abs() <= 0.15,
        "churn must stay within tolerance: {metric_churn} vs {metric_ok}"
    );
    let rerun = run_churn(8, true);
    assert_eq!(
        (losses, metric_churn),
        rerun,
        "1024-node churn must be bit-identical across thread counts"
    );
}
