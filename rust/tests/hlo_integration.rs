//! Integration tests over the real AOT artifacts: the Rust runtime
//! loads the JAX/Pallas-lowered HLO and must agree numerically with the
//! pure-Rust reference implementations.
//!
//! These tests need `make artifacts` to have run; when the artifact
//! tree is absent they skip (so `cargo test` stays green in a fresh
//! checkout), and the Makefile's `test` target builds artifacts first.

use ada_dist::coordinator::surrogate::MlpClassifier;
use ada_dist::coordinator::{HloModel, LocalModel, SgdFlavor, TrainConfig, Trainer};
use ada_dist::data::{Dataset, SyntheticClassification, SyntheticLm};
use ada_dist::gossip::GossipEngine;
use ada_dist::graph::{CommGraph, GraphKind};
use ada_dist::runtime::{GossipKernel, ModelKind, PjRtRuntime};

fn artifacts() -> Option<PjRtRuntime> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("mlp/manifest.json").exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(PjRtRuntime::cpu(dir).expect("cpu pjrt client"))
}

#[test]
fn all_model_bundles_load_and_init() {
    let Some(rt) = artifacts() else { return };
    for name in ["mlp", "cnn", "lstm", "transformer"] {
        let bundle = rt.load_model(name).unwrap_or_else(|e| panic!("{name}: {e}"));
        let p = bundle.init_params(0).unwrap();
        assert_eq!(p.len(), bundle.manifest.param_count, "{name}");
        assert!(p.iter().all(|v| v.is_finite()), "{name} init must be finite");
        // Different seeds give different parameters.
        let p2 = bundle.init_params(1).unwrap();
        assert_ne!(p, p2, "{name} init must depend on seed");
    }
}

#[test]
fn hlo_mlp_step_matches_rust_surrogate() {
    // The `mlp` artifact and the Rust MlpClassifier implement the same
    // architecture over the same flat layout. First steps must agree:
    // loss exactly (same formula) and updated params = p - lr*g (the
    // surrogate's first momentum step coincides with plain SGD).
    let Some(rt) = artifacts() else { return };
    let bundle = rt.load_model("mlp").unwrap();
    let m = &bundle.manifest;
    assert_eq!(m.kind, ModelKind::Classification);
    let data = SyntheticClassification::generate(256, m.x_dim, m.num_outputs, 3.0, 7);
    let batch = data.batch(&(0..m.batch_size).collect::<Vec<_>>());

    let params0 = bundle.init_params(5).unwrap();
    let surrogate = MlpClassifier::new(m.x_dim, 64, m.num_outputs, m.batch_size, 64, 1, 0.9);
    assert_eq!(surrogate.param_count(), m.param_count, "layout contract");
    let (rust_loss, rust_grad) = surrogate.loss_and_grad(&params0, &batch).unwrap();

    let lr = 0.05f32;
    let mut hlo_params = params0.clone();
    let out = bundle.local_step(&mut hlo_params, &batch, lr).unwrap();
    assert!(
        (out.loss - rust_loss).abs() < 1e-4 * rust_loss.abs().max(1.0),
        "losses disagree: hlo {} vs rust {rust_loss}",
        out.loss
    );
    for i in 0..m.param_count {
        let want = params0[i] - lr * rust_grad[i];
        assert!(
            (hlo_params[i] - want).abs() < 1e-4,
            "param {i}: hlo {} vs rust {want}",
            hlo_params[i]
        );
    }
}

#[test]
fn hlo_mlp_eval_matches_rust_surrogate() {
    let Some(rt) = artifacts() else { return };
    let bundle = rt.load_model("mlp").unwrap();
    let m = &bundle.manifest;
    let data = SyntheticClassification::generate(256, m.x_dim, m.num_outputs, 3.0, 9);
    let batch = data.batch(&(0..m.eval_batch_size).collect::<Vec<_>>());
    let params = bundle.init_params(3).unwrap();
    let surrogate =
        MlpClassifier::new(m.x_dim, 64, m.num_outputs, m.batch_size, m.eval_batch_size, 1, 0.0);
    let (rust_loss, rust_correct) = surrogate.eval_sums(&params, &batch).unwrap();
    let (hlo_loss, hlo_correct) = bundle.eval_batch(&params, &batch).unwrap();
    assert!((hlo_loss - rust_loss).abs() < 1e-3 * rust_loss.abs().max(1.0));
    assert_eq!(hlo_correct, rust_correct, "argmax agreement");
}

#[test]
fn gossip_kernel_matches_native_engine() {
    // The L1 Pallas mixing kernel (via PJRT) vs the native Rust path.
    let Some(rt) = artifacts() else { return };
    let n = 8;
    let p = 2762; // mlp param count — lowered variant
    let kernel = GossipKernel::load(&rt, n, p).unwrap();
    for kind in [GraphKind::Ring, GraphKind::Exponential, GraphKind::AdaLattice { k: 4 }] {
        let g = CommGraph::build(kind, n).unwrap();
        let mut rng = ada_dist::util::rng::Rng::seed_from_u64(11);
        let src: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..p).map(|_| rng.range_f32(-1.0, 1.0)).collect())
            .collect();
        let mut native = ada_dist::ReplicaMatrix::from_rows(&src);
        GossipEngine::new().mix(&g, &mut native);
        let mut hlo = src.clone();
        kernel.mix(&g, &mut hlo).unwrap();
        for i in 0..n {
            for j in (0..p).step_by(97) {
                assert!(
                    (native[i][j] - hlo[i][j]).abs() < 1e-5,
                    "{kind} mismatch at [{i}][{j}]: {} vs {}",
                    native[i][j],
                    hlo[i][j]
                );
            }
        }
    }
}

#[test]
fn gossip_kernel_rejects_wrong_sizes() {
    let Some(rt) = artifacts() else { return };
    assert!(GossipKernel::load(&rt, 8, 999).is_err(), "unknown p must fail");
    let kernel = GossipKernel::load(&rt, 8, 2762).unwrap();
    let g = CommGraph::build(GraphKind::Ring, 4).unwrap();
    let mut reps = vec![vec![0.0f32; 2762]; 4];
    assert!(kernel.mix(&g, &mut reps).is_err(), "n mismatch must fail");
}

#[test]
fn hlo_training_runs_all_decentralized_flavors() {
    // A short end-to-end run of the production path per flavor.
    let Some(rt) = artifacts() else { return };
    let bundle = rt.load_model("mlp").unwrap();
    let m = bundle.manifest.clone();
    let data = SyntheticClassification::generate(512, m.x_dim, m.num_outputs, 3.0, 13);
    for flavor in [
        SgdFlavor::DecentralizedRing,
        SgdFlavor::Ada { k0: 3, gamma_k: 1.0 },
    ] {
        let mut model = HloModel::new(rt.load_model("mlp").unwrap());
        let mut cfg = TrainConfig::quick(4, 2);
        cfg.max_iters_per_epoch = Some(4);
        let mut trainer = Trainer::new(&mut model, cfg);
        let (rec, summary) = trainer.run(&data, &flavor).unwrap();
        assert!(!summary.diverged, "{} diverged", summary.flavor);
        assert!(!rec.records().is_empty());
        assert!(
            rec.records().iter().all(|r| r.train_loss.is_finite()),
            "{} non-finite loss",
            summary.flavor
        );
    }
}

#[test]
fn hlo_lstm_trains_and_reports_perplexity() {
    let Some(rt) = artifacts() else { return };
    let mut model = HloModel::new(rt.load_model("lstm").unwrap());
    let m = model.bundle().manifest.clone();
    assert_eq!(m.kind, ModelKind::Lm);
    let data = SyntheticLm::generate(256, m.x_dim, m.num_outputs, 2, 17);
    let mut cfg = TrainConfig::quick(4, 2);
    cfg.max_iters_per_epoch = Some(3);
    cfg.shard = ada_dist::data::ShardStrategy::Iid;
    let mut trainer = Trainer::new(&mut model, cfg);
    let (_, summary) = trainer
        .run(&data, &SgdFlavor::DecentralizedComplete)
        .unwrap();
    assert!(!summary.diverged);
    // Perplexity of a barely-trained model over vocab 32 sits near 32.
    assert!(
        summary.final_eval.metric > 1.0 && summary.final_eval.metric < 100.0,
        "ppl = {}",
        summary.final_eval.metric
    );
}
