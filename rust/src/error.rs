//! Crate-wide error type.

use std::fmt;

/// Errors produced by the ada-dist library.
#[derive(Debug)]
pub enum AdaError {
    /// A communication graph could not be constructed (bad node count,
    /// incompatible parameters, …).
    Graph(String),
    /// Configuration file / CLI parameter problems.
    Config(String),
    /// Artifact loading / PJRT compile / execute failures.
    Runtime(String),
    /// Dataset or sharding problems.
    Data(String),
    /// Coordinator invariant violations (mismatched worker state, …).
    Coordinator(String),
    /// Wrapped I/O error.
    Io(std::io::Error),
}

impl fmt::Display for AdaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            AdaError::Graph(m) => write!(f, "graph error: {m}"),
            AdaError::Config(m) => write!(f, "config error: {m}"),
            AdaError::Runtime(m) => write!(f, "runtime error: {m}"),
            AdaError::Data(m) => write!(f, "data error: {m}"),
            AdaError::Coordinator(m) => write!(f, "coordinator error: {m}"),
            AdaError::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for AdaError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            AdaError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for AdaError {
    fn from(e: std::io::Error) -> Self {
        AdaError::Io(e)
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, AdaError>;
