//! Launcher configuration: the process-level settings shared by the
//! `ada` and `dbench` binaries (artifact root, output directory),
//! loadable from TOML and overridable from the CLI.

use crate::error::Result;
use crate::util::tomlmini::{TomlDoc, TomlValue};
use std::path::{Path, PathBuf};

/// Process-level configuration.
#[derive(Debug, Clone)]
pub struct LauncherConfig {
    /// Root of AOT artifacts (`make artifacts` output).
    pub artifact_dir: PathBuf,
    /// Where run records / tables are written.
    pub output_dir: PathBuf,
    /// Default gossip/fused-kernel fan-out for the binaries (`0` = all
    /// cores). Overridable per run with `--threads`; results are
    /// bit-identical for every value (see `crate::exec`).
    pub threads: usize,
}

impl Default for LauncherConfig {
    fn default() -> Self {
        LauncherConfig {
            artifact_dir: PathBuf::from("artifacts"),
            output_dir: PathBuf::from("out"),
            threads: 0,
        }
    }
}

impl LauncherConfig {
    /// Load from a TOML file (`artifact_dir` / `output_dir` keys).
    pub fn from_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::from_toml_str(&text)
    }

    /// Parse from TOML text.
    pub fn from_toml_str(text: &str) -> Result<Self> {
        let doc = TomlDoc::parse(text)?;
        let mut cfg = LauncherConfig::default();
        if let Some(v) = doc.get("artifact_dir").and_then(TomlValue::as_str) {
            cfg.artifact_dir = PathBuf::from(v);
        }
        if let Some(v) = doc.get("output_dir").and_then(TomlValue::as_str) {
            cfg.output_dir = PathBuf::from(v);
        }
        if let Some(v) = doc.get("threads").and_then(TomlValue::as_int) {
            cfg.threads = v.max(0) as usize;
        }
        Ok(cfg)
    }

    /// Ensure the output directory exists and return it.
    pub fn ensure_output_dir(&self) -> Result<&Path> {
        std::fs::create_dir_all(&self.output_dir)?;
        Ok(&self.output_dir)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_sane() {
        let c = LauncherConfig::default();
        assert_eq!(c.artifact_dir, PathBuf::from("artifacts"));
        assert_eq!(c.output_dir, PathBuf::from("out"));
    }

    #[test]
    fn toml_overrides() {
        let c = LauncherConfig::from_toml_str("artifact_dir = \"/x\"\n").unwrap();
        assert_eq!(c.artifact_dir, PathBuf::from("/x"));
        assert_eq!(c.output_dir, PathBuf::from("out"), "default kept");
        assert_eq!(c.threads, 0, "default threads = auto");
        let c = LauncherConfig::from_toml_str("threads = 4\n").unwrap();
        assert_eq!(c.threads, 4);
    }

    #[test]
    fn ensure_output_dir_creates() {
        let dir = crate::util::scratch_dir("config").unwrap();
        let c = LauncherConfig {
            output_dir: dir.join("nested/out"),
            ..Default::default()
        };
        assert!(c.ensure_output_dir().is_ok());
        assert!(dir.join("nested/out").is_dir());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
