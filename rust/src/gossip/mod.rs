//! The gossip mixing engine: applies a mixing matrix to the stacked
//! replica parameters, `Θ' = W Θ` (§2.2's neighbor averaging
//! `Σ_j E_ij θ_j`).
//!
//! ## Data plane
//!
//! Since the flat-store refactor the replica stack lives in a
//! [`ReplicaMatrix`] — one 64-byte-aligned contiguous allocation with a
//! padded row stride (`rust/src/util/matrix.rs` documents the layout
//! contract) — and every inner loop below runs on the explicit SIMD
//! kernel layer ([`crate::exec::simd`]): AVX2 `f32x8` behind runtime
//! feature detection, with a fixed-8-lane scalar fallback that is
//! bit-identical by construction.
//!
//! Two interchangeable execution paths:
//!  * **native** (this module): sparse row-wise mixing over the graph's
//!    neighbor lists with a reused scratch matrix, an O(nP) fast path
//!    for uniform complete graphs, and **fused gossip+SGD kernels**
//!    ([`GossipEngine::mix_step`], and [`GossipEngine::mix_active_step`]
//!    for partial-participation rounds) that apply the momentum update
//!    while each mixed tile is still cache-resident. This is the
//!    production hot path and the baseline the kernel path is
//!    benchmarked against. The training loop reaches it through the
//!    open strategy layer (`crate::coordinator::strategy`): the
//!    `GossipCombine`/`FusedGossipCombine` strategies call `mix`/
//!    `mix_step` (or the `_active` variants under failure injection),
//!    and custom strategies get the same engine via their `StepCtx`.
//!  * **HLO kernel** (`crate::runtime::GossipKernel`, `pjrt` feature):
//!    the L1 Pallas `gossip_mix` kernel AOT-lowered to an HLO executable
//!    and run via PJRT — demonstrating the paper's averaging step as an
//!    MXU matmul (DESIGN.md §Hardware-Adaptation).
//!
//! ## Parallel execution
//!
//! The native kernels fan out over the [`crate::exec`] engine — a
//! **persistent worker pool**, spawned once when the `GossipEngine` is
//! built and parked between rounds: the parameter axis is partitioned
//! into contiguous column tiles and each worker owns its tiles of
//! **all** n replicas (a blocked SpMM over the sparse mixing matrix).
//! [`ReplicaMatrix::rows_mut`] is the split point: disjoint mutable row
//! views of the flat buffer, transposed into per-worker column views by
//! [`column_views`]. Because every output element's reduction order is
//! fixed by its graph row alone — and the SIMD layer never reassociates
//! an elementwise sequence — results are **bit-identical for any thread
//! count and for both SIMD and scalar paths** — see
//! `rust/src/exec/mod.rs` and `rust/src/exec/simd.rs` for the argument
//! and `rust/tests/exec_determinism.rs` for the proof-by-test. Scratch
//! pages are first-touched inside the owning worker's column tile
//! ([`GossipEngine::ensure_scratch`]) so page placement follows tile
//! ownership — the groundwork for NUMA pinning (ROADMAP §Open items).

use crate::exec::{column_views, simd, ExecEngine};
use crate::graph::CommGraph;
use crate::optim::SgdState;
use std::ops::Range;

pub use crate::util::matrix::ReplicaMatrix;

/// Column-tile width of the blocked SpMM: the working set (one tile of
/// every replica) stays cache-resident across all n output rows
/// (§Perf iteration 2: ~2× at n=64, P=1M on the higher-degree graphs,
/// where a row-major pass re-streams each 4 MB source row from DRAM
/// once per consumer).
const TILE: usize = 4096;

/// A worker must own at least one full tile before a mix call fans out;
/// below that the spawn cost dwarfs the arithmetic and everything runs
/// on the calling thread.
const MIN_COLS_PER_WORKER: usize = TILE;

/// Reusable mixing engine. Holds a scratch matrix so steady-state
/// rounds allocate nothing, plus the execution engine that decides
/// fan-out.
#[derive(Debug, Default)]
pub struct GossipEngine {
    scratch: ReplicaMatrix,
    mean_scratch: Vec<f32>,
    exec: ExecEngine,
}

impl GossipEngine {
    /// New single-threaded engine with empty scratch (grown on first use).
    pub fn new() -> Self {
        Self::default()
    }

    /// Engine fanning out over `threads` workers (`0` = all cores).
    /// Results are bit-identical to [`GossipEngine::new`] for any value.
    pub fn with_threads(threads: usize) -> Self {
        GossipEngine {
            scratch: ReplicaMatrix::default(),
            mean_scratch: Vec::new(),
            exec: ExecEngine::new(threads),
        }
    }

    /// Worker count this engine fans out over.
    pub fn threads(&self) -> usize {
        self.exec.threads()
    }

    /// The underlying execution engine — shared with the trainer's
    /// pooled variance capture and mean-model construction so the whole
    /// iteration runs on one worker set.
    pub fn exec(&self) -> &ExecEngine {
        &self.exec
    }

    /// One gossip round in place: `Θ_i ← Σ_j W_ij · Θ_j`.
    ///
    /// `replicas.n()` must equal `graph.n()` (the equal-parameter-count
    /// invariant is structural in [`ReplicaMatrix`]).
    pub fn mix(&mut self, graph: &CommGraph, replicas: &mut ReplicaMatrix) {
        let n = graph.n();
        assert_eq!(replicas.n(), n, "replica count must match graph size");
        if n == 0 {
            return;
        }
        let p = replicas.p();

        // Fast path: uniform complete graph == global mean.
        if is_uniform_complete(graph) {
            self.mix_complete(replicas, p);
            return;
        }

        self.ensure_scratch(n, p);
        let ranges = self.exec.partition(p, MIN_COLS_PER_WORKER);
        {
            let reps: &ReplicaMatrix = replicas;
            let views = column_views(self.scratch.rows_mut(), &ranges);
            let jobs: Vec<_> = views
                .into_iter()
                .zip(ranges.iter().cloned())
                .map(|(chunks, range)| move || mix_tile(graph, reps, chunks, range))
                .collect();
            self.exec.run_jobs(jobs);
        }
        self.swap_in_scratch(replicas);
    }

    /// Mix only a subset round (partial participation is not used by the
    /// paper but exercised by failure-injection tests): rows not in
    /// `active` keep their parameters; active rows renormalize their
    /// mixing weights over the active participants so the result stays
    /// a convex combination.
    pub fn mix_active(
        &mut self,
        graph: &CommGraph,
        replicas: &mut ReplicaMatrix,
        active: &[bool],
    ) {
        let n = graph.n();
        assert_eq!(replicas.n(), n, "replica count must match graph size");
        assert_eq!(active.len(), n, "active mask must match graph size");
        if n == 0 {
            return;
        }
        let p = replicas.p();
        if active.iter().all(|&a| a) {
            return self.mix(graph, replicas);
        }
        self.ensure_scratch(n, p);
        let totals = active_totals(graph, active);
        let ranges = self.exec.partition(p, MIN_COLS_PER_WORKER);
        {
            let reps: &ReplicaMatrix = replicas;
            let totals: &[f32] = &totals;
            let views = column_views(self.scratch.rows_mut(), &ranges);
            let jobs: Vec<_> = views
                .into_iter()
                .zip(ranges.iter().cloned())
                .map(|(chunks, range)| {
                    move || mix_active_tile(graph, reps, active, totals, chunks, range)
                })
                .collect();
            self.exec.run_jobs(jobs);
        }
        self.swap_in_scratch(replicas);
    }

    /// **Fused gossip + momentum-SGD round** — the combined kernel that
    /// eliminates one full O(nP) DRAM round-trip per training iteration:
    ///
    /// ```text
    /// θ'_i = Σ_j W_ij θ_j            (gossip SpMM tile)
    /// v_i  ← μ_i v_i + (g_i + λ_i θ'_i)   (momentum, while the tile
    /// θ'_i ← θ'_i − γ v_i                  is still cache-resident)
    /// ```
    ///
    /// Bit-identical to calling [`GossipEngine::mix`] followed by
    /// [`SgdState::step`] per replica, *except* on uniform complete
    /// graphs where `mix` takes the global-mean fast path (the fused
    /// kernel always runs the general SpMM; results then agree to float
    /// rounding, ~1e-7). `μ_i`/`λ_i` come from each replica's
    /// [`SgdState`]; `γ` is `lr`. Gradients are a [`ReplicaMatrix`] of
    /// the same shape, so the fused tile streams three flat buffers.
    pub fn mix_step(
        &mut self,
        graph: &CommGraph,
        replicas: &mut ReplicaMatrix,
        grads: &ReplicaMatrix,
        states: &mut [SgdState],
        lr: f32,
    ) {
        let n = graph.n();
        assert_eq!(replicas.n(), n, "replica count must match graph size");
        assert_eq!(grads.n(), n, "gradient count must match graph size");
        assert_eq!(states.len(), n, "optimizer state count must match graph size");
        if n == 0 {
            return;
        }
        let p = replicas.p();
        assert_eq!(grads.p(), p, "gradients must match parameter counts");
        assert!(
            states.iter().all(|s| s.len() == p),
            "optimizer states must match parameter counts"
        );

        self.ensure_scratch(n, p);
        let hyper: Vec<(f32, f32)> =
            states.iter().map(|s| (s.momentum, s.weight_decay)).collect();
        let ranges = self.exec.partition(p, MIN_COLS_PER_WORKER);
        {
            let reps: &ReplicaMatrix = replicas;
            let hyper: &[(f32, f32)] = &hyper;
            let out_views = column_views(self.scratch.rows_mut(), &ranges);
            let vel_views =
                column_views(states.iter_mut().map(SgdState::velocity_mut).collect(), &ranges);
            let jobs: Vec<_> = out_views
                .into_iter()
                .zip(vel_views)
                .zip(ranges.iter().cloned())
                .map(|((outs, vels), range)| {
                    move || mix_step_tile(graph, reps, grads, hyper, lr, outs, vels, range)
                })
                .collect();
            self.exec.run_jobs(jobs);
        }
        self.swap_in_scratch(replicas);
    }

    /// **Fused partial-participation gossip + momentum-SGD round** —
    /// [`GossipEngine::mix_active`] and the per-replica
    /// [`SgdState::step`] in one pass, so dropout rounds stop paying
    /// the extra O(nP) DRAM round-trip the split fallback costs:
    ///
    /// ```text
    /// θ'_i = Σ_{j active} (W_ij / T_i) θ_j   if active[i]   (renormalized SpMM)
    /// θ'_i = θ_i                              otherwise      (passthrough)
    /// v_i ← μ_i v_i + (g_i + λ_i θ'_i);  θ'_i ← θ'_i − γ v_i   (every i)
    /// ```
    ///
    /// Matching the trainer's straggler model, **inactive rows still
    /// apply their local gradient** — they only miss the exchange.
    /// Bit-identical to `mix_active` followed by `SgdState::step` per
    /// replica (same per-element float sequence), except when every row
    /// is active: that mask delegates to [`GossipEngine::mix_step`],
    /// whose complete-graph handling is documented there.
    #[allow(clippy::too_many_arguments)]
    pub fn mix_active_step(
        &mut self,
        graph: &CommGraph,
        replicas: &mut ReplicaMatrix,
        grads: &ReplicaMatrix,
        states: &mut [SgdState],
        lr: f32,
        active: &[bool],
    ) {
        let n = graph.n();
        assert_eq!(replicas.n(), n, "replica count must match graph size");
        assert_eq!(grads.n(), n, "gradient count must match graph size");
        assert_eq!(states.len(), n, "optimizer state count must match graph size");
        assert_eq!(active.len(), n, "active mask must match graph size");
        if n == 0 {
            return;
        }
        if active.iter().all(|&a| a) {
            return self.mix_step(graph, replicas, grads, states, lr);
        }
        let p = replicas.p();
        assert_eq!(grads.p(), p, "gradients must match parameter counts");
        assert!(
            states.iter().all(|s| s.len() == p),
            "optimizer states must match parameter counts"
        );

        self.ensure_scratch(n, p);
        let totals = active_totals(graph, active);
        let hyper: Vec<(f32, f32)> =
            states.iter().map(|s| (s.momentum, s.weight_decay)).collect();
        let ranges = self.exec.partition(p, MIN_COLS_PER_WORKER);
        {
            let reps: &ReplicaMatrix = replicas;
            let totals: &[f32] = &totals;
            let hyper: &[(f32, f32)] = &hyper;
            let out_views = column_views(self.scratch.rows_mut(), &ranges);
            let vel_views =
                column_views(states.iter_mut().map(SgdState::velocity_mut).collect(), &ranges);
            let jobs: Vec<_> = out_views
                .into_iter()
                .zip(vel_views)
                .zip(ranges.iter().cloned())
                .map(|((outs, vels), range)| {
                    move || {
                        mix_active_step_tile(
                            graph, reps, active, totals, grads, hyper, lr, outs, vels, range,
                        )
                    }
                })
                .collect();
            self.exec.run_jobs(jobs);
        }
        self.swap_in_scratch(replicas);
    }

    /// Complete-graph fast path: one column-mean pass + one broadcast
    /// copy, both fanned out over the same column ranges.
    fn mix_complete(&mut self, replicas: &mut ReplicaMatrix, p: usize) {
        if self.mean_scratch.len() != p {
            // Fresh lazily-zero-mapped pages; the owning workers'
            // writes in phase 1 below are the first touch.
            self.mean_scratch = vec![0.0f32; p];
        }
        let ranges = self.exec.partition(p, MIN_COLS_PER_WORKER);
        // Phase 1: column mean of the replica stack. Write-first into
        // the scratch tile (replica 0 seeds it) instead of zeroing and
        // accumulating — one fewer pass over every tile per round.
        {
            let reps: &ReplicaMatrix = replicas;
            let mean_views = column_views(vec![self.mean_scratch.as_mut_slice()], &ranges);
            let jobs: Vec<_> = mean_views
                .into_iter()
                .zip(ranges.iter().cloned())
                .map(|(mut chunks, range)| {
                    move || {
                        let m = chunks.pop().expect("one mean row");
                        mean_tile(reps, m, range);
                    }
                })
                .collect();
            self.exec.run_jobs(jobs);
        }
        // Phase 2: broadcast the mean into every replica.
        {
            let mean: &[f32] = &self.mean_scratch;
            let rep_views = column_views(replicas.rows_mut(), &ranges);
            let jobs: Vec<_> = rep_views
                .into_iter()
                .zip(ranges.iter().cloned())
                .map(|(chunks, range)| {
                    move || {
                        let src = &mean[range];
                        for chunk in chunks {
                            chunk.copy_from_slice(src);
                        }
                    }
                })
                .collect();
            self.exec.run_jobs(jobs);
        }
    }

    fn ensure_scratch(&mut self, n: usize, p: usize) {
        if self.scratch.n() == n && self.scratch.p() == p {
            return;
        }
        // One flat zeroed allocation: the pages come back lazily mapped
        // from the zeroed allocator, so the pooled pass below is the
        // true first touch of every page, from the worker that owns
        // those columns — deciding which core (and on multi-socket
        // hosts, which NUMA node) backs each tile, aligned with the
        // tile ownership every later kernel call uses (ROADMAP §NUMA).
        self.scratch = ReplicaMatrix::zeros(n, p);
        let ranges = self.exec.partition(p, MIN_COLS_PER_WORKER);
        if ranges.len() > 1 {
            let views = column_views(self.scratch.rows_mut(), &ranges);
            let jobs: Vec<_> = views
                .into_iter()
                .map(|chunks| {
                    move || {
                        for chunk in chunks {
                            chunk.fill(0.0);
                            // Keep the touching stores observable.
                            std::hint::black_box(&mut *chunk);
                        }
                    }
                })
                .collect();
            self.exec.run_jobs(jobs);
        }
    }

    /// Swap the scratch store into `replicas` instead of copying back:
    /// with the flat layout this is one pointer-triple exchange — the
    /// old per-row `Vec` swap loop is gone entirely (§Perf iteration 1
    /// saved the copy; the flat store also saves the n swaps).
    fn swap_in_scratch(&mut self, replicas: &mut ReplicaMatrix) {
        std::mem::swap(replicas, &mut self.scratch);
    }
}

/// One worker's share of a mix round: the blocked SpMM over its column
/// range of every output row. `out_rows[i]` is row `i` restricted to
/// `range`; reads come from the (shared, immutable) pre-round replicas.
fn mix_tile(
    graph: &CommGraph,
    replicas: &ReplicaMatrix,
    mut out_rows: Vec<&mut [f32]>,
    range: Range<usize>,
) {
    let mut start = range.start;
    while start < range.end {
        let end = (start + TILE).min(range.end);
        let (lo, hi) = (start - range.start, end - range.start);
        for (i, out_row) in out_rows.iter_mut().enumerate() {
            let out = &mut out_row[lo..hi];
            let mut first = true;
            for (j, w) in graph.row(i) {
                let src = &replicas.row(j)[start..end];
                if first {
                    simd::scale(out, src, w);
                    first = false;
                } else {
                    simd::axpy(out, src, w);
                }
            }
        }
        start = end;
    }
}

/// [`mix_tile`] under partial participation: inactive rows copy their
/// parameters through; active rows renormalize by the precomputed
/// active weight mass `totals[i]`.
fn mix_active_tile(
    graph: &CommGraph,
    replicas: &ReplicaMatrix,
    active: &[bool],
    totals: &[f32],
    mut out_rows: Vec<&mut [f32]>,
    range: Range<usize>,
) {
    let mut start = range.start;
    while start < range.end {
        let end = (start + TILE).min(range.end);
        let (lo, hi) = (start - range.start, end - range.start);
        for (i, out_row) in out_rows.iter_mut().enumerate() {
            let out = &mut out_row[lo..hi];
            if !active[i] {
                out.copy_from_slice(&replicas.row(i)[start..end]);
                continue;
            }
            let total = totals[i];
            let mut first = true;
            for (j, w) in graph.row(i) {
                if !active[j] {
                    continue;
                }
                let w = w / total;
                let src = &replicas.row(j)[start..end];
                if first {
                    simd::scale(out, src, w);
                    first = false;
                } else {
                    simd::axpy(out, src, w);
                }
            }
        }
        start = end;
    }
}

/// Per-row active weight mass `T_i = Σ_{j active} W_ij`, O(n·deg) once
/// per round — the tiled inner loops of [`mix_active_tile`] and
/// [`mix_active_step_tile`] then only divide. Shared by both the split
/// and fused partial-participation paths so their renormalization can
/// never diverge.
fn active_totals(graph: &CommGraph, active: &[bool]) -> Vec<f32> {
    (0..graph.n())
        .map(|i| graph.row(i).filter(|&(j, _)| active[j]).map(|(_, w)| w).sum())
        .collect()
}

/// One worker's tile of a column mean: seed with replica 0, accumulate
/// the rest, scale — no zeroing pass. Per-element operand order is the
/// replica order, independent of tiling and of the SIMD/scalar path
/// (elementwise kernels never reassociate), so the mean is
/// bit-identical for any thread count.
fn mean_tile(replicas: &ReplicaMatrix, out: &mut [f32], range: Range<usize>) {
    out.copy_from_slice(&replicas.row(0)[range.clone()]);
    for i in 1..replicas.n() {
        simd::axpy(out, &replicas.row(i)[range.clone()], 1.0);
    }
    let inv = 1.0 / replicas.n() as f32;
    simd::scale_in_place(out, inv);
}

/// The replica-averaged model `θ̄ = (1/n) Σ_i θ_i`, fanned out over
/// `exec`'s column tiles — the parallel form of the trainer's
/// mean-model evaluation (§2.2: "the trained model takes θ as the
/// average over all θ_i"), which was the last serial O(n·P) pass on the
/// evaluation path.
pub fn mean_model(exec: &ExecEngine, replicas: &ReplicaMatrix) -> Vec<f32> {
    assert!(!replicas.is_empty(), "mean_model needs at least one replica");
    let p = replicas.p();
    let mut mean = vec![0.0f32; p];
    let ranges = exec.partition(p, MIN_COLS_PER_WORKER);
    {
        let views = column_views(vec![mean.as_mut_slice()], &ranges);
        let jobs: Vec<_> = views
            .into_iter()
            .zip(ranges.iter().cloned())
            .map(|(mut chunks, range)| {
                move || {
                    let m = chunks.pop().expect("one mean row");
                    mean_tile(replicas, m, range);
                }
            })
            .collect();
        exec.run_jobs(jobs);
    }
    mean
}

/// [`mix_step_tile`] under partial participation: active rows run the
/// renormalized SpMM, inactive rows copy through; **every** row then
/// gets the momentum update while the tile is cache-resident (the
/// trainer's straggler model: a dropped worker misses the exchange but
/// still applies its local gradient).
#[allow(clippy::too_many_arguments)]
fn mix_active_step_tile(
    graph: &CommGraph,
    replicas: &ReplicaMatrix,
    active: &[bool],
    totals: &[f32],
    grads: &ReplicaMatrix,
    hyper: &[(f32, f32)],
    lr: f32,
    mut out_rows: Vec<&mut [f32]>,
    mut vel_rows: Vec<&mut [f32]>,
    range: Range<usize>,
) {
    let mut start = range.start;
    while start < range.end {
        let end = (start + TILE).min(range.end);
        let (lo, hi) = (start - range.start, end - range.start);
        for (i, (out_row, vel_row)) in
            out_rows.iter_mut().zip(vel_rows.iter_mut()).enumerate()
        {
            let out = &mut out_row[lo..hi];
            if active[i] {
                let total = totals[i];
                let mut first = true;
                for (j, w) in graph.row(i) {
                    if !active[j] {
                        continue;
                    }
                    let w = w / total;
                    let src = &replicas.row(j)[start..end];
                    if first {
                        simd::scale(out, src, w);
                        first = false;
                    } else {
                        simd::axpy(out, src, w);
                    }
                }
            } else {
                out.copy_from_slice(&replicas.row(i)[start..end]);
            }
            let (mu, wd) = hyper[i];
            let vel = &mut vel_row[lo..hi];
            let g = &grads.row(i)[start..end];
            simd::sgd_step(out, vel, g, mu, wd, lr);
        }
        start = end;
    }
}

/// One worker's share of the fused gossip+SGD round: SpMM a tile, then
/// immediately run the momentum update on it (same element ops as
/// [`SgdState::step`] — both route through [`simd::sgd_step`]) before
/// moving to the next tile.
#[allow(clippy::too_many_arguments)]
fn mix_step_tile(
    graph: &CommGraph,
    replicas: &ReplicaMatrix,
    grads: &ReplicaMatrix,
    hyper: &[(f32, f32)],
    lr: f32,
    mut out_rows: Vec<&mut [f32]>,
    mut vel_rows: Vec<&mut [f32]>,
    range: Range<usize>,
) {
    let mut start = range.start;
    while start < range.end {
        let end = (start + TILE).min(range.end);
        let (lo, hi) = (start - range.start, end - range.start);
        for (i, (out_row, vel_row)) in
            out_rows.iter_mut().zip(vel_rows.iter_mut()).enumerate()
        {
            let out = &mut out_row[lo..hi];
            let mut first = true;
            for (j, w) in graph.row(i) {
                let src = &replicas.row(j)[start..end];
                if first {
                    simd::scale(out, src, w);
                    first = false;
                } else {
                    simd::axpy(out, src, w);
                }
            }
            let (mu, wd) = hyper[i];
            let vel = &mut vel_row[lo..hi];
            let g = &grads.row(i)[start..end];
            simd::sgd_step(out, vel, g, mu, wd, lr);
        }
        start = end;
    }
}

fn is_uniform_complete(graph: &CommGraph) -> bool {
    let n = graph.n();
    if n < 2 {
        return true;
    }
    let w = 1.0 / n as f32;
    (0..n).all(|i| {
        graph.degree_of(i) == n - 1 && (graph.self_weight(i) - w).abs() < 1e-7
    })
}

/// Reference dense mixing (O(n²P), allocation-heavy) over the
/// **pre-refactor `Vec<Vec<f32>>` layout** — kept as the independent
/// criterion baseline the flat-store kernels are tested against
/// (`ReplicaMatrix::to_vecs` bridges).
pub fn mix_dense_reference(graph: &CommGraph, replicas: &[Vec<f32>]) -> Vec<Vec<f32>> {
    let n = graph.n();
    let p = replicas[0].len();
    let w = graph.dense_mixing();
    let mut out = vec![vec![0.0f32; p]; n];
    for i in 0..n {
        for j in 0..n {
            let wij = w[i * n + j];
            if wij != 0.0 {
                for k in 0..p {
                    out[i][k] += wij * replicas[j][k];
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphKind;

    fn replicas(n: usize, p: usize, seed: u64) -> ReplicaMatrix {
        let mut rng = crate::util::rng::Rng::seed_from_u64(seed);
        let rows: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..p).map(|_| rng.range_f32(-1.0, 1.0)).collect())
            .collect();
        ReplicaMatrix::from_rows(&rows)
    }

    fn global_mean(replicas: &ReplicaMatrix) -> Vec<f64> {
        let p = replicas.p();
        let mut m = vec![0.0f64; p];
        for r in replicas.rows() {
            for (mi, &v) in m.iter_mut().zip(r.iter()) {
                *mi += v as f64;
            }
        }
        m.iter().map(|v| v / replicas.n() as f64).collect()
    }

    #[test]
    fn matches_dense_reference_all_graphs() {
        for kind in [
            GraphKind::Ring,
            GraphKind::Torus,
            GraphKind::RingLattice { k: 3 },
            GraphKind::AdaLattice { k: 4 },
            GraphKind::Exponential,
            GraphKind::Complete,
        ] {
            let n = 16;
            let g = CommGraph::build(kind, n).unwrap();
            let mut reps = replicas(n, 37, 5);
            let expect = mix_dense_reference(&g, &reps.to_vecs());
            GossipEngine::new().mix(&g, &mut reps);
            for i in 0..n {
                for k in 0..37 {
                    assert!(
                        (reps[i][k] - expect[i][k]).abs() < 1e-5,
                        "{kind} mismatch at [{i}][{k}]"
                    );
                }
            }
        }
    }

    #[test]
    fn preserves_global_mean() {
        // Doubly stochastic W ⇒ the global mean is invariant — the core
        // conservation law of decentralized averaging.
        for kind in [GraphKind::Ring, GraphKind::Exponential, GraphKind::AdaLattice { k: 6 }] {
            let n = 24;
            let g = CommGraph::build(kind, n).unwrap();
            let mut reps = replicas(n, 101, 9);
            let before = global_mean(&reps);
            let mut eng = GossipEngine::new();
            for _ in 0..10 {
                eng.mix(&g, &mut reps);
            }
            let after = global_mean(&reps);
            for (b, a) in before.iter().zip(&after) {
                assert!((b - a).abs() < 1e-4, "mean drifted: {b} → {a}");
            }
        }
    }

    #[test]
    fn converges_to_consensus() {
        let n = 12;
        let g = CommGraph::build(GraphKind::Ring, n).unwrap();
        let mut reps = replicas(n, 5, 2);
        let target = global_mean(&reps);
        let mut eng = GossipEngine::new();
        for _ in 0..2000 {
            eng.mix(&g, &mut reps);
        }
        for r in reps.rows() {
            for (v, t) in r.iter().zip(&target) {
                assert!((*v as f64 - t).abs() < 1e-3, "must reach consensus");
            }
        }
    }

    #[test]
    fn complete_graph_reaches_consensus_in_one_round() {
        let n = 9;
        let g = CommGraph::build(GraphKind::Complete, n).unwrap();
        let mut reps = replicas(n, 11, 3);
        let target = global_mean(&reps);
        GossipEngine::new().mix(&g, &mut reps);
        for r in reps.rows() {
            for (v, t) in r.iter().zip(&target) {
                assert!((*v as f64 - t).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn fast_path_equals_slow_path_for_complete() {
        let n = 8;
        let g = CommGraph::build(GraphKind::Complete, n).unwrap();
        let src = replicas(n, 23, 7);
        let mut fast = src.clone();
        GossipEngine::new().mix(&g, &mut fast);
        let slow = mix_dense_reference(&g, &src.to_vecs());
        for i in 0..n {
            for k in 0..23 {
                assert!((fast[i][k] - slow[i][k]).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn inactive_nodes_keep_parameters() {
        let n = 8;
        let g = CommGraph::build(GraphKind::Ring, n).unwrap();
        let mut reps = replicas(n, 7, 1);
        let frozen = reps.row(3).to_vec();
        let mut active = vec![true; n];
        active[3] = false;
        GossipEngine::new().mix_active(&g, &mut reps, &active);
        assert_eq!(reps.row(3), &frozen[..], "inactive node must not change");
    }

    #[test]
    fn active_mix_renormalizes_rows() {
        // With a dropped neighbor, remaining weights are rescaled so the
        // result is still a convex combination (no mass loss).
        let n = 6;
        let g = CommGraph::build(GraphKind::Complete, n).unwrap();
        let rows: Vec<Vec<f32>> = (0..n).map(|i| vec![i as f32]).collect();
        let mut reps = ReplicaMatrix::from_rows(&rows);
        let mut active = vec![true; n];
        active[5] = false;
        GossipEngine::new().mix_active(&g, &mut reps, &active);
        // Active nodes average over {0..4}: mean 2.0.
        for i in 0..5 {
            assert!((reps[i][0] - 2.0).abs() < 1e-5, "node {i} got {}", reps[i][0]);
        }
        assert_eq!(reps[5][0], 5.0);
    }

    #[test]
    #[should_panic(expected = "replica count")]
    fn mismatched_sizes_panic() {
        let g = CommGraph::build(GraphKind::Ring, 4).unwrap();
        let mut reps = replicas(3, 5, 0);
        GossipEngine::new().mix(&g, &mut reps);
    }

    #[test]
    fn scratch_is_reused_across_rounds() {
        // Behavioural proxy: repeated mixing with the same engine gives
        // identical results to fresh engines (no scratch contamination).
        let g = CommGraph::build(GraphKind::Torus, 9).unwrap();
        let src = replicas(9, 13, 4);
        let mut a = src.clone();
        let mut eng = GossipEngine::new();
        eng.mix(&g, &mut a);
        eng.mix(&g, &mut a);
        let mut b = src.clone();
        GossipEngine::new().mix(&g, &mut b);
        GossipEngine::new().mix(&g, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn parallel_mix_is_bit_identical_to_serial() {
        // P chosen to force several tiles per worker at 4 threads.
        for kind in [GraphKind::Ring, GraphKind::Exponential, GraphKind::Complete] {
            let n = 8;
            let p = 3 * MIN_COLS_PER_WORKER + 17;
            let g = CommGraph::build(kind, n).unwrap();
            let src = replicas(n, p, 21);
            let mut serial = src.clone();
            GossipEngine::new().mix(&g, &mut serial);
            for threads in [2, 3, 4, 8] {
                let mut par = src.clone();
                GossipEngine::with_threads(threads).mix(&g, &mut par);
                assert_eq!(serial, par, "{kind} differs at {threads} threads");
            }
        }
    }

    #[test]
    fn fused_mix_step_equals_mix_then_step() {
        for kind in [GraphKind::Ring, GraphKind::Torus, GraphKind::Exponential] {
            let n = 12;
            let p = 257;
            let g = CommGraph::build(kind, n).unwrap();
            let src = replicas(n, p, 31);
            let grads = replicas(n, p, 32);
            let (mu, wd, lr) = (0.9f32, 1e-4f32, 0.05f32);

            // Split: mix, then per-replica momentum step.
            let mut split = src.clone();
            let mut split_states: Vec<SgdState> =
                (0..n).map(|_| SgdState::new(p, mu, wd)).collect();
            let mut eng = GossipEngine::new();
            for round in 0..3 {
                eng.mix(&g, &mut split);
                let shared = grads.row(round % n).to_vec();
                for (w, s) in split_states.iter_mut().enumerate() {
                    s.step(split.row_mut(w), &shared, lr);
                }
            }

            // Fused: one pass per round.
            let mut fused = src.clone();
            let mut fused_states: Vec<SgdState> =
                (0..n).map(|_| SgdState::new(p, mu, wd)).collect();
            let mut feng = GossipEngine::new();
            for round in 0..3 {
                let gs = ReplicaMatrix::broadcast(n, grads.row(round % n));
                feng.mix_step(&g, &mut fused, &gs, &mut fused_states, lr);
            }
            // Same element ops in the same order ⇒ exact equality on the
            // general (non-complete) path.
            assert_eq!(split, fused, "{kind}: fused must equal mix-then-step");
        }
    }

    #[test]
    fn fused_mix_step_is_bit_identical_across_threads() {
        let n = 6;
        let p = 2 * MIN_COLS_PER_WORKER + 5;
        let g = CommGraph::build(GraphKind::RingLattice { k: 2 }, n).unwrap();
        let src = replicas(n, p, 41);
        let grads = replicas(n, p, 42);
        let run = |threads: usize| {
            let mut reps = src.clone();
            let mut states: Vec<SgdState> =
                (0..n).map(|_| SgdState::new(p, 0.9, 0.0)).collect();
            let mut eng = GossipEngine::with_threads(threads);
            for _ in 0..2 {
                eng.mix_step(&g, &mut reps, &grads, &mut states, 0.1);
            }
            reps
        };
        let one = run(1);
        for threads in [2, 4, 8] {
            assert_eq!(one, run(threads), "fused differs at {threads} threads");
        }
    }

    #[test]
    fn fused_active_step_equals_mix_active_then_step() {
        // The mix_active_step contract: identical floats to the split
        // mix_active + per-replica step fallback, inactive rows included
        // (they keep their parameters but still apply their gradient).
        for kind in [GraphKind::Ring, GraphKind::Torus, GraphKind::Exponential] {
            let n = 12;
            let p = 257;
            let g = CommGraph::build(kind, n).unwrap();
            let src = replicas(n, p, 51);
            let grads = replicas(n, p, 52);
            let active: Vec<bool> = (0..n).map(|i| i % 4 != 2).collect();
            let (mu, wd, lr) = (0.9f32, 1e-4f32, 0.05f32);

            let mut split = src.clone();
            let mut split_states: Vec<SgdState> =
                (0..n).map(|_| SgdState::new(p, mu, wd)).collect();
            let mut eng = GossipEngine::new();
            for _ in 0..3 {
                eng.mix_active(&g, &mut split, &active);
                for (w, s) in split_states.iter_mut().enumerate() {
                    s.step(split.row_mut(w), grads.row(w), lr);
                }
            }

            let mut fused = src.clone();
            let mut fused_states: Vec<SgdState> =
                (0..n).map(|_| SgdState::new(p, mu, wd)).collect();
            let mut feng = GossipEngine::new();
            for _ in 0..3 {
                feng.mix_active_step(&g, &mut fused, &grads, &mut fused_states, lr, &active);
            }
            assert_eq!(split, fused, "{kind}: fused active must equal split");
            for (a, b) in split_states.iter().zip(&fused_states) {
                assert_eq!(a.velocity(), b.velocity(), "{kind}: velocity drift");
            }
        }
    }

    #[test]
    fn fused_active_step_with_full_mask_routes_to_mix_step() {
        let n = 8;
        let g = CommGraph::build(GraphKind::Ring, n).unwrap();
        let src = replicas(n, 101, 61);
        let grads = replicas(n, 101, 62);
        let run = |fused_active: bool| {
            let mut reps = src.clone();
            let mut states: Vec<SgdState> =
                (0..n).map(|_| SgdState::new(101, 0.9, 0.0)).collect();
            let mut eng = GossipEngine::new();
            if fused_active {
                eng.mix_active_step(&g, &mut reps, &grads, &mut states, 0.1, &vec![true; n]);
            } else {
                eng.mix_step(&g, &mut reps, &grads, &mut states, 0.1);
            }
            reps
        };
        assert_eq!(run(true), run(false));
    }

    #[test]
    fn mean_model_matches_serial_mean() {
        let n = 9;
        let p = 2 * MIN_COLS_PER_WORKER + 33; // force several tiles
        let reps = replicas(n, p, 71);
        let serial = crate::exec::ExecEngine::serial();
        let reference = mean_model(&serial, &reps);
        // Bit-identical across thread counts.
        for threads in [2, 4, 8] {
            let eng = crate::exec::ExecEngine::new(threads);
            assert_eq!(reference, mean_model(&eng, &reps), "{threads} threads");
        }
        // And numerically the f32 replica mean.
        for k in (0..p).step_by(997) {
            let want: f32 = reps.rows().map(|r| r[k]).sum::<f32>() / n as f32;
            assert!((reference[k] - want).abs() < 1e-5, "col {k}");
        }
    }
}
