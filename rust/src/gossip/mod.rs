//! The gossip mixing engine: applies a mixing matrix to the stacked
//! replica parameters, `Θ' = W Θ` (§2.2's neighbor averaging
//! `Σ_j E_ij θ_j`).
//!
//! Two interchangeable execution paths:
//!  * **native** (this module): sparse row-wise mixing over the graph's
//!    neighbor lists with reused scratch buffers and an O(nP)
//!    fast path for uniform complete graphs. This is the production hot
//!    path and the baseline the kernel path is benchmarked against.
//!  * **HLO kernel** (`crate::runtime::GossipKernel`): the L1 Pallas
//!    `gossip_mix` kernel AOT-lowered to an HLO executable and run via
//!    PJRT — demonstrating the paper's averaging step as an MXU matmul
//!    (DESIGN.md §Hardware-Adaptation).

use crate::graph::CommGraph;

/// Reusable mixing engine. Holds scratch buffers so steady-state rounds
/// allocate nothing.
#[derive(Debug, Default)]
pub struct GossipEngine {
    scratch: Vec<Vec<f32>>,
}

impl GossipEngine {
    /// New engine with empty scratch (grown on first use).
    pub fn new() -> Self {
        Self::default()
    }

    /// One gossip round in place: `replicas[i] ← Σ_j W_ij · replicas[j]`.
    ///
    /// `replicas.len()` must equal `graph.n()` and all replicas must have
    /// equal length.
    pub fn mix(&mut self, graph: &CommGraph, replicas: &mut [Vec<f32>]) {
        let n = graph.n();
        assert_eq!(replicas.len(), n, "replica count must match graph size");
        if n == 0 {
            return;
        }
        let p = replicas[0].len();
        assert!(
            replicas.iter().all(|r| r.len() == p),
            "replicas must have equal parameter counts"
        );

        // Fast path: uniform complete graph == global mean.
        if is_uniform_complete(graph) {
            let mean = column_mean(replicas, p);
            for r in replicas.iter_mut() {
                r.copy_from_slice(&mean);
            }
            return;
        }

        self.ensure_scratch(n, p);
        let scratch = &mut self.scratch;
        // out[i] = Σ_(j,w) w · in[j], computed in column tiles so the
        // working set (one tile of every replica) stays cache-resident
        // across all n output rows — a blocked SpMM over the sparse
        // mixing matrix (§Perf iteration 2: ~2× at n=64, P=1M on the
        // higher-degree graphs, where the row-major pass re-streams
        // each 4 MB source row from DRAM once per consumer).
        const TILE: usize = 4096;
        let mut start = 0;
        while start < p {
            let end = (start + TILE).min(p);
            for (i, out) in scratch.iter_mut().enumerate() {
                let out = &mut out[start..end];
                let mut first = true;
                for (j, w) in graph.row(i) {
                    let src = &replicas[j][start..end];
                    if first {
                        for (o, &s) in out.iter_mut().zip(src.iter()) {
                            *o = w * s;
                        }
                        first = false;
                    } else {
                        axpy(out, src, w);
                    }
                }
            }
            start = end;
        }
        // Swap buffers instead of copying back: saves one full O(nP)
        // memory pass per round (§Perf iteration 1).
        for (r, s) in replicas.iter_mut().zip(scratch.iter_mut()) {
            std::mem::swap(r, s);
        }
    }

    /// Mix only a subset round (partial participation is not used by the
    /// paper but exercised by failure-injection tests): rows not in
    /// `active` keep their parameters.
    pub fn mix_active(&mut self, graph: &CommGraph, replicas: &mut [Vec<f32>], active: &[bool]) {
        let n = graph.n();
        assert_eq!(replicas.len(), n);
        assert_eq!(active.len(), n);
        if active.iter().all(|&a| a) {
            return self.mix(graph, replicas);
        }
        let p = replicas[0].len();
        self.ensure_scratch(n, p);
        let scratch = &mut self.scratch;
        scratch.iter_mut().enumerate().for_each(|(i, out)| {
            if !active[i] {
                out.copy_from_slice(&replicas[i]);
                return;
            }
            // Renormalize over active rows so the result stays an average.
            let mut total = 0.0f32;
            for (j, w) in graph.row(i) {
                if active[j] {
                    total += w;
                }
            }
            let mut first = true;
            for (j, w) in graph.row(i) {
                if !active[j] {
                    continue;
                }
                let w = w / total;
                let src = &replicas[j];
                if first {
                    for (o, &s) in out.iter_mut().zip(src.iter()) {
                        *o = w * s;
                    }
                    first = false;
                } else {
                    axpy(out, src, w);
                }
            }
        });
        for (r, s) in replicas.iter_mut().zip(scratch.iter_mut()) {
            std::mem::swap(r, s);
        }
    }

    fn ensure_scratch(&mut self, n: usize, p: usize) {
        if self.scratch.len() != n || self.scratch.first().map(Vec::len) != Some(p) {
            self.scratch = vec![vec![0.0f32; p]; n];
        }
    }
}

/// `out += w * src`, the inner loop of mixing. Written so LLVM
/// auto-vectorizes (no bounds checks in the loop body).
#[inline]
fn axpy(out: &mut [f32], src: &[f32], w: f32) {
    let len = out.len().min(src.len());
    let (o, s) = (&mut out[..len], &src[..len]);
    for i in 0..len {
        o[i] += w * s[i];
    }
}

/// Column-wise mean of the replica stack.
fn column_mean(replicas: &[Vec<f32>], p: usize) -> Vec<f32> {
    let n = replicas.len() as f32;
    let mut mean = vec![0.0f32; p];
    for r in replicas {
        axpy(&mut mean, r, 1.0);
    }
    for m in mean.iter_mut() {
        *m /= n;
    }
    mean
}

fn is_uniform_complete(graph: &CommGraph) -> bool {
    let n = graph.n();
    if n < 2 {
        return true;
    }
    let w = 1.0 / n as f32;
    (0..n).all(|i| {
        graph.degree_of(i) == n - 1 && (graph.self_weight(i) - w).abs() < 1e-7
    })
}

/// Reference dense mixing (O(n²P), allocation-heavy) used by tests and
/// as the criterion baseline.
pub fn mix_dense_reference(graph: &CommGraph, replicas: &[Vec<f32>]) -> Vec<Vec<f32>> {
    let n = graph.n();
    let p = replicas[0].len();
    let w = graph.dense_mixing();
    let mut out = vec![vec![0.0f32; p]; n];
    for i in 0..n {
        for j in 0..n {
            let wij = w[i * n + j];
            if wij != 0.0 {
                for k in 0..p {
                    out[i][k] += wij * replicas[j][k];
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphKind;

    fn replicas(n: usize, p: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = crate::util::rng::Rng::seed_from_u64(seed);
        (0..n)
            .map(|_| (0..p).map(|_| rng.range_f32(-1.0, 1.0)).collect())
            .collect()
    }

    fn global_mean(replicas: &[Vec<f32>]) -> Vec<f64> {
        let p = replicas[0].len();
        let mut m = vec![0.0f64; p];
        for r in replicas {
            for (mi, &v) in m.iter_mut().zip(r.iter()) {
                *mi += v as f64;
            }
        }
        m.iter().map(|v| v / replicas.len() as f64).collect()
    }

    #[test]
    fn matches_dense_reference_all_graphs() {
        for kind in [
            GraphKind::Ring,
            GraphKind::Torus,
            GraphKind::RingLattice { k: 3 },
            GraphKind::AdaLattice { k: 4 },
            GraphKind::Exponential,
            GraphKind::Complete,
        ] {
            let n = 16;
            let g = CommGraph::build(kind, n).unwrap();
            let mut reps = replicas(n, 37, 5);
            let expect = mix_dense_reference(&g, &reps);
            GossipEngine::new().mix(&g, &mut reps);
            for i in 0..n {
                for k in 0..37 {
                    assert!(
                        (reps[i][k] - expect[i][k]).abs() < 1e-5,
                        "{kind} mismatch at [{i}][{k}]"
                    );
                }
            }
        }
    }

    #[test]
    fn preserves_global_mean() {
        // Doubly stochastic W ⇒ the global mean is invariant — the core
        // conservation law of decentralized averaging.
        for kind in [GraphKind::Ring, GraphKind::Exponential, GraphKind::AdaLattice { k: 6 }] {
            let n = 24;
            let g = CommGraph::build(kind, n).unwrap();
            let mut reps = replicas(n, 101, 9);
            let before = global_mean(&reps);
            let mut eng = GossipEngine::new();
            for _ in 0..10 {
                eng.mix(&g, &mut reps);
            }
            let after = global_mean(&reps);
            for (b, a) in before.iter().zip(&after) {
                assert!((b - a).abs() < 1e-4, "mean drifted: {b} → {a}");
            }
        }
    }

    #[test]
    fn converges_to_consensus() {
        let n = 12;
        let g = CommGraph::build(GraphKind::Ring, n).unwrap();
        let mut reps = replicas(n, 5, 2);
        let target = global_mean(&reps);
        let mut eng = GossipEngine::new();
        for _ in 0..2000 {
            eng.mix(&g, &mut reps);
        }
        for r in &reps {
            for (v, t) in r.iter().zip(&target) {
                assert!((*v as f64 - t).abs() < 1e-3, "must reach consensus");
            }
        }
    }

    #[test]
    fn complete_graph_reaches_consensus_in_one_round() {
        let n = 9;
        let g = CommGraph::build(GraphKind::Complete, n).unwrap();
        let mut reps = replicas(n, 11, 3);
        let target = global_mean(&reps);
        GossipEngine::new().mix(&g, &mut reps);
        for r in &reps {
            for (v, t) in r.iter().zip(&target) {
                assert!((*v as f64 - t).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn fast_path_equals_slow_path_for_complete() {
        let n = 8;
        let g = CommGraph::build(GraphKind::Complete, n).unwrap();
        let src = replicas(n, 23, 7);
        let mut fast = src.clone();
        GossipEngine::new().mix(&g, &mut fast);
        let slow = mix_dense_reference(&g, &src);
        for i in 0..n {
            for k in 0..23 {
                assert!((fast[i][k] - slow[i][k]).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn inactive_nodes_keep_parameters() {
        let n = 8;
        let g = CommGraph::build(GraphKind::Ring, n).unwrap();
        let mut reps = replicas(n, 7, 1);
        let frozen = reps[3].clone();
        let mut active = vec![true; n];
        active[3] = false;
        GossipEngine::new().mix_active(&g, &mut reps, &active);
        assert_eq!(reps[3], frozen, "inactive node must not change");
    }

    #[test]
    fn active_mix_renormalizes_rows() {
        // With a dropped neighbor, remaining weights are rescaled so the
        // result is still a convex combination (no mass loss).
        let n = 6;
        let g = CommGraph::build(GraphKind::Complete, n).unwrap();
        let mut reps: Vec<Vec<f32>> = (0..n).map(|i| vec![i as f32]).collect();
        let mut active = vec![true; n];
        active[5] = false;
        GossipEngine::new().mix_active(&g, &mut reps, &active);
        // Active nodes average over {0..4}: mean 2.0.
        for (i, r) in reps.iter().enumerate().take(5) {
            assert!((r[0] - 2.0).abs() < 1e-5, "node {i} got {}", r[0]);
        }
        assert_eq!(reps[5][0], 5.0);
    }

    #[test]
    #[should_panic(expected = "replica count")]
    fn mismatched_sizes_panic() {
        let g = CommGraph::build(GraphKind::Ring, 4).unwrap();
        let mut reps = replicas(3, 5, 0);
        GossipEngine::new().mix(&g, &mut reps);
    }

    #[test]
    fn scratch_is_reused_across_rounds() {
        // Behavioural proxy: repeated mixing with the same engine gives
        // identical results to fresh engines (no scratch contamination).
        let g = CommGraph::build(GraphKind::Torus, 9).unwrap();
        let src = replicas(9, 13, 4);
        let mut a = src.clone();
        let mut eng = GossipEngine::new();
        eng.mix(&g, &mut a);
        eng.mix(&g, &mut a);
        let mut b = src.clone();
        GossipEngine::new().mix(&g, &mut b);
        GossipEngine::new().mix(&g, &mut b);
        assert_eq!(a, b);
    }
}
