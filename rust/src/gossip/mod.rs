//! The gossip mixing engine: applies a mixing matrix to the stacked
//! replica parameters, `Θ' = W Θ` (§2.2's neighbor averaging
//! `Σ_j E_ij θ_j`).
//!
//! ## Data plane
//!
//! Since the flat-store refactor the replica stack lives in a
//! [`ReplicaMatrix`] — one 64-byte-aligned contiguous allocation with a
//! padded row stride (`rust/src/util/matrix.rs` documents the layout
//! contract) — and every inner loop below runs on the explicit SIMD
//! kernel layer ([`crate::exec::simd`]): AVX2 `f32x8` behind runtime
//! feature detection, with a fixed-8-lane scalar fallback that is
//! bit-identical by construction.
//!
//! Two interchangeable execution paths:
//!  * **native** (this module): sparse row-wise mixing over the graph's
//!    neighbor lists with a reused scratch matrix, an O(nP) fast path
//!    for uniform complete graphs, and **fused gossip+SGD kernels**
//!    ([`GossipEngine::mix_step`], and [`GossipEngine::mix_active_step`]
//!    for partial-participation rounds) that apply the momentum update
//!    while each mixed tile is still cache-resident. This is the
//!    production hot path and the baseline the kernel path is
//!    benchmarked against. The training loop reaches it through the
//!    open strategy layer (`crate::coordinator::strategy`): the
//!    `GossipCombine`/`FusedGossipCombine` strategies call `mix`/
//!    `mix_step` (or the `_active` variants under failure injection),
//!    and custom strategies get the same engine via their `StepCtx`.
//!  * **HLO kernel** (`crate::runtime::GossipKernel`, `pjrt` feature):
//!    the L1 Pallas `gossip_mix` kernel AOT-lowered to an HLO executable
//!    and run via PJRT — demonstrating the paper's averaging step as an
//!    MXU matmul (DESIGN.md §Hardware-Adaptation).
//!
//! ## Parallel execution
//!
//! The native kernels fan out over the [`crate::exec`] engine — a
//! **persistent worker pool**, spawned once when the `GossipEngine` is
//! built and parked between rounds: the parameter axis is partitioned
//! into contiguous column tiles and each worker owns its tiles of
//! **all** n replicas (a blocked SpMM over the sparse mixing matrix).
//! [`ReplicaMatrix::rows_mut`] is the split point: disjoint mutable row
//! views of the flat buffer, transposed into per-worker column views by
//! [`column_views`]. Because every output element's reduction order is
//! fixed by its graph row alone — and the SIMD layer never reassociates
//! an elementwise sequence — results are **bit-identical for any thread
//! count and for both SIMD and scalar paths** — see
//! `rust/src/exec/mod.rs` and `rust/src/exec/simd.rs` for the argument
//! and `rust/tests/exec_determinism.rs` for the proof-by-test. Scratch
//! pages are first-touched inside the owning worker's column tile
//! ([`GossipEngine::ensure_scratch`]) so page placement follows tile
//! ownership — the groundwork for NUMA pinning (ROADMAP §Open items).

use crate::compress::Codec;
use crate::error::Result;
use crate::exec::pipeline::{run_overlapped, BucketTable, Progress};
use crate::exec::{column_views, simd, ExecEngine};
use crate::graph::CommGraph;
use crate::optim::SgdState;
use std::ops::Range;

pub use crate::util::matrix::ReplicaMatrix;

/// Column-tile width of the blocked SpMM: the working set (one tile of
/// every replica) stays cache-resident across all n output rows
/// (§Perf iteration 2: ~2× at n=64, P=1M on the higher-degree graphs,
/// where a row-major pass re-streams each 4 MB source row from DRAM
/// once per consumer).
const TILE: usize = 4096;

/// A worker must own at least one full tile before a mix call fans out;
/// below that the spawn cost dwarfs the arithmetic and everything runs
/// on the calling thread.
const MIN_COLS_PER_WORKER: usize = TILE;

/// Reusable mixing engine. Holds a scratch matrix plus cached
/// partition/bucket descriptor tables and scalar work buffers, so
/// steady-state rounds — phased or pipelined — allocate nothing on the
/// hot path beyond the O(threads) borrow plumbing `run_jobs` needs.
#[derive(Debug, Default)]
pub struct GossipEngine {
    scratch: ReplicaMatrix,
    mean_scratch: Vec<f32>,
    exec: ExecEngine,
    /// Cached `exec.partition(p, MIN_COLS_PER_WORKER)` keyed by
    /// `part_p` — the phased kernels' column-ownership map, computed
    /// once per parameter-count change instead of once per call.
    part_ranges: Vec<Range<usize>>,
    part_p: usize,
    /// Pipeline bucket width in f32 elements (`0` = the pipeline
    /// default, 256 KB); see [`GossipEngine::set_bucket_kb`].
    bucket_elems: usize,
    /// Cached bucket descriptor table for `(p, bucket_elems)` — the
    /// overlapped path's fixed column cuts, reused across rounds.
    bucket_table: Option<BucketTable>,
    /// Reused per-round `(momentum, weight_decay)` row table (fused
    /// kernels).
    hyper: Vec<(f32, f32)>,
    /// Reused per-round active weight-mass totals (partial
    /// participation).
    totals: Vec<f32>,
    /// Reused per-round produced-row dependency frontiers (overlapped
    /// split path): row `i`'s mix may start once `deps[i]` rows are
    /// retired.
    deps: Vec<usize>,
    /// An overlapped round has filled `scratch` and awaits
    /// [`GossipEngine::publish_overlapped`].
    pending_publish: bool,
    /// Per-edge last-*delivered* peer rows with age counters — the
    /// bounded-staleness path's mailbox ([`GossipEngine::mix_stale`]).
    stale: StaleBuffer,
}

/// Mailbox of last-delivered peer rows for bounded-staleness gossip:
/// one slot per directed edge `(dst, src)`, holding the copy of `src`'s
/// row that last reached `dst` plus the number of rounds since that
/// delivery. A missing slot means the edge has never delivered — the
/// peer is simply renormalized away, exactly like an inactive neighbor
/// in [`GossipEngine::mix_active`]. `BTreeMap` keeps iteration order
/// deterministic regardless of insertion history.
#[derive(Debug, Default)]
struct StaleBuffer {
    slots: std::collections::BTreeMap<(u32, u32), StaleSlot>,
}

#[derive(Debug)]
struct StaleSlot {
    row: Vec<f32>,
    age: usize,
}

impl StaleBuffer {
    fn slot(&self, dst: usize, src: usize) -> Option<&StaleSlot> {
        self.slots.get(&(dst as u32, src as u32))
    }

    fn is_fresh(&self, dst: usize, src: usize) -> bool {
        self.slot(dst, src).is_some_and(|s| s.age == 0)
    }
}

impl GossipEngine {
    /// New single-threaded engine with empty scratch (grown on first use).
    pub fn new() -> Self {
        Self::default()
    }

    /// Engine fanning out over `threads` workers (`0` = all cores).
    /// Results are bit-identical to [`GossipEngine::new`] for any value.
    pub fn with_threads(threads: usize) -> Self {
        GossipEngine {
            exec: ExecEngine::new(threads),
            ..GossipEngine::default()
        }
    }

    /// Set the overlapped pipeline's bucket width in **KB** (`0` =
    /// default 256 KB). Purely a wall-clock knob: bucket boundaries are
    /// fixed before any thread starts, so results are bit-identical for
    /// every value (see `crate::exec::pipeline`).
    pub fn set_bucket_kb(&mut self, kb: usize) {
        self.set_bucket_elems(kb * (1024 / std::mem::size_of::<f32>()));
    }

    /// Set the bucket width in f32 elements (`0` = default) — the
    /// fine-grained form [`GossipEngine::set_bucket_kb`] wraps, used by
    /// tests that need bucket boundaries inside small parameter counts.
    pub fn set_bucket_elems(&mut self, elems: usize) {
        self.bucket_elems = elems;
        // The cached table is keyed on (p, bucket_elems); it rebuilds
        // lazily on the next overlapped round.
    }

    /// Worker count this engine fans out over.
    pub fn threads(&self) -> usize {
        self.exec.threads()
    }

    /// The underlying execution engine — shared with the trainer's
    /// pooled variance capture and mean-model construction so the whole
    /// iteration runs on one worker set.
    pub fn exec(&self) -> &ExecEngine {
        &self.exec
    }

    /// One gossip round in place: `Θ_i ← Σ_j W_ij · Θ_j`.
    ///
    /// `replicas.n()` must equal `graph.n()` (the equal-parameter-count
    /// invariant is structural in [`ReplicaMatrix`]).
    pub fn mix(&mut self, graph: &CommGraph, replicas: &mut ReplicaMatrix) {
        let n = graph.n();
        assert_eq!(replicas.n(), n, "replica count must match graph size");
        if n == 0 {
            return;
        }
        let p = replicas.p();

        // Fast path: uniform complete graph == global mean.
        if is_uniform_complete(graph) {
            self.mix_complete(replicas, p);
            return;
        }

        self.ensure_scratch(n, p);
        self.ensure_part_ranges(p);
        {
            let Self { scratch, exec, part_ranges, .. } = &mut *self;
            let reps: &ReplicaMatrix = replicas;
            let views = column_views(scratch.rows_mut(), part_ranges);
            let jobs: Vec<_> = views
                .into_iter()
                .zip(part_ranges.iter().cloned())
                .map(|(chunks, range)| move || mix_tile(graph, reps, chunks, range))
                .collect();
            exec.run_jobs(jobs);
        }
        self.swap_in_scratch(replicas);
    }

    /// Mix only a subset round (partial participation is not used by the
    /// paper but exercised by failure-injection tests): rows not in
    /// `active` keep their parameters; active rows renormalize their
    /// mixing weights over the active participants so the result stays
    /// a convex combination.
    pub fn mix_active(
        &mut self,
        graph: &CommGraph,
        replicas: &mut ReplicaMatrix,
        active: &[bool],
    ) {
        let n = graph.n();
        assert_eq!(replicas.n(), n, "replica count must match graph size");
        assert_eq!(active.len(), n, "active mask must match graph size");
        if n == 0 {
            return;
        }
        let p = replicas.p();
        if active.iter().all(|&a| a) {
            return self.mix(graph, replicas);
        }
        self.ensure_scratch(n, p);
        self.ensure_part_ranges(p);
        active_totals_into(graph, active, &mut self.totals);
        {
            let Self { scratch, exec, part_ranges, totals, .. } = &mut *self;
            let reps: &ReplicaMatrix = replicas;
            let totals: &[f32] = totals;
            let views = column_views(scratch.rows_mut(), part_ranges);
            let jobs: Vec<_> = views
                .into_iter()
                .zip(part_ranges.iter().cloned())
                .map(|(chunks, range)| {
                    move || mix_active_tile(graph, reps, active, totals, chunks, range)
                })
                .collect();
            exec.run_jobs(jobs);
        }
        self.swap_in_scratch(replicas);
    }

    /// **Fused gossip + momentum-SGD round** — the combined kernel that
    /// eliminates one full O(nP) DRAM round-trip per training iteration:
    ///
    /// ```text
    /// θ'_i = Σ_j W_ij θ_j            (gossip SpMM tile)
    /// v_i  ← μ_i v_i + (g_i + λ_i θ'_i)   (momentum, while the tile
    /// θ'_i ← θ'_i − γ v_i                  is still cache-resident)
    /// ```
    ///
    /// Bit-identical to calling [`GossipEngine::mix`] followed by
    /// [`SgdState::step`] per replica, *except* on uniform complete
    /// graphs where `mix` takes the global-mean fast path (the fused
    /// kernel always runs the general SpMM; results then agree to float
    /// rounding, ~1e-7). `μ_i`/`λ_i` come from each replica's
    /// [`SgdState`]; `γ` is `lr`. Gradients are a [`ReplicaMatrix`] of
    /// the same shape, so the fused tile streams three flat buffers.
    pub fn mix_step(
        &mut self,
        graph: &CommGraph,
        replicas: &mut ReplicaMatrix,
        grads: &ReplicaMatrix,
        states: &mut [SgdState],
        lr: f32,
    ) {
        let n = graph.n();
        assert_eq!(replicas.n(), n, "replica count must match graph size");
        assert_eq!(grads.n(), n, "gradient count must match graph size");
        assert_eq!(states.len(), n, "optimizer state count must match graph size");
        if n == 0 {
            return;
        }
        let p = replicas.p();
        assert_eq!(grads.p(), p, "gradients must match parameter counts");
        assert!(
            states.iter().all(|s| s.len() == p),
            "optimizer states must match parameter counts"
        );

        self.ensure_scratch(n, p);
        self.ensure_part_ranges(p);
        self.hyper.clear();
        self.hyper.extend(states.iter().map(|s| (s.momentum, s.weight_decay)));
        {
            let Self { scratch, exec, part_ranges, hyper, .. } = &mut *self;
            let reps: &ReplicaMatrix = replicas;
            let hyper: &[(f32, f32)] = hyper;
            let out_views = column_views(scratch.rows_mut(), part_ranges);
            let vel_views = column_views(
                states.iter_mut().map(SgdState::velocity_mut).collect(),
                part_ranges,
            );
            let jobs: Vec<_> = out_views
                .into_iter()
                .zip(vel_views)
                .zip(part_ranges.iter().cloned())
                .map(|((outs, vels), range)| {
                    move || mix_step_tile(graph, reps, grads, hyper, lr, outs, vels, range)
                })
                .collect();
            exec.run_jobs(jobs);
        }
        self.swap_in_scratch(replicas);
    }

    /// **Fused partial-participation gossip + momentum-SGD round** —
    /// [`GossipEngine::mix_active`] and the per-replica
    /// [`SgdState::step`] in one pass, so dropout rounds stop paying
    /// the extra O(nP) DRAM round-trip the split fallback costs:
    ///
    /// ```text
    /// θ'_i = Σ_{j active} (W_ij / T_i) θ_j   if active[i]   (renormalized SpMM)
    /// θ'_i = θ_i                              otherwise      (passthrough)
    /// v_i ← μ_i v_i + (g_i + λ_i θ'_i);  θ'_i ← θ'_i − γ v_i   (every i)
    /// ```
    ///
    /// Matching the trainer's straggler model, **inactive rows still
    /// apply their local gradient** — they only miss the exchange.
    /// Bit-identical to `mix_active` followed by `SgdState::step` per
    /// replica (same per-element float sequence), except when every row
    /// is active: that mask delegates to [`GossipEngine::mix_step`],
    /// whose complete-graph handling is documented there.
    #[allow(clippy::too_many_arguments)]
    pub fn mix_active_step(
        &mut self,
        graph: &CommGraph,
        replicas: &mut ReplicaMatrix,
        grads: &ReplicaMatrix,
        states: &mut [SgdState],
        lr: f32,
        active: &[bool],
    ) {
        let n = graph.n();
        assert_eq!(replicas.n(), n, "replica count must match graph size");
        assert_eq!(grads.n(), n, "gradient count must match graph size");
        assert_eq!(states.len(), n, "optimizer state count must match graph size");
        assert_eq!(active.len(), n, "active mask must match graph size");
        if n == 0 {
            return;
        }
        if active.iter().all(|&a| a) {
            return self.mix_step(graph, replicas, grads, states, lr);
        }
        let p = replicas.p();
        assert_eq!(grads.p(), p, "gradients must match parameter counts");
        assert!(
            states.iter().all(|s| s.len() == p),
            "optimizer states must match parameter counts"
        );

        self.ensure_scratch(n, p);
        self.ensure_part_ranges(p);
        active_totals_into(graph, active, &mut self.totals);
        self.hyper.clear();
        self.hyper.extend(states.iter().map(|s| (s.momentum, s.weight_decay)));
        {
            let Self { scratch, exec, part_ranges, hyper, totals, .. } = &mut *self;
            let reps: &ReplicaMatrix = replicas;
            let totals: &[f32] = totals;
            let hyper: &[(f32, f32)] = hyper;
            let out_views = column_views(scratch.rows_mut(), part_ranges);
            let vel_views = column_views(
                states.iter_mut().map(SgdState::velocity_mut).collect(),
                part_ranges,
            );
            let jobs: Vec<_> = out_views
                .into_iter()
                .zip(vel_views)
                .zip(part_ranges.iter().cloned())
                .map(|((outs, vels), range)| {
                    move || {
                        mix_active_step_tile(
                            graph, reps, active, totals, grads, hyper, lr, outs, vels, range,
                        )
                    }
                })
                .collect();
            exec.run_jobs(jobs);
        }
        self.swap_in_scratch(replicas);
    }

    /// Complete-graph fast path: one column-mean pass + one broadcast
    /// copy, both fanned out over the same column ranges.
    fn mix_complete(&mut self, replicas: &mut ReplicaMatrix, p: usize) {
        if self.mean_scratch.len() != p {
            // Fresh lazily-zero-mapped pages; the owning workers'
            // writes in phase 1 below are the first touch.
            self.mean_scratch = vec![0.0f32; p];
        }
        self.ensure_part_ranges(p);
        let Self { mean_scratch, exec, part_ranges, pending_publish, .. } = &mut *self;
        // A completed phased round supersedes any unpublished
        // overlapped scratch.
        *pending_publish = false;
        // Phase 1: column mean of the replica stack. Write-first into
        // the scratch tile (replica 0 seeds it) instead of zeroing and
        // accumulating — one fewer pass over every tile per round.
        {
            let reps: &ReplicaMatrix = replicas;
            let mean_views = column_views(vec![mean_scratch.as_mut_slice()], part_ranges);
            let jobs: Vec<_> = mean_views
                .into_iter()
                .zip(part_ranges.iter().cloned())
                .map(|(mut chunks, range)| {
                    move || {
                        let m = chunks.pop().expect("one mean row");
                        mean_tile(reps, m, range);
                    }
                })
                .collect();
            exec.run_jobs(jobs);
        }
        // Phase 2: broadcast the mean into every replica.
        {
            let mean: &[f32] = mean_scratch;
            let rep_views = column_views(replicas.rows_mut(), part_ranges);
            let jobs: Vec<_> = rep_views
                .into_iter()
                .zip(part_ranges.iter().cloned())
                .map(|(chunks, range)| {
                    move || {
                        let src = &mean[range];
                        for chunk in chunks {
                            chunk.copy_from_slice(src);
                        }
                    }
                })
                .collect();
            exec.run_jobs(jobs);
        }
    }

    /// Refresh the cached column-partition table when the parameter
    /// count changes (satellite of the pipeline PR: the phased and
    /// overlapped hot paths recompute no descriptor tables per call).
    fn ensure_part_ranges(&mut self, p: usize) {
        if self.part_p != p || (p > 0 && self.part_ranges.is_empty()) {
            self.part_ranges = self.exec.partition(p, MIN_COLS_PER_WORKER);
            self.part_p = p;
        }
    }

    /// Refresh the cached bucket descriptor table for
    /// `(p, self.bucket_elems)`; reused across overlapped rounds.
    fn ensure_bucket_table(&mut self, p: usize) {
        let fresh = self
            .bucket_table
            .as_ref()
            .is_some_and(|t| t.matches(p, self.bucket_elems));
        if !fresh {
            self.bucket_table = Some(BucketTable::new(p, self.bucket_elems));
        }
    }

    fn ensure_scratch(&mut self, n: usize, p: usize) {
        if self.scratch.n() == n && self.scratch.p() == p {
            return;
        }
        // One flat zeroed allocation: the pages come back lazily mapped
        // from the zeroed allocator, so the pooled pass below is the
        // true first touch of every page, from the worker that owns
        // those columns — deciding which core (and on multi-socket
        // hosts, which NUMA node) backs each tile, aligned with the
        // tile ownership every later kernel call uses (ROADMAP §NUMA).
        self.scratch = ReplicaMatrix::zeros(n, p);
        self.ensure_part_ranges(p);
        let Self { scratch, exec, part_ranges, .. } = &mut *self;
        if part_ranges.len() > 1 {
            let views = column_views(scratch.rows_mut(), part_ranges);
            let jobs: Vec<_> = views
                .into_iter()
                .map(|chunks| {
                    move || {
                        for chunk in chunks {
                            chunk.fill(0.0);
                            // Keep the touching stores observable.
                            std::hint::black_box(&mut *chunk);
                        }
                    }
                })
                .collect();
            exec.run_jobs(jobs);
        }
    }

    /// Swap the scratch store into `replicas` instead of copying back:
    /// with the flat layout this is one pointer-triple exchange — the
    /// old per-row `Vec` swap loop is gone entirely (§Perf iteration 1
    /// saved the copy; the flat store also saves the n swaps).
    fn swap_in_scratch(&mut self, replicas: &mut ReplicaMatrix) {
        self.pending_publish = false;
        std::mem::swap(replicas, &mut self.scratch);
    }

    /// **Overlapped split-gossip round** (adapt-then-combine through the
    /// bucket pipeline): `produce(w, row)` runs the local step of
    /// replica `w` on the calling thread — ascending `w`, each row
    /// retired as it finishes — while pool workers mix finished rows
    /// into the scratch store one parameter bucket at a time
    /// ([`crate::exec::pipeline::run_overlapped`]). The mix of output
    /// row `i` starts as soon as every row its graph row reads is
    /// produced, so communication hides behind the remaining compute.
    ///
    /// The mixed result stays in scratch until
    /// [`GossipEngine::publish_overlapped`] swaps it in — the capture
    /// point between a session's two phases therefore still observes
    /// the post-local, pre-averaging replicas, exactly like the phased
    /// path.
    ///
    /// `active` follows [`GossipEngine::mix_active`]'s contract
    /// (all-present masks take the [`GossipEngine::mix`] route,
    /// including its uniform-complete fast path). Bit-identity: per
    /// element, the fold order is the graph row's neighbor order — the
    /// same sequence as `mix`/`mix_active` — so pipelined equals phased
    /// bitwise at any thread count and bucket size.
    ///
    /// On `Err` from `produce`, the round aborts (rows already stepped
    /// keep their new values, like a phased local phase failing
    /// mid-loop), scratch is not published, and the error is returned.
    pub fn mix_overlapped<F>(
        &mut self,
        graph: &CommGraph,
        replicas: &mut ReplicaMatrix,
        active: Option<&[bool]>,
        mut produce: F,
    ) -> Result<()>
    where
        F: FnMut(usize, &mut [f32]) -> Result<()>,
    {
        let n = graph.n();
        assert_eq!(replicas.n(), n, "replica count must match graph size");
        if let Some(a) = active {
            assert_eq!(a.len(), n, "active mask must match graph size");
        }
        let p = replicas.p();
        if n == 0 {
            self.ensure_scratch(0, p);
            self.pending_publish = true;
            return Ok(());
        }
        // All-present masks route like `None`, mirroring `mix_active`'s
        // delegation to `mix` so pipelined floats match phased floats.
        let active = active.filter(|a| a.iter().any(|&x| !x));
        let complete = active.is_none() && is_uniform_complete(graph);

        self.ensure_scratch(n, p);
        self.ensure_bucket_table(p);
        if let Some(a) = active {
            active_totals_into(graph, a, &mut self.totals);
        }
        if complete {
            if self.mean_scratch.len() != p {
                self.mean_scratch = vec![0.0f32; p];
            }
        } else {
            deps_into(graph, &mut self.deps);
        }

        let Self {
            scratch,
            mean_scratch,
            exec,
            bucket_table,
            totals,
            deps,
            ..
        } = &mut *self;
        let table = bucket_table.as_ref().expect("bucket table ensured");
        let stride = replicas.stride();
        let base = replicas.base_ptr_mut();
        // `replicas` is untouched through references for the rest of
        // the round: the producer writes rows through `writer`, the
        // consumers read them through `src`, and the produced-row
        // frontier keeps the two disjoint (see `SrcRows`).
        let src = SrcRows::new(base as *const f32, stride, p);
        let mut writer = RowWriter::new(base, stride, p);
        let producer = move |progress: &Progress| -> Result<()> {
            for w in 0..n {
                // SAFETY: row w is not yet retired, so no consumer
                // reads it; rows are disjoint by stride.
                produce(w, unsafe { writer.row_mut(w) })?;
                progress.retire(w + 1);
            }
            Ok(())
        };

        let result = if complete {
            let mean_chunks: Vec<&mut [f32]> =
                column_views(vec![mean_scratch.as_mut_slice()], table.buckets())
                    .into_iter()
                    .map(|mut v| v.pop().expect("one mean row"))
                    .collect();
            let out_views = column_views(scratch.rows_mut(), table.buckets());
            let consumers: Vec<_> = out_views
                .into_iter()
                .zip(mean_chunks)
                .zip(table.buckets().iter().cloned())
                .map(|((outs, mean_chunk), range)| {
                    move |progress: &Progress| {
                        mean_bucket_overlapped(src, n, progress, mean_chunk, outs, range)
                    }
                })
                .collect();
            run_overlapped(exec, consumers, producer)
        } else if let Some(a) = active {
            let totals: &[f32] = totals;
            let deps: &[usize] = deps;
            let out_views = column_views(scratch.rows_mut(), table.buckets());
            let consumers: Vec<_> = out_views
                .into_iter()
                .zip(table.buckets().iter().cloned())
                .map(|(outs, range)| {
                    move |progress: &Progress| {
                        mix_active_bucket_overlapped(
                            graph, src, a, totals, deps, progress, outs, range,
                        )
                    }
                })
                .collect();
            run_overlapped(exec, consumers, producer)
        } else {
            let deps: &[usize] = deps;
            let out_views = column_views(scratch.rows_mut(), table.buckets());
            let consumers: Vec<_> = out_views
                .into_iter()
                .zip(table.buckets().iter().cloned())
                .map(|(outs, range)| {
                    move |progress: &Progress| {
                        mix_bucket_overlapped(graph, src, deps, progress, outs, range)
                    }
                })
                .collect();
            run_overlapped(exec, consumers, producer)
        };
        result?;
        self.pending_publish = true;
        Ok(())
    }

    /// **Overlapped fused gossip + momentum-SGD round**
    /// (combine-then-adapt through the bucket pipeline): the D-PSGD
    /// analogue of [`GossipEngine::mix_overlapped`]. `produce(w,
    /// theta_row, grad_out)` computes replica `w`'s gradient at the
    /// *frozen* pre-round parameters on the calling thread; because
    /// `θ_t` never changes during the round, every bucket's gossip SpMM
    /// runs dependency-free on the pool from the first instant — the
    /// full communication pass hides behind gradient compute — and only
    /// the per-row momentum application waits for its own gradient row.
    ///
    /// Same complete-graph policy as the phased
    /// [`GossipEngine::mix_step`] (the fused kernels always run the
    /// general SpMM); same `active` contract as
    /// [`GossipEngine::mix_active_step`] (all-present masks route like
    /// `None`; inactive rows copy through but still apply their
    /// gradient). The updated parameters stay in scratch until
    /// [`GossipEngine::publish_overlapped`]. Bit-identical to the
    /// phased fused kernels: splitting SpMM and SGD into two passes
    /// leaves each element's float sequence unchanged.
    #[allow(clippy::too_many_arguments)]
    pub fn mix_step_overlapped<F>(
        &mut self,
        graph: &CommGraph,
        replicas: &ReplicaMatrix,
        grads: &mut ReplicaMatrix,
        states: &mut [SgdState],
        lr: f32,
        active: Option<&[bool]>,
        mut produce: F,
    ) -> Result<()>
    where
        F: FnMut(usize, &[f32], &mut [f32]) -> Result<()>,
    {
        let n = graph.n();
        assert_eq!(replicas.n(), n, "replica count must match graph size");
        assert_eq!(grads.n(), n, "gradient count must match graph size");
        assert_eq!(states.len(), n, "optimizer state count must match graph size");
        if let Some(a) = active {
            assert_eq!(a.len(), n, "active mask must match graph size");
        }
        let p = replicas.p();
        if n == 0 {
            self.ensure_scratch(0, p);
            self.pending_publish = true;
            return Ok(());
        }
        assert_eq!(grads.p(), p, "gradients must match parameter counts");
        assert!(
            states.iter().all(|s| s.len() == p),
            "optimizer states must match parameter counts"
        );
        let active = active.filter(|a| a.iter().any(|&x| !x));

        self.ensure_scratch(n, p);
        self.ensure_bucket_table(p);
        self.hyper.clear();
        self.hyper.extend(states.iter().map(|s| (s.momentum, s.weight_decay)));
        if let Some(a) = active {
            active_totals_into(graph, a, &mut self.totals);
        }

        let Self {
            scratch,
            exec,
            bucket_table,
            hyper,
            totals,
            ..
        } = &mut *self;
        let table = bucket_table.as_ref().expect("bucket table ensured");
        let hyper: &[(f32, f32)] = hyper;
        let reps: &ReplicaMatrix = replicas;
        let gstride = grads.stride();
        let gbase = grads.base_ptr_mut();
        let grad_src = SrcRows::new(gbase as *const f32, gstride, p);
        let mut writer = RowWriter::new(gbase, gstride, p);
        let producer = move |progress: &Progress| -> Result<()> {
            for w in 0..n {
                // SAFETY: gradient row w is not yet retired; consumers
                // only read retired rows.
                produce(w, reps.row(w), unsafe { writer.row_mut(w) })?;
                progress.retire(w + 1);
            }
            Ok(())
        };

        let result = if let Some(a) = active {
            let totals: &[f32] = totals;
            let out_views = column_views(scratch.rows_mut(), table.buckets());
            let vel_views = column_views(
                states.iter_mut().map(SgdState::velocity_mut).collect(),
                table.buckets(),
            );
            let consumers: Vec<_> = out_views
                .into_iter()
                .zip(vel_views)
                .zip(table.buckets().iter().cloned())
                .map(|((outs, vels), range)| {
                    move |progress: &Progress| {
                        mix_active_step_bucket_overlapped(
                            graph, reps, a, totals, grad_src, hyper, lr, progress, outs, vels,
                            range,
                        )
                    }
                })
                .collect();
            run_overlapped(exec, consumers, producer)
        } else {
            let out_views = column_views(scratch.rows_mut(), table.buckets());
            let vel_views = column_views(
                states.iter_mut().map(SgdState::velocity_mut).collect(),
                table.buckets(),
            );
            let consumers: Vec<_> = out_views
                .into_iter()
                .zip(vel_views)
                .zip(table.buckets().iter().cloned())
                .map(|((outs, vels), range)| {
                    move |progress: &Progress| {
                        mix_step_bucket_overlapped(
                            graph, reps, grad_src, hyper, lr, progress, outs, vels, range,
                        )
                    }
                })
                .collect();
            run_overlapped(exec, consumers, producer)
        };
        result?;
        self.pending_publish = true;
        Ok(())
    }

    /// Publish a completed overlapped round: swap the mixed scratch
    /// store into `replicas` (one pointer-triple exchange, the same
    /// hand-off the phased kernels make internally). Panics if no
    /// overlapped round is pending — the pipelined combine phase must
    /// follow a successful `*_overlapped` call.
    pub fn publish_overlapped(&mut self, replicas: &mut ReplicaMatrix) {
        assert!(
            self.pending_publish,
            "publish_overlapped requires a completed overlapped mix round"
        );
        assert_eq!(self.scratch.n(), replicas.n(), "publish shape mismatch (n)");
        assert_eq!(self.scratch.p(), replicas.p(), "publish shape mismatch (p)");
        self.pending_publish = false;
        std::mem::swap(replicas, &mut self.scratch);
    }

    /// Whether an overlapped round awaits [`GossipEngine::publish_overlapped`].
    pub fn has_pending_publish(&self) -> bool {
        self.pending_publish
    }

    /// Deliver this round's messages into the stale buffer. For every
    /// directed graph edge `j → i` (neighbor `j` of destination `i`),
    /// `delivered(j, i)` decides whether `j`'s current row reaches `i`:
    /// delivered edges overwrite the slot and reset its age to 0,
    /// undelivered edges age their existing slot by one round (a
    /// never-delivered edge stays absent). The simulated fault plane
    /// (`crate::simnet::FaultPlan`) is the intended `delivered` oracle;
    /// the closure is called in a fixed `(dst asc, src asc)` order so
    /// stateful oracles stay deterministic too.
    ///
    /// Call after the local step and before [`GossipEngine::mix_stale`]
    /// — the buffered copies are what peers *sent*, frozen even if the
    /// sender keeps training.
    pub fn ingest_stale<F>(
        &mut self,
        graph: &CommGraph,
        replicas: &ReplicaMatrix,
        delivered: F,
    ) where
        F: Fn(usize, usize) -> bool,
    {
        let n = graph.n();
        assert_eq!(replicas.n(), n, "replica count must match graph size");
        for i in 0..n {
            for (j, _) in graph.row(i) {
                if j == i {
                    continue;
                }
                if delivered(j, i) {
                    let slot = self
                        .stale
                        .slots
                        .entry((i as u32, j as u32))
                        .or_insert_with(|| StaleSlot { row: Vec::new(), age: 0 });
                    slot.row.clear();
                    slot.row.extend_from_slice(replicas.row(j));
                    slot.age = 0;
                } else if let Some(slot) =
                    self.stale.slots.get_mut(&(i as u32, j as u32))
                {
                    slot.age = slot.age.saturating_add(1);
                }
            }
        }
    }

    /// **Bounded-staleness gossip round**: like [`GossipEngine::mix`],
    /// but each destination averages against the last-*delivered* copy
    /// of every peer row (the stale buffer filled by
    /// [`GossipEngine::ingest_stale`]) instead of the live stack. A
    /// peer counts only if its slot exists, its age is ≤ `bound`
    /// rounds, and `active` (if given) marks it up; excluded peers are
    /// renormalized away exactly like [`GossipEngine::mix_active`]'s
    /// dropped participants, so late or lost messages degrade the round
    /// gracefully instead of stalling it. The self term always reads
    /// the live local row. A row whose peers are all stale renormalizes
    /// to its own value; a destination marked inactive copies through
    /// untouched.
    ///
    /// When every graph edge is fresh (age 0 — the fault-free steady
    /// state), the round delegates to [`GossipEngine::mix`] /
    /// [`GossipEngine::mix_active`], buffered copies being bitwise
    /// equal to the live rows — so a quiet `FaultPlan` with any bound
    /// reproduces the phased path's floats exactly (test-enforced).
    /// Like every kernel here, results are bit-identical for any
    /// thread count: the fold order per output element is fixed by the
    /// graph row alone.
    pub fn mix_stale(
        &mut self,
        graph: &CommGraph,
        replicas: &mut ReplicaMatrix,
        active: Option<&[bool]>,
        bound: usize,
    ) {
        let n = graph.n();
        assert_eq!(replicas.n(), n, "replica count must match graph size");
        if let Some(a) = active {
            assert_eq!(a.len(), n, "active mask must match graph size");
        }
        if n == 0 {
            return;
        }
        let p = replicas.p();
        // Slots from a run with a different parameter count are
        // meaningless; drop them so every surviving row slices cleanly.
        self.stale.slots.retain(|_, s| s.row.len() == p);

        let all_fresh = (0..n)
            .all(|i| graph.row(i).all(|(j, _)| j == i || self.stale.is_fresh(i, j)));
        if all_fresh {
            // Fresh buffered copies are bitwise equal to the live rows,
            // so the phased kernels (incl. the uniform-complete fast
            // path and mix_active's renormalization) give the exact
            // same floats with one less indirection.
            return match active.filter(|a| a.iter().any(|&x| !x)) {
                Some(a) => self.mix_active(graph, replicas, a),
                None => self.mix(graph, replicas),
            };
        }

        self.ensure_scratch(n, p);
        self.ensure_part_ranges(p);
        stale_totals_into(graph, &self.stale, active, bound, &mut self.totals);
        {
            let Self { scratch, exec, part_ranges, totals, stale, .. } = &mut *self;
            let reps: &ReplicaMatrix = replicas;
            let totals: &[f32] = totals;
            let stale: &StaleBuffer = stale;
            let views = column_views(scratch.rows_mut(), part_ranges);
            let jobs: Vec<_> = views
                .into_iter()
                .zip(part_ranges.iter().cloned())
                .map(|(chunks, range)| {
                    move || {
                        mix_stale_tile(graph, reps, stale, active, totals, bound, chunks, range)
                    }
                })
                .collect();
            exec.run_jobs(jobs);
        }
        self.swap_in_scratch(replicas);
    }

    /// Measured staleness over the graph's delivered edges: `(max age,
    /// mean age)`, or `(None, None)` when nothing has ever been
    /// delivered. Never-delivered edges are excluded (they have no age,
    /// only absence) — the session feeds these into `TrainSignals` for
    /// staleness-aware topology policies.
    pub fn stale_stats(&self, graph: &CommGraph) -> (Option<usize>, Option<f64>) {
        let mut max: Option<usize> = None;
        let mut sum = 0.0f64;
        let mut count = 0usize;
        for i in 0..graph.n() {
            for (j, _) in graph.row(i) {
                if j == i {
                    continue;
                }
                if let Some(s) = self.stale.slot(i, j) {
                    max = Some(max.map_or(s.age, |m| m.max(s.age)));
                    sum += s.age as f64;
                    count += 1;
                }
            }
        }
        (max, (count > 0).then(|| sum / count as f64))
    }

    /// Number of edges currently holding a delivered copy.
    pub fn stale_edges(&self) -> usize {
        self.stale.slots.len()
    }

    /// Forget every buffered peer row — the start-of-run state, used
    /// when a session reuses one engine across independent runs.
    pub fn reset_stale(&mut self) {
        self.stale.slots.clear();
    }

    /// [`GossipEngine::mix`] with peer rows travelling through a lossy
    /// exchange [`Codec`]: every *peer* contribution is encoded+decoded
    /// per tile right before it enters the weighted fold — modeling a
    /// half-width wire without materializing a compressed matrix — while
    /// the self contribution (never on the wire) stays f32.
    ///
    /// [`Codec::F32`] delegates to [`GossipEngine::mix`] (bit-identical,
    /// including the uniform-complete fast path). The lossy codecs run
    /// the general tiled path: the round-trip is elementwise and scalar,
    /// so results stay bit-identical across thread counts and SIMD
    /// modes.
    pub fn mix_codec(&mut self, graph: &CommGraph, replicas: &mut ReplicaMatrix, codec: Codec) {
        if codec == Codec::F32 {
            return self.mix(graph, replicas);
        }
        let n = graph.n();
        assert_eq!(replicas.n(), n, "replica count must match graph size");
        if n == 0 {
            return;
        }
        let p = replicas.p();
        self.ensure_scratch(n, p);
        self.ensure_part_ranges(p);
        {
            let Self { scratch, exec, part_ranges, .. } = &mut *self;
            let reps: &ReplicaMatrix = replicas;
            let views = column_views(scratch.rows_mut(), part_ranges);
            let jobs: Vec<_> = views
                .into_iter()
                .zip(part_ranges.iter().cloned())
                .map(|(chunks, range)| {
                    move || mix_exchange_tile(graph, reps, reps, codec, chunks, range)
                })
                .collect();
            exec.run_jobs(jobs);
        }
        self.swap_in_scratch(replicas);
    }

    /// A mix round whose *peer* contributions come from a separate
    /// message matrix (the sparsified/error-feedback exchange path):
    /// `Θ'_i = W_ii·Θ_i + Σ_{j≠i} W_ij·codec(M_j)`. The self term reads
    /// the live replica row — a node always has its own full-precision
    /// parameters — while peers only see what was published into
    /// `messages`.
    ///
    /// Always runs the general tiled path (no complete-graph fast path),
    /// so `messages == replicas` with [`Codec::F32`] reproduces
    /// [`GossipEngine::mix`]'s general path bitwise on non-complete
    /// graphs.
    pub fn mix_from(
        &mut self,
        graph: &CommGraph,
        replicas: &mut ReplicaMatrix,
        messages: &ReplicaMatrix,
        codec: Codec,
    ) {
        let n = graph.n();
        assert_eq!(replicas.n(), n, "replica count must match graph size");
        assert_eq!(messages.n(), n, "message count must match graph size");
        if n == 0 {
            return;
        }
        let p = replicas.p();
        assert_eq!(messages.p(), p, "message width must match replicas");
        self.ensure_scratch(n, p);
        self.ensure_part_ranges(p);
        {
            let Self { scratch, exec, part_ranges, .. } = &mut *self;
            let reps: &ReplicaMatrix = replicas;
            let views = column_views(scratch.rows_mut(), part_ranges);
            let jobs: Vec<_> = views
                .into_iter()
                .zip(part_ranges.iter().cloned())
                .map(|(chunks, range)| {
                    move || mix_exchange_tile(graph, reps, messages, codec, chunks, range)
                })
                .collect();
            exec.run_jobs(jobs);
        }
        self.swap_in_scratch(replicas);
    }
}

/// One worker's share of a mix round: the blocked SpMM over its column
/// range of every output row. `out_rows[i]` is row `i` restricted to
/// `range`; reads come from the (shared, immutable) pre-round replicas.
fn mix_tile(
    graph: &CommGraph,
    replicas: &ReplicaMatrix,
    mut out_rows: Vec<&mut [f32]>,
    range: Range<usize>,
) {
    let mut start = range.start;
    while start < range.end {
        let end = (start + TILE).min(range.end);
        let (lo, hi) = (start - range.start, end - range.start);
        for (i, out_row) in out_rows.iter_mut().enumerate() {
            let out = &mut out_row[lo..hi];
            let mut first = true;
            for (j, w) in graph.row(i) {
                let src = &replicas.row(j)[start..end];
                if first {
                    simd::scale(out, src, w);
                    first = false;
                } else {
                    simd::axpy(out, src, w);
                }
            }
        }
        start = end;
    }
}

/// [`mix_tile`] with peer contributions drawn from `messages` and
/// round-tripped through `codec` per tile (the compressed exchange
/// path; `messages` aliases `replicas` for the dense codec route). The
/// self contribution always reads the live replica row in f32. The
/// decode staging buffer is per worker and per tile, but the round-trip
/// is elementwise — value `i` depends only on value `i` — so tile and
/// thread boundaries cannot change the produced bits.
fn mix_exchange_tile(
    graph: &CommGraph,
    replicas: &ReplicaMatrix,
    messages: &ReplicaMatrix,
    codec: Codec,
    mut out_rows: Vec<&mut [f32]>,
    range: Range<usize>,
) {
    let mut decoded = vec![0.0f32; TILE.min(range.end - range.start)];
    let mut start = range.start;
    while start < range.end {
        let end = (start + TILE).min(range.end);
        let (lo, hi) = (start - range.start, end - range.start);
        let width = end - start;
        for (i, out_row) in out_rows.iter_mut().enumerate() {
            let out = &mut out_row[lo..hi];
            let mut first = true;
            for (j, w) in graph.row(i) {
                let src: &[f32] = if j == i {
                    &replicas.row(j)[start..end]
                } else {
                    let d = &mut decoded[..width];
                    codec.roundtrip_into(&messages.row(j)[start..end], d);
                    d
                };
                if first {
                    simd::scale(out, src, w);
                    first = false;
                } else {
                    simd::axpy(out, src, w);
                }
            }
        }
        start = end;
    }
}

/// [`mix_tile`] under partial participation: inactive rows copy their
/// parameters through; active rows renormalize by the precomputed
/// active weight mass `totals[i]`.
fn mix_active_tile(
    graph: &CommGraph,
    replicas: &ReplicaMatrix,
    active: &[bool],
    totals: &[f32],
    mut out_rows: Vec<&mut [f32]>,
    range: Range<usize>,
) {
    let mut start = range.start;
    while start < range.end {
        let end = (start + TILE).min(range.end);
        let (lo, hi) = (start - range.start, end - range.start);
        for (i, out_row) in out_rows.iter_mut().enumerate() {
            let out = &mut out_row[lo..hi];
            if !active[i] {
                out.copy_from_slice(&replicas.row(i)[start..end]);
                continue;
            }
            let total = totals[i];
            let mut first = true;
            for (j, w) in graph.row(i) {
                if !active[j] {
                    continue;
                }
                let w = w / total;
                let src = &replicas.row(j)[start..end];
                if first {
                    simd::scale(out, src, w);
                    first = false;
                } else {
                    simd::axpy(out, src, w);
                }
            }
        }
        start = end;
    }
}

/// Per-row active weight mass `T_i = Σ_{j active} W_ij`, O(n·deg) once
/// per round — the tiled inner loops of [`mix_active_tile`] and
/// [`mix_active_step_tile`] then only divide. Shared by both the split
/// and fused partial-participation paths so their renormalization can
/// never diverge.
fn active_totals_into(graph: &CommGraph, active: &[bool], out: &mut Vec<f32>) {
    out.clear();
    out.extend((0..graph.n()).map(|i| {
        graph
            .row(i)
            .filter(|&(j, _)| active[j])
            .map(|(_, w)| w)
            .sum::<f32>()
    }));
}

/// Per-row considered weight mass for the bounded-staleness round:
/// `T_i = W_ii + Σ_{j considered} W_ij`, where a neighbor `j` is
/// considered iff its slot exists with age ≤ `bound` and `active` (if
/// any) marks it up. Must match [`mix_stale_tile`]'s predicate exactly
/// or renormalization diverges — both route through
/// [`stale_considered`].
fn stale_totals_into(
    graph: &CommGraph,
    stale: &StaleBuffer,
    active: Option<&[bool]>,
    bound: usize,
    out: &mut Vec<f32>,
) {
    out.clear();
    out.extend((0..graph.n()).map(|i| {
        graph
            .row(i)
            .filter(|&(j, _)| j == i || stale_considered(stale, active, bound, i, j))
            .map(|(_, w)| w)
            .sum::<f32>()
    }));
}

/// The single considered-peer predicate shared by [`stale_totals_into`]
/// and [`mix_stale_tile`].
fn stale_considered(
    stale: &StaleBuffer,
    active: Option<&[bool]>,
    bound: usize,
    dst: usize,
    src: usize,
) -> bool {
    active.is_none_or(|a| a[src]) && stale.slot(dst, src).is_some_and(|s| s.age <= bound)
}

/// [`mix_active_tile`]'s shape for the bounded-staleness round: the
/// self term reads the **live** local row, every neighbor term reads
/// its buffered last-delivered copy, non-considered peers are skipped
/// and renormalized away via `totals`. Inactive destinations copy
/// through; a destination with zero considered mass (possible when the
/// self weight is 0 and every peer is stale) keeps its local row.
#[allow(clippy::too_many_arguments)]
fn mix_stale_tile(
    graph: &CommGraph,
    replicas: &ReplicaMatrix,
    stale: &StaleBuffer,
    active: Option<&[bool]>,
    totals: &[f32],
    bound: usize,
    mut out_rows: Vec<&mut [f32]>,
    range: Range<usize>,
) {
    let mut start = range.start;
    while start < range.end {
        let end = (start + TILE).min(range.end);
        let (lo, hi) = (start - range.start, end - range.start);
        for (i, out_row) in out_rows.iter_mut().enumerate() {
            let out = &mut out_row[lo..hi];
            let total = totals[i];
            if active.is_some_and(|a| !a[i]) || total <= 0.0 {
                out.copy_from_slice(&replicas.row(i)[start..end]);
                continue;
            }
            let mut first = true;
            for (j, w) in graph.row(i) {
                let src: &[f32] = if j == i {
                    replicas.row(i)
                } else if stale_considered(stale, active, bound, i, j) {
                    &stale.slot(i, j).expect("considered slot exists").row
                } else {
                    continue;
                };
                let w = w / total;
                let s = &src[start..end];
                if first {
                    simd::scale(out, s, w);
                    first = false;
                } else {
                    simd::axpy(out, s, w);
                }
            }
            if first {
                out.copy_from_slice(&replicas.row(i)[start..end]);
            }
        }
        start = end;
    }
}

/// Per-output-row pipeline dependency: mixing row `i` needs row `i`
/// itself (self weight) and every in-neighbor `j` produced, i.e. the
/// frontier must reach `1 + max(i, max_j)`. Computed once per round
/// into a reused buffer — a pure function of the graph, independent of
/// bucketing and thread count.
fn deps_into(graph: &CommGraph, out: &mut Vec<usize>) {
    out.clear();
    out.extend((0..graph.n()).map(|i| {
        1 + graph.row(i).map(|(j, _)| j).fold(i, usize::max)
    }));
}

/// One worker's tile of a column mean: seed with replica 0, accumulate
/// the rest, scale — no zeroing pass. Per-element operand order is the
/// replica order, independent of tiling and of the SIMD/scalar path
/// (elementwise kernels never reassociate), so the mean is
/// bit-identical for any thread count.
fn mean_tile(replicas: &ReplicaMatrix, out: &mut [f32], range: Range<usize>) {
    out.copy_from_slice(&replicas.row(0)[range.clone()]);
    for i in 1..replicas.n() {
        simd::axpy(out, &replicas.row(i)[range.clone()], 1.0);
    }
    let inv = 1.0 / replicas.n() as f32;
    simd::scale_in_place(out, inv);
}

/// The replica-averaged model `θ̄ = (1/n) Σ_i θ_i`, fanned out over
/// `exec`'s column tiles — the parallel form of the trainer's
/// mean-model evaluation (§2.2: "the trained model takes θ as the
/// average over all θ_i"), which was the last serial O(n·P) pass on the
/// evaluation path.
pub fn mean_model(exec: &ExecEngine, replicas: &ReplicaMatrix) -> Vec<f32> {
    assert!(!replicas.is_empty(), "mean_model needs at least one replica");
    let p = replicas.p();
    let mut mean = vec![0.0f32; p];
    let ranges = exec.partition(p, MIN_COLS_PER_WORKER);
    {
        let views = column_views(vec![mean.as_mut_slice()], &ranges);
        let jobs: Vec<_> = views
            .into_iter()
            .zip(ranges.iter().cloned())
            .map(|(mut chunks, range)| {
                move || {
                    let m = chunks.pop().expect("one mean row");
                    mean_tile(replicas, m, range);
                }
            })
            .collect();
        exec.run_jobs(jobs);
    }
    mean
}

/// [`mix_step_tile`] under partial participation: active rows run the
/// renormalized SpMM, inactive rows copy through; **every** row then
/// gets the momentum update while the tile is cache-resident (the
/// trainer's straggler model: a dropped worker misses the exchange but
/// still applies its local gradient).
#[allow(clippy::too_many_arguments)]
fn mix_active_step_tile(
    graph: &CommGraph,
    replicas: &ReplicaMatrix,
    active: &[bool],
    totals: &[f32],
    grads: &ReplicaMatrix,
    hyper: &[(f32, f32)],
    lr: f32,
    mut out_rows: Vec<&mut [f32]>,
    mut vel_rows: Vec<&mut [f32]>,
    range: Range<usize>,
) {
    let mut start = range.start;
    while start < range.end {
        let end = (start + TILE).min(range.end);
        let (lo, hi) = (start - range.start, end - range.start);
        for (i, (out_row, vel_row)) in
            out_rows.iter_mut().zip(vel_rows.iter_mut()).enumerate()
        {
            let out = &mut out_row[lo..hi];
            if active[i] {
                let total = totals[i];
                let mut first = true;
                for (j, w) in graph.row(i) {
                    if !active[j] {
                        continue;
                    }
                    let w = w / total;
                    let src = &replicas.row(j)[start..end];
                    if first {
                        simd::scale(out, src, w);
                        first = false;
                    } else {
                        simd::axpy(out, src, w);
                    }
                }
            } else {
                out.copy_from_slice(&replicas.row(i)[start..end]);
            }
            let (mu, wd) = hyper[i];
            let vel = &mut vel_row[lo..hi];
            let g = &grads.row(i)[start..end];
            simd::sgd_step(out, vel, g, mu, wd, lr);
        }
        start = end;
    }
}

/// One worker's share of the fused gossip+SGD round: SpMM a tile, then
/// immediately run the momentum update on it (same element ops as
/// [`SgdState::step`] — both route through [`simd::sgd_step`]) before
/// moving to the next tile.
#[allow(clippy::too_many_arguments)]
fn mix_step_tile(
    graph: &CommGraph,
    replicas: &ReplicaMatrix,
    grads: &ReplicaMatrix,
    hyper: &[(f32, f32)],
    lr: f32,
    mut out_rows: Vec<&mut [f32]>,
    mut vel_rows: Vec<&mut [f32]>,
    range: Range<usize>,
) {
    let mut start = range.start;
    while start < range.end {
        let end = (start + TILE).min(range.end);
        let (lo, hi) = (start - range.start, end - range.start);
        for (i, (out_row, vel_row)) in
            out_rows.iter_mut().zip(vel_rows.iter_mut()).enumerate()
        {
            let out = &mut out_row[lo..hi];
            let mut first = true;
            for (j, w) in graph.row(i) {
                let src = &replicas.row(j)[start..end];
                if first {
                    simd::scale(out, src, w);
                    first = false;
                } else {
                    simd::axpy(out, src, w);
                }
            }
            let (mu, wd) = hyper[i];
            let vel = &mut vel_row[lo..hi];
            let g = &grads.row(i)[start..end];
            simd::sgd_step(out, vel, g, mu, wd, lr);
        }
        start = end;
    }
}

/// Shared read view over a [`ReplicaMatrix`]'s rows for the overlapped
/// pipeline, by raw base pointer so the producer can keep a writer over
/// the same buffer. Disjointness is the pipeline protocol, not the type
/// system: a consumer may call [`SrcRows::row`] for row `w` only after
/// the produced-row frontier has retired `w` (`Progress::wait_for`
/// provides the happens-before edge), and the producer never rewrites a
/// retired row within the round.
#[derive(Clone, Copy)]
struct SrcRows<'a> {
    base: *const f32,
    stride: usize,
    p: usize,
    _marker: std::marker::PhantomData<&'a f32>,
}

// SAFETY: the pointer derives from a live `ReplicaMatrix` borrow held
// across the overlapped region; reads are confined to retired rows (see
// struct docs), which no thread writes after retirement.
unsafe impl Send for SrcRows<'_> {}
unsafe impl Sync for SrcRows<'_> {}

impl<'a> SrcRows<'a> {
    fn new(base: *const f32, stride: usize, p: usize) -> Self {
        SrcRows { base, stride, p, _marker: std::marker::PhantomData }
    }

    /// # Safety
    /// Row `i` must be retired on the frontier the caller waited on,
    /// and `i` must be in bounds of the source matrix.
    unsafe fn row(&self, i: usize) -> &'a [f32] {
        std::slice::from_raw_parts(self.base.add(i * self.stride), self.p)
    }
}

/// The producer's write view over the same buffer: row `w` is exclusively
/// the producer's until it retires `w` on the frontier, after which the
/// producer must not touch it again within the round.
struct RowWriter<'a> {
    base: *mut f32,
    stride: usize,
    p: usize,
    _marker: std::marker::PhantomData<&'a mut f32>,
}

// SAFETY: moved into the producer closure which runs on one thread; row
// access is serialized by the retire protocol described above.
unsafe impl Send for RowWriter<'_> {}

impl<'a> RowWriter<'a> {
    fn new(base: *mut f32, stride: usize, p: usize) -> Self {
        RowWriter { base, stride, p, _marker: std::marker::PhantomData }
    }

    /// # Safety
    /// Row `i` must not yet be retired (no concurrent reader) and must
    /// be in bounds; rows never alias (stride ≥ p).
    #[allow(clippy::mut_from_ref)]
    unsafe fn row_mut(&mut self, i: usize) -> &'a mut [f32] {
        std::slice::from_raw_parts_mut(self.base.add(i * self.stride), self.p)
    }
}

/// One bucket's share of an overlapped mix round: for each output row,
/// wait until every row its graph row reads has been produced, then run
/// exactly [`mix_tile`]'s float sequence over this bucket's column
/// range. Per-element operand order is the graph row order — identical
/// to the phased kernel — so bucketing changes scheduling, never bits.
fn mix_bucket_overlapped(
    graph: &CommGraph,
    src: SrcRows<'_>,
    deps: &[usize],
    progress: &Progress,
    mut out_rows: Vec<&mut [f32]>,
    range: Range<usize>,
) {
    for (i, out_row) in out_rows.iter_mut().enumerate() {
        progress.wait_for(deps[i]);
        let mut start = range.start;
        while start < range.end {
            let end = (start + TILE).min(range.end);
            let (lo, hi) = (start - range.start, end - range.start);
            let out = &mut out_row[lo..hi];
            let mut first = true;
            for (j, w) in graph.row(i) {
                // SAFETY: frontier has reached deps[i] ≥ j + 1.
                let src_row = unsafe { src.row(j) };
                let s = &src_row[start..end];
                if first {
                    simd::scale(out, s, w);
                    first = false;
                } else {
                    simd::axpy(out, s, w);
                }
            }
            start = end;
        }
    }
}

/// [`mix_bucket_overlapped`] under partial participation — the
/// overlapped form of [`mix_active_tile`], same copy-through /
/// renormalize policy and per-element float sequence.
#[allow(clippy::too_many_arguments)]
fn mix_active_bucket_overlapped(
    graph: &CommGraph,
    src: SrcRows<'_>,
    active: &[bool],
    totals: &[f32],
    deps: &[usize],
    progress: &Progress,
    mut out_rows: Vec<&mut [f32]>,
    range: Range<usize>,
) {
    for (i, out_row) in out_rows.iter_mut().enumerate() {
        // Inactive rows still wait on their own production (dep ≥ i+1).
        progress.wait_for(deps[i]);
        let mut start = range.start;
        while start < range.end {
            let end = (start + TILE).min(range.end);
            let (lo, hi) = (start - range.start, end - range.start);
            let out = &mut out_row[lo..hi];
            if !active[i] {
                // SAFETY: frontier has reached deps[i] ≥ i + 1.
                out.copy_from_slice(&unsafe { src.row(i) }[start..end]);
                start = end;
                continue;
            }
            let total = totals[i];
            let mut first = true;
            for (j, w) in graph.row(i) {
                if !active[j] {
                    continue;
                }
                let w = w / total;
                // SAFETY: frontier has reached deps[i] ≥ j + 1.
                let s = &unsafe { src.row(j) }[start..end];
                if first {
                    simd::scale(out, s, w);
                    first = false;
                } else {
                    simd::axpy(out, s, w);
                }
            }
            start = end;
        }
    }
}

/// Overlapped complete-graph fast path for one bucket: wait for the
/// full stack (the mean reads every row), run [`mean_tile`]'s exact
/// sequence into this bucket's slice of the mean scratch, then
/// broadcast it into every output row. Equals the phased
/// `mix_complete` values; the overlapped round lands them in scratch
/// for the later publish swap.
fn mean_bucket_overlapped(
    src: SrcRows<'_>,
    n: usize,
    progress: &Progress,
    mean_chunk: &mut [f32],
    out_rows: Vec<&mut [f32]>,
    range: Range<usize>,
) {
    progress.wait_for(n);
    // SAFETY: all n rows are retired.
    mean_chunk.copy_from_slice(&unsafe { src.row(0) }[range.clone()]);
    for i in 1..n {
        simd::axpy(mean_chunk, &unsafe { src.row(i) }[range.clone()], 1.0);
    }
    simd::scale_in_place(mean_chunk, 1.0 / n as f32);
    for out in out_rows {
        out.copy_from_slice(mean_chunk);
    }
}

/// One bucket of the overlapped fused round. Pass 1 — the gossip SpMM
/// over the *frozen* pre-round parameters — has no dependency on the
/// gradient frontier and runs immediately; pass 2 waits per row for its
/// gradient and applies the momentum update. Splitting the two passes
/// leaves every element's float sequence identical to
/// [`mix_step_tile`] (SpMM writes `out`, then `sgd_step` reads it).
#[allow(clippy::too_many_arguments)]
fn mix_step_bucket_overlapped(
    graph: &CommGraph,
    replicas: &ReplicaMatrix,
    grads: SrcRows<'_>,
    hyper: &[(f32, f32)],
    lr: f32,
    progress: &Progress,
    mut out_rows: Vec<&mut [f32]>,
    mut vel_rows: Vec<&mut [f32]>,
    range: Range<usize>,
) {
    // Pass 1: dependency-free SpMM (θ_t is frozen for the round).
    mix_tile(graph, replicas, out_rows.iter_mut().map(|r| &mut **r).collect(), range.clone());
    // Pass 2: per-row momentum update as gradients arrive.
    for (i, (out_row, vel_row)) in out_rows.iter_mut().zip(vel_rows.iter_mut()).enumerate() {
        progress.wait_for(i + 1);
        // SAFETY: gradient row i is retired.
        let grad_row = unsafe { grads.row(i) };
        let (mu, wd) = hyper[i];
        let mut start = range.start;
        while start < range.end {
            let end = (start + TILE).min(range.end);
            let (lo, hi) = (start - range.start, end - range.start);
            simd::sgd_step(
                &mut out_row[lo..hi],
                &mut vel_row[lo..hi],
                &grad_row[start..end],
                mu,
                wd,
                lr,
            );
            start = end;
        }
    }
}

/// [`mix_step_bucket_overlapped`] under partial participation — the
/// overlapped form of [`mix_active_step_tile`]: inactive rows copy
/// through in pass 1, every row applies its gradient in pass 2.
#[allow(clippy::too_many_arguments)]
fn mix_active_step_bucket_overlapped(
    graph: &CommGraph,
    replicas: &ReplicaMatrix,
    active: &[bool],
    totals: &[f32],
    grads: SrcRows<'_>,
    hyper: &[(f32, f32)],
    lr: f32,
    progress: &Progress,
    mut out_rows: Vec<&mut [f32]>,
    mut vel_rows: Vec<&mut [f32]>,
    range: Range<usize>,
) {
    // Pass 1: dependency-free renormalized SpMM / copy-through.
    {
        let mut start = range.start;
        while start < range.end {
            let end = (start + TILE).min(range.end);
            let (lo, hi) = (start - range.start, end - range.start);
            for (i, out_row) in out_rows.iter_mut().enumerate() {
                let out = &mut out_row[lo..hi];
                if !active[i] {
                    out.copy_from_slice(&replicas.row(i)[start..end]);
                    continue;
                }
                let total = totals[i];
                let mut first = true;
                for (j, w) in graph.row(i) {
                    if !active[j] {
                        continue;
                    }
                    let w = w / total;
                    let s = &replicas.row(j)[start..end];
                    if first {
                        simd::scale(out, s, w);
                        first = false;
                    } else {
                        simd::axpy(out, s, w);
                    }
                }
            }
            start = end;
        }
    }
    // Pass 2: per-row momentum update as gradients arrive.
    for (i, (out_row, vel_row)) in out_rows.iter_mut().zip(vel_rows.iter_mut()).enumerate() {
        progress.wait_for(i + 1);
        // SAFETY: gradient row i is retired.
        let grad_row = unsafe { grads.row(i) };
        let (mu, wd) = hyper[i];
        let mut start = range.start;
        while start < range.end {
            let end = (start + TILE).min(range.end);
            let (lo, hi) = (start - range.start, end - range.start);
            simd::sgd_step(
                &mut out_row[lo..hi],
                &mut vel_row[lo..hi],
                &grad_row[start..end],
                mu,
                wd,
                lr,
            );
            start = end;
        }
    }
}

fn is_uniform_complete(graph: &CommGraph) -> bool {
    let n = graph.n();
    if n < 2 {
        return true;
    }
    let w = 1.0 / n as f32;
    (0..n).all(|i| {
        graph.degree_of(i) == n - 1 && (graph.self_weight(i) - w).abs() < 1e-7
    })
}

/// Reference dense mixing (O(n²P), allocation-heavy) over the
/// **pre-refactor `Vec<Vec<f32>>` layout** — kept as the independent
/// criterion baseline the flat-store kernels are tested against
/// (`ReplicaMatrix::to_vecs` bridges).
pub fn mix_dense_reference(graph: &CommGraph, replicas: &[Vec<f32>]) -> Vec<Vec<f32>> {
    let n = graph.n();
    let p = replicas[0].len();
    let w = graph.dense_mixing();
    let mut out = vec![vec![0.0f32; p]; n];
    for i in 0..n {
        for j in 0..n {
            let wij = w[i * n + j];
            if wij != 0.0 {
                for k in 0..p {
                    out[i][k] += wij * replicas[j][k];
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphKind;

    fn replicas(n: usize, p: usize, seed: u64) -> ReplicaMatrix {
        let mut rng = crate::util::rng::Rng::seed_from_u64(seed);
        let rows: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..p).map(|_| rng.range_f32(-1.0, 1.0)).collect())
            .collect();
        ReplicaMatrix::from_rows(&rows)
    }

    fn global_mean(replicas: &ReplicaMatrix) -> Vec<f64> {
        let p = replicas.p();
        let mut m = vec![0.0f64; p];
        for r in replicas.rows() {
            for (mi, &v) in m.iter_mut().zip(r.iter()) {
                *mi += v as f64;
            }
        }
        m.iter().map(|v| v / replicas.n() as f64).collect()
    }

    #[test]
    fn matches_dense_reference_all_graphs() {
        for kind in [
            GraphKind::Ring,
            GraphKind::Torus,
            GraphKind::RingLattice { k: 3 },
            GraphKind::AdaLattice { k: 4 },
            GraphKind::Exponential,
            GraphKind::Complete,
        ] {
            let n = 16;
            let g = CommGraph::build(kind, n).unwrap();
            let mut reps = replicas(n, 37, 5);
            let expect = mix_dense_reference(&g, &reps.to_vecs());
            GossipEngine::new().mix(&g, &mut reps);
            for i in 0..n {
                for k in 0..37 {
                    assert!(
                        (reps[i][k] - expect[i][k]).abs() < 1e-5,
                        "{kind} mismatch at [{i}][{k}]"
                    );
                }
            }
        }
    }

    #[test]
    fn preserves_global_mean() {
        // Doubly stochastic W ⇒ the global mean is invariant — the core
        // conservation law of decentralized averaging.
        for kind in [GraphKind::Ring, GraphKind::Exponential, GraphKind::AdaLattice { k: 6 }] {
            let n = 24;
            let g = CommGraph::build(kind, n).unwrap();
            let mut reps = replicas(n, 101, 9);
            let before = global_mean(&reps);
            let mut eng = GossipEngine::new();
            for _ in 0..10 {
                eng.mix(&g, &mut reps);
            }
            let after = global_mean(&reps);
            for (b, a) in before.iter().zip(&after) {
                assert!((b - a).abs() < 1e-4, "mean drifted: {b} → {a}");
            }
        }
    }

    #[test]
    fn converges_to_consensus() {
        let n = 12;
        let g = CommGraph::build(GraphKind::Ring, n).unwrap();
        let mut reps = replicas(n, 5, 2);
        let target = global_mean(&reps);
        let mut eng = GossipEngine::new();
        for _ in 0..2000 {
            eng.mix(&g, &mut reps);
        }
        for r in reps.rows() {
            for (v, t) in r.iter().zip(&target) {
                assert!((*v as f64 - t).abs() < 1e-3, "must reach consensus");
            }
        }
    }

    #[test]
    fn mix_codec_f32_is_mix_bitwise() {
        // The identity codec delegates to mix() — including the
        // uniform-complete fast path — so results are bit-identical.
        for kind in [GraphKind::Ring, GraphKind::Exponential, GraphKind::Complete] {
            let n = 12;
            let g = CommGraph::build(kind, n).unwrap();
            let mut dense = replicas(n, 301, 7);
            let mut coded = replicas(n, 301, 7);
            GossipEngine::new().mix(&g, &mut dense);
            GossipEngine::new().mix_codec(&g, &mut coded, Codec::F32);
            for i in 0..n {
                for k in 0..301 {
                    assert_eq!(dense[i][k].to_bits(), coded[i][k].to_bits(), "{kind} [{i}][{k}]");
                }
            }
        }
    }

    #[test]
    fn mix_from_full_messages_f32_is_mix_bitwise() {
        // messages == replicas with the identity codec reproduces the
        // general mix path exactly (non-complete graphs only: mix()'s
        // uniform-complete fast path folds in a different float order).
        for kind in [GraphKind::Ring, GraphKind::Exponential] {
            let n = 12;
            let g = CommGraph::build(kind, n).unwrap();
            let mut dense = replicas(n, 513, 3);
            let mut sparse = replicas(n, 513, 3);
            let messages = replicas(n, 513, 3);
            GossipEngine::new().mix(&g, &mut dense);
            GossipEngine::new().mix_from(&g, &mut sparse, &messages, Codec::F32);
            for i in 0..n {
                for k in 0..513 {
                    assert_eq!(dense[i][k].to_bits(), sparse[i][k].to_bits(), "{kind} [{i}][{k}]");
                }
            }
        }
    }

    #[test]
    fn mix_codec_bit_identical_across_threads() {
        // The codec round-trip is elementwise, so tile/thread boundaries
        // cannot change the produced bits. (The SIMD × scalar cross
        // sweep lives in `rust/tests/compress_paths.rs` — the
        // process-global dispatch toggle is not safe to flip inside the
        // concurrently-running lib tests.)
        for codec in [Codec::Bf16, Codec::F16] {
            let n = 8;
            let p = 10_000; // several tiles per worker at 4 threads
            let g = CommGraph::build(GraphKind::Exponential, n).unwrap();
            let mut reference: Option<ReplicaMatrix> = None;
            for threads in [1usize, 4, 8] {
                let mut reps = replicas(n, p, 77);
                GossipEngine::with_threads(threads).mix_codec(&g, &mut reps, codec);
                match &reference {
                    None => reference = Some(reps),
                    Some(want) => {
                        for i in 0..n {
                            for k in 0..p {
                                assert_eq!(
                                    want[i][k].to_bits(),
                                    reps[i][k].to_bits(),
                                    "{codec:?} threads={threads} [{i}][{k}]"
                                );
                            }
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn mix_codec_quantizes_peers_but_not_self() {
        // One round on a ring: the output must equal the scalar
        // reference fold with peer rows round-tripped and the self row
        // kept in f32.
        let n = 6;
        let p = 257;
        let g = CommGraph::build(GraphKind::Ring, n).unwrap();
        let before = replicas(n, p, 21);
        let mut reps = replicas(n, p, 21);
        GossipEngine::new().mix_codec(&g, &mut reps, Codec::Bf16);
        for i in 0..n {
            let mut want = vec![0.0f32; p];
            let mut first = true;
            for (j, w) in g.row(i) {
                let src: Vec<f32> = if j == i {
                    before[j].to_vec()
                } else {
                    before[j].iter().map(|&v| Codec::Bf16.roundtrip(v)).collect()
                };
                if first {
                    simd::scale(&mut want, &src, w);
                    first = false;
                } else {
                    simd::axpy(&mut want, &src, w);
                }
            }
            for k in 0..p {
                assert_eq!(want[k].to_bits(), reps[i][k].to_bits(), "[{i}][{k}]");
            }
        }
    }

    #[test]
    fn complete_graph_reaches_consensus_in_one_round() {
        let n = 9;
        let g = CommGraph::build(GraphKind::Complete, n).unwrap();
        let mut reps = replicas(n, 11, 3);
        let target = global_mean(&reps);
        GossipEngine::new().mix(&g, &mut reps);
        for r in reps.rows() {
            for (v, t) in r.iter().zip(&target) {
                assert!((*v as f64 - t).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn fast_path_equals_slow_path_for_complete() {
        let n = 8;
        let g = CommGraph::build(GraphKind::Complete, n).unwrap();
        let src = replicas(n, 23, 7);
        let mut fast = src.clone();
        GossipEngine::new().mix(&g, &mut fast);
        let slow = mix_dense_reference(&g, &src.to_vecs());
        for i in 0..n {
            for k in 0..23 {
                assert!((fast[i][k] - slow[i][k]).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn inactive_nodes_keep_parameters() {
        let n = 8;
        let g = CommGraph::build(GraphKind::Ring, n).unwrap();
        let mut reps = replicas(n, 7, 1);
        let frozen = reps.row(3).to_vec();
        let mut active = vec![true; n];
        active[3] = false;
        GossipEngine::new().mix_active(&g, &mut reps, &active);
        assert_eq!(reps.row(3), &frozen[..], "inactive node must not change");
    }

    #[test]
    fn active_mix_renormalizes_rows() {
        // With a dropped neighbor, remaining weights are rescaled so the
        // result is still a convex combination (no mass loss).
        let n = 6;
        let g = CommGraph::build(GraphKind::Complete, n).unwrap();
        let rows: Vec<Vec<f32>> = (0..n).map(|i| vec![i as f32]).collect();
        let mut reps = ReplicaMatrix::from_rows(&rows);
        let mut active = vec![true; n];
        active[5] = false;
        GossipEngine::new().mix_active(&g, &mut reps, &active);
        // Active nodes average over {0..4}: mean 2.0.
        for i in 0..5 {
            assert!((reps[i][0] - 2.0).abs() < 1e-5, "node {i} got {}", reps[i][0]);
        }
        assert_eq!(reps[5][0], 5.0);
    }

    #[test]
    #[should_panic(expected = "replica count")]
    fn mismatched_sizes_panic() {
        let g = CommGraph::build(GraphKind::Ring, 4).unwrap();
        let mut reps = replicas(3, 5, 0);
        GossipEngine::new().mix(&g, &mut reps);
    }

    #[test]
    fn scratch_is_reused_across_rounds() {
        // Behavioural proxy: repeated mixing with the same engine gives
        // identical results to fresh engines (no scratch contamination).
        let g = CommGraph::build(GraphKind::Torus, 9).unwrap();
        let src = replicas(9, 13, 4);
        let mut a = src.clone();
        let mut eng = GossipEngine::new();
        eng.mix(&g, &mut a);
        eng.mix(&g, &mut a);
        let mut b = src.clone();
        GossipEngine::new().mix(&g, &mut b);
        GossipEngine::new().mix(&g, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn parallel_mix_is_bit_identical_to_serial() {
        // P chosen to force several tiles per worker at 4 threads.
        for kind in [GraphKind::Ring, GraphKind::Exponential, GraphKind::Complete] {
            let n = 8;
            let p = 3 * MIN_COLS_PER_WORKER + 17;
            let g = CommGraph::build(kind, n).unwrap();
            let src = replicas(n, p, 21);
            let mut serial = src.clone();
            GossipEngine::new().mix(&g, &mut serial);
            for threads in [2, 3, 4, 8] {
                let mut par = src.clone();
                GossipEngine::with_threads(threads).mix(&g, &mut par);
                assert_eq!(serial, par, "{kind} differs at {threads} threads");
            }
        }
    }

    #[test]
    fn fused_mix_step_equals_mix_then_step() {
        for kind in [GraphKind::Ring, GraphKind::Torus, GraphKind::Exponential] {
            let n = 12;
            let p = 257;
            let g = CommGraph::build(kind, n).unwrap();
            let src = replicas(n, p, 31);
            let grads = replicas(n, p, 32);
            let (mu, wd, lr) = (0.9f32, 1e-4f32, 0.05f32);

            // Split: mix, then per-replica momentum step.
            let mut split = src.clone();
            let mut split_states: Vec<SgdState> =
                (0..n).map(|_| SgdState::new(p, mu, wd)).collect();
            let mut eng = GossipEngine::new();
            for round in 0..3 {
                eng.mix(&g, &mut split);
                let shared = grads.row(round % n).to_vec();
                for (w, s) in split_states.iter_mut().enumerate() {
                    s.step(split.row_mut(w), &shared, lr);
                }
            }

            // Fused: one pass per round.
            let mut fused = src.clone();
            let mut fused_states: Vec<SgdState> =
                (0..n).map(|_| SgdState::new(p, mu, wd)).collect();
            let mut feng = GossipEngine::new();
            for round in 0..3 {
                let gs = ReplicaMatrix::broadcast(n, grads.row(round % n));
                feng.mix_step(&g, &mut fused, &gs, &mut fused_states, lr);
            }
            // Same element ops in the same order ⇒ exact equality on the
            // general (non-complete) path.
            assert_eq!(split, fused, "{kind}: fused must equal mix-then-step");
        }
    }

    #[test]
    fn fused_mix_step_is_bit_identical_across_threads() {
        let n = 6;
        let p = 2 * MIN_COLS_PER_WORKER + 5;
        let g = CommGraph::build(GraphKind::RingLattice { k: 2 }, n).unwrap();
        let src = replicas(n, p, 41);
        let grads = replicas(n, p, 42);
        let run = |threads: usize| {
            let mut reps = src.clone();
            let mut states: Vec<SgdState> =
                (0..n).map(|_| SgdState::new(p, 0.9, 0.0)).collect();
            let mut eng = GossipEngine::with_threads(threads);
            for _ in 0..2 {
                eng.mix_step(&g, &mut reps, &grads, &mut states, 0.1);
            }
            reps
        };
        let one = run(1);
        for threads in [2, 4, 8] {
            assert_eq!(one, run(threads), "fused differs at {threads} threads");
        }
    }

    #[test]
    fn fused_active_step_equals_mix_active_then_step() {
        // The mix_active_step contract: identical floats to the split
        // mix_active + per-replica step fallback, inactive rows included
        // (they keep their parameters but still apply their gradient).
        for kind in [GraphKind::Ring, GraphKind::Torus, GraphKind::Exponential] {
            let n = 12;
            let p = 257;
            let g = CommGraph::build(kind, n).unwrap();
            let src = replicas(n, p, 51);
            let grads = replicas(n, p, 52);
            let active: Vec<bool> = (0..n).map(|i| i % 4 != 2).collect();
            let (mu, wd, lr) = (0.9f32, 1e-4f32, 0.05f32);

            let mut split = src.clone();
            let mut split_states: Vec<SgdState> =
                (0..n).map(|_| SgdState::new(p, mu, wd)).collect();
            let mut eng = GossipEngine::new();
            for _ in 0..3 {
                eng.mix_active(&g, &mut split, &active);
                for (w, s) in split_states.iter_mut().enumerate() {
                    s.step(split.row_mut(w), grads.row(w), lr);
                }
            }

            let mut fused = src.clone();
            let mut fused_states: Vec<SgdState> =
                (0..n).map(|_| SgdState::new(p, mu, wd)).collect();
            let mut feng = GossipEngine::new();
            for _ in 0..3 {
                feng.mix_active_step(&g, &mut fused, &grads, &mut fused_states, lr, &active);
            }
            assert_eq!(split, fused, "{kind}: fused active must equal split");
            for (a, b) in split_states.iter().zip(&fused_states) {
                assert_eq!(a.velocity(), b.velocity(), "{kind}: velocity drift");
            }
        }
    }

    #[test]
    fn fused_active_step_with_full_mask_routes_to_mix_step() {
        let n = 8;
        let g = CommGraph::build(GraphKind::Ring, n).unwrap();
        let src = replicas(n, 101, 61);
        let grads = replicas(n, 101, 62);
        let run = |fused_active: bool| {
            let mut reps = src.clone();
            let mut states: Vec<SgdState> =
                (0..n).map(|_| SgdState::new(101, 0.9, 0.0)).collect();
            let mut eng = GossipEngine::new();
            if fused_active {
                eng.mix_active_step(&g, &mut reps, &grads, &mut states, 0.1, &vec![true; n]);
            } else {
                eng.mix_step(&g, &mut reps, &grads, &mut states, 0.1);
            }
            reps
        };
        assert_eq!(run(true), run(false));
    }

    /// The producer used across the overlapped tests: a deterministic
    /// stand-in for a local step that actually mutates the row, so the
    /// tests cover genuine produce-while-mix interleaving.
    fn fake_local_step(w: usize, row: &mut [f32]) {
        for (k, v) in row.iter_mut().enumerate() {
            *v += 0.01 * (w as f32 + 1.0) + 1e-4 * (k % 7) as f32;
        }
    }

    #[test]
    fn overlapped_mix_is_bit_identical_to_phased() {
        for kind in [GraphKind::Ring, GraphKind::Exponential, GraphKind::Complete] {
            let n = 8;
            let p = MIN_COLS_PER_WORKER + 37;
            let g = CommGraph::build(kind, n).unwrap();
            let src = replicas(n, p, 81);

            let mut phased = src.clone();
            for w in 0..n {
                fake_local_step(w, phased.row_mut(w));
            }
            GossipEngine::new().mix(&g, &mut phased);

            for threads in [1, 4] {
                for bucket_elems in [1024, 1000] {
                    let mut piped = src.clone();
                    let mut eng = GossipEngine::with_threads(threads);
                    eng.set_bucket_elems(bucket_elems);
                    eng.mix_overlapped(&g, &mut piped, None, |w, row| {
                        fake_local_step(w, row);
                        Ok(())
                    })
                    .unwrap();
                    eng.publish_overlapped(&mut piped);
                    assert_eq!(
                        phased, piped,
                        "{kind} differs at {threads} threads, {bucket_elems} elems"
                    );
                }
            }
        }
    }

    #[test]
    fn overlapped_mix_active_is_bit_identical_to_phased() {
        let n = 10;
        let p = MIN_COLS_PER_WORKER + 11;
        let g = CommGraph::build(GraphKind::Torus, n).unwrap();
        let src = replicas(n, p, 91);
        let active: Vec<bool> = (0..n).map(|i| i % 3 != 1).collect();

        let mut phased = src.clone();
        for w in 0..n {
            fake_local_step(w, phased.row_mut(w));
        }
        GossipEngine::new().mix_active(&g, &mut phased, &active);

        for threads in [1, 4] {
            let mut piped = src.clone();
            let mut eng = GossipEngine::with_threads(threads);
            eng.set_bucket_elems(777);
            eng.mix_overlapped(&g, &mut piped, Some(&active), |w, row| {
                fake_local_step(w, row);
                Ok(())
            })
            .unwrap();
            eng.publish_overlapped(&mut piped);
            assert_eq!(phased, piped, "active overlapped differs at {threads} threads");
        }
    }

    #[test]
    fn overlapped_full_mask_routes_like_none() {
        // All-present masks must follow the same delegation chain as
        // the phased path (mix_active → mix, incl. the complete-graph
        // fast path) so the floats cannot diverge on mask shape alone.
        let n = 6;
        let g = CommGraph::build(GraphKind::Complete, n).unwrap();
        let src = replicas(n, 301, 95);
        let run = |mask: Option<Vec<bool>>| {
            let mut reps = src.clone();
            let mut eng = GossipEngine::new();
            eng.mix_overlapped(&g, &mut reps, mask.as_deref(), |w, row| {
                fake_local_step(w, row);
                Ok(())
            })
            .unwrap();
            eng.publish_overlapped(&mut reps);
            reps
        };
        assert_eq!(run(None), run(Some(vec![true; n])));
    }

    #[test]
    fn overlapped_fused_is_bit_identical_to_phased() {
        let n = 8;
        let p = MIN_COLS_PER_WORKER + 29;
        let g = CommGraph::build(GraphKind::RingLattice { k: 2 }, n).unwrap();
        let src = replicas(n, p, 85);
        let (mu, wd, lr) = (0.9f32, 1e-4f32, 0.05f32);
        // The fused producer derives the gradient from the frozen θ_t
        // row, like loss_and_grad would.
        let grad_of = |w: usize, theta: &[f32], out: &mut [f32]| {
            for ((gk, &tk), k) in out.iter_mut().zip(theta).zip(0..) {
                *gk = 0.1 * tk + 1e-3 * ((w + k) % 5) as f32;
            }
        };

        let mut phased = src.clone();
        let mut phased_states: Vec<SgdState> =
            (0..n).map(|_| SgdState::new(p, mu, wd)).collect();
        let mut grads = ReplicaMatrix::zeros(n, p);
        for w in 0..n {
            let theta = phased.row(w).to_vec();
            grad_of(w, &theta, grads.row_mut(w));
        }
        GossipEngine::new().mix_step(&g, &mut phased, &grads, &mut phased_states, lr);

        for threads in [1, 4] {
            for bucket_elems in [2048, 999] {
                let mut piped = src.clone();
                let mut piped_states: Vec<SgdState> =
                    (0..n).map(|_| SgdState::new(p, mu, wd)).collect();
                let mut piped_grads = ReplicaMatrix::zeros(n, p);
                let mut eng = GossipEngine::with_threads(threads);
                eng.set_bucket_elems(bucket_elems);
                eng.mix_step_overlapped(
                    &g,
                    &piped,
                    &mut piped_grads,
                    &mut piped_states,
                    lr,
                    None,
                    |w, theta, gout| {
                        grad_of(w, theta, gout);
                        Ok(())
                    },
                )
                .unwrap();
                eng.publish_overlapped(&mut piped);
                assert_eq!(
                    phased, piped,
                    "fused overlapped differs at {threads} threads, {bucket_elems} elems"
                );
                for (a, b) in phased_states.iter().zip(&piped_states) {
                    assert_eq!(a.velocity(), b.velocity(), "velocity drift");
                }
            }
        }
    }

    #[test]
    fn overlapped_fused_active_is_bit_identical_to_phased() {
        let n = 9;
        let p = 513;
        let g = CommGraph::build(GraphKind::Ring, n).unwrap();
        let src = replicas(n, p, 87);
        let active: Vec<bool> = (0..n).map(|i| i != 4).collect();
        let (mu, wd, lr) = (0.9f32, 0.0f32, 0.1f32);
        let grad_of = |w: usize, theta: &[f32], out: &mut [f32]| {
            for (gk, &tk) in out.iter_mut().zip(theta) {
                *gk = 0.2 * tk - 0.01 * w as f32;
            }
        };

        let mut phased = src.clone();
        let mut phased_states: Vec<SgdState> =
            (0..n).map(|_| SgdState::new(p, mu, wd)).collect();
        let mut grads = ReplicaMatrix::zeros(n, p);
        for w in 0..n {
            let theta = phased.row(w).to_vec();
            grad_of(w, &theta, grads.row_mut(w));
        }
        GossipEngine::new().mix_active_step(
            &g, &mut phased, &grads, &mut phased_states, lr, &active,
        );

        for threads in [1, 4] {
            let mut piped = src.clone();
            let mut piped_states: Vec<SgdState> =
                (0..n).map(|_| SgdState::new(p, mu, wd)).collect();
            let mut piped_grads = ReplicaMatrix::zeros(n, p);
            let mut eng = GossipEngine::with_threads(threads);
            eng.set_bucket_kb(1); // 256-element buckets
            eng.mix_step_overlapped(
                &g,
                &piped,
                &mut piped_grads,
                &mut piped_states,
                lr,
                Some(&active),
                |w, theta, gout| {
                    grad_of(w, theta, gout);
                    Ok(())
                },
            )
            .unwrap();
            eng.publish_overlapped(&mut piped);
            assert_eq!(phased, piped, "fused active overlapped differs at {threads} threads");
        }
    }

    #[test]
    fn overlapped_error_aborts_without_publish() {
        let n = 6;
        let g = CommGraph::build(GraphKind::Ring, n).unwrap();
        let src = replicas(n, 129, 89);
        let mut reps = src.clone();
        let mut eng = GossipEngine::new();
        let err = eng.mix_overlapped(&g, &mut reps, None, |w, row| {
            if w == 3 {
                return Err(crate::error::AdaError::Runtime("boom".into()));
            }
            fake_local_step(w, row);
            Ok(())
        });
        assert!(err.is_err());
        assert!(!eng.has_pending_publish(), "failed round must not publish");
        // The engine stays usable for a phased round afterwards.
        eng.mix(&g, &mut reps);
    }

    #[test]
    fn overlapped_producer_panic_leaves_engine_reusable_without_publish() {
        // Satellite of the fault PR: a panicking local step (not just an
        // Err) must unwind out of the overlapped round with nothing
        // published and the engine still good for the next round.
        let n = 6;
        let g = CommGraph::build(GraphKind::Ring, n).unwrap();
        let src = replicas(n, 129, 89);
        let mut reps = src.clone();
        let mut eng = GossipEngine::new();
        let unwound = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            eng.mix_overlapped(&g, &mut reps, None, |w, row| {
                if w == 2 {
                    panic!("producer died mid-round");
                }
                fake_local_step(w, row);
                Ok(())
            })
        }));
        assert!(unwound.is_err(), "producer panic must propagate");
        assert!(!eng.has_pending_publish(), "panicked round must not publish");
        // The engine stays usable for a phased round afterwards.
        eng.mix(&g, &mut reps);
    }

    #[test]
    fn stale_mix_all_fresh_is_bit_identical_to_phased() {
        // A fully-delivered buffer at any bound must reproduce the
        // phased kernels exactly — acceptance criterion (b)'s kernel
        // half — including the uniform-complete fast path and the
        // partial-participation renormalization.
        for kind in [GraphKind::Ring, GraphKind::Exponential, GraphKind::Complete] {
            let n = 8;
            let g = CommGraph::build(kind, n).unwrap();
            let src = replicas(n, 37, 5);

            let mut phased = src.clone();
            GossipEngine::new().mix(&g, &mut phased);

            let mut staled = src.clone();
            let mut eng = GossipEngine::new();
            eng.ingest_stale(&g, &staled, |_, _| true);
            eng.mix_stale(&g, &mut staled, None, 0);
            assert_eq!(phased, staled, "{kind}: fresh stale round must equal mix");

            let active: Vec<bool> = (0..n).map(|i| i != 3).collect();
            let mut phased_a = src.clone();
            GossipEngine::new().mix_active(&g, &mut phased_a, &active);
            let mut staled_a = src.clone();
            let mut eng_a = GossipEngine::new();
            eng_a.ingest_stale(&g, &staled_a, |_, _| true);
            eng_a.mix_stale(&g, &mut staled_a, Some(&active), 0);
            assert_eq!(phased_a, staled_a, "{kind}: fresh active stale round");
        }
    }

    #[test]
    fn stale_mix_renormalizes_over_delivered_peers() {
        // Complete graph n=4, rows = node index. Destination 0 only
        // ever hears from node 1: its round averages over {self, 1}
        // with renormalized uniform weights → (0 + 1) / 2.
        let n = 4;
        let g = CommGraph::build(GraphKind::Complete, n).unwrap();
        let rows: Vec<Vec<f32>> = (0..n).map(|i| vec![i as f32]).collect();
        let mut reps = ReplicaMatrix::from_rows(&rows);
        let mut eng = GossipEngine::new();
        eng.ingest_stale(&g, &reps, |src, dst| dst != 0 || src == 1);
        eng.mix_stale(&g, &mut reps, None, 0);
        assert!((reps[0][0] - 0.5).abs() < 1e-6, "dst 0 got {}", reps[0][0]);
        // Other destinations heard everyone: full mean 1.5.
        for i in 1..n {
            assert!((reps[i][0] - 1.5).abs() < 1e-6, "dst {i} got {}", reps[i][0]);
        }
    }

    #[test]
    fn stale_rows_age_out_beyond_bound() {
        let n = 4;
        let g = CommGraph::build(GraphKind::Complete, n).unwrap();
        let rows: Vec<Vec<f32>> = (0..n).map(|i| vec![i as f32]).collect();
        let snapshot = ReplicaMatrix::from_rows(&rows);

        let run = |bound: usize| {
            let mut eng = GossipEngine::new();
            // Round 1: everything delivered (buffered copies = 0,1,2,3).
            eng.ingest_stale(&g, &snapshot, |_, _| true);
            // The senders keep training locally…
            let drifted: Vec<Vec<f32>> =
                (0..n).map(|i| vec![i as f32 + 100.0]).collect();
            let mut live = ReplicaMatrix::from_rows(&drifted);
            // …but round 2 delivers nothing, so every slot ages to 1.
            eng.ingest_stale(&g, &live, |_, _| false);
            eng.mix_stale(&g, &mut live, None, bound);
            live
        };

        // Bound 1 admits the age-1 copies: each destination mixes its
        // live self row with the *round-1 snapshots* of its peers.
        // dst 0: (100 + 1 + 2 + 3) / 4 = 26.5.
        let within = run(1);
        assert!((within[0][0] - 26.5).abs() < 1e-5, "got {}", within[0][0]);

        // Bound 0 rejects them: every row renormalizes to itself.
        let beyond = run(0);
        for i in 0..n {
            assert_eq!(beyond[i][0], i as f32 + 100.0, "dst {i} must keep its row");
        }
    }

    #[test]
    fn stale_mix_is_bit_identical_across_threads() {
        let n = 8;
        let p = 2 * MIN_COLS_PER_WORKER + 7;
        let g = CommGraph::build(GraphKind::Exponential, n).unwrap();
        let early = replicas(n, p, 61);
        let src = replicas(n, p, 62);
        // Deterministic partial delivery pattern with genuinely stale
        // survivors: deliver everything once from an earlier snapshot,
        // then a second round where only some edges deliver.
        let run = |threads: usize| {
            let mut eng = GossipEngine::with_threads(threads);
            eng.ingest_stale(&g, &early, |_, _| true);
            let mut reps = src.clone();
            eng.ingest_stale(&g, &reps, |s, d| (s + d) % 3 != 0);
            eng.mix_stale(&g, &mut reps, None, 1);
            reps
        };
        let one = run(1);
        assert!(one != src, "stale round must actually mix");
        for threads in [2, 4, 8] {
            assert_eq!(one, run(threads), "stale mix differs at {threads} threads");
        }
    }

    #[test]
    fn stale_mix_with_no_deliveries_keeps_rows_and_stats_track_ages() {
        let n = 5;
        let g = CommGraph::build(GraphKind::Ring, n).unwrap();
        let src = replicas(n, 17, 33);
        let mut reps = src.clone();
        let mut eng = GossipEngine::new();
        assert_eq!(eng.stale_stats(&g), (None, None), "empty buffer has no ages");
        // Nothing ever delivered: every row renormalizes to itself
        // (self weight only) — bitwise, since w/total == 1.0 scales.
        eng.ingest_stale(&g, &reps, |_, _| false);
        eng.mix_stale(&g, &mut reps, None, 3);
        assert_eq!(reps, src, "no-delivery round must keep all rows");
        assert_eq!(eng.stale_edges(), 0);

        // One full delivery, then two silent rounds: ages reach 2.
        eng.ingest_stale(&g, &reps, |_, _| true);
        eng.ingest_stale(&g, &reps, |_, _| false);
        eng.ingest_stale(&g, &reps, |_, _| false);
        let (max, mean) = eng.stale_stats(&g);
        assert_eq!(max, Some(2));
        assert_eq!(mean, Some(2.0));
        eng.reset_stale();
        assert_eq!(eng.stale_stats(&g), (None, None));
    }

    #[test]
    #[should_panic(expected = "publish_overlapped requires")]
    fn publish_without_round_panics() {
        let mut reps = replicas(4, 16, 99);
        GossipEngine::new().publish_overlapped(&mut reps);
    }

    #[test]
    fn mean_model_matches_serial_mean() {
        let n = 9;
        let p = 2 * MIN_COLS_PER_WORKER + 33; // force several tiles
        let reps = replicas(n, p, 71);
        let serial = crate::exec::ExecEngine::serial();
        let reference = mean_model(&serial, &reps);
        // Bit-identical across thread counts.
        for threads in [2, 4, 8] {
            let eng = crate::exec::ExecEngine::new(threads);
            assert_eq!(reference, mean_model(&eng, &reps), "{threads} threads");
        }
        // And numerically the f32 replica mean.
        for k in (0..p).step_by(997) {
            let want: f32 = reps.rows().map(|r| r[k]).sum::<f32>() / n as f32;
            assert!((reference[k] - want).abs() < 1e-5, "col {k}");
        }
    }
}
