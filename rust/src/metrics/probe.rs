//! The pre-averaging variance capture, packaged as a reusable probe —
//! DBench's §3.1.2 instrumentation point, decoupled from the training
//! loop so any session (or external harness) can sample it.

use super::{gini_coefficient, per_replica_l2_norms_pooled, VarianceReport};
use crate::exec::ExecEngine;
use crate::util::matrix::ReplicaMatrix;
use std::ops::Range;

/// One capture of the probe: the whole-model statistics, the tracked
/// per-tensor ginis, and the raw pooled per-replica L2 norms the
/// statistics were computed from (the series
/// [`crate::topology::TrainSignals`] aggregates per epoch).
#[derive(Debug, Clone)]
pub struct ProbeSample {
    /// Whole-model cross-replica variance statistics.
    pub report: VarianceReport,
    /// Gini of each tracked parameter-tensor slice (Fig. 4).
    pub per_tensor_gini: Vec<f64>,
    /// The pooled per-replica L2 norms themselves, one per replica.
    pub norms: Vec<f64>,
}

/// Samples cross-replica variance statistics on a fixed iteration
/// cadence: the whole-model [`VarianceReport`] plus the gini
/// coefficient of each tracked parameter-tensor slice (Fig. 4).
///
/// All norms fan out over the execution engine's persistent pool
/// ([`per_replica_l2_norms_pooled`]) — deterministic tiled reductions,
/// bit-identical for any thread count.
#[derive(Debug, Clone)]
pub struct VarianceProbe {
    every: usize,
    tracked: Vec<Range<usize>>,
}

impl VarianceProbe {
    /// Probe sampling every `every` iterations (`0` disables capture)
    /// over the given tracked flat-vector slices.
    pub fn new(every: usize, tracked: Vec<Range<usize>>) -> Self {
        VarianceProbe { every, tracked }
    }

    /// Whether `iteration` is a capture point.
    pub fn due(&self, iteration: usize) -> bool {
        self.every > 0 && iteration % self.every == 0
    }

    /// Capture at `iteration`: a full [`ProbeSample`] on cadence,
    /// `None` between capture points.
    pub fn capture(
        &self,
        exec: &ExecEngine,
        replicas: &ReplicaMatrix,
        iteration: usize,
    ) -> Option<ProbeSample> {
        if !self.due(iteration) {
            return None;
        }
        let p = replicas.p();
        let norms = per_replica_l2_norms_pooled(exec, replicas, 0..p);
        let report = VarianceReport::of(&norms);
        let per_tensor: Vec<f64> = self
            .tracked
            .iter()
            .map(|range| {
                let tn = per_replica_l2_norms_pooled(exec, replicas, range.clone());
                gini_coefficient(&tn)
            })
            .collect();
        Some(ProbeSample {
            report,
            per_tensor_gini: per_tensor,
            norms,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn replicas() -> ReplicaMatrix {
        ReplicaMatrix::from_rows(&[vec![1.0; 64], vec![2.0; 64], vec![4.0; 64]])
    }

    #[test]
    fn cadence_is_respected() {
        let probe = VarianceProbe::new(3, vec![]);
        let exec = ExecEngine::serial();
        let reps = replicas();
        assert!(probe.capture(&exec, &reps, 0).is_some());
        assert!(probe.capture(&exec, &reps, 1).is_none());
        assert!(probe.capture(&exec, &reps, 2).is_none());
        assert!(probe.capture(&exec, &reps, 3).is_some());
        let off = VarianceProbe::new(0, vec![]);
        assert!(off.capture(&exec, &reps, 0).is_none());
    }

    #[test]
    fn captures_tracked_slices() {
        let probe = VarianceProbe::new(1, vec![0..32, 32..64]);
        let exec = ExecEngine::serial();
        let sample = probe.capture(&exec, &replicas(), 0).unwrap();
        assert!(sample.report.gini > 0.0, "unequal norms must show dispersion");
        assert_eq!(sample.per_tensor_gini.len(), 2);
        // Constant-per-replica slices: both halves carry the same gini.
        assert!((sample.per_tensor_gini[0] - sample.per_tensor_gini[1]).abs() < 1e-12);
        // The raw norms ride along (one per replica, ordered).
        assert_eq!(sample.norms.len(), 3);
        assert!(sample.norms[0] < sample.norms[1] && sample.norms[1] < sample.norms[2]);
    }
}
