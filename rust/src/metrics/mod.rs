//! White-box training metrics — the instrumentation DBench adds (§3 of
//! the paper): per-replica parameter-tensor L2 norms collected *before*
//! the gossip averaging step, and four cross-replica variance statistics
//! (gini coefficient, index of dispersion, coefficient of variation,
//! quartile coefficient of dispersion), plus the variance **ranking
//! analysis** of §3.3 and structured recorders for the figure data.

mod probe;
mod ranking;
mod recorder;
mod variance;

pub use probe::{ProbeSample, VarianceProbe};
pub use ranking::{rank_ascending, RankSummary};
pub use recorder::{IterationRecord, RunRecorder};
pub use variance::{
    coefficient_of_variation, gini_coefficient, index_of_dispersion,
    quartile_coefficient_of_dispersion, VarianceReport,
};

/// L2 norm of a parameter vector — the per-replica quantity DBench logs
/// via `torch.tensor.norm()` in the paper (§3.1.2).
pub fn l2_norm(v: &[f32]) -> f64 {
    v.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
}

/// L2 norms of a named slice of each replica's row in the flat store —
/// used to study individual parameter tensors (Fig. 4) rather than the
/// whole model. Serial reference path (single left-to-right f64 sum).
pub fn per_replica_l2_norms(
    replicas: &crate::util::matrix::ReplicaMatrix,
    range: std::ops::Range<usize>,
) -> Vec<f64> {
    replicas.rows().map(|p| l2_norm(&p[range.clone()])).collect()
}

/// [`per_replica_l2_norms`] fanned out over the execution engine's
/// persistent pool — the trainer's per-iteration variance capture,
/// which was the largest remaining serial O(n·P) pass. One fork-join
/// round covers the whole `replicas × tiles` grid
/// ([`crate::exec::ExecEngine::run_reduce_rows`]); each tile's sum of
/// squares runs on the explicit SIMD layer
/// ([`crate::exec::simd::sumsq_f64`]).
///
/// The sum of squares is grouped by the engine's fixed
/// [`crate::exec::REDUCE_GRANULARITY`] tiles, and within a tile by the
/// SIMD layer's fixed 8 virtual lanes — both groupings are independent
/// of the thread count and of AVX2 availability, so results are
/// **bit-identical for every thread count and for both SIMD and scalar
/// paths**. The tiled+laned grouping differs from [`l2_norm`]'s single
/// left-to-right f64 sum only in float rounding (≲1e-12 relative).
pub fn per_replica_l2_norms_pooled(
    exec: &crate::exec::ExecEngine,
    replicas: &crate::util::matrix::ReplicaMatrix,
    range: std::ops::Range<usize>,
) -> Vec<f64> {
    let base = range.start;
    exec.run_reduce_rows(
        replicas.n(),
        range.len(),
        crate::exec::REDUCE_GRANULARITY,
        |row, tile| {
            crate::exec::simd::sumsq_f64(
                &replicas.row(row)[base + tile.start..base + tile.end],
            )
        },
        |a, b| a + b,
        0.0,
    )
    .into_iter()
    .map(f64::sqrt)
    .collect()
}

/// Mean L2 distance of the replicas to an explicit mean model — the
/// **consensus distance** of Kong et al. 2021 (*Consensus Control for
/// Decentralized Deep Learning*), one of the feedback signals
/// [`crate::topology::TrainSignals`] carries to topology policies.
///
/// Fanned out over the execution engine like
/// [`per_replica_l2_norms_pooled`]: one partial per fixed
/// [`crate::exec::REDUCE_GRANULARITY`] tile, folded ascending in f64 —
/// bit-identical for every thread count (the per-tile sum is a plain
/// scalar f64 loop, so SIMD dispatch cannot change it either).
pub fn consensus_distance(
    exec: &crate::exec::ExecEngine,
    replicas: &crate::util::matrix::ReplicaMatrix,
    mean_model: &[f32],
) -> f64 {
    let n = replicas.n();
    if n == 0 {
        return 0.0;
    }
    debug_assert_eq!(mean_model.len(), replicas.p());
    let dists: Vec<f64> = exec
        .run_reduce_rows(
            n,
            replicas.p(),
            crate::exec::REDUCE_GRANULARITY,
            |row, tile| {
                let r = &replicas.row(row)[tile.start..tile.end];
                let m = &mean_model[tile.start..tile.end];
                r.iter()
                    .zip(m)
                    .map(|(&a, &b)| {
                        let d = a as f64 - b as f64;
                        d * d
                    })
                    .sum::<f64>()
            },
            |a, b| a + b,
            0.0,
        )
        .into_iter()
        .map(f64::sqrt)
        .collect();
    dists.iter().sum::<f64>() / n as f64
}

/// Mean of a sample.
pub(crate) fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population variance of a sample.
pub(crate) fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|&x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn l2_norm_matches_manual() {
        assert!((l2_norm(&[3.0, 4.0]) - 5.0).abs() < 1e-12);
        assert_eq!(l2_norm(&[]), 0.0);
    }

    #[test]
    fn per_replica_norms_slice_correctly() {
        let replicas = crate::util::matrix::ReplicaMatrix::from_rows(&[
            vec![3.0, 4.0, 100.0],
            vec![6.0, 8.0, 100.0],
        ]);
        let norms = per_replica_l2_norms(&replicas, 0..2);
        assert!((norms[0] - 5.0).abs() < 1e-12);
        assert!((norms[1] - 10.0).abs() < 1e-12);
    }

    #[test]
    fn consensus_distance_matches_manual_and_is_thread_invariant() {
        use crate::exec::ExecEngine;
        let replicas = crate::util::matrix::ReplicaMatrix::from_rows(&[
            vec![1.0; 64],
            vec![3.0; 64],
        ]);
        let mean_model = vec![2.0f32; 64];
        // Every replica is exactly 1.0 away per element: ||diff|| = 8.
        let d = consensus_distance(&ExecEngine::serial(), &replicas, &mean_model);
        assert!((d - 8.0).abs() < 1e-12, "{d}");
        for threads in [2, 4] {
            let eng = ExecEngine::new(threads);
            assert_eq!(d, consensus_distance(&eng, &replicas, &mean_model));
        }
        // Identical replicas ⇒ zero consensus distance.
        let same = crate::util::matrix::ReplicaMatrix::broadcast(3, &mean_model);
        assert_eq!(consensus_distance(&ExecEngine::serial(), &same, &mean_model), 0.0);
    }

    #[test]
    fn pooled_norms_match_serial_and_are_thread_invariant() {
        use crate::exec::ExecEngine;
        let mut rng = crate::util::rng::Rng::seed_from_u64(9);
        let p = 10_000; // several reduction tiles
        let rows: Vec<Vec<f32>> = (0..6)
            .map(|_| (0..p).map(|_| rng.range_f32(-1.0, 1.0)).collect())
            .collect();
        let replicas = crate::util::matrix::ReplicaMatrix::from_rows(&rows);
        let serial = per_replica_l2_norms_pooled(&ExecEngine::serial(), &replicas, 0..p);
        for (pooled, reference) in serial.iter().zip(per_replica_l2_norms(&replicas, 0..p)) {
            assert!(
                (pooled - reference).abs() <= 1e-9 * reference.max(1.0),
                "tiled vs flat sum: {pooled} vs {reference}"
            );
        }
        for threads in [2, 4, 8] {
            let eng = ExecEngine::new(threads);
            let got = per_replica_l2_norms_pooled(&eng, &replicas, 0..p);
            assert_eq!(serial, got, "{threads} threads");
            // Sliced capture (per-tensor gini path) is thread-invariant too.
            assert_eq!(
                per_replica_l2_norms_pooled(&ExecEngine::serial(), &replicas, 100..7000),
                per_replica_l2_norms_pooled(&eng, &replicas, 100..7000),
            );
        }
    }
}
