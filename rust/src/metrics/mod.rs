//! White-box training metrics — the instrumentation DBench adds (§3 of
//! the paper): per-replica parameter-tensor L2 norms collected *before*
//! the gossip averaging step, and four cross-replica variance statistics
//! (gini coefficient, index of dispersion, coefficient of variation,
//! quartile coefficient of dispersion), plus the variance **ranking
//! analysis** of §3.3 and structured recorders for the figure data.

mod ranking;
mod recorder;
mod variance;

pub use ranking::{rank_ascending, RankSummary};
pub use recorder::{IterationRecord, RunRecorder};
pub use variance::{
    coefficient_of_variation, gini_coefficient, index_of_dispersion,
    quartile_coefficient_of_dispersion, VarianceReport,
};

/// L2 norm of a parameter vector — the per-replica quantity DBench logs
/// via `torch.tensor.norm()` in the paper (§3.1.2).
pub fn l2_norm(v: &[f32]) -> f64 {
    v.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
}

/// L2 norms of a named slice of each replica's flat parameter vector —
/// used to study individual parameter tensors (Fig. 4) rather than the
/// whole model.
pub fn per_replica_l2_norms(replicas: &[Vec<f32>], range: std::ops::Range<usize>) -> Vec<f64> {
    replicas
        .iter()
        .map(|p| l2_norm(&p[range.clone()]))
        .collect()
}

/// Mean of a sample.
pub(crate) fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population variance of a sample.
pub(crate) fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|&x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn l2_norm_matches_manual() {
        assert!((l2_norm(&[3.0, 4.0]) - 5.0).abs() < 1e-12);
        assert_eq!(l2_norm(&[]), 0.0);
    }

    #[test]
    fn per_replica_norms_slice_correctly() {
        let replicas = vec![vec![3.0, 4.0, 100.0], vec![6.0, 8.0, 100.0]];
        let norms = per_replica_l2_norms(&replicas, 0..2);
        assert!((norms[0] - 5.0).abs() < 1e-12);
        assert!((norms[1] - 10.0).abs() < 1e-12);
    }
}
