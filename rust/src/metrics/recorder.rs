//! Structured run recording: one [`IterationRecord`] per training
//! iteration, streamed to JSONL and summarizable to the CSV series the
//! figure benches print. This is DBench's profiling-data path (§3.1.2).

use super::VarianceReport;
use crate::error::Result;
use crate::util::json::Value;
use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

/// Everything DBench logs for one training iteration.
#[derive(Debug, Clone)]
pub struct IterationRecord {
    /// 0-based global iteration index.
    pub iteration: usize,
    /// 0-based epoch.
    pub epoch: usize,
    /// Mean training loss across replicas this iteration.
    pub train_loss: f64,
    /// Test accuracy (classification) or perplexity (LM), when evaluated
    /// this iteration; `None` between eval points.
    pub test_metric: Option<f64>,
    /// Cross-replica variance of whole-model parameter L2 norms,
    /// sampled *before* gossip averaging.
    pub variance: VarianceReport,
    /// Gini coefficients of individual tracked parameter tensors
    /// (Fig. 4 uses single parameters).
    pub per_tensor_gini: Vec<f64>,
    /// Degree of the communication graph used this iteration.
    pub graph_degree: usize,
    /// Bytes sent per node this iteration (communication cost).
    pub bytes_per_node: u64,
    /// Learning rate in effect.
    pub lr: f64,
}

impl IterationRecord {
    /// JSON encoding (one JSONL line).
    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("iteration", Value::Num(self.iteration as f64)),
            ("epoch", Value::Num(self.epoch as f64)),
            ("train_loss", Value::Num(self.train_loss)),
            (
                "test_metric",
                match self.test_metric {
                    Some(m) => Value::Num(m),
                    None => Value::Null,
                },
            ),
            (
                "variance",
                Value::obj(vec![
                    ("gini", Value::Num(self.variance.gini)),
                    ("iod", Value::Num(self.variance.index_of_dispersion)),
                    ("cov", Value::Num(self.variance.coeff_of_variation)),
                    ("qcd", Value::Num(self.variance.quartile_coeff)),
                ]),
            ),
            (
                "per_tensor_gini",
                Value::Arr(self.per_tensor_gini.iter().map(|&g| Value::Num(g)).collect()),
            ),
            ("graph_degree", Value::Num(self.graph_degree as f64)),
            ("bytes_per_node", Value::Num(self.bytes_per_node as f64)),
            ("lr", Value::Num(self.lr)),
        ])
    }

    /// Decode from JSON (inverse of [`IterationRecord::to_json`]).
    pub fn from_json(v: &Value) -> Result<Self> {
        let variance = v
            .get("variance")
            .ok_or_else(|| crate::AdaError::Config("missing variance".into()))?;
        Ok(IterationRecord {
            iteration: v.usize_field("iteration")?,
            epoch: v.usize_field("epoch")?,
            train_loss: v.num_field("train_loss")?,
            test_metric: match v.get("test_metric") {
                Some(Value::Num(m)) => Some(*m),
                _ => None,
            },
            variance: VarianceReport {
                gini: variance.num_field("gini")?,
                index_of_dispersion: variance.num_field("iod")?,
                coeff_of_variation: variance.num_field("cov")?,
                quartile_coeff: variance.num_field("qcd")?,
            },
            per_tensor_gini: v
                .arr_field("per_tensor_gini")?
                .iter()
                .filter_map(Value::as_f64)
                .collect(),
            graph_degree: v.usize_field("graph_degree")?,
            bytes_per_node: v.num_field("bytes_per_node")? as u64,
            lr: v.num_field("lr")?,
        })
    }
}

/// Streams [`IterationRecord`]s to a JSONL file and keeps an in-memory
/// copy for post-run analysis.
#[derive(Debug)]
pub struct RunRecorder {
    records: Vec<IterationRecord>,
    sink: Option<BufWriter<File>>,
    /// Run label (SGD implementation name, e.g. `D_ring`).
    pub label: String,
}

impl RunRecorder {
    /// In-memory only recorder.
    pub fn in_memory(label: impl Into<String>) -> Self {
        RunRecorder {
            records: Vec::new(),
            sink: None,
            label: label.into(),
        }
    }

    /// Recorder that also appends JSONL to `path`.
    pub fn to_file(label: impl Into<String>, path: &Path) -> Result<Self> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        Ok(RunRecorder {
            records: Vec::new(),
            sink: Some(BufWriter::new(File::create(path)?)),
            label: label.into(),
        })
    }

    /// Record one iteration.
    pub fn push(&mut self, rec: IterationRecord) -> Result<()> {
        if let Some(sink) = &mut self.sink {
            writeln!(sink, "{}", rec.to_json().to_string())?;
        }
        self.records.push(rec);
        Ok(())
    }

    /// All records so far.
    pub fn records(&self) -> &[IterationRecord] {
        &self.records
    }

    /// Final test metric (last evaluated point), if any.
    pub fn final_test_metric(&self) -> Option<f64> {
        self.records.iter().rev().find_map(|r| r.test_metric)
    }

    /// Best test metric over the run (`higher_is_better` flips for PPL).
    pub fn best_test_metric(&self, higher_is_better: bool) -> Option<f64> {
        let it = self.records.iter().filter_map(|r| r.test_metric);
        if higher_is_better {
            it.max_by(|a, b| a.partial_cmp(b).expect("NaN metric"))
        } else {
            it.min_by(|a, b| a.partial_cmp(b).expect("NaN metric"))
        }
    }

    /// Total bytes sent per node over the run (communication cost).
    pub fn total_bytes_per_node(&self) -> u64 {
        self.records.iter().map(|r| r.bytes_per_node).sum()
    }

    /// Mean gini over a window of iterations (for early/late-stage
    /// comparisons, Observation 4).
    pub fn mean_gini(&self, range: std::ops::Range<usize>) -> f64 {
        let window: Vec<f64> = self
            .records
            .iter()
            .filter(|r| range.contains(&r.iteration))
            .map(|r| r.variance.gini)
            .collect();
        if window.is_empty() {
            0.0
        } else {
            window.iter().sum::<f64>() / window.len() as f64
        }
    }

    /// The (iteration, test_metric) series — the accuracy curves of
    /// Figures 2/3/7.
    pub fn metric_series(&self) -> Vec<(usize, f64)> {
        self.records
            .iter()
            .filter_map(|r| r.test_metric.map(|m| (r.iteration, m)))
            .collect()
    }

    /// The (iteration, gini) series — Fig. 4's curves.
    pub fn gini_series(&self) -> Vec<(usize, f64)> {
        self.records
            .iter()
            .map(|r| (r.iteration, r.variance.gini))
            .collect()
    }

    /// Flush the JSONL sink.
    pub fn flush(&mut self) -> Result<()> {
        if let Some(sink) = &mut self.sink {
            sink.flush()?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::scratch_dir;

    fn rec(iteration: usize, gini: f64, test: Option<f64>) -> IterationRecord {
        IterationRecord {
            iteration,
            epoch: iteration / 10,
            train_loss: 1.0,
            test_metric: test,
            variance: VarianceReport {
                gini,
                index_of_dispersion: 0.0,
                coeff_of_variation: 0.0,
                quartile_coeff: 0.0,
            },
            per_tensor_gini: vec![gini],
            graph_degree: 2,
            bytes_per_node: 800,
            lr: 0.1,
        }
    }

    #[test]
    fn in_memory_aggregations() {
        let mut r = RunRecorder::in_memory("D_ring");
        r.push(rec(0, 0.5, None)).unwrap();
        r.push(rec(1, 0.3, Some(0.6))).unwrap();
        r.push(rec(2, 0.1, Some(0.8))).unwrap();
        assert_eq!(r.final_test_metric(), Some(0.8));
        assert_eq!(r.best_test_metric(true), Some(0.8));
        assert_eq!(r.best_test_metric(false), Some(0.6));
        assert_eq!(r.total_bytes_per_node(), 2400);
        assert!((r.mean_gini(0..2) - 0.4).abs() < 1e-12);
        assert_eq!(r.metric_series(), vec![(1, 0.6), (2, 0.8)]);
    }

    #[test]
    fn jsonl_roundtrip() {
        let dir = scratch_dir("recorder").unwrap();
        let path = dir.join("run.jsonl");
        {
            let mut r = RunRecorder::to_file("D_torus", &path).unwrap();
            r.push(rec(0, 0.2, Some(0.7))).unwrap();
            r.push(rec(1, 0.1, None)).unwrap();
            r.flush().unwrap();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        let parsed =
            IterationRecord::from_json(&Value::parse(lines[0]).unwrap()).unwrap();
        assert_eq!(parsed.iteration, 0);
        assert_eq!(parsed.test_metric, Some(0.7));
        assert!((parsed.variance.gini - 0.2).abs() < 1e-12);
        let parsed1 =
            IterationRecord::from_json(&Value::parse(lines[1]).unwrap()).unwrap();
        assert_eq!(parsed1.test_metric, None);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn empty_recorder_is_sane() {
        let r = RunRecorder::in_memory("x");
        assert_eq!(r.final_test_metric(), None);
        assert_eq!(r.mean_gini(0..100), 0.0);
        assert_eq!(r.total_bytes_per_node(), 0);
    }
}
