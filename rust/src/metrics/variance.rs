//! The four cross-replica variance statistics of §3.3.
//!
//! Each takes the per-replica L2 norms of a parameter tensor (one value
//! per GPU, sampled *before* gossip averaging) and returns a scalar
//! dispersion measure. The paper reports that all four "present the same
//! trends and patterns consistently" and publishes gini; we implement all
//! four and test that they order dispersion consistently.

use super::{mean, variance};

/// Gini coefficient of a non-negative sample (the paper's headline
/// metric). Uses the standard mean-absolute-difference form
/// `G = Σᵢⱼ|xᵢ−xⱼ| / (2 n² μ)`, computed in O(n log n) via the sorted
/// identity `G = (2 Σᵢ i·x₍ᵢ₎ / (n Σ x)) − (n+1)/n`.
pub fn gini_coefficient(xs: &[f64]) -> f64 {
    let n = xs.len();
    if n < 2 {
        return 0.0;
    }
    let mut sorted: Vec<f64> = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).expect("NaN in gini input"));
    let total: f64 = sorted.iter().sum();
    if total <= 0.0 {
        return 0.0;
    }
    let weighted: f64 = sorted
        .iter()
        .enumerate()
        .map(|(i, &x)| (i as f64 + 1.0) * x)
        .sum();
    let n_f = n as f64;
    // Clamp the O(ε) residue of the sorted-sum identity on (near-)
    // constant samples so exact zeros stay exactly zero.
    ((2.0 * weighted / (n_f * total)) - (n_f + 1.0) / n_f).max(0.0)
}

/// Index of dispersion (variance-to-mean ratio) `σ² / μ`.
pub fn index_of_dispersion(xs: &[f64]) -> f64 {
    let m = mean(xs);
    if m == 0.0 {
        return 0.0;
    }
    variance(xs) / m
}

/// Coefficient of variation `σ / μ`.
pub fn coefficient_of_variation(xs: &[f64]) -> f64 {
    let m = mean(xs);
    if m == 0.0 {
        return 0.0;
    }
    variance(xs).sqrt() / m
}

/// Quartile coefficient of dispersion `(Q3 − Q1) / (Q3 + Q1)`.
pub fn quartile_coefficient_of_dispersion(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let mut sorted: Vec<f64> = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).expect("NaN in QCD input"));
    let q1 = quantile(&sorted, 0.25);
    let q3 = quantile(&sorted, 0.75);
    if q3 + q1 == 0.0 {
        return 0.0;
    }
    (q3 - q1) / (q3 + q1)
}

/// Linear-interpolated quantile of a sorted sample.
fn quantile(sorted: &[f64], q: f64) -> f64 {
    let n = sorted.len();
    if n == 1 {
        return sorted[0];
    }
    let pos = q * (n - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// All four §3.3 statistics of one cross-replica sample, bundled for
/// the recorders.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct VarianceReport {
    /// Gini coefficient (the paper's reported metric).
    pub gini: f64,
    /// Index of dispersion σ²/μ.
    pub index_of_dispersion: f64,
    /// Coefficient of variation σ/μ.
    pub coeff_of_variation: f64,
    /// Quartile coefficient of dispersion.
    pub quartile_coeff: f64,
}

impl VarianceReport {
    /// Compute all four statistics of `xs` (per-replica L2 norms).
    pub fn of(xs: &[f64]) -> Self {
        VarianceReport {
            gini: gini_coefficient(xs),
            index_of_dispersion: index_of_dispersion(xs),
            coeff_of_variation: coefficient_of_variation(xs),
            quartile_coeff: quartile_coefficient_of_dispersion(xs),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gini_zero_for_constant_sample() {
        assert_eq!(gini_coefficient(&[5.0; 8]), 0.0);
        assert_eq!(gini_coefficient(&[5.0]), 0.0);
        assert_eq!(gini_coefficient(&[]), 0.0);
    }

    #[test]
    fn gini_known_values() {
        // Two-point {0, x}: G = 1/2.
        assert!((gini_coefficient(&[0.0, 1.0]) - 0.5).abs() < 1e-12);
        // Maximal inequality over n → (n-1)/n.
        let mut xs = vec![0.0; 10];
        xs[9] = 7.0;
        assert!((gini_coefficient(&xs) - 0.9).abs() < 1e-12);
    }

    #[test]
    fn gini_matches_quadratic_definition() {
        let xs = [1.0, 2.5, 0.3, 4.0, 4.0, 0.9];
        let n = xs.len() as f64;
        let mu = xs.iter().sum::<f64>() / n;
        let mut mad = 0.0;
        for &a in &xs {
            for &b in &xs {
                mad += (a - b).abs();
            }
        }
        let expect = mad / (2.0 * n * n * mu);
        assert!((gini_coefficient(&xs) - expect).abs() < 1e-12);
    }

    #[test]
    fn gini_scale_invariant() {
        let xs = [1.0, 3.0, 7.0, 2.0];
        let scaled: Vec<f64> = xs.iter().map(|x| x * 42.0).collect();
        assert!((gini_coefficient(&xs) - gini_coefficient(&scaled)).abs() < 1e-12);
    }

    #[test]
    fn gini_bounded() {
        let xs = [0.0, 0.0, 1.0, 100.0, 3.0];
        let g = gini_coefficient(&xs);
        assert!((0.0..1.0).contains(&g));
    }

    #[test]
    fn cov_and_iod_known_values() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]; // σ=2, μ=5
        assert!((coefficient_of_variation(&xs) - 0.4).abs() < 1e-12);
        assert!((index_of_dispersion(&xs) - 0.8).abs() < 1e-12);
    }

    #[test]
    fn qcd_known_value() {
        // 1..=9: Q1=3, Q3=7 → (7-3)/(7+3) = 0.4
        let xs: Vec<f64> = (1..=9).map(|i| i as f64).collect();
        assert!((quartile_coefficient_of_dispersion(&xs) - 0.4).abs() < 1e-12);
    }

    #[test]
    fn all_metrics_agree_on_dispersion_ordering() {
        // §3.3: "the results of different metrics present the same trends".
        let tight = [10.0, 10.1, 9.9, 10.05, 9.95];
        let wide = [2.0, 18.0, 9.0, 14.0, 5.0];
        let t = VarianceReport::of(&tight);
        let w = VarianceReport::of(&wide);
        assert!(t.gini < w.gini);
        assert!(t.index_of_dispersion < w.index_of_dispersion);
        assert!(t.coeff_of_variation < w.coeff_of_variation);
        assert!(t.quartile_coeff < w.quartile_coeff);
    }

    #[test]
    fn degenerate_inputs_are_zero_not_nan() {
        for f in [
            gini_coefficient as fn(&[f64]) -> f64,
            index_of_dispersion,
            coefficient_of_variation,
            quartile_coefficient_of_dispersion,
        ] {
            assert_eq!(f(&[]), 0.0);
            assert_eq!(f(&[0.0, 0.0]), 0.0);
        }
    }
}
