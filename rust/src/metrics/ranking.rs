//! The ranking analysis of §3.3: per iteration, each SGD implementation
//! is assigned a rank 1..=m by its gini coefficient (1 = lowest variance),
//! which "filters out the value differences among the variances [and]
//! makes the variances across parameters comparable and integrable".
//! Summed over iterations and parameters, the rank totals reproduce
//! Fig. 5.

use std::collections::HashMap;

/// Ranks of `values` in ascending order, 1-based: the smallest value gets
/// rank 1. Ties receive the same (minimum) rank, like competition ranking.
pub fn rank_ascending(values: &[f64]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..values.len()).collect();
    idx.sort_by(|&a, &b| values[a].partial_cmp(&values[b]).expect("NaN in ranks"));
    let mut ranks = vec![0usize; values.len()];
    let mut i = 0;
    while i < idx.len() {
        let mut j = i;
        // Tie group shares the rank i+1.
        while j + 1 < idx.len() && values[idx[j + 1]] == values[idx[i]] {
            j += 1;
        }
        for &k in &idx[i..=j] {
            ranks[k] = i + 1;
        }
        i = j + 1;
    }
    ranks
}

/// Accumulates variance ranks per SGD implementation across iterations
/// (and across parameter tensors), reproducing Fig. 5's summaries.
#[derive(Debug, Default, Clone)]
pub struct RankSummary {
    /// Implementation name → (sum of ranks, observation count).
    totals: HashMap<String, (u64, u64)>,
}

impl RankSummary {
    /// Create an empty summary.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one iteration's gini coefficients: `entries` pairs each SGD
    /// implementation with its measured gini for the same parameter at the
    /// same iteration.
    pub fn record(&mut self, entries: &[(&str, f64)]) {
        let values: Vec<f64> = entries.iter().map(|&(_, v)| v).collect();
        let ranks = rank_ascending(&values);
        for ((name, _), rank) in entries.iter().zip(ranks) {
            let e = self.totals.entry((*name).to_string()).or_insert((0, 0));
            e.0 += rank as u64;
            e.1 += 1;
        }
    }

    /// Mean rank of an implementation (1 = consistently lowest variance).
    pub fn mean_rank(&self, name: &str) -> Option<f64> {
        self.totals
            .get(name)
            .map(|&(sum, count)| sum as f64 / count as f64)
    }

    /// Implementations sorted by ascending mean rank — the Fig. 5 ordering.
    pub fn ordering(&self) -> Vec<(String, f64)> {
        let mut v: Vec<(String, f64)> = self
            .totals
            .iter()
            .map(|(k, &(sum, count))| (k.clone(), sum as f64 / count as f64))
            .collect();
        v.sort_by(|a, b| a.1.partial_cmp(&b.1).expect("NaN mean rank"));
        v
    }

    /// Number of observations recorded for `name`.
    pub fn count(&self, name: &str) -> u64 {
        self.totals.get(name).map(|&(_, c)| c).unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ranks_are_ascending_one_based() {
        assert_eq!(rank_ascending(&[0.3, 0.1, 0.2]), vec![3, 1, 2]);
        assert_eq!(rank_ascending(&[5.0]), vec![1]);
        assert_eq!(rank_ascending(&[]), Vec::<usize>::new());
    }

    #[test]
    fn ties_share_minimum_rank() {
        assert_eq!(rank_ascending(&[1.0, 1.0, 2.0]), vec![1, 1, 3]);
        assert_eq!(rank_ascending(&[2.0, 2.0, 2.0]), vec![1, 1, 1]);
    }

    #[test]
    fn summary_reproduces_fig5_ordering() {
        // C_complete consistently lowest variance, D_ring highest —
        // the ResNet20 pattern described in §3.3.
        let mut s = RankSummary::new();
        for iter in 0..100 {
            let base = 0.001 * (100 - iter) as f64;
            s.record(&[
                ("C_complete", base * 1.0),
                ("D_complete", base * 1.5),
                ("D_torus", base * 3.0),
                ("D_ring", base * 5.0),
            ]);
        }
        let order: Vec<String> = s.ordering().into_iter().map(|(n, _)| n).collect();
        assert_eq!(order, vec!["C_complete", "D_complete", "D_torus", "D_ring"]);
        assert_eq!(s.mean_rank("C_complete"), Some(1.0));
        assert_eq!(s.mean_rank("D_ring"), Some(4.0));
        assert_eq!(s.count("D_torus"), 100);
    }

    #[test]
    fn mean_rank_missing_is_none() {
        let s = RankSummary::new();
        assert_eq!(s.mean_rank("nope"), None);
    }
}
