//! [`ParamTable`] — the one parameter-table shape every name-keyed
//! registry constructor consumes.
//!
//! Both registries (`crate::topology::registry`,
//! `crate::coordinator::strategy::registry` via
//! `StrategyParams::from_table`) resolve `name → ctor(params)`, and the
//! params arrive from two surfaces that must agree: a TOML section
//! (`[topology.<name>]` / `[strategy.<name>]` in an experiment spec) and
//! a CLI argument (`--topology name:k0=10,gamma_k=0.5`). This module is
//! that shared parser: one table type, typed getters with loud errors,
//! and an unknown-key check so typos fail instead of silently falling
//! back to defaults.

use super::tomlmini::TomlValue;
use crate::error::{AdaError, Result};
use std::collections::BTreeMap;

/// A named-parameter bag: `key → TomlValue`, ordered, cloneable, and
/// printable (it participates in the experiment pipeline's cell
/// fingerprints via `Debug`).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ParamTable {
    entries: BTreeMap<String, TomlValue>,
}

impl ParamTable {
    /// An empty table (all constructor defaults apply).
    pub fn new() -> Self {
        ParamTable::default()
    }

    /// Adopt a parsed TOML section verbatim.
    pub fn from_toml_section(section: &BTreeMap<String, TomlValue>) -> Self {
        ParamTable { entries: section.clone() }
    }

    /// Parse the CLI form `k=v,k2=v2,…` (empty input = empty table).
    /// Values follow TOML scalar rules without quoting: `true`/`false`,
    /// then integer, then float, else a bare string — so `graph=ring`
    /// and `gamma_k=0.5` both read naturally from a shell.
    pub fn parse_kv(text: &str) -> Result<Self> {
        let mut entries = BTreeMap::new();
        for part in text.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (key, value) = part.split_once('=').ok_or_else(|| {
                AdaError::Config(format!("parameter {part:?} is not of the form key=value"))
            })?;
            let key = key.trim();
            if key.is_empty() {
                return Err(AdaError::Config(format!("empty key in parameter {part:?}")));
            }
            entries.insert(key.to_string(), parse_scalar(value.trim()));
        }
        Ok(ParamTable { entries })
    }

    /// Insert/overwrite `key` (builder-style, used by tests and custom
    /// plans).
    pub fn set(mut self, key: impl Into<String>, value: TomlValue) -> Self {
        self.entries.insert(key.into(), value);
        self
    }

    /// Whether no parameters were given.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// The raw value under `key`, if present — for callers that forward
    /// a subset of keys into another table ([`ParamTable::set`]).
    pub fn get(&self, key: &str) -> Option<&TomlValue> {
        self.entries.get(key)
    }

    /// All keys, sorted.
    pub fn keys(&self) -> Vec<&str> {
        self.entries.keys().map(String::as_str).collect()
    }

    /// Error unless every key is in `known` — the typo guard every
    /// registry constructor should call first.
    pub fn expect_only(&self, known: &[&str]) -> Result<()> {
        for key in self.entries.keys() {
            if !known.contains(&key.as_str()) {
                return Err(AdaError::Config(format!(
                    "unknown parameter {key:?} (known: {})",
                    known.join(", ")
                )));
            }
        }
        Ok(())
    }

    /// `key` as usize, if present; error when present but not a
    /// non-negative integer.
    pub fn get_usize(&self, key: &str) -> Result<Option<usize>> {
        match self.entries.get(key) {
            None => Ok(None),
            Some(v) => v
                .as_int()
                .and_then(|i| usize::try_from(i).ok())
                .map(Some)
                .ok_or_else(|| bad(key, v, "a non-negative integer")),
        }
    }

    /// `key` as f64 (ints widen), if present.
    pub fn get_f64(&self, key: &str) -> Result<Option<f64>> {
        match self.entries.get(key) {
            None => Ok(None),
            Some(v) => v
                .as_float()
                .map(Some)
                .ok_or_else(|| bad(key, v, "a number")),
        }
    }

    /// `key` as bool, if present.
    pub fn get_bool(&self, key: &str) -> Result<Option<bool>> {
        match self.entries.get(key) {
            None => Ok(None),
            Some(v) => v.as_bool().map(Some).ok_or_else(|| bad(key, v, "a boolean")),
        }
    }

    /// `key` as str, if present.
    pub fn get_str(&self, key: &str) -> Result<Option<&str>> {
        match self.entries.get(key) {
            None => Ok(None),
            Some(v) => v.as_str().map(Some).ok_or_else(|| bad(key, v, "a string")),
        }
    }

    /// `key` as usize with a default.
    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        Ok(self.get_usize(key)?.unwrap_or(default))
    }

    /// `key` as f64 with a default.
    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        Ok(self.get_f64(key)?.unwrap_or(default))
    }

    /// `key` as bool with a default.
    pub fn bool_or(&self, key: &str, default: bool) -> Result<bool> {
        Ok(self.get_bool(key)?.unwrap_or(default))
    }

    /// `key` as usize, required.
    pub fn need_usize(&self, key: &str, who: &str) -> Result<usize> {
        self.get_usize(key)?
            .ok_or_else(|| AdaError::Config(format!("{who} needs parameter {key} = <int>")))
    }

    /// `key` as f64, required.
    pub fn need_f64(&self, key: &str, who: &str) -> Result<f64> {
        self.get_f64(key)?
            .ok_or_else(|| AdaError::Config(format!("{who} needs parameter {key} = <number>")))
    }
}

fn bad(key: &str, value: &TomlValue, wanted: &str) -> AdaError {
    AdaError::Config(format!("parameter {key} = {value:?} is not {wanted}"))
}

/// CLI scalar: bool, then int, then float, else bare string.
fn parse_scalar(text: &str) -> TomlValue {
    match text {
        "true" => return TomlValue::Bool(true),
        "false" => return TomlValue::Bool(false),
        _ => {}
    }
    if let Ok(i) = text.parse::<i64>() {
        return TomlValue::Int(i);
    }
    if let Ok(f) = text.parse::<f64>() {
        return TomlValue::Float(f);
    }
    TomlValue::Str(text.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kv_form_parses_typed_scalars() {
        let t = ParamTable::parse_kv("k0=10,gamma_k=0.5,per_iter=true,graph=ring").unwrap();
        assert_eq!(t.get_usize("k0").unwrap(), Some(10));
        assert_eq!(t.get_f64("gamma_k").unwrap(), Some(0.5));
        assert_eq!(t.get_bool("per_iter").unwrap(), Some(true));
        assert_eq!(t.get_str("graph").unwrap(), Some("ring"));
        assert_eq!(t.get_usize("absent").unwrap(), None);
        assert_eq!(t.usize_or("absent", 7).unwrap(), 7);
    }

    #[test]
    fn ints_widen_to_floats_but_not_vice_versa() {
        let t = ParamTable::parse_kv("x=3").unwrap();
        assert_eq!(t.get_f64("x").unwrap(), Some(3.0));
        let t = ParamTable::parse_kv("x=3.5").unwrap();
        assert!(t.get_usize("x").is_err(), "float is not an int");
    }

    #[test]
    fn empty_and_malformed_inputs() {
        assert!(ParamTable::parse_kv("").unwrap().is_empty());
        assert!(ParamTable::parse_kv("justakey").is_err());
        assert!(ParamTable::parse_kv("=3").is_err());
    }

    #[test]
    fn unknown_keys_are_loud() {
        let t = ParamTable::parse_kv("k0=4,tpyo=2").unwrap();
        let err = t.expect_only(&["k0", "gamma_k"]).unwrap_err().to_string();
        assert!(err.contains("tpyo"), "{err}");
        assert!(t.expect_only(&["k0", "tpyo"]).is_ok());
    }

    #[test]
    fn required_keys_error_with_owner_name() {
        let t = ParamTable::new();
        let err = t.need_usize("k0", "policy ada").unwrap_err().to_string();
        assert!(err.contains("policy ada") && err.contains("k0"), "{err}");
    }

    #[test]
    fn toml_section_roundtrip() {
        let doc = crate::util::tomlmini::TomlDoc::parse(
            "[topology.comm_budget]\nbudget_mb = 12.5\nk0 = 8\n",
        )
        .unwrap();
        let section = doc.sections.get("topology.comm_budget").unwrap();
        let t = ParamTable::from_toml_section(section);
        assert_eq!(t.get_f64("budget_mb").unwrap(), Some(12.5));
        assert_eq!(t.get_usize("k0").unwrap(), Some(8));
    }
}
