//! [`ReplicaMatrix`] — the flat replica parameter store.
//!
//! The training state of an n-replica run used to live in a
//! `Vec<Vec<f32>>`: n separate heap allocations with no alignment or
//! adjacency guarantees. That layout defeats everything the execution
//! engine's column tiling is built around — aligned vector loads,
//! hardware prefetch across rows, and the NUMA first-touch placement of
//! scratch pages. `ReplicaMatrix` replaces it with **one contiguous
//! allocation**:
//!
//! ```text
//! ┌────────── stride (p rounded up to 16 f32 = 64 B) ──────────┐
//! │ row 0: p live f32s                        │ zero padding   │
//! │ row 1: p live f32s                        │ zero padding   │
//! │ …                                         │                │
//! │ row n−1                                   │                │
//! └────────────────────────────────────────────────────────────┘
//! base pointer and every row start are 64-byte aligned
//! ```
//!
//! ## Layout contract
//!
//! * The base allocation is 64-byte aligned ([`ROW_ALIGN`] bytes — one
//!   cache line, and the natural alignment of an AVX-512 register; AVX2
//!   needs 32).
//! * The row stride is `p` rounded up to [`ROW_ALIGN`]`/4` floats, so
//!   **every row starts 64-byte aligned**. Column tiles *within* a row
//!   start at arbitrary offsets — the SIMD kernels
//!   ([`crate::exec::simd`]) therefore use unaligned loads, which cost
//!   nothing on current x86 when the data is in fact aligned.
//! * Padding floats are **always zero**: rows are only ever exposed as
//!   `&[f32]`/`&mut [f32]` of length `p`, so no kernel can write (or
//!   observe) padding. Equality compares live elements only.
//!
//! ## Tile ownership
//!
//! [`ReplicaMatrix::rows_mut`] splits the buffer into n disjoint
//! mutable row views — the hand-off point to
//! [`crate::exec::column_views`], which transposes them into per-worker
//! column tiles. One worker owns one contiguous column range of *every*
//! row for a whole kernel call (see `rust/src/exec/mod.rs`), and the
//! allocation being a single flat block is what lets consecutive rows
//! of one tile prefetch into the same cache set predictably.
//!
//! The store is deliberately dumb: no growth, no raggedness (the
//! equal-parameter-count invariant of the old `Vec<Vec<f32>>` asserts
//! is now structural), no interior mutability.

use std::alloc::{alloc, alloc_zeroed, dealloc, handle_alloc_error, Layout};
use std::fmt;
use std::ops::{Index, IndexMut};
use std::ptr::NonNull;

/// Row alignment in bytes: one cache line. Every row of a
/// [`ReplicaMatrix`] starts on this boundary.
pub const ROW_ALIGN: usize = 64;

/// Row alignment in f32 elements (16).
const ALIGN_F32: usize = ROW_ALIGN / std::mem::size_of::<f32>();

/// A 64-byte-aligned heap buffer of f32s. Plain data: no interior
/// mutability, freed on drop.
struct AlignedBuf {
    ptr: NonNull<f32>,
    len: usize,
}

// SAFETY: the buffer is plain `f32` data behind a unique owner; access
// is governed by ordinary borrows on the wrapper.
unsafe impl Send for AlignedBuf {}
unsafe impl Sync for AlignedBuf {}

impl AlignedBuf {
    fn layout(len: usize) -> Layout {
        Layout::from_size_align(len * std::mem::size_of::<f32>(), ROW_ALIGN)
            .expect("replica matrix layout")
    }

    /// Zeroed buffer of `len` floats. Uses the zeroed allocator so
    /// large buffers come back as lazily-mapped zero pages — the first
    /// *write* to each page decides its physical placement, which the
    /// gossip engine exploits for NUMA-aligned first touch.
    fn zeroed(len: usize) -> Self {
        if len == 0 {
            return AlignedBuf { ptr: NonNull::dangling(), len: 0 };
        }
        let layout = Self::layout(len);
        // SAFETY: layout has non-zero size.
        let raw = unsafe { alloc_zeroed(layout) } as *mut f32;
        let Some(ptr) = NonNull::new(raw) else {
            handle_alloc_error(layout);
        };
        AlignedBuf { ptr, len }
    }

    fn as_slice(&self) -> &[f32] {
        // SAFETY: ptr/len describe the owned allocation (or a dangling
        // ptr with len 0, for which from_raw_parts is defined).
        unsafe { std::slice::from_raw_parts(self.ptr.as_ptr(), self.len) }
    }

    fn as_mut_slice(&mut self) -> &mut [f32] {
        // SAFETY: as above, plus unique access via &mut self.
        unsafe { std::slice::from_raw_parts_mut(self.ptr.as_ptr(), self.len) }
    }
}

impl Drop for AlignedBuf {
    fn drop(&mut self) {
        if self.len != 0 {
            // SAFETY: allocated with the identical layout in `zeroed`/`clone`.
            unsafe { dealloc(self.ptr.as_ptr() as *mut u8, Self::layout(self.len)) };
        }
    }
}

impl Clone for AlignedBuf {
    fn clone(&self) -> Self {
        if self.len == 0 {
            return AlignedBuf { ptr: NonNull::dangling(), len: 0 };
        }
        let layout = Self::layout(self.len);
        // SAFETY: non-zero size; contents copied below before any read.
        let raw = unsafe { alloc(layout) } as *mut f32;
        let Some(ptr) = NonNull::new(raw) else {
            handle_alloc_error(layout);
        };
        // SAFETY: both buffers hold `len` floats and do not overlap.
        unsafe { std::ptr::copy_nonoverlapping(self.ptr.as_ptr(), ptr.as_ptr(), self.len) };
        AlignedBuf { ptr, len: self.len }
    }
}

/// The flat replica parameter store: `n` rows of `p` live f32s in one
/// 64-byte-aligned allocation with a padded row stride. See the module
/// docs for the layout contract.
#[derive(Clone)]
pub struct ReplicaMatrix {
    buf: AlignedBuf,
    n: usize,
    p: usize,
    stride: usize,
}

impl ReplicaMatrix {
    /// The padded row stride for `p` live elements.
    fn stride_for(p: usize) -> usize {
        p.div_ceil(ALIGN_F32) * ALIGN_F32
    }

    /// A zeroed `n × p` matrix. Pages are lazily mapped (zeroed
    /// allocator) so the first write to each page decides placement.
    pub fn zeros(n: usize, p: usize) -> Self {
        let stride = Self::stride_for(p);
        ReplicaMatrix {
            buf: AlignedBuf::zeroed(n * stride),
            n,
            p,
            stride,
        }
    }

    /// Build from equal-length rows (panics on ragged input — the
    /// invariant every old `Vec<Vec<f32>>` call site asserted is now
    /// enforced at construction, once).
    pub fn from_rows(rows: &[Vec<f32>]) -> Self {
        let p = rows.first().map(Vec::len).unwrap_or(0);
        assert!(
            rows.iter().all(|r| r.len() == p),
            "replicas must have equal parameter counts"
        );
        let mut m = Self::zeros(rows.len(), p);
        for (i, r) in rows.iter().enumerate() {
            m.row_mut(i).copy_from_slice(r);
        }
        m
    }

    /// `n` identical rows — §2.2's identical initial replicas.
    pub fn broadcast(n: usize, row: &[f32]) -> Self {
        let mut m = Self::zeros(n, row.len());
        for i in 0..n {
            m.row_mut(i).copy_from_slice(row);
        }
        m
    }

    /// Replica count.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Live parameters per replica.
    pub fn p(&self) -> usize {
        self.p
    }

    /// Row stride in f32 elements (`p` rounded up to 16).
    pub fn stride(&self) -> usize {
        self.stride
    }

    /// True when the matrix holds no replicas.
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Row `i` (the live `p` elements; padding is never exposed).
    pub fn row(&self, i: usize) -> &[f32] {
        assert!(i < self.n, "row {i} out of range (n = {})", self.n);
        &self.buf.as_slice()[i * self.stride..i * self.stride + self.p]
    }

    /// Mutable row `i`.
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        assert!(i < self.n, "row {i} out of range (n = {})", self.n);
        let (stride, p) = (self.stride, self.p);
        &mut self.buf.as_mut_slice()[i * stride..i * stride + p]
    }

    /// All `n` rows, in order (empty slices when `p == 0`, so the row
    /// count always agrees with [`ReplicaMatrix::n`]).
    pub fn rows(&self) -> impl Iterator<Item = &[f32]> {
        let (stride, p) = (self.stride, self.p);
        let buf = self.buf.as_slice();
        (0..self.n).map(move |i| &buf[i * stride..i * stride + p])
    }

    /// Split-row mutable access: all `n` rows as disjoint mutable
    /// views, the hand-off point to the execution engine's
    /// [`crate::exec::column_views`] tiling. Always `n` entries.
    pub fn rows_mut(&mut self) -> Vec<&mut [f32]> {
        let (stride, p, n) = (self.stride, self.p, self.n);
        if p == 0 {
            // Zero-width rows share no storage; hand out promoted
            // empty slices so the count still matches `n`.
            return (0..n).map(|_| &mut [] as &mut [f32]).collect();
        }
        self.buf
            .as_mut_slice()
            .chunks_exact_mut(stride)
            .take(n)
            .map(|c| &mut c[..p])
            .collect()
    }

    /// Copy row 0 into every other row (the centralized strategy's
    /// post-step broadcast), without intermediate allocation.
    pub fn broadcast_first_row(&mut self) {
        if self.n <= 1 || self.p == 0 {
            return;
        }
        let (stride, p) = (self.stride, self.p);
        let (head, rest) = self.buf.as_mut_slice().split_at_mut(stride);
        let src = &head[..p];
        for chunk in rest.chunks_exact_mut(stride).take(self.n - 1) {
            chunk[..p].copy_from_slice(src);
        }
    }

    /// Back to the legacy row-vector form (tests, the dense reference
    /// path, external tooling).
    pub fn to_vecs(&self) -> Vec<Vec<f32>> {
        self.rows().map(<[f32]>::to_vec).collect()
    }

    /// Raw base pointer of the flat store (crate-internal: the
    /// overlapped gossip pipeline derives per-row views from it whose
    /// disjointness is enforced by the pipeline's produced-row
    /// protocol rather than the borrow checker; see
    /// `crate::gossip`'s `SrcRows`). Dangling (but well-aligned) when
    /// the matrix is empty — pair only with zero-length reads.
    pub(crate) fn base_ptr(&self) -> *const f32 {
        self.buf.ptr.as_ptr()
    }

    /// Mutable raw base pointer; same contract as
    /// [`ReplicaMatrix::base_ptr`].
    pub(crate) fn base_ptr_mut(&mut self) -> *mut f32 {
        self.buf.ptr.as_ptr()
    }
}

impl Default for ReplicaMatrix {
    fn default() -> Self {
        Self::zeros(0, 0)
    }
}

impl Index<usize> for ReplicaMatrix {
    type Output = [f32];

    fn index(&self, i: usize) -> &[f32] {
        self.row(i)
    }
}

impl IndexMut<usize> for ReplicaMatrix {
    fn index_mut(&mut self, i: usize) -> &mut [f32] {
        self.row_mut(i)
    }
}

impl PartialEq for ReplicaMatrix {
    /// Live elements only — padding does not participate.
    fn eq(&self, other: &Self) -> bool {
        self.n == other.n
            && self.p == other.p
            && self.rows().zip(other.rows()).all(|(a, b)| a == b)
    }
}

impl fmt::Debug for ReplicaMatrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("ReplicaMatrix")
            .field("n", &self.n)
            .field("p", &self.p)
            .field("stride", &self.stride)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_row_is_cache_line_aligned() {
        for (n, p) in [(1, 1), (3, 17), (8, 4096), (5, 4097), (16, 15)] {
            let m = ReplicaMatrix::zeros(n, p);
            assert_eq!(m.stride() % ALIGN_F32, 0);
            assert!(m.stride() >= p);
            assert!(m.stride() < p + ALIGN_F32);
            for i in 0..n {
                assert_eq!(
                    m.row(i).as_ptr() as usize % ROW_ALIGN,
                    0,
                    "row {i} of {n}×{p} must start 64-byte aligned"
                );
            }
        }
    }

    #[test]
    fn from_rows_roundtrips_through_to_vecs() {
        let rows = vec![vec![1.0f32, 2.0, 3.0], vec![4.0, 5.0, 6.0]];
        let m = ReplicaMatrix::from_rows(&rows);
        assert_eq!(m.n(), 2);
        assert_eq!(m.p(), 3);
        assert_eq!(m.to_vecs(), rows);
        assert_eq!(&m[1][..2], &[4.0, 5.0]);
    }

    #[test]
    #[should_panic(expected = "equal parameter counts")]
    fn from_rows_rejects_ragged_input() {
        ReplicaMatrix::from_rows(&[vec![1.0], vec![1.0, 2.0]]);
    }

    #[test]
    fn rows_mut_views_are_disjoint_and_cover() {
        let mut m = ReplicaMatrix::zeros(4, 5);
        {
            let rows = m.rows_mut();
            assert_eq!(rows.len(), 4);
            for (i, r) in rows.into_iter().enumerate() {
                assert_eq!(r.len(), 5);
                r.fill(i as f32 + 1.0);
            }
        }
        for i in 0..4 {
            assert!(m.row(i).iter().all(|&v| v == i as f32 + 1.0));
        }
    }

    #[test]
    fn broadcast_fills_identical_rows() {
        let m = ReplicaMatrix::broadcast(3, &[7.0, 8.0]);
        for i in 0..3 {
            assert_eq!(m.row(i), &[7.0, 8.0]);
        }
    }

    #[test]
    fn broadcast_first_row_copies_over_all_rows() {
        let mut m = ReplicaMatrix::from_rows(&[
            vec![1.0, 2.0],
            vec![3.0, 4.0],
            vec![5.0, 6.0],
        ]);
        m.broadcast_first_row();
        assert_eq!(m, ReplicaMatrix::broadcast(3, &[1.0, 2.0]));
    }

    #[test]
    fn equality_is_shape_and_live_elements() {
        let a = ReplicaMatrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        let mut b = a.clone();
        assert_eq!(a, b);
        b.row_mut(1)[0] = 9.0;
        assert_ne!(a, b);
        assert_ne!(a, ReplicaMatrix::zeros(2, 3));
        assert_ne!(a, ReplicaMatrix::zeros(3, 2));
    }

    #[test]
    fn clone_is_deep() {
        let a = ReplicaMatrix::broadcast(2, &[1.0; 33]);
        let mut b = a.clone();
        b.row_mut(0)[32] = -1.0;
        assert_eq!(a.row(0)[32], 1.0, "clone must not alias");
    }

    #[test]
    fn swap_exchanges_whole_stores() {
        let mut a = ReplicaMatrix::broadcast(2, &[1.0, 2.0]);
        let mut b = ReplicaMatrix::broadcast(2, &[3.0, 4.0]);
        std::mem::swap(&mut a, &mut b);
        assert_eq!(a.row(0), &[3.0, 4.0]);
        assert_eq!(b.row(1), &[1.0, 2.0]);
    }

    #[test]
    fn empty_matrices_are_safe() {
        let mut m = ReplicaMatrix::zeros(0, 0);
        assert!(m.is_empty());
        assert_eq!(m.rows().count(), 0);
        assert!(m.rows_mut().is_empty());
        assert_eq!(m, ReplicaMatrix::default());
        // Zero-width rows still count n rows everywhere.
        let mut z = ReplicaMatrix::zeros(3, 0);
        assert_eq!(z.n(), 3);
        assert_eq!(z.rows().count(), 3);
        assert_eq!(z.rows_mut().len(), 3);
        assert!(z.rows().all(<[f32]>::is_empty));
        assert_eq!(z.to_vecs(), vec![Vec::<f32>::new(); 3]);
        assert_eq!(ReplicaMatrix::from_rows(&z.to_vecs()), z, "roundtrip at p = 0");
    }
}
