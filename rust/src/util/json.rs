//! Minimal JSON: a `Value` tree, a recursive-descent parser, and a
//! writer. Used for the artifact manifests (written by `aot.py`, read
//! here) and the JSONL run records. Supports the full JSON grammar
//! except `\u` surrogate pairs beyond the BMP (not needed by any of our
//! producers, which emit ASCII).

use crate::error::{AdaError, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// Any JSON number (stored as f64; integers round-trip to 2^53).
    Num(f64),
    /// String.
    Str(String),
    /// Array.
    Arr(Vec<Value>),
    /// Object (sorted keys — deterministic output).
    Obj(BTreeMap<String, Value>),
}

impl Value {
    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Value> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters after document"));
        }
        Ok(v)
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else if n.is_finite() {
                    let _ = write!(out, "{n}");
                } else {
                    out.push_str("null"); // JSON has no NaN/Inf
                }
            }
            Value::Str(s) => write_escaped(s, out),
            Value::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Value::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    // ---- typed accessors ------------------------------------------------

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Required string field.
    pub fn str_field(&self, key: &str) -> Result<&str> {
        match self.get(key) {
            Some(Value::Str(s)) => Ok(s),
            _ => Err(AdaError::Config(format!("missing/invalid string field '{key}'"))),
        }
    }

    /// Required numeric field as f64.
    pub fn num_field(&self, key: &str) -> Result<f64> {
        match self.get(key) {
            Some(Value::Num(n)) => Ok(*n),
            _ => Err(AdaError::Config(format!("missing/invalid number field '{key}'"))),
        }
    }

    /// Required numeric field as usize.
    pub fn usize_field(&self, key: &str) -> Result<usize> {
        let n = self.num_field(key)?;
        if n < 0.0 || n.fract() != 0.0 {
            return Err(AdaError::Config(format!("field '{key}' is not a usize: {n}")));
        }
        Ok(n as usize)
    }

    /// Required array field.
    pub fn arr_field(&self, key: &str) -> Result<&[Value]> {
        match self.get(key) {
            Some(Value::Arr(a)) => Ok(a),
            _ => Err(AdaError::Config(format!("missing/invalid array field '{key}'"))),
        }
    }

    /// As f64 if numeric.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Build an object from pairs.
    pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
        Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> AdaError {
        AdaError::Config(format!("json parse error at byte {}: {msg}", self.pos))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Value) -> Result<Value> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Value> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Value::Null),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 code point.
                    let rest = &self.bytes[self.pos..];
                    let text = std::str::from_utf8(rest).map_err(|_| self.err("bad utf8"))?;
                    let c = text.chars().next().ok_or_else(|| self.err("empty"))?;
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("bad number"))?;
        text.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut xs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(xs));
        }
        loop {
            xs.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Value::Arr(xs));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(key, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Value::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Value::parse("null").unwrap(), Value::Null);
        assert_eq!(Value::parse("true").unwrap(), Value::Bool(true));
        assert_eq!(Value::parse("-3.5e2").unwrap(), Value::Num(-350.0));
        assert_eq!(
            Value::parse(r#""a\nbA""#).unwrap(),
            Value::Str("a\nbA".into())
        );
    }

    #[test]
    fn parses_nested_structures() {
        let v = Value::parse(r#"{"a":[1,2,{"b":null}],"c":"x"}"#).unwrap();
        assert_eq!(v.str_field("c").unwrap(), "x");
        let a = v.arr_field("a").unwrap();
        assert_eq!(a.len(), 3);
        assert_eq!(a[0], Value::Num(1.0));
    }

    #[test]
    fn roundtrip() {
        let cases = [
            r#"{"kind":"classification","n":42,"xs":[1,2.5,-3],"ok":true}"#,
            r#"[[],{},"",0]"#,
            r#"{"nested":{"deep":{"deeper":[null]}}}"#,
        ];
        for c in cases {
            let v = Value::parse(c).unwrap();
            let s = v.to_string();
            assert_eq!(Value::parse(&s).unwrap(), v, "roundtrip {c}");
        }
    }

    #[test]
    fn integers_print_without_decimal() {
        assert_eq!(Value::Num(42.0).to_string(), "42");
        assert_eq!(Value::Num(2.5).to_string(), "2.5");
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["", "{", "[1,", "tru", "{\"a\" 1}", "1 2", "{'a':1}"] {
            assert!(Value::parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn typed_accessors_validate() {
        let v = Value::parse(r#"{"n":3,"s":"x","f":1.5}"#).unwrap();
        assert_eq!(v.usize_field("n").unwrap(), 3);
        assert!(v.usize_field("f").is_err());
        assert!(v.usize_field("missing").is_err());
        assert!(v.str_field("n").is_err());
    }

    #[test]
    fn escapes_control_characters() {
        let v = Value::Str("a\"b\\c\nd\u{1}".into());
        let s = v.to_string();
        assert_eq!(Value::parse(&s).unwrap(), v);
    }

    #[test]
    fn nan_serializes_as_null() {
        assert_eq!(Value::Num(f64::NAN).to_string(), "null");
    }
}
