//! A TOML subset parser for the config files under `configs/`:
//! top-level `key = value` pairs and one level of `[section]` tables,
//! with string / integer / float / boolean / homogeneous-array values
//! and `#` comments. That is exactly the shape of every config this
//! project ships; anything fancier is a config error, loudly.

use crate::error::{AdaError, Result};
use std::collections::BTreeMap;

/// A TOML-subset value.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    /// Quoted string.
    Str(String),
    /// Integer.
    Int(i64),
    /// Float.
    Float(f64),
    /// Boolean.
    Bool(bool),
    /// Array of values.
    Arr(Vec<TomlValue>),
}

impl TomlValue {
    /// As i64 (ints only).
    pub fn as_int(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }

    /// As f64 (ints widen).
    pub fn as_float(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    /// As str.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    /// As bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// As usize array.
    pub fn as_usize_array(&self) -> Option<Vec<usize>> {
        match self {
            TomlValue::Arr(xs) => xs
                .iter()
                .map(|x| x.as_int().and_then(|i| usize::try_from(i).ok()))
                .collect(),
            _ => None,
        }
    }
}

/// A parsed document: the root table plus named sections.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TomlDoc {
    /// Top-level `key = value` pairs.
    pub root: BTreeMap<String, TomlValue>,
    /// `[section]` tables.
    pub sections: BTreeMap<String, BTreeMap<String, TomlValue>>,
}

impl TomlDoc {
    /// Parse a document.
    pub fn parse(text: &str) -> Result<TomlDoc> {
        let mut doc = TomlDoc::default();
        let mut current: Option<String> = None;
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[') {
                let name = name
                    .strip_suffix(']')
                    .ok_or_else(|| err(lineno, "unterminated [section]"))?
                    .trim();
                if name.is_empty() || name.contains('[') {
                    return Err(err(lineno, "bad section name"));
                }
                doc.sections.entry(name.to_string()).or_default();
                current = Some(name.to_string());
                continue;
            }
            let (key, value) = line
                .split_once('=')
                .ok_or_else(|| err(lineno, "expected key = value"))?;
            let key = key.trim();
            if key.is_empty() {
                return Err(err(lineno, "empty key"));
            }
            let value = parse_value(value.trim()).map_err(|m| err(lineno, &m))?;
            let table = match &current {
                Some(s) => doc.sections.get_mut(s).expect("section exists"),
                None => &mut doc.root,
            };
            table.insert(key.to_string(), value);
        }
        Ok(doc)
    }

    /// Look up `key` at top level, or `section.key`.
    pub fn get(&self, path: &str) -> Option<&TomlValue> {
        match path.split_once('.') {
            Some((section, key)) => self.sections.get(section)?.get(key),
            None => self.root.get(path),
        }
    }

    /// A whole `[section]` table by its literal header name — the
    /// accessor for dotted headers like `[topology.comm_budget]`, whose
    /// keys [`TomlDoc::get`]'s first-dot split cannot reach.
    pub fn section(&self, name: &str) -> Option<&BTreeMap<String, TomlValue>> {
        self.sections.get(name)
    }
}

fn err(lineno: usize, msg: &str) -> AdaError {
    AdaError::Config(format!("toml parse error on line {}: {msg}", lineno + 1))
}

fn strip_comment(line: &str) -> &str {
    // '#' outside quotes starts a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(text: &str) -> std::result::Result<TomlValue, String> {
    if text.is_empty() {
        return Err("empty value".into());
    }
    if let Some(inner) = text.strip_prefix('"') {
        let inner = inner.strip_suffix('"').ok_or("unterminated string")?;
        if inner.contains('"') {
            return Err("embedded quotes unsupported".into());
        }
        return Ok(TomlValue::Str(inner.to_string()));
    }
    if text == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if text == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(inner) = text.strip_prefix('[') {
        let inner = inner.strip_suffix(']').ok_or("unterminated array")?.trim();
        if inner.is_empty() {
            return Ok(TomlValue::Arr(Vec::new()));
        }
        let items: std::result::Result<Vec<TomlValue>, String> =
            split_top_level(inner).iter().map(|s| parse_value(s.trim())).collect();
        return Ok(TomlValue::Arr(items?));
    }
    if !text.contains(['.', 'e', 'E']) {
        if let Ok(i) = text.replace('_', "").parse::<i64>() {
            return Ok(TomlValue::Int(i));
        }
    }
    if let Ok(f) = text.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(format!("cannot parse value {text:?}"))
}

/// Split an array body on commas, respecting quotes (no nested arrays —
/// not needed by our configs).
fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_root_and_sections() {
        let doc = TomlDoc::parse(
            r#"
            # launcher config
            name = "fig3"          # inline comment
            epochs = 6
            peak_lr = 0.05
            sqrt = false
            scales = [8, 16, 32]

            [workload]
            kind = "mlp_image"
            dim = 32
            "#,
        )
        .unwrap();
        assert_eq!(doc.get("name").unwrap().as_str(), Some("fig3"));
        assert_eq!(doc.get("epochs").unwrap().as_int(), Some(6));
        assert_eq!(doc.get("peak_lr").unwrap().as_float(), Some(0.05));
        assert_eq!(doc.get("sqrt").unwrap().as_bool(), Some(false));
        assert_eq!(
            doc.get("scales").unwrap().as_usize_array(),
            Some(vec![8, 16, 32])
        );
        assert_eq!(doc.get("workload.kind").unwrap().as_str(), Some("mlp_image"));
        assert_eq!(doc.get("workload.dim").unwrap().as_int(), Some(32));
        assert!(doc.get("missing").is_none());
        assert!(doc.get("workload.missing").is_none());
    }

    #[test]
    fn int_widens_to_float() {
        let doc = TomlDoc::parse("x = 3").unwrap();
        assert_eq!(doc.get("x").unwrap().as_float(), Some(3.0));
    }

    #[test]
    fn string_arrays() {
        let doc = TomlDoc::parse(r#"fs = ["a", "b,c"]"#).unwrap();
        match doc.get("fs").unwrap() {
            TomlValue::Arr(xs) => {
                assert_eq!(xs[0].as_str(), Some("a"));
                assert_eq!(xs[1].as_str(), Some("b,c"), "comma inside quotes");
            }
            _ => panic!("not an array"),
        }
    }

    #[test]
    fn rejects_malformed() {
        for bad in ["key", "= 3", "[open", "x = ", "x = 'single'", "x = [1,"] {
            assert!(TomlDoc::parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn underscored_ints() {
        let doc = TomlDoc::parse("p = 25_560_000").unwrap();
        assert_eq!(doc.get("p").unwrap().as_int(), Some(25_560_000));
    }
}
