//! Micro-benchmark harness for the `benches/` binaries: warmup +
//! repeated timing with median/mean/min reporting, and a tiny aligned
//! table printer shared by the figure benches.

use std::time::{Duration, Instant};

/// Timing summary of one benchmarked operation.
#[derive(Debug, Clone, Copy)]
pub struct Timing {
    /// Median per-iteration time.
    pub median: Duration,
    /// Mean per-iteration time.
    pub mean: Duration,
    /// Fastest iteration.
    pub min: Duration,
    /// Iterations measured.
    pub iters: usize,
}

impl Timing {
    /// ns as f64 of the median.
    pub fn median_ns(&self) -> f64 {
        self.median.as_secs_f64() * 1e9
    }

    /// Throughput in ops/sec given `work` units per iteration.
    pub fn throughput(&self, work: f64) -> f64 {
        work / self.median.as_secs_f64()
    }
}

/// Benchmark `f` with `warmup` unmeasured and `iters` measured runs.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Timing {
    for _ in 0..warmup {
        f();
    }
    let mut samples: Vec<Duration> = Vec::with_capacity(iters.max(1));
    for _ in 0..iters.max(1) {
        let t = Instant::now();
        f();
        samples.push(t.elapsed());
    }
    samples.sort_unstable();
    let min = samples[0];
    let median = samples[samples.len() / 2];
    let mean = samples.iter().sum::<Duration>() / samples.len() as u32;
    Timing {
        median,
        mean,
        min,
        iters: samples.len(),
    }
}

/// Auto-scaled duration formatting (ns/µs/ms/s).
pub fn fmt_duration(d: Duration) -> String {
    let ns = d.as_secs_f64() * 1e9;
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

/// Read a bench-scaling knob from the environment (e.g. `ADA_BENCH_FULL=1`
/// for paper-scale sweeps; default is the quick preset).
pub fn env_flag(name: &str) -> bool {
    std::env::var(name).map(|v| v == "1" || v == "true").unwrap_or(false)
}

/// Read a numeric knob from the environment.
pub fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// Aligned table printer.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with column headers.
    pub fn new(headers: &[&str]) -> Self {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row (stringified cells).
    pub fn row(&mut self, cells: Vec<String>) {
        self.rows.push(cells);
    }

    /// Render with per-column alignment.
    pub fn render(&self) -> String {
        let cols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(String::len).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate().take(cols) {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}", w = w))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (cols - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut x = 0u64;
        let t = bench(1, 5, || {
            for i in 0..1000 {
                x = x.wrapping_add(i);
            }
        });
        assert_eq!(t.iters, 5);
        assert!(t.min <= t.median);
        std::hint::black_box(x);
    }

    #[test]
    fn duration_formatting_scales() {
        assert!(fmt_duration(Duration::from_nanos(500)).contains("ns"));
        assert!(fmt_duration(Duration::from_micros(50)).contains("µs"));
        assert!(fmt_duration(Duration::from_millis(50)).contains("ms"));
        assert!(fmt_duration(Duration::from_secs(5)).contains(" s"));
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["name", "value"]);
        t.row(vec!["a".into(), "1".into()]);
        t.row(vec!["longer".into(), "22".into()]);
        let s = t.render();
        assert!(s.contains("name"));
        assert!(s.lines().count() == 4);
    }

    #[test]
    fn env_knobs_default() {
        assert!(!env_flag("ADA_DEFINITELY_UNSET_FLAG"));
        assert_eq!(env_usize("ADA_DEFINITELY_UNSET_NUM", 7), 7);
    }
}
