//! Minimal CLI argument parsing for the `ada`/`dbench` binaries:
//! `binary <subcommand> [--key value]... [--flag]...`.

use std::collections::BTreeMap;

/// Parsed command line: a subcommand plus `--key value` options and
/// boolean `--flag`s.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// The subcommand (first non-flag argument), if any.
    pub command: Option<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an argument iterator (excluding argv[0]). `known_flags`
    /// lists options that take no value.
    pub fn parse<I: IntoIterator<Item = String>>(
        argv: I,
        known_flags: &[&str],
    ) -> Result<Args, String> {
        let mut args = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if known_flags.contains(&key) {
                    args.flags.push(key.to_string());
                } else {
                    let val = it
                        .next()
                        .ok_or_else(|| format!("option --{key} needs a value"))?;
                    args.options.insert(key.to_string(), val);
                }
            } else if args.command.is_none() {
                args.command = Some(a);
            } else {
                return Err(format!("unexpected positional argument {a:?}"));
            }
        }
        Ok(args)
    }

    /// Option value by key.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(String::as_str)
    }

    /// Option value or default.
    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    /// Typed option with default; errors on unparseable values.
    pub fn get_parse<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("cannot parse --{key} value {v:?}")),
        }
    }

    /// Typed optional option.
    pub fn get_opt<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>, String> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| format!("cannot parse --{key} value {v:?}")),
        }
    }

    /// Whether a boolean flag was passed.
    pub fn has_flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    /// The shared `--threads` option of the `ada`/`dbench` binaries:
    /// worker count for the execution engine's persistent pool (gossip,
    /// fused kernels, variance capture, mean eval). `0` (and the
    /// conventional default) means "all cores" — the resolution happens
    /// in [`crate::exec::ExecEngine::new`], which spawns the workers
    /// exactly once — and results are bit-identical for every value, so
    /// this knob only moves wall-clock time.
    pub fn threads(&self, default: usize) -> Result<usize, String> {
        self.get_parse("threads", default)
    }

    /// Comma-separated list option.
    pub fn get_list<T: std::str::FromStr>(&self, key: &str) -> Result<Option<Vec<T>>, String> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => v
                .split(',')
                .map(|x| {
                    x.trim()
                        .parse()
                        .map_err(|_| format!("cannot parse --{key} element {x:?}"))
                })
                .collect::<Result<Vec<T>, String>>()
                .map(Some),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn parses_subcommand_options_and_flags() {
        let a = Args::parse(argv("run --workers 8 --save --flavor d_ring"), &["save"]).unwrap();
        assert_eq!(a.command.as_deref(), Some("run"));
        assert_eq!(a.get("workers"), Some("8"));
        assert_eq!(a.get("flavor"), Some("d_ring"));
        assert!(a.has_flag("save"));
        assert!(!a.has_flag("other"));
    }

    #[test]
    fn typed_accessors() {
        let a = Args::parse(argv("x --n 16 --scales 8,16,32"), &[]).unwrap();
        assert_eq!(a.get_parse("n", 0usize).unwrap(), 16);
        assert_eq!(a.get_parse("missing", 7usize).unwrap(), 7);
        assert_eq!(
            a.get_list::<usize>("scales").unwrap(),
            Some(vec![8, 16, 32])
        );
        assert_eq!(a.get_opt::<f64>("missing").unwrap(), None);
        assert!(a.get_parse::<usize>("scales", 0).is_err());
    }

    #[test]
    fn threads_option_defaults_and_parses() {
        let a = Args::parse(argv("run --threads 8"), &[]).unwrap();
        assert_eq!(a.threads(0).unwrap(), 8);
        let b = Args::parse(argv("run"), &[]).unwrap();
        assert_eq!(b.threads(4).unwrap(), 4);
        let c = Args::parse(argv("run --threads x"), &[]).unwrap();
        assert!(c.threads(0).is_err());
    }

    #[test]
    fn missing_value_is_an_error() {
        assert!(Args::parse(argv("run --workers"), &[]).is_err());
        assert!(Args::parse(argv("run extra"), &[]).is_err());
    }
}
