//! Deterministic pseudo-random generation: xoshiro256** core with the
//! sampling adapters the data/sharding/init layers need (uniform ranges,
//! Fisher–Yates shuffle, Box–Muller normals, Marsaglia–Tsang gammas →
//! Dirichlet). Same seed ⇒ same stream on every platform, which is what
//! makes the DBench controlled experiments reproducible.

/// xoshiro256** (Blackman & Vigna) seeded via SplitMix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed deterministically from a u64.
    pub fn seed_from_u64(seed: u64) -> Self {
        // SplitMix64 expansion, the reference seeding procedure.
        let mut x = seed;
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let s = [next(), next(), next(), next()];
        Rng { s }
    }

    /// Next raw u64.
    pub fn next_u64(&mut self) -> u64 {
        let [s0, s1, s2, s3] = self.s;
        let result = s1.wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s1 << 17;
        let mut s = [s0, s1, s2, s3];
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        self.s = s;
        result
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        // 53 high bits → [0,1).
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform f32 in [lo, hi).
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Uniform usize in [0, n) (Lemire-reduced; n > 0).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        // 128-bit multiply reduction — negligible bias-free for our sizes.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Bernoulli(p).
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let mut u1 = self.f64();
        if u1 <= f64::MIN_POSITIVE {
            u1 = f64::MIN_POSITIVE;
        }
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Gamma(shape, 1) via Marsaglia–Tsang (with the shape<1 boost).
    pub fn gamma(&mut self, shape: f64) -> f64 {
        assert!(shape > 0.0, "gamma shape must be positive");
        if shape < 1.0 {
            // Boost: Gamma(a) = Gamma(a+1) · U^(1/a).
            let g = self.gamma(shape + 1.0);
            let u = self.f64().max(f64::MIN_POSITIVE);
            return g * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.f64().max(f64::MIN_POSITIVE);
            if u.ln() < 0.5 * x * x + d - d * v + d * v.ln() {
                return d * v;
            }
        }
    }

    /// Dirichlet(α·1) proportions over `n` buckets.
    pub fn dirichlet(&mut self, alpha: f64, n: usize) -> Vec<f64> {
        let mut props: Vec<f64> = (0..n).map(|_| self.gamma(alpha).max(1e-300)).collect();
        let total: f64 = props.iter().sum();
        for p in props.iter_mut() {
            *p /= total;
        }
        props
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::seed_from_u64(7);
        let mut b = Rng::seed_from_u64(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::seed_from_u64(8);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_moments() {
        let mut r = Rng::seed_from_u64(1);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "uniform mean {mean}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::seed_from_u64(2);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = r.below(7);
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets hit");
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut r = Rng::seed_from_u64(3);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>(), "shuffled order differs");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from_u64(4);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "normal mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "normal var {var}");
    }

    #[test]
    fn gamma_mean_matches_shape() {
        let mut r = Rng::seed_from_u64(5);
        for shape in [0.3, 1.0, 4.5] {
            let n = 20_000;
            let mean: f64 = (0..n).map(|_| r.gamma(shape)).sum::<f64>() / n as f64;
            assert!(
                (mean - shape).abs() < 0.08 * shape.max(1.0),
                "gamma({shape}) mean {mean}"
            );
        }
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = Rng::seed_from_u64(6);
        for alpha in [0.05, 1.0, 50.0] {
            let p = r.dirichlet(alpha, 8);
            assert_eq!(p.len(), 8);
            let s: f64 = p.iter().sum();
            assert!((s - 1.0).abs() < 1e-12);
            assert!(p.iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn small_alpha_concentrates_mass() {
        let mut r = Rng::seed_from_u64(7);
        let mut max_sum = 0.0;
        let trials = 200;
        for _ in 0..trials {
            let p = r.dirichlet(0.05, 10);
            max_sum += p.iter().cloned().fold(0.0, f64::max);
        }
        assert!(
            max_sum / trials as f64 > 0.7,
            "alpha=0.05 should concentrate: {}",
            max_sum / trials as f64
        );
    }
}
