//! Self-contained substrate utilities: deterministic RNG, JSON codec,
//! TOML-subset config parser, and a micro-benchmark harness.
//!
//! The coordinator is deliberately dependency-free (beyond the PJRT
//! bindings): everything a distributed-training launcher needs from the
//! usual crates.io stack is implemented here, tested, and sized to this
//! project's needs.

pub mod bench;
pub mod cli;
pub mod json;
pub mod matrix;
pub mod params;
pub mod rng;
pub mod tomlmini;

/// A unique scratch directory under the system temp dir (test helper).
/// The caller owns cleanup; tests lean on the OS tmp reaper.
pub fn scratch_dir(tag: &str) -> std::io::Result<std::path::PathBuf> {
    use std::time::{SystemTime, UNIX_EPOCH};
    let nanos = SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_nanos())
        .unwrap_or(0);
    let pid = std::process::id();
    let dir = std::env::temp_dir().join(format!("ada_{tag}_{pid}_{nanos}"));
    std::fs::create_dir_all(&dir)?;
    Ok(dir)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scratch_dirs_are_unique_and_exist() {
        let a = scratch_dir("t").unwrap();
        let b = scratch_dir("t").unwrap();
        assert_ne!(a, b);
        assert!(a.is_dir() && b.is_dir());
        let _ = std::fs::remove_dir_all(&a);
        let _ = std::fs::remove_dir_all(&b);
    }
}
