//! # ada-dist — adaptive decentralized data-parallel training
//!
//! Reproduction of *“Scaling Up Data Parallelism in Decentralized Deep
//! Learning”* (Xie, Yin, Zhou, Oral, Wang — CS.LG 2025): the **DBench**
//! benchmarking framework for centralized/decentralized data-parallel DNN
//! training, and **Ada**, an adaptive decentralized SGD that decays the
//! coordination number of a ring-lattice communication graph across epochs.
//!
//! ## Architecture (three layers)
//!
//! * **L3 (this crate)** — the coordinator: communication graphs and mixing
//!   matrices ([`graph`]), adaptive topology policies with their own
//!   name registry ([`topology`]), the
//!   gossip mixing engine ([`gossip`]) — with a compressed exchange
//!   path (bf16/f16 codecs, top-k error feedback, [`compress`]) —
//!   fanned out over the deterministic
//!   thread-pool execution engine ([`exec`]), the n-worker decentralized
//!   training loop ([`coordinator`]) — a `TrainSession` builder over an
//!   open strategy registry (`coordinator::strategy`) and observer hooks
//!   (`coordinator::observer`) —, variance metrics and ranking analysis
//!   ([`metrics`]), the DBench experiment runner ([`dbench`]) with its
//!   resumable/parallel `SessionPlan` pipeline, the multi-tenant
//!   experiment service ([`serve`]) that runs DBench behind an HTTP
//!   API with fair-share scheduling and a content-addressed result
//!   store, and a Summit-like analytic network cost model ([`simnet`]).
//! * **L2 (build-time Python)** — JAX model definitions (`python/compile/`)
//!   AOT-lowered to HLO text artifacts, loaded and executed from Rust via
//!   the PJRT C API ([`runtime`]).
//! * **L1 (build-time Python)** — Pallas kernels for the gossip mixing
//!   matmul and the fused SGD update, lowered into the same HLO artifacts.
//!
//! Python never runs on the training path: after `make artifacts` the Rust
//! binary is self-contained.
//!
//! ## Quick start
//!
//! ```no_run
//! use ada_dist::graph::{CommGraph, GraphKind};
//! use ada_dist::topology::{AdaSchedule, TopologyPolicy};
//!
//! // A 16-node torus mixing matrix:
//! let g = CommGraph::build(GraphKind::Torus, 16).unwrap();
//! assert_eq!(g.degree(), 4);
//!
//! // Ada's adaptive ring lattice (Algorithm 1): k0 = 8, gamma_k = 0.5.
//! let ada = AdaSchedule::new(16, 8, 0.5);
//! let g0 = ada.graph_for_epoch(0).unwrap();   // near-complete
//! let g9 = ada.graph_for_epoch(20).unwrap();  // decayed to k = 2
//! assert!(g0.degree() > g9.degree());
//! ```

pub mod compress;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod dbench;
pub mod error;
pub mod exec;
pub mod gossip;
pub mod graph;
pub mod metrics;
pub mod optim;
pub mod runtime;
pub mod serve;
pub mod simnet;
pub mod topology;
pub mod util;

pub use error::{AdaError, Result};
pub use util::matrix::ReplicaMatrix;
