//! Artifact manifests — the PJRT-independent half of the runtime layer.
//!
//! These types describe the AOT artifacts `python/compile/aot.py`
//! writes. They compile on every feature set (the coordinator and
//! DBench specs consume them to size datasets and models); actually
//! *executing* the artifacts needs the `pjrt` feature.

use crate::error::{AdaError, Result};
use crate::util::json::Value;
use std::path::Path;

/// Task family of a model (decides how `eval`'s outputs are interpreted).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ModelKind {
    /// `eval → (loss_sum, correct_count)`; metric = accuracy.
    Classification,
    /// `eval → (nll_sum, token_count)`; metric = perplexity.
    Lm,
}

/// `manifest.json` written next to each model's artifacts.
#[derive(Debug, Clone)]
pub struct ModelManifest {
    /// Model name (artifact directory name).
    pub name: String,
    /// Task family.
    pub kind: ModelKind,
    /// Flat parameter-vector length.
    pub param_count: usize,
    /// Feature width per example.
    pub x_dim: usize,
    /// Target width per example (1 for classification).
    pub y_dim: usize,
    /// Training batch rows the `step` executable was lowered for.
    pub batch_size: usize,
    /// Eval batch rows the `eval` executable was lowered for.
    pub eval_batch_size: usize,
    /// Classes (classification) or vocabulary size (LM).
    pub num_outputs: usize,
    /// Flat-vector layer boundaries `[start, end)` — used by LARS and by
    /// the per-tensor variance analysis (Fig. 4 tracks single tensors).
    pub layer_ranges: Vec<(usize, usize)>,
    /// Artifact filenames relative to the model directory.
    pub files: ManifestFiles,
}

/// Artifact filenames of one model.
#[derive(Debug, Clone)]
pub struct ManifestFiles {
    /// `init(seed:i32) → (params,)`.
    pub init: String,
    /// `step(params, x, y, lr) → (params', loss)`.
    pub step: String,
    /// `eval(params, x, y) → (loss_sum, metric_sum)`.
    pub eval: String,
}

impl ModelManifest {
    /// Read and parse a manifest file.
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path).map_err(|e| {
            AdaError::Runtime(format!(
                "cannot read {} ({e}) — run `make artifacts` first",
                path.display()
            ))
        })?;
        Self::from_json_text(&text)
            .map_err(|e| AdaError::Runtime(format!("bad manifest {}: {e}", path.display())))
    }

    /// Parse from JSON text (the format `aot.py` writes).
    pub fn from_json_text(text: &str) -> Result<Self> {
        let v = Value::parse(text)?;
        let kind = match v.str_field("kind")? {
            "classification" => ModelKind::Classification,
            "lm" => ModelKind::Lm,
            other => {
                return Err(AdaError::Config(format!("unknown model kind {other:?}")))
            }
        };
        let files = v
            .get("files")
            .ok_or_else(|| AdaError::Config("missing 'files'".into()))?;
        let layer_ranges = v
            .arr_field("layer_ranges")?
            .iter()
            .map(|pair| match pair {
                Value::Arr(ab) if ab.len() == 2 => {
                    match (ab[0].as_f64(), ab[1].as_f64()) {
                        (Some(a), Some(b)) => Ok((a as usize, b as usize)),
                        _ => Err(AdaError::Config("bad layer range".into())),
                    }
                }
                _ => Err(AdaError::Config("bad layer range".into())),
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(ModelManifest {
            name: v.str_field("name")?.to_string(),
            kind,
            param_count: v.usize_field("param_count")?,
            x_dim: v.usize_field("x_dim")?,
            y_dim: v.usize_field("y_dim")?,
            batch_size: v.usize_field("batch_size")?,
            eval_batch_size: v.usize_field("eval_batch_size")?,
            num_outputs: v.usize_field("num_outputs")?,
            layer_ranges,
            files: ManifestFiles {
                init: files.str_field("init")?.to_string(),
                step: files.str_field("step")?.to_string(),
                eval: files.str_field("eval")?.to_string(),
            },
        })
    }

    /// JSON encoding (inverse of [`ModelManifest::from_json_text`]).
    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("name", Value::Str(self.name.clone())),
            (
                "kind",
                Value::Str(
                    match self.kind {
                        ModelKind::Classification => "classification",
                        ModelKind::Lm => "lm",
                    }
                    .into(),
                ),
            ),
            ("param_count", Value::Num(self.param_count as f64)),
            ("x_dim", Value::Num(self.x_dim as f64)),
            ("y_dim", Value::Num(self.y_dim as f64)),
            ("batch_size", Value::Num(self.batch_size as f64)),
            ("eval_batch_size", Value::Num(self.eval_batch_size as f64)),
            ("num_outputs", Value::Num(self.num_outputs as f64)),
            (
                "layer_ranges",
                Value::Arr(
                    self.layer_ranges
                        .iter()
                        .map(|&(a, b)| {
                            Value::Arr(vec![Value::Num(a as f64), Value::Num(b as f64)])
                        })
                        .collect(),
                ),
            ),
            (
                "files",
                Value::obj(vec![
                    ("init", Value::Str(self.files.init.clone())),
                    ("step", Value::Str(self.files.step.clone())),
                    ("eval", Value::Str(self.files.eval.clone())),
                ]),
            ),
        ])
    }
}

/// Result of one local training step.
#[derive(Debug, Clone, Copy)]
pub struct StepOutput {
    /// Mean loss of the step's batch.
    pub loss: f32,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_roundtrip() {
        let m = ModelManifest {
            name: "mlp".into(),
            kind: ModelKind::Classification,
            param_count: 100,
            x_dim: 32,
            y_dim: 1,
            batch_size: 16,
            eval_batch_size: 64,
            num_outputs: 10,
            layer_ranges: vec![(0, 80), (80, 100)],
            files: ManifestFiles {
                init: "init.hlo.txt".into(),
                step: "step.hlo.txt".into(),
                eval: "eval.hlo.txt".into(),
            },
        };
        let json = m.to_json().to_string();
        let back = ModelManifest::from_json_text(&json).unwrap();
        assert_eq!(back.param_count, 100);
        assert_eq!(back.kind, ModelKind::Classification);
        assert_eq!(back.layer_ranges, vec![(0, 80), (80, 100)]);
        assert_eq!(back.files.step, "step.hlo.txt");
    }

    #[test]
    fn missing_manifest_mentions_make_artifacts() {
        let err = ModelManifest::load(Path::new("/no/such/manifest.json")).unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }
}
