//! PJRT runtime: loads the AOT-compiled HLO artifacts produced by
//! `python/compile/aot.py` and executes them on the CPU PJRT client.
//!
//! Interchange format is **HLO text** (not serialized `HloModuleProto`):
//! jax ≥ 0.5 emits protos with 64-bit instruction ids which the crate's
//! xla_extension 0.5.1 rejects; the text parser reassigns ids and
//! round-trips cleanly (see /opt/xla-example/README.md).
//!
//! Python runs only at `make artifacts` time; everything here is pure
//! Rust + the PJRT C API on the training path.
//!
//! ## The `pjrt` feature
//!
//! The PJRT/XLA bindings are gated behind the off-by-default `pjrt`
//! cargo feature so the default build is pure-std (the coordinator,
//! gossip engine, surrogates, DBench and all tier-1 tests run without
//! any external dependency). Manifest types ([`ModelKind`],
//! [`ModelManifest`], [`StepOutput`]) are always available; executing
//! artifacts ([`PjRtRuntime`], [`ModelBundle`], [`GossipKernel`])
//! requires `--features pjrt` and a real `xla` crate (the in-tree
//! `rust/xla-stub` placeholder satisfies the build; point the `xla`
//! dependency at a vendored `xla_extension` checkout to actually run).

mod manifest;

pub use manifest::{ManifestFiles, ModelKind, ModelManifest, StepOutput};

#[cfg(feature = "pjrt")]
mod bundle;
#[cfg(feature = "pjrt")]
mod gossip_kernel;

#[cfg(feature = "pjrt")]
pub use bundle::ModelBundle;
#[cfg(feature = "pjrt")]
pub use gossip_kernel::GossipKernel;

#[cfg(feature = "pjrt")]
pub use pjrt_impl::*;

#[cfg(feature = "pjrt")]
mod pjrt_impl {
    use crate::error::{AdaError, Result};
    use std::path::{Path, PathBuf};

    impl From<xla::Error> for AdaError {
        fn from(e: xla::Error) -> Self {
            AdaError::Runtime(e.to_string())
        }
    }

    /// A PJRT client plus the artifact root it loads from.
    pub struct PjRtRuntime {
        client: xla::PjRtClient,
        artifact_dir: PathBuf,
    }

    impl std::fmt::Debug for PjRtRuntime {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            f.debug_struct("PjRtRuntime")
                .field("artifact_dir", &self.artifact_dir)
                .finish_non_exhaustive()
        }
    }

    impl PjRtRuntime {
        /// CPU PJRT client rooted at `artifact_dir` (usually `artifacts/`).
        pub fn cpu(artifact_dir: impl Into<PathBuf>) -> Result<Self> {
            let client = xla::PjRtClient::cpu()?;
            Ok(PjRtRuntime {
                client,
                artifact_dir: artifact_dir.into(),
            })
        }

        /// Platform string of the underlying client.
        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Artifact root.
        pub fn artifact_dir(&self) -> &Path {
            &self.artifact_dir
        }

        /// Load + compile one HLO-text artifact (path relative to the
        /// artifact root unless absolute).
        pub fn load(&self, rel: impl AsRef<Path>) -> Result<HloExecutable> {
            let rel = rel.as_ref();
            let path = if rel.is_absolute() {
                rel.to_path_buf()
            } else {
                self.artifact_dir.join(rel)
            };
            if !path.exists() {
                return Err(AdaError::Runtime(format!(
                    "artifact {} not found — run `make artifacts` first",
                    path.display()
                )));
            }
            let proto = xla::HloModuleProto::from_text_file(&path)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            Ok(HloExecutable {
                exe,
                path: path.clone(),
            })
        }

        /// Load a [`super::ModelBundle`] by model name (directory under
        /// the root).
        pub fn load_model(&self, name: &str) -> Result<super::ModelBundle> {
            super::ModelBundle::load(self, name)
        }
    }

    /// One compiled HLO executable.
    pub struct HloExecutable {
        pub(super) exe: xla::PjRtLoadedExecutable,
        pub(super) path: PathBuf,
    }

    impl std::fmt::Debug for HloExecutable {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            f.debug_struct("HloExecutable").field("path", &self.path).finish()
        }
    }

    impl HloExecutable {
        /// Execute with the given input literals. The artifacts are lowered
        /// with `return_tuple=True`, so the single output literal is a tuple;
        /// this unwraps it into its elements.
        pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
            let outs = self.exe.execute::<xla::Literal>(inputs)?;
            let lit = outs
                .first()
                .and_then(|d| d.first())
                .ok_or_else(|| AdaError::Runtime("executable returned no outputs".into()))?
                .to_literal_sync()?;
            Ok(lit.to_tuple()?)
        }

        /// Source artifact path.
        pub fn path(&self) -> &Path {
            &self.path
        }
    }

    /// f32 literal of shape `dims` from a flat slice.
    pub fn lit_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
        let expect: i64 = dims.iter().product();
        if expect as usize != data.len() {
            return Err(AdaError::Runtime(format!(
                "literal shape {dims:?} needs {expect} elements, got {}",
                data.len()
            )));
        }
        Ok(xla::Literal::vec1(data).reshape(dims)?)
    }

    /// i32 literal of shape `dims` from a flat slice.
    pub fn lit_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
        let expect: i64 = dims.iter().product();
        if expect as usize != data.len() {
            return Err(AdaError::Runtime(format!(
                "literal shape {dims:?} needs {expect} elements, got {}",
                data.len()
            )));
        }
        Ok(xla::Literal::vec1(data).reshape(dims)?)
    }

    /// Rank-0 f32 literal.
    pub fn lit_scalar_f32(v: f32) -> Result<xla::Literal> {
        Ok(xla::Literal::vec1(&[v]).reshape(&[])?)
    }

    /// Rank-0 i32 literal.
    pub fn lit_scalar_i32(v: i32) -> Result<xla::Literal> {
        Ok(xla::Literal::vec1(&[v]).reshape(&[])?)
    }

    /// Extract a literal's contents as `Vec<f32>`.
    pub fn to_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
        Ok(lit.to_vec::<f32>()?)
    }

    /// Extract a rank-0/rank-1 literal's first f32.
    pub fn scalar_f32(lit: &xla::Literal) -> Result<f32> {
        let v = lit.to_vec::<f32>()?;
        v.first()
            .copied()
            .ok_or_else(|| AdaError::Runtime("empty literal where scalar expected".into()))
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        #[test]
        fn lit_shape_validation() {
            assert!(lit_f32(&[1.0, 2.0], &[2]).is_ok());
            assert!(lit_f32(&[1.0, 2.0], &[3]).is_err());
            assert!(lit_i32(&[1, 2, 3, 4], &[2, 2]).is_ok());
            assert!(lit_i32(&[1], &[2]).is_err());
        }

        #[test]
        fn missing_artifact_is_a_clear_error() {
            let rt = match PjRtRuntime::cpu("/nonexistent-artifacts") {
                Ok(rt) => rt,
                Err(e) => panic!("cpu client failed: {e}"),
            };
            let err = rt.load("nope.hlo.txt").unwrap_err();
            assert!(err.to_string().contains("make artifacts"), "{err}");
        }
    }
}
