//! Model bundles: the trio of HLO executables (`init`, `step`, `eval`)
//! plus the JSON manifest that `python/compile/aot.py` writes per model.
//! Compiled only with the `pjrt` feature; the manifest types themselves
//! live in [`super::manifest`] and are always available.

use super::manifest::{ModelManifest, StepOutput};
use super::{lit_f32, lit_i32, lit_scalar_f32, lit_scalar_i32, scalar_f32, to_f32, HloExecutable, PjRtRuntime};
use crate::data::Batch;
use crate::error::{AdaError, Result};
use std::path::Path;

/// A loaded model: manifest + compiled executables.
#[derive(Debug)]
pub struct ModelBundle {
    /// The manifest the artifacts were built with.
    pub manifest: ModelManifest,
    init: HloExecutable,
    step: HloExecutable,
    eval: HloExecutable,
}

impl ModelBundle {
    /// Load `artifacts/<name>/manifest.json` and compile its executables.
    pub fn load(rt: &PjRtRuntime, name: &str) -> Result<Self> {
        let manifest =
            Self::read_manifest(&rt.artifact_dir().join(name).join("manifest.json"))?;
        let rel = std::path::Path::new(name);
        let init = rt.load(rel.join(&manifest.files.init))?;
        let step = rt.load(rel.join(&manifest.files.step))?;
        let eval = rt.load(rel.join(&manifest.files.eval))?;
        Ok(ModelBundle {
            manifest,
            init,
            step,
            eval,
        })
    }

    /// Parse a manifest file (alias of [`ModelManifest::load`]).
    pub fn read_manifest(path: &Path) -> Result<ModelManifest> {
        ModelManifest::load(path)
    }

    /// Initialize a fresh flat parameter vector from `seed`.
    pub fn init_params(&self, seed: i32) -> Result<Vec<f32>> {
        let outs = self.init.run(&[lit_scalar_i32(seed)?])?;
        let params = to_f32(&outs[0])?;
        if params.len() != self.manifest.param_count {
            return Err(AdaError::Runtime(format!(
                "init returned {} params, manifest says {}",
                params.len(),
                self.manifest.param_count
            )));
        }
        Ok(params)
    }

    /// One fused local step (fwd + bwd + SGD update, one PJRT call):
    /// `params` is updated in place; returns the batch loss.
    pub fn local_step(&self, params: &mut [f32], batch: &Batch, lr: f32) -> Result<StepOutput> {
        let m = &self.manifest;
        if batch.batch_size != m.batch_size {
            return Err(AdaError::Runtime(format!(
                "step lowered for batch {}, got {}",
                m.batch_size, batch.batch_size
            )));
        }
        let x = lit_f32(&batch.x, &[m.batch_size as i64, m.x_dim as i64])?;
        let y = if m.y_dim == 1 {
            lit_i32(&batch.y, &[m.batch_size as i64])?
        } else {
            lit_i32(&batch.y, &[m.batch_size as i64, m.y_dim as i64])?
        };
        let p = lit_f32(params, &[m.param_count as i64])?;
        let outs = self.step.run(&[p, x, y, lit_scalar_f32(lr)?])?;
        let updated = to_f32(&outs[0])?;
        if updated.len() != params.len() {
            return Err(AdaError::Runtime(format!(
                "step returned {} params, expected {}",
                updated.len(),
                params.len()
            )));
        }
        params.copy_from_slice(&updated);
        Ok(StepOutput {
            loss: scalar_f32(&outs[1])?,
        })
    }

    /// Evaluate on one eval batch: returns `(loss_sum, metric_sum)` in the
    /// manifest's convention (correct count / token NLL sums).
    pub fn eval_batch(&self, params: &[f32], batch: &Batch) -> Result<(f32, f32)> {
        let m = &self.manifest;
        if batch.batch_size != m.eval_batch_size {
            return Err(AdaError::Runtime(format!(
                "eval lowered for batch {}, got {}",
                m.eval_batch_size, batch.batch_size
            )));
        }
        let x = lit_f32(&batch.x, &[m.eval_batch_size as i64, m.x_dim as i64])?;
        let y = if m.y_dim == 1 {
            lit_i32(&batch.y, &[m.eval_batch_size as i64])?
        } else {
            lit_i32(&batch.y, &[m.eval_batch_size as i64, m.y_dim as i64])?
        };
        let p = lit_f32(params, &[m.param_count as i64])?;
        let outs = self.eval.run(&[p, x, y])?;
        Ok((scalar_f32(&outs[0])?, scalar_f32(&outs[1])?))
    }
}
