//! The L1 Pallas `gossip_mix` kernel, loaded as an HLO executable.
//!
//! `python/compile/kernels/gossip_mix.py` writes one artifact per
//! `(n, param_count)` pair it was asked to lower
//! (`artifacts/gossip/mix_n{n}_p{p}.hlo.txt`) plus an index
//! (`artifacts/gossip/manifest.json`). The kernel computes `Θ' = W Θ`
//! with W the `n × n` mixing matrix and Θ the stacked `n × p` replica
//! parameters — the paper's averaging step as one MXU-shaped matmul
//! (DESIGN.md §Hardware-Adaptation).

use super::{lit_f32, to_f32, HloExecutable, PjRtRuntime};
use crate::error::{AdaError, Result};
use crate::graph::CommGraph;
use crate::util::json::Value;

/// Index of the lowered gossip kernels.
#[derive(Debug, Clone)]
struct GossipManifest {
    /// `(n, p)` pairs with artifacts available.
    variants: Vec<(usize, usize)>,
}

impl GossipManifest {
    fn from_json_text(text: &str) -> Result<Self> {
        let v = Value::parse(text)?;
        let variants = v
            .arr_field("variants")?
            .iter()
            .map(|pair| match pair {
                Value::Arr(np) if np.len() == 2 => match (np[0].as_f64(), np[1].as_f64()) {
                    (Some(n), Some(p)) => Ok((n as usize, p as usize)),
                    _ => Err(AdaError::Config("bad gossip variant".into())),
                },
                _ => Err(AdaError::Config("bad gossip variant".into())),
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(GossipManifest { variants })
    }
}

/// A compiled gossip-mix kernel for fixed `(n, p)`.
#[derive(Debug)]
pub struct GossipKernel {
    exe: HloExecutable,
    n: usize,
    p: usize,
}

impl GossipKernel {
    /// Load the kernel for exactly `(n, param_count)`, erroring with the
    /// available variants if missing.
    pub fn load(rt: &PjRtRuntime, n: usize, param_count: usize) -> Result<Self> {
        let dir = rt.artifact_dir().join("gossip");
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).map_err(|e| {
            AdaError::Runtime(format!(
                "cannot read {} ({e}) — run `make artifacts` first",
                manifest_path.display()
            ))
        })?;
        let manifest = GossipManifest::from_json_text(&text)
            .map_err(|e| AdaError::Runtime(format!("bad gossip manifest: {e}")))?;
        if !manifest.variants.contains(&(n, param_count)) {
            return Err(AdaError::Runtime(format!(
                "no gossip kernel lowered for (n={n}, p={param_count}); \
                 available: {:?}",
                manifest.variants
            )));
        }
        let exe = rt.load(
            std::path::Path::new("gossip").join(format!("mix_n{n}_p{param_count}.hlo.txt")),
        )?;
        Ok(GossipKernel {
            exe,
            n,
            p: param_count,
        })
    }

    /// Replica count the kernel was lowered for.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Parameter count the kernel was lowered for.
    pub fn param_count(&self) -> usize {
        self.p
    }

    /// One gossip round through the kernel: `replicas[i] ← Σ_j W_ij θ_j`.
    /// Equivalent to [`crate::gossip::GossipEngine::mix`] (tested against
    /// it in `rust/tests/hlo_integration.rs`).
    pub fn mix(&self, graph: &CommGraph, replicas: &mut [Vec<f32>]) -> Result<()> {
        if graph.n() != self.n || replicas.len() != self.n {
            return Err(AdaError::Runtime(format!(
                "kernel lowered for n={}, got graph n={} / {} replicas",
                self.n,
                graph.n(),
                replicas.len()
            )));
        }
        let w = graph.dense_mixing();
        let mut theta = Vec::with_capacity(self.n * self.p);
        for r in replicas.iter() {
            if r.len() != self.p {
                return Err(AdaError::Runtime(format!(
                    "kernel lowered for p={}, replica has {}",
                    self.p,
                    r.len()
                )));
            }
            theta.extend_from_slice(r);
        }
        let w_lit = lit_f32(&w, &[self.n as i64, self.n as i64])?;
        let t_lit = lit_f32(&theta, &[self.n as i64, self.p as i64])?;
        let outs = self.exe.run(&[w_lit, t_lit])?;
        let mixed = to_f32(&outs[0])?;
        for (i, r) in replicas.iter_mut().enumerate() {
            r.copy_from_slice(&mixed[i * self.p..(i + 1) * self.p]);
        }
        Ok(())
    }
}
