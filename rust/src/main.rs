//! `ada` — the launcher CLI: single training runs, graph inspection,
//! communication-cost analysis, and artifact smoke checks.
//!
//! ```text
//! ada run    --workload mlp --flavor d_ring --workers 8 --epochs 4
//! ada run    --workload mlp --flavor d_ring --threads 8 --fused   # fast path
//! ada run    --workload hlo:mlp --flavor ada --workers 8      # PJRT path
//! ada graphs --n 96                                           # Table 1
//! ada simnet --n 1008 --params 25560000                       # comm cost
//! ada check-artifacts                                         # PJRT smoke
//! ```

use ada_dist::config::LauncherConfig;
use ada_dist::coordinator::SgdFlavor;
use ada_dist::dbench::{format_table, ExperimentSpec, SessionPlan, StrategyRef, TopologyRef, Workload};
use ada_dist::graph::{CommGraph, GraphKind};
use ada_dist::simnet::{ClusterSpec, SimNet};
use ada_dist::util::cli::Args;

type CliResult = Result<(), Box<dyn std::error::Error>>;

const USAGE: &str = "\
ada <command> [options]
  run              train one workload with one SGD flavor
    --workload softmax|mlp|mlp_large|bigram|hlo:<name>   (default softmax)
    --flavor c_complete|d_complete|d_ring|d_torus|d_exponential|ada|one_peer|var_adaptive
    --workers N --epochs N --k0 N --gamma-k F --seed N --record PATH
    --topology name[:k=v,...]   override the flavor's communication-graph
                     policy with one from the topology registry (see
                     `ada topologies`); decentralized flavors only
    --strategy name[:k=v,...]   train a registry strategy instead of a
                     flavor (see `ada strategies`), e.g.
                     compressed_gossip:codec=bf16,k=65536 — overrides
                     --flavor
    --threads N      persistent worker-pool fan-out for the gossip/fused
                     kernels and metric capture (0 = all cores; default
                     from launcher config; bit-identical results)
    --fused          fused gossip+SGD execution (combine-then-adapt order)
    --pipeline       overlap gossip communication with local compute
                     bucket-by-bucket (bit-identical to the phased path)
    --bucket-kb N    pipeline bucket width in KB (0 = default 256 KB)
    --faults k=v,... deterministic fault plan (seed, drop_prob,
                     straggler_prob, straggler_iters, straggler_slowdown,
                     link_jitter, crash=n@from:to;.., recover_dir);
                     decentralized flavors only
    --staleness-bound N  fault-injected gossip mixes peer rows up to N
                     rounds old (0 = only this round's deliveries)
  strategies       list the registered SGD strategy names (open registry)
  topologies       list the registered topology policy names
  graphs           print Table 1 for --n nodes (default 96)
  simnet           Summit-model comm costs: --n nodes --params P
  check-artifacts  load every artifact and smoke-test via PJRT (needs
                   a build with `--features pjrt`)
  (global) --config PATH   launcher TOML (artifact_dir/output_dir/threads)";

pub(crate) fn parse_flavor(
    name: &str,
    workers: usize,
    k0: Option<usize>,
    gamma_k: f64,
) -> Result<SgdFlavor, String> {
    Ok(match name {
        "c_complete" => SgdFlavor::CentralizedComplete,
        "d_complete" => SgdFlavor::DecentralizedComplete,
        "d_ring" => SgdFlavor::DecentralizedRing,
        "d_torus" => SgdFlavor::DecentralizedTorus,
        "d_exponential" => SgdFlavor::DecentralizedExponential,
        "ada" => SgdFlavor::Ada {
            k0: k0.unwrap_or(workers.saturating_sub(1).max(2)),
            gamma_k,
        },
        "one_peer" => SgdFlavor::OnePeer,
        "var_adaptive" => SgdFlavor::VarianceAdaptive {
            k0: k0.unwrap_or(workers.saturating_sub(1).max(2)),
            step: 2,
            threshold: 0.002,
            patience: 1,
        },
        other => return Err(format!("unknown flavor {other}")),
    })
}

fn parse_workload(name: &str, artifact_dir: &std::path::Path) -> Result<Workload, String> {
    Ok(match name {
        "softmax" => ExperimentSpec::resnet20_analog().workload,
        "mlp" => ExperimentSpec::densenet_analog().workload,
        "mlp_large" => ExperimentSpec::resnet50_analog().workload,
        "bigram" => ExperimentSpec::lstm_analog().workload,
        other if other.starts_with("hlo:") => Workload::Hlo {
            name: other.trim_start_matches("hlo:").to_string(),
            n_examples: 4096,
            artifact_dir: artifact_dir.display().to_string(),
        },
        other => {
            return Err(format!(
                "unknown workload {other} (softmax|mlp|mlp_large|bigram|hlo:<name>)"
            ))
        }
    })
}

fn main() -> CliResult {
    let args = Args::parse(std::env::args().skip(1), &["help", "fused", "pipeline"])
        .map_err(|e| format!("{e}\n\n{USAGE}"))?;
    let cfg = match args.get("config") {
        Some(p) => LauncherConfig::from_file(std::path::Path::new(p))
            .map_err(|e| format!("loading launcher config: {e}"))?,
        None => LauncherConfig::default(),
    };

    match args.command.as_deref() {
        Some("run") => cmd_run(&args, &cfg),
        Some("strategies") => {
            for name in ada_dist::coordinator::strategy::registry().names() {
                println!("{name}");
            }
            Ok(())
        }
        Some("topologies") => {
            for name in ada_dist::topology::registry().names() {
                println!("{name}");
            }
            Ok(())
        }
        Some("graphs") => cmd_graphs(&args),
        Some("simnet") => cmd_simnet(&args),
        Some("check-artifacts") => cmd_check_artifacts(&cfg),
        _ => {
            println!("{USAGE}");
            Ok(())
        }
    }
}

fn cmd_run(args: &Args, cfg: &LauncherConfig) -> CliResult {
    let workers: usize = args.get_parse("workers", 8)?;
    let epochs: usize = args.get_parse("epochs", 6)?;
    let k0: Option<usize> = args.get_opt("k0")?;
    let gamma_k: f64 = args.get_parse("gamma-k", 1.0)?;
    let seed: u64 = args.get_parse("seed", 42)?;
    let flavor = parse_flavor(args.get_or("flavor", "ada"), workers, k0, gamma_k)?;
    let workload = parse_workload(args.get_or("workload", "softmax"), &cfg.artifact_dir)?;

    let mut spec = ExperimentSpec::resnet20_analog();
    spec.workload = workload;
    spec.epochs = epochs;
    spec.seed = seed;
    spec.scales = vec![workers];
    spec.flavors = vec![flavor];
    spec.threads = args.threads(cfg.threads)?;
    spec.fused = args.has_flag("fused");
    spec.pipeline = args.has_flag("pipeline");
    spec.bucket_kb = args.get_parse("bucket-kb", 0)?;
    if let Some(kv) = args.get("faults") {
        let table = ada_dist::util::params::ParamTable::parse_kv(kv)?;
        spec.faults = Some(ada_dist::simnet::FaultPlan::from_table(&table)?);
    }
    spec.staleness_bound = args.get_parse("staleness-bound", 0)?;
    if let Some(t) = args.get("topology") {
        // Resolved by name through the topology registry; `ada
        // topologies` lists the choices. C_complete stays centralized.
        spec.topology = Some(TopologyRef::parse(t)?);
    }
    if let Some(s) = args.get("strategy") {
        // Resolved by name through the strategy registry; `ada
        // strategies` lists the choices. Replaces the flavor.
        spec.strategies = vec![StrategyRef::parse(s)?];
        spec.flavors = vec![];
    }
    let mut plan = SessionPlan::from_spec(&spec);
    plan.cells[0].config.record_path = args.get("record").map(std::path::PathBuf::from);
    let t0 = std::time::Instant::now();
    let cells = plan.run()?;
    println!(
        "{}",
        format_table(
            &format!(
                "{} @ {workers} workers ({:.1?})",
                spec.workload.name(),
                t0.elapsed()
            ),
            &cells
        )
    );
    Ok(())
}

fn cmd_graphs(args: &Args) -> CliResult {
    let n: usize = args.get_parse("n", 96)?;
    println!(
        "{:<22} {:>8} {:>10} {:>10} {:>14} {:>10}",
        "graph", "degree", "edges", "directed", "spectral gap", "regular"
    );
    for kind in [
        GraphKind::Ring,
        GraphKind::Torus,
        GraphKind::RingLattice { k: 3 },
        GraphKind::AdaLattice { k: 6 },
        GraphKind::Exponential,
        GraphKind::Complete,
    ] {
        match CommGraph::build(kind, n) {
            Ok(g) => println!(
                "{:<22} {:>8} {:>10} {:>10} {:>14.6} {:>10}",
                kind.to_string(),
                g.degree(),
                g.edge_count(),
                g.is_directed(),
                g.spectral_gap(),
                g.is_regular()
            ),
            Err(e) => println!("{:<22} {e}", kind.to_string()),
        }
    }
    Ok(())
}

fn cmd_simnet(args: &Args) -> CliResult {
    let n: usize = args.get_parse("n", 1008)?;
    let params: usize = args.get_parse("params", 25_560_000)?;
    let net = SimNet::new(ClusterSpec::summit());
    println!("Summit model: {n} GPUs, {params} params ({} nodes)", n.div_ceil(6));
    println!(
        "{:<22} {:>14} {:>16} {:>16}",
        "graph", "round (ms)", "total MB", "inter-node MB"
    );
    for kind in [
        GraphKind::Ring,
        GraphKind::Torus,
        GraphKind::Exponential,
        GraphKind::AdaLattice { k: 112.min(n.saturating_sub(1)).max(2) },
        GraphKind::Complete,
    ] {
        if let Ok(g) = CommGraph::build(kind, n) {
            let c = net.gossip_round(&g, params);
            println!(
                "{:<22} {:>14.3} {:>16.1} {:>16.1}",
                kind.to_string(),
                c.time_s * 1e3,
                c.total_bytes as f64 / 1e6,
                c.inter_node_bytes as f64 / 1e6
            );
        }
    }
    let ar = net.allreduce(n, params);
    println!(
        "{:<22} {:>14.3} {:>16.1} {:>16.1}   (C_complete)",
        "ring-allreduce",
        ar.time_s * 1e3,
        ar.total_bytes as f64 / 1e6,
        ar.inter_node_bytes as f64 / 1e6
    );
    Ok(())
}

#[cfg(feature = "pjrt")]
fn cmd_check_artifacts(cfg: &LauncherConfig) -> CliResult {
    use ada_dist::runtime::PjRtRuntime;
    let rt = PjRtRuntime::cpu(&cfg.artifact_dir)?;
    println!("PJRT platform: {}", rt.platform());
    let mut ok = 0;
    for entry in std::fs::read_dir(&cfg.artifact_dir)
        .map_err(|e| format!("reading artifact dir — run `make artifacts` ({e})"))?
    {
        let entry = entry?;
        let manifest = entry.path().join("manifest.json");
        if !manifest.exists() {
            continue;
        }
        let name = entry.file_name().to_string_lossy().to_string();
        if name == "gossip" {
            // Kernel manifests have their own schema; smoke-tested below
            // via GossipKernel in the integration tests.
            continue;
        }
        let bundle = rt.load_model(&name)?;
        let params = bundle.init_params(0)?;
        println!(
            "  {name}: {} params, kind {:?} — OK",
            params.len(),
            bundle.manifest.kind
        );
        ok += 1;
    }
    if ok == 0 {
        return Err(format!(
            "no model artifacts found under {}",
            cfg.artifact_dir.display()
        )
        .into());
    }
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_check_artifacts(_cfg: &LauncherConfig) -> CliResult {
    Err("check-artifacts needs the PJRT runtime: rebuild with `--features pjrt`".into())
}
