//! Learning-rate schedules in Table 2's notation.
//!
//! Table 2 specifies schedules as paired lists
//! `epoch=[(e0,e1),(e1,e2),…]` and `lr=[(lr_start,lr_end),…]`: within
//! each epoch segment the LR interpolates linearly between the pair.
//! One-cycle (ResNet20/DenseNet100) and warmup+multi-step
//! (ResNet50/LSTM) are both instances of this piecewise-linear form.

/// One segment: over `epoch ∈ [e0, e1)`, LR goes linearly `lr0 → lr1`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Segment {
    /// Segment start epoch (inclusive, fractional allowed).
    pub e0: f64,
    /// Segment end epoch (exclusive).
    pub e1: f64,
    /// LR at `e0`.
    pub lr0: f64,
    /// LR approached at `e1`.
    pub lr1: f64,
}

/// A piecewise-linear LR schedule over (fractional) epochs.
#[derive(Debug, Clone, PartialEq)]
pub struct PiecewiseLinear {
    segments: Vec<Segment>,
}

impl PiecewiseLinear {
    /// Build from Table-2-style paired lists. Panics if the lists are
    /// empty, differ in length, or the epochs are not contiguous.
    pub fn from_table(epochs: &[(f64, f64)], lrs: &[(f64, f64)]) -> Self {
        assert!(!epochs.is_empty() && epochs.len() == lrs.len(), "paired lists");
        let segments: Vec<Segment> = epochs
            .iter()
            .zip(lrs)
            .map(|(&(e0, e1), &(lr0, lr1))| {
                assert!(e1 > e0, "segment must advance: ({e0},{e1})");
                Segment { e0, e1, lr0, lr1 }
            })
            .collect();
        for w in segments.windows(2) {
            assert!(
                (w[0].e1 - w[1].e0).abs() < 1e-9,
                "segments must be contiguous"
            );
        }
        PiecewiseLinear { segments }
    }

    /// LR at a fractional epoch. Clamps before the first and after the
    /// last segment.
    pub fn lr_at(&self, epoch: f64) -> f64 {
        let first = self.segments.first().expect("nonempty");
        if epoch <= first.e0 {
            return first.lr0;
        }
        for s in &self.segments {
            if epoch < s.e1 {
                let t = (epoch - s.e0) / (s.e1 - s.e0);
                return s.lr0 + t * (s.lr1 - s.lr0);
            }
        }
        self.segments.last().expect("nonempty").lr1
    }

    /// Multiply every LR by `s` (the scaling-rule factor).
    pub fn scaled(&self, s: f64) -> Self {
        PiecewiseLinear {
            segments: self
                .segments
                .iter()
                .map(|&seg| Segment {
                    lr0: seg.lr0 * s,
                    lr1: seg.lr1 * s,
                    ..seg
                })
                .collect(),
        }
    }

    /// Last scheduled epoch.
    pub fn end_epoch(&self) -> f64 {
        self.segments.last().expect("nonempty").e1
    }
}

/// The named schedule families used in Table 2.
#[derive(Debug, Clone, PartialEq)]
pub enum LrSchedule {
    /// Constant LR.
    Constant {
        /// The LR.
        lr: f64,
    },
    /// Arbitrary piecewise-linear schedule.
    Piecewise {
        /// Segments.
        schedule: PiecewiseLinear,
    },
}

impl LrSchedule {
    /// Table 2's one-cycle for ResNet20/DenseNet100 on CIFAR10:
    /// `epoch=[(1,23),(23,46),(46,300)]`,
    /// `lr=[(0.15, 3s),(3s, 0.15s),(0.15s, 0.015s)]`.
    pub fn one_cycle_cifar(s: f64) -> Self {
        LrSchedule::Piecewise {
            schedule: PiecewiseLinear::from_table(
                &[(1.0, 23.0), (23.0, 46.0), (46.0, 300.0)],
                &[
                    (0.15, 3.0 * s),
                    (3.0 * s, 0.15 * s),
                    (0.15 * s, 0.015 * s),
                ],
            ),
        }
    }

    /// Table 2's warmup + multi-step for ResNet50/ImageNet:
    /// warmup over `[0,5)`, then steps at 30/60/80 dividing by 10.
    pub fn warmup_multistep_imagenet(lr0: f64, s: f64) -> Self {
        LrSchedule::Piecewise {
            schedule: PiecewiseLinear::from_table(
                &[(0.0, 5.0), (5.0, 30.0), (30.0, 60.0), (60.0, 80.0), (80.0, 90.0)],
                &[
                    (lr0, lr0 * s),
                    (lr0 * s, lr0 * s),
                    (lr0 / 10.0 * s, lr0 / 10.0 * s),
                    (lr0 / 100.0 * s, lr0 / 100.0 * s),
                    (lr0 / 1000.0 * s, lr0 / 1000.0 * s),
                ],
            ),
        }
    }

    /// Table 2's warmup + multi-step for the WikiText2 LSTM.
    pub fn warmup_multistep_lstm(s: f64) -> Self {
        LrSchedule::Piecewise {
            schedule: PiecewiseLinear::from_table(
                &[(0.0, 5.0), (5.0, 150.0), (150.0, 225.0), (225.0, 300.0)],
                &[
                    (2.5, 2.5 * s),
                    (2.5 * s, 2.5 * s),
                    (0.25 * s, 0.25 * s),
                    (0.025 * s, 0.025 * s),
                ],
            ),
        }
    }

    /// A short generic warmup-then-decay schedule for the synthetic
    /// benchmark workloads: warmup over `warmup` epochs to `peak·s`,
    /// hold, then linear decay to 10% by `total`.
    pub fn bench_default(peak: f64, s: f64, warmup: f64, total: f64) -> Self {
        let total = total.max(0.5);
        let warmup = warmup.clamp(0.0, total * 0.5);
        let hold_end = warmup.max(total * 0.4).min(total);
        let mut epochs: Vec<(f64, f64)> = Vec::new();
        let mut lrs: Vec<(f64, f64)> = Vec::new();
        if warmup > 0.0 {
            epochs.push((0.0, warmup));
            lrs.push((peak * s * 0.1, peak * s));
        }
        if hold_end > warmup {
            epochs.push((warmup, hold_end));
            lrs.push((peak * s, peak * s));
        }
        if total > hold_end {
            epochs.push((hold_end, total));
            lrs.push((peak * s, peak * s * 0.1));
        }
        if epochs.is_empty() {
            return LrSchedule::Constant { lr: peak * s };
        }
        LrSchedule::Piecewise {
            schedule: PiecewiseLinear::from_table(&epochs, &lrs),
        }
    }

    /// LR at a fractional epoch.
    pub fn lr_at(&self, epoch: f64) -> f64 {
        match self {
            LrSchedule::Constant { lr } => *lr,
            LrSchedule::Piecewise { schedule } => schedule.lr_at(epoch),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn piecewise_interpolates_linearly() {
        let p = PiecewiseLinear::from_table(&[(0.0, 10.0), (10.0, 20.0)], &[(0.0, 1.0), (1.0, 0.0)]);
        assert!((p.lr_at(0.0) - 0.0).abs() < 1e-12);
        assert!((p.lr_at(5.0) - 0.5).abs() < 1e-12);
        assert!((p.lr_at(10.0) - 1.0).abs() < 1e-12);
        assert!((p.lr_at(15.0) - 0.5).abs() < 1e-12);
        assert!((p.lr_at(99.0) - 0.0).abs() < 1e-12, "clamps after end");
        assert!((p.lr_at(-1.0) - 0.0).abs() < 1e-12, "clamps before start");
    }

    #[test]
    fn one_cycle_matches_table2_breakpoints() {
        let s = 2.0;
        let lr = LrSchedule::one_cycle_cifar(s);
        assert!((lr.lr_at(1.0) - 0.15).abs() < 1e-9);
        assert!((lr.lr_at(23.0) - 3.0 * s).abs() < 1e-9);
        assert!((lr.lr_at(46.0) - 0.15 * s).abs() < 1e-9);
        assert!((lr.lr_at(300.0) - 0.015 * s).abs() < 1e-9);
    }

    #[test]
    fn imagenet_multistep_drops_by_ten() {
        let lr = LrSchedule::warmup_multistep_imagenet(0.1, 1.0);
        assert!((lr.lr_at(10.0) - 0.1).abs() < 1e-9);
        assert!((lr.lr_at(45.0) - 0.01).abs() < 1e-9);
        assert!((lr.lr_at(70.0) - 0.001).abs() < 1e-9);
        assert!((lr.lr_at(85.0) - 0.0001).abs() < 1e-9);
    }

    #[test]
    fn warmup_starts_low() {
        let lr = LrSchedule::warmup_multistep_imagenet(0.1, 4.0);
        assert!(lr.lr_at(0.0) < lr.lr_at(4.9), "LR must grow through warmup");
        assert!((lr.lr_at(0.0) - 0.1).abs() < 1e-9, "warmup starts at lr0");
    }

    #[test]
    fn scaled_multiplies_everything() {
        let p = PiecewiseLinear::from_table(&[(0.0, 10.0)], &[(1.0, 2.0)]).scaled(3.0);
        assert!((p.lr_at(0.0) - 3.0).abs() < 1e-12);
        assert!((p.lr_at(10.0) - 6.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "contiguous")]
    fn rejects_gapped_segments() {
        PiecewiseLinear::from_table(&[(0.0, 5.0), (6.0, 10.0)], &[(1.0, 1.0), (1.0, 1.0)]);
    }
}
