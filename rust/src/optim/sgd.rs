//! Momentum SGD over flat parameter vectors — the optimizer for the
//! in-process surrogate models (the HLO models fuse their own update
//! into the `step` executable; see `python/compile/models/`).

/// Heavy-ball momentum SGD state.
#[derive(Debug, Clone)]
pub struct SgdState {
    velocity: Vec<f32>,
    /// Momentum coefficient μ (0 disables).
    pub momentum: f32,
    /// Decoupled L2 weight decay λ.
    pub weight_decay: f32,
}

impl SgdState {
    /// Fresh state for `n_params` parameters.
    pub fn new(n_params: usize, momentum: f32, weight_decay: f32) -> Self {
        SgdState {
            velocity: vec![0.0; n_params],
            momentum,
            weight_decay,
        }
    }

    /// In-place update: `v ← μv + (g + λθ)`, `θ ← θ − γv`.
    ///
    /// Routed through the explicit SIMD layer
    /// ([`crate::exec::simd::sgd_step`]) — the same kernel the fused
    /// gossip+SGD tiles run, so split and fused execution share one
    /// float sequence and stay bit-identical (SIMD or scalar path
    /// alike).
    pub fn step(&mut self, params: &mut [f32], grads: &[f32], lr: f32) {
        assert_eq!(params.len(), grads.len());
        assert_eq!(params.len(), self.velocity.len());
        crate::exec::simd::sgd_step(
            params,
            &mut self.velocity,
            grads,
            self.momentum,
            self.weight_decay,
            lr,
        );
    }

    /// Reset accumulated velocity (e.g. after a topology change study).
    pub fn reset(&mut self) {
        self.velocity.iter_mut().for_each(|v| *v = 0.0);
    }

    /// Read access to the velocity buffer (checkpointing, tests).
    pub fn velocity(&self) -> &[f32] {
        &self.velocity
    }

    /// Mutable velocity buffer — used by the fused gossip+SGD kernel
    /// ([`crate::gossip::GossipEngine::mix_step`]) to update momentum
    /// tile-by-tile while the mixed parameters are cache-resident. The
    /// per-element update it performs is exactly [`SgdState::step`]'s.
    pub fn velocity_mut(&mut self) -> &mut [f32] {
        &mut self.velocity
    }

    /// Parameter count this state serves.
    pub fn len(&self) -> usize {
        self.velocity.len()
    }

    /// True when sized zero.
    pub fn is_empty(&self) -> bool {
        self.velocity.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plain_sgd_without_momentum() {
        let mut s = SgdState::new(2, 0.0, 0.0);
        let mut p = vec![1.0f32, 2.0];
        s.step(&mut p, &[0.5, -0.5], 0.1);
        assert!((p[0] - 0.95).abs() < 1e-7);
        assert!((p[1] - 2.05).abs() < 1e-7);
    }

    #[test]
    fn momentum_accumulates() {
        let mut s = SgdState::new(1, 0.9, 0.0);
        let mut p = vec![0.0f32];
        s.step(&mut p, &[1.0], 1.0); // v=1, p=-1
        s.step(&mut p, &[1.0], 1.0); // v=1.9, p=-2.9
        assert!((p[0] + 2.9).abs() < 1e-6);
    }

    #[test]
    fn weight_decay_pulls_to_zero() {
        let mut s = SgdState::new(1, 0.0, 0.1);
        let mut p = vec![10.0f32];
        s.step(&mut p, &[0.0], 1.0);
        assert!((p[0] - 9.0).abs() < 1e-6);
    }

    #[test]
    fn reset_clears_velocity() {
        let mut s = SgdState::new(1, 0.9, 0.0);
        let mut p = vec![0.0f32];
        s.step(&mut p, &[1.0], 1.0);
        s.reset();
        s.step(&mut p, &[0.0], 1.0);
        assert!((p[0] + 1.0).abs() < 1e-6, "no velocity carryover after reset");
    }
}
