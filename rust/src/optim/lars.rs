//! LARS — layer-wise adaptive rate scaling (You et al. 2017).
//!
//! §4.2 of the paper proposes applying LARS to decentralized large-batch
//! training as future work ("The application of layer-wise adaptive rate
//! scaling (LARS) to the decentralized setting might be an option to
//! further improve the performance of our approach"). We implement it so
//! the ablation bench can measure exactly that option.

use super::SgdState;

/// LARS wrapper around momentum SGD: per layer ℓ the local LR is
/// `γ_ℓ = η · ‖θ_ℓ‖ / (‖g_ℓ‖ + β‖θ_ℓ‖ + ε)`, applied on top of the
/// global schedule LR.
#[derive(Debug, Clone)]
pub struct Lars {
    /// Trust coefficient η (paper default 0.001 for ResNet-scale nets).
    pub eta: f32,
    /// Weight decay β folded into the trust ratio.
    pub weight_decay: f32,
    /// Numerical floor.
    pub epsilon: f32,
    sgd: SgdState,
    /// Flat-vector layer boundaries: layer ℓ is `params[ranges[ℓ].0..ranges[ℓ].1]`.
    ranges: Vec<(usize, usize)>,
}

impl Lars {
    /// Create LARS state over `n_params` parameters split at `ranges`.
    pub fn new(
        n_params: usize,
        ranges: Vec<(usize, usize)>,
        eta: f32,
        momentum: f32,
        weight_decay: f32,
    ) -> Self {
        assert!(
            ranges.iter().all(|&(a, b)| a < b && b <= n_params),
            "layer ranges must be valid sub-slices"
        );
        Lars {
            eta,
            weight_decay,
            epsilon: 1e-9,
            sgd: SgdState::new(n_params, momentum, 0.0),
            ranges,
        }
    }

    /// The trust ratio for one layer.
    fn trust_ratio(&self, theta: &[f32], grad: &[f32]) -> f32 {
        let wn = l2(theta);
        let gn = l2(grad);
        if wn == 0.0 || gn == 0.0 {
            return 1.0;
        }
        self.eta * wn / (gn + self.weight_decay * wn + self.epsilon)
    }

    /// In-place LARS update with global LR `lr`: rescales each layer's
    /// gradient by its trust ratio, then momentum-SGD-steps.
    pub fn step(&mut self, params: &mut [f32], grads: &[f32], lr: f32) {
        assert_eq!(params.len(), grads.len());
        let mut scaled = grads.to_vec();
        for &(a, b) in &self.ranges {
            let ratio = self.trust_ratio(&params[a..b], &grads[a..b]);
            for (g, &p) in scaled[a..b].iter_mut().zip(&params[a..b]) {
                *g = (*g + self.weight_decay * p) * ratio;
            }
        }
        self.sgd.step(params, &scaled, lr);
    }
}

/// Per-layer L2 norm, on the explicit SIMD layer's fixed-8-lane
/// sum-of-squares ([`crate::exec::simd::sumsq_f32`]) — bit-identical
/// between the AVX2 and scalar paths by construction.
fn l2(v: &[f32]) -> f32 {
    crate::exec::simd::sumsq_f32(v).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trust_ratio_normalizes_large_gradients() {
        let lars = Lars::new(4, vec![(0, 4)], 0.001, 0.0, 0.0);
        // Huge gradient relative to weights ⇒ tiny trust ratio.
        let ratio = lars.trust_ratio(&[1.0, 0.0, 0.0, 0.0], &[1000.0, 0.0, 0.0, 0.0]);
        assert!((ratio - 0.001 / 1000.0).abs() < 1e-9);
    }

    #[test]
    fn zero_norms_fall_back_to_unit_ratio() {
        let lars = Lars::new(2, vec![(0, 2)], 0.001, 0.0, 0.0);
        assert_eq!(lars.trust_ratio(&[0.0, 0.0], &[1.0, 1.0]), 1.0);
        assert_eq!(lars.trust_ratio(&[1.0, 1.0], &[0.0, 0.0]), 1.0);
    }

    #[test]
    fn per_layer_scaling_differs() {
        // Layer 0 has balanced norms, layer 1 has exploding gradient:
        // after one step, layer 1's parameters must move *less* relative
        // to its gradient magnitude.
        let mut lars = Lars::new(4, vec![(0, 2), (2, 4)], 0.01, 0.0, 0.0);
        let mut p = vec![1.0f32, 1.0, 1.0, 1.0];
        let g = vec![1.0f32, 1.0, 100.0, 100.0];
        lars.step(&mut p, &g, 1.0);
        let move0 = (1.0 - p[0]).abs();
        let move1 = (1.0 - p[2]).abs();
        // Trust ratios: both layers scale to η·‖θ‖/‖g‖ ⇒ absolute moves equal.
        assert!(
            (move0 - move1).abs() < 1e-6,
            "LARS equalizes per-layer update magnitude: {move0} vs {move1}"
        );
    }

    #[test]
    #[should_panic(expected = "layer ranges")]
    fn rejects_bad_ranges() {
        Lars::new(4, vec![(0, 5)], 0.001, 0.0, 0.0);
    }
}
