//! Optimization: learning-rate schedules (Table 2 of the paper), the
//! linear / square-root batch-size scaling rules whose interaction with
//! decentralization §3.2 analyzes (Observation 3), momentum SGD for the
//! in-process surrogate models, and LARS (the paper's proposed future
//! work for large-batch decentralized training, §4.2).

mod lars;
mod schedule;
mod sgd;

pub use lars::Lars;
pub use schedule::{LrSchedule, PiecewiseLinear};
pub use sgd::SgdState;

/// Batch-size scaling rule applied to the base learning rate.
///
/// Table 2 uses `s = batch_size · (k+1) / divisor` — the effective data
/// consumed per averaging neighborhood — scaled linearly; §3.2's tuned
/// runs replace the linear rule with square-root scaling, which the
/// paper finds becomes necessary at *smaller* scales for decentralized
/// runs than for centralized ones (Observation 3).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ScalingRule {
    /// No scaling: s = 1.
    None,
    /// Linear: s = effective_batch / divisor.
    Linear,
    /// Square-root: s = sqrt(effective_batch / divisor).
    Sqrt,
}

impl ScalingRule {
    /// Compute the scale factor `s` from the per-GPU batch size, the
    /// neighbor count `k` of the communication graph (so `k+1` replicas
    /// participate in each average) and the paper's divisor (256 for
    /// ImageNet-style runs, 24 for the LSTM).
    pub fn factor(self, batch_per_gpu: usize, k_neighbors: usize, divisor: f64) -> f64 {
        let eff = batch_per_gpu as f64 * (k_neighbors as f64 + 1.0) / divisor;
        match self {
            ScalingRule::None => 1.0,
            ScalingRule::Linear => eff,
            ScalingRule::Sqrt => eff.sqrt(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_resnet50_scaling_examples() {
        // Table 2: s = Batch_Size·(k+1)/256, k=2 (ring) … k=#GPU−1 (complete).
        let s_ring = ScalingRule::Linear.factor(32, 2, 256.0);
        assert!((s_ring - 32.0 * 3.0 / 256.0).abs() < 1e-12);
        let s_complete_96 = ScalingRule::Linear.factor(32, 95, 256.0);
        assert!((s_complete_96 - 12.0).abs() < 1e-12);
    }

    #[test]
    fn sqrt_is_smaller_than_linear_above_one() {
        let lin = ScalingRule::Linear.factor(32, 95, 256.0);
        let sqr = ScalingRule::Sqrt.factor(32, 95, 256.0);
        assert!(sqr < lin, "sqrt must damp large-scale LR: {sqr} < {lin}");
        assert!((sqr - lin.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn none_is_identity() {
        assert_eq!(ScalingRule::None.factor(999, 999, 1.0), 1.0);
    }
}
