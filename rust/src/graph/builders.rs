//! Constructors for the Table-1 graph families.

use super::{CommGraph, GraphKind};
use crate::error::{AdaError, Result};

pub(super) fn build(kind: GraphKind, n: usize) -> Result<CommGraph> {
    if n == 0 {
        return Err(AdaError::Graph("graph must have at least one node".into()));
    }
    match kind {
        GraphKind::Ring => ring(n),
        GraphKind::Torus => torus(n),
        GraphKind::RingLattice { k } => ring_lattice(n, k),
        GraphKind::AdaLattice { k } => ada_lattice(n, k),
        GraphKind::Exponential => exponential(n),
        GraphKind::Complete => complete(n),
        GraphKind::Hypercube => hypercube(n),
        GraphKind::RandomRegular { d, seed } => random_regular(n, d, seed),
    }
}

/// Binary hypercube over n = 2^m nodes: neighbors flip one address bit.
fn hypercube(n: usize) -> Result<CommGraph> {
    if n < 2 || !n.is_power_of_two() {
        return Err(AdaError::Graph(format!(
            "hypercube needs a power-of-two node count, got {n}"
        )));
    }
    let bits = n.trailing_zeros() as usize;
    let neighbors = (0..n)
        .map(|i| (0..bits).map(|b| i ^ (1 << b)).collect())
        .collect();
    CommGraph::from_neighbor_lists(GraphKind::Hypercube, neighbors, false)
}

/// Random d-regular circulant: d/2 distinct random offsets `o ∈ [1, n/2)`
/// with neighbors `i ± o`. Always simple and d-regular; connected iff
/// `gcd(offsets, n) = 1`, so offsets are resampled until connected.
/// Vertex-transitive like the paper's graphs, with near-expander gaps
/// for random offsets.
fn random_regular(n: usize, d: usize, seed: u64) -> Result<CommGraph> {
    if d < 2 || d % 2 != 0 {
        return Err(AdaError::Graph(format!(
            "random regular graph needs an even degree ≥ 2, got {d}"
        )));
    }
    if d >= n || d / 2 >= n.div_ceil(2) {
        return Err(AdaError::Graph(format!(
            "degree {d} too large for n = {n} distinct offsets"
        )));
    }
    let mut rng = crate::util::rng::Rng::seed_from_u64(seed ^ 0x5EED_6A7);
    let half_max = n.div_ceil(2); // offsets in 1..half_max avoid i ≡ i±o
    for _attempt in 0..256 {
        let mut offsets = std::collections::BTreeSet::new();
        // Offset 1 guarantees connectivity on the first try for most
        // seeds; still sample randomly and just retry when unlucky.
        while offsets.len() < d / 2 {
            offsets.insert(1 + rng.below(half_max - 1));
        }
        let mut neighbors: Vec<Vec<usize>> = Vec::with_capacity(n);
        for i in 0..n {
            let mut nb: Vec<usize> = offsets
                .iter()
                .flat_map(|&o| [(i + o) % n, (i + n - o) % n])
                .collect();
            nb.sort_unstable();
            nb.dedup();
            neighbors.push(nb);
        }
        // All offsets < ⌈n/2⌉ ⇒ ±o distinct ⇒ exactly d neighbors.
        if neighbors[0].len() != d {
            continue;
        }
        let kind = GraphKind::RandomRegular { d, seed };
        let g = CommGraph::from_neighbor_lists(kind, neighbors, false)?;
        if g.is_connected() {
            return Ok(g);
        }
    }
    Err(AdaError::Graph(format!(
        "could not build a connected random {d}-regular graph on {n} nodes"
    )))
}

/// Degree-2 cycle. Needs n ≥ 3 for two *distinct* neighbors.
fn ring(n: usize) -> Result<CommGraph> {
    if n < 3 {
        return Err(AdaError::Graph(format!("ring needs n ≥ 3, got {n}")));
    }
    let neighbors = (0..n)
        .map(|i| vec![(i + n - 1) % n, (i + 1) % n])
        .collect();
    CommGraph::from_neighbor_lists(GraphKind::Ring, neighbors, false)
}

/// 2-D wrap-around grid. Picks the most square factorization r × c = n
/// with r, c ≥ 2. When a dimension is 2, its two wrap neighbors coincide
/// and are deduplicated (degree drops to 3), matching how production
/// torus collectives degenerate on 2-wide meshes.
fn torus(n: usize) -> Result<CommGraph> {
    let (r, c) = squarest_factors(n).ok_or_else(|| {
        AdaError::Graph(format!("torus needs a factorization r×c={n} with r,c ≥ 2"))
    })?;
    let idx = |row: usize, col: usize| row * c + col;
    let mut neighbors = Vec::with_capacity(n);
    for row in 0..r {
        for col in 0..c {
            let mut nb = vec![
                idx((row + r - 1) % r, col),
                idx((row + 1) % r, col),
                idx(row, (col + c - 1) % c),
                idx(row, (col + 1) % c),
            ];
            nb.sort_unstable();
            nb.dedup();
            neighbors.push(nb);
        }
    }
    CommGraph::from_neighbor_lists(GraphKind::Torus, neighbors, false)
}

/// Table-1 ring lattice: 2k neighbors (k nearest on each side).
fn ring_lattice(n: usize, k: usize) -> Result<CommGraph> {
    if k == 0 {
        return Err(AdaError::Graph("ring lattice needs k ≥ 1".into()));
    }
    if 2 * k >= n {
        return Err(AdaError::Graph(format!(
            "ring lattice needs 2k < n (k={k}, n={n}); use Complete instead"
        )));
    }
    let mut neighbors = Vec::with_capacity(n);
    for i in 0..n {
        let mut nb: Vec<usize> = (1..=k)
            .flat_map(|h| [(i + h) % n, (i + n - h) % n])
            .collect();
        nb.sort_unstable();
        nb.dedup();
        neighbors.push(nb);
    }
    CommGraph::from_neighbor_lists(GraphKind::RingLattice { k }, neighbors, false)
}

/// Algorithm-1 lattice: neighbors `(i+j) mod n` for `j ∈ [-k/2, k/2] \ {0}`
/// (integer division, so `k` neighbors when `k` is even), uniform weight
/// `1/(k+1)`. `k` saturates at `n-1` (complete graph).
fn ada_lattice(n: usize, k: usize) -> Result<CommGraph> {
    if k < 2 {
        return Err(AdaError::Graph(format!(
            "Algorithm 1 keeps k ≥ 2 (got {k})"
        )));
    }
    let k = k.min(n - 1);
    let half = (k / 2) as isize;
    let mut neighbors = Vec::with_capacity(n);
    for i in 0..n {
        let mut nb: Vec<usize> = (-half..=half)
            .filter(|&j| j != 0)
            .map(|j| (i as isize + j).rem_euclid(n as isize) as usize)
            .collect();
        nb.sort_unstable();
        nb.dedup();
        neighbors.push(nb);
    }
    CommGraph::from_neighbor_lists(GraphKind::AdaLattice { k }, neighbors, false)
}

/// Directed exponential expander (§3.1.2): out-neighbors `(i + 2^m) % n`.
fn exponential(n: usize) -> Result<CommGraph> {
    if n < 3 {
        return Err(AdaError::Graph(format!("exponential needs n ≥ 3, got {n}")));
    }
    let mut neighbors = Vec::with_capacity(n);
    for i in 0..n {
        let mut nb: Vec<usize> = (0..)
            .map(|m| 1usize << m)
            .take_while(|&p| p <= n - 1)
            .map(|p| (i + p) % n)
            .collect();
        nb.sort_unstable();
        nb.dedup();
        neighbors.push(nb);
    }
    CommGraph::from_neighbor_lists(GraphKind::Exponential, neighbors, true)
}

/// Complete graph: uniform 1/n averaging (decentralized complete).
fn complete(n: usize) -> Result<CommGraph> {
    if n < 2 {
        return Err(AdaError::Graph(format!("complete needs n ≥ 2, got {n}")));
    }
    let neighbors = (0..n)
        .map(|i| (0..n).filter(|&j| j != i).collect())
        .collect();
    CommGraph::from_neighbor_lists(GraphKind::Complete, neighbors, false)
}

/// Most-square factorization n = r × c with r ≤ c and r ≥ 2.
pub fn squarest_factors(n: usize) -> Option<(usize, usize)> {
    let mut best = None;
    let mut r = (n as f64).sqrt() as usize;
    while r >= 2 {
        if n % r == 0 && n / r >= 2 {
            best = Some((r, n / r));
            break;
        }
        r -= 1;
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn factorization_is_squarest() {
        assert_eq!(squarest_factors(96), Some((8, 12)));
        assert_eq!(squarest_factors(16), Some((4, 4)));
        assert_eq!(squarest_factors(1008), Some((28, 36)));
        assert_eq!(squarest_factors(7), None);
        assert_eq!(squarest_factors(2), None);
    }

    #[test]
    fn ada_lattice_saturates_at_complete() {
        let g = ada_lattice(9, 100).unwrap();
        assert_eq!(g.degree(), 8);
    }

    #[test]
    fn ring_lattice_k1_is_a_ring() {
        let lat = ring_lattice(12, 1).unwrap();
        let ring = super::ring(12).unwrap();
        for i in 0..12 {
            assert_eq!(lat.neighbors_of(i), ring.neighbors_of(i));
        }
    }
}
