//! Spectral analysis of mixing matrices.
//!
//! Gossip convergence speed is governed by the second-largest singular
//! value σ₂ of the mixing matrix `W` (Xiao & Boyd 2004): the disagreement
//! `‖Θ − Θ̄‖` contracts by σ₂ per round. `1 − σ₂` is the spectral gap.
//! We compute σ₂ by power iteration on `WᵀW` restricted to the complement
//! of the consensus direction (the all-ones vector), which works uniformly
//! for symmetric and asymmetric (exponential-graph) mixing matrices.

/// Second-largest singular value of the `n × n` row-major matrix `w`,
/// assuming `w` is doubly stochastic (σ₁ = 1 with singular vector 1/√n).
pub fn power_iteration_sigma2(w: &[f32], n: usize) -> f64 {
    assert_eq!(w.len(), n * n, "matrix shape mismatch");
    if n == 1 {
        return 0.0;
    }
    let wf: Vec<f64> = w.iter().map(|&x| x as f64).collect();
    // x ← deflate(x); y = W x; x' = Wᵀ y  (i.e. one step of WᵀW)
    let mut x: Vec<f64> = (0..n).map(|i| ((i * 2654435761) % 1000) as f64 / 1000.0 - 0.5).collect();
    deflate(&mut x);
    normalize(&mut x);
    let mut y = vec![0.0f64; n];
    let mut sigma2_sq = 0.0f64;
    for _ in 0..600 {
        // y = W x
        for i in 0..n {
            let row = &wf[i * n..(i + 1) * n];
            y[i] = row.iter().zip(x.iter()).map(|(a, b)| a * b).sum();
        }
        // x' = Wᵀ y
        for i in 0..n {
            x[i] = 0.0;
        }
        for i in 0..n {
            let row = &wf[i * n..(i + 1) * n];
            let yi = y[i];
            for j in 0..n {
                x[j] += row[j] * yi;
            }
        }
        deflate(&mut x);
        let norm = normalize(&mut x);
        let prev = sigma2_sq;
        sigma2_sq = norm;
        if (sigma2_sq - prev).abs() < 1e-13 {
            break;
        }
    }
    sigma2_sq.max(0.0).sqrt()
}

/// σ₂ of the mixing matrix: the per-round contraction factor of the
/// disagreement. `1 − mixing_contraction` is the spectral gap.
pub fn mixing_contraction(w: &[f32], n: usize) -> f64 {
    power_iteration_sigma2(w, n)
}

/// Remove the component along the all-ones consensus direction.
fn deflate(x: &mut [f64]) {
    let mean = x.iter().sum::<f64>() / x.len() as f64;
    for v in x.iter_mut() {
        *v -= mean;
    }
}

/// Normalize to unit length, returning the prior squared norm after one
/// WᵀW application (the Rayleigh-quotient estimate of σ₂²).
fn normalize(x: &mut [f64]) -> f64 {
    let norm = x.iter().map(|v| v * v).sum::<f64>().sqrt();
    if norm > 0.0 {
        for v in x.iter_mut() {
            *v /= norm;
        }
    }
    norm
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{CommGraph, GraphKind};

    #[test]
    fn complete_graph_has_gap_one() {
        let g = CommGraph::build(GraphKind::Complete, 16).unwrap();
        let s2 = power_iteration_sigma2(&g.dense_mixing(), 16);
        assert!(s2 < 1e-6, "uniform averaging reaches consensus in one round, σ2={s2}");
    }

    #[test]
    fn ring_sigma2_matches_closed_form() {
        // Uniform-weight ring: eigenvalues (1 + 2cos(2πk/n)) / 3.
        let n = 24;
        let g = CommGraph::build(GraphKind::Ring, n).unwrap();
        let s2 = power_iteration_sigma2(&g.dense_mixing(), n);
        let expect = (1.0 + 2.0 * (2.0 * std::f64::consts::PI / n as f64).cos()) / 3.0;
        assert!(
            (s2 - expect).abs() < 1e-6,
            "σ2 = {s2}, closed form = {expect}"
        );
    }

    #[test]
    fn sigma2_decreases_with_lattice_k() {
        // Ada's premise: larger k ⇒ faster mixing.
        let n = 32;
        let mut prev = 1.0f64;
        for k in [2, 4, 8, 12] {
            let g = CommGraph::build(GraphKind::AdaLattice { k }, n).unwrap();
            let s2 = power_iteration_sigma2(&g.dense_mixing(), n);
            assert!(
                s2 < prev + 1e-9,
                "σ2 must not increase with k: k={k} σ2={s2} prev={prev}"
            );
            prev = s2;
        }
    }

    #[test]
    fn identity_matrix_sigma2_is_one() {
        let n = 8;
        let mut w = vec![0.0f32; n * n];
        for i in 0..n {
            w[i * n + i] = 1.0;
        }
        let s2 = power_iteration_sigma2(&w, n);
        assert!((s2 - 1.0).abs() < 1e-9, "no mixing ⇒ σ2 = 1, got {s2}");
    }

    #[test]
    fn single_node_gap() {
        assert_eq!(power_iteration_sigma2(&[1.0], 1), 0.0);
    }
}
