//! Communication graphs and mixing matrices for decentralized SGD.
//!
//! This is the substrate underneath both DBench (§3 of the paper, which
//! sweeps ring / torus / exponential / complete graphs) and Ada (§4, which
//! evolves a ring lattice by decaying its coordination number `k`).
//!
//! A [`CommGraph`] couples the *topology* (who talks to whom) with the
//! *mixing weights* (how parameter tensors are averaged): each node `i`
//! holds a row `W_i` of the mixing matrix with `W_ii + Σ_j W_ij = 1`.
//! For all undirected graphs here the weights are the uniform
//! `1/(deg+1)` scheme used by the paper's Algorithm 1, which makes `W`
//! symmetric and doubly stochastic; the (directed) exponential graph is
//! regular in both in- and out-degree, so uniform weights remain doubly
//! stochastic while `W` itself is asymmetric.

mod builders;
mod spectral;

pub use spectral::{mixing_contraction, power_iteration_sigma2};

use crate::error::{AdaError, Result};
use std::collections::VecDeque;
use std::fmt;

/// The communication-graph families studied in the paper (Table 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum GraphKind {
    /// Each node has 2 neighbors, one hop each way. Degree 2, `n` edges.
    Ring,
    /// 2-D wrap-around grid, degree 4 (fewer when a grid dimension is 2),
    /// `2n` edges.
    Torus,
    /// Ring lattice with coordination number `k` per Table 1: each node
    /// connects to the `k` nearest neighbors on each side → degree `2k`,
    /// `kn` edges.
    RingLattice {
        /// Coordination number (neighbors per side).
        k: usize,
    },
    /// Ada's lattice exactly as in Algorithm 1 of the paper: node `i`
    /// connects to `(i+j) mod n` for `j ∈ [-k/2, k/2] \ {0}` with uniform
    /// weight `1/(k+1)` (so `k` neighbors, self-weight `1/(k+1)`).
    AdaLattice {
        /// Algorithm-1 coordination number (total neighbor count).
        k: usize,
    },
    /// Directed expander: node `i`'s out-neighbors are `{(i+2^m) mod n}`
    /// for `m = 0..⌊log2(n-1)⌋`. Degree `⌊log2(n-1)⌋ + 1`.
    Exponential,
    /// Every node connected to every other node. Degree `n-1`.
    Complete,
    /// Binary hypercube (n must be a power of two): neighbors differ in
    /// one address bit. Degree `log2 n` — the classic HPC topology,
    /// included beyond the paper's five for the design-space study.
    Hypercube,
    /// Random d-regular graph (permutation-union construction, seeded):
    /// the expander family the theory literature analyzes.
    RandomRegular {
        /// Even degree (built from d/2 random cyclic permutations).
        d: usize,
        /// Construction seed.
        seed: u64,
    },
}

impl fmt::Display for GraphKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            GraphKind::Ring => write!(f, "ring"),
            GraphKind::Torus => write!(f, "torus"),
            GraphKind::RingLattice { k } => write!(f, "ring_lattice(k={k})"),
            GraphKind::AdaLattice { k } => write!(f, "ada_lattice(k={k})"),
            GraphKind::Exponential => write!(f, "exponential"),
            GraphKind::Complete => write!(f, "complete"),
            GraphKind::Hypercube => write!(f, "hypercube"),
            GraphKind::RandomRegular { d, .. } => write!(f, "random_regular(d={d})"),
        }
    }
}

/// A communication graph together with its mixing weights.
///
/// Immutable after construction; cheap to clone (used per-epoch by the
/// adaptive schedules).
#[derive(Debug, Clone, PartialEq)]
pub struct CommGraph {
    kind: GraphKind,
    n: usize,
    /// Out-neighbors of each node, sorted, no self-loops, deduplicated.
    neighbors: Vec<Vec<usize>>,
    /// Mixing weight of each out-neighbor, aligned with `neighbors`.
    weights: Vec<Vec<f32>>,
    /// Self-mixing weight of each node.
    self_weight: Vec<f32>,
    directed: bool,
}

impl CommGraph {
    /// Build a graph of `kind` over `n` nodes with uniform mixing weights.
    pub fn build(kind: GraphKind, n: usize) -> Result<Self> {
        builders::build(kind, n)
    }

    /// Construct from explicit neighbor lists with uniform `1/(deg_i + 1)`
    /// weights. `neighbors[i]` must not contain `i` or duplicates.
    pub fn from_neighbor_lists(
        kind: GraphKind,
        neighbors: Vec<Vec<usize>>,
        directed: bool,
    ) -> Result<Self> {
        let n = neighbors.len();
        if n == 0 {
            return Err(AdaError::Graph("graph must have at least one node".into()));
        }
        let mut weights = Vec::with_capacity(n);
        let mut self_weight = Vec::with_capacity(n);
        for (i, nb) in neighbors.iter().enumerate() {
            let mut seen = vec![false; n];
            for &j in nb {
                if j >= n {
                    return Err(AdaError::Graph(format!(
                        "node {i} has out-of-range neighbor {j} (n={n})"
                    )));
                }
                if j == i {
                    return Err(AdaError::Graph(format!("node {i} has a self-loop")));
                }
                if seen[j] {
                    return Err(AdaError::Graph(format!(
                        "node {i} lists neighbor {j} twice"
                    )));
                }
                seen[j] = true;
            }
            let w = 1.0 / (nb.len() as f32 + 1.0);
            weights.push(vec![w; nb.len()]);
            self_weight.push(w);
        }
        let mut g = CommGraph {
            kind,
            n,
            neighbors,
            weights,
            self_weight,
            directed,
        };
        for nb in &mut g.neighbors {
            nb.sort_unstable();
        }
        Ok(g)
    }

    /// Number of nodes.
    pub fn n(&self) -> usize {
        self.n
    }

    /// The graph family this was built from.
    pub fn kind(&self) -> GraphKind {
        self.kind
    }

    /// Whether edges are directed (true only for the exponential graph).
    pub fn is_directed(&self) -> bool {
        self.directed
    }

    /// Out-degree of node `i`.
    pub fn degree_of(&self, i: usize) -> usize {
        self.neighbors[i].len()
    }

    /// Common degree if the graph is regular, else the maximum degree.
    pub fn degree(&self) -> usize {
        self.neighbors.iter().map(Vec::len).max().unwrap_or(0)
    }

    /// True if every node has the same out-degree.
    pub fn is_regular(&self) -> bool {
        let d = self.degree_of(0);
        self.neighbors.iter().all(|nb| nb.len() == d)
    }

    /// Number of edges: undirected edge count for undirected graphs,
    /// directed arc count otherwise (matching Table 1's conventions).
    pub fn edge_count(&self) -> usize {
        let arcs: usize = self.neighbors.iter().map(Vec::len).sum();
        if self.directed {
            arcs
        } else {
            arcs / 2
        }
    }

    /// Out-neighbors of node `i` (sorted).
    pub fn neighbors_of(&self, i: usize) -> &[usize] {
        &self.neighbors[i]
    }

    /// Mixing weight on the edge `i → j`, if present. The self weight is
    /// returned for `i == j`.
    pub fn weight(&self, i: usize, j: usize) -> Option<f32> {
        if i == j {
            return Some(self.self_weight[i]);
        }
        self.neighbors[i]
            .binary_search(&j)
            .ok()
            .map(|idx| self.weights[i][idx])
    }

    /// Iterate the full mixing row of node `i`: `(j, w)` pairs including
    /// the self-loop entry.
    pub fn row(&self, i: usize) -> impl Iterator<Item = (usize, f32)> + '_ {
        std::iter::once((i, self.self_weight[i])).chain(
            self.neighbors[i]
                .iter()
                .copied()
                .zip(self.weights[i].iter().copied()),
        )
    }

    /// Self-mixing weight of node `i`.
    pub fn self_weight(&self, i: usize) -> f32 {
        self.self_weight[i]
    }

    /// Dense row-major `n × n` mixing matrix (for the HLO gossip kernel
    /// and spectral analysis).
    pub fn dense_mixing(&self) -> Vec<f32> {
        let n = self.n;
        let mut w = vec![0.0f32; n * n];
        for i in 0..n {
            for (j, wij) in self.row(i) {
                w[i * n + j] = wij;
            }
        }
        w
    }

    /// True if the graph is connected, treating directed arcs as
    /// bidirectional for reachability (standard for gossip convergence:
    /// the union graph must be strongly connected; the exponential graph
    /// is vertex-transitive so weak connectivity implies strong).
    pub fn is_connected(&self) -> bool {
        if self.n == 1 {
            return true;
        }
        // Build undirected reachability.
        let mut adj: Vec<Vec<usize>> = vec![Vec::new(); self.n];
        for (i, nb) in self.neighbors.iter().enumerate() {
            for &j in nb {
                adj[i].push(j);
                adj[j].push(i);
            }
        }
        let mut seen = vec![false; self.n];
        let mut q = VecDeque::from([0usize]);
        seen[0] = true;
        let mut count = 1;
        while let Some(u) = q.pop_front() {
            for &v in &adj[u] {
                if !seen[v] {
                    seen[v] = true;
                    count += 1;
                    q.push_back(v);
                }
            }
        }
        count == self.n
    }

    /// Check all mixing-matrix invariants; returns an error describing the
    /// first violation. Used by tests and by the coordinator at startup.
    pub fn validate(&self) -> Result<()> {
        let n = self.n;
        // Row stochasticity.
        for i in 0..n {
            let s: f32 = self.row(i).map(|(_, w)| w).sum();
            if (s - 1.0).abs() > 1e-5 {
                return Err(AdaError::Graph(format!(
                    "row {i} of mixing matrix sums to {s}, expected 1"
                )));
            }
            if self.self_weight[i] < 0.0 || self.weights[i].iter().any(|&w| w < 0.0) {
                return Err(AdaError::Graph(format!("row {i} has negative weights")));
            }
        }
        // Column stochasticity (doubly stochastic ⇒ gossip preserves the
        // global mean). Holds for uniform weights on regular graphs.
        let dense = self.dense_mixing();
        for j in 0..n {
            let s: f32 = (0..n).map(|i| dense[i * n + j]).sum();
            if (s - 1.0).abs() > 1e-4 {
                return Err(AdaError::Graph(format!(
                    "column {j} of mixing matrix sums to {s}, expected 1 \
                     (graph not regular?)"
                )));
            }
        }
        // Symmetry for undirected graphs.
        if !self.directed {
            for i in 0..n {
                for &j in &self.neighbors[i] {
                    if self.weight(j, i) != self.weight(i, j) {
                        return Err(AdaError::Graph(format!(
                            "undirected graph asymmetric at ({i},{j})"
                        )));
                    }
                }
            }
        }
        if !self.is_connected() {
            return Err(AdaError::Graph("graph is not connected".into()));
        }
        Ok(())
    }

    /// `1 − σ₂(W)`: the spectral gap of the mixing matrix, the standard
    /// measure of gossip mixing speed (larger = faster consensus).
    pub fn spectral_gap(&self) -> f64 {
        1.0 - spectral::mixing_contraction(&self.dense_mixing(), self.n)
    }

    /// Bytes a single node sends per gossip round for a model of
    /// `param_count` f32 parameters (degree × 4 bytes × params).
    pub fn bytes_sent_per_node(&self, param_count: usize) -> u64 {
        self.degree() as u64 * 4 * param_count as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds_for(n: usize) -> Vec<GraphKind> {
        vec![
            GraphKind::Ring,
            GraphKind::Torus,
            GraphKind::RingLattice { k: 2 },
            GraphKind::AdaLattice { k: 4 },
            GraphKind::Exponential,
            GraphKind::Complete,
        ]
        .into_iter()
        .filter(|k| !(matches!(k, GraphKind::Torus) && n < 4))
        .filter(|k| !matches!(k, GraphKind::RingLattice { k } if 2 * k >= n))
        .collect()
    }

    #[test]
    fn table1_ring_degree_and_edges() {
        for n in [4, 8, 12, 16, 96] {
            let g = CommGraph::build(GraphKind::Ring, n).unwrap();
            assert_eq!(g.degree(), 2, "ring degree must be 2 (Table 1)");
            assert!(g.is_regular());
            assert_eq!(g.edge_count(), n, "ring has n edges (Table 1)");
            assert!(!g.is_directed());
        }
    }

    #[test]
    fn table1_torus_degree_and_edges() {
        // n with both grid dims ≥ 3 matches Table 1 exactly.
        for n in [9, 12, 16, 24, 48, 96] {
            let g = CommGraph::build(GraphKind::Torus, n).unwrap();
            assert_eq!(g.degree(), 4, "torus degree must be 4 (Table 1), n={n}");
            assert!(g.is_regular());
            assert_eq!(g.edge_count(), 2 * n, "torus has 2n edges (Table 1)");
        }
    }

    #[test]
    fn table1_ring_lattice_degree_and_edges() {
        for (n, k) in [(12, 2), (16, 3), (96, 5)] {
            let g = CommGraph::build(GraphKind::RingLattice { k }, n).unwrap();
            assert_eq!(g.degree(), 2 * k, "ring lattice degree must be 2k");
            assert!(g.is_regular());
            assert_eq!(g.edge_count(), k * n, "ring lattice has kn edges");
        }
    }

    #[test]
    fn table1_exponential_degree_and_edges() {
        for n in [8, 12, 16, 24, 48, 96] {
            let g = CommGraph::build(GraphKind::Exponential, n).unwrap();
            let expect = ((n - 1) as f64).log2().floor() as usize + 1;
            assert_eq!(
                g.degree(),
                expect,
                "exponential degree must be ⌊log2(n-1)⌋+1, n={n}"
            );
            assert!(g.is_regular());
            assert_eq!(g.edge_count(), n * expect, "n(⌊log2(n-1)⌋+1) arcs");
            assert!(g.is_directed());
        }
    }

    #[test]
    fn table1_complete_degree_and_edges() {
        for n in [4, 12, 96] {
            let g = CommGraph::build(GraphKind::Complete, n).unwrap();
            assert_eq!(g.degree(), n - 1);
            assert!(g.is_regular());
            assert_eq!(g.edge_count(), n * (n - 1) / 2, "n(n-1)/2 edges");
        }
    }

    #[test]
    fn exponential_neighbors_match_paper_formula() {
        // §3.1.2: S_i = {(i + 2^m) % n}, m = 0..⌊log2(n-1)⌋.
        let n = 12;
        let g = CommGraph::build(GraphKind::Exponential, n).unwrap();
        for i in 0..n {
            let mut expect: Vec<usize> = (0..)
                .map(|m| 1usize << m)
                .take_while(|&p| p <= n - 1)
                .map(|p| (i + p) % n)
                .collect();
            expect.sort_unstable();
            expect.dedup();
            assert_eq!(g.neighbors_of(i), expect.as_slice(), "node {i}");
        }
    }

    #[test]
    fn ada_lattice_matches_algorithm1() {
        // Algorithm 1: graph[i][(i+j)%n] = 1/(k+1) for j in -k/2..k/2, j≠0,
        // and graph[i][i] = 1/(k+1).
        let (n, k) = (9, 4);
        let g = CommGraph::build(GraphKind::AdaLattice { k }, n).unwrap();
        for i in 0..n {
            assert!((g.self_weight(i) - 1.0 / (k as f32 + 1.0)).abs() < 1e-6);
            let half = k as isize / 2;
            let mut expect: Vec<usize> = (-half..=half)
                .filter(|&j| j != 0)
                .map(|j| (i as isize + j).rem_euclid(n as isize) as usize)
                .collect();
            expect.sort_unstable();
            expect.dedup();
            assert_eq!(g.neighbors_of(i), expect.as_slice());
            for &j in g.neighbors_of(i) {
                assert!((g.weight(i, j).unwrap() - 1.0 / (k as f32 + 1.0)).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn ada_lattice_k_saturates_to_complete() {
        // k = n-1 (odd n) reaches the complete graph, as in Fig. 6(a).
        let g = CommGraph::build(GraphKind::AdaLattice { k: 8 }, 9).unwrap();
        assert_eq!(g.degree(), 8);
        let c = CommGraph::build(GraphKind::Complete, 9).unwrap();
        assert_eq!(g.dense_mixing(), c.dense_mixing());
    }

    #[test]
    fn all_graphs_validate() {
        for n in [4, 8, 9, 12, 16, 24, 48, 96] {
            for kind in kinds_for(n) {
                let g = CommGraph::build(kind, n)
                    .unwrap_or_else(|e| panic!("build {kind} n={n}: {e}"));
                g.validate()
                    .unwrap_or_else(|e| panic!("validate {kind} n={n}: {e}"));
            }
        }
    }

    #[test]
    fn spectral_gap_orders_by_connectivity() {
        // Observation 2's mechanism: more connections ⇒ faster mixing.
        let n = 24;
        let gap = |k: GraphKind| CommGraph::build(k, n).unwrap().spectral_gap();
        let ring = gap(GraphKind::Ring);
        let torus = gap(GraphKind::Torus);
        let expo = gap(GraphKind::Exponential);
        let complete = gap(GraphKind::Complete);
        assert!(
            ring < torus && torus < expo && expo <= complete + 1e-9,
            "expected gap(ring) < gap(torus) < gap(exp) ≤ gap(complete): \
             {ring} {torus} {expo} {complete}"
        );
        assert!((complete - 1.0).abs() < 1e-3, "complete graph mixes in one step");
    }

    #[test]
    fn complete_graph_row_is_uniform_average() {
        let n = 8;
        let g = CommGraph::build(GraphKind::Complete, n).unwrap();
        for i in 0..n {
            for (j, w) in g.row(i) {
                assert!((w - 1.0 / n as f32).abs() < 1e-6, "W[{i}][{j}]={w}");
            }
        }
    }

    #[test]
    fn weight_lookup_roundtrip() {
        let g = CommGraph::build(GraphKind::Torus, 16).unwrap();
        for i in 0..16 {
            for j in 0..16 {
                let dense = g.dense_mixing();
                let w = g.weight(i, j).unwrap_or(0.0);
                assert_eq!(w, dense[i * 16 + j]);
            }
        }
    }

    #[test]
    fn torus_small_dim_degenerates_gracefully() {
        // 2×4 grid: vertical neighbors coincide → degree 3, still valid.
        let g = CommGraph::build(GraphKind::Torus, 8).unwrap();
        assert!(g.is_regular());
        assert_eq!(g.degree(), 3);
        g.validate().unwrap();
    }

    #[test]
    fn rejects_bad_inputs() {
        assert!(CommGraph::build(GraphKind::Ring, 0).is_err());
        assert!(CommGraph::build(GraphKind::Ring, 2).is_err());
        assert!(CommGraph::build(GraphKind::Torus, 7).is_err()); // prime
        assert!(CommGraph::build(GraphKind::RingLattice { k: 0 }, 8).is_err());
        assert!(CommGraph::build(GraphKind::RingLattice { k: 5 }, 8).is_err()); // 2k ≥ n
        assert!(CommGraph::from_neighbor_lists(
            GraphKind::Ring,
            vec![vec![0], vec![0]], // self loop
            false
        )
        .is_err());
    }

    #[test]
    fn hypercube_degree_and_distance() {
        for n in [4usize, 16, 64] {
            let g = CommGraph::build(GraphKind::Hypercube, n).unwrap();
            assert_eq!(g.degree(), n.trailing_zeros() as usize);
            assert!(g.is_regular());
            g.validate().unwrap();
            // Every neighbor differs in exactly one bit.
            for i in 0..n {
                for &j in g.neighbors_of(i) {
                    assert_eq!((i ^ j).count_ones(), 1, "{i} ↔ {j}");
                }
            }
        }
        assert!(CommGraph::build(GraphKind::Hypercube, 12).is_err());
    }

    #[test]
    fn random_regular_is_regular_connected_deterministic() {
        for (n, d) in [(16, 4), (30, 6), (96, 4)] {
            let g = CommGraph::build(GraphKind::RandomRegular { d, seed: 9 }, n).unwrap();
            assert!(g.is_regular(), "n={n} d={d}");
            assert_eq!(g.degree(), d);
            g.validate().unwrap();
            let g2 = CommGraph::build(GraphKind::RandomRegular { d, seed: 9 }, n).unwrap();
            assert_eq!(g.dense_mixing(), g2.dense_mixing(), "seeded determinism");
        }
        assert!(CommGraph::build(GraphKind::RandomRegular { d: 3, seed: 0 }, 16).is_err());
        assert!(CommGraph::build(GraphKind::RandomRegular { d: 16, seed: 0 }, 16).is_err());
    }

    #[test]
    fn random_regular_is_a_good_expander() {
        // The theory motivation: a random 4-regular graph's spectral gap
        // crushes the ring's at the same per-round cost ballpark.
        let n = 64;
        let ring = CommGraph::build(GraphKind::Ring, n).unwrap().spectral_gap();
        let rr = CommGraph::build(GraphKind::RandomRegular { d: 4, seed: 3 }, n)
            .unwrap()
            .spectral_gap();
        assert!(rr > 10.0 * ring, "expander gap {rr} vs ring {ring}");
    }

    #[test]
    fn n1008_topologies_build_exactly() {
        // Fig 7(d) scale: topology machinery is exact at n = 1008.
        let n = 1008;
        let ring = CommGraph::build(GraphKind::Ring, n).unwrap();
        assert_eq!(ring.edge_count(), n);
        let torus = CommGraph::build(GraphKind::Torus, n).unwrap();
        assert_eq!(torus.degree(), 4); // 1008 = 24 × 42
        let expo = CommGraph::build(GraphKind::Exponential, n).unwrap();
        assert_eq!(expo.degree(), 10); // ⌊log2(1007)⌋ + 1 = 10
        let ada = CommGraph::build(GraphKind::AdaLattice { k: 112 }, n).unwrap();
        assert_eq!(ada.degree(), 112); // Table 4: k0 = 112
        for g in [&ring, &torus, &expo, &ada] {
            g.validate().unwrap();
        }
    }
}
