//! [`FaultPlan`] — a seeded, stateless schedule of injected faults.
//!
//! The plan is the pure-function core of the fault plane: every query
//! (`delivered`, `straggler_factor`, `is_down`, `link_scale`) is a
//! deterministic function of `(plan seed, coordinates)` computed by
//! hashing the coordinates through a SplitMix64 finalizer chain. No
//! state is consumed, so the training loop may evaluate faults in any
//! order — per tile, per thread, per retry — and still produce
//! bit-identical runs at any thread count (the same contract as the
//! execution engine, test-enforced in `rust/tests/fault_injection.rs`).
//!
//! Fault kinds:
//!
//! * **message drops** — per-`(epoch, iter, src, dst)` Bernoulli draws;
//!   a dropped edge leaves the receiver mixing against its stale buffer
//!   ([`crate::gossip::GossipEngine::mix_stale`]);
//! * **stragglers** — per-node slowdown windows of
//!   [`straggler_iters`](FaultPlan::straggler_iters) iterations; a slow
//!   node's outgoing messages miss the round and its factor feeds
//!   [`crate::topology::TrainSignals::straggler_factor`];
//! * **link jitter** — per-edge latency/bandwidth scale draws consumed
//!   by [`crate::simnet::SimNet::gossip_round_with`];
//! * **crash/restart and join/leave** — explicit [`CrashEvent`]s with
//!   epoch granularity (`down_from = 0` models a late join); recovery
//!   goes through the checkpoint / neighbor-average path in the
//!   session.

use crate::error::{AdaError, Result};
use crate::util::params::ParamTable;
use std::path::PathBuf;

/// One node outage: the node is down for epochs
/// `down_from <= e < restart_at`. `restart_at = usize::MAX` (spelled
/// `-` in the compact syntax) never restarts; `down_from = 0` models a
/// cold join at `restart_at`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CrashEvent {
    /// Node (graph vertex) index.
    pub node: usize,
    /// First epoch the node is down.
    pub down_from: usize,
    /// First epoch the node is back up (`usize::MAX` = never).
    pub restart_at: usize,
}

impl CrashEvent {
    fn parse(text: &str) -> Result<CrashEvent> {
        let err = || {
            AdaError::Config(format!(
                "crash event {text:?} must be node@down_from:restart_at \
                 (restart_at `-` = never), e.g. 3@2:4"
            ))
        };
        let (node, span) = text.split_once('@').ok_or_else(err)?;
        let (from, until) = span.split_once(':').ok_or_else(err)?;
        let node: usize = node.trim().parse().map_err(|_| err())?;
        let down_from: usize = from.trim().parse().map_err(|_| err())?;
        let restart_at = match until.trim() {
            "-" => usize::MAX,
            s => s.parse().map_err(|_| err())?,
        };
        if restart_at <= down_from {
            return Err(AdaError::Config(format!(
                "crash event {text:?}: restart_at must be after down_from"
            )));
        }
        Ok(CrashEvent { node, down_from, restart_at })
    }
}

/// A seeded fault schedule — see the module docs. Construct with
/// [`FaultPlan::quiet`] (no faults) or [`FaultPlan::from_table`] (the
/// `[faults]` spec section / `--faults k=v,…` CLI form), then hand it
/// to [`crate::coordinator::TrainConfig::faults`].
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    /// Seed of every stochastic draw (independent of the run seed, so
    /// the same fault weather can be replayed over different runs).
    pub seed: u64,
    /// Per-(iteration, edge) probability that a message is dropped.
    pub drop_prob: f64,
    /// Per-(window, node) probability that the node straggles.
    pub straggler_prob: f64,
    /// Length of a straggler window in iterations (a slow node stays
    /// slow for the whole window; `0` is treated as `1`).
    pub straggler_iters: usize,
    /// Compute-time multiplier of a straggling node (`> 1`). A
    /// straggler's outgoing messages miss their round.
    pub straggler_slowdown: f64,
    /// Per-edge link-time jitter: each message's simulated transfer
    /// time is scaled by `1 + link_jitter · U[0,1)`.
    pub link_jitter: f64,
    /// Scheduled node outages (crash/restart, join/leave).
    pub crashes: Vec<CrashEvent>,
    /// Directory scanned for the newest usable checkpoint when a
    /// crashed node restarts; `None` (or no usable file) cold-joins
    /// from the neighbor-average row instead.
    pub recover_dir: Option<PathBuf>,
}

/// SplitMix64 finalizer — the avalanche permutation behind every draw.
fn mix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Top 53 bits as a uniform draw in `[0, 1)`.
fn unit(key: u64) -> f64 {
    (key >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

impl FaultPlan {
    /// A plan that injects nothing (every query returns the fault-free
    /// answer) — the identity element the bit-identity tests compare
    /// against.
    pub fn quiet() -> Self {
        FaultPlan {
            seed: 0,
            drop_prob: 0.0,
            straggler_prob: 0.0,
            straggler_iters: 1,
            straggler_slowdown: 1.0,
            link_jitter: 0.0,
            crashes: Vec::new(),
            recover_dir: None,
        }
    }

    /// Whether every query is guaranteed fault-free.
    pub fn is_quiet(&self) -> bool {
        self.drop_prob == 0.0
            && (self.straggler_prob == 0.0 || self.straggler_slowdown <= 1.0)
            && self.link_jitter == 0.0
            && self.crashes.is_empty()
    }

    /// Domain-separated key chain over up to four coordinates.
    fn key(&self, domain: u64, a: u64, b: u64, c: u64, d: u64) -> u64 {
        let mut h = mix64(self.seed ^ domain);
        h = mix64(h ^ a);
        h = mix64(h ^ b);
        h = mix64(h ^ c);
        mix64(h ^ d)
    }

    /// Whether the message `src → dst` of iteration `(epoch, iter)` is
    /// delivered in its round (drops only — crash and straggler gating
    /// is layered on top by the session).
    pub fn delivered(&self, epoch: usize, iter: usize, src: usize, dst: usize) -> bool {
        if self.drop_prob <= 0.0 {
            return true;
        }
        let k = self.key(0xD809, epoch as u64, iter as u64, src as u64, dst as u64);
        unit(k) >= self.drop_prob
    }

    /// Compute-time multiplier of `node` at `(epoch, iter)`: `1.0` when
    /// healthy, [`straggler_slowdown`](FaultPlan::straggler_slowdown)
    /// inside a straggler window. Windows are
    /// [`straggler_iters`](FaultPlan::straggler_iters) long and drawn
    /// per `(epoch, window, node)`.
    pub fn straggler_factor(&self, epoch: usize, iter: usize, node: usize) -> f64 {
        if self.straggler_prob <= 0.0 || self.straggler_slowdown <= 1.0 {
            return 1.0;
        }
        let window = self.straggler_iters.max(1);
        let w0 = iter - iter % window;
        let k = self.key(0x51A6, epoch as u64, w0 as u64, node as u64, 0);
        if unit(k) < self.straggler_prob {
            self.straggler_slowdown
        } else {
            1.0
        }
    }

    /// Whether `node` is down (crashed, or not yet joined) at `epoch`.
    pub fn is_down(&self, epoch: usize, node: usize) -> bool {
        self.crashes
            .iter()
            .any(|c| c.node == node && c.down_from <= epoch && epoch < c.restart_at)
    }

    /// Whether `node` recovers at the start of `epoch` (it was down the
    /// previous epoch and is up this one) — the session's trigger for
    /// checkpoint / neighbor-average restoration.
    pub fn recovers_at(&self, epoch: usize, node: usize) -> bool {
        epoch > 0 && self.is_down(epoch - 1, node) && !self.is_down(epoch, node)
    }

    /// Simulated-time scale of the link `src → dst` at `(epoch, iter)`:
    /// `1 + link_jitter · U[0,1)`.
    pub fn link_scale(&self, epoch: usize, iter: usize, src: usize, dst: usize) -> f64 {
        if self.link_jitter <= 0.0 {
            return 1.0;
        }
        let k = self.key(0x7177, epoch as u64, iter as u64, src as u64, dst as u64);
        1.0 + self.link_jitter * unit(k)
    }

    /// Validate against a run of `n` workers (crash events must name
    /// real nodes).
    pub fn validate(&self, n: usize) -> Result<()> {
        if !(0.0..1.0).contains(&self.drop_prob) {
            return Err(AdaError::Config(format!(
                "faults: drop_prob {} must be in [0, 1)",
                self.drop_prob
            )));
        }
        if !(0.0..=1.0).contains(&self.straggler_prob) {
            return Err(AdaError::Config(format!(
                "faults: straggler_prob {} must be in [0, 1]",
                self.straggler_prob
            )));
        }
        if self.straggler_slowdown < 1.0 {
            return Err(AdaError::Config(format!(
                "faults: straggler_slowdown {} must be ≥ 1",
                self.straggler_slowdown
            )));
        }
        if self.link_jitter < 0.0 {
            return Err(AdaError::Config(format!(
                "faults: link_jitter {} must be ≥ 0",
                self.link_jitter
            )));
        }
        for c in &self.crashes {
            if c.node >= n {
                return Err(AdaError::Config(format!(
                    "faults: crash event names node {} but the run has {n} workers",
                    c.node
                )));
            }
        }
        Ok(())
    }

    /// Build from a [`ParamTable`] — the `[faults]` TOML section and
    /// the CLI `--faults k=v,…` form. Crash events use the compact
    /// `node@down_from:restart_at` syntax, `;`-separated (`,` is the
    /// CLI pair separator), `-` for never: `crash = "3@2:4;5@1:-"`.
    /// Unknown keys error.
    pub fn from_table(table: &ParamTable) -> Result<FaultPlan> {
        table.expect_only(&[
            "seed",
            "drop_prob",
            "straggler_prob",
            "straggler_iters",
            "straggler_slowdown",
            "link_jitter",
            "crash",
            "recover_dir",
        ])?;
        let mut plan = FaultPlan::quiet();
        plan.seed = table.usize_or("seed", 0)? as u64;
        plan.drop_prob = table.f64_or("drop_prob", 0.0)?;
        plan.straggler_prob = table.f64_or("straggler_prob", 0.0)?;
        plan.straggler_iters = table.usize_or("straggler_iters", 1)?;
        plan.straggler_slowdown = table.f64_or("straggler_slowdown", 1.0)?;
        plan.link_jitter = table.f64_or("link_jitter", 0.0)?;
        if let Some(spec) = table.get_str("crash")? {
            for ev in spec.split(';').filter(|s| !s.trim().is_empty()) {
                plan.crashes.push(CrashEvent::parse(ev.trim())?);
            }
        }
        if let Some(dir) = table.get_str("recover_dir")? {
            plan.recover_dir = Some(PathBuf::from(dir));
        }
        Ok(plan)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quiet_plan_injects_nothing() {
        let p = FaultPlan::quiet();
        assert!(p.is_quiet());
        for (e, i) in [(0, 0), (3, 7), (100, 41)] {
            assert!(p.delivered(e, i, 0, 1));
            assert_eq!(p.straggler_factor(e, i, 2), 1.0);
            assert_eq!(p.link_scale(e, i, 0, 1), 1.0);
            assert!(!p.is_down(e, 0));
        }
    }

    #[test]
    fn queries_are_stateless_and_seeded() {
        let mut p = FaultPlan::quiet();
        p.seed = 7;
        p.drop_prob = 0.5;
        // Stateless: the same coordinates always answer the same.
        let first = p.delivered(2, 3, 1, 4);
        for _ in 0..10 {
            assert_eq!(p.delivered(2, 3, 1, 4), first);
        }
        // Seeded: a different seed flips some answers, and both seeds
        // land near the configured rate.
        let mut q = p.clone();
        q.seed = 8;
        let count = |plan: &FaultPlan| {
            let mut delivered = 0;
            for e in 0..10 {
                for i in 0..10 {
                    for s in 0..4 {
                        for d in 0..4 {
                            if s != d && plan.delivered(e, i, s, d) {
                                delivered += 1;
                            }
                        }
                    }
                }
            }
            delivered
        };
        let (a, b) = (count(&p), count(&q));
        let total = 10 * 10 * 4 * 3;
        for c in [a, b] {
            assert!(
                (total / 3..=2 * total / 3).contains(&c),
                "drop rate far from 0.5: {c}/{total}"
            );
        }
        let mut differs = false;
        'outer: for e in 0..10 {
            for i in 0..10 {
                if p.delivered(e, i, 0, 1) != q.delivered(e, i, 0, 1) {
                    differs = true;
                    break 'outer;
                }
            }
        }
        assert!(differs, "different seeds must draw different weather");
    }

    #[test]
    fn straggler_windows_hold_for_their_length() {
        let mut p = FaultPlan::quiet();
        p.seed = 3;
        p.straggler_prob = 0.5;
        p.straggler_iters = 4;
        p.straggler_slowdown = 3.0;
        let mut saw_slow = false;
        for e in 0..8 {
            for w0 in (0..32).step_by(4) {
                let f = p.straggler_factor(e, w0, 1);
                saw_slow |= f > 1.0;
                for i in w0..w0 + 4 {
                    assert_eq!(
                        p.straggler_factor(e, i, 1),
                        f,
                        "factor must be constant inside a window"
                    );
                }
            }
        }
        assert!(saw_slow, "p=0.5 over 64 windows must slow at least once");
    }

    #[test]
    fn crash_schedule_and_recovery_edges() {
        let mut p = FaultPlan::quiet();
        p.crashes = vec![
            CrashEvent { node: 2, down_from: 1, restart_at: 3 },
            CrashEvent { node: 5, down_from: 2, restart_at: usize::MAX },
            CrashEvent { node: 0, down_from: 0, restart_at: 2 }, // late join
        ];
        assert!(!p.is_quiet());
        assert!(!p.is_down(0, 2) && p.is_down(1, 2) && p.is_down(2, 2) && !p.is_down(3, 2));
        assert!(p.is_down(100, 5), "`-` never restarts");
        assert!(p.is_down(0, 0) && !p.is_down(2, 0), "cold join");
        assert!(p.recovers_at(3, 2));
        assert!(!p.recovers_at(2, 2) && !p.recovers_at(4, 2));
        assert!(p.recovers_at(2, 0));
        assert!(!p.recovers_at(0, 0), "epoch 0 has no previous epoch");
    }

    #[test]
    fn link_scale_is_bounded_by_jitter() {
        let mut p = FaultPlan::quiet();
        p.seed = 9;
        p.link_jitter = 0.5;
        for e in 0..5 {
            for i in 0..5 {
                let s = p.link_scale(e, i, 0, 1);
                assert!((1.0..1.5).contains(&s), "scale {s} out of [1, 1.5)");
            }
        }
    }

    #[test]
    fn from_table_parses_and_rejects_typos() {
        let t = ParamTable::parse_kv(
            "seed=7,drop_prob=0.1,straggler_prob=0.2,straggler_iters=3,\
             straggler_slowdown=2.5,link_jitter=0.3,crash=3@2:4;1@0:-",
        )
        .unwrap();
        let p = FaultPlan::from_table(&t).unwrap();
        assert_eq!(p.seed, 7);
        assert_eq!(p.drop_prob, 0.1);
        assert_eq!(p.straggler_prob, 0.2);
        assert_eq!(p.straggler_iters, 3);
        assert_eq!(p.straggler_slowdown, 2.5);
        assert_eq!(p.link_jitter, 0.3);
        assert_eq!(
            p.crashes,
            vec![
                CrashEvent { node: 3, down_from: 2, restart_at: 4 },
                CrashEvent { node: 1, down_from: 0, restart_at: usize::MAX },
            ]
        );
        p.validate(8).unwrap();
        assert!(p.validate(2).is_err(), "crash node out of range");

        assert!(FaultPlan::from_table(&ParamTable::parse_kv("dropprob=0.1").unwrap()).is_err());
        assert!(FaultPlan::from_table(&ParamTable::parse_kv("crash=3@4:2").unwrap()).is_err());
        assert!(FaultPlan::from_table(&ParamTable::parse_kv("crash=oops").unwrap()).is_err());

        let empty = FaultPlan::from_table(&ParamTable::new()).unwrap();
        assert_eq!(empty, FaultPlan::quiet());
    }

    #[test]
    fn validate_rejects_out_of_range_probabilities() {
        let mut p = FaultPlan::quiet();
        p.drop_prob = 1.0;
        assert!(p.validate(4).is_err());
        p.drop_prob = 0.2;
        p.straggler_slowdown = 0.5;
        assert!(p.validate(4).is_err());
        p.straggler_slowdown = 2.0;
        p.link_jitter = -0.1;
        assert!(p.validate(4).is_err());
        p.link_jitter = 0.0;
        p.validate(4).unwrap();
    }
}
