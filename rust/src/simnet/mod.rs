//! Analytic network cost model for a Summit-like cluster.
//!
//! The paper runs on OLCF Summit: 6 V100s per node, NVLink 2.0 at
//! 50 GB/s within a node, EDR InfiniBand at 23 GB/s between nodes
//! (§3.1.1). We cannot occupy 1008 GPUs, but the *communication cost*
//! side of Ada's accuracy/cost trade-off is a deterministic function of
//! the communication graph, message sizes, and these link constants — so
//! we compute it exactly (α–β model: `time = latency + bytes/bandwidth`
//! per message, per-GPU serialized sends, cluster time = max over GPUs).

use crate::graph::CommGraph;

/// Link constants of the modeled cluster.
#[derive(Debug, Clone, Copy)]
pub struct ClusterSpec {
    /// GPUs per node (Summit: 6).
    pub gpus_per_node: usize,
    /// Intra-node bandwidth, bytes/sec (NVLink 2.0: 50 GB/s).
    pub intra_bw: f64,
    /// Inter-node bandwidth, bytes/sec (EDR IB: 23 GB/s).
    pub inter_bw: f64,
    /// Intra-node message latency, seconds.
    pub intra_lat: f64,
    /// Inter-node message latency, seconds.
    pub inter_lat: f64,
}

impl ClusterSpec {
    /// Summit's published constants (§3.1.1 of the paper).
    pub fn summit() -> Self {
        ClusterSpec {
            gpus_per_node: 6,
            intra_bw: 50e9,
            inter_bw: 23e9,
            intra_lat: 1e-6,
            inter_lat: 5e-6,
        }
    }

    /// Node index hosting GPU `i` (block placement, like jsrun).
    pub fn node_of(&self, gpu: usize) -> usize {
        gpu / self.gpus_per_node
    }

    /// Point-to-point transfer time for `bytes` between two GPUs.
    pub fn p2p_time(&self, from: usize, to: usize, bytes: u64) -> f64 {
        if self.node_of(from) == self.node_of(to) {
            self.intra_lat + bytes as f64 / self.intra_bw
        } else {
            self.inter_lat + bytes as f64 / self.inter_bw
        }
    }
}

/// Per-iteration communication cost of one gossip round or allreduce.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CommCost {
    /// Wall-clock seconds for the round (max over GPUs).
    pub time_s: f64,
    /// Total bytes crossing node boundaries.
    pub inter_node_bytes: u64,
    /// Total bytes moved (all links).
    pub total_bytes: u64,
}

/// Analytic cost model over a [`ClusterSpec`].
#[derive(Debug, Clone)]
pub struct SimNet {
    spec: ClusterSpec,
}

impl SimNet {
    /// Model over `spec`.
    pub fn new(spec: ClusterSpec) -> Self {
        SimNet { spec }
    }

    /// The cluster constants in use.
    pub fn spec(&self) -> &ClusterSpec {
        &self.spec
    }

    /// Cost of one **gossip round** over `graph` exchanging `param_count`
    /// f32 parameters: every GPU sends its parameter vector to each
    /// out-neighbor; sends from one GPU serialize, GPUs overlap.
    pub fn gossip_round(&self, graph: &CommGraph, param_count: usize) -> CommCost {
        let bytes_per_msg = 4 * param_count as u64;
        let mut worst = 0.0f64;
        let mut inter = 0u64;
        let mut total = 0u64;
        for i in 0..graph.n() {
            let mut t = 0.0;
            for &j in graph.neighbors_of(i) {
                t += self.spec.p2p_time(i, j, bytes_per_msg);
                total += bytes_per_msg;
                if self.spec.node_of(i) != self.spec.node_of(j) {
                    inter += bytes_per_msg;
                }
            }
            worst = worst.max(t);
        }
        CommCost {
            time_s: worst,
            inter_node_bytes: inter,
            total_bytes: total,
        }
    }

    /// Cost of one **ring allreduce** over all `n` GPUs (the centralized
    /// `C_complete` baseline, NCCL-style): `2(n−1)` pipeline steps each
    /// moving `bytes/n`, bound by the slowest link in the ring.
    pub fn allreduce(&self, n: usize, param_count: usize) -> CommCost {
        if n <= 1 {
            return CommCost {
                time_s: 0.0,
                inter_node_bytes: 0,
                total_bytes: 0,
            };
        }
        let bytes = 4 * param_count as u64;
        let chunk = bytes as f64 / n as f64;
        // Slowest hop in the block-placement ring: inter-node whenever the
        // cluster spans > 1 node.
        let spans_nodes = self.spec.node_of(n - 1) > 0;
        let (bw, lat) = if spans_nodes {
            (self.spec.inter_bw, self.spec.inter_lat)
        } else {
            (self.spec.intra_bw, self.spec.intra_lat)
        };
        let steps = 2 * (n - 1);
        let time = steps as f64 * (lat + chunk / bw);
        // Every GPU sends `chunk` per step.
        let total = (steps * n) as f64 * chunk;
        let inter_links = if spans_nodes {
            // Ring over block placement crosses nodes 2·(#nodes) times
            // per step direction; approximate with per-hop accounting.
            let hops_inter = (0..n)
                .filter(|&i| self.spec.node_of(i) != self.spec.node_of((i + 1) % n))
                .count();
            (steps * hops_inter) as f64 * chunk
        } else {
            0.0
        };
        CommCost {
            time_s: time,
            inter_node_bytes: inter_links as u64,
            total_bytes: total as u64,
        }
    }

    /// Per-epoch communication time of a topology schedule (seconds),
    /// used by the fig7 bench to plot Ada's decaying cost.
    pub fn epoch_cost(
        &self,
        graph: &CommGraph,
        param_count: usize,
        iters_per_epoch: usize,
    ) -> f64 {
        self.gossip_round(graph, param_count).time_s * iters_per_epoch as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{CommGraph, GraphKind};

    #[test]
    fn node_placement_is_block() {
        let s = ClusterSpec::summit();
        assert_eq!(s.node_of(0), 0);
        assert_eq!(s.node_of(5), 0);
        assert_eq!(s.node_of(6), 1);
        assert_eq!(s.node_of(1007), 167); // 1008 GPUs = 168 Summit nodes
    }

    #[test]
    fn intra_node_is_faster() {
        let s = ClusterSpec::summit();
        let fast = s.p2p_time(0, 1, 1 << 20);
        let slow = s.p2p_time(0, 6, 1 << 20);
        assert!(fast < slow);
    }

    #[test]
    fn ring_cheaper_than_complete_per_round() {
        // The premise of Ada's late stage: sparse graphs cost less.
        let net = SimNet::new(ClusterSpec::summit());
        let n = 48;
        let p = 1_000_000;
        let ring = net.gossip_round(&CommGraph::build(GraphKind::Ring, n).unwrap(), p);
        let complete = net.gossip_round(&CommGraph::build(GraphKind::Complete, n).unwrap(), p);
        assert!(
            ring.time_s * 5.0 < complete.time_s,
            "ring {} vs complete {}",
            ring.time_s,
            complete.time_s
        );
        assert!(ring.total_bytes < complete.total_bytes);
    }

    #[test]
    fn gossip_cost_scales_with_degree() {
        let net = SimNet::new(ClusterSpec::summit());
        let n = 96;
        let p = 25_560_000; // ResNet50-sized
        let mut prev = 0.0;
        for kind in [GraphKind::Ring, GraphKind::Torus, GraphKind::Exponential] {
            let c = net.gossip_round(&CommGraph::build(kind, n).unwrap(), p);
            assert!(c.time_s > prev, "{kind:?} must cost more than sparser graphs");
            prev = c.time_s;
        }
    }

    #[test]
    fn allreduce_single_gpu_is_free() {
        let net = SimNet::new(ClusterSpec::summit());
        assert_eq!(net.allreduce(1, 1000).time_s, 0.0);
    }

    #[test]
    fn allreduce_bandwidth_term_saturates() {
        // Ring allreduce moves 2·(n−1)/n·bytes per GPU regardless of n:
        // time should grow with latency·n but the bandwidth term plateaus.
        let net = SimNet::new(ClusterSpec::summit());
        let p = 25_560_000;
        let t96 = net.allreduce(96, p).time_s;
        let t1008 = net.allreduce(1008, p).time_s;
        assert!(t1008 < t96 * 12.0, "allreduce must not scale linearly with n");
        assert!(t1008 > t96, "latency term still grows");
    }

    #[test]
    fn ada_cost_decays_with_k() {
        let net = SimNet::new(ClusterSpec::summit());
        let n = 96;
        let p = 1_000_000;
        let dense = CommGraph::build(GraphKind::AdaLattice { k: 10 }, n).unwrap();
        let sparse = CommGraph::build(GraphKind::AdaLattice { k: 2 }, n).unwrap();
        let cd = net.epoch_cost(&dense, p, 100);
        let cs = net.epoch_cost(&sparse, p, 100);
        assert!(cs < cd / 3.0, "k=2 must be ≳5× cheaper: {cs} vs {cd}");
    }

    #[test]
    fn exponential_graph_crosses_nodes() {
        // Exponential neighbors at offsets ≥ 8 always leave a 6-GPU node.
        let net = SimNet::new(ClusterSpec::summit());
        let g = CommGraph::build(GraphKind::Exponential, 48).unwrap();
        let c = net.gossip_round(&g, 1000);
        assert!(c.inter_node_bytes > 0);
        assert!(c.inter_node_bytes <= c.total_bytes);
    }
}
