//! Analytic network cost model for a Summit-like cluster.
//!
//! The paper runs on OLCF Summit: 6 V100s per node, NVLink 2.0 at
//! 50 GB/s within a node, EDR InfiniBand at 23 GB/s between nodes
//! (§3.1.1). We cannot occupy 1008 GPUs, but the *communication cost*
//! side of Ada's accuracy/cost trade-off is a deterministic function of
//! the communication graph, message sizes, and these link constants — so
//! we compute it exactly (α–β model: `time = latency + bytes/bandwidth`
//! per message, per-GPU serialized sends, cluster time = max over GPUs).

mod faults;

pub use faults::{CrashEvent, FaultPlan};

use crate::graph::CommGraph;

/// Link constants of the modeled cluster.
#[derive(Debug, Clone, Copy)]
pub struct ClusterSpec {
    /// GPUs per node (Summit: 6).
    pub gpus_per_node: usize,
    /// Intra-node bandwidth, bytes/sec (NVLink 2.0: 50 GB/s).
    pub intra_bw: f64,
    /// Inter-node bandwidth, bytes/sec (EDR IB: 23 GB/s).
    pub inter_bw: f64,
    /// Intra-node message latency, seconds.
    pub intra_lat: f64,
    /// Inter-node message latency, seconds.
    pub inter_lat: f64,
}

impl ClusterSpec {
    /// Summit's published constants (§3.1.1 of the paper).
    pub fn summit() -> Self {
        ClusterSpec {
            gpus_per_node: 6,
            intra_bw: 50e9,
            inter_bw: 23e9,
            intra_lat: 1e-6,
            inter_lat: 5e-6,
        }
    }

    /// Node index hosting GPU `i` (block placement, like jsrun).
    pub fn node_of(&self, gpu: usize) -> usize {
        gpu / self.gpus_per_node
    }

    /// Point-to-point transfer time for `bytes` between two GPUs.
    pub fn p2p_time(&self, from: usize, to: usize, bytes: u64) -> f64 {
        if self.node_of(from) == self.node_of(to) {
            self.intra_lat + bytes as f64 / self.intra_bw
        } else {
            self.inter_lat + bytes as f64 / self.inter_bw
        }
    }
}

/// Per-iteration communication cost of one gossip round or allreduce.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CommCost {
    /// Wall-clock seconds for the round (max over GPUs).
    pub time_s: f64,
    /// Total bytes crossing node boundaries.
    pub inter_node_bytes: u64,
    /// Total bytes moved (all links).
    pub total_bytes: u64,
}

/// Analytic cost model over a [`ClusterSpec`].
#[derive(Debug, Clone)]
pub struct SimNet {
    spec: ClusterSpec,
}

impl SimNet {
    /// Model over `spec`.
    pub fn new(spec: ClusterSpec) -> Self {
        SimNet { spec }
    }

    /// The cluster constants in use.
    pub fn spec(&self) -> &ClusterSpec {
        &self.spec
    }

    /// Cost of one **gossip round** over `graph` exchanging `param_count`
    /// f32 parameters: every GPU sends its parameter vector to each
    /// out-neighbor; sends from one GPU serialize, GPUs overlap.
    pub fn gossip_round(&self, graph: &CommGraph, param_count: usize) -> CommCost {
        self.gossip_round_with(graph, param_count, |_, _| 1.0)
    }

    /// [`SimNet::gossip_round`] with a per-link time scale — the hook
    /// the fault plane uses to inject link jitter
    /// ([`FaultPlan::link_scale`]): each message's transfer time is
    /// multiplied by `link_scale(src, dst)`; byte counts are unchanged
    /// (jitter slows links, it doesn't grow messages).
    pub fn gossip_round_with(
        &self,
        graph: &CommGraph,
        param_count: usize,
        link_scale: impl Fn(usize, usize) -> f64,
    ) -> CommCost {
        self.round_with_bytes(graph, 4 * param_count as u64, link_scale)
    }

    /// Cost of one gossip round with an **explicit message size** — the
    /// compressed-exchange hook: a bf16/f16 path ships
    /// `codec.bytes_per_value() · p` bytes per message, a top-k path
    /// `k · (4 + bytes_per_value)` (index + payload), and this model
    /// prices either without assuming 4-byte values.
    pub fn gossip_round_bytes(&self, graph: &CommGraph, bytes_per_msg: u64) -> CommCost {
        self.round_with_bytes(graph, bytes_per_msg, |_, _| 1.0)
    }

    fn round_with_bytes(
        &self,
        graph: &CommGraph,
        bytes_per_msg: u64,
        link_scale: impl Fn(usize, usize) -> f64,
    ) -> CommCost {
        let mut worst = 0.0f64;
        let mut inter = 0u64;
        let mut total = 0u64;
        for i in 0..graph.n() {
            let mut t = 0.0;
            for &j in graph.neighbors_of(i) {
                t += self.spec.p2p_time(i, j, bytes_per_msg) * link_scale(i, j);
                total += bytes_per_msg;
                if self.spec.node_of(i) != self.spec.node_of(j) {
                    inter += bytes_per_msg;
                }
            }
            worst = worst.max(t);
        }
        CommCost {
            time_s: worst,
            inter_node_bytes: inter,
            total_bytes: total,
        }
    }

    /// Cost of one **ring allreduce** over all `n` GPUs (the centralized
    /// `C_complete` baseline, NCCL-style): the vector splits into `n`
    /// chunks (the first `bytes mod n` chunks one byte larger), and each
    /// GPU pipelines `n−1` reduce-scatter steps then `n−1` all-gather
    /// steps along the ring. Byte counts are exact integer sums per
    /// chunk and per hop: in the reduce-scatter phase GPU `h` sends
    /// every chunk except `(h+1) mod n` across hop `h → h+1`, in the
    /// all-gather phase every chunk except `(h+2) mod n` — so the two
    /// directions contribute *different* chunk sets to an inter-node
    /// hop when chunks are uneven.
    pub fn allreduce(&self, n: usize, param_count: usize) -> CommCost {
        if n <= 1 {
            return CommCost {
                time_s: 0.0,
                inter_node_bytes: 0,
                total_bytes: 0,
            };
        }
        let bytes = 4 * param_count as u64;
        let nn = n as u64;
        let (q, r) = (bytes / nn, bytes % nn);
        let chunk_size = |c: usize| q + u64::from((c as u64) < r);
        // Slowest hop in the block-placement ring: inter-node whenever the
        // cluster spans > 1 node.
        let spans_nodes = self.spec.node_of(n - 1) > 0;
        let (bw, lat) = if spans_nodes {
            (self.spec.inter_bw, self.spec.inter_lat)
        } else {
            (self.spec.intra_bw, self.spec.intra_lat)
        };
        let steps = 2 * (n - 1);
        let max_chunk = q + u64::from(r > 0);
        let time = steps as f64 * (lat + max_chunk as f64 / bw);
        // Each phase moves every chunk across n−1 of the n hops, so each
        // GPU sends bytes − (one chunk) per phase: 2·(n−1)·bytes total.
        let total = 2 * (nn - 1) * bytes;
        // Hop h → (h+1) mod n carries, over both phases, all chunks
        // except (h+1) mod n and all except (h+2) mod n.
        let inter = (0..n)
            .filter(|&h| self.spec.node_of(h) != self.spec.node_of((h + 1) % n))
            .map(|h| 2 * bytes - chunk_size((h + 1) % n) - chunk_size((h + 2) % n))
            .sum();
        CommCost {
            time_s: time,
            inter_node_bytes: inter,
            total_bytes: total,
        }
    }

    /// Per-epoch communication time of a topology schedule (seconds),
    /// used by the fig7 bench to plot Ada's decaying cost.
    pub fn epoch_cost(
        &self,
        graph: &CommGraph,
        param_count: usize,
        iters_per_epoch: usize,
    ) -> f64 {
        self.gossip_round(graph, param_count).time_s * iters_per_epoch as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{CommGraph, GraphKind};

    #[test]
    fn node_placement_is_block() {
        let s = ClusterSpec::summit();
        assert_eq!(s.node_of(0), 0);
        assert_eq!(s.node_of(5), 0);
        assert_eq!(s.node_of(6), 1);
        assert_eq!(s.node_of(1007), 167); // 1008 GPUs = 168 Summit nodes
    }

    #[test]
    fn intra_node_is_faster() {
        let s = ClusterSpec::summit();
        let fast = s.p2p_time(0, 1, 1 << 20);
        let slow = s.p2p_time(0, 6, 1 << 20);
        assert!(fast < slow);
    }

    #[test]
    fn ring_cheaper_than_complete_per_round() {
        // The premise of Ada's late stage: sparse graphs cost less.
        let net = SimNet::new(ClusterSpec::summit());
        let n = 48;
        let p = 1_000_000;
        let ring = net.gossip_round(&CommGraph::build(GraphKind::Ring, n).unwrap(), p);
        let complete = net.gossip_round(&CommGraph::build(GraphKind::Complete, n).unwrap(), p);
        assert!(
            ring.time_s * 5.0 < complete.time_s,
            "ring {} vs complete {}",
            ring.time_s,
            complete.time_s
        );
        assert!(ring.total_bytes < complete.total_bytes);
    }

    #[test]
    fn gossip_cost_scales_with_degree() {
        let net = SimNet::new(ClusterSpec::summit());
        let n = 96;
        let p = 25_560_000; // ResNet50-sized
        let mut prev = 0.0;
        for kind in [GraphKind::Ring, GraphKind::Torus, GraphKind::Exponential] {
            let c = net.gossip_round(&CommGraph::build(kind, n).unwrap(), p);
            assert!(c.time_s > prev, "{kind:?} must cost more than sparser graphs");
            prev = c.time_s;
        }
    }

    #[test]
    fn allreduce_single_gpu_is_free() {
        let net = SimNet::new(ClusterSpec::summit());
        assert_eq!(net.allreduce(1, 1000).time_s, 0.0);
    }

    #[test]
    fn allreduce_bandwidth_term_saturates() {
        // Ring allreduce moves 2·(n−1)/n·bytes per GPU regardless of n:
        // time should grow with latency·n but the bandwidth term plateaus.
        let net = SimNet::new(ClusterSpec::summit());
        let p = 25_560_000;
        let t96 = net.allreduce(96, p).time_s;
        let t1008 = net.allreduce(1008, p).time_s;
        assert!(t1008 < t96 * 12.0, "allreduce must not scale linearly with n");
        assert!(t1008 > t96, "latency term still grows");
    }

    #[test]
    fn ada_cost_decays_with_k() {
        let net = SimNet::new(ClusterSpec::summit());
        let n = 96;
        let p = 1_000_000;
        let dense = CommGraph::build(GraphKind::AdaLattice { k: 10 }, n).unwrap();
        let sparse = CommGraph::build(GraphKind::AdaLattice { k: 2 }, n).unwrap();
        let cd = net.epoch_cost(&dense, p, 100);
        let cs = net.epoch_cost(&sparse, p, 100);
        assert!(cs < cd / 3.0, "k=2 must be ≳5× cheaper: {cs} vs {cd}");
    }

    #[test]
    fn allreduce_byte_accounting_is_exact() {
        let net = SimNet::new(ClusterSpec::summit());
        // n=4, p=10: bytes=40, all intra-node → total 2·3·40, inter 0.
        let c = net.allreduce(4, 10);
        assert_eq!(c.total_bytes, 240);
        assert_eq!(c.inter_node_bytes, 0);
        // n=12, p=12: bytes=48 splits evenly (4 per chunk). The ring
        // crosses nodes at hops 5→6 and 11→0; each inter hop carries
        // 2·48 − 4 − 4 = 88 bytes.
        let c = net.allreduce(12, 12);
        assert_eq!(c.total_bytes, 2 * 11 * 48);
        assert_eq!(c.inter_node_bytes, 176);
        // n=12, p=13: bytes=52 = 4·12 + 4, so chunks 0–3 hold 5 bytes.
        // Hop 5 skips chunks 6 and 7 (4+4): 104−8 = 96; hop 11 skips
        // chunks 0 and 1 (5+5): 104−10 = 94 — the reduce-scatter vs
        // all-gather direction split the truncating f64 version lost.
        let c = net.allreduce(12, 13);
        assert_eq!(c.total_bytes, 2 * 11 * 52);
        assert_eq!(c.inter_node_bytes, 96 + 94);
    }

    #[test]
    fn jittered_gossip_round_only_stretches_time() {
        let net = SimNet::new(ClusterSpec::summit());
        let g = CommGraph::build(GraphKind::Ring, 12).unwrap();
        let base = net.gossip_round(&g, 1000);
        let jittered = net.gossip_round_with(&g, 1000, |i, j| 1.0 + 0.5 * ((i + j) % 3) as f64);
        assert!(jittered.time_s > base.time_s);
        assert_eq!(jittered.total_bytes, base.total_bytes);
        assert_eq!(jittered.inter_node_bytes, base.inter_node_bytes);
        // A unit scale is exactly the plain round.
        let unit = net.gossip_round_with(&g, 1000, |_, _| 1.0);
        assert_eq!(unit, base);
    }

    #[test]
    fn explicit_message_size_prices_compressed_rounds() {
        let net = SimNet::new(ClusterSpec::summit());
        let g = CommGraph::build(GraphKind::Exponential, 48).unwrap();
        let p = 1_000_000;
        let dense = net.gossip_round(&g, p);
        // bf16 halves every message: exactly half the bytes, less time
        // (the latency term doesn't shrink, so not exactly half).
        let bf16 = net.gossip_round_bytes(&g, 2 * p as u64);
        assert_eq!(bf16.total_bytes * 2, dense.total_bytes);
        assert_eq!(bf16.inter_node_bytes * 2, dense.inter_node_bytes);
        assert!(bf16.time_s < dense.time_s);
        assert!(bf16.time_s * 2.0 > dense.time_s, "latency floor remains");
        // The f32 message size reproduces gossip_round bit-for-bit.
        let explicit = net.gossip_round_bytes(&g, 4 * p as u64);
        assert_eq!(explicit, dense);
    }

    #[test]
    fn exponential_graph_crosses_nodes() {
        // Exponential neighbors at offsets ≥ 8 always leave a 6-GPU node.
        let net = SimNet::new(ClusterSpec::summit());
        let g = CommGraph::build(GraphKind::Exponential, 48).unwrap();
        let c = net.gossip_round(&g, 1000);
        assert!(c.inter_node_bytes > 0);
        assert!(c.inter_node_bytes <= c.total_bytes);
    }
}
