//! The compressed / variance-corrected combine strategies:
//! [`CompressedGossip`] (codec + optional top-k exchange),
//! [`D2Combine`] (D², Tang et al. 2018) and [`ConsensusGossip`]
//! (consensus-controlled repeated mixing, Kong et al. 2021).
//!
//! All three are registered in
//! [`crate::coordinator::strategy::registry`] — `compressed_gossip`,
//! `d2`, `consensus_gossip` — and run end-to-end from spec TOML
//! (`[strategy.compressed_gossip]` parameter tables) or the CLIs'
//! `--strategy name:k=v,…` flag through
//! [`crate::dbench::SessionPlan`], so each is benchmarkable against
//! the §3.1.2 five from one grid cell.
//!
//! None of the three supports the fault plane (partial participation /
//! bounded staleness) yet: compressed messages and correction terms
//! interact with renormalized averaging in ways the deterministic
//! replay contract doesn't cover, so those routes fail loudly instead
//! of silently changing semantics.

use super::codec::Codec;
use super::topk::sparsify_row;
use crate::coordinator::strategy::{CombineStrategy, StepCtx};
use crate::error::{AdaError, Result};
use crate::gossip::mean_model;
use crate::graph::CommGraph;
use crate::metrics::consensus_distance;
use crate::util::matrix::ReplicaMatrix;

fn need_graph<'a>(ctx: &StepCtx<'a>, name: &str) -> Result<&'a CommGraph> {
    ctx.graph.ok_or_else(|| {
        AdaError::Coordinator(format!(
            "{name} needs a communication graph (decentralized strategies \
             require a topology schedule)"
        ))
    })
}

fn reject_fault_routes(ctx: &StepCtx<'_>, name: &str) -> Result<()> {
    if ctx.staleness.is_some() || ctx.active.is_some() {
        return Err(AdaError::Coordinator(format!(
            "{name} does not support fault injection (partial participation \
             or bounded staleness) — run it without a fault plan"
        )));
    }
    Ok(())
}

/// Adapt-then-combine gossip whose exchange travels through a lossy
/// [`Codec`], optionally sparsified to the top-k largest-magnitude
/// entries with per-replica error-feedback residuals.
///
/// * Dense (`k = None`): one [`crate::gossip::GossipEngine::mix_codec`]
///   round — every peer row is quantized per tile inside the kernel;
///   the local row never leaves the node and stays f32.
/// * Sparse (`k = Some(_)`): each replica ships the top-k of its
///   error-compensated accumulator ([`sparsify_row`]); peers fold the
///   sparse message through
///   [`crate::gossip::GossipEngine::mix_from`].
///
/// Degenerate configs are bitwise equivalences: `codec = f32, k = None`
/// reproduces dense gossip exactly, and `k = p` with zeroed residuals
/// ships the full row.
pub struct CompressedGossip {
    codec: Codec,
    k: Option<usize>,
    residuals: ReplicaMatrix,
    messages: ReplicaMatrix,
}

impl CompressedGossip {
    /// New strategy; `k = None` is the dense codec path.
    pub fn new(codec: Codec, k: Option<usize>) -> Self {
        CompressedGossip {
            codec,
            k,
            residuals: ReplicaMatrix::default(),
            messages: ReplicaMatrix::default(),
        }
    }

    /// Modeled wire bytes one node sends per round (indices cost 4
    /// bytes each on the sparse path).
    fn bytes_per_node(&self, degree: usize, p: usize) -> u64 {
        let per_msg = match self.k {
            Some(k) => k.min(p) as u64 * (4 + self.codec.bytes_per_value()),
            None => self.codec.bytes_per_value() * p as u64,
        };
        degree as u64 * per_msg
    }
}

impl CombineStrategy for CompressedGossip {
    fn name(&self) -> &str {
        "compressed_gossip"
    }

    fn prepare(&mut self, n: usize, p: usize) -> Result<()> {
        // Residuals restart at zero on every fresh run, like the fused
        // strategy's momentum buffers; the message stash only exists on
        // the sparse path.
        if self.k.is_some() {
            self.residuals = ReplicaMatrix::zeros(n, p);
            self.messages = ReplicaMatrix::zeros(n, p);
        }
        Ok(())
    }

    fn local_phase(
        &mut self,
        ctx: &mut StepCtx<'_>,
        replicas: &mut ReplicaMatrix,
    ) -> Result<f64> {
        let mut loss_sum = 0.0f64;
        for (w, loader) in ctx.loaders.iter().enumerate() {
            let batch = ctx.dataset.batch(&loader.batch_indices(ctx.epoch, ctx.batch));
            let loss = ctx.model.local_step(w, replicas.row_mut(w), &batch, ctx.lr)?;
            loss_sum += loss as f64;
        }
        Ok(loss_sum / ctx.n as f64)
    }

    fn combine_phase(
        &mut self,
        ctx: &mut StepCtx<'_>,
        replicas: &mut ReplicaMatrix,
    ) -> Result<(usize, u64)> {
        let g = need_graph(ctx, "CompressedGossip")?;
        reject_fault_routes(ctx, "CompressedGossip")?;
        match self.k {
            Some(k) => {
                for w in 0..ctx.n {
                    sparsify_row(
                        replicas.row(w),
                        self.residuals.row_mut(w),
                        self.messages.row_mut(w),
                        k,
                    );
                }
                ctx.engine.mix_from(g, replicas, &self.messages, self.codec);
            }
            None => ctx.engine.mix_codec(g, replicas, self.codec),
        }
        Ok((g.degree(), self.bytes_per_node(g.degree(), ctx.param_count)))
    }
}

/// The D² per-row pre-mix transform (Tang et al. 2018, eq. 6):
/// `z_t = 2·x_t − x_{t−1} − γ·g_t + γ·g_{t−1}` (first iteration:
/// `z_0 = x_0 − γ·g_0`), after which the caller mixes `z`. `prev_params`
/// and `prev_grads` are updated in place to `x_t` / `g_t`. Pure scalar
/// elementwise, evaluated left-to-right — bit-identical everywhere.
pub fn d2_transform(
    replicas: &mut ReplicaMatrix,
    prev_params: &mut ReplicaMatrix,
    prev_grads: &mut ReplicaMatrix,
    grads: &ReplicaMatrix,
    lr: f32,
    first: bool,
) {
    for w in 0..replicas.n() {
        let x = replicas.row_mut(w);
        let px = prev_params.row_mut(w);
        let pg = prev_grads.row_mut(w);
        let gw = grads.row(w);
        for i in 0..x.len() {
            let xt = x[i];
            let z = if first {
                xt - lr * gw[i]
            } else {
                2.0 * xt - px[i] - lr * gw[i] + lr * pg[i]
            };
            px[i] = xt;
            x[i] = z;
        }
        pg.copy_from_slice(gw);
    }
}

/// D² / decentralized variance reduction: the previous-iterate
/// correction term `x_t − x_{t−1} + γ·g_{t−1}` cancels the data
/// heterogeneity between replicas that plain D-PSGD averaging leaves
/// behind — exactly the cross-replica parameter variance the paper's
/// obs. 3 identifies as the accuracy bottleneck at scale.
///
/// Requires [`crate::coordinator::LocalModel::loss_and_grad`] (gradient
/// access, like the fused strategy).
pub struct D2Combine {
    prev_params: ReplicaMatrix,
    prev_grads: ReplicaMatrix,
    grads: ReplicaMatrix,
    started: bool,
}

impl D2Combine {
    /// New strategy (state allocated in [`CombineStrategy::prepare`]).
    pub fn new() -> Self {
        D2Combine {
            prev_params: ReplicaMatrix::default(),
            prev_grads: ReplicaMatrix::default(),
            grads: ReplicaMatrix::default(),
            started: false,
        }
    }
}

impl Default for D2Combine {
    fn default() -> Self {
        Self::new()
    }
}

impl CombineStrategy for D2Combine {
    fn name(&self) -> &str {
        "d2"
    }

    fn prepare(&mut self, n: usize, p: usize) -> Result<()> {
        self.prev_params = ReplicaMatrix::zeros(n, p);
        self.prev_grads = ReplicaMatrix::zeros(n, p);
        self.grads = ReplicaMatrix::zeros(n, p);
        self.started = false;
        Ok(())
    }

    fn local_phase(
        &mut self,
        ctx: &mut StepCtx<'_>,
        replicas: &mut ReplicaMatrix,
    ) -> Result<f64> {
        if !ctx.model.supports_loss_and_grad() {
            return Err(AdaError::Coordinator(
                "d2 requires a model with gradient access (loss_and_grad); \
                 this model only exposes a fused local step"
                    .into(),
            ));
        }
        let mut loss_sum = 0.0f64;
        for (w, loader) in ctx.loaders.iter().enumerate() {
            let batch = ctx.dataset.batch(&loader.batch_indices(ctx.epoch, ctx.batch));
            let (loss, g) = ctx.model.loss_and_grad(replicas.row(w), &batch)?;
            loss_sum += loss as f64;
            self.grads.row_mut(w).copy_from_slice(&g);
        }
        Ok(loss_sum / ctx.n as f64)
    }

    fn combine_phase(
        &mut self,
        ctx: &mut StepCtx<'_>,
        replicas: &mut ReplicaMatrix,
    ) -> Result<(usize, u64)> {
        let g = need_graph(ctx, "D2Combine")?;
        reject_fault_routes(ctx, "D2Combine")?;
        d2_transform(
            replicas,
            &mut self.prev_params,
            &mut self.prev_grads,
            &self.grads,
            ctx.lr,
            !self.started,
        );
        self.started = true;
        ctx.engine.mix(g, replicas);
        Ok((g.degree(), g.bytes_sent_per_node(ctx.param_count)))
    }
}

/// Consensus-controlled mixing (Kong et al. 2021): gossip once, then
/// keep mixing — up to `max_rounds` total — until the consensus
/// distance undershoots `target`. The combine-side twin of the
/// topology-side `consensus_decay` policy: that one re-wires the graph
/// on the signal, this one spends extra rounds on a fixed graph.
///
/// `max_rounds = 1` is bitwise-identical to plain gossip (exactly one
/// mix, no distance probe).
pub struct ConsensusGossip {
    target: f64,
    max_rounds: usize,
}

impl ConsensusGossip {
    /// New strategy; `max_rounds` is clamped to at least 1.
    pub fn new(target: f64, max_rounds: usize) -> Self {
        ConsensusGossip {
            target,
            max_rounds: max_rounds.max(1),
        }
    }
}

impl CombineStrategy for ConsensusGossip {
    fn name(&self) -> &str {
        "consensus_gossip"
    }

    fn local_phase(
        &mut self,
        ctx: &mut StepCtx<'_>,
        replicas: &mut ReplicaMatrix,
    ) -> Result<f64> {
        let mut loss_sum = 0.0f64;
        for (w, loader) in ctx.loaders.iter().enumerate() {
            let batch = ctx.dataset.batch(&loader.batch_indices(ctx.epoch, ctx.batch));
            let loss = ctx.model.local_step(w, replicas.row_mut(w), &batch, ctx.lr)?;
            loss_sum += loss as f64;
        }
        Ok(loss_sum / ctx.n as f64)
    }

    fn combine_phase(
        &mut self,
        ctx: &mut StepCtx<'_>,
        replicas: &mut ReplicaMatrix,
    ) -> Result<(usize, u64)> {
        let g = need_graph(ctx, "ConsensusGossip")?;
        reject_fault_routes(ctx, "ConsensusGossip")?;
        ctx.engine.mix(g, replicas);
        let mut rounds = 1u64;
        while (rounds as usize) < self.max_rounds {
            let mean = mean_model(ctx.engine.exec(), replicas);
            if consensus_distance(ctx.engine.exec(), replicas, &mean) <= self.target {
                break;
            }
            ctx.engine.mix(g, replicas);
            rounds += 1;
        }
        Ok((
            g.degree(),
            rounds * g.bytes_sent_per_node(ctx.param_count),
        ))
    }
}
