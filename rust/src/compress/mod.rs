//! Compressed & variance-corrected gossip: the combine-side answer to
//! the paper's obs. 3 (decentralized accuracy tracks the cross-replica
//! parameter variance).
//!
//! Three pieces, composed by the strategies in [`strategies`]:
//!
//! * [`Codec`] — bf16/f16 lossy exchange formats, round-tripped per
//!   tile inside the codec-aware mix kernels
//!   ([`crate::gossip::GossipEngine::mix_codec`] /
//!   [`crate::gossip::GossipEngine::mix_from`]) so the memory-bound
//!   SpMM models a half-width wire without a second matrix copy.
//! * [`topk`] — deterministic top-k magnitude sparsification with
//!   per-replica error-feedback residuals (fixed `(|v| desc, index
//!   asc)` tie-break → bit-identical across thread counts and
//!   SIMD/scalar).
//! * [`CompressedGossip`] / [`D2Combine`] / [`ConsensusGossip`] —
//!   [`crate::coordinator::strategy::CombineStrategy`] implementations
//!   registered as `compressed_gossip`, `d2` and `consensus_gossip`.

mod codec;
mod strategies;
pub mod topk;

pub use codec::{bf16_to_f32, f16_to_f32, f32_to_bf16, f32_to_f16, Codec};
pub use strategies::{d2_transform, CompressedGossip, ConsensusGossip, D2Combine};
