//! Deterministic top-k magnitude sparsification with error feedback.
//!
//! The sparsifier sends only the `k` largest-magnitude entries of the
//! error-compensated accumulator `acc = row + residual` and banks the
//! rest back into the residual (Stich et al. 2018 style memory), so
//! dropped mass re-enters later rounds instead of being lost.
//!
//! **Determinism contract.** Selection orders candidates by the strict
//! total order `(|value| descending, index ascending)`. Magnitudes are
//! compared as the integer bits of `|v|` (monotone with magnitude for
//! non-NaN f32), and the index tie-break makes every key unique — so
//! the *selected set* is the same for any selection algorithm, thread
//! count or SIMD mode, and the update below is pure scalar elementwise
//! bookkeeping. Conservation is bitwise:
//! `message[i] + residual'[i] == row[i] + residual[i]` holds exactly
//! because each entry lands whole in exactly one of the two outputs.

/// Indices of the `k` largest-magnitude entries of `values`, tie-broken
/// by lowest index, returned in ascending index order. `k >= len`
/// selects everything.
pub fn top_k_indices(values: &[f32], k: usize) -> Vec<usize> {
    let k = k.min(values.len());
    let mut idx: Vec<usize> = (0..values.len()).collect();
    if k < values.len() {
        // Strict total order: larger |v| first, then lower index. NaN
        // magnitudes compare above inf (their bit patterns are larger),
        // which is fine — the order stays total and deterministic.
        idx.select_nth_unstable_by(k, |&a, &b| {
            let ma = values[a].abs().to_bits();
            let mb = values[b].abs().to_bits();
            mb.cmp(&ma).then(a.cmp(&b))
        });
        idx.truncate(k);
    }
    idx.sort_unstable();
    idx
}

/// One error-feedback sparsification step for a single replica row.
///
/// Computes `acc = row + residual` elementwise, then splits `acc`
/// whole-entry-wise into `message` (the `k` selected entries, zeros
/// elsewhere) and the updated `residual` (everything unselected).
/// Returns the selected indices (ascending).
pub fn sparsify_row(
    row: &[f32],
    residual: &mut [f32],
    message: &mut [f32],
    k: usize,
) -> Vec<usize> {
    assert_eq!(row.len(), residual.len());
    assert_eq!(row.len(), message.len());
    // Stage the accumulator in `residual` (the default outcome for an
    // entry is "kept back"), then promote the selected entries.
    for ((r, m), &x) in residual.iter_mut().zip(message.iter_mut()).zip(row) {
        *r += x;
        *m = 0.0;
    }
    let selected = top_k_indices(residual, k);
    for &j in &selected {
        message[j] = residual[j];
        residual[j] = 0.0;
    }
    selected
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn selects_largest_magnitudes_with_index_tiebreak() {
        let v = [1.0f32, -3.0, 2.0, -2.0, 0.5];
        assert_eq!(top_k_indices(&v, 1), vec![1]);
        // |2.0| ties with |-2.0|: the lower index (2) wins.
        assert_eq!(top_k_indices(&v, 2), vec![1, 2]);
        assert_eq!(top_k_indices(&v, 3), vec![1, 2, 3]);
        // All-equal magnitudes: the first k indices, in order.
        let flat = [1.0f32; 6];
        assert_eq!(top_k_indices(&flat, 3), vec![0, 1, 2]);
        // k >= len selects everything; k = 0 nothing.
        assert_eq!(top_k_indices(&v, 9), vec![0, 1, 2, 3, 4]);
        assert_eq!(top_k_indices(&v, 0), Vec::<usize>::new());
        assert_eq!(top_k_indices(&[], 3), Vec::<usize>::new());
    }

    #[test]
    fn residual_conservation_is_bitwise() {
        let mut rng = Rng::seed_from_u64(42);
        let p = 513;
        let row: Vec<f32> = (0..p).map(|_| rng.range_f32(-2.0, 2.0)).collect();
        let mut residual: Vec<f32> = (0..p).map(|_| rng.range_f32(-0.1, 0.1)).collect();
        let before = residual.clone();
        let mut message = vec![0.0f32; p];
        let selected = sparsify_row(&row, &mut residual, &mut message, 32);
        assert_eq!(selected.len(), 32);
        for i in 0..p {
            let acc = row[i] + before[i];
            // Each entry lands whole in exactly one output.
            assert_eq!(
                (message[i] + residual[i]).to_bits(),
                acc.to_bits(),
                "conservation at {i}"
            );
            if selected.binary_search(&i).is_ok() {
                assert_eq!(message[i].to_bits(), acc.to_bits());
                assert_eq!(residual[i].to_bits(), 0.0f32.to_bits());
            } else {
                assert_eq!(message[i].to_bits(), 0.0f32.to_bits());
                assert_eq!(residual[i].to_bits(), acc.to_bits());
            }
        }
    }

    #[test]
    fn dropped_mass_reenters_later_rounds() {
        // A small entry ignored in round 1 accumulates in the residual
        // until it out-ranks a fresh large entry — the error-feedback
        // property that distinguishes this from plain top-k.
        let mut residual = vec![0.0f32; 2];
        let mut message = vec![0.0f32; 2];
        for _ in 0..10 {
            let sel = sparsify_row(&[1.0, 0.3], &mut residual, &mut message, 1);
            if sel == vec![1] {
                assert!(message[1] >= 1.0, "banked mass ships when it wins");
                return;
            }
            assert_eq!(sel, vec![0]);
        }
        panic!("residual feedback never promoted the small entry");
    }

    #[test]
    fn k_equal_p_ships_everything_and_zeroes_residual() {
        let mut rng = Rng::seed_from_u64(5);
        let p = 100;
        let row: Vec<f32> = (0..p).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let mut residual: Vec<f32> = (0..p).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let before = residual.clone();
        let mut message = vec![0.0f32; p];
        sparsify_row(&row, &mut residual, &mut message, p);
        for i in 0..p {
            assert_eq!(message[i].to_bits(), (row[i] + before[i]).to_bits());
            assert_eq!(residual[i].to_bits(), 0.0f32.to_bits());
        }
    }
}
