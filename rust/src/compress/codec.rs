//! Lossy exchange codecs for the compressed gossip path.
//!
//! A [`Codec`] names the wire format a replica's parameters travel in
//! during a gossip exchange. The engine never stores compressed
//! matrices: inside the codec-aware mix kernels every peer row is
//! encoded+decoded **per tile** right before it enters the weighted
//! fold ([`crate::gossip::GossipEngine::mix_codec`]), so the lossy
//! quantization models exactly what a real half-precision wire would
//! deliver while the local row (never on the wire) stays f32.
//!
//! Both conversions are **elementwise and scalar**: value `i`'s
//! round-trip depends only on value `i`, so tile boundaries, thread
//! counts and the SIMD dispatch mode cannot change the produced bits —
//! the same determinism contract as the rest of `exec::simd`.
//!
//! * [`Codec::Bf16`] — bfloat16, round-to-nearest-even truncation of
//!   the high 16 f32 bits (full f32 exponent range, 8-bit mantissa).
//! * [`Codec::F16`] — IEEE 754 binary16 with gradual underflow
//!   (denormals) and overflow saturating to ±inf.
//! * [`Codec::F32`] — the identity codec; the compressed strategy with
//!   `codec = "f32"` is bit-identical to dense gossip.

use crate::error::{AdaError, Result};

/// Wire format for gossip exchange (see the module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Codec {
    /// Identity — 4 bytes/value, lossless.
    F32,
    /// bfloat16 — 2 bytes/value, 8-bit mantissa, f32 exponent range.
    Bf16,
    /// IEEE binary16 — 2 bytes/value, 10-bit mantissa, ±65504 range.
    F16,
}

impl Codec {
    /// Parse the spec-TOML / CLI name (`f32` | `bf16` | `f16`).
    pub fn parse(name: &str) -> Result<Codec> {
        match name {
            "f32" => Ok(Codec::F32),
            "bf16" => Ok(Codec::Bf16),
            "f16" => Ok(Codec::F16),
            other => Err(AdaError::Config(format!(
                "unknown codec {other:?} (f32 | bf16 | f16)"
            ))),
        }
    }

    /// The registry/spec name.
    pub fn name(self) -> &'static str {
        match self {
            Codec::F32 => "f32",
            Codec::Bf16 => "bf16",
            Codec::F16 => "f16",
        }
    }

    /// Bytes one value occupies on the wire.
    pub fn bytes_per_value(self) -> u64 {
        match self {
            Codec::F32 => 4,
            Codec::Bf16 | Codec::F16 => 2,
        }
    }

    /// Encode+decode one value — what the receiving peer reconstructs.
    pub fn roundtrip(self, x: f32) -> f32 {
        match self {
            Codec::F32 => x,
            Codec::Bf16 => bf16_to_f32(f32_to_bf16(x)),
            Codec::F16 => f16_to_f32(f32_to_f16(x)),
        }
    }

    /// Round-trip `src` into `dst` (same length), elementwise.
    pub fn roundtrip_into(self, src: &[f32], dst: &mut [f32]) {
        debug_assert_eq!(src.len(), dst.len());
        match self {
            Codec::F32 => dst.copy_from_slice(src),
            Codec::Bf16 => {
                for (d, &s) in dst.iter_mut().zip(src) {
                    *d = bf16_to_f32(f32_to_bf16(s));
                }
            }
            Codec::F16 => {
                for (d, &s) in dst.iter_mut().zip(src) {
                    *d = f16_to_f32(f32_to_f16(s));
                }
            }
        }
    }
}

/// f32 → bfloat16 with round-to-nearest-even; NaN stays NaN (quieted).
pub fn f32_to_bf16(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        // Truncation alone could zero the payload and turn a NaN into
        // ±inf; force a quiet bit instead.
        return ((bits >> 16) as u16) | 0x0040;
    }
    // Round to nearest, ties to even mantissa LSB. Max finite input is
    // 0xFF7F_FFFF so the add cannot overflow u32; finite values beyond
    // the largest bf16 round up to ±inf, matching hardware converters.
    let round = ((bits >> 16) & 1) + 0x7FFF;
    ((bits.wrapping_add(round)) >> 16) as u16
}

/// bfloat16 → f32 (exact: bf16 values are a subset of f32).
pub fn bf16_to_f32(b: u16) -> f32 {
    f32::from_bits((b as u32) << 16)
}

/// f32 → IEEE binary16 with round-to-nearest-even, gradual underflow
/// and overflow saturating to ±inf.
pub fn f32_to_f16(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let man = bits & 0x007F_FFFF;
    if exp == 0xFF {
        // Inf keeps inf; NaN becomes a quiet NaN.
        return if man == 0 { sign | 0x7C00 } else { sign | 0x7E00 };
    }
    let e = exp - 127;
    if e > 15 {
        return sign | 0x7C00; // overflow → ±inf
    }
    if e >= -14 {
        // Normal half: keep the top 10 mantissa bits, RNE on the rest.
        let mut m = man >> 13;
        let rest = man & 0x1FFF;
        if rest > 0x1000 || (rest == 0x1000 && (m & 1) == 1) {
            m += 1;
        }
        let mut he = (e + 15) as u32;
        if m == 0x400 {
            // Mantissa carry: bump the exponent (may overflow to inf).
            m = 0;
            he += 1;
            if he >= 31 {
                return sign | 0x7C00;
            }
        }
        return sign | ((he as u16) << 10) | (m as u16);
    }
    if e < -25 {
        return sign; // below half the smallest subnormal → ±0
    }
    // Subnormal half: shift the implicit-1 significand into place, RNE.
    let full = man | 0x0080_0000;
    let shift = (13 + (-14 - e)) as u32;
    let mut m = full >> shift;
    let rest = full & ((1u32 << shift) - 1);
    let half = 1u32 << (shift - 1);
    if rest > half || (rest == half && (m & 1) == 1) {
        // A carry out of the subnormal range lands exactly on the
        // smallest normal encoding, so no special case is needed.
        m += 1;
    }
    sign | (m as u16)
}

/// IEEE binary16 → f32 (exact: every half value is representable).
pub fn f16_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = (h >> 10) & 0x1F;
    let man = (h & 0x03FF) as u32;
    let bits = if exp == 0x1F {
        sign | 0x7F80_0000 | (man << 13)
    } else if exp == 0 {
        if man == 0 {
            sign
        } else {
            // Subnormal: renormalize into the f32 format.
            let mut e = -14i32;
            let mut m = man;
            while m & 0x0400 == 0 {
                m <<= 1;
                e -= 1;
            }
            sign | (((e + 127) as u32) << 23) | ((m & 0x03FF) << 13)
        }
    } else {
        sign | ((exp as u32 + 112) << 23) | (man << 13)
    };
    f32::from_bits(bits)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn parse_and_names_roundtrip() {
        for c in [Codec::F32, Codec::Bf16, Codec::F16] {
            assert_eq!(Codec::parse(c.name()).unwrap(), c);
        }
        assert!(Codec::parse("int8").is_err());
        assert_eq!(Codec::F32.bytes_per_value(), 4);
        assert_eq!(Codec::Bf16.bytes_per_value(), 2);
        assert_eq!(Codec::F16.bytes_per_value(), 2);
    }

    #[test]
    fn exactly_representable_values_pass_through() {
        // Small integers, powers of two and simple fractions fit both
        // half formats exactly.
        for v in [0.0f32, -0.0, 1.0, -1.0, 0.5, 2.0, -4.0, 0.25, 96.0, -1024.0] {
            assert_eq!(Codec::Bf16.roundtrip(v).to_bits(), v.to_bits(), "bf16 {v}");
            assert_eq!(Codec::F16.roundtrip(v).to_bits(), v.to_bits(), "f16 {v}");
            assert_eq!(Codec::F32.roundtrip(v).to_bits(), v.to_bits(), "f32 {v}");
        }
    }

    #[test]
    fn relative_error_is_bounded() {
        // One ulp at 8 mantissa bits (bf16) is 2^-8; at 10 bits (f16,
        // normal range) 2^-10. Half-ulp rounding → bounds below.
        let mut rng = Rng::seed_from_u64(7);
        for _ in 0..10_000 {
            let v = rng.range_f32(-100.0, 100.0);
            if v == 0.0 {
                continue;
            }
            let rb = Codec::Bf16.roundtrip(v);
            let rh = Codec::F16.roundtrip(v);
            assert!(((rb - v) / v).abs() <= 1.0 / 256.0, "bf16 {v} -> {rb}");
            assert!(((rh - v) / v).abs() <= 1.0 / 1024.0, "f16 {v} -> {rh}");
        }
    }

    #[test]
    fn roundtrip_is_monotone() {
        // Quantization must preserve ordering: x <= y ⇒ q(x) <= q(y).
        // Sample an ordered grid crossing zero, the f16 subnormal range
        // and both formats' rounding boundaries.
        let mut grid = Vec::new();
        let mut rng = Rng::seed_from_u64(11);
        for _ in 0..4_000 {
            grid.push(rng.range_f32(-2.0, 2.0));
        }
        for m in 0..200 {
            grid.push((m as f32) * 1e-8); // deep inside f16 subnormals
            grid.push(65_000.0 + m as f32 * 10.0); // f16 overflow edge
        }
        grid.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for codec in [Codec::Bf16, Codec::F16] {
            let q: Vec<f32> = grid.iter().map(|&v| codec.roundtrip(v)).collect();
            for w in q.windows(2) {
                assert!(w[0] <= w[1], "{codec:?}: {} > {}", w[0], w[1]);
            }
        }
    }

    #[test]
    fn specials_and_saturation() {
        for codec in [Codec::Bf16, Codec::F16] {
            assert_eq!(codec.roundtrip(f32::INFINITY), f32::INFINITY);
            assert_eq!(codec.roundtrip(f32::NEG_INFINITY), f32::NEG_INFINITY);
            assert!(codec.roundtrip(f32::NAN).is_nan());
            assert_eq!(codec.roundtrip(0.0).to_bits(), 0.0f32.to_bits());
            assert_eq!(codec.roundtrip(-0.0).to_bits(), (-0.0f32).to_bits());
        }
        // f16 overflows to inf past ~65504; bf16 keeps the exponent.
        assert_eq!(Codec::F16.roundtrip(1.0e6), f32::INFINITY);
        assert_eq!(Codec::F16.roundtrip(-1.0e6), f32::NEG_INFINITY);
        assert_eq!(Codec::F16.roundtrip(65504.0), 65504.0);
        assert!(Codec::Bf16.roundtrip(1.0e6).is_finite());
        // f16 gradual underflow: the smallest subnormal survives.
        let tiny = f16_to_f32(1); // 2^-24
        assert_eq!(Codec::F16.roundtrip(tiny), tiny);
        assert_eq!(Codec::F16.roundtrip(tiny * 0.25), 0.0);
    }

    #[test]
    fn roundtrip_into_matches_scalar() {
        let mut rng = Rng::seed_from_u64(3);
        let src: Vec<f32> = (0..777).map(|_| rng.range_f32(-5.0, 5.0)).collect();
        for codec in [Codec::F32, Codec::Bf16, Codec::F16] {
            let mut dst = vec![0.0f32; src.len()];
            codec.roundtrip_into(&src, &mut dst);
            for (i, (&d, &s)) in dst.iter().zip(&src).enumerate() {
                assert_eq!(d.to_bits(), codec.roundtrip(s).to_bits(), "{codec:?} [{i}]");
            }
        }
    }
}
