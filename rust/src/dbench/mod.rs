//! DBench — the controlled-experiment harness of §3.
//!
//! An [`ExperimentSpec`] names a workload (one of the paper's four
//! application analogs, or an HLO artifact model), a set of training
//! scales, and a set of SGD flavors; [`run_experiment`] executes the
//! full grid with a shared seed and returns per-cell records + summaries
//! — the data behind Figures 2–5 and 7.
//!
//! Execution runs on the [`SessionPlan`] pipeline: the spec's grid is
//! enumerated into per-cell plans (each with its own seed and config),
//! strategies resolve by name against an extensible registry, cells can
//! execute in parallel (opt-in, bounded by the core count) and persist
//! individually for resumable sweeps. `run_experiment` is the
//! sequential, non-persistent default over that pipeline.

mod plan;
mod spec;

pub use plan::{fingerprint, CellPlan, SessionPlan, StrategyRef, TopologyRef};
pub use spec::{ExperimentSpec, Workload};

use crate::coordinator::{SgdFlavor, TrainConfig, Trainer};
use crate::error::Result;
use crate::metrics::{RankSummary, RunRecorder};
use crate::coordinator::trainer::RunSummary;

/// One grid cell: a workload trained at one scale with one SGD flavor.
#[derive(Debug)]
pub struct CellResult {
    /// Training scale (worker count).
    pub scale: usize,
    /// Flavor name (`C_complete`, `D_ring`, …).
    pub flavor: String,
    /// Per-iteration records.
    pub recorder: RunRecorder,
    /// Run summary.
    pub summary: RunSummary,
}

/// Run the full grid of `spec` through the [`SessionPlan`] pipeline.
/// Cells run sequentially (each cell's workers already parallelize
/// internally); the same seed is reused so all flavors at a scale see
/// identical data, sharding, and init — the controlled-experiment
/// discipline of §3.1. Build the plan directly for parallel or
/// resumable execution, or to train registry strategies the closed
/// flavor list cannot name.
pub fn run_experiment(spec: &ExperimentSpec) -> Result<Vec<CellResult>> {
    SessionPlan::from_spec(spec).run()
}

/// Run a single cell.
pub fn run_cell(spec: &ExperimentSpec, scale: usize, flavor: &SgdFlavor) -> Result<CellResult> {
    let dataset = spec.workload.dataset(spec.seed)?;
    let mut model = spec.workload.model(scale)?;
    let config: TrainConfig = spec.train_config(scale);
    let mut trainer = Trainer::new(model.as_mut(), config);
    let (recorder, summary) = trainer.run(dataset.as_ref(), flavor)?;
    Ok(CellResult {
        scale,
        flavor: flavor.name(),
        recorder,
        summary,
    })
}

/// The §3.3 ranking analysis over the cells of one scale: for every
/// iteration where all flavors have a gini sample, rank them 1..m and
/// accumulate. Returns the Fig. 5-style summary.
pub fn rank_analysis<'a>(cells: impl IntoIterator<Item = &'a CellResult>) -> RankSummary {
    let cells: Vec<&CellResult> = cells.into_iter().collect();
    let mut summary = RankSummary::new();
    if cells.is_empty() {
        return summary;
    }
    let min_len = cells
        .iter()
        .map(|c| c.recorder.records().len())
        .min()
        .unwrap_or(0);
    for i in 0..min_len {
        let entries: Vec<(&str, f64)> = cells
            .iter()
            .map(|c| (c.flavor.as_str(), c.recorder.records()[i].variance.gini))
            .collect();
        summary.record(&entries);
    }
    summary
}

/// One `(scale, flavor)` group of seed-replicated cells, folded into
/// mean ± standard-error estimates — the variance-of-the-estimate view
/// the paper's single-seed tables lack.
#[derive(Debug, Clone)]
pub struct CellStats {
    /// Training scale (worker count).
    pub scale: usize,
    /// Flavor / strategy label.
    pub flavor: String,
    /// Number of seed replicates folded in.
    pub seeds: usize,
    /// Mean final metric across seeds.
    pub mean_metric: f64,
    /// Standard error of the final metric (0 for a single seed).
    pub stderr_metric: f64,
    /// Mean final loss across seeds.
    pub mean_loss: f64,
    /// Standard error of the final loss.
    pub stderr_loss: f64,
    /// Mean bytes sent per node.
    pub mean_bytes_per_node: f64,
    /// How many replicates diverged.
    pub diverged: usize,
}

fn mean_stderr(xs: &[f64]) -> (f64, f64) {
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let n = xs.len() as f64;
    let mean = xs.iter().sum::<f64>() / n;
    if xs.len() < 2 {
        return (mean, 0.0);
    }
    let var = xs.iter().map(|&x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1.0);
    (mean, (var / n).sqrt())
}

/// Fold seed-replicated cells (see [`SessionPlan::expand_seeds`]) into
/// one [`CellStats`] row per `(scale, flavor)` group, preserving first
/// appearance order. Works on single-seed runs too (stderr 0).
///
/// Diverged replicates are **excluded** from the metric/loss estimates
/// (their NaN losses would poison the whole row) and reported through
/// [`CellStats::diverged`] instead; a row whose every replicate
/// diverged gets NaN means.
pub fn seed_stats(cells: &[CellResult]) -> Vec<CellStats> {
    let mut order: Vec<(usize, &str)> = Vec::new();
    for c in cells {
        if !order.iter().any(|&(s, f)| s == c.scale && f == c.flavor) {
            order.push((c.scale, &c.flavor));
        }
    }
    order
        .into_iter()
        .map(|(scale, flavor)| {
            let group: Vec<&CellResult> = cells
                .iter()
                .filter(|c| c.scale == scale && c.flavor == flavor)
                .collect();
            let healthy: Vec<&&CellResult> =
                group.iter().filter(|c| !c.summary.diverged).collect();
            let metrics: Vec<f64> =
                healthy.iter().map(|c| c.summary.final_eval.metric).collect();
            let losses: Vec<f64> =
                healthy.iter().map(|c| c.summary.final_eval.loss).collect();
            let (mean_metric, stderr_metric) = if healthy.is_empty() {
                (f64::NAN, 0.0)
            } else {
                mean_stderr(&metrics)
            };
            let (mean_loss, stderr_loss) = if healthy.is_empty() {
                (f64::NAN, 0.0)
            } else {
                mean_stderr(&losses)
            };
            let bytes =
                group.iter().map(|c| c.summary.bytes_per_node as f64).sum::<f64>()
                    / group.len() as f64;
            CellStats {
                scale,
                flavor: flavor.to_string(),
                seeds: group.len(),
                mean_metric,
                stderr_metric,
                mean_loss,
                stderr_loss,
                mean_bytes_per_node: bytes,
                diverged: group.len() - healthy.len(),
            }
        })
        .collect()
}

/// Render seed statistics as an aligned text table with mean ± stderr
/// columns (the k-seeds-per-cell companion of [`format_table`]).
pub fn format_stats_table(title: &str, stats: &[CellStats]) -> String {
    let mut out = String::new();
    out.push_str(&format!("== {title} ==\n"));
    out.push_str(&format!(
        "{:<8} {:<24} {:>6} {:>20} {:>20} {:>14} {:>6}\n",
        "scale", "flavor", "seeds", "metric (mean±se)", "loss (mean±se)", "MB/node", "div"
    ));
    for s in stats {
        out.push_str(&format!(
            "{:<8} {:<24} {:>6} {:>12.4}±{:<7.4} {:>12.4}±{:<7.4} {:>14.2} {:>6}\n",
            s.scale,
            s.flavor,
            s.seeds,
            s.mean_metric,
            s.stderr_metric,
            s.mean_loss,
            s.stderr_loss,
            s.mean_bytes_per_node / 1e6,
            s.diverged,
        ));
    }
    out
}

/// Render cells as an aligned text table (the bench harness output).
pub fn format_table(title: &str, cells: &[CellResult]) -> String {
    let mut out = String::new();
    out.push_str(&format!("== {title} ==\n"));
    out.push_str(&format!(
        "{:<8} {:<16} {:>10} {:>10} {:>12} {:>12} {:>14}\n",
        "scale", "flavor", "metric", "loss", "early_gini", "late_gini", "MB/node"
    ));
    for c in cells {
        out.push_str(&format!(
            "{:<8} {:<16} {:>10.4} {:>10.4} {:>12.6} {:>12.6} {:>14.2}{}\n",
            c.scale,
            c.flavor,
            c.summary.final_eval.metric,
            c.summary.final_eval.loss,
            c.summary.early_gini,
            c.summary.late_gini,
            c.summary.bytes_per_node as f64 / 1e6,
            if c.summary.diverged { "  DIVERGED" } else { "" },
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_spec() -> ExperimentSpec {
        let mut s = ExperimentSpec::resnet20_analog();
        s.scales = vec![4];
        s.epochs = 2;
        s.max_iters_per_epoch = Some(4);
        s.flavors = vec![
            SgdFlavor::DecentralizedRing,
            SgdFlavor::DecentralizedComplete,
        ];
        s
    }

    #[test]
    fn grid_runs_all_cells() {
        let spec = tiny_spec();
        let cells = run_experiment(&spec).unwrap();
        assert_eq!(cells.len(), 2);
        assert_eq!(cells[0].flavor, "D_ring");
        assert_eq!(cells[1].flavor, "D_complete");
        for c in &cells {
            assert!(!c.recorder.records().is_empty());
        }
    }

    #[test]
    fn same_seed_same_data_across_flavors() {
        // Controlled experiment: both flavors must see the same initial
        // loss (identical init + identical first batches).
        let spec = tiny_spec();
        let cells = run_experiment(&spec).unwrap();
        let l0 = cells[0].recorder.records()[0].train_loss;
        let l1 = cells[1].recorder.records()[0].train_loss;
        assert!((l0 - l1).abs() < 1e-9, "{l0} vs {l1}");
    }

    #[test]
    fn fused_grid_runs_and_stays_deterministic() {
        // The fused execution mode through the full DBench harness:
        // identical results at 1 and 4 threads.
        let run = |threads: usize| {
            let mut spec = tiny_spec();
            spec.fused = true;
            spec.threads = threads;
            run_experiment(&spec).unwrap()
        };
        let a = run(1);
        let b = run(4);
        assert_eq!(a.len(), b.len());
        for (ca, cb) in a.iter().zip(&b) {
            assert_eq!(
                ca.summary.final_eval.metric, cb.summary.final_eval.metric,
                "{} differs across thread counts",
                ca.flavor
            );
        }
    }

    #[test]
    fn rank_analysis_produces_full_counts() {
        let spec = tiny_spec();
        let cells = run_experiment(&spec).unwrap();
        let ranks = rank_analysis(&cells);
        assert!(ranks.count("D_ring") > 0);
        assert_eq!(ranks.count("D_ring"), ranks.count("D_complete"));
    }

    #[test]
    fn seed_stats_fold_replicates_into_mean_and_stderr() {
        let mut spec = tiny_spec();
        spec.flavors = vec![SgdFlavor::DecentralizedRing];
        let mut plan = SessionPlan::from_spec(&spec);
        plan.expand_seeds(3);
        assert_eq!(plan.cells.len(), 3, "one cell × 3 seed replicates");
        let seeds: Vec<u64> = plan.cells.iter().map(|c| c.seed).collect();
        assert_eq!(seeds, vec![spec.seed, spec.seed + 1, spec.seed + 2]);
        let cells = plan.run().unwrap();
        let stats = seed_stats(&cells);
        assert_eq!(stats.len(), 1, "replicates fold back into one row");
        let s = &stats[0];
        assert_eq!(s.seeds, 3);
        assert_eq!(s.flavor, "D_ring");
        assert!(
            s.stderr_metric > 0.0,
            "different seeds must disperse the estimate: {}",
            s.stderr_metric
        );
        let within = cells
            .iter()
            .all(|c| (c.summary.final_eval.metric - s.mean_metric).abs() < 0.5);
        assert!(within, "mean must sit among the replicates");
        let table = format_stats_table("stats", &stats);
        assert!(table.contains('±'), "{table}");
        assert!(table.contains("D_ring"), "{table}");
    }

    #[test]
    fn seed_stats_exclude_diverged_replicates_from_the_estimates() {
        use crate::coordinator::EvalResult;
        let cell = |metric: f64, loss: f64, diverged: bool| CellResult {
            scale: 8,
            flavor: "D_ring".into(),
            recorder: RunRecorder::in_memory("D_ring"),
            summary: crate::coordinator::RunSummary {
                flavor: "D_ring".into(),
                final_eval: EvalResult { loss, metric },
                diverged,
                bytes_per_node: 100,
                early_gini: 0.0,
                late_gini: 0.0,
            },
        };
        let cells = vec![
            cell(0.8, 0.5, false),
            cell(0.6, 0.7, false),
            cell(f64::NAN, f64::NAN, true), // must not poison the row
        ];
        let stats = seed_stats(&cells);
        assert_eq!(stats.len(), 1);
        let s = &stats[0];
        assert_eq!(s.seeds, 3);
        assert_eq!(s.diverged, 1);
        assert!((s.mean_metric - 0.7).abs() < 1e-12, "{}", s.mean_metric);
        assert!((s.mean_loss - 0.6).abs() < 1e-12, "{}", s.mean_loss);
        assert!(s.stderr_metric.is_finite() && s.stderr_metric > 0.0);
        // All replicates diverged: NaN means, but the row still exists.
        let all_bad = vec![cell(f64::NAN, f64::NAN, true)];
        let s = &seed_stats(&all_bad)[0];
        assert!(s.mean_metric.is_nan());
        assert_eq!(s.diverged, 1);
    }

    #[test]
    fn seed_stats_on_single_seed_runs_have_zero_stderr() {
        let spec = tiny_spec();
        let cells = run_experiment(&spec).unwrap();
        let stats = seed_stats(&cells);
        assert_eq!(stats.len(), cells.len());
        for s in &stats {
            assert_eq!(s.seeds, 1);
            assert_eq!(s.stderr_metric, 0.0);
        }
    }

    #[test]
    fn table_formatting_contains_rows() {
        let spec = tiny_spec();
        let cells = run_experiment(&spec).unwrap();
        let table = format_table("test", &cells);
        assert!(table.contains("D_ring"));
        assert!(table.contains("MB/node"));
    }
}
