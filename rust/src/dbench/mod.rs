//! DBench — the controlled-experiment harness of §3.
//!
//! An [`ExperimentSpec`] names a workload (one of the paper's four
//! application analogs, or an HLO artifact model), a set of training
//! scales, and a set of SGD flavors; [`run_experiment`] executes the
//! full grid with a shared seed and returns per-cell records + summaries
//! — the data behind Figures 2–5 and 7.
//!
//! Execution runs on the [`SessionPlan`] pipeline: the spec's grid is
//! enumerated into per-cell plans (each with its own seed and config),
//! strategies resolve by name against an extensible registry, cells can
//! execute in parallel (opt-in, bounded by the core count) and persist
//! individually for resumable sweeps. `run_experiment` is the
//! sequential, non-persistent default over that pipeline.

mod plan;
mod spec;

pub use plan::{CellPlan, SessionPlan, StrategyRef};
pub use spec::{ExperimentSpec, Workload};

use crate::coordinator::{SgdFlavor, TrainConfig, Trainer};
use crate::error::Result;
use crate::metrics::{RankSummary, RunRecorder};
use crate::coordinator::trainer::RunSummary;

/// One grid cell: a workload trained at one scale with one SGD flavor.
#[derive(Debug)]
pub struct CellResult {
    /// Training scale (worker count).
    pub scale: usize,
    /// Flavor name (`C_complete`, `D_ring`, …).
    pub flavor: String,
    /// Per-iteration records.
    pub recorder: RunRecorder,
    /// Run summary.
    pub summary: RunSummary,
}

/// Run the full grid of `spec` through the [`SessionPlan`] pipeline.
/// Cells run sequentially (each cell's workers already parallelize
/// internally); the same seed is reused so all flavors at a scale see
/// identical data, sharding, and init — the controlled-experiment
/// discipline of §3.1. Build the plan directly for parallel or
/// resumable execution, or to train registry strategies the closed
/// flavor list cannot name.
pub fn run_experiment(spec: &ExperimentSpec) -> Result<Vec<CellResult>> {
    SessionPlan::from_spec(spec).run()
}

/// Run a single cell.
pub fn run_cell(spec: &ExperimentSpec, scale: usize, flavor: &SgdFlavor) -> Result<CellResult> {
    let dataset = spec.workload.dataset(spec.seed)?;
    let mut model = spec.workload.model(scale)?;
    let config: TrainConfig = spec.train_config(scale);
    let mut trainer = Trainer::new(model.as_mut(), config);
    let (recorder, summary) = trainer.run(dataset.as_ref(), flavor)?;
    Ok(CellResult {
        scale,
        flavor: flavor.name(),
        recorder,
        summary,
    })
}

/// The §3.3 ranking analysis over the cells of one scale: for every
/// iteration where all flavors have a gini sample, rank them 1..m and
/// accumulate. Returns the Fig. 5-style summary.
pub fn rank_analysis<'a>(cells: impl IntoIterator<Item = &'a CellResult>) -> RankSummary {
    let cells: Vec<&CellResult> = cells.into_iter().collect();
    let mut summary = RankSummary::new();
    if cells.is_empty() {
        return summary;
    }
    let min_len = cells
        .iter()
        .map(|c| c.recorder.records().len())
        .min()
        .unwrap_or(0);
    for i in 0..min_len {
        let entries: Vec<(&str, f64)> = cells
            .iter()
            .map(|c| (c.flavor.as_str(), c.recorder.records()[i].variance.gini))
            .collect();
        summary.record(&entries);
    }
    summary
}

/// Render cells as an aligned text table (the bench harness output).
pub fn format_table(title: &str, cells: &[CellResult]) -> String {
    let mut out = String::new();
    out.push_str(&format!("== {title} ==\n"));
    out.push_str(&format!(
        "{:<8} {:<16} {:>10} {:>10} {:>12} {:>12} {:>14}\n",
        "scale", "flavor", "metric", "loss", "early_gini", "late_gini", "MB/node"
    ));
    for c in cells {
        out.push_str(&format!(
            "{:<8} {:<16} {:>10.4} {:>10.4} {:>12.6} {:>12.6} {:>14.2}{}\n",
            c.scale,
            c.flavor,
            c.summary.final_eval.metric,
            c.summary.final_eval.loss,
            c.summary.early_gini,
            c.summary.late_gini,
            c.summary.bytes_per_node as f64 / 1e6,
            if c.summary.diverged { "  DIVERGED" } else { "" },
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_spec() -> ExperimentSpec {
        let mut s = ExperimentSpec::resnet20_analog();
        s.scales = vec![4];
        s.epochs = 2;
        s.max_iters_per_epoch = Some(4);
        s.flavors = vec![
            SgdFlavor::DecentralizedRing,
            SgdFlavor::DecentralizedComplete,
        ];
        s
    }

    #[test]
    fn grid_runs_all_cells() {
        let spec = tiny_spec();
        let cells = run_experiment(&spec).unwrap();
        assert_eq!(cells.len(), 2);
        assert_eq!(cells[0].flavor, "D_ring");
        assert_eq!(cells[1].flavor, "D_complete");
        for c in &cells {
            assert!(!c.recorder.records().is_empty());
        }
    }

    #[test]
    fn same_seed_same_data_across_flavors() {
        // Controlled experiment: both flavors must see the same initial
        // loss (identical init + identical first batches).
        let spec = tiny_spec();
        let cells = run_experiment(&spec).unwrap();
        let l0 = cells[0].recorder.records()[0].train_loss;
        let l1 = cells[1].recorder.records()[0].train_loss;
        assert!((l0 - l1).abs() < 1e-9, "{l0} vs {l1}");
    }

    #[test]
    fn fused_grid_runs_and_stays_deterministic() {
        // The fused execution mode through the full DBench harness:
        // identical results at 1 and 4 threads.
        let run = |threads: usize| {
            let mut spec = tiny_spec();
            spec.fused = true;
            spec.threads = threads;
            run_experiment(&spec).unwrap()
        };
        let a = run(1);
        let b = run(4);
        assert_eq!(a.len(), b.len());
        for (ca, cb) in a.iter().zip(&b) {
            assert_eq!(
                ca.summary.final_eval.metric, cb.summary.final_eval.metric,
                "{} differs across thread counts",
                ca.flavor
            );
        }
    }

    #[test]
    fn rank_analysis_produces_full_counts() {
        let spec = tiny_spec();
        let cells = run_experiment(&spec).unwrap();
        let ranks = rank_analysis(&cells);
        assert!(ranks.count("D_ring") > 0);
        assert_eq!(ranks.count("D_ring"), ranks.count("D_complete"));
    }

    #[test]
    fn table_formatting_contains_rows() {
        let spec = tiny_spec();
        let cells = run_experiment(&spec).unwrap();
        let table = format_table("test", &cells);
        assert!(table.contains("D_ring"));
        assert!(table.contains("MB/node"));
    }
}
