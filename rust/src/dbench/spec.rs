//! Experiment specifications: the four application analogs of Table 2/3
//! plus HLO-artifact workloads, with TOML-loadable parameters.

use super::plan::{StrategyRef, TopologyRef};
use crate::coordinator::surrogate::{BigramLm, MlpClassifier, SoftmaxRegression};
#[cfg(feature = "pjrt")]
use crate::coordinator::HloModel;
use crate::coordinator::{LocalModel, SgdFlavor, StrategyParams};
use crate::coordinator::trainer::{LrPolicy, TrainConfig};
use crate::data::{Dataset, ShardStrategy, SyntheticClassification, SyntheticLm};
use crate::error::{AdaError, Result};
use crate::optim::ScalingRule;
#[cfg(feature = "pjrt")]
use crate::runtime::PjRtRuntime;
use crate::util::json::Value;
use crate::util::params::ParamTable;
use crate::util::tomlmini::{TomlDoc, TomlValue};

/// The workload of an experiment: which model family + synthetic dataset.
#[derive(Debug, Clone)]
pub enum Workload {
    /// ResNet20/CIFAR10 analog: linear softmax classifier on Gaussian
    /// class clusters (smallest model of the family).
    SoftmaxImage {
        /// Dataset size.
        n_examples: usize,
        /// Feature width.
        dim: usize,
        /// Classes.
        classes: usize,
        /// Class separation (difficulty dial).
        separation: f32,
        /// Train batch rows per worker.
        batch: usize,
        /// Eval batch rows.
        eval_batch: usize,
        /// Momentum coefficient.
        momentum: f32,
    },
    /// DenseNet100/ResNet50 analog: one-hidden-layer MLP.
    MlpImage {
        /// Dataset size.
        n_examples: usize,
        /// Feature width.
        dim: usize,
        /// Hidden width.
        hidden: usize,
        /// Classes.
        classes: usize,
        /// Class separation.
        separation: f32,
        /// Train batch rows per worker.
        batch: usize,
        /// Eval batch rows.
        eval_batch: usize,
        /// Momentum coefficient.
        momentum: f32,
    },
    /// LSTM/WikiText2 analog: bigram LM on Markov-chain text.
    BigramText {
        /// Number of sequences.
        n_seq: usize,
        /// Tokens per sequence.
        seq_len: usize,
        /// Vocabulary.
        vocab: usize,
        /// Markov branching factor.
        branching: usize,
        /// Train batch rows per worker.
        batch: usize,
        /// Eval batch rows.
        eval_batch: usize,
        /// Momentum coefficient.
        momentum: f32,
    },
    /// An AOT-compiled HLO model from `artifacts/<name>/` (the
    /// production path; dataset synthesized to match its manifest).
    Hlo {
        /// Artifact model name.
        name: String,
        /// Dataset size to synthesize.
        n_examples: usize,
        /// Artifact root (default `artifacts`).
        artifact_dir: String,
    },
}

impl Workload {
    /// Construct the synthetic dataset for this workload.
    pub fn dataset(&self, seed: u64) -> Result<Box<dyn Dataset>> {
        Ok(match self {
            Workload::SoftmaxImage {
                n_examples,
                dim,
                classes,
                separation,
                ..
            } => Box::new(SyntheticClassification::generate(
                *n_examples,
                *dim,
                *classes,
                *separation,
                seed,
            )),
            Workload::MlpImage {
                n_examples,
                dim,
                classes,
                separation,
                ..
            } => Box::new(SyntheticClassification::generate(
                *n_examples,
                *dim,
                *classes,
                *separation,
                seed,
            )),
            Workload::BigramText {
                n_seq,
                seq_len,
                vocab,
                branching,
                ..
            } => Box::new(SyntheticLm::generate(
                *n_seq, *seq_len, *vocab, *branching, seed,
            )),
            Workload::Hlo {
                name,
                n_examples,
                artifact_dir,
            } => {
                let manifest = crate::runtime::ModelManifest::load(
                    &std::path::Path::new(artifact_dir)
                        .join(name)
                        .join("manifest.json"),
                )?;
                match manifest.kind {
                    crate::runtime::ModelKind::Classification => {
                        Box::new(SyntheticClassification::generate(
                            *n_examples,
                            manifest.x_dim,
                            manifest.num_outputs,
                            3.0,
                            seed,
                        ))
                    }
                    crate::runtime::ModelKind::Lm => Box::new(SyntheticLm::generate(
                        *n_examples,
                        manifest.x_dim,
                        manifest.num_outputs,
                        2,
                        seed,
                    )),
                }
            }
        })
    }

    /// Construct the model for `n_workers` worker slots.
    pub fn model(&self, n_workers: usize) -> Result<Box<dyn LocalModel>> {
        Ok(match self {
            Workload::SoftmaxImage {
                dim,
                classes,
                batch,
                eval_batch,
                momentum,
                ..
            } => Box::new(SoftmaxRegression::new(
                *dim, *classes, *batch, *eval_batch, n_workers, *momentum,
            )),
            Workload::MlpImage {
                dim,
                hidden,
                classes,
                batch,
                eval_batch,
                momentum,
                ..
            } => Box::new(MlpClassifier::new(
                *dim, *hidden, *classes, *batch, *eval_batch, n_workers, *momentum,
            )),
            Workload::BigramText {
                vocab,
                seq_len,
                batch,
                eval_batch,
                momentum,
                ..
            } => Box::new(BigramLm::new(
                *vocab, *seq_len, *batch, *eval_batch, n_workers, *momentum,
            )),
            #[cfg(feature = "pjrt")]
            Workload::Hlo {
                name, artifact_dir, ..
            } => {
                let rt = PjRtRuntime::cpu(artifact_dir)?;
                Box::new(HloModel::new(rt.load_model(name)?))
            }
            #[cfg(not(feature = "pjrt"))]
            Workload::Hlo { name, .. } => {
                return Err(AdaError::Runtime(format!(
                    "workload hlo:{name} needs the `pjrt` feature \
                     (build with `--features pjrt`)"
                )));
            }
        })
    }

    /// Short identifier for reports.
    pub fn name(&self) -> String {
        match self {
            Workload::SoftmaxImage { .. } => "softmax_image".into(),
            Workload::MlpImage { .. } => "mlp_image".into(),
            Workload::BigramText { .. } => "bigram_text".into(),
            Workload::Hlo { name, .. } => format!("hlo:{name}"),
        }
    }

    /// Train batch rows per worker.
    pub fn batch_size(&self) -> usize {
        match self {
            Workload::SoftmaxImage { batch, .. }
            | Workload::MlpImage { batch, .. }
            | Workload::BigramText { batch, .. } => *batch,
            Workload::Hlo { .. } => 0, // fixed by the artifact manifest
        }
    }

    /// Grow the synthetic dataset to at least `min` examples/sequences
    /// (never shrinks). The scale sweeps use this so shards stay
    /// non-degenerate at n=512–1024: paired with
    /// [`ExperimentSpec::max_iters_per_epoch`], large scales get enough
    /// data per shard while small scales keep bounded epochs.
    pub fn ensure_examples(&mut self, min: usize) {
        match self {
            Workload::SoftmaxImage { n_examples, .. }
            | Workload::MlpImage { n_examples, .. }
            | Workload::Hlo { n_examples, .. } => *n_examples = (*n_examples).max(min),
            Workload::BigramText { n_seq, .. } => *n_seq = (*n_seq).max(min),
        }
    }
}

/// A full DBench experiment: workload × scales × flavors.
#[derive(Debug, Clone)]
pub struct ExperimentSpec {
    /// Experiment name (used in output paths and tables).
    pub name: String,
    /// Workload.
    pub workload: Workload,
    /// Training scales (the paper uses 12/24/48/96).
    pub scales: Vec<usize>,
    /// SGD flavors to run.
    pub flavors: Vec<SgdFlavor>,
    /// Registry strategies to run alongside the flavors, named with
    /// parameter tables (TOML `strategies = [...]` + `[strategy.<name>]`
    /// sections — scenarios the closed flavor list cannot name run from
    /// `dbench --spec` without code).
    pub strategies: Vec<StrategyRef>,
    /// Topology override applied to every decentralized cell, resolved
    /// by name through `crate::topology::registry` (TOML
    /// `topology = "<name>"` + an optional `[topology.<name>]` table).
    pub topology: Option<TopologyRef>,
    /// Epochs per run.
    pub epochs: usize,
    /// Shared seed (controlled experiments).
    pub seed: u64,
    /// Dirichlet alpha for label-skew sharding (`None` = iid).
    pub skew_alpha: Option<f64>,
    /// Peak base LR for the scaled policy.
    pub peak_lr: f64,
    /// LR scaling rule (linear conventional / sqrt tuned).
    pub scaling: ScalingRule,
    /// Table-2 divisor.
    pub lr_divisor: f64,
    /// Eval cadence in epochs.
    pub eval_every_epochs: usize,
    /// Metric capture cadence in iterations.
    pub metrics_every: usize,
    /// Iteration cap per epoch (benches subsample).
    pub max_iters_per_epoch: Option<usize>,
    /// Tracked layer indices for per-tensor gini (Fig. 4).
    pub track_layers: Vec<usize>,
    /// Gossip/fused kernel fan-out (`0` = all cores; bit-identical
    /// results for every value — see `crate::exec`).
    pub threads: usize,
    /// Run decentralized flavors through the fused gossip+SGD kernel
    /// (combine-then-adapt order; see [`TrainConfig::fused`]).
    pub fused: bool,
    /// Overlap communication with compute through the bucketed pipeline
    /// (bit-identical to phased; see [`TrainConfig::pipeline`]).
    pub pipeline: bool,
    /// Pipeline bucket width in KB (`0` = default 256 KB; see
    /// [`TrainConfig::bucket_kb`]).
    pub bucket_kb: usize,
    /// Deterministic fault plan applied to every decentralized cell
    /// (TOML `[faults]` section / CLI `--faults k=v,…`); `None` = the
    /// fault-free paths, bit-for-bit. See [`crate::simnet::FaultPlan`].
    pub faults: Option<crate::simnet::FaultPlan>,
    /// Staleness bound of fault-injected gossip (TOML/CLI
    /// `staleness_bound`; see [`TrainConfig::staleness_bound`]).
    pub staleness_bound: usize,
}

impl ExperimentSpec {
    /// The five §3.1.2 SGD implementations.
    pub fn five_sgd_implementations() -> Vec<SgdFlavor> {
        vec![
            SgdFlavor::CentralizedComplete,
            SgdFlavor::DecentralizedComplete,
            SgdFlavor::DecentralizedRing,
            SgdFlavor::DecentralizedTorus,
            SgdFlavor::DecentralizedExponential,
        ]
    }

    fn base(name: &str, workload: Workload) -> Self {
        ExperimentSpec {
            name: name.into(),
            workload,
            scales: vec![8, 16, 32, 64],
            flavors: Self::five_sgd_implementations(),
            strategies: Vec::new(),
            topology: None,
            epochs: 6,
            seed: 42,
            skew_alpha: Some(0.3),
            peak_lr: 0.05,
            scaling: ScalingRule::Linear,
            lr_divisor: 256.0,
            eval_every_epochs: 1,
            metrics_every: 1,
            max_iters_per_epoch: None,
            track_layers: vec![0, 1],
            threads: 0,
            fused: false,
            pipeline: false,
            bucket_kb: 0,
            faults: None,
            staleness_bound: 0,
        }
    }

    /// ResNet20/CIFAR10 analog (Table 2 row 1).
    pub fn resnet20_analog() -> Self {
        Self::base(
            "resnet20_cifar_analog",
            Workload::SoftmaxImage {
                n_examples: 4096,
                dim: 32,
                classes: 10,
                separation: 2.5,
                batch: 16,
                eval_batch: 64,
                momentum: 0.9,
            },
        )
    }

    /// ResNet50/ImageNet analog (Table 2 row 2) — bigger MLP, harder data.
    pub fn resnet50_analog() -> Self {
        let mut s = Self::base(
            "resnet50_imagenet_analog",
            Workload::MlpImage {
                n_examples: 8192,
                dim: 64,
                hidden: 128,
                classes: 20,
                separation: 2.0,
                batch: 16,
                eval_batch: 64,
                momentum: 0.9,
            },
        );
        s.peak_lr = 0.03;
        s
    }

    /// DenseNet100/CIFAR10 analog (Table 2 row 3).
    pub fn densenet_analog() -> Self {
        let mut s = Self::base(
            "densenet_cifar_analog",
            Workload::MlpImage {
                n_examples: 4096,
                dim: 32,
                hidden: 64,
                classes: 10,
                separation: 2.5,
                batch: 16,
                eval_batch: 64,
                momentum: 0.9,
            },
        );
        s.peak_lr = 0.04;
        s
    }

    /// LSTM/WikiText2 analog (Table 2 row 4).
    pub fn lstm_analog() -> Self {
        let mut s = Self::base(
            "lstm_wikitext_analog",
            Workload::BigramText {
                n_seq: 2048,
                seq_len: 16,
                vocab: 32,
                branching: 2,
                batch: 8,
                eval_batch: 32,
                momentum: 0.9,
            },
        );
        s.peak_lr = 0.8;
        s.lr_divisor = 24.0;
        s
    }

    /// All four application analogs (the Fig. 3 grid).
    pub fn four_applications() -> Vec<ExperimentSpec> {
        vec![
            Self::resnet20_analog(),
            Self::resnet50_analog(),
            Self::densenet_analog(),
            Self::lstm_analog(),
        ]
    }

    /// Translate into a per-run [`TrainConfig`] at `scale`.
    pub fn train_config(&self, scale: usize) -> TrainConfig {
        TrainConfig {
            n_workers: scale,
            epochs: self.epochs,
            seed: self.seed,
            lr: LrPolicy::Scaled {
                peak: self.peak_lr,
                rule: self.scaling,
                divisor: self.lr_divisor,
                warmup: (self.epochs as f64 * 0.15).max(0.5),
            },
            shard: match self.skew_alpha {
                Some(alpha) if self.supports_label_skew() => ShardStrategy::LabelSkew { alpha },
                _ => ShardStrategy::Iid,
            },
            test_frac: 0.15,
            eval_every_epochs: self.eval_every_epochs,
            metrics_every: self.metrics_every,
            max_iters_per_epoch: self.max_iters_per_epoch,
            track_layers: self.track_layers.clone(),
            central_momentum: 0.9,
            drop_prob: 0.0,
            threads: self.threads,
            fused: self.fused,
            fused_momentum: 0.9,
            pipeline: self.pipeline,
            bucket_kb: self.bucket_kb,
            record_path: None,
            faults: self.faults.clone(),
            staleness_bound: self.staleness_bound,
        }
    }

    fn supports_label_skew(&self) -> bool {
        !matches!(self.workload, Workload::BigramText { .. })
    }

    /// Load a spec from a TOML file: a built-in app named by `base`, with
    /// any top-level field overridden. See `configs/*.toml`.
    pub fn from_toml_file(path: &std::path::Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::from_toml_str(&text)
            .map_err(|e| AdaError::Config(format!("{}: {e}", path.display())))
    }

    /// Parse from TOML text (see [`ExperimentSpec::from_toml_file`]).
    pub fn from_toml_str(text: &str) -> Result<Self> {
        Self::from_doc(&TomlDoc::parse(text)?)
    }

    /// Parse from a JSON document with the same shape as the TOML form:
    /// scalar/array fields at the top level, parameter tables as nested
    /// objects (`{"ada": {"k0": 10}}` ≡ `[ada]` / `k0 = 10`, and
    /// `{"topology": "ada", "topology_params": …}` nesting one level
    /// deeper as `{"strategy": {"mix": {…}}}` ≡ `[strategy.mix]`). The
    /// experiment service accepts either encoding on `POST /jobs`.
    pub fn from_json_str(text: &str) -> Result<Self> {
        Self::from_doc(&json_to_doc(&Value::parse(text)?)?)
    }

    /// Parse spec text, sniffing the encoding: a body whose first
    /// non-whitespace byte is `{` is JSON, anything else TOML.
    pub fn from_text(text: &str) -> Result<Self> {
        if text.trim_start().starts_with('{') {
            Self::from_json_str(text)
        } else {
            Self::from_toml_str(text)
        }
    }

    /// Build a spec from an already-parsed key/section document — the
    /// one implementation behind both the TOML and JSON front ends.
    pub fn from_doc(doc: &TomlDoc) -> Result<Self> {
        let base = doc
            .get("base")
            .and_then(TomlValue::as_str)
            .ok_or_else(|| {
                AdaError::Config("spec needs `base = \"resnet20|resnet50|densenet|lstm\"`".into())
            })?;
        let mut spec = match base {
            "resnet20" => Self::resnet20_analog(),
            "resnet50" => Self::resnet50_analog(),
            "densenet" => Self::densenet_analog(),
            "lstm" => Self::lstm_analog(),
            other => {
                return Err(AdaError::Config(format!("unknown base app {other:?}")))
            }
        };
        if let Some(v) = doc.get("name").and_then(TomlValue::as_str) {
            spec.name = v.to_string();
        }
        if let Some(v) = doc.get("scales").and_then(TomlValue::as_usize_array) {
            spec.scales = v;
        }
        if let Some(v) = doc.get("epochs").and_then(TomlValue::as_int) {
            spec.epochs = v as usize;
        }
        if let Some(v) = doc.get("seed").and_then(TomlValue::as_int) {
            spec.seed = v as u64;
        }
        if let Some(v) = doc.get("skew_alpha").and_then(TomlValue::as_float) {
            spec.skew_alpha = if v > 0.0 { Some(v) } else { None };
        }
        if let Some(v) = doc.get("peak_lr").and_then(TomlValue::as_float) {
            spec.peak_lr = v;
        }
        if let Some(v) = doc.get("scaling").and_then(TomlValue::as_str) {
            spec.scaling = match v {
                "linear" => ScalingRule::Linear,
                "sqrt" => ScalingRule::Sqrt,
                "none" => ScalingRule::None,
                other => {
                    return Err(AdaError::Config(format!("unknown scaling {other:?}")))
                }
            };
        }
        if let Some(v) = doc.get("lr_divisor").and_then(TomlValue::as_float) {
            spec.lr_divisor = v;
        }
        if let Some(v) = doc.get("eval_every_epochs").and_then(TomlValue::as_int) {
            spec.eval_every_epochs = v as usize;
        }
        if let Some(v) = doc.get("metrics_every").and_then(TomlValue::as_int) {
            spec.metrics_every = v as usize;
        }
        if let Some(v) = doc.get("max_iters_per_epoch").and_then(TomlValue::as_int) {
            spec.max_iters_per_epoch = if v > 0 { Some(v as usize) } else { None };
        }
        if let Some(v) = doc.get("track_layers").and_then(TomlValue::as_usize_array) {
            spec.track_layers = v;
        }
        if let Some(v) = doc.get("threads").and_then(TomlValue::as_int) {
            spec.threads = v.max(0) as usize;
        }
        if let Some(v) = doc.get("fused").and_then(TomlValue::as_bool) {
            spec.fused = v;
        }
        if let Some(v) = doc.get("pipeline").and_then(TomlValue::as_bool) {
            spec.pipeline = v;
        }
        if let Some(v) = doc.get("bucket_kb").and_then(TomlValue::as_int) {
            spec.bucket_kb = v.max(0) as usize;
        }
        if let Some(v) = doc.get("staleness_bound").and_then(TomlValue::as_int) {
            spec.staleness_bound = v.max(0) as usize;
        }
        // The `[faults]` section as a FaultPlan (unknown keys error
        // inside `from_table`, like every param table here).
        if let Some(section) = doc.section("faults") {
            let table = ParamTable::from_toml_section(section);
            let plan = crate::simnet::FaultPlan::from_table(&table)
                .map_err(|e| AdaError::Config(format!("[faults]: {e}")))?;
            spec.faults = Some(plan);
        }
        if let Some(TomlValue::Arr(fs)) = doc.get("flavors") {
            let mut flavors = Vec::new();
            for f in fs {
                let name = f.as_str().ok_or_else(|| {
                    AdaError::Config("flavors must be strings".into())
                })?;
                flavors.push(Self::flavor_by_name(name, doc)?);
            }
            spec.flavors = flavors;
        }
        // Registry strategies by name, each with an optional
        // `[strategy.<name>]` parameter table.
        if let Some(TomlValue::Arr(names)) = doc.get("strategies") {
            for v in names {
                let name = v.as_str().ok_or_else(|| {
                    AdaError::Config("strategies must be strings".into())
                })?;
                let table = section_params(doc, "strategy", name);
                let params = StrategyParams::from_table(0, &table)
                    .map_err(|e| AdaError::Config(format!("[strategy.{name}]: {e}")))?;
                spec.strategies.push(StrategyRef::Named {
                    name: name.to_string(),
                    params,
                });
            }
        }
        // Topology override by name, with an optional `[topology.<name>]`
        // parameter table, resolved through the topology registry at
        // plan time.
        if let Some(name) = doc.get("topology").and_then(TomlValue::as_str) {
            spec.topology = Some(TopologyRef {
                name: name.to_string(),
                params: section_params(doc, "topology", name),
            });
        }
        // Orphaned param tables are loud, like unknown keys inside
        // them: a `[topology.X]`/`[strategy.X]` section whose X is not
        // the referenced name would otherwise silently fall back to
        // defaults (the classic typo'd-section trap).
        for section in doc.sections.keys() {
            if let Some(suffix) = section.strip_prefix("topology.") {
                if spec.topology.as_ref().map(|t| t.name.as_str()) != Some(suffix) {
                    return Err(AdaError::Config(format!(
                        "[{section}] does not match the referenced topology \
                         ({:?}) — typo, or missing `topology = \"{suffix}\"`?",
                        spec.topology.as_ref().map(|t| t.name.as_str())
                    )));
                }
            } else if let Some(suffix) = section.strip_prefix("strategy.") {
                let referenced = spec.strategies.iter().any(|s| match s {
                    StrategyRef::Named { name, .. } => name == suffix,
                    StrategyRef::Flavor(f) => f.name() == suffix,
                });
                if !referenced {
                    return Err(AdaError::Config(format!(
                        "[{section}] does not match any name in \
                         `strategies = [...]` — typo, or missing entry?"
                    )));
                }
            }
        }
        Ok(spec)
    }

    fn flavor_by_name(name: &str, doc: &TomlDoc) -> Result<SgdFlavor> {
        let k0 = doc
            .get("ada.k0")
            .and_then(TomlValue::as_int)
            .map(|v| v as usize);
        let gamma_k = doc
            .get("ada.gamma_k")
            .and_then(TomlValue::as_float)
            .unwrap_or(1.0);
        Ok(match name {
            "c_complete" | "C_complete" => SgdFlavor::CentralizedComplete,
            "d_complete" | "D_complete" => SgdFlavor::DecentralizedComplete,
            "d_ring" | "D_ring" => SgdFlavor::DecentralizedRing,
            "d_torus" | "D_torus" => SgdFlavor::DecentralizedTorus,
            "d_exponential" | "D_exponential" => SgdFlavor::DecentralizedExponential,
            "ada" | "D_adaptive" => SgdFlavor::Ada {
                k0: k0.ok_or_else(|| {
                    AdaError::Config("ada flavor needs [ada] k0 = <int>".into())
                })?,
                gamma_k,
            },
            "one_peer" | "D_one_peer" => SgdFlavor::OnePeer,
            "var_adaptive" | "D_var_adaptive" => SgdFlavor::VarianceAdaptive {
                k0: k0.ok_or_else(|| {
                    AdaError::Config("var_adaptive flavor needs [ada] k0 = <int>".into())
                })?,
                step: 2,
                threshold: 0.002,
                patience: 1,
            },
            other => {
                return Err(AdaError::Config(format!("unknown flavor {other:?}")))
            }
        })
    }
}

/// The `[kind.<name>]` section as a [`ParamTable`] (empty when the
/// section is absent) — the one parser behind `[strategy.<name>]` and
/// `[topology.<name>]` tables.
fn section_params(doc: &TomlDoc, kind: &str, name: &str) -> ParamTable {
    doc.section(&format!("{kind}.{name}"))
        .map(ParamTable::from_toml_section)
        .unwrap_or_default()
}

/// One JSON scalar/array as a [`TomlValue`]. Numbers become `Int` when
/// integral (matching what the TOML parser would have produced for the
/// same spec), `Float` otherwise.
fn json_scalar(key: &str, v: &Value) -> Result<TomlValue> {
    Ok(match v {
        Value::Str(s) => TomlValue::Str(s.clone()),
        Value::Bool(b) => TomlValue::Bool(*b),
        Value::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 9e15 {
                TomlValue::Int(*n as i64)
            } else {
                TomlValue::Float(*n)
            }
        }
        Value::Arr(items) => TomlValue::Arr(
            items
                .iter()
                .map(|item| json_scalar(key, item))
                .collect::<Result<Vec<_>>>()?,
        ),
        Value::Null | Value::Obj(_) => {
            return Err(AdaError::Config(format!(
                "spec key {key:?}: expected a scalar or array"
            )))
        }
    })
}

/// Reshape a JSON object into the [`TomlDoc`] key/section layout the
/// spec parser consumes: top-level scalars/arrays → root keys, a nested
/// object → a section (`{"ada": {…}}` ≡ `[ada]`), and an object inside
/// that → a dotted section (`{"strategy": {"mix": {…}}}` ≡
/// `[strategy.mix]`). Anything deeper is an error.
fn json_to_doc(v: &Value) -> Result<TomlDoc> {
    let top = match v {
        Value::Obj(map) => map,
        _ => return Err(AdaError::Config("JSON spec must be an object".into())),
    };
    let mut doc = TomlDoc::default();
    for (key, val) in top {
        match val {
            Value::Obj(section) => {
                for (k2, v2) in section {
                    match v2 {
                        Value::Obj(nested) => {
                            let name = format!("{key}.{k2}");
                            let entry = doc.sections.entry(name.clone()).or_default();
                            for (k3, v3) in nested {
                                entry.insert(
                                    k3.clone(),
                                    json_scalar(&format!("{name}.{k3}"), v3)?,
                                );
                            }
                        }
                        _ => {
                            doc.sections.entry(key.clone()).or_default().insert(
                                k2.clone(),
                                json_scalar(&format!("{key}.{k2}"), v2)?,
                            );
                        }
                    }
                }
            }
            _ => {
                doc.root.insert(key.clone(), json_scalar(key, val)?);
            }
        }
    }
    Ok(doc)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn specs_load_from_toml() {
        let spec = ExperimentSpec::from_toml_str(
            r#"
            base = "densenet"
            name = "fig3_densenet"
            scales = [8, 16]
            epochs = 3
            peak_lr = 0.02
            scaling = "sqrt"
            flavors = ["d_ring", "ada"]

            [ada]
            k0 = 10
            gamma_k = 0.5
            "#,
        )
        .unwrap();
        assert_eq!(spec.name, "fig3_densenet");
        assert_eq!(spec.scales, vec![8, 16]);
        assert_eq!(spec.epochs, 3);
        assert_eq!(spec.scaling, ScalingRule::Sqrt);
        assert_eq!(spec.flavors.len(), 2);
        assert_eq!(
            spec.flavors[1],
            SgdFlavor::Ada { k0: 10, gamma_k: 0.5 }
        );
    }

    #[test]
    fn json_specs_match_their_toml_twin() {
        let toml = ExperimentSpec::from_toml_str(
            r#"
            base = "densenet"
            name = "fig3_densenet"
            scales = [8, 16]
            epochs = 3
            peak_lr = 0.02
            scaling = "sqrt"
            flavors = ["d_ring", "ada"]

            [ada]
            k0 = 10
            gamma_k = 0.5
            "#,
        )
        .unwrap();
        let json = ExperimentSpec::from_json_str(
            r#"{
                "base": "densenet",
                "name": "fig3_densenet",
                "scales": [8, 16],
                "epochs": 3,
                "peak_lr": 0.02,
                "scaling": "sqrt",
                "flavors": ["d_ring", "ada"],
                "ada": {"k0": 10, "gamma_k": 0.5}
            }"#,
        )
        .unwrap();
        assert_eq!(json.name, toml.name);
        assert_eq!(json.scales, toml.scales);
        assert_eq!(json.epochs, toml.epochs);
        assert_eq!(json.peak_lr, toml.peak_lr);
        assert_eq!(json.scaling, toml.scaling);
        assert_eq!(json.flavors, toml.flavors);
    }

    #[test]
    fn json_specs_reach_dotted_sections() {
        // {"strategy": {"mix": {...}}} ≡ [strategy.mix] — the nested
        // parameter-table form.
        let spec = ExperimentSpec::from_json_str(
            r#"{
                "base": "resnet20",
                "strategies": ["mix"],
                "strategy": {"mix": {"k0": 2, "gamma_k": 0.5}}
            }"#,
        )
        .unwrap();
        assert_eq!(spec.strategies.len(), 1);
        match &spec.strategies[0] {
            StrategyRef::Named { name, params } => {
                assert_eq!(name, "mix");
                assert_eq!(params.k0, Some(2));
                assert_eq!(params.gamma_k, 0.5);
            }
            other => panic!("expected named strategy, got {other:?}"),
        }
        // The orphaned-section guard fires through the JSON door too.
        let err = ExperimentSpec::from_json_str(
            r#"{"base": "resnet20", "strategy": {"typo": {"k0": 2}}}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("strategy.typo"), "{err}");
    }

    #[test]
    fn from_text_sniffs_the_encoding() {
        let json = ExperimentSpec::from_text("  {\"base\": \"resnet20\"}").unwrap();
        assert_eq!(json.name, "resnet20_cifar_analog");
        let toml = ExperimentSpec::from_text("base = \"resnet20\"").unwrap();
        assert_eq!(toml.name, "resnet20_cifar_analog");
        assert!(ExperimentSpec::from_text("{not json").is_err());
    }

    #[test]
    fn json_rejects_malformed_shapes() {
        assert!(ExperimentSpec::from_json_str("[1, 2]").is_err(), "not an object");
        assert!(
            ExperimentSpec::from_json_str(
                r#"{"base": "resnet20", "epochs": null}"#
            )
            .is_err(),
            "null scalar"
        );
        assert!(
            ExperimentSpec::from_json_str(
                r#"{"base": "resnet20", "a": {"b": {"c": {"d": 1}}}}"#
            )
            .is_err(),
            "over-nested"
        );
    }

    #[test]
    fn toml_names_registry_strategies_with_param_tables() {
        let spec = ExperimentSpec::from_toml_str(
            r#"
            base = "resnet20"
            scales = [8]
            flavors = ["d_ring"]
            strategies = ["D_var_adaptive"]

            [strategy.D_var_adaptive]
            k0 = 6
            step = 1
            threshold = 0.01
            "#,
        )
        .unwrap();
        assert_eq!(spec.strategies.len(), 1);
        match &spec.strategies[0] {
            StrategyRef::Named { name, params } => {
                assert_eq!(name, "D_var_adaptive");
                assert_eq!(params.k0, Some(6));
                assert_eq!(params.step, 1);
                assert_eq!(params.threshold, 0.01);
            }
            other => panic!("expected a named strategy, got {other:?}"),
        }
        // Unknown keys inside the table are loud.
        assert!(ExperimentSpec::from_toml_str(
            "base = \"resnet20\"\nstrategies = [\"x\"]\n[strategy.x]\nnope = 1\n"
        )
        .is_err());
    }

    #[test]
    fn toml_topology_override_with_param_table() {
        let spec = ExperimentSpec::from_toml_str(
            r#"
            base = "densenet"
            flavors = ["d_ring", "c_complete"]
            topology = "comm_budget"

            [topology.comm_budget]
            budget_mb = 2.5
            k0 = 6
            "#,
        )
        .unwrap();
        let t = spec.topology.as_ref().expect("topology parsed");
        assert_eq!(t.name, "comm_budget");
        assert_eq!(t.params.get_f64("budget_mb").unwrap(), Some(2.5));
        assert_eq!(t.params.get_usize("k0").unwrap(), Some(6));
        // A topology with no param table parses to an empty table.
        let bare = ExperimentSpec::from_toml_str(
            "base = \"densenet\"\ntopology = \"one_peer\"\n",
        )
        .unwrap();
        assert!(bare.topology.as_ref().unwrap().params.is_empty());
    }

    #[test]
    fn orphaned_param_sections_are_rejected() {
        // A typo'd section name must not silently fall back to defaults.
        let err = ExperimentSpec::from_toml_str(
            "base = \"densenet\"\ntopology = \"one_peer\"\n\
             [topology.one_per]\nper_iter = true\n",
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("one_per"), "{err}");
        // Same for a [strategy.X] whose X is not in `strategies`.
        let err = ExperimentSpec::from_toml_str(
            "base = \"densenet\"\n[strategy.D_var_adaptive]\nk0 = 4\n",
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("strategy.D_var_adaptive"), "{err}");
        // A topology section without any `topology = ...` reference.
        assert!(ExperimentSpec::from_toml_str(
            "base = \"densenet\"\n[topology.ada]\nk0 = 4\n"
        )
        .is_err());
    }

    #[test]
    fn toml_faults_section_builds_a_plan() {
        let spec = ExperimentSpec::from_toml_str(
            r#"
            base = "resnet20"
            staleness_bound = 2

            [faults]
            seed = 9
            drop_prob = 0.1
            crash = "1@1:2"
            "#,
        )
        .unwrap();
        assert_eq!(spec.staleness_bound, 2);
        let plan = spec.faults.as_ref().expect("plan parsed");
        assert_eq!(plan.seed, 9);
        assert_eq!(plan.drop_prob, 0.1);
        assert_eq!(plan.crashes.len(), 1);
        // The plan reaches every per-cell TrainConfig.
        let cfg = spec.train_config(8);
        assert_eq!(cfg.staleness_bound, 2);
        assert_eq!(cfg.faults, spec.faults);
        // Typos inside [faults] are loud, and a spec without the
        // section stays fault-free.
        assert!(ExperimentSpec::from_toml_str(
            "base = \"resnet20\"\n[faults]\ndropprob = 0.5\n"
        )
        .is_err());
        let bare = ExperimentSpec::from_toml_str("base = \"resnet20\"").unwrap();
        assert!(bare.faults.is_none());
        assert_eq!(bare.staleness_bound, 0);
    }

    #[test]
    fn toml_spec_rejects_bad_inputs() {
        assert!(ExperimentSpec::from_toml_str("epochs = 3").is_err(), "no base");
        assert!(ExperimentSpec::from_toml_str("base = \"nope\"").is_err());
        assert!(ExperimentSpec::from_toml_str(
            "base = \"lstm\"\nflavors = [\"ada\"]"
        )
        .is_err(), "ada without k0");
    }

    #[test]
    fn workloads_build_models_and_datasets() {
        for spec in ExperimentSpec::four_applications() {
            let d = spec.workload.dataset(1).unwrap();
            assert!(d.len() > 0);
            let m = spec.workload.model(4).unwrap();
            assert!(m.param_count() > 0);
            assert_eq!(d.x_dim(), {
                // Batch shape agreement between dataset and model inputs.
                let b = d.batch(&[0]);
                b.x_dim
            });
        }
    }

    #[test]
    fn ensure_examples_grows_but_never_shrinks() {
        let mut w = ExperimentSpec::resnet50_analog().workload;
        assert_eq!(w.batch_size(), 16);
        w.ensure_examples(100_000);
        let d = w.dataset(1).unwrap();
        assert_eq!(d.len(), 100_000);
        w.ensure_examples(10); // no shrink
        assert_eq!(w.dataset(1).unwrap().len(), 100_000);
        let mut lm = ExperimentSpec::lstm_analog().workload;
        lm.ensure_examples(5000);
        assert_eq!(lm.dataset(1).unwrap().len(), 5000);
    }

    #[test]
    fn lm_workload_uses_iid_sharding() {
        let spec = ExperimentSpec::lstm_analog();
        let cfg = spec.train_config(8);
        assert_eq!(cfg.shard, ShardStrategy::Iid);
    }

    #[test]
    fn five_implementations_match_paper_names() {
        let names: Vec<String> = ExperimentSpec::five_sgd_implementations()
            .iter()
            .map(|f| f.name())
            .collect();
        assert_eq!(
            names,
            vec!["C_complete", "D_complete", "D_ring", "D_torus", "D_exponential"]
        );
    }
}
