//! [`SessionPlan`] — the composable experiment pipeline behind
//! [`run_experiment`](super::run_experiment).
//!
//! A plan owns the three things the old grid loop hard-wired:
//!
//! * **cell enumeration** — scale × strategy, each cell carrying its
//!   own seed and [`TrainConfig`], built up front so the grid is
//!   inspectable and extensible (push cells for strategies the spec's
//!   closed flavor list cannot name);
//! * **strategy resolution** — cells reference strategies by
//!   [`SgdFlavor`] or by registry name ([`StrategyRef::Named`]),
//!   resolved per cell against a [`Registry`] the caller may extend —
//!   a new [`crate::coordinator::strategy::CombineStrategy`] trains
//!   end-to-end from here without touching `coordinator/` source; a
//!   cell may additionally carry a [`TopologyRef`] that swaps the
//!   strategy's communication-graph policy for one resolved by name
//!   (with a parameter table) against the plan's
//!   [`topologies`](SessionPlan::topologies) registry;
//! * **execution** — sequential by default; `parallel > 1` opts into a
//!   bounded cell executor (scoped threads over an atomic work queue,
//!   capped by the machine's core count), and `resume_dir` makes cells
//!   resumable: each finished cell is persisted into a
//!   [`crate::serve::ResultStore`] (content-addressed by the cell
//!   [`fingerprint`] — the same store the experiment service shares)
//!   and reloaded instead of re-run on the next invocation — but only
//!   while that fingerprint still matches. Pre-store flat-layout
//!   resume directories keep working: legacy files are validated, read
//!   and migrated into the content-addressed layout on first touch.
//!
//! Results are **identical** for every `parallel` value: cells are
//! independent runs (each builds its own dataset, model and engine from
//! the cell seed) and land in their enumeration slot, so execution
//! order is unobservable. When cells run concurrently, auto-threaded
//! cells (`config.threads == 0`) execute single-threaded so cell-level
//! parallelism and the intra-cell pool don't oversubscribe the same
//! cores (see [`SessionPlan::run`]) — thread count never changes the
//! floats, so this is purely a scheduling choice.

use super::spec::ExperimentSpec;
use super::CellResult;
use crate::coordinator::strategy::{self, Registry, StrategyInstance, StrategyParams};
use crate::coordinator::{Observer, SgdFlavor, TrainConfig, TrainSession};
use crate::error::{AdaError, Result};
use crate::exec::resolve_threads;
use crate::metrics::{IterationRecord, RunRecorder};
use crate::serve::store::ResultStore;
use crate::topology::{self, TopologyRegistry};
use crate::util::json::Value;
use crate::util::params::ParamTable;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// How a cell names its strategy.
#[derive(Debug, Clone)]
pub enum StrategyRef {
    /// A legacy flavor (resolved under its paper name).
    Flavor(SgdFlavor),
    /// A registry name plus parameters (`n_workers` is overridden by
    /// the cell's scale at resolution time).
    Named {
        /// Registry key.
        name: String,
        /// Constructor parameters.
        params: StrategyParams,
    },
}

/// A by-name reference to a topology policy, resolved per cell against
/// the plan's [`TopologyRegistry`] and applied **over** the strategy's
/// own schedule: the policy replaces the instance's graph schedule, the
/// LR-scaling neighbor count is re-derived from the policy's `k_hint`,
/// and the cell label gains a `+<name>` suffix. Strategies that resolve
/// *without* a schedule (centralized) ignore the override and keep
/// their label. The same shape backs spec TOML `[topology.<name>]`
/// tables and the CLI `--topology name:k=v,…` flag.
#[derive(Debug, Clone, PartialEq)]
pub struct TopologyRef {
    /// Registry key.
    pub name: String,
    /// Constructor parameters.
    pub params: ParamTable,
}

impl TopologyRef {
    /// A named reference with default params.
    pub fn named(name: impl Into<String>) -> Self {
        TopologyRef {
            name: name.into(),
            params: ParamTable::new(),
        }
    }

    /// Parse the CLI form `name` or `name:k=v,k2=v2`.
    pub fn parse(text: &str) -> Result<Self> {
        let (name, rest) = match text.split_once(':') {
            Some((name, rest)) => (name, rest),
            None => (text, ""),
        };
        let name = name.trim();
        if name.is_empty() {
            return Err(AdaError::Config(format!(
                "topology reference {text:?} is missing a name (name:k=v,…)"
            )));
        }
        Ok(TopologyRef {
            name: name.to_string(),
            params: ParamTable::parse_kv(rest)?,
        })
    }
}

impl StrategyRef {
    /// A named reference with default params (filled at resolution).
    pub fn named(name: impl Into<String>) -> Self {
        StrategyRef::Named {
            name: name.into(),
            params: StrategyParams::for_n(0),
        }
    }

    /// Parse the CLI form `name` or `name:k=v,k2=v2` — the strategy
    /// twin of [`TopologyRef::parse`], backing the `--strategy` flag.
    /// `n_workers` stays 0 here; [`StrategyRef::resolve`] overrides it
    /// with the cell's scale.
    pub fn parse(text: &str) -> Result<Self> {
        let (name, rest) = match text.split_once(':') {
            Some((name, rest)) => (name, rest),
            None => (text, ""),
        };
        let name = name.trim();
        if name.is_empty() {
            return Err(AdaError::Config(format!(
                "strategy reference {text:?} is missing a name (name:k=v,…)"
            )));
        }
        Ok(StrategyRef::Named {
            name: name.to_string(),
            params: StrategyParams::from_table(0, &ParamTable::parse_kv(rest)?)?,
        })
    }

    /// The registry key / file-naming key of this reference.
    pub fn key(&self) -> String {
        match self {
            StrategyRef::Flavor(f) => f.name(),
            StrategyRef::Named { name, .. } => name.clone(),
        }
    }

    /// Resolve against `registry` at scale `n`.
    pub fn resolve(&self, registry: &Registry, n: usize) -> Result<StrategyInstance> {
        match self {
            StrategyRef::Flavor(f) => registry.resolve(&f.name(), &f.params(n)),
            StrategyRef::Named { name, params } => {
                let mut p = params.clone();
                p.n_workers = n;
                registry.resolve(name, &p)
            }
        }
    }
}

/// One enumerated grid cell, fully specified before execution.
#[derive(Debug, Clone)]
pub struct CellPlan {
    /// Position in the enumeration (stable across runs — the resume
    /// key and the result slot).
    pub index: usize,
    /// Training scale (worker count).
    pub scale: usize,
    /// Cell seed: dataset generation, sharding, init and shuffling all
    /// derive from it. The spec pipeline shares one seed across cells
    /// (the §3.1 controlled-experiment discipline); custom plans may
    /// vary it per cell.
    pub seed: u64,
    /// The strategy to train.
    pub strategy: StrategyRef,
    /// Optional topology override, resolved against
    /// [`SessionPlan::topologies`] (`None` = the strategy's own
    /// schedule).
    pub topology: Option<TopologyRef>,
    /// The per-run configuration.
    pub config: TrainConfig,
}

impl CellPlan {
    /// Stable result-file name for resumable execution.
    pub fn file_name(&self) -> String {
        format!("cell_{:04}_{}_{}.json", self.index, self.scale, self.strategy.key())
    }
}

/// The experiment pipeline: enumerated cells + registry + executor
/// knobs. Build with [`SessionPlan::from_spec`], extend freely, then
/// [`SessionPlan::run`].
pub struct SessionPlan {
    /// Experiment name (tables, output paths).
    pub name: String,
    /// The workload every cell trains.
    pub workload: super::Workload,
    /// The enumerated grid.
    pub cells: Vec<CellPlan>,
    /// Strategy resolution table (builtin flavors preloaded; register
    /// custom scenarios here).
    pub registry: Registry,
    /// Topology resolution table (builtin policies preloaded; register
    /// custom graph policies here).
    pub topologies: TopologyRegistry,
    /// Max concurrently executing cells (`0`/`1` = sequential). The
    /// effective bound is `min(parallel, available cores, cells)`.
    pub parallel: usize,
    /// When set, finished cells persist here as JSON and are reloaded
    /// instead of re-run on the next invocation.
    pub resume_dir: Option<PathBuf>,
}

impl SessionPlan {
    /// Enumerate `spec`'s grid (scale-major, flavor-minor with named
    /// registry strategies after the flavors — the order
    /// [`super::run_experiment`] has always produced) with the spec's
    /// shared seed in every cell. The spec's topology override (TOML
    /// `topology = "<name>"` + `[topology.<name>]`) lands on every
    /// decentralized cell; `C_complete` keeps its centralized path
    /// (and [`SessionPlan::run_cell_plan`] skips the override for any
    /// strategy that resolves without a graph schedule, so named
    /// centralized strategies behave identically to the flavor).
    pub fn from_spec(spec: &ExperimentSpec) -> Self {
        let per_scale = spec.flavors.len() + spec.strategies.len();
        let mut cells = Vec::with_capacity(spec.scales.len() * per_scale);
        for &scale in &spec.scales {
            for flavor in &spec.flavors {
                let topology = match flavor {
                    SgdFlavor::CentralizedComplete => None,
                    _ => spec.topology.clone(),
                };
                let index = cells.len();
                cells.push(CellPlan {
                    index,
                    scale,
                    seed: spec.seed,
                    strategy: StrategyRef::Flavor(flavor.clone()),
                    topology,
                    config: spec.train_config(scale),
                });
            }
            for named in &spec.strategies {
                let index = cells.len();
                cells.push(CellPlan {
                    index,
                    scale,
                    seed: spec.seed,
                    strategy: named.clone(),
                    topology: spec.topology.clone(),
                    config: spec.train_config(scale),
                });
            }
        }
        SessionPlan {
            name: spec.name.clone(),
            workload: spec.workload.clone(),
            cells,
            registry: strategy::registry(),
            topologies: topology::registry(),
            parallel: 1,
            resume_dir: None,
        }
    }

    /// Append a cell (index assigned automatically; `config.seed` is
    /// forced to `seed` so data order follows the cell).
    pub fn push_cell(
        &mut self,
        scale: usize,
        seed: u64,
        strategy: StrategyRef,
        mut config: TrainConfig,
    ) {
        config.seed = seed;
        config.n_workers = scale;
        self.cells.push(CellPlan {
            index: self.cells.len(),
            scale,
            seed,
            strategy,
            topology: None,
            config,
        });
    }

    /// Append a cell whose graph policy is resolved by name against
    /// [`SessionPlan::topologies`] instead of coming from the strategy.
    pub fn push_cell_with_topology(
        &mut self,
        scale: usize,
        seed: u64,
        strategy: StrategyRef,
        topology: TopologyRef,
        config: TrainConfig,
    ) {
        self.push_cell(scale, seed, strategy, config);
        self.cells.last_mut().expect("cell just pushed").topology = Some(topology);
    }

    /// Replicate every cell `k` times with derived per-replicate seeds
    /// (`seed, seed+1, …, seed+k−1`) — the variance-of-the-estimate
    /// mode. Cells re-enumerate replicate-minor, so
    /// [`super::seed_stats`] can fold the results back into one row of
    /// mean ± stderr per original cell. `k ≤ 1` leaves the plan
    /// unchanged.
    pub fn expand_seeds(&mut self, k: usize) {
        if k <= 1 {
            return;
        }
        let mut cells = Vec::with_capacity(self.cells.len() * k);
        for cell in &self.cells {
            for r in 0..k as u64 {
                let mut c = cell.clone();
                c.index = cells.len();
                c.seed = cell.seed + r;
                c.config.seed = c.seed;
                cells.push(c);
            }
        }
        self.cells = cells;
    }

    /// Execute every cell, returning results in enumeration order.
    /// Identical output for any `parallel` value; errors surface from
    /// the lowest-index failing cell. When cells run concurrently,
    /// cells whose `config.threads` is `0` (auto = all cores) execute
    /// single-threaded instead — cell-level parallelism and the
    /// intra-cell pool would otherwise oversubscribe the same cores —
    /// which is safe because engine results are bit-identical for
    /// every thread count; an explicit non-zero `threads` is respected.
    pub fn run(&self) -> Result<Vec<CellResult>> {
        let workers = self
            .parallel
            .max(1)
            .min(resolve_threads(0))
            .min(self.cells.len().max(1));
        let run_one = |cell: &CellPlan| {
            if workers > 1 && cell.config.threads == 0 {
                let mut c = cell.clone();
                c.config.threads = 1;
                self.run_cell_plan(&c)
            } else {
                self.run_cell_plan(cell)
            }
        };
        if workers <= 1 {
            return self.cells.iter().map(run_one).collect();
        }
        let slots: Vec<_> = self.cells.iter().map(|_| Mutex::new(None)).collect();
        let next = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 0..workers {
                s.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::SeqCst);
                    if i >= self.cells.len() {
                        break;
                    }
                    let r = run_one(&self.cells[i]);
                    *slots[i].lock().expect("cell slot") = Some(r);
                });
            }
        });
        slots
            .into_iter()
            .map(|m| m.into_inner().expect("cell slot").expect("cell executed"))
            .collect()
    }

    /// Execute (or reload) one cell. A persisted result is reused only
    /// when its recorded [fingerprint](SessionPlan::cell_fingerprint)
    /// — workload, strategy, seed and every float-affecting config
    /// field — matches the cell exactly; a rerun with any changed
    /// configuration re-executes (and overwrites) instead of returning
    /// stale data.
    pub fn run_cell_plan(&self, cell: &CellPlan) -> Result<CellResult> {
        if let Some(dir) = &self.resume_dir {
            let fp = self.cell_fingerprint(cell);
            let store = ResultStore::open(dir)?;
            // The legacy name keeps pre-store flat-layout resume trees
            // readable; a validated legacy hit migrates into objects/.
            if let Some(prev) = store.load(&fp, Some(&cell.file_name())) {
                return Ok(prev);
            }
            let result = self.run_cell_plan_with(cell, Vec::new())?;
            store.save(&fp, &result)?;
            return Ok(result);
        }
        self.run_cell_plan_with(cell, Vec::new())
    }

    /// Execute one cell unconditionally (no cache consultation, no
    /// persistence), attaching `observers` to the session — the hook the
    /// experiment service uses to stream per-iteration metrics and to
    /// stop a cancelled cell at an iteration boundary. Callers that want
    /// caching go through [`SessionPlan::run_cell_plan`] (CLI resume) or
    /// the service's [`crate::serve::ResultStore`]-backed scheduler.
    pub fn run_cell_plan_with(
        &self,
        cell: &CellPlan,
        observers: Vec<Box<dyn Observer>>,
    ) -> Result<CellResult> {
        let dataset = self.workload.dataset(cell.seed)?;
        let mut model = self.workload.model(cell.scale)?;
        let mut instance = cell.strategy.resolve(&self.registry, cell.scale)?;
        let mut builder = TrainSession::builder(model.as_mut(), cell.config.clone());
        for obs in observers {
            builder = builder.observer(obs);
        }
        // The override only applies to strategies that already exchange
        // over a graph — centralized instances (no schedule) keep their
        // path and label, however the cell was referenced.
        if let (Some(tref), true) = (&cell.topology, instance.schedule.is_some()) {
            instance.label = format!("{}+{}", instance.label, tref.name);
            let policy = self
                .topologies
                .resolve(&tref.name, cell.scale, &tref.params)?;
            builder = builder.topology(policy);
        }
        let label = instance.label.clone();
        let session = builder.strategy(instance).build()?;
        let (recorder, summary) = session.run(dataset.as_ref())?;
        Ok(CellResult {
            scale: cell.scale,
            flavor: label,
            recorder,
            summary,
        })
    }

    /// The cache key of a cell's result: everything that changes the
    /// produced floats — the workload (dataset shape + model family),
    /// the strategy reference with its parameters, the topology
    /// override (when present), and every result-affecting
    /// [`TrainConfig`] field. Deliberately excluded: `threads`,
    /// `pipeline` and `bucket_kb` (all bit-identical by the engine's
    /// contracts — `crate::exec` for threads, `crate::exec::pipeline`
    /// for the overlapped path — so the cache is shared across every
    /// scheduling setting) and `record_path`. Cells without a topology
    /// override keep their pre-redesign fingerprint, so existing resume
    /// caches stay valid.
    pub fn cell_fingerprint(&self, cell: &CellPlan) -> String {
        fingerprint(&self.workload, cell)
    }
}

/// The cache key of a cell's result — the single canonical
/// implementation behind [`SessionPlan::cell_fingerprint`], the CLI
/// resume cache and the experiment service's content-addressed store.
/// Covers everything that changes the produced floats; deliberately
/// excludes `threads`, `pipeline`, `bucket_kb` (bit-identical by the
/// engine's contracts) and `record_path`, and appends the topology /
/// fault suffixes only when present so pre-existing cache keys stay
/// valid.
pub fn fingerprint(workload: &super::Workload, cell: &CellPlan) -> String {
    let c = &cell.config;
    let topology = match &cell.topology {
        Some(t) => format!(" topology={t:?}"),
        None => String::new(),
    };
    // Fault-free cells keep their pre-fault-plane fingerprint (the
    // same backward-compatibility discipline as `topology` above).
    let faults = match &c.faults {
        Some(f) => format!(" faults={f:?} staleness_bound={}", c.staleness_bound),
        None => String::new(),
    };
    format!(
        "workload={:?} strategy={:?} n={} epochs={} seed={} lr={:?} shard={:?} \
         test_frac={} eval_every={} metrics_every={} max_iters={:?} track={:?} \
         central_momentum={} drop_prob={} fused={} fused_momentum={}{}{faults}",
        workload,
        cell.strategy,
        c.n_workers,
        c.epochs,
        c.seed,
        c.lr,
        c.shard,
        c.test_frac,
        c.eval_every_epochs,
        c.metrics_every,
        c.max_iters_per_epoch,
        c.track_layers,
        c.central_momentum,
        c.drop_prob,
        c.fused,
        c.fused_momentum,
        topology,
    )
}

impl CellResult {
    /// JSON encoding: summary + full per-iteration records (the
    /// resumable-pipeline on-disk format).
    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("scale", Value::Num(self.scale as f64)),
            ("flavor", Value::Str(self.flavor.clone())),
            ("summary", self.summary.to_json()),
            (
                "records",
                Value::Arr(self.recorder.records().iter().map(IterationRecord::to_json).collect()),
            ),
        ])
    }

    /// Decode from JSON (inverse of [`CellResult::to_json`]).
    pub fn from_json(v: &Value) -> Result<Self> {
        let flavor = v.str_field("flavor")?.to_string();
        let summary = crate::coordinator::RunSummary::from_json(
            v.get("summary")
                .ok_or_else(|| AdaError::Config("cell result missing summary".into()))?,
        )?;
        let mut recorder = RunRecorder::in_memory(flavor.clone());
        for rv in v.arr_field("records")? {
            recorder.push(IterationRecord::from_json(rv)?)?;
        }
        Ok(CellResult {
            scale: v.usize_field("scale")?,
            flavor,
            recorder,
            summary,
        })
    }

    /// Persist to `path` as a single JSON document.
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.to_json().to_string())?;
        Ok(())
    }

    /// Load a previously [`CellResult::save`]d result.
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json(&Value::parse(&text)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_spec() -> ExperimentSpec {
        let mut s = ExperimentSpec::resnet20_analog();
        s.scales = vec![4];
        s.epochs = 2;
        s.max_iters_per_epoch = Some(4);
        s.threads = 1;
        s.flavors = vec![SgdFlavor::DecentralizedRing, SgdFlavor::DecentralizedComplete];
        s
    }

    #[test]
    fn plan_enumerates_scale_major() {
        let mut spec = tiny_spec();
        spec.scales = vec![4, 8];
        let plan = SessionPlan::from_spec(&spec);
        assert_eq!(plan.cells.len(), 4);
        assert_eq!(
            plan.cells.iter().map(|c| (c.scale, c.strategy.key())).collect::<Vec<_>>(),
            vec![
                (4, "D_ring".to_string()),
                (4, "D_complete".to_string()),
                (8, "D_ring".to_string()),
                (8, "D_complete".to_string()),
            ]
        );
        for (i, c) in plan.cells.iter().enumerate() {
            assert_eq!(c.index, i);
            assert_eq!(c.seed, spec.seed, "spec cells share the seed");
            assert_eq!(c.config.n_workers, c.scale);
        }
    }

    #[test]
    fn cell_result_json_roundtrip() {
        let plan = SessionPlan::from_spec(&tiny_spec());
        let result = plan.run_cell_plan(&plan.cells[0]).unwrap();
        let back = CellResult::from_json(&result.to_json()).unwrap();
        assert_eq!(back.scale, result.scale);
        assert_eq!(back.flavor, result.flavor);
        assert_eq!(back.recorder.records().len(), result.recorder.records().len());
        assert_eq!(
            back.summary.final_eval.metric,
            result.summary.final_eval.metric
        );
        for (a, b) in back.recorder.records().iter().zip(result.recorder.records()) {
            assert_eq!(a.train_loss, b.train_loss);
            assert_eq!(a.bytes_per_node, b.bytes_per_node);
        }
    }

    #[test]
    fn resume_dir_reloads_finished_cells() {
        let dir = crate::util::scratch_dir("plan_resume").unwrap();
        let mut plan = SessionPlan::from_spec(&tiny_spec());
        plan.resume_dir = Some(dir.clone());
        let first = plan.run().unwrap();
        // New writes land in the content-addressed layout only.
        let store = ResultStore::open(&dir).unwrap();
        for cell in &plan.cells {
            let fp = plan.cell_fingerprint(cell);
            assert!(store.object_path(&fp).exists(), "{}", cell.file_name());
            assert!(
                !dir.join(cell.file_name()).exists(),
                "no legacy flat files for new runs"
            );
        }
        // Second run must reload byte-identical results from disk.
        let second = plan.run().unwrap();
        assert_eq!(first.len(), second.len());
        for (a, b) in first.iter().zip(&second) {
            assert_eq!(a.summary.final_eval.metric, b.summary.final_eval.metric);
            assert_eq!(a.recorder.records().len(), b.recorder.records().len());
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn legacy_layout_results_migrate_into_the_store() {
        let dir = crate::util::scratch_dir("plan_legacy").unwrap();
        let mut plan = SessionPlan::from_spec(&tiny_spec());
        plan.cells.truncate(1);
        plan.resume_dir = Some(dir.clone());
        let cell = plan.cells[0].clone();
        let fp = plan.cell_fingerprint(&cell);
        // Plant a pre-store flat-layout file with a sentinel metric: if
        // the plan *reads* it (instead of re-running), the sentinel
        // comes back — proof the legacy path is honored.
        let mut fake = plan.run_cell_plan_with(&cell, Vec::new()).unwrap();
        fake.summary.final_eval.metric = 9999.0;
        std::fs::write(
            dir.join(cell.file_name()),
            crate::serve::store::tagged_json(&fp, &fake).to_string(),
        )
        .unwrap();
        let reloaded = plan.run().unwrap();
        assert_eq!(
            reloaded[0].summary.final_eval.metric, 9999.0,
            "legacy flat-layout file must be served, not re-run"
        );
        // ...and the read migrated it into the content-addressed layout.
        let store = ResultStore::open(&dir).unwrap();
        assert!(store.object_path(&fp).exists(), "migration shim ran");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn fingerprint_helper_is_stable() {
        let plan = SessionPlan::from_spec(&tiny_spec());
        let cell = &plan.cells[0];
        let fp = fingerprint(&plan.workload, cell);
        // The free helper IS the method.
        assert_eq!(fp, plan.cell_fingerprint(cell));
        assert!(fp.starts_with("workload=SoftmaxImage"), "{fp}");
        assert!(fp.contains("strategy=Flavor(DecentralizedRing)"), "{fp}");
        assert!(fp.contains("n=4"), "{fp}");
        // Base cells carry no topology/fault suffix (cache keys from
        // before those planes existed stay valid).
        assert!(!fp.contains("topology="), "{fp}");
        assert!(!fp.contains("faults="), "{fp}");
        // Scheduling knobs are excluded: the cache is shared across
        // thread counts and pipeline settings.
        let mut sched = cell.clone();
        sched.config.threads = 7;
        sched.config.pipeline = !sched.config.pipeline;
        sched.config.bucket_kb = 1234;
        assert_eq!(fp, fingerprint(&plan.workload, &sched));
        // Float-affecting knobs are included.
        let mut other = cell.clone();
        other.config.epochs += 1;
        assert_ne!(fp, fingerprint(&plan.workload, &other));
        let mut reseeded = cell.clone();
        reseeded.config.seed += 1;
        assert_ne!(fp, fingerprint(&plan.workload, &reseeded));
        // Suffixed planes extend (not rewrite) the base key.
        let mut topo = cell.clone();
        topo.topology = Some(TopologyRef::named("one_peer"));
        let tfp = fingerprint(&plan.workload, &topo);
        assert!(tfp.starts_with(&fp), "{tfp}");
        assert!(tfp.contains("topology="), "{tfp}");
    }

    #[test]
    fn resume_rejects_stale_cells_after_config_change() {
        let dir = crate::util::scratch_dir("plan_stale").unwrap();
        let mut spec = tiny_spec();
        let mut plan = SessionPlan::from_spec(&spec);
        plan.resume_dir = Some(dir.clone());
        let short = plan.run().unwrap();
        // Same grid, more epochs: the persisted 2-epoch cells must NOT
        // be reused as 3-epoch results.
        spec.epochs = 3;
        let mut plan3 = SessionPlan::from_spec(&spec);
        plan3.resume_dir = Some(dir.clone());
        let long = plan3.run().unwrap();
        for (a, b) in short.iter().zip(&long) {
            assert!(
                b.recorder.records().len() > a.recorder.records().len(),
                "{}: stale cell reused ({} vs {} records)",
                b.flavor,
                b.recorder.records().len(),
                a.recorder.records().len()
            );
        }
        // And the refreshed files are reusable again.
        let again = plan3.run().unwrap();
        for (a, b) in long.iter().zip(&again) {
            assert_eq!(a.recorder.records().len(), b.recorder.records().len());
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn topology_ref_parses_cli_syntax() {
        let t = TopologyRef::parse("ada:k0=10,gamma_k=0.5").unwrap();
        assert_eq!(t.name, "ada");
        assert_eq!(t.params.get_usize("k0").unwrap(), Some(10));
        assert_eq!(t.params.get_f64("gamma_k").unwrap(), Some(0.5));
        let bare = TopologyRef::parse("one_peer").unwrap();
        assert_eq!(bare.name, "one_peer");
        assert!(bare.params.is_empty());
        assert!(TopologyRef::parse(":k=1").is_err());
        assert!(TopologyRef::parse("ada:k0").is_err());
    }

    #[test]
    fn strategy_ref_parses_cli_syntax() {
        let s = StrategyRef::parse("compressed_gossip:codec=f16,k=1024").unwrap();
        match &s {
            StrategyRef::Named { name, params } => {
                assert_eq!(name, "compressed_gossip");
                assert_eq!(params.extra.get_str("codec").unwrap(), Some("f16"));
                assert_eq!(params.extra.get_usize("k").unwrap(), Some(1024));
            }
            other => panic!("expected Named, got {other:?}"),
        }
        assert_eq!(s.key(), "compressed_gossip");
        let bare = StrategyRef::parse("d2").unwrap();
        assert_eq!(bare.key(), "d2");
        assert!(StrategyRef::parse(":codec=bf16").is_err());
        // Unknown param keys fail at parse time, not resolution time.
        assert!(StrategyRef::parse("gossip:tpyo=1").is_err());
    }

    #[test]
    fn topology_cells_resolve_and_label_with_suffix() {
        let mut spec = tiny_spec();
        spec.flavors = vec![SgdFlavor::DecentralizedRing];
        let mut plan = SessionPlan::from_spec(&spec);
        plan.push_cell_with_topology(
            4,
            spec.seed,
            StrategyRef::Flavor(SgdFlavor::DecentralizedRing),
            TopologyRef::parse("static:graph=complete").unwrap(),
            spec.train_config(4),
        );
        let cells = plan.run().unwrap();
        assert_eq!(cells.len(), 2);
        assert_eq!(cells[0].flavor, "D_ring");
        assert_eq!(cells[1].flavor, "D_ring+static");
        // Complete-graph gossip sends more bytes than the ring override
        // it replaced — proof the policy actually drove the run.
        assert!(
            cells[1].summary.bytes_per_node > cells[0].summary.bytes_per_node,
            "{} vs {}",
            cells[1].summary.bytes_per_node,
            cells[0].summary.bytes_per_node
        );
        // An unknown topology name fails at resolution with the
        // registered list in the message.
        let mut bad = SessionPlan::from_spec(&spec);
        bad.push_cell_with_topology(
            4,
            spec.seed,
            StrategyRef::Flavor(SgdFlavor::DecentralizedRing),
            TopologyRef::named("mystery"),
            spec.train_config(4),
        );
        let err = bad.run_cell_plan(&bad.cells[1]).unwrap_err().to_string();
        assert!(err.contains("mystery"), "{err}");
    }

    #[test]
    fn spec_topology_override_skips_centralized_cells() {
        let mut spec = tiny_spec();
        spec.flavors = vec![
            SgdFlavor::CentralizedComplete,
            SgdFlavor::DecentralizedRing,
        ];
        spec.topology = Some(TopologyRef::named("one_peer"));
        let plan = SessionPlan::from_spec(&spec);
        assert_eq!(plan.cells[0].topology, None, "C_complete stays centralized");
        assert_eq!(
            plan.cells[1].topology.as_ref().map(|t| t.name.as_str()),
            Some("one_peer")
        );
    }

    #[test]
    fn named_centralized_strategies_ignore_the_override_too() {
        // A cell that *explicitly* carries a TopologyRef but resolves to
        // a schedule-less (centralized) instance: the override is
        // skipped at resolution time — same rule as the flavor path —
        // so the run stays centralized and the label unsuffixed.
        let mut plan = SessionPlan::from_spec(&tiny_spec());
        plan.cells.clear();
        plan.push_cell_with_topology(
            4,
            42,
            StrategyRef::named("C_complete"),
            TopologyRef::named("one_peer"),
            tiny_spec().train_config(4),
        );
        let with_override = plan.run_cell_plan(&plan.cells[0]).unwrap();
        assert_eq!(with_override.flavor, "C_complete", "no +one_peer suffix");
        // Bit-identical to the same cell without the (ignored) override.
        let mut bare = plan.cells[0].clone();
        bare.topology = None;
        let plain = plan.run_cell_plan(&bare).unwrap();
        assert_eq!(
            with_override.summary.final_eval.metric,
            plain.summary.final_eval.metric
        );
        assert_eq!(
            with_override.summary.bytes_per_node,
            plain.summary.bytes_per_node
        );
    }

    #[test]
    fn expanded_seed_cells_fingerprint_differently() {
        let mut plan = SessionPlan::from_spec(&tiny_spec());
        plan.cells.truncate(1);
        plan.expand_seeds(2);
        assert_eq!(plan.cells.len(), 2);
        assert_eq!(plan.cells[1].index, 1);
        assert_ne!(
            plan.cell_fingerprint(&plan.cells[0]),
            plan.cell_fingerprint(&plan.cells[1]),
            "replicates must not share a resume cache entry"
        );
    }

    #[test]
    fn per_cell_seeds_are_honored() {
        let mut plan = SessionPlan::from_spec(&tiny_spec());
        plan.cells.truncate(1);
        let base = plan.run_cell_plan(&plan.cells[0]).unwrap();
        let mut reseeded = plan.cells[0].clone();
        reseeded.seed = 1234;
        reseeded.config.seed = 1234;
        let other = plan.run_cell_plan(&reseeded).unwrap();
        assert_ne!(
            base.recorder.records()[0].train_loss,
            other.recorder.records()[0].train_loss,
            "a different cell seed must change the data stream"
        );
    }
}
