//! Communication-budget-aware topology — pick the **densest graph
//! affordable** under a bytes-per-node budget for the whole run, in the
//! communication/topology co-design spirit of Wang et al. 2024 (*From
//! Promise to Practice*).
//!
//! The Ada lattice family prices linearly: a `k`-lattice epoch costs
//! `k · 4 · P · iters_per_epoch` bytes per node (each round every node
//! sends its `P` f32 parameters to `k` neighbors). Given the run
//! geometry from [`TopologyPolicy::on_run_start`] and the cumulative
//! spend reported through [`TrainSignals::comm_bytes_per_node`], each
//! epoch `e` picks
//!
//! ```text
//! k_e = clamp( (budget − spent) / (4·P·iters · (epochs − e)), 2 ..= k0 )
//! ```
//!
//! — the densest sustainable coordination number if the remaining
//! budget were spread evenly over the remaining epochs. Under-spending
//! early (because `k` is capped at `k0`) automatically rolls the savings
//! forward into denser later epochs; over-budget configurations degrade
//! to the `k = 2` ring floor rather than erroring. The pricing treats
//! `k` as the degree, which over-estimates odd `k` (the lattice builder
//! truncates to `2·⌊k/2⌋` neighbors) — conservative, and corrected each
//! epoch anyway because [`observe`](TopologyPolicy::observe) feeds back
//! the *measured* spend.

use super::{RunInfo, TopologyPolicy, TrainSignals};
use crate::error::Result;
use crate::graph::{CommGraph, GraphKind};
use std::collections::HashMap;
use std::sync::Mutex;

/// Budget-constrained densest-affordable-lattice policy.
///
/// The budget covers one session: a checkpoint-resumed run re-budgets
/// the remaining epochs from zero spend, because
/// [`TrainSignals::comm_bytes_per_node`] counts per session leg (the
/// checkpoint format carries no byte ledger). Size `budget_mb` per leg
/// when resuming.
#[derive(Debug)]
pub struct CommBudget {
    n: usize,
    /// Densest allowed coordination number (cap).
    k0: usize,
    /// Whole-run budget, bytes per node.
    budget_bytes: u64,
    state: Mutex<State>,
}

#[derive(Debug)]
struct State {
    /// Bytes per node per unit k per epoch (`4·P·iters`); 0 until
    /// `on_run_start` delivers the geometry.
    epoch_cost_per_k: u64,
    /// Total epochs of the run.
    epochs: usize,
    /// Cumulative spend after the most recently observed epoch.
    spent: u64,
    /// k pinned per epoch, assigned the first time the epoch is priced.
    history: HashMap<usize, usize>,
    cache: HashMap<usize, CommGraph>,
}

impl CommBudget {
    /// A policy over `n` nodes capped at coordination number `k0`,
    /// spending at most `budget_bytes` per node across the whole run.
    pub fn new(n: usize, k0: usize, budget_bytes: u64) -> Self {
        CommBudget {
            n,
            k0: k0.max(2),
            budget_bytes,
            state: Mutex::new(State {
                epoch_cost_per_k: 0,
                epochs: 0,
                spent: 0,
                history: HashMap::new(),
                cache: HashMap::new(),
            }),
        }
    }

    /// Convenience constructor taking the budget in megabytes (the
    /// registry's `budget_mb` parameter).
    pub fn with_budget_mb(n: usize, k0: usize, budget_mb: f64) -> Self {
        Self::new(n, k0, (budget_mb.max(0.0) * 1e6) as u64)
    }

    /// The k this policy would run `epoch` with, given what it has
    /// observed so far.
    pub fn k_for_epoch(&self, epoch: usize) -> usize {
        let mut st = self.state.lock().expect("state poisoned");
        self.price_epoch(&mut st, epoch)
    }

    /// Affordable k at `epoch`, pinning it in the history. Before
    /// `on_run_start` no pricing is possible and the floor `k = 2` is
    /// used (a session always delivers the geometry first).
    fn price_epoch(&self, st: &mut State, epoch: usize) -> usize {
        if let Some(&k) = st.history.get(&epoch) {
            return k;
        }
        let k = if st.epoch_cost_per_k == 0 || epoch >= st.epochs {
            2
        } else {
            let remaining_epochs = (st.epochs - epoch) as u64;
            let remaining_budget = self.budget_bytes.saturating_sub(st.spent);
            let affordable = remaining_budget / (st.epoch_cost_per_k * remaining_epochs);
            (affordable as usize).clamp(2, self.k0)
        };
        st.history.insert(epoch, k);
        k
    }
}

impl TopologyPolicy for CommBudget {
    fn graph_for(&self, epoch: usize, _iter: usize) -> Result<CommGraph> {
        let mut st = self.state.lock().expect("state poisoned");
        let k = self.price_epoch(&mut st, epoch);
        if let Some(g) = st.cache.get(&k) {
            return Ok(g.clone());
        }
        let g = CommGraph::build(GraphKind::AdaLattice { k }, self.n)?;
        st.cache.insert(k, g.clone());
        Ok(g)
    }

    fn on_run_start(&mut self, info: &RunInfo) {
        let mut st = self.state.lock().expect("state poisoned");
        st.epoch_cost_per_k = 4 * info.param_count as u64 * info.iters_per_epoch.max(1) as u64;
        st.epochs = info.epochs;
    }

    fn observe(&mut self, signals: &TrainSignals) {
        let mut st = self.state.lock().expect("state poisoned");
        // The session reports *measured* cumulative spend, which also
        // absorbs rounds the pricing could not foresee (failure
        // injection, strategies that skip exchanges).
        st.spent = signals.comm_bytes_per_node;
    }

    fn name(&self) -> String {
        format!(
            "comm_budget(k0={},budget={:.1}MB)",
            self.k0,
            self.budget_bytes as f64 / 1e6
        )
    }

    fn k_hint(&self) -> usize {
        // Deliberately the floor, not k0: the hint feeds Table 2's LR
        // scaling (`s = batch·(k+1)/divisor`), and a tight budget may
        // never afford k0 — scaling the LR for a density that never
        // executes risks divergence on the ring-floor epochs. The
        // sparse-safe LR merely under-serves denser epochs.
        2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn started(
        n: usize,
        k0: usize,
        budget: u64,
        p: usize,
        iters: usize,
        epochs: usize,
    ) -> CommBudget {
        let mut s = CommBudget::new(n, k0, budget);
        s.on_run_start(&RunInfo {
            n_workers: n,
            param_count: p,
            epochs,
            iters_per_epoch: iters,
        });
        s
    }

    fn spent(epoch: usize, bytes: u64) -> TrainSignals {
        TrainSignals {
            epoch,
            comm_bytes_per_node: bytes,
            ..TrainSignals::default()
        }
    }

    #[test]
    fn picks_the_densest_sustainable_k() {
        // 4·P·iters = 4·1000·10 = 40_000 bytes per unit k per epoch.
        // Budget 800_000 over 5 epochs → 160_000/epoch → k = 4.
        let s = started(16, 12, 800_000, 1000, 10, 5);
        assert_eq!(s.k_for_epoch(0), 4);
        assert_eq!(s.graph_for_epoch(0).unwrap().degree(), 4);
    }

    #[test]
    fn caps_at_k0_and_rolls_savings_forward() {
        // Budget would afford k = 20/epoch but the cap is 6: early
        // under-spend leaves more than enough for k = 6 throughout.
        let s = started(32, 6, 4_000_000, 1000, 10, 5);
        assert_eq!(s.k_for_epoch(0), 6);
        let mut s = s;
        // After one 6-lattice epoch (240_000 bytes), remaining budget
        // still affords the cap for the remaining 4 epochs.
        s.observe(&spent(0, 240_000));
        assert_eq!(s.k_for_epoch(1), 6);
    }

    #[test]
    fn overspending_degrades_toward_the_ring_floor() {
        // Budget 400_000 over 4 epochs at 40_000/k/epoch → k = 2 (floor:
        // sustainable would be 2.5). Report a blowout and it stays 2.
        let mut s = started(16, 12, 400_000, 1000, 10, 4);
        assert_eq!(s.k_for_epoch(0), 2);
        s.observe(&spent(0, 399_999));
        assert_eq!(s.k_for_epoch(1), 2, "floor even with nothing left");
    }

    #[test]
    fn unpriced_runs_floor_and_epochs_pin_their_k() {
        let s = CommBudget::new(16, 8, 1_000_000);
        assert_eq!(s.k_for_epoch(0), 2, "no geometry yet → floor");
        let mut s = started(16, 8, 3_200_000, 1000, 10, 5);
        assert_eq!(s.k_for_epoch(0), 8); // 640_000/epoch → k capped at 8
        // A later blowout must not rewrite epoch 0's pinned k.
        s.observe(&spent(0, 3_000_000));
        assert_eq!(s.k_for_epoch(0), 8, "epoch 0 keeps the k it ran with");
        assert_eq!(s.k_for_epoch(1), 2, "epoch 1 repriced after the blowout");
    }

    #[test]
    fn budget_mb_constructor_converts() {
        let s = CommBudget::with_budget_mb(16, 8, 1.5);
        assert_eq!(s.budget_bytes, 1_500_000);
        assert_eq!(s.name(), "comm_budget(k0=8,budget=1.5MB)");
        assert_eq!(s.k_hint(), 2, "LR hint stays sparse-safe, not k0");
    }
}
