//! Variance-triggered adaptive schedule — an extension built directly on
//! the paper's Observation 4: the cross-replica parameter-tensor variance
//! (gini coefficient) is high early and diminishes as training progresses,
//! and the benefit of dense graphs tracks that variance.
//!
//! Instead of Ada's fixed epoch clock (`k0 − int(γk·epoch)`), this
//! schedule *measures* the gini coefficient each epoch and steps `k` down
//! only when the variance has fallen below a threshold for `patience`
//! consecutive epochs — a feedback controller on the same signal the
//! white-box analysis identified.

use super::{TopologyPolicy, TrainSignals};
use crate::error::Result;
use crate::graph::{CommGraph, GraphKind};
use std::collections::HashMap;
use std::sync::Mutex;

/// Feedback-driven coordination-number controller.
#[derive(Debug)]
pub struct VarianceAdaptive {
    n: usize,
    k0: usize,
    /// Decay k by this much per trigger.
    step: usize,
    /// Gini threshold below which a decay is considered.
    threshold: f64,
    /// Consecutive below-threshold epochs required before decaying.
    patience: usize,
    state: Mutex<State>,
}

#[derive(Debug)]
struct State {
    k: usize,
    below_count: usize,
    /// k effective per epoch, recorded as observations arrive; epochs not
    /// yet observed use the current k.
    history: HashMap<usize, usize>,
    cache: HashMap<usize, CommGraph>,
}

impl VarianceAdaptive {
    /// `threshold` is on the gini coefficient of cross-replica parameter
    /// L2 norms (≈ 0.0005–0.05 in practice; see Fig 4 of the paper).
    pub fn new(n: usize, k0: usize, step: usize, threshold: f64, patience: usize) -> Self {
        VarianceAdaptive {
            n,
            k0,
            step: step.max(1),
            threshold,
            patience: patience.max(1),
            state: Mutex::new(State {
                k: k0,
                below_count: 0,
                history: HashMap::new(),
                cache: HashMap::new(),
            }),
        }
    }

    /// Current coordination number.
    pub fn current_k(&self) -> usize {
        self.state.lock().expect("state poisoned").k
    }
}

impl TopologyPolicy for VarianceAdaptive {
    fn graph_for(&self, epoch: usize, _iter: usize) -> Result<CommGraph> {
        let mut st = self.state.lock().expect("state poisoned");
        let k = st.history.get(&epoch).copied().unwrap_or(st.k);
        if let Some(g) = st.cache.get(&k) {
            return Ok(g.clone());
        }
        let g = CommGraph::build(GraphKind::AdaLattice { k }, self.n)?;
        st.cache.insert(k, g.clone());
        Ok(g)
    }

    fn observe(&mut self, signals: &TrainSignals) {
        let mut st = self.state.lock().expect("state poisoned");
        let current_k = st.k;
        st.history.insert(signals.epoch, current_k);
        // Epochs without a variance capture pin their k but cannot
        // trigger a decay — exactly the pre-redesign call pattern, where
        // observe simply never fired without a gini sample.
        let Some(gini) = signals.gini else { return };
        if gini < self.threshold {
            st.below_count += 1;
            if st.below_count >= self.patience {
                st.k = st.k.saturating_sub(self.step).max(2);
                st.below_count = 0;
            }
        } else {
            st.below_count = 0;
        }
    }

    fn name(&self) -> String {
        format!(
            "variance_adaptive(k0={},step={},thr={})",
            self.k0, self.step, self.threshold
        )
    }

    fn k_hint(&self) -> usize {
        self.k0.max(2)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gini(epoch: usize, g: f64) -> TrainSignals {
        TrainSignals::for_epoch_gini(epoch, g)
    }

    #[test]
    fn stays_dense_while_variance_high() {
        let mut s = VarianceAdaptive::new(16, 8, 2, 0.01, 2);
        for e in 0..5 {
            s.observe(&gini(e, 0.5)); // high variance
        }
        assert_eq!(s.current_k(), 8);
    }

    #[test]
    fn decays_after_patience_epochs_below_threshold() {
        let mut s = VarianceAdaptive::new(16, 8, 2, 0.01, 2);
        s.observe(&gini(0, 0.001));
        assert_eq!(s.current_k(), 8, "patience not yet met");
        s.observe(&gini(1, 0.001));
        assert_eq!(s.current_k(), 6, "decayed by step after patience");
    }

    #[test]
    fn spike_resets_patience() {
        let mut s = VarianceAdaptive::new(16, 8, 2, 0.01, 3);
        s.observe(&gini(0, 0.001));
        s.observe(&gini(1, 0.001));
        s.observe(&gini(2, 0.9)); // spike
        s.observe(&gini(3, 0.001));
        s.observe(&gini(4, 0.001));
        assert_eq!(s.current_k(), 8, "spike must reset the counter");
        s.observe(&gini(5, 0.001));
        assert_eq!(s.current_k(), 6);
    }

    #[test]
    fn floors_at_k2() {
        let mut s = VarianceAdaptive::new(16, 4, 10, 0.5, 1);
        s.observe(&gini(0, 0.0));
        s.observe(&gini(1, 0.0));
        assert_eq!(s.current_k(), 2, "k never drops below 2 (Algorithm 1)");
    }

    #[test]
    fn epochs_without_a_capture_cannot_trigger_decay() {
        let mut s = VarianceAdaptive::new(16, 8, 2, 0.01, 1);
        s.observe(&TrainSignals { epoch: 0, gini: None, ..TrainSignals::default() });
        assert_eq!(s.current_k(), 8, "no gini sample, no decay");
        s.observe(&gini(1, 0.001));
        assert_eq!(s.current_k(), 6);
    }

    #[test]
    fn graph_for_observed_epoch_uses_recorded_k() {
        let mut s = VarianceAdaptive::new(16, 8, 4, 0.01, 1);
        let g0 = s.graph_for_epoch(0).unwrap();
        assert_eq!(g0.degree(), 8);
        s.observe(&gini(0, 0.0)); // k → 4
        let g1 = s.graph_for_epoch(1).unwrap();
        assert_eq!(g1.degree(), 4);
        // Epoch 0 is pinned to the k it actually ran with.
        assert_eq!(s.graph_for_epoch(0).unwrap().degree(), 8);
    }
}
