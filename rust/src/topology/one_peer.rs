//! One-peer exponential schedule (Ying et al. 2021, cited by the paper):
//! each round every node talks to exactly **one** neighbor at offset
//! `2^(t mod ⌈log2 n⌉)`, cycling through the exponential graph's edges.
//! Over a full cycle this achieves the mixing of the static exponential
//! graph at degree-1 per-round communication — the communication-minimal
//! corner of the design space that Ada is compared against.

use super::TopologySchedule;
use crate::error::Result;
use crate::graph::{CommGraph, GraphKind};

/// Rotating single-neighbor exponential schedule.
#[derive(Debug, Clone)]
pub struct OnePeerExponential {
    n: usize,
    /// Number of distinct offsets = ⌊log2(n−1)⌋ + 1.
    period: usize,
}

impl OnePeerExponential {
    /// Create the schedule over `n ≥ 3` nodes.
    pub fn new(n: usize) -> Result<Self> {
        // Validate n by building the static exponential graph once.
        let g = CommGraph::build(GraphKind::Exponential, n)?;
        Ok(OnePeerExponential {
            n,
            period: g.degree(),
        })
    }

    /// Offsets cycle with this period.
    pub fn period(&self) -> usize {
        self.period
    }
}

impl TopologySchedule for OnePeerExponential {
    fn graph_for_epoch(&self, epoch: usize) -> Result<CommGraph> {
        let m = epoch % self.period;
        let offset = 1usize << m;
        let neighbors = (0..self.n)
            .map(|i| {
                let j = (i + offset) % self.n;
                if j == i {
                    vec![]
                } else {
                    vec![j]
                }
            })
            .collect();
        CommGraph::from_neighbor_lists(GraphKind::Exponential, neighbors, true)
    }

    fn name(&self) -> String {
        format!("one_peer_exponential(n={})", self.n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn each_round_has_degree_one() {
        let s = OnePeerExponential::new(16).unwrap();
        for e in 0..s.period() {
            let g = s.graph_for_epoch(e).unwrap();
            assert_eq!(g.degree(), 1, "round {e}");
            assert!(g.is_regular());
        }
    }

    #[test]
    fn rounds_cycle_through_powers_of_two() {
        let s = OnePeerExponential::new(16).unwrap();
        assert_eq!(s.period(), 4); // ⌊log2 15⌋ + 1
        let g0 = s.graph_for_epoch(0).unwrap();
        assert_eq!(g0.neighbors_of(0), &[1]);
        let g2 = s.graph_for_epoch(2).unwrap();
        assert_eq!(g2.neighbors_of(0), &[4]);
        let g4 = s.graph_for_epoch(4).unwrap();
        assert_eq!(g4.neighbors_of(0), &[1], "period wraps");
    }

    #[test]
    fn per_round_mixing_preserves_mean() {
        // Each per-round W is doubly stochastic (permutation-structured):
        // rows and columns sum to 1. A single round need not be
        // *connected* — only the union over a period is — so this checks
        // stochasticity directly rather than `validate()`.
        let s = OnePeerExponential::new(12).unwrap();
        let n = 12;
        for e in 0..s.period() {
            let g = s.graph_for_epoch(e).unwrap();
            let w = g.dense_mixing();
            for i in 0..n {
                let row: f32 = (0..n).map(|j| w[i * n + j]).sum();
                let col: f32 = (0..n).map(|j| w[j * n + i]).sum();
                assert!((row - 1.0).abs() < 1e-6, "round {e} row {i}: {row}");
                assert!((col - 1.0).abs() < 1e-6, "round {e} col {i}: {col}");
            }
        }
    }

    #[test]
    fn union_over_period_is_connected() {
        let s = OnePeerExponential::new(16).unwrap();
        let mut union: Vec<Vec<usize>> = vec![Vec::new(); 16];
        for e in 0..s.period() {
            let g = s.graph_for_epoch(e).unwrap();
            for i in 0..16 {
                union[i].extend_from_slice(g.neighbors_of(i));
            }
        }
        for nb in union.iter_mut() {
            nb.sort_unstable();
            nb.dedup();
        }
        let g = crate::graph::CommGraph::from_neighbor_lists(
            crate::graph::GraphKind::Exponential,
            union,
            true,
        )
        .unwrap();
        assert!(g.is_connected(), "union over a period must be connected");
    }

    #[test]
    fn cheapest_communication_of_all_schedules() {
        let one = OnePeerExponential::new(64).unwrap();
        let bytes = one.comm_bytes_per_node(10, 5, 1000).unwrap();
        assert_eq!(bytes, 1 * 4 * 1000 * 5 * 10);
    }
}
