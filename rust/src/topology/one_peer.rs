//! One-peer exponential schedule (Ying et al. 2021, cited by the paper):
//! each round every node talks to exactly **one** neighbor at offset
//! `2^(t mod ⌈log2 n⌉)`, cycling through the exponential graph's edges.
//! Over a full cycle this achieves the mixing of the static exponential
//! graph at degree-1 per-round communication — the communication-minimal
//! corner of the design space that Ada is compared against.
//!
//! Two rotation cadences:
//!
//! * **per-epoch** ([`OnePeerExponential::new`], the default and the
//!   pre-redesign behaviour, kept bit-identical): the offset advances
//!   once per epoch — every iteration of an epoch reuses one offset.
//! * **per-iteration** ([`OnePeerExponential::per_iteration`]): the
//!   offset advances every gossip round, which is what Ying et al.
//!   actually prescribe — the whole point of the iteration-level
//!   decision point `graph_for(epoch, iter)`.

use super::{RunInfo, TopologyPolicy};
use crate::error::Result;
use crate::graph::{CommGraph, GraphKind};

/// Rotating single-neighbor exponential schedule.
#[derive(Debug, Clone)]
pub struct OnePeerExponential {
    n: usize,
    /// Number of distinct offsets = ⌊log2(n−1)⌋ + 1.
    period: usize,
    /// Advance the offset every iteration instead of every epoch.
    per_iter: bool,
    /// Gossip rounds per epoch (from [`TopologyPolicy::on_run_start`]);
    /// only the per-iteration cadence consumes it.
    iters_per_epoch: usize,
}

impl OnePeerExponential {
    /// The per-epoch-rotating schedule over `n ≥ 3` nodes.
    pub fn new(n: usize) -> Result<Self> {
        // Validate n by building the static exponential graph once.
        let g = CommGraph::build(GraphKind::Exponential, n)?;
        Ok(OnePeerExponential {
            n,
            period: g.degree(),
            per_iter: false,
            iters_per_epoch: 1,
        })
    }

    /// The per-iteration-rotating variant: the offset advances on every
    /// gossip round, completing a full mixing cycle every `period`
    /// *iterations* rather than every `period` epochs.
    pub fn per_iteration(n: usize) -> Result<Self> {
        let mut s = Self::new(n)?;
        s.per_iter = true;
        Ok(s)
    }

    /// Offsets cycle with this period.
    pub fn period(&self) -> usize {
        self.period
    }

    /// Whether the offset advances per iteration.
    pub fn rotates_per_iteration(&self) -> bool {
        self.per_iter
    }

    fn graph_at(&self, round: usize) -> Result<CommGraph> {
        let m = round % self.period;
        let offset = 1usize << m;
        let neighbors = (0..self.n)
            .map(|i| {
                let j = (i + offset) % self.n;
                if j == i {
                    vec![]
                } else {
                    vec![j]
                }
            })
            .collect();
        CommGraph::from_neighbor_lists(GraphKind::Exponential, neighbors, true)
    }
}

impl TopologyPolicy for OnePeerExponential {
    fn graph_for(&self, epoch: usize, iter: usize) -> Result<CommGraph> {
        if self.per_iter {
            self.graph_at(epoch * self.iters_per_epoch + iter)
        } else {
            self.graph_at(epoch)
        }
    }

    fn iteration_scoped(&self) -> bool {
        self.per_iter
    }

    fn on_run_start(&mut self, info: &RunInfo) {
        self.iters_per_epoch = info.iters_per_epoch.max(1);
    }

    fn name(&self) -> String {
        if self.per_iter {
            format!("one_peer_exponential(n={},per_iter)", self.n)
        } else {
            format!("one_peer_exponential(n={})", self.n)
        }
    }

    fn k_hint(&self) -> usize {
        1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn each_round_has_degree_one() {
        let s = OnePeerExponential::new(16).unwrap();
        for e in 0..s.period() {
            let g = s.graph_for_epoch(e).unwrap();
            assert_eq!(g.degree(), 1, "round {e}");
            assert!(g.is_regular());
        }
    }

    #[test]
    fn rounds_cycle_through_powers_of_two() {
        let s = OnePeerExponential::new(16).unwrap();
        assert_eq!(s.period(), 4); // ⌊log2 15⌋ + 1
        let g0 = s.graph_for_epoch(0).unwrap();
        assert_eq!(g0.neighbors_of(0), &[1]);
        let g2 = s.graph_for_epoch(2).unwrap();
        assert_eq!(g2.neighbors_of(0), &[4]);
        let g4 = s.graph_for_epoch(4).unwrap();
        assert_eq!(g4.neighbors_of(0), &[1], "period wraps");
    }

    #[test]
    fn epoch_cadence_ignores_the_iteration() {
        let s = OnePeerExponential::new(16).unwrap();
        assert!(!s.iteration_scoped());
        assert_eq!(
            s.graph_for(1, 0).unwrap().neighbors_of(0),
            s.graph_for(1, 7).unwrap().neighbors_of(0),
            "per-epoch rotation must reuse one offset all epoch"
        );
    }

    #[test]
    fn per_iteration_cadence_rotates_within_an_epoch() {
        let mut s = OnePeerExponential::per_iteration(16).unwrap();
        assert!(s.iteration_scoped());
        s.on_run_start(&RunInfo {
            n_workers: 16,
            param_count: 100,
            epochs: 2,
            iters_per_epoch: 3,
        });
        assert_eq!(s.graph_for(0, 0).unwrap().neighbors_of(0), &[1]);
        assert_eq!(s.graph_for(0, 1).unwrap().neighbors_of(0), &[2]);
        assert_eq!(s.graph_for(0, 2).unwrap().neighbors_of(0), &[4]);
        // Epoch 1 continues the global round counter: 1·3 + 0 = round 3.
        assert_eq!(s.graph_for(1, 0).unwrap().neighbors_of(0), &[8]);
        assert_eq!(s.graph_for(1, 1).unwrap().neighbors_of(0), &[1], "wraps");
    }

    #[test]
    fn per_round_mixing_preserves_mean() {
        // Each per-round W is doubly stochastic (permutation-structured):
        // rows and columns sum to 1. A single round need not be
        // *connected* — only the union over a period is — so this checks
        // stochasticity directly rather than `validate()`.
        let s = OnePeerExponential::new(12).unwrap();
        let n = 12;
        for e in 0..s.period() {
            let g = s.graph_for_epoch(e).unwrap();
            let w = g.dense_mixing();
            for i in 0..n {
                let row: f32 = (0..n).map(|j| w[i * n + j]).sum();
                let col: f32 = (0..n).map(|j| w[j * n + i]).sum();
                assert!((row - 1.0).abs() < 1e-6, "round {e} row {i}: {row}");
                assert!((col - 1.0).abs() < 1e-6, "round {e} col {i}: {col}");
            }
        }
    }

    #[test]
    fn union_over_period_is_connected() {
        let s = OnePeerExponential::new(16).unwrap();
        let mut union: Vec<Vec<usize>> = vec![Vec::new(); 16];
        for e in 0..s.period() {
            let g = s.graph_for_epoch(e).unwrap();
            for i in 0..16 {
                union[i].extend_from_slice(g.neighbors_of(i));
            }
        }
        for nb in union.iter_mut() {
            nb.sort_unstable();
            nb.dedup();
        }
        let g = crate::graph::CommGraph::from_neighbor_lists(
            crate::graph::GraphKind::Exponential,
            union,
            true,
        )
        .unwrap();
        assert!(g.is_connected(), "union over a period must be connected");
    }

    #[test]
    fn cheapest_communication_of_all_schedules() {
        let one = OnePeerExponential::new(64).unwrap();
        let bytes = one.comm_bytes_per_node(10, 5, 1000).unwrap();
        assert_eq!(bytes, 4 * 1000 * 5 * 10);
        // The per-iteration variant spends exactly the same: degree 1
        // every round, whichever round it is.
        let per_iter = OnePeerExponential::per_iteration(64).unwrap();
        assert_eq!(per_iter.comm_bytes_per_node(10, 5, 1000).unwrap(), bytes);
    }
}
