//! Per-epoch reseeded random-regular expander schedule.
//!
//! The random d-regular family is the expander the theory literature
//! analyzes: a *fresh* draw every epoch keeps the expected spectral gap
//! of the averaged mixing process near the Ramanujan bound while every
//! single round still costs only `d` messages — the same
//! communication/connectivity trade `one_peer per_iter=true` makes at
//! iteration granularity, here at epoch granularity with degree `d`.
//! Registered as `random_regular` (`d`/`seed` params) so it can be
//! benchmarked head-to-head against the one-peer rotation.

use super::TopologyPolicy;
use crate::error::Result;
use crate::graph::{CommGraph, GraphKind};

/// A fresh seeded random d-regular graph each epoch
/// ([`GraphKind::RandomRegular`], permutation-union construction). The
/// epoch-`e` graph is a pure function of `(seed, e)`, so runs stay
/// bit-identical across thread counts and resumable mid-run.
#[derive(Debug, Clone)]
pub struct RandomRegularSchedule {
    n: usize,
    d: usize,
    seed: u64,
}

impl RandomRegularSchedule {
    /// New schedule over `n` nodes with even degree `d`; fails fast on
    /// the constraints the graph builder enforces (`d` even, `d < n`).
    pub fn new(n: usize, d: usize, seed: u64) -> Result<Self> {
        // Build the epoch-0 graph once so a bad (d, n) pair errors at
        // construction, not mid-run.
        CommGraph::build(GraphKind::RandomRegular { d, seed }, n)?;
        Ok(RandomRegularSchedule { n, d, seed })
    }

    /// The derived construction seed for `epoch` — splitmix-style
    /// golden-ratio stride so consecutive epochs land far apart in the
    /// builder's seed space while epoch 0 keeps the user's seed.
    fn epoch_seed(&self, epoch: usize) -> u64 {
        self.seed
            .wrapping_add((epoch as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }
}

impl TopologyPolicy for RandomRegularSchedule {
    fn graph_for(&self, epoch: usize, _iter: usize) -> Result<CommGraph> {
        CommGraph::build(
            GraphKind::RandomRegular { d: self.d, seed: self.epoch_seed(epoch) },
            self.n,
        )
    }

    fn name(&self) -> String {
        format!("random_regular(d={}, seed={})", self.d, self.seed)
    }

    fn k_hint(&self) -> usize {
        self.d
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reseeds_per_epoch_deterministically() {
        let s = RandomRegularSchedule::new(16, 4, 7).unwrap();
        let e0 = s.graph_for_epoch(0).unwrap();
        let e1 = s.graph_for_epoch(1).unwrap();
        // Same epoch → identical graph (bit-identical resume contract).
        assert_eq!(e0.dense_mixing(), s.graph_for(0, 3).unwrap().dense_mixing());
        // Different epochs → a fresh draw (the 16-choose-edges space is
        // large enough that a collision means the reseed is broken).
        assert_ne!(e0.dense_mixing(), e1.dense_mixing());
        // Degree is d every epoch.
        assert_eq!(e0.degree(), 4);
        assert_eq!(e1.degree(), 4);
        assert_eq!(s.k_hint(), 4);
        assert!(!s.iteration_scoped());
        assert_eq!(s.name(), "random_regular(d=4, seed=7)");
    }

    #[test]
    fn epoch_zero_keeps_the_user_seed() {
        let s = RandomRegularSchedule::new(16, 4, 9).unwrap();
        let direct = CommGraph::build(GraphKind::RandomRegular { d: 4, seed: 9 }, 16).unwrap();
        assert_eq!(s.graph_for_epoch(0).unwrap().dense_mixing(), direct.dense_mixing());
    }

    #[test]
    fn invalid_degree_fails_at_construction() {
        assert!(RandomRegularSchedule::new(16, 3, 0).is_err(), "odd d");
        assert!(RandomRegularSchedule::new(16, 16, 0).is_err(), "d >= n");
    }
}
