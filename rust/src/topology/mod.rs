//! Topology schedules: which communication graph each epoch uses.
//!
//! The paper's contribution, **Ada** (§4), is a schedule: start from a
//! highly connected ring lattice and decay its coordination number `k`
//! per epoch (Algorithm 1), trading connectivity for communication cost
//! exactly when the white-box analysis (§3.3) shows the cross-graph
//! variance differences have diminished.
//!
//! Alongside [`AdaSchedule`] we provide [`StaticSchedule`] (the fixed
//! graphs DBench benchmarks against), [`OnePeerExponential`] (a rotating
//! one-neighbor exponential schedule — the communication-minimal point in
//! the design space), [`VarianceAdaptive`] (an extension from the
//! paper's Observation 4: decay `k` when the measured parameter-tensor
//! variance drops below a threshold instead of on a fixed epoch clock),
//! and [`FnSchedule`] (a closure adapter, the quickest way to give a
//! custom registry strategy its own graph sequence).

mod ada;
mod one_peer;
mod variance_adaptive;

pub use ada::AdaSchedule;
pub use one_peer::OnePeerExponential;
pub use variance_adaptive::VarianceAdaptive;

use crate::error::Result;
use crate::graph::{CommGraph, GraphKind};

/// A per-epoch communication-graph policy.
///
/// Schedules may react to training feedback (e.g. the measured
/// parameter-tensor variance) via [`TopologySchedule::observe`].
pub trait TopologySchedule: Send {
    /// The graph to gossip over during `epoch` (0-based).
    fn graph_for_epoch(&self, epoch: usize) -> Result<CommGraph>;

    /// Feed back the cross-replica parameter variance (gini coefficient)
    /// measured at the end of `epoch`. Default: ignored.
    fn observe(&mut self, _epoch: usize, _gini: f64) {}

    /// Human-readable name for reports.
    fn name(&self) -> String;

    /// Total bytes each node sends over `epochs` epochs of `iters_per_epoch`
    /// gossip rounds for a `param_count`-parameter model — the communication
    /// cost side of the paper's accuracy/cost trade-off.
    fn comm_bytes_per_node(
        &self,
        epochs: usize,
        iters_per_epoch: usize,
        param_count: usize,
    ) -> Result<u64> {
        let mut total = 0u64;
        for e in 0..epochs {
            let g = self.graph_for_epoch(e)?;
            total += g.bytes_sent_per_node(param_count) * iters_per_epoch as u64;
        }
        Ok(total)
    }
}

/// A fixed communication graph for the whole run (the paper's baselines:
/// `D_ring`, `D_torus`, `D_exponential`, `D_complete`).
#[derive(Debug, Clone)]
pub struct StaticSchedule {
    kind: GraphKind,
    n: usize,
    cached: CommGraph,
}

impl StaticSchedule {
    /// Build the fixed graph once; `graph_for_epoch` clones the cache.
    pub fn new(kind: GraphKind, n: usize) -> Result<Self> {
        let cached = CommGraph::build(kind, n)?;
        Ok(StaticSchedule { kind, n, cached })
    }

    /// Number of nodes.
    pub fn n(&self) -> usize {
        self.n
    }
}

impl TopologySchedule for StaticSchedule {
    fn graph_for_epoch(&self, _epoch: usize) -> Result<CommGraph> {
        Ok(self.cached.clone())
    }

    fn name(&self) -> String {
        format!("static({})", self.kind)
    }
}

/// A closure as a schedule — the one-liner adapter for custom registry
/// strategies (`crate::coordinator::strategy`): wrap any
/// `Fn(epoch) -> CommGraph` without declaring a new type. Feedback
/// (`observe`) is ignored; implement the trait directly for schedules
/// that react to training signals.
pub struct FnSchedule<F: Fn(usize) -> Result<CommGraph> + Send> {
    label: String,
    f: F,
}

impl<F: Fn(usize) -> Result<CommGraph> + Send> FnSchedule<F> {
    /// Wrap `f` under a report label.
    pub fn new(label: impl Into<String>, f: F) -> Self {
        FnSchedule { label: label.into(), f }
    }
}

impl<F: Fn(usize) -> Result<CommGraph> + Send> TopologySchedule for FnSchedule<F> {
    fn graph_for_epoch(&self, epoch: usize) -> Result<CommGraph> {
        (self.f)(epoch)
    }

    fn name(&self) -> String {
        self.label.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn static_schedule_is_constant() {
        let s = StaticSchedule::new(GraphKind::Torus, 16).unwrap();
        let g0 = s.graph_for_epoch(0).unwrap();
        let g9 = s.graph_for_epoch(9).unwrap();
        assert_eq!(g0.dense_mixing(), g9.dense_mixing());
        assert_eq!(s.name(), "static(torus)");
    }

    #[test]
    fn fn_schedule_wraps_a_closure() {
        let s = FnSchedule::new("alternating", |epoch| {
            CommGraph::build(
                if epoch % 2 == 0 { GraphKind::Ring } else { GraphKind::Complete },
                8,
            )
        });
        assert_eq!(s.graph_for_epoch(0).unwrap().degree(), 2);
        assert_eq!(s.graph_for_epoch(1).unwrap().degree(), 7);
        assert_eq!(s.name(), "alternating");
    }

    #[test]
    fn comm_bytes_counts_degree() {
        let s = StaticSchedule::new(GraphKind::Ring, 8).unwrap();
        // degree 2 × 4 bytes × 100 params × 3 iters × 2 epochs
        assert_eq!(s.comm_bytes_per_node(2, 3, 100).unwrap(), 2 * 4 * 100 * 3 * 2);
    }
}
