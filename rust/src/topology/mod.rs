//! Topology policies: which communication graph each gossip round uses,
//! and the feedback signals that drive adaptation.
//!
//! The paper's contribution, **Ada** (§4), is a policy: start from a
//! highly connected ring lattice and decay its coordination number `k`
//! per epoch (Algorithm 1), trading connectivity for communication cost
//! exactly when the white-box analysis (§3.3) shows the cross-graph
//! variance differences have diminished.
//!
//! [`TopologyPolicy`] is the open form of that idea: a policy picks a
//! graph at **iteration** granularity (`graph_for(epoch, iter)` — so
//! one-peer-style rotating schedules can rotate within an epoch instead
//! of faking it through epochs) and receives a structured
//! [`TrainSignals`] feedback bundle each epoch — gini coefficient,
//! pooled per-replica L2 variance, consensus distance to the mean model
//! (Kong et al. 2021's control signal), train loss, latest eval metric
//! and cumulative communication spend — instead of the bare `gini: f64`
//! the old `TopologySchedule` trait carried.
//!
//! Policies are constructible **by name with a parameter table** through
//! [`registry()`] — the same `Arc`-shared extensible shape as the
//! combine-strategy registry (`crate::coordinator::strategy`) — so graph
//! adaptation plugs into spec TOML (`[topology.<name>]`), both CLIs
//! (`--topology name:k=v,…`) and [`crate::dbench::SessionPlan`] cells
//! without touching this crate.
//!
//! Built-in policies: [`StaticSchedule`] (the fixed graphs DBench
//! benchmarks against), [`AdaSchedule`] (Algorithm 1),
//! [`OnePeerExponential`] (rotating one-neighbor exponential, per-epoch
//! or per-iteration), [`RandomRegularSchedule`] (a fresh seeded random
//! d-regular expander each epoch), [`VarianceAdaptive`] (gini-triggered decay,
//! Observation 4), [`ConsensusDecay`] (consensus-distance-triggered
//! decay in the spirit of Kong et al. 2021), [`CommBudget`] (densest
//! lattice affordable under a bytes-per-node budget), [`StragglerAware`]
//! (thins the graph while the fault plane reports slow nodes, re-densifies
//! when they recover — driven by the per-iteration feedback channel), and
//! [`FnSchedule`] (a closure adapter — the quickest way to register a
//! custom graph sequence at runtime).

mod ada;
mod comm_budget;
mod consensus_decay;
mod one_peer;
mod random_regular;
mod registry;
mod straggler_aware;
mod variance_adaptive;

pub use ada::AdaSchedule;
pub use comm_budget::CommBudget;
pub use consensus_decay::ConsensusDecay;
pub use one_peer::OnePeerExponential;
pub use random_regular::RandomRegularSchedule;
pub use registry::{registry, PolicyCtor, TopologyRegistry};
pub use straggler_aware::StragglerAware;
pub use variance_adaptive::VarianceAdaptive;

use crate::error::Result;
use crate::graph::{CommGraph, GraphKind};

/// Everything a policy learns about the run before the first iteration —
/// scale, model size and loop geometry. Delivered once through
/// [`TopologyPolicy::on_run_start`]; budget-style policies need it to
/// price a graph (bytes per round = degree × 4 × `param_count`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RunInfo {
    /// Worker count (graph nodes).
    pub n_workers: usize,
    /// Flat parameter count per replica.
    pub param_count: usize,
    /// Total epochs the run will execute.
    pub epochs: usize,
    /// Gossip rounds per epoch.
    pub iters_per_epoch: usize,
}

/// The per-epoch feedback bundle handed to [`TopologyPolicy::observe`]
/// — the structured replacement for the old bare `gini: f64` channel.
///
/// Signals derived from the variance probe (`gini`, `l2_variance`) are
/// `None` on epochs where the probe captured nothing
/// (`metrics_every = 0` or a cadence that skipped the epoch);
/// `consensus_distance` is `None` for centralized runs (no mean-model
/// divergence to measure) and `test_metric` is `None` on epochs without
/// an evaluation.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TrainSignals {
    /// The 0-based epoch that just finished.
    pub epoch: usize,
    /// Mean gini coefficient of the per-replica L2 norms over the
    /// epoch's captures (the paper's reported dispersion metric).
    pub gini: Option<f64>,
    /// Mean population variance of the same pooled per-replica L2 norms
    /// (`metrics::VarianceProbe` captures them pre-averaging).
    pub l2_variance: Option<f64>,
    /// Mean L2 distance of the replicas to the mean model at epoch end —
    /// the consensus-distance signal of Kong et al. 2021. `None` unless
    /// the policy opted in via
    /// [`TopologyPolicy::wants_consensus_distance`] (it costs two
    /// O(n·P) passes per epoch, which static benchmark schedules
    /// shouldn't pay).
    pub consensus_distance: Option<f64>,
    /// Mean training loss over the epoch's iterations.
    pub train_loss: f64,
    /// The latest evaluation metric, when this epoch evaluated.
    pub test_metric: Option<f64>,
    /// Cumulative communication spend per node since this session
    /// started, in bytes — the budget side of the accuracy/cost
    /// trade-off. A checkpoint-resumed session counts from its resume
    /// point (the checkpoint format carries no byte ledger), matching
    /// the recorder's own per-leg accounting; budget-style policies
    /// therefore budget each session leg, not the concatenated run.
    pub comm_bytes_per_node: u64,
    /// `Some(iter)` when this bundle is a **per-iteration** feedback
    /// tick from the fault plane (delivered only to policies that opt
    /// in via [`TopologyPolicy::wants_iteration_signals`]); `None` on
    /// the ordinary end-of-epoch bundle.
    pub iteration: Option<usize>,
    /// Per-node straggler slowdown factors for this iteration (`1.0` =
    /// full speed, `> 1.0` = slowed by that factor, from the
    /// `FaultPlan` straggler schedule). Empty outside fault-injection
    /// runs and on epoch bundles.
    pub straggler_factor: Vec<f64>,
    /// Maximum per-edge staleness age (rounds since last delivery) over
    /// the graph's delivered edges — `None` outside the
    /// bounded-staleness path or before any delivery.
    pub max_staleness: Option<usize>,
    /// Mean per-edge staleness age over the same edges.
    pub mean_staleness: Option<f64>,
    /// Simulated wall-clock cost of the gossip exchange(s) this bundle
    /// covers, in seconds, under the fault plane's α–β + jitter +
    /// straggler model: one round for iteration bundles, the epoch's
    /// total for epoch bundles. `None` outside fault-injection runs.
    pub sim_delay_s: Option<f64>,
}

impl TrainSignals {
    /// A minimal bundle carrying only the legacy `(epoch, gini)` pair —
    /// what unit tests and simple controllers feed policies directly.
    pub fn for_epoch_gini(epoch: usize, gini: f64) -> Self {
        TrainSignals {
            epoch,
            gini: Some(gini),
            ..TrainSignals::default()
        }
    }
}

/// A communication-graph policy with iteration-level decision points
/// and a structured feedback/control channel.
///
/// The session calls [`graph_for`](TopologyPolicy::graph_for) once per
/// epoch when [`iteration_scoped`](TopologyPolicy::iteration_scoped) is
/// `false` (the default — graph construction and cloning stay off the
/// iteration path, and pre-redesign runs keep their exact floats), or
/// once per iteration when it is `true`. Feedback arrives through
/// [`observe`](TopologyPolicy::observe) after every epoch.
pub trait TopologyPolicy: Send {
    /// The graph to gossip over during iteration `iter` of `epoch`
    /// (both 0-based). Policies that only vary per epoch ignore `iter`.
    fn graph_for(&self, epoch: usize, iter: usize) -> Result<CommGraph>;

    /// The epoch-level decision point: the graph in effect at the start
    /// of `epoch` (iteration 0).
    fn graph_for_epoch(&self, epoch: usize) -> Result<CommGraph> {
        self.graph_for(epoch, 0)
    }

    /// Whether the graph may change *within* an epoch. When `false`
    /// (default) the session resolves the graph once per epoch.
    fn iteration_scoped(&self) -> bool {
        false
    }

    /// Run geometry, delivered once before the first iteration.
    fn on_run_start(&mut self, _info: &RunInfo) {}

    /// Whether this policy reads
    /// [`TrainSignals::consensus_distance`]. Measuring it costs a
    /// mean-model build plus a distance reduction — two O(n·P) passes
    /// per epoch — so the session only runs them when a policy opts in
    /// (`false` by default; the probe-derived signals are always
    /// present).
    fn wants_consensus_distance(&self) -> bool {
        false
    }

    /// Whether this policy wants the fault plane's **per-iteration**
    /// feedback ticks (straggler factors, measured staleness, simulated
    /// delay — [`TrainSignals::iteration`] is `Some`). Off by default:
    /// an iteration-rate `observe` call on every round is pure overhead
    /// for epoch-granular policies, and outside fault-injection runs no
    /// iteration bundles exist at all.
    fn wants_iteration_signals(&self) -> bool {
        false
    }

    /// End-of-epoch feedback — and, for policies that opted in via
    /// [`TopologyPolicy::wants_iteration_signals`], per-iteration fault
    /// ticks (distinguished by [`TrainSignals::iteration`]). Default:
    /// ignored.
    fn observe(&mut self, _signals: &TrainSignals) {}

    /// Human-readable name for reports.
    fn name(&self) -> String;

    /// Neighbor count of the policy's *densest* phase — the Table 2
    /// LR-scaling input (`s = batch·(k+1)/divisor`). Defaults to the
    /// degree of the first graph.
    fn k_hint(&self) -> usize {
        self.graph_for(0, 0).map(|g| g.degree()).unwrap_or(2)
    }

    /// Total bytes each node sends over `epochs` epochs of
    /// `iters_per_epoch` gossip rounds for a `param_count`-parameter
    /// model — the communication cost side of the paper's accuracy/cost
    /// trade-off. Iteration-scoped policies price every round.
    fn comm_bytes_per_node(
        &self,
        epochs: usize,
        iters_per_epoch: usize,
        param_count: usize,
    ) -> Result<u64> {
        let mut total = 0u64;
        for e in 0..epochs {
            if self.iteration_scoped() {
                for i in 0..iters_per_epoch {
                    total += self.graph_for(e, i)?.bytes_sent_per_node(param_count);
                }
            } else {
                total += self.graph_for(e, 0)?.bytes_sent_per_node(param_count)
                    * iters_per_epoch as u64;
            }
        }
        Ok(total)
    }
}

/// A fixed communication graph for the whole run (the paper's baselines:
/// `D_ring`, `D_torus`, `D_exponential`, `D_complete`).
#[derive(Debug, Clone)]
pub struct StaticSchedule {
    kind: GraphKind,
    n: usize,
    cached: CommGraph,
}

impl StaticSchedule {
    /// Build the fixed graph once; `graph_for` clones the cache.
    pub fn new(kind: GraphKind, n: usize) -> Result<Self> {
        let cached = CommGraph::build(kind, n)?;
        Ok(StaticSchedule { kind, n, cached })
    }

    /// Number of nodes.
    pub fn n(&self) -> usize {
        self.n
    }
}

impl TopologyPolicy for StaticSchedule {
    fn graph_for(&self, _epoch: usize, _iter: usize) -> Result<CommGraph> {
        Ok(self.cached.clone())
    }

    fn name(&self) -> String {
        format!("static({})", self.kind)
    }
}

/// A closure as a policy — the one-liner adapter for custom registry
/// strategies (`crate::coordinator::strategy`) and runtime-registered
/// topology entries: wrap any `Fn(epoch) -> CommGraph` without
/// declaring a new type. Feedback (`observe`) is ignored; implement
/// [`TopologyPolicy`] directly for policies that react to
/// [`TrainSignals`].
pub struct FnSchedule<F: Fn(usize) -> Result<CommGraph> + Send> {
    label: String,
    f: F,
}

impl<F: Fn(usize) -> Result<CommGraph> + Send> FnSchedule<F> {
    /// Wrap `f` under a report label.
    pub fn new(label: impl Into<String>, f: F) -> Self {
        FnSchedule { label: label.into(), f }
    }
}

impl<F: Fn(usize) -> Result<CommGraph> + Send> TopologyPolicy for FnSchedule<F> {
    fn graph_for(&self, epoch: usize, _iter: usize) -> Result<CommGraph> {
        (self.f)(epoch)
    }

    fn name(&self) -> String {
        self.label.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn static_schedule_is_constant() {
        let s = StaticSchedule::new(GraphKind::Torus, 16).unwrap();
        let g0 = s.graph_for_epoch(0).unwrap();
        let g9 = s.graph_for(9, 3).unwrap();
        assert_eq!(g0.dense_mixing(), g9.dense_mixing());
        assert_eq!(s.name(), "static(torus)");
        assert!(!s.iteration_scoped());
        assert_eq!(s.k_hint(), 4);
    }

    #[test]
    fn fn_schedule_wraps_a_closure() {
        let s = FnSchedule::new("alternating", |epoch| {
            CommGraph::build(
                if epoch % 2 == 0 { GraphKind::Ring } else { GraphKind::Complete },
                8,
            )
        });
        assert_eq!(s.graph_for_epoch(0).unwrap().degree(), 2);
        assert_eq!(s.graph_for_epoch(1).unwrap().degree(), 7);
        assert_eq!(s.name(), "alternating");
        assert_eq!(s.k_hint(), 2, "k_hint defaults to the first graph's degree");
    }

    #[test]
    fn comm_bytes_counts_degree() {
        let s = StaticSchedule::new(GraphKind::Ring, 8).unwrap();
        // degree 2 × 4 bytes × 100 params × 3 iters × 2 epochs
        assert_eq!(s.comm_bytes_per_node(2, 3, 100).unwrap(), 2 * 4 * 100 * 3 * 2);
    }

    #[test]
    fn default_signals_are_empty() {
        let s = TrainSignals::default();
        assert_eq!(s.gini, None);
        assert_eq!(s.consensus_distance, None);
        assert_eq!(s.comm_bytes_per_node, 0);
        assert_eq!(s.iteration, None, "default bundle is an epoch bundle");
        assert!(s.straggler_factor.is_empty());
        assert_eq!(s.max_staleness, None);
        assert_eq!(s.mean_staleness, None);
        assert_eq!(s.sim_delay_s, None);
        let s = TrainSignals::for_epoch_gini(3, 0.5);
        assert_eq!(s.epoch, 3);
        assert_eq!(s.gini, Some(0.5));
    }
}
