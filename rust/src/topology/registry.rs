//! The name → constructor table for topology policies — the open twin
//! of the combine-strategy registry
//! (`crate::coordinator::strategy::Registry`).
//!
//! Every constructor takes the training scale `n` plus a
//! [`ParamTable`] (the shared parameter shape behind spec TOML
//! `[topology.<name>]` sections and the CLI `--topology name:k=v,…`
//! syntax) and returns a boxed [`TopologyPolicy`]. The builtin table
//! registers the four pre-existing schedules and the two signal-driven
//! policies; [`FnSchedule`](super::FnSchedule)-backed custom entries
//! register at runtime with [`TopologyRegistry::register`] — see
//! `examples/custom_strategy.rs` for one trained end-to-end.
//!
//! | name              | parameters (defaults)                                  |
//! |-------------------|--------------------------------------------------------|
//! | `ring` / `torus` / `exponential` / `complete` / `hypercube` | — |
//! | `static`          | `graph` (= `ring`), or `k` for an Ada lattice          |
//! | `ada`             | `k0` (= n−1), `gamma_k` (= 1.0)                        |
//! | `one_peer`        | `per_iter` (= false)                                   |
//! | `random_regular`  | `d` (= 4, even), `seed` (= 0) — a fresh random d-regular expander each epoch |
//! | `var_adaptive`    | `k0` (= n−1), `step` (= 2), `threshold` (= 0.002), `patience` (= 1) |
//! | `consensus_decay` | `k0` (= n/2 — a complete lattice would zero the post-averaging signal), `step` (= 2), `threshold` (= 0.25), `patience` (= 1) |
//! | `comm_budget`     | `budget_mb` (required), `k0` (= n−1)                   |
//! | `straggler_aware` | `k0` (= n−1), `step` (= 2), `ema` (= 0.25), `threshold` (= 0.5), `patience` (= 1) |

use super::{
    AdaSchedule, CommBudget, ConsensusDecay, OnePeerExponential, StaticSchedule, StragglerAware,
    TopologyPolicy, VarianceAdaptive,
};
use crate::error::{AdaError, Result};
use crate::graph::GraphKind;
use crate::util::params::ParamTable;
use std::collections::BTreeMap;
use std::sync::Arc;

/// A registry constructor: build a policy for scale `n` from a
/// parameter table.
pub type PolicyCtor =
    Arc<dyn Fn(usize, &ParamTable) -> Result<Box<dyn TopologyPolicy>> + Send + Sync>;

/// Name → constructor table for topology policies. Starts from the
/// builtin [`registry()`] and is extensible at runtime — registering a
/// new policy requires no change to `topology/` source.
pub struct TopologyRegistry {
    entries: BTreeMap<String, PolicyCtor>,
}

impl TopologyRegistry {
    /// An empty registry (no builtins).
    pub fn empty() -> Self {
        TopologyRegistry { entries: BTreeMap::new() }
    }

    /// Register `ctor` under `name`, replacing any previous entry.
    pub fn register<F>(&mut self, name: impl Into<String>, ctor: F)
    where
        F: Fn(usize, &ParamTable) -> Result<Box<dyn TopologyPolicy>> + Send + Sync + 'static,
    {
        self.entries.insert(name.into(), Arc::new(ctor));
    }

    /// Register `alias` as another name for the existing `name`.
    pub fn alias(&mut self, alias: impl Into<String>, name: &str) -> Result<()> {
        let ctor = self.entries.get(name).cloned().ok_or_else(|| {
            AdaError::Config(format!("cannot alias unknown topology {name:?}"))
        })?;
        self.entries.insert(alias.into(), ctor);
        Ok(())
    }

    /// Whether `name` resolves.
    pub fn contains(&self, name: &str) -> bool {
        self.entries.contains_key(name)
    }

    /// All registered names, sorted.
    pub fn names(&self) -> Vec<&str> {
        self.entries.keys().map(String::as_str).collect()
    }

    /// Construct the policy registered under `name` for scale `n`.
    pub fn resolve(
        &self,
        name: &str,
        n: usize,
        params: &ParamTable,
    ) -> Result<Box<dyn TopologyPolicy>> {
        let ctor = self.entries.get(name).ok_or_else(|| {
            AdaError::Config(format!(
                "unknown topology {name:?} (registered: {})",
                self.names().join(", ")
            ))
        })?;
        ctor(n, params)
    }
}

/// Default `k0`: the densest lattice at scale `n`.
fn default_k0(n: usize) -> usize {
    n.saturating_sub(1).max(2)
}

fn static_kind(
    name: &'static str,
    kind: GraphKind,
) -> impl Fn(usize, &ParamTable) -> Result<Box<dyn TopologyPolicy>> {
    move |n, t| {
        t.expect_only(&[])
            .map_err(|e| AdaError::Config(format!("topology {name}: {e}")))?;
        Ok(Box::new(StaticSchedule::new(kind, n)?) as Box<dyn TopologyPolicy>)
    }
}

/// The builtin topology table (see the module docs for the parameter
/// reference). Callers extend the returned registry with their own
/// policies and hand it to [`crate::dbench::SessionPlan`].
pub fn registry() -> TopologyRegistry {
    let mut reg = TopologyRegistry::empty();
    reg.register("ring", static_kind("ring", GraphKind::Ring));
    reg.register("torus", static_kind("torus", GraphKind::Torus));
    reg.register("exponential", static_kind("exponential", GraphKind::Exponential));
    reg.register("complete", static_kind("complete", GraphKind::Complete));
    reg.register("hypercube", static_kind("hypercube", GraphKind::Hypercube));
    reg.register("static", |n, t| {
        t.expect_only(&["graph", "k"])?;
        if let Some(k) = t.get_usize("k")? {
            return Ok(Box::new(StaticSchedule::new(GraphKind::AdaLattice { k }, n)?)
                as Box<dyn TopologyPolicy>);
        }
        let kind = match t.get_str("graph")?.unwrap_or("ring") {
            "ring" => GraphKind::Ring,
            "torus" => GraphKind::Torus,
            "exponential" => GraphKind::Exponential,
            "complete" => GraphKind::Complete,
            "hypercube" => GraphKind::Hypercube,
            other => {
                return Err(AdaError::Config(format!(
                    "topology static: unknown graph {other:?} \
                     (ring|torus|exponential|complete|hypercube, or k = <int>)"
                )))
            }
        };
        Ok(Box::new(StaticSchedule::new(kind, n)?))
    });
    reg.register("ada", |n, t| {
        t.expect_only(&["k0", "gamma_k"])?;
        let k0 = t.usize_or("k0", default_k0(n))?;
        let gamma_k = t.f64_or("gamma_k", 1.0)?;
        Ok(Box::new(AdaSchedule::new(n, k0, gamma_k)))
    });
    reg.register("one_peer", |n, t| {
        t.expect_only(&["per_iter"])?;
        Ok(Box::new(if t.bool_or("per_iter", false)? {
            OnePeerExponential::per_iteration(n)?
        } else {
            OnePeerExponential::new(n)?
        }))
    });
    reg.register("random_regular", |n, t| {
        t.expect_only(&["d", "seed"])?;
        let d = t.usize_or("d", 4)?;
        let seed = t.usize_or("seed", 0)? as u64;
        Ok(Box::new(super::RandomRegularSchedule::new(n, d, seed)?))
    });
    reg.register("var_adaptive", |n, t| {
        t.expect_only(&["k0", "step", "threshold", "patience"])?;
        Ok(Box::new(VarianceAdaptive::new(
            n,
            t.usize_or("k0", default_k0(n))?,
            t.usize_or("step", 2)?,
            t.f64_or("threshold", 0.002)?,
            t.usize_or("patience", 1)?,
        )))
    });
    reg.register("consensus_decay", |n, t| {
        t.expect_only(&["k0", "step", "threshold", "patience"])?;
        // NOT default_k0: a complete (k = n−1) lattice equalizes the
        // replicas every round, so the post-averaging consensus
        // distance this policy keys on would be ~0 from epoch 0 and
        // the d0 reference degenerate. Default to a half-dense lattice
        // that leaves a measurable signal standing.
        Ok(Box::new(ConsensusDecay::new(
            n,
            t.usize_or("k0", (n / 2).max(2))?,
            t.usize_or("step", 2)?,
            t.f64_or("threshold", 0.25)?,
            t.usize_or("patience", 1)?,
        )))
    });
    reg.register("straggler_aware", |n, t| {
        t.expect_only(&["k0", "step", "ema", "threshold", "patience"])?;
        Ok(Box::new(StragglerAware::new(
            n,
            t.usize_or("k0", default_k0(n))?,
            t.usize_or("step", 2)?,
            t.f64_or("ema", 0.25)?,
            t.f64_or("threshold", 0.5)?,
            t.usize_or("patience", 1)?,
        )))
    });
    reg.register("comm_budget", |n, t| {
        t.expect_only(&["budget_mb", "k0"])?;
        let budget_mb = t.need_f64("budget_mb", "topology comm_budget")?;
        Ok(Box::new(CommBudget::with_budget_mb(
            n,
            t.usize_or("k0", default_k0(n))?,
            budget_mb,
        )))
    });
    reg
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtins_resolve_with_empty_params() {
        let reg = registry();
        for name in [
            "ring",
            "torus",
            "exponential",
            "complete",
            "static",
            "ada",
            "one_peer",
            "random_regular",
            "var_adaptive",
            "consensus_decay",
            "straggler_aware",
        ] {
            let p = reg
                .resolve(name, 16, &ParamTable::new())
                .unwrap_or_else(|e| panic!("builtin {name} must resolve: {e}"));
            p.graph_for(0, 0)
                .unwrap_or_else(|e| panic!("{name} must build its first graph: {e}"));
        }
        // hypercube needs a power-of-two n.
        assert!(reg.resolve("hypercube", 16, &ParamTable::new()).is_ok());
    }

    #[test]
    fn comm_budget_requires_its_budget() {
        let reg = registry();
        let err = reg
            .resolve("comm_budget", 16, &ParamTable::new())
            .unwrap_err()
            .to_string();
        assert!(err.contains("budget_mb"), "{err}");
        let t = ParamTable::parse_kv("budget_mb=5.0,k0=6").unwrap();
        let p = reg.resolve("comm_budget", 16, &t).unwrap();
        assert_eq!(p.k_hint(), 2, "budget policies hint the sparse-safe LR");
    }

    #[test]
    fn params_shape_the_policy() {
        let reg = registry();
        let t = ParamTable::parse_kv("k0=6,gamma_k=2.0").unwrap();
        let ada = reg.resolve("ada", 16, &t).unwrap();
        assert_eq!(ada.graph_for(0, 0).unwrap().degree(), 6);
        assert_eq!(ada.graph_for(2, 0).unwrap().degree(), 2);
        let t = ParamTable::parse_kv("graph=torus").unwrap();
        let torus = reg.resolve("static", 16, &t).unwrap();
        assert_eq!(torus.graph_for(0, 0).unwrap().degree(), 4);
        let t = ParamTable::parse_kv("k=6").unwrap();
        let lattice = reg.resolve("static", 16, &t).unwrap();
        assert_eq!(lattice.graph_for(5, 0).unwrap().degree(), 6);
        let t = ParamTable::parse_kv("per_iter=true").unwrap();
        assert!(reg.resolve("one_peer", 16, &t).unwrap().iteration_scoped());
    }

    #[test]
    fn unknown_names_and_params_are_loud() {
        let reg = registry();
        let err = reg
            .resolve("mystery", 8, &ParamTable::new())
            .unwrap_err()
            .to_string();
        assert!(err.contains("mystery") && err.contains("ada"), "{err}");
        let t = ParamTable::parse_kv("k0=4,tpyo=1").unwrap();
        assert!(reg.resolve("ada", 8, &t).is_err(), "typo'd params must error");
    }

    #[test]
    fn runtime_registration_and_alias() {
        let mut reg = registry();
        reg.register("always_ring", |n, _| {
            Ok(Box::new(super::super::FnSchedule::new("always_ring", move |_| {
                crate::graph::CommGraph::build(GraphKind::Ring, n)
            })))
        });
        assert!(reg.contains("always_ring"));
        let custom = reg.resolve("always_ring", 8, &ParamTable::new()).unwrap();
        assert_eq!(custom.graph_for(3, 0).unwrap().degree(), 2);
        reg.alias("ring2", "always_ring").unwrap();
        assert!(reg.contains("ring2"));
        assert!(reg.alias("x", "nope").is_err());
    }
}
