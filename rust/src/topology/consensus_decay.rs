//! Consensus-distance-triggered decay — a feedback controller in the
//! spirit of Kong et al. 2021 (*Consensus Control for Decentralized
//! Deep Learning*), built on the richer [`TrainSignals`] channel.
//!
//! Kong et al. show the mean L2 distance of the replicas to the mean
//! model (the **consensus distance**) is the quantity that predicts
//! whether decentralized training matches its centralized counterpart:
//! early in training a *large* consensus distance is harmless (even
//! beneficial), late in training it must shrink. Dense graphs buy small
//! consensus distance with communication. This policy runs that logic
//! in reverse to save bandwidth: start dense at `k0` and step the
//! lattice's coordination number down whenever the observed consensus
//! distance has already collapsed relative to its starting level —
//! i.e. the graph is denser than the replicas need.
//!
//! Concretely, with `d_0` the first observed consensus distance, the
//! policy decays `k` by `step` whenever `d_t < threshold · d_0` for
//! `patience` consecutive epochs, flooring at `k = 2` (Algorithm 1's
//! floor). Epochs pin the `k` they actually ran with, like
//! [`super::VarianceAdaptive`].

use super::{TopologyPolicy, TrainSignals};
use crate::error::Result;
use crate::graph::{CommGraph, GraphKind};
use std::collections::HashMap;
use std::sync::Mutex;

/// Consensus-distance feedback controller over the Ada lattice family.
#[derive(Debug)]
pub struct ConsensusDecay {
    n: usize,
    k0: usize,
    /// Decay k by this much per trigger.
    step: usize,
    /// Relative threshold: decay when `d_t < threshold · d_0`.
    threshold: f64,
    /// Consecutive below-threshold epochs required before decaying.
    patience: usize,
    state: Mutex<State>,
}

#[derive(Debug)]
struct State {
    k: usize,
    /// First observed consensus distance (the reference level `d_0`).
    initial_distance: Option<f64>,
    below_count: usize,
    /// k effective per epoch, recorded as observations arrive; epochs
    /// not yet observed use the current k.
    history: HashMap<usize, usize>,
    cache: HashMap<usize, CommGraph>,
}

impl ConsensusDecay {
    /// `threshold` is *relative* to the first observed consensus
    /// distance (e.g. `0.25` = decay once the replicas are 4× closer to
    /// the mean model than they started out).
    ///
    /// `k0` should leave the lattice *incomplete* (`k0 < n − 1`): the
    /// distance is measured post-averaging, and a complete lattice
    /// equalizes the replicas every round, pinning the signal (and the
    /// `d0` reference) at ~0 so no decay ever triggers. The registry
    /// defaults to `n / 2` for exactly this reason.
    pub fn new(n: usize, k0: usize, step: usize, threshold: f64, patience: usize) -> Self {
        ConsensusDecay {
            n,
            k0,
            step: step.max(1),
            threshold,
            patience: patience.max(1),
            state: Mutex::new(State {
                k: k0,
                initial_distance: None,
                below_count: 0,
                history: HashMap::new(),
                cache: HashMap::new(),
            }),
        }
    }

    /// Current coordination number.
    pub fn current_k(&self) -> usize {
        self.state.lock().expect("state poisoned").k
    }
}

impl TopologyPolicy for ConsensusDecay {
    fn graph_for(&self, epoch: usize, _iter: usize) -> Result<CommGraph> {
        let mut st = self.state.lock().expect("state poisoned");
        let k = st.history.get(&epoch).copied().unwrap_or(st.k);
        if let Some(g) = st.cache.get(&k) {
            return Ok(g.clone());
        }
        let g = CommGraph::build(GraphKind::AdaLattice { k }, self.n)?;
        st.cache.insert(k, g.clone());
        Ok(g)
    }

    fn wants_consensus_distance(&self) -> bool {
        true
    }

    fn observe(&mut self, signals: &TrainSignals) {
        let mut st = self.state.lock().expect("state poisoned");
        let current_k = st.k;
        st.history.insert(signals.epoch, current_k);
        let Some(d) = signals.consensus_distance else { return };
        let d0 = *st.initial_distance.get_or_insert(d);
        if d0 > 0.0 && d < self.threshold * d0 {
            st.below_count += 1;
            if st.below_count >= self.patience {
                st.k = st.k.saturating_sub(self.step).max(2);
                st.below_count = 0;
            }
        } else {
            st.below_count = 0;
        }
    }

    fn name(&self) -> String {
        format!(
            "consensus_decay(k0={},step={},thr={})",
            self.k0, self.step, self.threshold
        )
    }

    fn k_hint(&self) -> usize {
        self.k0.max(2)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dist(epoch: usize, d: f64) -> TrainSignals {
        TrainSignals {
            epoch,
            consensus_distance: Some(d),
            ..TrainSignals::default()
        }
    }

    #[test]
    fn first_observation_sets_the_reference_level() {
        let mut s = ConsensusDecay::new(16, 8, 2, 0.25, 1);
        s.observe(&dist(0, 2.0)); // d0 = 2.0; 2.0 ≥ 0.25·2.0 → no decay
        assert_eq!(s.current_k(), 8);
        s.observe(&dist(1, 1.0)); // 1.0 ≥ 0.5 → still no decay
        assert_eq!(s.current_k(), 8);
        s.observe(&dist(2, 0.4)); // 0.4 < 0.5 → decay
        assert_eq!(s.current_k(), 6);
    }

    #[test]
    fn patience_requires_consecutive_collapsed_epochs() {
        let mut s = ConsensusDecay::new(16, 8, 2, 0.5, 2);
        s.observe(&dist(0, 1.0)); // d0 = 1.0
        s.observe(&dist(1, 0.1));
        assert_eq!(s.current_k(), 8, "patience not yet met");
        s.observe(&dist(2, 0.9)); // consensus re-opened → reset
        s.observe(&dist(3, 0.1));
        assert_eq!(s.current_k(), 8, "spike must reset the counter");
        s.observe(&dist(4, 0.1));
        assert_eq!(s.current_k(), 6);
    }

    #[test]
    fn floors_at_k2_and_pins_history() {
        let mut s = ConsensusDecay::new(16, 4, 10, 0.9, 1);
        s.observe(&dist(0, 1.0)); // reference
        assert_eq!(s.graph_for_epoch(0).unwrap().degree(), 4);
        s.observe(&dist(1, 0.0));
        assert_eq!(s.current_k(), 2, "k never drops below 2");
        assert_eq!(s.graph_for_epoch(2).unwrap().degree(), 2);
        // Epoch 0 is pinned to the k it actually ran with.
        assert_eq!(s.graph_for_epoch(0).unwrap().degree(), 4);
    }

    #[test]
    fn missing_signal_is_ignored() {
        let mut s = ConsensusDecay::new(16, 8, 2, 0.5, 1);
        s.observe(&TrainSignals::for_epoch_gini(0, 0.0)); // gini only
        assert_eq!(s.current_k(), 8, "no consensus signal → no reference, no decay");
    }
}
