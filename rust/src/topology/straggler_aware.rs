//! Straggler-aware adaptive schedule — the fault plane's consumer of
//! the per-iteration feedback channel.
//!
//! "From Promise to Practice" (Wang et al. 2024) locates decentralized
//! SGD's practical edge exactly where links and nodes are unreliable;
//! this policy is the routing half of that argument. It keeps a
//! per-node EMA of the fault plane's straggler slowdown factors
//! (delivered every iteration via [`TrainSignals::straggler_factor`])
//! and, at epoch granularity, thins the lattice while any node's
//! smoothed excess slowness exceeds a threshold — a sparse graph bounds
//! how many peers each round must hear from, so slow nodes stall fewer
//! edges — then re-densifies once the cluster has been calm for
//! `patience` epochs, recovering Ada-style connectivity when it is
//! affordable again.

use super::{TopologyPolicy, TrainSignals};
use crate::error::Result;
use crate::graph::{CommGraph, GraphKind};
use std::collections::HashMap;
use std::sync::Mutex;

/// Feedback controller that routes around slow nodes: dense lattice
/// while the cluster is healthy, thinned by `step` after `patience`
/// slow epochs, re-grown by `step` after `patience` calm ones.
#[derive(Debug)]
pub struct StragglerAware {
    n: usize,
    k0: usize,
    /// Change k by this much per trigger (both directions).
    step: usize,
    /// EMA smoothing factor for the per-node slowness estimate.
    alpha: f64,
    /// Excess-slowness threshold: a node is "slow" while its smoothed
    /// `factor − 1` exceeds this (e.g. 0.5 ⇒ ≥ 1.5× its normal time).
    threshold: f64,
    /// Consecutive slow (resp. calm) epochs before thinning
    /// (resp. re-densifying).
    patience: usize,
    state: Mutex<State>,
}

#[derive(Debug)]
struct State {
    k: usize,
    /// Per-node EMA of excess slowness (`factor − 1`).
    slow: Vec<f64>,
    /// Consecutive epochs with at least one slow node.
    hot: usize,
    /// Consecutive epochs with none.
    cool: usize,
    /// k effective per epoch, pinned as epoch bundles arrive (same
    /// history discipline as `VarianceAdaptive`).
    history: HashMap<usize, usize>,
    cache: HashMap<usize, CommGraph>,
}

impl StragglerAware {
    /// `threshold` is on the smoothed excess slowdown `factor − 1`;
    /// `alpha` is the EMA weight of each new iteration sample.
    pub fn new(
        n: usize,
        k0: usize,
        step: usize,
        alpha: f64,
        threshold: f64,
        patience: usize,
    ) -> Self {
        StragglerAware {
            n,
            k0,
            step: step.max(1),
            alpha: alpha.clamp(0.0, 1.0),
            threshold,
            patience: patience.max(1),
            state: Mutex::new(State {
                k: k0,
                slow: vec![0.0; n],
                hot: 0,
                cool: 0,
                history: HashMap::new(),
                cache: HashMap::new(),
            }),
        }
    }

    /// Current coordination number.
    pub fn current_k(&self) -> usize {
        self.state.lock().expect("state poisoned").k
    }

    /// Current smoothed excess slowness per node (tests/diagnostics).
    pub fn slowness(&self) -> Vec<f64> {
        self.state.lock().expect("state poisoned").slow.clone()
    }
}

impl TopologyPolicy for StragglerAware {
    fn graph_for(&self, epoch: usize, _iter: usize) -> Result<CommGraph> {
        let mut st = self.state.lock().expect("state poisoned");
        let k = st.history.get(&epoch).copied().unwrap_or(st.k);
        if let Some(g) = st.cache.get(&k) {
            return Ok(g.clone());
        }
        let g = CommGraph::build(GraphKind::AdaLattice { k }, self.n)?;
        st.cache.insert(k, g.clone());
        Ok(g)
    }

    fn wants_iteration_signals(&self) -> bool {
        true
    }

    fn observe(&mut self, signals: &TrainSignals) {
        let mut st = self.state.lock().expect("state poisoned");
        if signals.iteration.is_some() {
            // Iteration tick: fold this round's straggler factors into
            // the per-node EMA and return — adaptation is epoch-paced.
            for (s, &f) in st.slow.iter_mut().zip(&signals.straggler_factor) {
                *s += self.alpha * ((f - 1.0).max(0.0) - *s);
            }
            return;
        }
        let current_k = st.k;
        st.history.insert(signals.epoch, current_k);
        let any_slow = st.slow.iter().any(|&s| s > self.threshold);
        if any_slow {
            st.hot += 1;
            st.cool = 0;
            if st.hot >= self.patience {
                st.k = st.k.saturating_sub(self.step).max(2);
                st.hot = 0;
            }
        } else {
            st.cool += 1;
            st.hot = 0;
            if st.cool >= self.patience {
                st.k = (st.k + self.step).min(self.k0);
                st.cool = 0;
            }
        }
    }

    fn name(&self) -> String {
        format!(
            "straggler_aware(k0={},step={},thr={})",
            self.k0, self.step, self.threshold
        )
    }

    fn k_hint(&self) -> usize {
        self.k0.max(2)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn iter_tick(epoch: usize, iteration: usize, factors: Vec<f64>) -> TrainSignals {
        TrainSignals {
            epoch,
            iteration: Some(iteration),
            straggler_factor: factors,
            ..TrainSignals::default()
        }
    }

    fn epoch_tick(epoch: usize) -> TrainSignals {
        TrainSignals { epoch, ..TrainSignals::default() }
    }

    #[test]
    fn opts_into_iteration_signals() {
        let s = StragglerAware::new(8, 6, 2, 0.5, 0.5, 1);
        assert!(s.wants_iteration_signals());
        // The default for every other builtin stays off.
        assert!(!super::super::StaticSchedule::new(GraphKind::Ring, 8)
            .unwrap()
            .wants_iteration_signals());
    }

    #[test]
    fn stays_dense_while_cluster_is_calm() {
        let mut s = StragglerAware::new(8, 6, 2, 0.5, 0.5, 2);
        for e in 0..4 {
            s.observe(&iter_tick(e, 0, vec![1.0; 8]));
            s.observe(&epoch_tick(e));
        }
        assert_eq!(s.current_k(), 6, "no stragglers, no change");
    }

    #[test]
    fn thins_after_patience_slow_epochs_and_regrows_after_calm() {
        let mut s = StragglerAware::new(8, 6, 2, 1.0, 0.5, 2);
        let mut slowed = vec![1.0; 8];
        slowed[3] = 4.0; // node 3 runs at 4× its normal time
        s.observe(&iter_tick(0, 0, slowed.clone()));
        s.observe(&epoch_tick(0));
        assert_eq!(s.current_k(), 6, "patience not yet met");
        s.observe(&iter_tick(1, 0, slowed));
        s.observe(&epoch_tick(1));
        assert_eq!(s.current_k(), 4, "thinned by step after patience");
        // Recovery: with alpha=1 the EMA forgets instantly.
        for e in 2..4 {
            s.observe(&iter_tick(e, 0, vec![1.0; 8]));
            s.observe(&epoch_tick(e));
        }
        assert_eq!(s.current_k(), 6, "re-densified after calm patience");
    }

    #[test]
    fn regrowth_is_capped_at_k0_and_thinning_floors_at_2() {
        let mut s = StragglerAware::new(8, 4, 10, 1.0, 0.5, 1);
        let mut slowed = vec![1.0; 8];
        slowed[0] = 9.0;
        s.observe(&iter_tick(0, 0, slowed));
        s.observe(&epoch_tick(0));
        assert_eq!(s.current_k(), 2, "k never drops below 2 (Algorithm 1)");
        for e in 1..4 {
            s.observe(&iter_tick(e, 0, vec![1.0; 8]));
            s.observe(&epoch_tick(e));
        }
        assert_eq!(s.current_k(), 4, "k never grows past k0");
    }

    #[test]
    fn ema_smooths_single_iteration_spikes() {
        // One slow iteration out of many, with a small alpha, must not
        // push the smoothed estimate over the threshold.
        let mut s = StragglerAware::new(4, 6, 2, 0.1, 0.5, 1);
        s.observe(&iter_tick(0, 0, vec![1.0, 5.0, 1.0, 1.0]));
        for i in 1..20 {
            s.observe(&iter_tick(0, i, vec![1.0; 4]));
        }
        s.observe(&epoch_tick(0));
        assert_eq!(s.current_k(), 6, "one spike must not thin the graph");
        assert!(s.slowness()[1] < 0.5);
    }

    #[test]
    fn graph_for_observed_epoch_uses_recorded_k() {
        let mut s = StragglerAware::new(16, 8, 4, 1.0, 0.5, 1);
        assert_eq!(s.graph_for_epoch(0).unwrap().degree(), 8);
        let mut slowed = vec![1.0; 16];
        slowed[7] = 3.0;
        s.observe(&iter_tick(0, 0, slowed));
        s.observe(&epoch_tick(0)); // k → 4
        assert_eq!(s.graph_for_epoch(1).unwrap().degree(), 4);
        // Epoch 0 is pinned to the k it actually ran with.
        assert_eq!(s.graph_for_epoch(0).unwrap().degree(), 8);
    }
}
