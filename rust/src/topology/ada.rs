//! Ada's adaptive ring-lattice schedule — Algorithm 1 of the paper.
//!
//! ```text
//! for epoch = 1..nepochs:
//!     k ← max(k0 − int(γk · epoch), 2)
//!     graph[i][i]            = 1/(k+1)
//!     graph[i][(i+j) mod n]  = 1/(k+1)   for j ∈ [−k/2, k/2] \ {0}
//!     decentralized_training(epoch, graph)
//! ```
//!
//! The run starts near-complete (`k0` large, e.g. `n−1`) and decays to a
//! sparse lattice, keeping `k ≥ 2`. Table 4 of the paper uses
//! `(k0, γk) = (10, 0.02)` at 96 GPUs and `(112, 1)` at 1008 GPUs.

use super::TopologyPolicy;
use crate::error::Result;
use crate::graph::{CommGraph, GraphKind};
use std::collections::HashMap;
use std::sync::Mutex;

/// Algorithm-1 schedule: `k(epoch) = max(k0 − int(γk · epoch), 2)`.
#[derive(Debug)]
pub struct AdaSchedule {
    n: usize,
    k0: usize,
    gamma_k: f64,
    /// Graphs cached by k — k repeats for many consecutive epochs when
    /// γk < 1, and rebuilding the lattice each epoch is wasted work.
    cache: Mutex<HashMap<usize, CommGraph>>,
}

impl AdaSchedule {
    /// Create a schedule over `n` nodes starting at coordination number
    /// `k0` and decaying at `gamma_k` per epoch.
    pub fn new(n: usize, k0: usize, gamma_k: f64) -> Self {
        AdaSchedule {
            n,
            k0,
            gamma_k,
            cache: Mutex::new(HashMap::new()),
        }
    }

    /// The coordination number used at `epoch` (Algorithm 1, line 2).
    pub fn k_for_epoch(&self, epoch: usize) -> usize {
        let decayed = self.k0 as i64 - (self.gamma_k * epoch as f64) as i64;
        decayed.max(2) as usize
    }

    /// Number of nodes.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Initial coordination number.
    pub fn k0(&self) -> usize {
        self.k0
    }

    /// Per-epoch decay rate of `k`.
    pub fn gamma_k(&self) -> f64 {
        self.gamma_k
    }

    /// Epoch at which the schedule reaches its floor `k = 2`.
    pub fn epochs_to_floor(&self) -> usize {
        if self.gamma_k <= 0.0 || self.k0 <= 2 {
            return 0;
        }
        ((self.k0 - 2) as f64 / self.gamma_k).ceil() as usize
    }
}

impl TopologyPolicy for AdaSchedule {
    fn graph_for(&self, epoch: usize, _iter: usize) -> Result<CommGraph> {
        let k = self.k_for_epoch(epoch);
        let mut cache = self.cache.lock().expect("ada cache poisoned");
        if let Some(g) = cache.get(&k) {
            return Ok(g.clone());
        }
        let g = CommGraph::build(GraphKind::AdaLattice { k }, self.n)?;
        cache.insert(k, g.clone());
        Ok(g)
    }

    fn name(&self) -> String {
        format!("ada(k0={},γk={})", self.k0, self.gamma_k)
    }

    fn k_hint(&self) -> usize {
        // Algorithm 1 starts at its densest phase; k0 sets the safe LR.
        self.k0.max(2)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn k_decays_linearly_with_floor_two() {
        // Matches Algorithm 1 line 2: k = max(k0 − int(γk·epoch), 2).
        let s = AdaSchedule::new(16, 10, 1.0);
        assert_eq!(s.k_for_epoch(0), 10);
        assert_eq!(s.k_for_epoch(3), 7);
        assert_eq!(s.k_for_epoch(8), 2);
        assert_eq!(s.k_for_epoch(100), 2, "floor at k = 2");
    }

    #[test]
    fn fractional_gamma_uses_int_truncation() {
        // int(0.02 · epoch): k stays at k0 for the first 49 epochs.
        let s = AdaSchedule::new(96, 10, 0.02);
        assert_eq!(s.k_for_epoch(0), 10);
        assert_eq!(s.k_for_epoch(49), 10);
        assert_eq!(s.k_for_epoch(50), 9);
        assert_eq!(s.k_for_epoch(399), 3);
        assert_eq!(s.k_for_epoch(400), 2);
    }

    #[test]
    fn k_is_monotone_nonincreasing() {
        let s = AdaSchedule::new(32, 31, 0.7);
        let mut prev = usize::MAX;
        for e in 0..120 {
            let k = s.k_for_epoch(e);
            assert!(k <= prev, "k must not increase: epoch {e}");
            assert!(k >= 2, "k must stay ≥ 2: epoch {e}");
            prev = k;
        }
    }

    #[test]
    fn graph_degree_tracks_k() {
        // Fig. 6: 9-node lattice evolving from complete (k=8) toward ring.
        let s = AdaSchedule::new(9, 8, 2.0);
        assert_eq!(s.graph_for_epoch(0).unwrap().degree(), 8); // complete
        assert_eq!(s.graph_for_epoch(1).unwrap().degree(), 6);
        assert_eq!(s.graph_for_epoch(2).unwrap().degree(), 4);
        assert_eq!(s.graph_for_epoch(3).unwrap().degree(), 2); // k=2 ⇒ ring
    }

    #[test]
    fn table4_configurations_build() {
        // (k0, γk) = (10, 0.02) @ 96 and (112, 1) @ 1008.
        let s96 = AdaSchedule::new(96, 10, 0.02);
        s96.graph_for_epoch(0).unwrap().validate().unwrap();
        assert_eq!(s96.epochs_to_floor(), 400);

        let s1008 = AdaSchedule::new(1008, 112, 1.0);
        let g0 = s1008.graph_for_epoch(0).unwrap();
        assert_eq!(g0.degree(), 112);
        let g_late = s1008.graph_for_epoch(110).unwrap();
        assert_eq!(g_late.degree(), 2);
        assert_eq!(s1008.epochs_to_floor(), 110);
    }

    #[test]
    fn comm_cost_decreases_across_epochs() {
        // The point of Ada: late epochs are cheaper than early ones.
        let s = AdaSchedule::new(32, 20, 1.0);
        let early = s.graph_for_epoch(0).unwrap().bytes_sent_per_node(1000);
        let late = s.graph_for_epoch(30).unwrap().bytes_sent_per_node(1000);
        assert!(late < early / 5, "late {late} vs early {early}");
    }

    #[test]
    fn cache_returns_identical_graphs() {
        let s = AdaSchedule::new(16, 10, 0.1);
        let a = s.graph_for_epoch(0).unwrap();
        let b = s.graph_for_epoch(5).unwrap(); // same k
        assert_eq!(a.dense_mixing(), b.dense_mixing());
    }
}
