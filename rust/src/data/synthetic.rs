//! Deterministic synthetic datasets standing in for CIFAR10 / ImageNet /
//! WikiText2 (DESIGN.md §2: substitutions).

use super::{Batch, Dataset};
use crate::util::rng::Rng;

/// Gaussian class-cluster classification data: `num_classes` means on a
/// scaled hypersphere plus isotropic noise. Learnable by a linear model
/// at high `separation`, genuinely hard at low `separation` — which lets
/// the benchmarks place the task difficulty where scale/graph effects
/// are visible.
#[derive(Debug, Clone)]
pub struct SyntheticClassification {
    features: Vec<f32>,
    labels: Vec<u32>,
    dim: usize,
    num_classes: usize,
}

impl SyntheticClassification {
    /// Generate `n` examples of width `dim` over `num_classes` classes.
    /// `separation` is the class-mean radius in units of the noise σ.
    pub fn generate(n: usize, dim: usize, num_classes: usize, separation: f32, seed: u64) -> Self {
        assert!(num_classes >= 2 && dim >= 1 && n >= num_classes);
        let mut rng = Rng::seed_from_u64(seed);
        // Random unit-ish class means, scaled by `separation`.
        let mut means = vec![0.0f32; num_classes * dim];
        for c in 0..num_classes {
            let row = &mut means[c * dim..(c + 1) * dim];
            for v in row.iter_mut() {
                *v = rng.normal() as f32;
            }
            let norm = row.iter().map(|v| v * v).sum::<f32>().sqrt().max(1e-6);
            for v in row.iter_mut() {
                *v *= separation / norm;
            }
        }
        let mut features = Vec::with_capacity(n * dim);
        let mut labels = Vec::with_capacity(n);
        for i in 0..n {
            let c = i % num_classes; // balanced classes
            labels.push(c as u32);
            for d in 0..dim {
                features.push(means[c * dim + d] + rng.normal() as f32);
            }
        }
        SyntheticClassification {
            features,
            labels,
            dim,
            num_classes,
        }
    }

    /// Number of classes.
    pub fn num_classes(&self) -> usize {
        self.num_classes
    }
}

impl Dataset for SyntheticClassification {
    fn len(&self) -> usize {
        self.labels.len()
    }

    fn x_dim(&self) -> usize {
        self.dim
    }

    fn y_dim(&self) -> usize {
        1
    }

    fn labels(&self) -> Option<&[u32]> {
        Some(&self.labels)
    }

    fn batch(&self, indices: &[usize]) -> Batch {
        let mut x = Vec::with_capacity(indices.len() * self.dim);
        let mut y = Vec::with_capacity(indices.len());
        for &i in indices {
            x.extend_from_slice(&self.features[i * self.dim..(i + 1) * self.dim]);
            y.push(self.labels[i] as i32);
        }
        Batch {
            x,
            y,
            batch_size: indices.len(),
            x_dim: self.dim,
            y_dim: 1,
        }
    }
}

/// Synthetic language-modeling data: sequences sampled from a seeded
/// first-order Markov chain over `vocab` tokens with a sparse, peaked
/// transition structure — so there is real next-token signal for an
/// LSTM/transformer to learn (unlike uniform noise), and perplexity has
/// a meaningful floor.
#[derive(Debug, Clone)]
pub struct SyntheticLm {
    /// `n × (seq_len + 1)` token matrix; a training example is
    /// `x = row[..seq_len]`, `y = row[1..]`.
    tokens: Vec<u32>,
    seq_len: usize,
    vocab: usize,
    n: usize,
}

impl SyntheticLm {
    /// Generate `n` sequences of `seq_len` (+1 for targets) tokens over
    /// `vocab` symbols. `branching` is how many successors each token
    /// favors (smaller ⇒ lower achievable perplexity).
    pub fn generate(n: usize, seq_len: usize, vocab: usize, branching: usize, seed: u64) -> Self {
        assert!(vocab >= 2 && seq_len >= 2 && branching >= 1);
        let mut rng = Rng::seed_from_u64(seed);
        // Each token's favored successors (deterministic from seed).
        let succ: Vec<Vec<u32>> = (0..vocab)
            .map(|_| {
                (0..branching)
                    .map(|_| rng.below(vocab) as u32)
                    .collect()
            })
            .collect();
        let row_len = seq_len + 1;
        let mut tokens = Vec::with_capacity(n * row_len);
        for _ in 0..n {
            let mut t = rng.below(vocab) as u32;
            tokens.push(t);
            for _ in 0..seq_len {
                // 90% follow the chain, 10% jump uniformly.
                t = if rng.bool(0.9) {
                    let s = &succ[t as usize];
                    s[rng.below(s.len())]
                } else {
                    rng.below(vocab) as u32
                };
                tokens.push(t);
            }
        }
        SyntheticLm {
            tokens,
            seq_len,
            vocab,
            n,
        }
    }

    /// Vocabulary size.
    pub fn vocab(&self) -> usize {
        self.vocab
    }

    /// Sequence length of a training example.
    pub fn seq_len(&self) -> usize {
        self.seq_len
    }
}

impl Dataset for SyntheticLm {
    fn len(&self) -> usize {
        self.n
    }

    fn x_dim(&self) -> usize {
        self.seq_len
    }

    fn y_dim(&self) -> usize {
        self.seq_len
    }

    fn labels(&self) -> Option<&[u32]> {
        None
    }

    fn batch(&self, indices: &[usize]) -> Batch {
        let row_len = self.seq_len + 1;
        let mut x = Vec::with_capacity(indices.len() * self.seq_len);
        let mut y = Vec::with_capacity(indices.len() * self.seq_len);
        for &i in indices {
            let row = &self.tokens[i * row_len..(i + 1) * row_len];
            x.extend(row[..self.seq_len].iter().map(|&t| t as f32));
            y.extend(row[1..].iter().map(|&t| t as i32));
        }
        Batch {
            x,
            y,
            batch_size: indices.len(),
            x_dim: self.seq_len,
            y_dim: self.seq_len,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classification_is_deterministic() {
        let a = SyntheticClassification::generate(100, 8, 4, 3.0, 7);
        let b = SyntheticClassification::generate(100, 8, 4, 3.0, 7);
        assert_eq!(a.features, b.features);
        assert_eq!(a.labels, b.labels);
        let c = SyntheticClassification::generate(100, 8, 4, 3.0, 8);
        assert_ne!(a.features, c.features, "different seed differs");
    }

    #[test]
    fn classes_are_balanced() {
        let d = SyntheticClassification::generate(120, 4, 10, 2.0, 1);
        let mut counts = vec![0usize; 10];
        for &l in d.labels().unwrap() {
            counts[l as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c == 12));
    }

    #[test]
    fn classification_batch_layout() {
        let d = SyntheticClassification::generate(10, 3, 2, 2.0, 0);
        let b = d.batch(&[0, 5]);
        assert_eq!(b.batch_size, 2);
        assert_eq!(b.x.len(), 6);
        assert_eq!(b.y.len(), 2);
        assert_eq!(b.y[0], 0);
        assert_eq!(b.y[1], 1); // 5 % 2
    }

    #[test]
    fn separation_separates() {
        // With huge separation a nearest-class-mean rule is near-perfect;
        // sanity-check that class means differ between classes.
        let d = SyntheticClassification::generate(200, 16, 2, 50.0, 3);
        let mean_of = |cls: u32| -> Vec<f32> {
            let idx: Vec<usize> = (0..d.len()).filter(|&i| d.labels[i] == cls).collect();
            let mut m = vec![0.0f32; 16];
            for &i in &idx {
                for k in 0..16 {
                    m[k] += d.features[i * 16 + k];
                }
            }
            m.iter().map(|v| v / idx.len() as f32).collect()
        };
        let m0 = mean_of(0);
        let m1 = mean_of(1);
        let dist: f32 = m0
            .iter()
            .zip(&m1)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f32>()
            .sqrt();
        assert!(dist > 10.0, "class means must be far apart, got {dist}");
    }

    #[test]
    fn lm_batch_shifts_targets() {
        let d = SyntheticLm::generate(4, 8, 32, 2, 5);
        let b = d.batch(&[2]);
        assert_eq!(b.x.len(), 8);
        assert_eq!(b.y.len(), 8);
        // y[t] must equal x[t+1] (token shift).
        for t in 0..7 {
            assert_eq!(b.x[t + 1] as i32, b.y[t]);
        }
    }

    #[test]
    fn lm_tokens_in_vocab() {
        let d = SyntheticLm::generate(16, 12, 50, 3, 9);
        assert!(d.tokens.iter().all(|&t| (t as usize) < 50));
        assert_eq!(d.len(), 16);
        assert_eq!(d.x_dim(), 12);
    }

    #[test]
    fn lm_has_markov_signal() {
        // The chain is peaked: the empirical next-token distribution given
        // a token should be far from uniform.
        let d = SyntheticLm::generate(200, 32, 16, 2, 11);
        let mut counts = vec![vec![0u32; 16]; 16];
        for row in d.tokens.chunks(33) {
            for w in row.windows(2) {
                counts[w[0] as usize][w[1] as usize] += 1;
            }
        }
        // For tokens with enough observations, top-2 successors should
        // carry well over the uniform 2/16 share.
        let mut checked = 0;
        for c in &counts {
            let total: u32 = c.iter().sum();
            if total < 50 {
                continue;
            }
            let mut sorted = c.clone();
            sorted.sort_unstable_by(|a, b| b.cmp(a));
            let top2 = (sorted[0] + sorted[1]) as f64 / total as f64;
            assert!(top2 > 0.5, "top-2 successor mass {top2} too uniform");
            checked += 1;
        }
        assert!(checked > 4, "not enough tokens observed");
    }
}
