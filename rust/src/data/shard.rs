//! Sharding training data across workers.
//!
//! Decentralized SGD's sensitivity to the communication graph is driven
//! by *shard heterogeneity*: with perfectly iid shards all replicas see
//! statistically identical gradients and even a ring stays close to the
//! complete graph. DBench therefore supports a label-skew strategy
//! (Dirichlet over class proportions, the standard non-iid benchmark
//! protocol) alongside iid round-robin.

use crate::error::{AdaError, Result};
use crate::util::rng::Rng;

/// How training indices are distributed across workers.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ShardStrategy {
    /// Shuffle once, deal round-robin: statistically identical shards.
    Iid,
    /// Dirichlet(α) label skew: each class's examples are split across
    /// workers with Dirichlet-distributed proportions. Small α ⇒ each
    /// worker sees few classes (highly non-iid); α → ∞ ⇒ iid.
    LabelSkew {
        /// Dirichlet concentration.
        alpha: f64,
    },
    /// Contiguous blocks (for sequence data, preserves locality).
    Contiguous,
}

/// Partition `indices` (0..len) into `n_workers` shards.
///
/// `labels` is required for [`ShardStrategy::LabelSkew`]. Every index is
/// assigned to exactly one worker; shards are non-empty for sane inputs
/// (`len ≥ n_workers`).
pub fn shard_indices(
    len: usize,
    labels: Option<&[u32]>,
    n_workers: usize,
    strategy: ShardStrategy,
    seed: u64,
) -> Result<Vec<Vec<usize>>> {
    if n_workers == 0 {
        return Err(AdaError::Data("n_workers must be positive".into()));
    }
    if len < n_workers {
        return Err(AdaError::Data(format!(
            "cannot shard {len} examples across {n_workers} workers"
        )));
    }
    let mut rng = Rng::seed_from_u64(seed ^ 0xA5A5_5A5A_DEAD_BEEF);
    match strategy {
        ShardStrategy::Iid => {
            let mut order: Vec<usize> = (0..len).collect();
            rng.shuffle(&mut order);
            let mut shards = vec![Vec::with_capacity(len / n_workers + 1); n_workers];
            for (i, idx) in order.into_iter().enumerate() {
                shards[i % n_workers].push(idx);
            }
            Ok(shards)
        }
        ShardStrategy::Contiguous => {
            let mut shards = Vec::with_capacity(n_workers);
            let base = len / n_workers;
            let extra = len % n_workers;
            let mut start = 0;
            for w in 0..n_workers {
                let size = base + usize::from(w < extra);
                shards.push((start..start + size).collect());
                start += size;
            }
            Ok(shards)
        }
        ShardStrategy::LabelSkew { alpha } => {
            let labels = labels.ok_or_else(|| {
                AdaError::Data("label-skew sharding requires labels".into())
            })?;
            if labels.len() != len {
                return Err(AdaError::Data(format!(
                    "labels length {} ≠ dataset length {len}",
                    labels.len()
                )));
            }
            if alpha <= 0.0 {
                return Err(AdaError::Data("Dirichlet alpha must be > 0".into()));
            }
            label_skew(labels, n_workers, alpha, &mut rng)
        }
    }
}

fn label_skew(
    labels: &[u32],
    n_workers: usize,
    alpha: f64,
    rng: &mut Rng,
) -> Result<Vec<Vec<usize>>> {
    let num_classes = labels.iter().copied().max().unwrap_or(0) as usize + 1;
    // Group indices by class, shuffled within class.
    let mut by_class: Vec<Vec<usize>> = vec![Vec::new(); num_classes];
    for (i, &l) in labels.iter().enumerate() {
        by_class[l as usize].push(i);
    }
    for c in by_class.iter_mut() {
        rng.shuffle(c);
    }
    let mut shards: Vec<Vec<usize>> = vec![Vec::new(); n_workers];
    for class in by_class {
        if class.is_empty() {
            continue;
        }
        // Dirichlet proportions via normalized Gammas.
        let props = rng.dirichlet(alpha, n_workers);
        // Convert to cumulative cut points over the class's examples.
        let m = class.len();
        let mut cum = 0.0;
        let mut start = 0;
        for (w, &p) in props.iter().enumerate() {
            cum += p;
            let end = if w == n_workers - 1 {
                m
            } else {
                (cum * m as f64).round() as usize
            }
            .min(m);
            shards[w].extend_from_slice(&class[start..end.max(start)]);
            start = end.max(start);
        }
    }
    // Rebalance: guarantee no empty shard by stealing from the largest.
    for w in 0..n_workers {
        if shards[w].is_empty() {
            let donor = (0..n_workers)
                .max_by_key(|&i| shards[i].len())
                .expect("nonempty worker set");
            let moved = shards[donor].pop().ok_or_else(|| {
                AdaError::Data("cannot rebalance empty shards".into())
            })?;
            shards[w].push(moved);
        }
    }
    Ok(shards)
}

/// Shard heterogeneity score in [0, 1]: mean total-variation distance
/// between each shard's label distribution and the global one. 0 = iid.
pub fn heterogeneity(shards: &[Vec<usize>], labels: &[u32]) -> f64 {
    let num_classes = labels.iter().copied().max().unwrap_or(0) as usize + 1;
    let mut global = vec![0.0f64; num_classes];
    for &l in labels {
        global[l as usize] += 1.0;
    }
    let n = labels.len() as f64;
    for g in global.iter_mut() {
        *g /= n;
    }
    let mut tv_sum = 0.0;
    for shard in shards {
        if shard.is_empty() {
            continue;
        }
        let mut local = vec![0.0f64; num_classes];
        for &i in shard {
            local[labels[i] as usize] += 1.0;
        }
        let m = shard.len() as f64;
        let tv: f64 = local
            .iter()
            .zip(&global)
            .map(|(l, g)| (l / m - g).abs())
            .sum::<f64>()
            / 2.0;
        tv_sum += tv;
    }
    tv_sum / shards.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn labels_balanced(n: usize, classes: u32) -> Vec<u32> {
        (0..n).map(|i| (i as u32) % classes).collect()
    }

    fn assert_partition(shards: &[Vec<usize>], len: usize) {
        let mut all: Vec<usize> = shards.iter().flatten().copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..len).collect::<Vec<_>>(), "must partition exactly");
    }

    #[test]
    fn iid_partitions_evenly() {
        let shards = shard_indices(100, None, 8, ShardStrategy::Iid, 1).unwrap();
        assert_partition(&shards, 100);
        for s in &shards {
            assert!(s.len() == 12 || s.len() == 13);
        }
    }

    #[test]
    fn contiguous_blocks() {
        let shards = shard_indices(10, None, 3, ShardStrategy::Contiguous, 0).unwrap();
        assert_eq!(shards[0], vec![0, 1, 2, 3]);
        assert_eq!(shards[1], vec![4, 5, 6]);
        assert_eq!(shards[2], vec![7, 8, 9]);
    }

    #[test]
    fn label_skew_partitions_and_is_nonempty() {
        let labels = labels_balanced(400, 10);
        let shards =
            shard_indices(400, Some(&labels), 16, ShardStrategy::LabelSkew { alpha: 0.1 }, 3)
                .unwrap();
        assert_partition(&shards, 400);
        assert!(shards.iter().all(|s| !s.is_empty()));
    }

    #[test]
    fn small_alpha_is_more_heterogeneous() {
        let labels = labels_balanced(2000, 10);
        let skewed =
            shard_indices(2000, Some(&labels), 8, ShardStrategy::LabelSkew { alpha: 0.05 }, 9)
                .unwrap();
        let mild =
            shard_indices(2000, Some(&labels), 8, ShardStrategy::LabelSkew { alpha: 100.0 }, 9)
                .unwrap();
        let iid = shard_indices(2000, Some(&labels), 8, ShardStrategy::Iid, 9).unwrap();
        let h_skew = heterogeneity(&skewed, &labels);
        let h_mild = heterogeneity(&mild, &labels);
        let h_iid = heterogeneity(&iid, &labels);
        assert!(
            h_skew > 5.0 * h_mild && h_skew > 5.0 * h_iid,
            "small alpha must dominate: {h_skew} vs mild {h_mild} / iid {h_iid}"
        );
        assert!(h_skew > 0.3, "alpha=0.05 should be strongly non-iid: {h_skew}");
        assert!(h_mild < 0.1, "alpha=100 should be near-iid: {h_mild}");
    }

    #[test]
    fn deterministic_under_seed() {
        let labels = labels_balanced(300, 5);
        let a = shard_indices(300, Some(&labels), 4, ShardStrategy::LabelSkew { alpha: 0.5 }, 7)
            .unwrap();
        let b = shard_indices(300, Some(&labels), 4, ShardStrategy::LabelSkew { alpha: 0.5 }, 7)
            .unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn rejects_bad_inputs() {
        assert!(shard_indices(10, None, 0, ShardStrategy::Iid, 0).is_err());
        assert!(shard_indices(3, None, 8, ShardStrategy::Iid, 0).is_err());
        assert!(shard_indices(10, None, 2, ShardStrategy::LabelSkew { alpha: 0.5 }, 0).is_err());
        let labels = labels_balanced(10, 2);
        assert!(
            shard_indices(10, Some(&labels), 2, ShardStrategy::LabelSkew { alpha: -1.0 }, 0)
                .is_err()
        );
    }
}
