//! Datasets and sharding for the simulated cluster.
//!
//! The paper trains on CIFAR10, ImageNet-1K and WikiText2 on Summit; we
//! substitute deterministic synthetic equivalents (see DESIGN.md §2) that
//! preserve what matters for decentralized-SGD behaviour: a learnable
//! signal, controllable class structure, and **controllable per-worker
//! heterogeneity** (the non-iid-ness of shards is what makes sparse
//! gossip graphs diverge from the complete graph at scale).

mod shard;
mod synthetic;

pub use shard::{heterogeneity, shard_indices, ShardStrategy};
pub use synthetic::{SyntheticClassification, SyntheticLm};

/// One minibatch in the model-agnostic layout the runtime feeds to HLO
/// executables: `x` is `batch × x_dim` f32 (pixels for classification,
/// token ids for LM — the model casts), `y` is `batch × y_dim` i32
/// (class label, or next-token targets for LM).
#[derive(Debug, Clone, PartialEq)]
pub struct Batch {
    /// Flat row-major features, `len = batch_size * x_dim`.
    pub x: Vec<f32>,
    /// Flat targets, `len = batch_size * y_dim`.
    pub y: Vec<i32>,
    /// Rows in this batch.
    pub batch_size: usize,
    /// Feature width.
    pub x_dim: usize,
    /// Target width (1 for classification, seq_len for LM).
    pub y_dim: usize,
}

/// A dataset that can materialize arbitrary index sets as batches.
pub trait Dataset: Send + Sync {
    /// Number of examples.
    fn len(&self) -> usize;
    /// True when empty.
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
    /// Feature width of a single example.
    fn x_dim(&self) -> usize;
    /// Target width of a single example.
    fn y_dim(&self) -> usize;
    /// Class labels if this is a labeled classification set (used by
    /// label-skew sharding); `None` for LM data.
    fn labels(&self) -> Option<&[u32]>;
    /// Materialize the examples at `indices` into a batch.
    fn batch(&self, indices: &[usize]) -> Batch;
}

/// Deterministic per-worker epoch loader: owns a shard of dataset
/// indices, reshuffles them each epoch (seeded by `worker`, `epoch`), and
/// yields fixed-size batches. Drops the trailing partial batch, matching
/// the paper's equal-sized-batch setup (§2.1).
#[derive(Debug, Clone)]
pub struct ShardLoader {
    indices: Vec<usize>,
    batch_size: usize,
    worker: usize,
    base_seed: u64,
}

impl ShardLoader {
    /// Create a loader over `indices` for `worker`.
    pub fn new(indices: Vec<usize>, batch_size: usize, worker: usize, base_seed: u64) -> Self {
        assert!(batch_size > 0, "batch_size must be positive");
        ShardLoader {
            indices,
            batch_size,
            worker,
            base_seed,
        }
    }

    /// Number of batches per epoch: full batches, but at least one when
    /// the shard is non-empty (heavily label-skewed shards can be smaller
    /// than a batch; those cycle their examples — see
    /// [`ShardLoader::batch_indices`]).
    pub fn batches_per_epoch(&self) -> usize {
        (self.indices.len() / self.batch_size).max(usize::from(!self.indices.is_empty()))
    }

    /// The shuffled index order for `epoch` (deterministic).
    pub fn epoch_order(&self, epoch: usize) -> Vec<usize> {
        let mut order = self.indices.clone();
        let seed = self
            .base_seed
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add((self.worker as u64) << 32)
            .wrapping_add(epoch as u64);
        let mut rng = crate::util::rng::Rng::seed_from_u64(seed);
        rng.shuffle(&mut order);
        order
    }

    /// The index set of batch `b` (0-based) within `epoch`. Wraps modulo
    /// the shard length, so shards smaller than a batch resample their
    /// examples (with the epoch's shuffled order).
    pub fn batch_indices(&self, epoch: usize, b: usize) -> Vec<usize> {
        let order = self.epoch_order(epoch);
        let len = order.len();
        assert!(len > 0, "empty shard");
        (0..self.batch_size)
            .map(|i| order[(b * self.batch_size + i) % len])
            .collect()
    }

    /// Number of examples in the shard.
    pub fn shard_len(&self) -> usize {
        self.indices.len()
    }
}

/// Split `len` indices into train/test deterministically (test = every
/// `1/test_frac`-th example), so train/test never overlap.
pub fn train_test_split(len: usize, test_frac: f64) -> (Vec<usize>, Vec<usize>) {
    assert!((0.0..1.0).contains(&test_frac));
    let period = if test_frac > 0.0 {
        (1.0 / test_frac).round() as usize
    } else {
        usize::MAX
    };
    let mut train = Vec::new();
    let mut test = Vec::new();
    for i in 0..len {
        if period != usize::MAX && i % period == period - 1 {
            test.push(i);
        } else {
            train.push(i);
        }
    }
    (train, test)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loader_is_deterministic_and_partitions() {
        let loader = ShardLoader::new((0..100).collect(), 8, 3, 42);
        assert_eq!(loader.batches_per_epoch(), 12);
        let a = loader.epoch_order(5);
        let b = loader.epoch_order(5);
        assert_eq!(a, b, "same epoch ⇒ same order");
        let c = loader.epoch_order(6);
        assert_ne!(a, c, "different epoch ⇒ reshuffled");
        // Batches tile the epoch order without overlap.
        let b0 = loader.batch_indices(5, 0);
        let b1 = loader.batch_indices(5, 1);
        assert_eq!(b0, a[0..8].to_vec());
        assert_eq!(b1, a[8..16].to_vec());
    }

    #[test]
    fn different_workers_shuffle_differently() {
        let l0 = ShardLoader::new((0..64).collect(), 4, 0, 7);
        let l1 = ShardLoader::new((0..64).collect(), 4, 1, 7);
        assert_ne!(l0.epoch_order(0), l1.epoch_order(0));
    }

    #[test]
    fn split_is_disjoint_and_complete() {
        let (train, test) = train_test_split(100, 0.2);
        assert_eq!(train.len() + test.len(), 100);
        assert_eq!(test.len(), 20);
        let mut all: Vec<usize> = train.iter().chain(test.iter()).copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn zero_test_frac_keeps_all_train() {
        let (train, test) = train_test_split(10, 0.0);
        assert_eq!(train.len(), 10);
        assert!(test.is_empty());
    }
}
