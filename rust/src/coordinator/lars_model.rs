//! LARS-wrapped models — the paper's proposed future work (§4.2):
//! "The application of layer-wise adaptive rate scaling (LARS) to the
//! decentralized setting might be an option to further improve the
//! performance of our approach."
//!
//! [`LarsWrapped`] turns any gradient-exposing [`LocalModel`] into one
//! whose local step applies per-worker LARS (layer-wise trust ratios +
//! momentum) instead of plain momentum SGD, so every decentralized
//! flavor — including Ada — can train large-batch with LARS. Benchmarked
//! in `benches/ablation_bench.rs`.

use super::LocalModel;
use crate::data::Batch;
use crate::error::Result;
use crate::optim::Lars;
use crate::runtime::ModelKind;

/// A [`LocalModel`] whose update rule is LARS.
pub struct LarsWrapped<M: LocalModel> {
    inner: M,
    states: Vec<Lars>,
}

impl<M: LocalModel> LarsWrapped<M> {
    /// Wrap `inner` with per-worker LARS state (`eta` trust coefficient).
    pub fn new(inner: M, n_workers: usize, eta: f32, momentum: f32, weight_decay: f32) -> Self {
        let ranges = inner.layer_ranges();
        let p = inner.param_count();
        let states = (0..n_workers)
            .map(|_| Lars::new(p, ranges.clone(), eta, momentum, weight_decay))
            .collect();
        LarsWrapped { inner, states }
    }

    /// The wrapped model.
    pub fn inner(&self) -> &M {
        &self.inner
    }
}

impl<M: LocalModel> LocalModel for LarsWrapped<M> {
    fn param_count(&self) -> usize {
        self.inner.param_count()
    }

    fn kind(&self) -> ModelKind {
        self.inner.kind()
    }

    fn batch_size(&self) -> usize {
        self.inner.batch_size()
    }

    fn eval_batch_size(&self) -> usize {
        self.inner.eval_batch_size()
    }

    fn layer_ranges(&self) -> Vec<(usize, usize)> {
        self.inner.layer_ranges()
    }

    fn init_params(&self, seed: i32) -> Result<Vec<f32>> {
        self.inner.init_params(seed)
    }

    fn local_step(
        &mut self,
        worker: usize,
        params: &mut [f32],
        batch: &Batch,
        lr: f32,
    ) -> Result<f32> {
        let (loss, grads) = self.inner.loss_and_grad(params, batch)?;
        self.states
            .get_mut(worker)
            .ok_or_else(|| {
                crate::AdaError::Coordinator(format!("no LARS slot for worker {worker}"))
            })?
            .step(params, &grads, lr);
        Ok(loss)
    }

    fn loss_and_grad(&self, params: &[f32], batch: &Batch) -> Result<(f32, Vec<f32>)> {
        self.inner.loss_and_grad(params, batch)
    }

    fn supports_loss_and_grad(&self) -> bool {
        self.inner.supports_loss_and_grad()
    }

    fn eval_sums(&self, params: &[f32], batch: &Batch) -> Result<(f32, f32)> {
        self.inner.eval_sums(params, batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::surrogate::SoftmaxRegression;
    use crate::coordinator::{SgdFlavor, TrainConfig, Trainer};
    use crate::data::{Dataset, SyntheticClassification};

    #[test]
    fn lars_wrapped_trains_decentralized() {
        let data = SyntheticClassification::generate(1024, 8, 4, 3.0, 19);
        let base = SoftmaxRegression::new(8, 4, 16, 32, 8, 0.0);
        let mut model = LarsWrapped::new(base, 8, 0.02, 0.9, 1e-4);
        let mut cfg = TrainConfig::quick(8, 6);
        cfg.lr = crate::coordinator::LrPolicy::Fixed {
            schedule: crate::optim::LrSchedule::Constant { lr: 1.0 },
        };
        let mut trainer = Trainer::new(&mut model, cfg);
        let (_, summary) = trainer
            .run(&data, &SgdFlavor::Ada { k0: 7, gamma_k: 1.5 })
            .unwrap();
        assert!(!summary.diverged);
        assert!(
            summary.final_eval.metric > 0.5,
            "LARS + Ada must learn: {}",
            summary.final_eval.metric
        );
    }

    #[test]
    fn lars_step_differs_from_plain_sgd() {
        let data = SyntheticClassification::generate(64, 8, 4, 3.0, 5);
        let batch = data.batch(&(0..16).collect::<Vec<_>>());
        let base = SoftmaxRegression::new(8, 4, 16, 32, 1, 0.0);
        let p0 = base.init_params(1).unwrap();
        let mut plain = SoftmaxRegression::new(8, 4, 16, 32, 1, 0.0);
        let mut a = p0.clone();
        plain.local_step(0, &mut a, &batch, 0.1).unwrap();
        let mut lars = LarsWrapped::new(SoftmaxRegression::new(8, 4, 16, 32, 1, 0.0), 1, 0.001, 0.0, 0.0);
        let mut b = p0.clone();
        lars.local_step(0, &mut b, &batch, 0.1).unwrap();
        assert_ne!(a, b, "trust-ratio scaling must change the update");
    }
}
