//! [`TrainSession`] — the composable training loop behind the
//! [`Trainer`](super::Trainer) facade.
//!
//! A session is assembled from four open parts:
//!
//! * a **strategy** ([`crate::coordinator::strategy::CombineStrategy`] +
//!   optional [`TopologyPolicy`]), resolved from a
//!   [`StrategyInstance`] — by flavor name through the registry, or a
//!   custom instance the caller built — with an optional
//!   [`topology`](SessionBuilder::topology) override swapping in any
//!   policy (e.g. one resolved from `crate::topology::registry`);
//! * a **variance probe** ([`VarianceProbe`]) sampling the §3.1.2
//!   pre-averaging instrumentation point;
//! * **observers** ([`Observer`]) — the run's own [`RunRecorder`]
//!   driven through the same trait, followed by user observers in
//!   registration order; their [`ControlFlow`](super::observer::ControlFlow)
//!   verdicts flow *back* into the loop (observer-driven early stopping);
//! * the **config** ([`TrainConfig`]), unchanged from the closed API.
//!
//! The loop itself is the §2.1 iteration structure the old 961-line
//! trainer hard-wired: local phase → capture → combine phase → eval +
//! record, with failure injection, LR schedules, checkpoint resume and
//! the deterministic execution engine all preserved bit-for-bit.
//! Topology policies get iteration-level decision points
//! ([`crate::topology::TopologyPolicy::graph_for`]) and a structured
//! [`TrainSignals`] feedback bundle after every epoch.

use super::checkpoint::Checkpoint;
use super::observer::{EpochInfo, Observer};
use super::strategy::{
    self, CentralizedAverage, CombineStrategy, FusedGossipCombine, GossipCombine,
    StepCtx, StrategyInstance,
};
use super::trainer::{RunSummary, SgdFlavor, TrainConfig};
use super::{EvalResult, LocalModel};
use crate::data::{shard_indices, train_test_split, Dataset, ShardLoader};
use crate::error::{AdaError, Result};
use crate::exec::ExecEngine;
use crate::gossip::{mean_model, GossipEngine};
use crate::graph::CommGraph;
use crate::metrics::{
    consensus_distance, IterationRecord, RunRecorder, VarianceProbe, VarianceReport,
};
use crate::runtime::ModelKind;
use crate::simnet::{ClusterSpec, FaultPlan, SimNet};
use crate::topology::{RunInfo, TopologyPolicy, TrainSignals};
use crate::util::matrix::ReplicaMatrix;
use std::path::{Path, PathBuf};

/// Builder for a [`TrainSession`]. Obtain via [`TrainSession::builder`],
/// pick a strategy (by [`SgdFlavor`] or custom [`StrategyInstance`]),
/// optionally add observers or a resume point, then [`build`].
///
/// [`build`]: SessionBuilder::build
pub struct SessionBuilder<'m> {
    model: &'m mut dyn LocalModel,
    config: TrainConfig,
    label: Option<String>,
    schedule: Option<Box<dyn TopologyPolicy>>,
    k_neighbors: usize,
    combine: Option<Box<dyn CombineStrategy>>,
    topology_override: Option<Box<dyn TopologyPolicy>>,
    observers: Vec<Box<dyn Observer>>,
    initial_replicas: Option<ReplicaMatrix>,
    start_epoch: usize,
}

impl<'m> SessionBuilder<'m> {
    /// Resolve `flavor` through the builtin strategy registry — the
    /// backward-compatible path every [`super::Trainer`] run takes.
    pub fn flavor(self, flavor: &SgdFlavor) -> Result<Self> {
        let n = self.config.n_workers;
        let inst = strategy::registry().resolve(&flavor.name(), &flavor.params(n))?;
        Ok(self.strategy(inst))
    }

    /// Use a resolved strategy instance (from any registry, or built by
    /// hand) — the open path.
    pub fn strategy(mut self, inst: StrategyInstance) -> Self {
        self.label = Some(inst.label);
        self.schedule = inst.schedule;
        self.k_neighbors = inst.k_neighbors;
        self.combine = inst.combine;
        self
    }

    /// Replace the strategy's communication-graph policy with `policy`
    /// (e.g. one resolved by name from [`crate::topology::registry`]).
    /// `k_neighbors` — the Table 2 LR-scaling input — is re-derived
    /// from the policy's [`k_hint`](TopologyPolicy::k_hint). Applies on
    /// [`build`](SessionBuilder::build), whatever the call order.
    ///
    /// The strategy must already be decentralized: overriding a
    /// schedule-less (centralized) strategy is a [`build`] error —
    /// silently rewiring it into gossip would belie its label, and
    /// [`crate::dbench::SessionPlan`] skips such overrides for the same
    /// reason.
    ///
    /// [`build`]: SessionBuilder::build
    pub fn topology(mut self, policy: Box<dyn TopologyPolicy>) -> Self {
        self.topology_override = Some(policy);
        self
    }

    /// Append an observer (invoked after the built-in recorder, in
    /// registration order).
    pub fn observer(mut self, obs: Box<dyn Observer>) -> Self {
        self.observers.push(obs);
        self
    }

    /// Resume from saved replica state at `epoch` (shapes validated at
    /// run time against the dataset/model pair).
    pub fn start_from(mut self, epoch: usize, replicas: ReplicaMatrix) -> Self {
        self.start_epoch = epoch;
        self.initial_replicas = Some(replicas);
        self
    }

    /// Finalize. Picks the default combine strategy when the instance
    /// left it open: [`CentralizedAverage`] without a topology
    /// schedule; with one, [`FusedGossipCombine`] when
    /// `config.fused` is set and the model exposes raw gradients, else
    /// [`GossipCombine`].
    pub fn build(self) -> Result<TrainSession<'m>> {
        let label = self.label.ok_or_else(|| {
            AdaError::Coordinator(
                "session needs a strategy (SessionBuilder::flavor or ::strategy)".into(),
            )
        })?;
        if self.config.n_workers < 2 {
            return Err(AdaError::Coordinator("need at least 2 workers".into()));
        }
        // A topology override replaces the strategy's own schedule and
        // re-derives the LR-scaling neighbor count from the policy.
        let (schedule, k_neighbors) = match self.topology_override {
            Some(policy) => {
                if self.schedule.is_none() {
                    return Err(AdaError::Coordinator(format!(
                        "topology override {:?} needs a decentralized strategy \
                         ({:?} has no graph schedule to replace)",
                        policy.name(),
                        label
                    )));
                }
                let k = policy.k_hint();
                (Some(policy), k)
            }
            None => (self.schedule, self.k_neighbors),
        };
        let combine: Box<dyn CombineStrategy> = match self.combine {
            Some(c) => c,
            None => {
                if schedule.is_none() {
                    Box::new(CentralizedAverage::new(self.config.central_momentum))
                } else if self.config.fused && self.model.supports_loss_and_grad() {
                    Box::new(FusedGossipCombine::new(self.config.fused_momentum))
                } else {
                    Box::new(GossipCombine::new())
                }
            }
        };
        Ok(TrainSession {
            model: self.model,
            config: self.config,
            label,
            schedule,
            k_neighbors,
            combine,
            observers: self.observers,
            initial_replicas: self.initial_replicas,
            start_epoch: self.start_epoch,
        })
    }
}

/// One fully assembled training run. Consumed by [`TrainSession::run`].
pub struct TrainSession<'m> {
    model: &'m mut dyn LocalModel,
    config: TrainConfig,
    label: String,
    schedule: Option<Box<dyn TopologyPolicy>>,
    k_neighbors: usize,
    combine: Box<dyn CombineStrategy>,
    observers: Vec<Box<dyn Observer>>,
    initial_replicas: Option<ReplicaMatrix>,
    start_epoch: usize,
}

impl<'m> TrainSession<'m> {
    /// Start assembling a session over `model` with `config`.
    pub fn builder(model: &'m mut dyn LocalModel, config: TrainConfig) -> SessionBuilder<'m> {
        SessionBuilder {
            model,
            config,
            label: None,
            schedule: None,
            k_neighbors: 0,
            combine: None,
            topology_override: None,
            observers: Vec::new(),
            initial_replicas: None,
            start_epoch: 0,
        }
    }

    /// Run label (`C_complete`, `D_ring`, a custom strategy's name, …).
    pub fn label(&self) -> &str {
        &self.label
    }

    /// Train on `dataset`, returning the iteration records and a
    /// summary. Deterministic for a given `(config.seed, strategy)`.
    pub fn run(mut self, dataset: &dyn Dataset) -> Result<(RunRecorder, RunSummary)> {
        let cfg = self.config.clone();
        let n = cfg.n_workers;
        let (train_idx, test_idx) = train_test_split(dataset.len(), cfg.test_frac);
        // Shard the *positions within train_idx*, then map back.
        let train_labels: Option<Vec<u32>> = dataset
            .labels()
            .map(|ls| train_idx.iter().map(|&i| ls[i]).collect());
        let shards = shard_indices(
            train_idx.len(),
            train_labels.as_deref(),
            n,
            cfg.shard,
            cfg.seed,
        )?;
        let loaders: Vec<ShardLoader> = shards
            .into_iter()
            .enumerate()
            .map(|(w, s)| {
                let mapped: Vec<usize> = s.into_iter().map(|p| train_idx[p]).collect();
                ShardLoader::new(mapped, self.model.batch_size(), w, cfg.seed)
            })
            .collect();
        let min_batches = loaders
            .iter()
            .map(ShardLoader::batches_per_epoch)
            .min()
            .unwrap_or(0);
        if min_batches == 0 {
            return Err(AdaError::Coordinator(
                "a worker received an empty shard; reduce workers".into(),
            ));
        }
        let iters_per_epoch = cfg
            .max_iters_per_epoch
            .map_or(min_batches, |m| m.min(min_batches));

        let lr_schedule =
            cfg.lr
                .build(self.k_neighbors, self.model.batch_size(), cfg.epochs as f64);
        let p = self.model.param_count();
        let layer_ranges = self.model.layer_ranges();
        let tracked: Vec<std::ops::Range<usize>> = cfg
            .track_layers
            .iter()
            .filter_map(|&l| layer_ranges.get(l).map(|&(a, b)| a..b))
            .collect();
        let probe = VarianceProbe::new(cfg.metrics_every, tracked);

        // Identical initial replicas (§2.2's setup), or restored state,
        // in the flat 64-byte-aligned replica store every kernel below
        // operates on.
        let mut replicas: ReplicaMatrix = match self.initial_replicas.take() {
            Some(reps) => {
                if reps.n() != n || reps.p() != p {
                    return Err(AdaError::Coordinator(format!(
                        "checkpoint shape ({} replicas × {} params) does not \
                         match run (n={n}, P={p})",
                        reps.n(),
                        reps.p()
                    )));
                }
                reps
            }
            None => {
                let init = self.model.init_params(cfg.seed as i32)?;
                ReplicaMatrix::broadcast(n, &init)
            }
        };
        let mut engine = GossipEngine::with_threads(cfg.threads);
        engine.set_bucket_kb(cfg.bucket_kb);
        // The fault plane engages only on decentralized runs (the
        // centralized allreduce has no bounded-staleness analogue
        // here); the plan is validated once, up front.
        let faults: Option<&FaultPlan> = match (&cfg.faults, &self.schedule) {
            (Some(plan), Some(_)) => {
                plan.validate(n)?;
                Some(plan)
            }
            _ => None,
        };
        let simnet = faults.map(|_| SimNet::new(ClusterSpec::summit()));
        // The overlapped route is taken only when asked for AND the
        // strategy implements it; everything else stays phase-ordered.
        // Both routes are bit-identical by the pipeline's determinism
        // contract (`crate::exec::pipeline`), test-enforced in
        // `rust/tests/exec_determinism.rs`. Fault-injection rounds stay
        // phase-ordered: the stale ingest must snapshot the round's
        // post-local-phase rows before the combine consumes them.
        let pipelined =
            cfg.pipeline && self.combine.supports_pipeline() && faults.is_none();
        self.combine.prepare(n, p)?;
        if let Some(s) = &mut self.schedule {
            s.on_run_start(&RunInfo {
                n_workers: n,
                param_count: p,
                epochs: cfg.epochs,
                iters_per_epoch,
            });
        }
        // Epoch-scoped policies (the default) resolve their graph once
        // per epoch — graph construction and cloning stay off the
        // iteration path, exactly as before the policy redesign.
        let iteration_scoped = self.schedule.as_ref().is_some_and(|s| s.iteration_scoped());
        // Failure-injection stream (deterministic under the run seed).
        let mut drop_rng = crate::util::rng::Rng::seed_from_u64(cfg.seed ^ 0xD209);

        let mut recorder = match &cfg.record_path {
            Some(path) => RunRecorder::to_file(self.label.clone(), path)?,
            None => RunRecorder::in_memory(self.label.clone()),
        };
        let mut diverged = false;
        let mut iteration = 0usize;
        let mut total_bytes_per_node = 0u64;

        'epochs: for epoch in self.start_epoch..cfg.epochs {
            let epoch_graph = match &self.schedule {
                Some(s) if !iteration_scoped => Some(s.graph_for(epoch, 0)?),
                _ => None,
            };
            // --- fault plane: crash/restart bookkeeping --------------
            // A node that recovers this epoch re-enters from the newest
            // usable checkpoint in the plan's `recover_dir`, or — when
            // none is usable — cold-joins from its in-neighbor average.
            // Down nodes keep stepping locally (their rows drift, also
            // deterministically); they are only cut out of the gossip.
            if let Some(plan) = faults {
                for node in 0..n {
                    if !plan.recovers_at(epoch, node) {
                        continue;
                    }
                    let g = match (&epoch_graph, &self.schedule) {
                        (Some(g), _) => Some(g.clone()),
                        (None, Some(s)) => Some(s.graph_for(epoch, 0)?),
                        (None, None) => None,
                    };
                    restore_replica(plan, &self.label, epoch, node, g.as_ref(), &mut replicas)?;
                }
            }
            let down: Vec<bool> = match faults {
                Some(plan) => (0..n).map(|i| plan.is_down(epoch, i)).collect(),
                None => Vec::new(),
            };
            let mut epoch_max_stale: Option<usize> = None;
            let mut epoch_stale_sum = 0.0f64;
            let mut epoch_stale_count = 0usize;
            let mut epoch_delay_s = 0.0f64;
            let mut epoch_gini_sum = 0.0f64;
            let mut epoch_var_sum = 0.0f64;
            let mut epoch_gini_count = 0usize;
            let mut epoch_loss_sum = 0.0f64;
            let mut epoch_iter_count = 0usize;
            let mut epoch_test_metric: Option<f64> = None;
            for b in 0..iters_per_epoch {
                let iter_graph = match &self.schedule {
                    Some(s) if iteration_scoped => Some(s.graph_for(epoch, b)?),
                    _ => None,
                };
                let graph = iter_graph.as_ref().or(epoch_graph.as_ref());
                let frac_epoch = epoch as f64 + b as f64 / iters_per_epoch as f64;
                let lr = lr_schedule.lr_at(frac_epoch) as f32;
                // The failure-injection mask is drawn here — by the
                // session, not the strategy — so the deterministic RNG
                // stream is a property of the run, and only gossip
                // rounds consume it (centralized runs draw nothing,
                // exactly as the closed path did). Drawn before the
                // local phase because the pipelined route starts the
                // combine's communication *during* local compute; the
                // dedicated RNG stream makes the draw order immaterial.
                let active_mask: Option<Vec<bool>> =
                    if graph.is_some() && cfg.drop_prob > 0.0 {
                        Some((0..n).map(|_| !drop_rng.bool(cfg.drop_prob)).collect())
                    } else {
                        None
                    };
                // Crashed nodes leave the round entirely: fold the
                // epoch's outage schedule into the participation mask
                // (the legacy drop stream above stays untouched, so
                // fault-free runs keep their exact RNG sequence).
                let active_mask: Option<Vec<bool>> =
                    if faults.is_some() && down.iter().any(|&d| d) {
                        let mut mask = active_mask.unwrap_or_else(|| vec![true; n]);
                        for (m, &d) in mask.iter_mut().zip(&down) {
                            *m &= !d;
                        }
                        Some(mask)
                    } else {
                        active_mask
                    };
                // --- local phase (strategy) --------------------------
                let train_loss = {
                    let mut ctx = StepCtx {
                        model: &mut *self.model,
                        dataset,
                        loaders: &loaders,
                        engine: &mut engine,
                        graph,
                        // The phased local phase never sees the mask
                        // (it belongs to the combine); the pipelined
                        // one drives the combine too, so it must.
                        active: if pipelined { active_mask.as_deref() } else { None },
                        // The local phase never mixes; staleness is a
                        // combine-phase property.
                        staleness: None,
                        epoch,
                        batch: b,
                        lr,
                        n,
                        param_count: p,
                    };
                    if pipelined {
                        self.combine.local_phase_bucket(&mut ctx, &mut replicas)?
                    } else {
                        self.combine.local_phase(&mut ctx, &mut replicas)?
                    }
                };
                if !train_loss.is_finite() {
                    diverged = true;
                }
                epoch_loss_sum += train_loss;
                epoch_iter_count += 1;

                // --- pre-averaging metric capture (DBench §3.1.2) ----
                let captured = probe.capture(engine.exec(), &replicas, iteration);
                if let Some(sample) = &captured {
                    epoch_gini_sum += sample.report.gini;
                    epoch_var_sum += crate::metrics::variance(&sample.norms);
                    epoch_gini_count += 1;
                }
                let (variance, per_tensor) = match captured {
                    Some(sample) => (sample.report, sample.per_tensor_gini),
                    None => (VarianceReport::of(&[]), Vec::new()),
                };

                // --- fault plane: deliveries, staleness, sim time ----
                // Every draw is a pure function of (plan seed, epoch,
                // iter, edge), so this block is deterministic at any
                // thread count. Straggling or crashed senders miss the
                // round; their receivers fall back to the stale buffer
                // the combine below mixes against.
                if let (Some(plan), Some(g)) = (faults, graph) {
                    let factors: Vec<f64> = (0..n)
                        .map(|i| {
                            if down[i] {
                                1.0
                            } else {
                                plan.straggler_factor(epoch, b, i)
                            }
                        })
                        .collect();
                    engine.ingest_stale(g, &replicas, |src, dst| {
                        !down[src]
                            && !down[dst]
                            && factors[src] <= 1.0
                            && plan.delivered(epoch, b, src, dst)
                    });
                    let (iter_max_stale, iter_mean_stale) = engine.stale_stats(g);
                    if let Some(mx) = iter_max_stale {
                        epoch_max_stale =
                            Some(epoch_max_stale.map_or(mx, |m| m.max(mx)));
                    }
                    if let Some(mean) = iter_mean_stale {
                        epoch_stale_sum += mean;
                        epoch_stale_count += 1;
                    }
                    // Simulated round time: the α–β communication cost
                    // under this iteration's link jitter, stretched by
                    // the slowest node's compute factor.
                    let net = simnet.as_ref().expect("fault plane built its simnet");
                    let worst = factors.iter().copied().fold(1.0f64, f64::max);
                    let delay = net
                        .gossip_round_with(g, p, |i, j| plan.link_scale(epoch, b, i, j))
                        .time_s
                        * worst;
                    epoch_delay_s += delay;
                    if let Some(s) = &mut self.schedule {
                        if s.wants_iteration_signals() {
                            s.observe(&TrainSignals {
                                epoch,
                                iteration: Some(b),
                                straggler_factor: factors,
                                max_staleness: iter_max_stale,
                                mean_staleness: iter_mean_stale,
                                sim_delay_s: Some(delay),
                                ..TrainSignals::default()
                            });
                        }
                    }
                }

                // --- combine phase (strategy) ------------------------
                let (degree, bytes) = {
                    let mut ctx = StepCtx {
                        model: &mut *self.model,
                        dataset,
                        loaders: &loaders,
                        engine: &mut engine,
                        graph,
                        active: active_mask.as_deref(),
                        staleness: faults.map(|_| cfg.staleness_bound),
                        epoch,
                        batch: b,
                        lr,
                        n,
                        param_count: p,
                    };
                    if pipelined {
                        self.combine.combine_phase_bucket(&mut ctx, &mut replicas)?
                    } else {
                        self.combine.combine_phase(&mut ctx, &mut replicas)?
                    }
                };
                total_bytes_per_node += bytes;

                // --- eval + record + observers -----------------------
                let eval_now = b + 1 == iters_per_epoch
                    && (cfg.eval_every_epochs != 0
                        && (epoch + 1) % cfg.eval_every_epochs == 0
                        || epoch + 1 == cfg.epochs);
                let test_metric = if eval_now {
                    Some(
                        evaluate_mean(
                            &*self.model,
                            dataset,
                            &test_idx,
                            &replicas,
                            engine.exec(),
                        )?
                        .metric,
                    )
                } else {
                    None
                };
                if test_metric.is_some() {
                    epoch_test_metric = test_metric;
                }
                let rec = IterationRecord {
                    iteration,
                    epoch,
                    train_loss,
                    test_metric,
                    variance,
                    per_tensor_gini: per_tensor,
                    graph_degree: degree,
                    bytes_per_node: bytes,
                    lr: lr as f64,
                };
                let mut flow = Observer::on_iteration(&mut recorder, &rec, &replicas)?;
                for obs in &mut self.observers {
                    flow = flow.merge(obs.on_iteration(&rec, &replicas)?);
                }
                iteration += 1;
                if diverged {
                    break 'epochs;
                }
                if flow.is_stop() {
                    // Observer-driven early stop: like the divergence
                    // break, the run ends here and proceeds straight to
                    // the final evaluation and `on_complete`.
                    break 'epochs;
                }
            }
            let mean_gini = if epoch_gini_count > 0 {
                Some(epoch_gini_sum / epoch_gini_count as f64)
            } else {
                None
            };
            if let Some(s) = &mut self.schedule {
                // The structured feedback bundle. The consensus
                // distance costs two O(n·P) passes, so it is measured
                // only for policies that opted in — static benchmark
                // schedules (and centralized sessions) pay nothing.
                let distance = if s.wants_consensus_distance() {
                    let mean = mean_model(engine.exec(), &replicas);
                    Some(consensus_distance(engine.exec(), &replicas, &mean))
                } else {
                    None
                };
                let l2_variance = if epoch_gini_count > 0 {
                    Some(epoch_var_sum / epoch_gini_count as f64)
                } else {
                    None
                };
                let signals = TrainSignals {
                    epoch,
                    gini: mean_gini,
                    l2_variance,
                    consensus_distance: distance,
                    train_loss: if epoch_iter_count > 0 {
                        epoch_loss_sum / epoch_iter_count as f64
                    } else {
                        f64::NAN
                    },
                    test_metric: epoch_test_metric,
                    comm_bytes_per_node: total_bytes_per_node,
                    iteration: None,
                    straggler_factor: Vec::new(),
                    max_staleness: epoch_max_stale,
                    mean_staleness: if epoch_stale_count > 0 {
                        Some(epoch_stale_sum / epoch_stale_count as f64)
                    } else {
                        None
                    },
                    sim_delay_s: faults.map(|_| epoch_delay_s),
                };
                s.observe(&signals);
            }
            let info = EpochInfo {
                epoch,
                mean_gini,
                replicas: &replicas,
                label: &self.label,
                seed: cfg.seed,
            };
            let mut flow = Observer::on_epoch(&mut recorder, &info)?;
            for obs in &mut self.observers {
                flow = flow.merge(obs.on_epoch(&info)?);
            }
            if flow.is_stop() {
                break 'epochs;
            }
        }

        let final_eval =
            evaluate_mean(&*self.model, dataset, &test_idx, &replicas, engine.exec())?;
        let total_iters = recorder.records().len();
        let decile = (total_iters / 10).max(1);
        let summary = RunSummary {
            flavor: self.label.clone(),
            final_eval,
            diverged,
            bytes_per_node: recorder.total_bytes_per_node(),
            early_gini: recorder.mean_gini(0..decile),
            late_gini: recorder.mean_gini(total_iters.saturating_sub(decile)..total_iters),
        };
        Observer::on_complete(&mut recorder, &summary, &replicas)?;
        for obs in &mut self.observers {
            obs.on_complete(&summary, &replicas)?;
        }
        Ok((recorder, summary))
    }
}

/// Evaluate the replica-averaged model (§2.2: "the trained model takes
/// θ as the average over all θ_i") on the test split. The mean model is
/// built over the run's persistent worker pool ([`mean_model`]).
pub(crate) fn evaluate_mean(
    model: &dyn LocalModel,
    dataset: &dyn Dataset,
    test_idx: &[usize],
    replicas: &ReplicaMatrix,
    exec: &ExecEngine,
) -> Result<EvalResult> {
    let mean = mean_model(exec, replicas);
    evaluate_params(model, dataset, test_idx, &mean)
}

/// Evaluate explicit parameters on the test split.
pub(crate) fn evaluate_params(
    model: &dyn LocalModel,
    dataset: &dyn Dataset,
    test_idx: &[usize],
    params: &[f32],
) -> Result<EvalResult> {
    let eb = model.eval_batch_size();
    let mut loss_sum = 0.0f64;
    let mut metric_sum = 0.0f64;
    let mut count = 0.0f64;
    for chunk in test_idx.chunks(eb) {
        if chunk.len() < eb {
            break; // fixed-shape executables: drop the remainder
        }
        let batch = dataset.batch(chunk);
        let (ls, ms) = model.eval_sums(params, &batch)?;
        loss_sum += ls as f64;
        metric_sum += ms as f64;
        count += match model.kind() {
            ModelKind::Classification => eb as f64,
            ModelKind::Lm => 0.0, // token count comes back in ms
        };
    }
    Ok(match model.kind() {
        ModelKind::Classification => EvalResult {
            loss: if count > 0.0 { loss_sum / count } else { f64::NAN },
            metric: if count > 0.0 { metric_sum / count } else { 0.0 },
        },
        ModelKind::Lm => {
            let tokens = metric_sum;
            let nll = if tokens > 0.0 { loss_sum / tokens } else { f64::NAN };
            EvalResult {
                loss: nll,
                metric: nll.exp(), // perplexity
            }
        }
    })
}

/// Restore a recovering node's replica row: prefer the newest usable
/// checkpoint in the plan's `recover_dir` (same flavor label and shape,
/// resume epoch not past the current one), fall back to the mean of the
/// node's in-neighbors — the "ask the cluster" cold join. Serial and
/// deterministic: directory entries are sorted, the neighbor fold order
/// is the graph row's, and the mean accumulates in f64. Momentum
/// buffers are *not* restored (the model's stay as they drifted) — a
/// documented simplification; SGD re-converges within an epoch.
fn restore_replica(
    plan: &FaultPlan,
    label: &str,
    epoch: usize,
    node: usize,
    graph: Option<&CommGraph>,
    replicas: &mut ReplicaMatrix,
) -> Result<()> {
    if let Some(dir) = &plan.recover_dir {
        if let Some(ck) =
            newest_checkpoint(dir, label, epoch, replicas.n(), replicas.p())
        {
            replicas.row_mut(node).copy_from_slice(ck.replicas.row(node));
            return Ok(());
        }
    }
    let Some(g) = graph else { return Ok(()) };
    let p = replicas.p();
    let mut acc = vec![0.0f64; p];
    let mut count = 0usize;
    for &j in g.neighbors_of(node) {
        if j == node {
            continue;
        }
        for (a, &v) in acc.iter_mut().zip(replicas.row(j)) {
            *a += v as f64;
        }
        count += 1;
    }
    if count > 0 {
        let inv = 1.0 / count as f64;
        for (dst, a) in replicas.row_mut(node).iter_mut().zip(&acc) {
            *dst = (*a * inv) as f32;
        }
    }
    Ok(())
}

/// Newest checkpoint in `dir` usable for the current run: matching
/// flavor `label`, matching replica shape, and a resume epoch ≤ the
/// recovery epoch (a checkpoint "from the future" of this replay is
/// skipped). Unreadable files are ignored; ties on epoch resolve to the
/// lexicographically later filename.
fn newest_checkpoint(
    dir: &Path,
    label: &str,
    epoch: usize,
    n: usize,
    p: usize,
) -> Option<Checkpoint> {
    let mut paths: Vec<PathBuf> = std::fs::read_dir(dir)
        .ok()?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|path| path.extension().is_some_and(|e| e == "ckpt"))
        .collect();
    paths.sort();
    let mut best: Option<Checkpoint> = None;
    for path in paths {
        let Ok(ck) = Checkpoint::load(&path) else { continue };
        if ck.flavor != label
            || ck.epoch > epoch
            || ck.replicas.n() != n
            || ck.replicas.p() != p
        {
            continue;
        }
        if best.as_ref().is_none_or(|b| ck.epoch >= b.epoch) {
            best = Some(ck);
        }
    }
    best
}
