//! The decentralized training coordinator — the paper's L3 contribution.
//!
//! [`Trainer`] owns `n` worker slots (each a model replica as a flat f32
//! parameter vector plus a data shard) and drives the §2.1 iteration
//! structure: local fwd/bwd/update on every worker, **pre-averaging
//! metric capture** (the DBench instrumentation point), then a gossip
//! round over the epoch's communication graph. Centralized SGD
//! (`C_complete`) instead averages *gradients* globally with a shared
//! momentum buffer — the PyTorch-DDP baseline of §3.1.2.
//!
//! Models plug in through [`LocalModel`]: either [`HloModel`] (the AOT
//! JAX/Pallas artifacts run via PJRT — the production path) or the pure
//! Rust [`surrogate`] models (fast, used by the large DBench sweeps; see
//! EXPERIMENTS.md for where each is used).
//!
//! ## The open API
//!
//! Since the TrainSession redesign the closed trainer is a facade over
//! three open layers:
//!
//! * [`strategy`] — the per-iteration [`strategy::CombineStrategy`]
//!   and the name-keyed [`strategy::Registry`] of scenarios;
//! * [`session`] — the [`TrainSession`] builder that assembles a run
//!   from a strategy, a variance probe and observers;
//! * [`observer`] — the [`Observer`] hooks (`on_iteration` /
//!   `on_epoch` / `on_complete`) behind recording and checkpointing.

pub mod checkpoint;
#[cfg(feature = "pjrt")]
mod hlo_model;
mod lars_model;
pub mod observer;
pub mod session;
pub mod strategy;
pub mod surrogate;
pub mod trainer;

pub use checkpoint::Checkpoint;
#[cfg(feature = "pjrt")]
pub use hlo_model::HloModel;
pub use lars_model::LarsWrapped;
pub use observer::{
    ChannelObserver, CheckpointObserver, ControlFlow, DivergenceStreakStop, EpochInfo, Observer,
    TargetAccuracyStop, TrainEvent,
};
pub use session::{SessionBuilder, TrainSession};
pub use strategy::{CombineStrategy, Registry, StepCtx, StrategyInstance, StrategyParams};
pub use trainer::{LrPolicy, RunSummary, SgdFlavor, TrainConfig, Trainer};

use crate::data::Batch;
use crate::error::Result;
use crate::runtime::ModelKind;

/// A model replica's compute: everything the coordinator needs to train
/// and evaluate one worker's copy.
pub trait LocalModel {
    /// Flat parameter-vector length.
    fn param_count(&self) -> usize;
    /// Task family (decides metric interpretation).
    fn kind(&self) -> ModelKind;
    /// Training batch rows per step.
    fn batch_size(&self) -> usize;
    /// Eval batch rows per eval call.
    fn eval_batch_size(&self) -> usize;
    /// Flat-vector layer boundaries (for LARS and per-tensor variance).
    fn layer_ranges(&self) -> Vec<(usize, usize)>;
    /// Fresh parameters from a seed (identical across workers at start,
    /// like the paper's identical model replicas).
    fn init_params(&self, seed: i32) -> Result<Vec<f32>>;
    /// Fused local step (fwd + bwd + update) for `worker`; `params` —
    /// typically one row view of the run's
    /// [`crate::util::matrix::ReplicaMatrix`] — updated in place;
    /// returns the batch mean loss.
    fn local_step(
        &mut self,
        worker: usize,
        params: &mut [f32],
        batch: &Batch,
        lr: f32,
    ) -> Result<f32>;
    /// Loss and gradient without updating (needed by centralized SGD).
    /// Models that only expose a fused step (the HLO bundles) return an
    /// error, restricting them to the decentralized algorithms.
    fn loss_and_grad(&self, params: &[f32], batch: &Batch) -> Result<(f32, Vec<f32>)>;
    /// Whether [`LocalModel::loss_and_grad`] works. Models that only
    /// expose a fused local step (the HLO bundles) return `false`, and
    /// the trainer's `fused` execution mode falls back to the default
    /// adapt-then-combine path for them.
    fn supports_loss_and_grad(&self) -> bool {
        true
    }
    /// `(loss_sum, metric_sum)` over one eval batch: metric_sum is the
    /// correct-prediction count (classification) or token count (LM,
    /// where loss_sum is the summed token NLL).
    fn eval_sums(&self, params: &[f32], batch: &Batch) -> Result<(f32, f32)>;
}

/// Final evaluation numbers of a model on a test set.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EvalResult {
    /// Mean loss per example (classification) or per token (LM).
    pub loss: f64,
    /// Accuracy in [0,1] (classification) or perplexity (LM).
    pub metric: f64,
}

impl EvalResult {
    /// Whether a higher metric is better (accuracy yes, perplexity no).
    pub fn higher_is_better(kind: ModelKind) -> bool {
        matches!(kind, ModelKind::Classification)
    }
}
