//! Pure-Rust surrogate models with hand-derived gradients.
//!
//! The big DBench sweeps run 5 SGD implementations × 4 scales × hundreds
//! of iterations × up to 64 workers; driving every one of those steps
//! through PJRT would spend the benchmark budget on dispatch overhead.
//! These surrogates implement the same [`LocalModel`] contract with the
//! same flat-parameter layout conventions, exact analytic gradients, and
//! per-worker momentum (which is what makes centralized vs decentralized
//! *genuinely different* — momentum buffers are local in decentralized
//! SGD). The HLO bundles remain the production path and are
//! cross-validated against these in `rust/tests/`.

use super::LocalModel;
use crate::data::Batch;
use crate::error::{AdaError, Result};
use crate::optim::SgdState;
use crate::runtime::ModelKind;
use crate::util::rng::Rng;

/// Numerically stable log-softmax over a logits row, in place.
fn log_softmax(row: &mut [f32]) {
    let max = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0f32;
    for v in row.iter_mut() {
        *v -= max;
        sum += v.exp();
    }
    let lse = sum.ln();
    for v in row.iter_mut() {
        *v -= lse;
    }
}

/// Multinomial logistic regression (`W: classes × dim`, `b: classes`) —
/// the smallest member of the workload family (ResNet20 stand-in scale).
#[derive(Debug)]
pub struct SoftmaxRegression {
    dim: usize,
    classes: usize,
    batch_size: usize,
    eval_batch_size: usize,
    momentum: Vec<SgdState>,
    momentum_coef: f32,
}

impl SoftmaxRegression {
    /// Build for `n_workers` worker slots.
    pub fn new(
        dim: usize,
        classes: usize,
        batch_size: usize,
        eval_batch_size: usize,
        n_workers: usize,
        momentum: f32,
    ) -> Self {
        let p = dim * classes + classes;
        SoftmaxRegression {
            dim,
            classes,
            batch_size,
            eval_batch_size,
            momentum: (0..n_workers)
                .map(|_| SgdState::new(p, momentum, 0.0))
                .collect(),
            momentum_coef: momentum,
        }
    }

    /// Logits for one example.
    fn logits(&self, params: &[f32], x: &[f32], out: &mut [f32]) {
        let (w, b) = params.split_at(self.dim * self.classes);
        for c in 0..self.classes {
            let row = &w[c * self.dim..(c + 1) * self.dim];
            let mut acc = b[c];
            for (wi, xi) in row.iter().zip(x) {
                acc += wi * xi;
            }
            out[c] = acc;
        }
    }

    fn grad_impl(&self, params: &[f32], batch: &Batch) -> (f32, Vec<f32>) {
        let mut grads = vec![0.0f32; params.len()];
        let mut loss = 0.0f32;
        let mut logit = vec![0.0f32; self.classes];
        let bsz = batch.batch_size;
        let (gw, gb) = grads.split_at_mut(self.dim * self.classes);
        for i in 0..bsz {
            let x = &batch.x[i * self.dim..(i + 1) * self.dim];
            let y = batch.y[i] as usize;
            self.logits(params, x, &mut logit);
            log_softmax(&mut logit);
            loss -= logit[y];
            for c in 0..self.classes {
                let p = logit[c].exp();
                let err = p - if c == y { 1.0 } else { 0.0 };
                let row = &mut gw[c * self.dim..(c + 1) * self.dim];
                for (g, xi) in row.iter_mut().zip(x) {
                    *g += err * xi;
                }
                gb[c] += err;
            }
        }
        let inv = 1.0 / bsz as f32;
        for g in grads.iter_mut() {
            *g *= inv;
        }
        (loss * inv, grads)
    }
}

impl LocalModel for SoftmaxRegression {
    fn param_count(&self) -> usize {
        self.dim * self.classes + self.classes
    }

    fn kind(&self) -> ModelKind {
        ModelKind::Classification
    }

    fn batch_size(&self) -> usize {
        self.batch_size
    }

    fn eval_batch_size(&self) -> usize {
        self.eval_batch_size
    }

    fn layer_ranges(&self) -> Vec<(usize, usize)> {
        let wb = self.dim * self.classes;
        vec![(0, wb), (wb, wb + self.classes)]
    }

    fn init_params(&self, seed: i32) -> Result<Vec<f32>> {
        let mut rng = Rng::seed_from_u64(seed as u64);
        let scale = (1.0 / self.dim as f32).sqrt();
        let mut p: Vec<f32> = (0..self.dim * self.classes)
            .map(|_| rng.range_f32(-scale, scale))
            .collect();
        p.extend(std::iter::repeat(0.0f32).take(self.classes));
        Ok(p)
    }

    fn local_step(
        &mut self,
        worker: usize,
        params: &mut [f32],
        batch: &Batch,
        lr: f32,
    ) -> Result<f32> {
        let (loss, grads) = self.grad_impl(params, batch);
        self.momentum
            .get_mut(worker)
            .ok_or_else(|| AdaError::Coordinator(format!("no momentum slot for worker {worker}")))?
            .step(params, &grads, lr);
        Ok(loss)
    }

    fn loss_and_grad(&self, params: &[f32], batch: &Batch) -> Result<(f32, Vec<f32>)> {
        Ok(self.grad_impl(params, batch))
    }

    fn eval_sums(&self, params: &[f32], batch: &Batch) -> Result<(f32, f32)> {
        let mut loss_sum = 0.0f32;
        let mut correct = 0.0f32;
        let mut logit = vec![0.0f32; self.classes];
        for i in 0..batch.batch_size {
            let x = &batch.x[i * self.dim..(i + 1) * self.dim];
            let y = batch.y[i] as usize;
            self.logits(params, x, &mut logit);
            log_softmax(&mut logit);
            loss_sum -= logit[y];
            let argmax = logit
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).expect("NaN logit"))
                .map(|(c, _)| c)
                .expect("nonempty logits");
            if argmax == y {
                correct += 1.0;
            }
        }
        Ok((loss_sum, correct))
    }
}

impl SoftmaxRegression {
    /// Reset all workers' momentum (used between DBench runs).
    pub fn reset_momentum(&mut self) {
        for m in self.momentum.iter_mut() {
            m.reset();
        }
    }

    /// Momentum coefficient.
    pub fn momentum_coef(&self) -> f32 {
        self.momentum_coef
    }
}

/// One-hidden-layer tanh MLP classifier — the mid-sized workload
/// (DenseNet100 stand-in scale). Layout: `W1(h×d) ‖ b1(h) ‖ W2(c×h) ‖ b2(c)`.
#[derive(Debug)]
pub struct MlpClassifier {
    dim: usize,
    hidden: usize,
    classes: usize,
    batch_size: usize,
    eval_batch_size: usize,
    momentum: Vec<SgdState>,
}

impl MlpClassifier {
    /// Build for `n_workers` worker slots.
    pub fn new(
        dim: usize,
        hidden: usize,
        classes: usize,
        batch_size: usize,
        eval_batch_size: usize,
        n_workers: usize,
        momentum: f32,
    ) -> Self {
        let p = hidden * dim + hidden + classes * hidden + classes;
        MlpClassifier {
            dim,
            hidden,
            classes,
            batch_size,
            eval_batch_size,
            momentum: (0..n_workers)
                .map(|_| SgdState::new(p, momentum, 0.0))
                .collect(),
        }
    }

    fn split<'a>(&self, params: &'a [f32]) -> (&'a [f32], &'a [f32], &'a [f32], &'a [f32]) {
        let (d, h, c) = (self.dim, self.hidden, self.classes);
        let (w1, rest) = params.split_at(h * d);
        let (b1, rest) = rest.split_at(h);
        let (w2, b2) = rest.split_at(c * h);
        (w1, b1, w2, b2)
    }

    /// Forward one example; fills `hid` (tanh activations) and `logit`.
    fn forward(&self, params: &[f32], x: &[f32], hid: &mut [f32], logit: &mut [f32]) {
        let (w1, b1, w2, b2) = self.split(params);
        for j in 0..self.hidden {
            let row = &w1[j * self.dim..(j + 1) * self.dim];
            let mut acc = b1[j];
            for (wi, xi) in row.iter().zip(x) {
                acc += wi * xi;
            }
            hid[j] = acc.tanh();
        }
        for c in 0..self.classes {
            let row = &w2[c * self.hidden..(c + 1) * self.hidden];
            let mut acc = b2[c];
            for (wi, hi) in row.iter().zip(hid.iter()) {
                acc += wi * hi;
            }
            logit[c] = acc;
        }
    }

    fn grad_impl(&self, params: &[f32], batch: &Batch) -> (f32, Vec<f32>) {
        let (d, h, c) = (self.dim, self.hidden, self.classes);
        let mut grads = vec![0.0f32; params.len()];
        let mut loss = 0.0f32;
        let mut hid = vec![0.0f32; h];
        let mut logit = vec![0.0f32; c];
        let mut dh = vec![0.0f32; h];
        let (_, _, w2, _) = self.split(params);
        let w2 = w2.to_vec(); // borrow dance: params vs grads
        for i in 0..batch.batch_size {
            let x = &batch.x[i * d..(i + 1) * d];
            let y = batch.y[i] as usize;
            self.forward(params, x, &mut hid, &mut logit);
            log_softmax(&mut logit);
            loss -= logit[y];
            dh.iter_mut().for_each(|v| *v = 0.0);
            {
                let (gw1, rest) = grads.split_at_mut(h * d);
                let (gb1, rest) = rest.split_at_mut(h);
                let (gw2, gb2) = rest.split_at_mut(c * h);
                for cc in 0..c {
                    let p = logit[cc].exp();
                    let err = p - if cc == y { 1.0 } else { 0.0 };
                    let row = &mut gw2[cc * h..(cc + 1) * h];
                    for (g, hi) in row.iter_mut().zip(hid.iter()) {
                        *g += err * hi;
                    }
                    gb2[cc] += err;
                    let wrow = &w2[cc * h..(cc + 1) * h];
                    for (dv, wi) in dh.iter_mut().zip(wrow) {
                        *dv += err * wi;
                    }
                }
                for j in 0..h {
                    let dz = dh[j] * (1.0 - hid[j] * hid[j]); // tanh'
                    let row = &mut gw1[j * d..(j + 1) * d];
                    for (g, xi) in row.iter_mut().zip(x) {
                        *g += dz * xi;
                    }
                    gb1[j] += dz;
                }
            }
        }
        let inv = 1.0 / batch.batch_size as f32;
        for g in grads.iter_mut() {
            *g *= inv;
        }
        (loss * inv, grads)
    }
}

impl LocalModel for MlpClassifier {
    fn param_count(&self) -> usize {
        self.hidden * self.dim + self.hidden + self.classes * self.hidden + self.classes
    }

    fn kind(&self) -> ModelKind {
        ModelKind::Classification
    }

    fn batch_size(&self) -> usize {
        self.batch_size
    }

    fn eval_batch_size(&self) -> usize {
        self.eval_batch_size
    }

    fn layer_ranges(&self) -> Vec<(usize, usize)> {
        let (d, h, c) = (self.dim, self.hidden, self.classes);
        let a = h * d;
        let b = a + h;
        let e = b + c * h;
        vec![(0, a), (a, b), (b, e), (e, e + c)]
    }

    fn init_params(&self, seed: i32) -> Result<Vec<f32>> {
        let mut rng = Rng::seed_from_u64(seed as u64 ^ 0x4D4C50);
        let (d, h, c) = (self.dim, self.hidden, self.classes);
        let s1 = (1.0 / d as f32).sqrt();
        let s2 = (1.0 / h as f32).sqrt();
        let mut p: Vec<f32> = (0..h * d).map(|_| rng.range_f32(-s1, s1)).collect();
        p.extend(std::iter::repeat(0.0f32).take(h));
        p.extend((0..c * h).map(|_| rng.range_f32(-s2, s2)));
        p.extend(std::iter::repeat(0.0f32).take(c));
        Ok(p)
    }

    fn local_step(
        &mut self,
        worker: usize,
        params: &mut [f32],
        batch: &Batch,
        lr: f32,
    ) -> Result<f32> {
        let (loss, grads) = self.grad_impl(params, batch);
        self.momentum
            .get_mut(worker)
            .ok_or_else(|| AdaError::Coordinator(format!("no momentum slot for worker {worker}")))?
            .step(params, &grads, lr);
        Ok(loss)
    }

    fn loss_and_grad(&self, params: &[f32], batch: &Batch) -> Result<(f32, Vec<f32>)> {
        Ok(self.grad_impl(params, batch))
    }

    fn eval_sums(&self, params: &[f32], batch: &Batch) -> Result<(f32, f32)> {
        let mut loss_sum = 0.0f32;
        let mut correct = 0.0f32;
        let mut hid = vec![0.0f32; self.hidden];
        let mut logit = vec![0.0f32; self.classes];
        for i in 0..batch.batch_size {
            let x = &batch.x[i * self.dim..(i + 1) * self.dim];
            let y = batch.y[i] as usize;
            self.forward(params, x, &mut hid, &mut logit);
            log_softmax(&mut logit);
            loss_sum -= logit[y];
            let argmax = logit
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).expect("NaN logit"))
                .map(|(cc, _)| cc)
                .expect("nonempty");
            if argmax == y {
                correct += 1.0;
            }
        }
        Ok((loss_sum, correct))
    }
}

/// Bigram language model: logits for the next token are a learned row
/// per current token (`W: vocab × vocab`) — the LM-family surrogate
/// (LSTM/WikiText2 stand-in; perplexity-metric workload).
#[derive(Debug)]
pub struct BigramLm {
    vocab: usize,
    seq_len: usize,
    batch_size: usize,
    eval_batch_size: usize,
    momentum: Vec<SgdState>,
}

impl BigramLm {
    /// Build for `n_workers` worker slots.
    pub fn new(
        vocab: usize,
        seq_len: usize,
        batch_size: usize,
        eval_batch_size: usize,
        n_workers: usize,
        momentum: f32,
    ) -> Self {
        BigramLm {
            vocab,
            seq_len,
            batch_size,
            eval_batch_size,
            momentum: (0..n_workers)
                .map(|_| SgdState::new(vocab * vocab, momentum, 0.0))
                .collect(),
        }
    }

    fn grad_impl(&self, params: &[f32], batch: &Batch) -> (f32, Vec<f32>) {
        let v = self.vocab;
        let mut grads = vec![0.0f32; params.len()];
        let mut loss = 0.0f32;
        let mut logit = vec![0.0f32; v];
        let tokens = batch.batch_size * self.seq_len;
        for i in 0..batch.batch_size {
            for t in 0..self.seq_len {
                let cur = batch.x[i * self.seq_len + t] as usize;
                let next = batch.y[i * self.seq_len + t] as usize;
                logit.copy_from_slice(&params[cur * v..(cur + 1) * v]);
                log_softmax(&mut logit);
                loss -= logit[next];
                let grow = &mut grads[cur * v..(cur + 1) * v];
                for (c, g) in grow.iter_mut().enumerate() {
                    let p = logit[c].exp();
                    *g += p - if c == next { 1.0 } else { 0.0 };
                }
            }
        }
        let inv = 1.0 / tokens as f32;
        for g in grads.iter_mut() {
            *g *= inv;
        }
        (loss * inv, grads)
    }
}

impl LocalModel for BigramLm {
    fn param_count(&self) -> usize {
        self.vocab * self.vocab
    }

    fn kind(&self) -> ModelKind {
        ModelKind::Lm
    }

    fn batch_size(&self) -> usize {
        self.batch_size
    }

    fn eval_batch_size(&self) -> usize {
        self.eval_batch_size
    }

    fn layer_ranges(&self) -> Vec<(usize, usize)> {
        // One row per token is the natural tensor granularity.
        let v = self.vocab;
        (0..v.min(8)).map(|r| (r * v, (r + 1) * v)).collect()
    }

    fn init_params(&self, seed: i32) -> Result<Vec<f32>> {
        let mut rng = Rng::seed_from_u64(seed as u64 ^ 0x4C4D);
        let s = 0.01f32;
        Ok((0..self.vocab * self.vocab)
            .map(|_| rng.range_f32(-s, s))
            .collect())
    }

    fn local_step(
        &mut self,
        worker: usize,
        params: &mut [f32],
        batch: &Batch,
        lr: f32,
    ) -> Result<f32> {
        let (loss, grads) = self.grad_impl(params, batch);
        self.momentum
            .get_mut(worker)
            .ok_or_else(|| AdaError::Coordinator(format!("no momentum slot for worker {worker}")))?
            .step(params, &grads, lr);
        Ok(loss)
    }

    fn loss_and_grad(&self, params: &[f32], batch: &Batch) -> Result<(f32, Vec<f32>)> {
        Ok(self.grad_impl(params, batch))
    }

    fn eval_sums(&self, params: &[f32], batch: &Batch) -> Result<(f32, f32)> {
        let v = self.vocab;
        let mut nll = 0.0f32;
        let mut logit = vec![0.0f32; v];
        let tokens = batch.batch_size * self.seq_len;
        for i in 0..batch.batch_size {
            for t in 0..self.seq_len {
                let cur = batch.x[i * self.seq_len + t] as usize;
                let next = batch.y[i * self.seq_len + t] as usize;
                logit.copy_from_slice(&params[cur * v..(cur + 1) * v]);
                log_softmax(&mut logit);
                nll -= logit[next];
            }
        }
        Ok((nll, tokens as f32))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Dataset, SyntheticClassification, SyntheticLm};

    fn finite_diff_check(
        model: &dyn LocalModel,
        params: &[f32],
        batch: &Batch,
        indices: &[usize],
    ) {
        let (_, grads) = model.loss_and_grad(params, batch).unwrap();
        let eps = 1e-3f32;
        for &i in indices {
            let mut plus = params.to_vec();
            plus[i] += eps;
            let (lp, _) = model.loss_and_grad(&plus, batch).unwrap();
            let mut minus = params.to_vec();
            minus[i] -= eps;
            let (lm, _) = model.loss_and_grad(&minus, batch).unwrap();
            let numeric = (lp - lm) / (2.0 * eps);
            assert!(
                (numeric - grads[i]).abs() < 2e-2_f32.max(0.1 * numeric.abs()),
                "grad[{i}]: analytic {} vs numeric {numeric}",
                grads[i]
            );
        }
    }

    #[test]
    fn softmax_gradient_matches_finite_differences() {
        let data = SyntheticClassification::generate(64, 6, 3, 2.0, 1);
        let m = SoftmaxRegression::new(6, 3, 16, 16, 1, 0.0);
        let params = m.init_params(7).unwrap();
        let batch = data.batch(&(0..16).collect::<Vec<_>>());
        finite_diff_check(&m, &params, &batch, &[0, 5, 10, 17, 20]);
    }

    #[test]
    fn mlp_gradient_matches_finite_differences() {
        let data = SyntheticClassification::generate(64, 5, 3, 2.0, 2);
        let m = MlpClassifier::new(5, 7, 3, 8, 8, 1, 0.0);
        let params = m.init_params(3).unwrap();
        let batch = data.batch(&(0..8).collect::<Vec<_>>());
        let p = m.param_count();
        finite_diff_check(&m, &params, &batch, &[0, 11, 35, 42, p - 1]);
    }

    #[test]
    fn bigram_gradient_matches_finite_differences() {
        let data = SyntheticLm::generate(16, 6, 8, 2, 3);
        let m = BigramLm::new(8, 6, 4, 4, 1, 0.0);
        let params = m.init_params(5).unwrap();
        let batch = data.batch(&[0, 1, 2, 3]);
        finite_diff_check(&m, &params, &batch, &[0, 9, 30, 63]);
    }

    #[test]
    fn softmax_learns_separable_data() {
        let data = SyntheticClassification::generate(512, 8, 4, 4.0, 11);
        let mut m = SoftmaxRegression::new(8, 4, 32, 128, 1, 0.9);
        let mut params = m.init_params(0).unwrap();
        for epoch in 0..20 {
            for b in 0..16 {
                let idx: Vec<usize> = (0..32).map(|i| (b * 32 + i) % 512).collect();
                let batch = data.batch(&idx);
                m.local_step(0, &mut params, &batch, 0.1).unwrap();
                let _ = epoch;
            }
        }
        let test = data.batch(&(0..128).collect::<Vec<_>>());
        let (_, correct) = m.eval_sums(&params, &test).unwrap();
        let acc = correct / 128.0;
        assert!(acc > 0.9, "separable data must be learnable, acc={acc}");
    }

    #[test]
    fn mlp_learns_better_than_chance() {
        let data = SyntheticClassification::generate(512, 8, 4, 3.0, 13);
        let mut m = MlpClassifier::new(8, 16, 4, 32, 128, 1, 0.9);
        let mut params = m.init_params(1).unwrap();
        for _ in 0..15 {
            for b in 0..16 {
                let idx: Vec<usize> = (0..32).map(|i| (b * 32 + i) % 512).collect();
                m.local_step(0, &mut params, &data.batch(&idx), 0.05).unwrap();
            }
        }
        let test = data.batch(&(0..128).collect::<Vec<_>>());
        let (_, correct) = m.eval_sums(&params, &test).unwrap();
        assert!(correct / 128.0 > 0.6, "acc={}", correct / 128.0);
    }

    #[test]
    fn bigram_reduces_perplexity() {
        let data = SyntheticLm::generate(256, 16, 12, 2, 17);
        let mut m = BigramLm::new(12, 16, 16, 64, 1, 0.9);
        let mut params = m.init_params(2).unwrap();
        let test = data.batch(&(0..64).collect::<Vec<_>>());
        let (nll0, tok0) = m.eval_sums(&params, &test).unwrap();
        let ppl0 = (nll0 / tok0).exp();
        for _ in 0..10 {
            for b in 0..16 {
                let idx: Vec<usize> = (0..16).map(|i| (b * 16 + i) % 256).collect();
                m.local_step(0, &mut params, &data.batch(&idx), 0.5).unwrap();
            }
        }
        let (nll1, tok1) = m.eval_sums(&params, &test).unwrap();
        let ppl1 = (nll1 / tok1).exp();
        assert!(
            ppl1 < ppl0 * 0.8,
            "training must reduce perplexity: {ppl0} → {ppl1}"
        );
        assert!(ppl1 < 12.0, "below uniform-vocab perplexity: {ppl1}");
    }

    #[test]
    fn layer_ranges_cover_params() {
        let m = MlpClassifier::new(5, 7, 3, 8, 8, 1, 0.0);
        let ranges = m.layer_ranges();
        assert_eq!(ranges.first().unwrap().0, 0);
        assert_eq!(ranges.last().unwrap().1, m.param_count());
        for w in ranges.windows(2) {
            assert_eq!(w[0].1, w[1].0, "ranges must tile");
        }
    }

    #[test]
    fn per_worker_momentum_is_isolated() {
        let data = SyntheticClassification::generate(64, 4, 2, 3.0, 5);
        let mut m = SoftmaxRegression::new(4, 2, 8, 8, 2, 0.9);
        let p0 = m.init_params(9).unwrap();
        let batch = data.batch(&(0..8).collect::<Vec<_>>());
        // Worker 0 steps twice (momentum builds); worker 1 steps once
        // from the same start — their params must differ after w0's 2nd.
        let mut a = p0.clone();
        m.local_step(0, &mut a, &batch, 0.1).unwrap();
        let mut b = p0.clone();
        m.local_step(1, &mut b, &batch, 0.1).unwrap();
        assert_eq!(a, b, "first steps identical (same grads, fresh momentum)");
        m.local_step(0, &mut a, &batch, 0.1).unwrap();
        m.local_step(1, &mut b, &batch, 0.1).unwrap();
        assert_eq!(a, b, "parallel workers with same data stay in lockstep");
    }
}
