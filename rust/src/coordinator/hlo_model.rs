//! [`LocalModel`] over an AOT-compiled [`ModelBundle`] — the production
//! path: every local step is one PJRT execution of the fused
//! fwd+bwd+update HLO, and Python is nowhere in sight.

use super::LocalModel;
use crate::data::Batch;
use crate::error::{AdaError, Result};
use crate::runtime::{ModelBundle, ModelKind};

/// HLO-backed model replica compute.
#[derive(Debug)]
pub struct HloModel {
    bundle: ModelBundle,
}

impl HloModel {
    /// Wrap a loaded bundle.
    pub fn new(bundle: ModelBundle) -> Self {
        HloModel { bundle }
    }

    /// The underlying bundle.
    pub fn bundle(&self) -> &ModelBundle {
        &self.bundle
    }
}

impl LocalModel for HloModel {
    fn param_count(&self) -> usize {
        self.bundle.manifest.param_count
    }

    fn kind(&self) -> ModelKind {
        self.bundle.manifest.kind
    }

    fn batch_size(&self) -> usize {
        self.bundle.manifest.batch_size
    }

    fn eval_batch_size(&self) -> usize {
        self.bundle.manifest.eval_batch_size
    }

    fn layer_ranges(&self) -> Vec<(usize, usize)> {
        self.bundle.manifest.layer_ranges.clone()
    }

    fn init_params(&self, seed: i32) -> Result<Vec<f32>> {
        self.bundle.init_params(seed)
    }

    fn local_step(
        &mut self,
        _worker: usize,
        params: &mut [f32],
        batch: &Batch,
        lr: f32,
    ) -> Result<f32> {
        Ok(self.bundle.local_step(params, batch, lr)?.loss)
    }

    fn loss_and_grad(&self, _params: &[f32], _batch: &Batch) -> Result<(f32, Vec<f32>)> {
        // The HLO step is fused (fwd+bwd+update in one executable by
        // design); raw gradients never leave the device. Centralized
        // gradient averaging therefore runs on the surrogate models —
        // see DESIGN.md §3. (For plain SGD, C_complete is mathematically
        // identical to D_complete, which the HLO path does support.)
        Err(AdaError::Coordinator(
            "HLO models expose only the fused step; use D_* algorithms \
             (or a surrogate model for C_complete)"
                .into(),
        ))
    }

    fn supports_loss_and_grad(&self) -> bool {
        false
    }

    fn eval_sums(&self, params: &[f32], batch: &Batch) -> Result<(f32, f32)> {
        self.bundle.eval_batch(params, batch)
    }
}
