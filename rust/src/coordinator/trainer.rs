//! The backward-compatible training facade: [`SgdFlavor`] (the named
//! SGD implementations of §3.1.2, Ada §4 and the extension schedules),
//! [`LrPolicy`]/[`TrainConfig`], and the [`Trainer`] entry point.
//!
//! Everything here is a thin layer over the open API: `SgdFlavor`
//! resolves through [`crate::coordinator::strategy::registry`], and
//! `Trainer::run` assembles a [`TrainSession`] — one builder call per
//! legacy run. New scenarios should target the session/strategy API
//! directly; this module exists so every pre-refactor call site (and
//! its bit-exact results) keeps working unchanged.

use super::session::{evaluate_params, TrainSession};
use super::strategy::{self, StrategyParams};
use super::{EvalResult, LocalModel};
use crate::data::{Dataset, ShardStrategy};
use crate::error::{AdaError, Result};
use crate::metrics::RunRecorder;
use crate::optim::{LrSchedule, ScalingRule};
use crate::topology::TopologyPolicy;
use crate::util::json::Value;
use std::path::PathBuf;

/// The SGD implementations benchmarked by DBench (§3.1.2), Ada (§4),
/// and the extension schedules — now a thin facade: each variant is a
/// name plus parameters, resolved through the open strategy registry
/// ([`crate::coordinator::strategy::registry`]).
#[derive(Debug, Clone, PartialEq)]
pub enum SgdFlavor {
    /// `C_complete`: centralized gradient averaging (PyTorch-DDP-like),
    /// one shared momentum buffer, globally consistent replicas.
    CentralizedComplete,
    /// `D_complete`: parameter averaging over the complete graph.
    DecentralizedComplete,
    /// `D_ring`.
    DecentralizedRing,
    /// `D_torus`.
    DecentralizedTorus,
    /// `D_exponential`.
    DecentralizedExponential,
    /// `D_adaptive` — Ada, Algorithm 1.
    Ada {
        /// Initial coordination number.
        k0: usize,
        /// Per-epoch decay of k.
        gamma_k: f64,
    },
    /// One-peer rotating exponential (communication-minimal baseline).
    OnePeer,
    /// Variance-triggered adaptive lattice (extension; Observation 4).
    VarianceAdaptive {
        /// Initial coordination number.
        k0: usize,
        /// k decrement per trigger.
        step: usize,
        /// Gini threshold.
        threshold: f64,
        /// Consecutive epochs below threshold before decaying.
        patience: usize,
    },
}

impl SgdFlavor {
    /// Paper-style short name (`C_complete`, `D_ring`, …) — the key
    /// this flavor resolves under in the strategy registry.
    pub fn name(&self) -> String {
        match self {
            SgdFlavor::CentralizedComplete => "C_complete".into(),
            SgdFlavor::DecentralizedComplete => "D_complete".into(),
            SgdFlavor::DecentralizedRing => "D_ring".into(),
            SgdFlavor::DecentralizedTorus => "D_torus".into(),
            SgdFlavor::DecentralizedExponential => "D_exponential".into(),
            SgdFlavor::Ada { .. } => "D_adaptive".into(),
            SgdFlavor::OnePeer => "D_one_peer".into(),
            SgdFlavor::VarianceAdaptive { .. } => "D_var_adaptive".into(),
        }
    }

    /// This flavor's knobs as registry parameters at scale `n`.
    pub fn params(&self, n: usize) -> StrategyParams {
        let mut p = StrategyParams::for_n(n);
        match *self {
            SgdFlavor::Ada { k0, gamma_k } => {
                p.k0 = Some(k0);
                p.gamma_k = gamma_k;
            }
            SgdFlavor::VarianceAdaptive {
                k0,
                step,
                threshold,
                patience,
            } => {
                p.k0 = Some(k0);
                p.step = step;
                p.threshold = threshold;
                p.patience = patience;
            }
            _ => {}
        }
        p
    }

    /// Topology policy for decentralized flavors (`None` =
    /// centralized), resolved through the builtin strategy registry.
    /// The registry's [`StrategyInstance`] is also the single source of
    /// the flavor's `k_neighbors` (Table 2's LR-scaling input) — there
    /// is deliberately no duplicate per-flavor formula here.
    ///
    /// [`StrategyInstance`]: crate::coordinator::strategy::StrategyInstance
    pub fn schedule(&self, n: usize) -> Result<Option<Box<dyn TopologyPolicy>>> {
        Ok(strategy::registry()
            .resolve(&self.name(), &self.params(n))?
            .schedule)
    }
}

/// How the base LR schedule is produced per strategy.
#[derive(Debug, Clone)]
pub enum LrPolicy {
    /// Use this schedule as-is for every strategy.
    Fixed {
        /// The schedule.
        schedule: LrSchedule,
    },
    /// Table-2-style: generic warmup/hold/decay at `peak·s`, where
    /// `s = rule(batch·(k+1)/divisor)` depends on the strategy's graph.
    Scaled {
        /// Peak base LR before scaling.
        peak: f64,
        /// Linear (conventional) or sqrt (the §3.2 tuned runs).
        rule: ScalingRule,
        /// Table 2's divisor (256 ImageNet-style, 24 LSTM-style).
        divisor: f64,
        /// Warmup epochs.
        warmup: f64,
    },
}

impl LrPolicy {
    /// Build the concrete schedule for a strategy with `k_neighbors`
    /// graph neighbors (from
    /// [`crate::coordinator::strategy::StrategyInstance::k_neighbors`]).
    pub fn build(&self, k_neighbors: usize, batch_size: usize, total_epochs: f64) -> LrSchedule {
        match self {
            LrPolicy::Fixed { schedule } => schedule.clone(),
            LrPolicy::Scaled {
                peak,
                rule,
                divisor,
                warmup,
            } => {
                let s = rule.factor(batch_size, k_neighbors, *divisor);
                LrSchedule::bench_default(*peak, s, *warmup, total_epochs)
            }
        }
    }
}

/// Trainer configuration.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Simulated GPUs (graph nodes).
    pub n_workers: usize,
    /// Training epochs.
    pub epochs: usize,
    /// Seed for init, sharding and shuffling.
    pub seed: u64,
    /// LR policy.
    pub lr: LrPolicy,
    /// Shard strategy (label skew drives graph sensitivity; DESIGN.md §2).
    pub shard: ShardStrategy,
    /// Held-out fraction for the test split.
    pub test_frac: f64,
    /// Evaluate the mean model every this many epochs (0 = only at end).
    pub eval_every_epochs: usize,
    /// Capture variance metrics every this many iterations (they cost
    /// O(nP); 1 = every iteration, DBench's setting).
    pub metrics_every: usize,
    /// Cap iterations per epoch (benches subsample; `None` = full shard).
    pub max_iters_per_epoch: Option<usize>,
    /// Layer indices whose per-tensor gini is tracked (Fig. 4).
    pub track_layers: Vec<usize>,
    /// Momentum of the shared buffer used by `C_complete`'s gradient
    /// averaging (decentralized flavors carry momentum inside the model;
    /// set both to the same value for like-for-like comparisons).
    pub central_momentum: f32,
    /// Failure injection: per-iteration probability that a worker misses
    /// the gossip exchange (straggler model — it still computes locally;
    /// its neighbors renormalize over the present participants). 0 = off.
    /// Decentralized strategies only; the production-stability scenario
    /// the paper's introduction motivates.
    pub drop_prob: f64,
    /// Worker threads of the run's persistent execution pool (`0` = all
    /// cores), shared by the gossip/fused kernels, the per-iteration
    /// variance capture and the mean-model evaluation. The workers are
    /// spawned once per run and parked between calls. Results are
    /// **bit-identical for every value** — see `crate::exec` — so this
    /// is purely a wall-clock knob.
    pub threads: usize,
    /// Execute decentralized strategies in the **fused**
    /// combine-then-adapt order (D-PSGD, Lian et al. 2017): each
    /// iteration computes gradients at `θ_t`, then applies
    /// `θ_{t+1} = W θ_t − γ v` with the momentum update running inside
    /// the gossip pass ([`crate::gossip::GossipEngine::mix_step`]),
    /// eliminating one O(nP) DRAM round-trip per iteration. `false`
    /// (default) keeps the paper's adapt-then-combine order (local
    /// momentum step inside the model, then gossip). Both orders are
    /// standard; they are *not* numerically identical to each other.
    /// Requires the model to expose [`super::LocalModel::loss_and_grad`]
    /// (all surrogates do; the HLO bundles only expose the fused local
    /// step and stay on the default path). `C_complete` ignores this
    /// flag. Strategy-level view: this picks between
    /// [`crate::coordinator::strategy::GossipCombine`] and
    /// [`crate::coordinator::strategy::FusedGossipCombine`] when the
    /// strategy instance leaves the combine step open.
    pub fused: bool,
    /// Momentum coefficient of the per-worker buffers owned by the fused
    /// path (set equal to the model's momentum for like-for-like runs).
    pub fused_momentum: f32,
    /// Run decentralized iterations through the **overlapped bucketed
    /// pipeline** (`crate::exec::pipeline`): the combine's gossip runs
    /// on pool workers bucket-by-bucket while the local phase is still
    /// stepping later replicas, instead of the two phases running
    /// fork-join back-to-back. Output is **bit-identical** to the
    /// phase-ordered path at any thread count and bucket size
    /// (test-enforced), so this — like `threads` — is purely a
    /// wall-clock knob. Ignored by strategies that don't implement the
    /// bucketed path (e.g. centralized runs).
    pub pipeline: bool,
    /// Bucket width of the overlapped pipeline in KB of f32 parameters
    /// (`0` = default 256 KB). Smaller buckets overlap sooner but pay
    /// more wake-ups; see `BENCH_gossip.json` § pipeline_vs_phased.
    pub bucket_kb: usize,
    /// Optional JSONL output path.
    pub record_path: Option<PathBuf>,
    /// Deterministic fault plan (`None` = fault-free). When set on a
    /// decentralized run the session routes gossip through the
    /// bounded-staleness path, draws per-iteration stragglers / message
    /// drops / crash windows as a pure function of the plan's seed, and
    /// feeds the measured staleness and simulated delay into
    /// [`crate::topology::TrainSignals`]. Centralized strategies ignore
    /// it. See `crate::simnet::FaultPlan`.
    pub faults: Option<crate::simnet::FaultPlan>,
    /// Staleness bound `τ` of the fault plane's gossip: a peer row older
    /// than `τ` rounds is renormalized away instead of averaged
    /// ([`crate::gossip::GossipEngine::mix_stale`]). `0` = only rows
    /// delivered this round count. Ignored when `faults` is `None`.
    pub staleness_bound: usize,
}

impl TrainConfig {
    /// Reasonable defaults for `n_workers` over a synthetic workload.
    pub fn quick(n_workers: usize, epochs: usize) -> Self {
        TrainConfig {
            n_workers,
            epochs,
            seed: 42,
            lr: LrPolicy::Scaled {
                peak: 0.05,
                rule: ScalingRule::Linear,
                divisor: 256.0,
                warmup: 1.0,
            },
            shard: ShardStrategy::LabelSkew { alpha: 0.3 },
            test_frac: 0.15,
            eval_every_epochs: 1,
            metrics_every: 1,
            max_iters_per_epoch: None,
            track_layers: vec![0],
            central_momentum: 0.9,
            drop_prob: 0.0,
            threads: 0,
            fused: false,
            fused_momentum: 0.9,
            pipeline: false,
            bucket_kb: 0,
            record_path: None,
            faults: None,
            staleness_bound: 0,
        }
    }
}

/// Summary of one finished run.
#[derive(Debug, Clone)]
pub struct RunSummary {
    /// SGD implementation / strategy label.
    pub flavor: String,
    /// Final evaluation of the averaged model.
    pub final_eval: EvalResult,
    /// Whether any loss went non-finite (the paper's unconvergence cases).
    pub diverged: bool,
    /// Total bytes sent per node over the run.
    pub bytes_per_node: u64,
    /// Mean gini over the first 10% of iterations (early stage).
    pub early_gini: f64,
    /// Mean gini over the last 10% of iterations (late stage).
    pub late_gini: f64,
}

impl RunSummary {
    /// JSON encoding (used by the resumable experiment pipeline).
    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("flavor", Value::Str(self.flavor.clone())),
            ("loss", Value::Num(self.final_eval.loss)),
            ("metric", Value::Num(self.final_eval.metric)),
            ("diverged", Value::Bool(self.diverged)),
            ("bytes_per_node", Value::Num(self.bytes_per_node as f64)),
            ("early_gini", Value::Num(self.early_gini)),
            ("late_gini", Value::Num(self.late_gini)),
        ])
    }

    /// Decode from JSON (inverse of [`RunSummary::to_json`]).
    pub fn from_json(v: &Value) -> Result<Self> {
        Ok(RunSummary {
            flavor: v.str_field("flavor")?.to_string(),
            final_eval: EvalResult {
                loss: v.num_field("loss")?,
                metric: v.num_field("metric")?,
            },
            diverged: matches!(v.get("diverged"), Some(Value::Bool(true))),
            bytes_per_node: v.num_field("bytes_per_node")? as u64,
            early_gini: v.num_field("early_gini")?,
            late_gini: v.num_field("late_gini")?,
        })
    }
}

/// The legacy coordinator entry point: drives one run of one
/// [`SgdFlavor`] by assembling a [`TrainSession`] per call.
pub struct Trainer<'m> {
    model: &'m mut dyn LocalModel,
    config: TrainConfig,
}

impl<'m> Trainer<'m> {
    /// New trainer over `model` with `config`.
    pub fn new(model: &'m mut dyn LocalModel, config: TrainConfig) -> Self {
        Trainer { model, config }
    }

    /// Train `flavor` on `dataset`, returning the iteration records and a
    /// summary. Deterministic for a given `(config.seed, flavor)`.
    pub fn run(
        &mut self,
        dataset: &dyn Dataset,
        flavor: &SgdFlavor,
    ) -> Result<(RunRecorder, RunSummary)> {
        TrainSession::builder(&mut *self.model, self.config.clone())
            .flavor(flavor)?
            .build()?
            .run(dataset)
    }

    /// Resume a run from a [`crate::coordinator::Checkpoint`]: replicas
    /// are restored and training continues at the saved epoch with the
    /// saved seed (so data order, LR schedule position and topology
    /// schedule all line up with the original run).
    pub fn resume(
        &mut self,
        dataset: &dyn Dataset,
        flavor: &SgdFlavor,
        ckpt: crate::coordinator::Checkpoint,
    ) -> Result<(RunRecorder, RunSummary)> {
        if ckpt.flavor != flavor.name() {
            return Err(AdaError::Coordinator(format!(
                "checkpoint was taken under {} but resuming {}",
                ckpt.flavor,
                flavor.name()
            )));
        }
        self.config.seed = ckpt.seed;
        TrainSession::builder(&mut *self.model, self.config.clone())
            .flavor(flavor)?
            .start_from(ckpt.epoch, ckpt.replicas)
            .build()?
            .run(dataset)
    }

    /// Evaluate explicit parameters on the test split.
    pub fn evaluate_params(
        &self,
        dataset: &dyn Dataset,
        test_idx: &[usize],
        params: &[f32],
    ) -> Result<EvalResult> {
        evaluate_params(&*self.model, dataset, test_idx, params)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::surrogate::SoftmaxRegression;
    use crate::data::SyntheticClassification;

    fn quick_config(n: usize, epochs: usize) -> TrainConfig {
        let mut c = TrainConfig::quick(n, epochs);
        // Fixed LR across flavors: unit tests isolate the *averaging*
        // mechanism from Table 2's per-graph LR scaling (which the
        // figure benches exercise instead).
        c.lr = LrPolicy::Fixed {
            schedule: LrSchedule::Constant { lr: 0.05 },
        };
        c.shard = ShardStrategy::LabelSkew { alpha: 0.1 };
        c.metrics_every = 1;
        c
    }

    fn run_flavor(flavor: SgdFlavor, n: usize) -> RunSummary {
        let data = SyntheticClassification::generate(1024, 8, 4, 3.0, 21);
        let mut model = SoftmaxRegression::new(8, 4, 16, 32, n, 0.9);
        let mut t = Trainer::new(&mut model, quick_config(n, 8));
        let (_, summary) = t.run(&data, &flavor).unwrap();
        summary
    }

    #[test]
    fn all_flavors_train_without_divergence() {
        for flavor in [
            SgdFlavor::CentralizedComplete,
            SgdFlavor::DecentralizedComplete,
            SgdFlavor::DecentralizedRing,
            SgdFlavor::DecentralizedTorus,
            SgdFlavor::DecentralizedExponential,
            SgdFlavor::Ada { k0: 7, gamma_k: 2.0 },
            SgdFlavor::OnePeer,
            SgdFlavor::VarianceAdaptive {
                k0: 7,
                step: 2,
                threshold: 0.01,
                patience: 1,
            },
        ] {
            let s = run_flavor(flavor.clone(), 8);
            assert!(!s.diverged, "{} diverged", s.flavor);
            assert!(
                s.final_eval.metric > 0.5,
                "{} should beat chance (0.25): {}",
                s.flavor,
                s.final_eval.metric
            );
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = run_flavor(SgdFlavor::DecentralizedRing, 8);
        let b = run_flavor(SgdFlavor::DecentralizedRing, 8);
        assert_eq!(a.final_eval.metric, b.final_eval.metric);
        assert_eq!(a.bytes_per_node, b.bytes_per_node);
    }

    #[test]
    fn ring_sends_fewer_bytes_than_complete() {
        let ring = run_flavor(SgdFlavor::DecentralizedRing, 8);
        let complete = run_flavor(SgdFlavor::DecentralizedComplete, 8);
        assert!(ring.bytes_per_node < complete.bytes_per_node / 3);
    }

    #[test]
    fn ada_bytes_between_ring_and_complete() {
        let ring = run_flavor(SgdFlavor::DecentralizedRing, 8);
        let complete = run_flavor(SgdFlavor::DecentralizedComplete, 8);
        let ada = run_flavor(SgdFlavor::Ada { k0: 7, gamma_k: 2.0 }, 8);
        assert!(ada.bytes_per_node < complete.bytes_per_node);
        assert!(ada.bytes_per_node > ring.bytes_per_node);
    }

    #[test]
    fn ring_has_higher_early_variance_than_complete() {
        // Observation 4's mechanism at miniature scale: once replicas
        // have diverged (iteration ≥ 1), the sparser graph leaves more
        // cross-replica variance standing before each averaging step.
        let run = |flavor: SgdFlavor| {
            let data = SyntheticClassification::generate(1024, 8, 4, 3.0, 21);
            let mut model = SoftmaxRegression::new(8, 4, 16, 32, 8, 0.9);
            let mut t = Trainer::new(&mut model, quick_config(8, 8));
            let (rec, _) = t.run(&data, &flavor).unwrap();
            let n = rec.records().len();
            assert!(n > 4, "need a few iterations, got {n}");
            rec.mean_gini(1..n)
        };
        let ring = run(SgdFlavor::DecentralizedRing);
        let complete = run(SgdFlavor::DecentralizedComplete);
        assert!(
            ring > complete,
            "ring {ring} vs complete {complete}"
        );
    }

    #[test]
    fn centralized_and_decentralized_complete_are_close() {
        // With parameter averaging over the complete graph and fresh
        // momentum, D_complete tracks C_complete closely (§2.1 notes
        // they differ only in *what* is averaged).
        let c = run_flavor(SgdFlavor::CentralizedComplete, 8);
        let d = run_flavor(SgdFlavor::DecentralizedComplete, 8);
        assert!(
            (c.final_eval.metric - d.final_eval.metric).abs() < 0.15,
            "C {} vs D {}",
            c.final_eval.metric,
            d.final_eval.metric
        );
    }

    #[test]
    fn momentum_free_c_and_d_complete_coincide() {
        // §2.1/§2.2: for plain SGD (no momentum), averaging parameters
        // after identical-start local steps (D_complete) is algebraically
        // identical to averaging gradients (C_complete). With momentum
        // they diverge (per-worker vs shared buffers) — which is exactly
        // why the paper distinguishes the two.
        let run = |flavor: SgdFlavor, momentum: f32| {
            let data = SyntheticClassification::generate(512, 8, 4, 3.0, 31);
            let mut model = SoftmaxRegression::new(8, 4, 16, 32, 6, momentum);
            let mut cfg = quick_config(6, 3);
            cfg.shard = ShardStrategy::Iid;
            cfg.central_momentum = momentum;
            let mut t = Trainer::new(&mut model, cfg);
            let (rec, _) = t.run(&data, &flavor).unwrap();
            rec.records().iter().map(|r| r.train_loss).collect::<Vec<_>>()
        };
        let c = run(SgdFlavor::CentralizedComplete, 0.0);
        let d = run(SgdFlavor::DecentralizedComplete, 0.0);
        assert_eq!(c.len(), d.len());
        for (i, (a, b)) in c.iter().zip(&d).enumerate() {
            assert!(
                (a - b).abs() < 1e-4 * a.abs().max(1.0),
                "iter {i}: C {a} vs D {b} must coincide without momentum"
            );
        }
    }

    #[test]
    fn sqrt_scaling_rescues_sparse_graphs_at_scale() {
        // Observation 3: at larger scales the conventional linear rule
        // under-serves the sparse graphs; sqrt scaling lifts D_ring.
        let run = |rule: ScalingRule| {
            let data = SyntheticClassification::generate(2048, 8, 4, 3.0, 33);
            let mut model = SoftmaxRegression::new(8, 4, 16, 32, 16, 0.9);
            let mut cfg = TrainConfig::quick(16, 6);
            cfg.lr = LrPolicy::Scaled {
                peak: 0.05,
                rule,
                divisor: 256.0,
                warmup: 1.0,
            };
            let mut t = Trainer::new(&mut model, cfg);
            let (_, s) = t.run(&data, &SgdFlavor::DecentralizedRing).unwrap();
            s.final_eval.metric
        };
        let linear = run(ScalingRule::Linear);
        let sqrt = run(ScalingRule::Sqrt);
        assert!(
            sqrt > linear,
            "sqrt scaling must beat linear for the ring at scale: {sqrt} vs {linear}"
        );
    }

    #[test]
    fn survives_worker_dropout() {
        // Failure injection: 20% of workers miss each gossip exchange.
        // Training must stay stable (no divergence) and still learn —
        // the production-stability property the paper's intro motivates.
        let data = SyntheticClassification::generate(1024, 8, 4, 3.0, 23);
        let mut model = SoftmaxRegression::new(8, 4, 16, 32, 8, 0.9);
        let mut cfg = quick_config(8, 8);
        cfg.drop_prob = 0.2;
        let mut t = Trainer::new(&mut model, cfg);
        let (_, s) = t.run(&data, &SgdFlavor::DecentralizedTorus).unwrap();
        assert!(!s.diverged);
        assert!(
            s.final_eval.metric > 0.5,
            "dropout run must still learn: {}",
            s.final_eval.metric
        );
        // Deterministic under seed even with injected failures.
        let mut model2 = SoftmaxRegression::new(8, 4, 16, 32, 8, 0.9);
        let mut cfg2 = quick_config(8, 8);
        cfg2.drop_prob = 0.2;
        let (_, s2) = Trainer::new(&mut model2, cfg2)
            .run(&data, &SgdFlavor::DecentralizedTorus)
            .unwrap();
        assert_eq!(s.final_eval.metric, s2.final_eval.metric);
    }

    #[test]
    fn fused_flavors_train_without_divergence() {
        // The fused gossip+SGD path (combine-then-adapt) must learn on
        // every decentralized flavor.
        for flavor in [
            SgdFlavor::DecentralizedComplete,
            SgdFlavor::DecentralizedRing,
            SgdFlavor::DecentralizedTorus,
            SgdFlavor::DecentralizedExponential,
            SgdFlavor::Ada { k0: 7, gamma_k: 2.0 },
        ] {
            let data = SyntheticClassification::generate(1024, 8, 4, 3.0, 21);
            let mut model = SoftmaxRegression::new(8, 4, 16, 32, 8, 0.9);
            let mut cfg = quick_config(8, 8);
            cfg.fused = true;
            let mut t = Trainer::new(&mut model, cfg);
            let (_, s) = t.run(&data, &flavor).unwrap();
            assert!(!s.diverged, "{} diverged (fused)", s.flavor);
            assert!(
                s.final_eval.metric > 0.5,
                "fused {} should beat chance (0.25): {}",
                s.flavor,
                s.final_eval.metric
            );
        }
    }

    #[test]
    fn fused_is_bit_identical_across_thread_counts() {
        // The headline determinism guarantee, end to end: a full fused
        // training run produces the same floats at 1, 2, 4 threads.
        let run = |threads: usize| {
            let data = SyntheticClassification::generate(1024, 8, 4, 3.0, 21);
            let mut model = SoftmaxRegression::new(8, 4, 16, 32, 8, 0.9);
            let mut cfg = quick_config(8, 4);
            cfg.fused = true;
            cfg.threads = threads;
            let mut t = Trainer::new(&mut model, cfg);
            let (rec, s) = t.run(&data, &SgdFlavor::DecentralizedRing).unwrap();
            (
                rec.records().iter().map(|r| r.train_loss).collect::<Vec<_>>(),
                s.final_eval.metric,
            )
        };
        let (l1, m1) = run(1);
        for threads in [2, 4] {
            let (lt, mt) = run(threads);
            assert_eq!(l1, lt, "loss series differs at {threads} threads");
            assert_eq!(m1, mt, "metric differs at {threads} threads");
        }
    }

    #[test]
    fn fused_flag_does_not_change_centralized_sgd() {
        // C_complete averages gradients globally; the fused gossip path
        // never engages, so the flag must be a no-op there.
        let run = |fused: bool| {
            let data = SyntheticClassification::generate(512, 8, 4, 3.0, 31);
            let mut model = SoftmaxRegression::new(8, 4, 16, 32, 6, 0.9);
            let mut cfg = quick_config(6, 3);
            cfg.fused = fused;
            let mut t = Trainer::new(&mut model, cfg);
            let (rec, _) = t.run(&data, &SgdFlavor::CentralizedComplete).unwrap();
            rec.records().iter().map(|r| r.train_loss).collect::<Vec<_>>()
        };
        assert_eq!(run(false), run(true));
    }

    #[test]
    fn fused_survives_worker_dropout() {
        // Fused mode under failure injection takes the fused
        // mix_active_step path but keeps the same semantics: stable,
        // learning, deterministic.
        let data = SyntheticClassification::generate(1024, 8, 4, 3.0, 23);
        let run = || {
            let mut model = SoftmaxRegression::new(8, 4, 16, 32, 8, 0.9);
            let mut cfg = quick_config(8, 8);
            cfg.drop_prob = 0.2;
            cfg.fused = true;
            let mut t = Trainer::new(&mut model, cfg);
            t.run(&data, &SgdFlavor::DecentralizedTorus).unwrap().1
        };
        let s = run();
        assert!(!s.diverged);
        assert!(s.final_eval.metric > 0.5, "must still learn: {}", s.final_eval.metric);
        assert_eq!(s.final_eval.metric, run().final_eval.metric, "deterministic");
    }

    #[test]
    fn threaded_split_path_matches_serial_exactly() {
        // The non-fused path through the parallel engine is the same
        // floats as the serial engine, end to end.
        let run = |threads: usize| {
            let data = SyntheticClassification::generate(1024, 8, 4, 3.0, 21);
            let mut model = SoftmaxRegression::new(8, 4, 16, 32, 8, 0.9);
            let mut cfg = quick_config(8, 4);
            cfg.threads = threads;
            let mut t = Trainer::new(&mut model, cfg);
            let (_, s) = t.run(&data, &SgdFlavor::DecentralizedExponential).unwrap();
            s.final_eval.metric
        };
        assert_eq!(run(1), run(4));
    }

    #[test]
    fn rejects_single_worker() {
        let data = SyntheticClassification::generate(64, 4, 2, 3.0, 1);
        let mut model = SoftmaxRegression::new(4, 2, 8, 8, 1, 0.0);
        let mut t = Trainer::new(&mut model, quick_config(1, 1));
        assert!(t.run(&data, &SgdFlavor::DecentralizedRing).is_err());
    }

    #[test]
    fn records_have_monotone_iterations_and_lr() {
        let data = SyntheticClassification::generate(512, 8, 4, 3.0, 5);
        let mut model = SoftmaxRegression::new(8, 4, 16, 32, 9, 0.9);
        let mut t = Trainer::new(&mut model, quick_config(9, 3));
        let (rec, _) = t.run(&data, &SgdFlavor::DecentralizedTorus).unwrap();
        let records = rec.records();
        assert!(!records.is_empty());
        for (i, r) in records.iter().enumerate() {
            assert_eq!(r.iteration, i);
            assert!(r.lr > 0.0);
            assert_eq!(r.graph_degree, 4, "torus degree");
        }
        assert!(rec.final_test_metric().is_some(), "must eval at end");
    }

    #[test]
    fn run_summary_json_roundtrip() {
        let s = RunSummary {
            flavor: "D_ring".into(),
            final_eval: EvalResult { loss: 0.5, metric: 0.875 },
            diverged: false,
            bytes_per_node: 123_456,
            early_gini: 0.01,
            late_gini: 0.002,
        };
        let back = RunSummary::from_json(&s.to_json()).unwrap();
        assert_eq!(back.flavor, s.flavor);
        assert_eq!(back.final_eval, s.final_eval);
        assert_eq!(back.diverged, s.diverged);
        assert_eq!(back.bytes_per_node, s.bytes_per_node);
        assert_eq!(back.early_gini, s.early_gini);
        assert_eq!(back.late_gini, s.late_gini);
    }
}
