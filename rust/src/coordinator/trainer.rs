//! The n-worker training loop: the five SGD implementations of §3.1.2
//! plus Ada and the extension schedules, over any [`LocalModel`].

use super::{EvalResult, LocalModel};
use crate::data::{shard_indices, train_test_split, Dataset, ShardLoader, ShardStrategy};
use crate::error::{AdaError, Result};
use crate::exec::ExecEngine;
use crate::graph::GraphKind;
use crate::metrics::{
    per_replica_l2_norms_pooled, IterationRecord, RunRecorder, VarianceReport,
};
use crate::optim::{LrSchedule, ScalingRule, SgdState};
use crate::runtime::ModelKind;
use crate::topology::{
    AdaSchedule, OnePeerExponential, StaticSchedule, TopologySchedule, VarianceAdaptive,
};
use crate::gossip::{mean_model, GossipEngine};
use std::path::PathBuf;

/// The SGD implementations benchmarked by DBench (§3.1.2), Ada (§4), and
/// the extension schedules.
#[derive(Debug, Clone, PartialEq)]
pub enum SgdFlavor {
    /// `C_complete`: centralized gradient averaging (PyTorch-DDP-like),
    /// one shared momentum buffer, globally consistent replicas.
    CentralizedComplete,
    /// `D_complete`: parameter averaging over the complete graph.
    DecentralizedComplete,
    /// `D_ring`.
    DecentralizedRing,
    /// `D_torus`.
    DecentralizedTorus,
    /// `D_exponential`.
    DecentralizedExponential,
    /// `D_adaptive` — Ada, Algorithm 1.
    Ada {
        /// Initial coordination number.
        k0: usize,
        /// Per-epoch decay of k.
        gamma_k: f64,
    },
    /// One-peer rotating exponential (communication-minimal baseline).
    OnePeer,
    /// Variance-triggered adaptive lattice (extension; Observation 4).
    VarianceAdaptive {
        /// Initial coordination number.
        k0: usize,
        /// k decrement per trigger.
        step: usize,
        /// Gini threshold.
        threshold: f64,
        /// Consecutive epochs below threshold before decaying.
        patience: usize,
    },
}

impl SgdFlavor {
    /// Paper-style short name (`C_complete`, `D_ring`, …).
    pub fn name(&self) -> String {
        match self {
            SgdFlavor::CentralizedComplete => "C_complete".into(),
            SgdFlavor::DecentralizedComplete => "D_complete".into(),
            SgdFlavor::DecentralizedRing => "D_ring".into(),
            SgdFlavor::DecentralizedTorus => "D_torus".into(),
            SgdFlavor::DecentralizedExponential => "D_exponential".into(),
            SgdFlavor::Ada { .. } => "D_adaptive".into(),
            SgdFlavor::OnePeer => "D_one_peer".into(),
            SgdFlavor::VarianceAdaptive { .. } => "D_var_adaptive".into(),
        }
    }

    /// Topology schedule for decentralized flavors; `None` = centralized.
    pub fn schedule(&self, n: usize) -> Result<Option<Box<dyn TopologySchedule>>> {
        Ok(match *self {
            SgdFlavor::CentralizedComplete => None,
            SgdFlavor::DecentralizedComplete => {
                Some(Box::new(StaticSchedule::new(GraphKind::Complete, n)?))
            }
            SgdFlavor::DecentralizedRing => {
                Some(Box::new(StaticSchedule::new(GraphKind::Ring, n)?))
            }
            SgdFlavor::DecentralizedTorus => {
                Some(Box::new(StaticSchedule::new(GraphKind::Torus, n)?))
            }
            SgdFlavor::DecentralizedExponential => {
                Some(Box::new(StaticSchedule::new(GraphKind::Exponential, n)?))
            }
            SgdFlavor::Ada { k0, gamma_k } => Some(Box::new(AdaSchedule::new(n, k0, gamma_k))),
            SgdFlavor::OnePeer => Some(Box::new(OnePeerExponential::new(n)?)),
            SgdFlavor::VarianceAdaptive {
                k0,
                step,
                threshold,
                patience,
            } => Some(Box::new(VarianceAdaptive::new(n, k0, step, threshold, patience))),
        })
    }

    /// Neighbor count `k` used by Table 2's LR scaling
    /// (`s = batch·(k+1)/divisor`): k=2 ring, 4 torus, ⌊log2(n−1)⌋+1
    /// exponential, n−1 complete (and centralized), k0 for the adaptive
    /// schedules (their densest phase sets the safe LR).
    pub fn k_neighbors(&self, n: usize) -> usize {
        match *self {
            SgdFlavor::CentralizedComplete | SgdFlavor::DecentralizedComplete => n - 1,
            SgdFlavor::DecentralizedRing => 2,
            SgdFlavor::DecentralizedTorus => 4,
            SgdFlavor::DecentralizedExponential => {
                ((n - 1) as f64).log2().floor() as usize + 1
            }
            SgdFlavor::Ada { k0, .. } => k0,
            SgdFlavor::OnePeer => 1,
            SgdFlavor::VarianceAdaptive { k0, .. } => k0,
        }
    }
}

/// How the base LR schedule is produced per flavor.
#[derive(Debug, Clone)]
pub enum LrPolicy {
    /// Use this schedule as-is for every flavor.
    Fixed {
        /// The schedule.
        schedule: LrSchedule,
    },
    /// Table-2-style: generic warmup/hold/decay at `peak·s`, where
    /// `s = rule(batch·(k+1)/divisor)` depends on the flavor's graph.
    Scaled {
        /// Peak base LR before scaling.
        peak: f64,
        /// Linear (conventional) or sqrt (the §3.2 tuned runs).
        rule: ScalingRule,
        /// Table 2's divisor (256 ImageNet-style, 24 LSTM-style).
        divisor: f64,
        /// Warmup epochs.
        warmup: f64,
    },
}

impl LrPolicy {
    /// Build the concrete schedule for a flavor at scale `n`.
    pub fn build(
        &self,
        flavor: &SgdFlavor,
        n: usize,
        batch_size: usize,
        total_epochs: f64,
    ) -> LrSchedule {
        match self {
            LrPolicy::Fixed { schedule } => schedule.clone(),
            LrPolicy::Scaled {
                peak,
                rule,
                divisor,
                warmup,
            } => {
                let s = rule.factor(batch_size, flavor.k_neighbors(n), *divisor);
                LrSchedule::bench_default(*peak, s, *warmup, total_epochs)
            }
        }
    }
}

/// Trainer configuration.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Simulated GPUs (graph nodes).
    pub n_workers: usize,
    /// Training epochs.
    pub epochs: usize,
    /// Seed for init, sharding and shuffling.
    pub seed: u64,
    /// LR policy.
    pub lr: LrPolicy,
    /// Shard strategy (label skew drives graph sensitivity; DESIGN.md §2).
    pub shard: ShardStrategy,
    /// Held-out fraction for the test split.
    pub test_frac: f64,
    /// Evaluate the mean model every this many epochs (0 = only at end).
    pub eval_every_epochs: usize,
    /// Capture variance metrics every this many iterations (they cost
    /// O(nP); 1 = every iteration, DBench's setting).
    pub metrics_every: usize,
    /// Cap iterations per epoch (benches subsample; `None` = full shard).
    pub max_iters_per_epoch: Option<usize>,
    /// Layer indices whose per-tensor gini is tracked (Fig. 4).
    pub track_layers: Vec<usize>,
    /// Momentum of the shared buffer used by `C_complete`'s gradient
    /// averaging (decentralized flavors carry momentum inside the model;
    /// set both to the same value for like-for-like comparisons).
    pub central_momentum: f32,
    /// Failure injection: per-iteration probability that a worker misses
    /// the gossip exchange (straggler model — it still computes locally;
    /// its neighbors renormalize over the present participants). 0 = off.
    /// Decentralized flavors only; the production-stability scenario the
    /// paper's introduction motivates.
    pub drop_prob: f64,
    /// Worker threads of the run's persistent execution pool (`0` = all
    /// cores), shared by the gossip/fused kernels, the per-iteration
    /// variance capture and the mean-model evaluation. The workers are
    /// spawned once per run and parked between calls. Results are
    /// **bit-identical for every value** — see `crate::exec` — so this
    /// is purely a wall-clock knob.
    pub threads: usize,
    /// Execute decentralized flavors in the **fused** combine-then-adapt
    /// order (D-PSGD, Lian et al. 2017): each iteration computes
    /// gradients at `θ_t`, then applies `θ_{t+1} = W θ_t − γ v` with the
    /// momentum update running inside the gossip pass
    /// ([`GossipEngine::mix_step`]), eliminating one O(nP) DRAM
    /// round-trip per iteration. `false` (default) keeps the paper's
    /// adapt-then-combine order (local momentum step inside the model,
    /// then gossip). Both orders are standard; they are *not* numerically
    /// identical to each other. Requires the model to expose
    /// [`super::LocalModel::loss_and_grad`] (all surrogates do; the HLO
    /// bundles only expose the fused local step and stay on the default
    /// path). `C_complete` ignores this flag.
    pub fused: bool,
    /// Momentum coefficient of the per-worker buffers owned by the fused
    /// path (set equal to the model's momentum for like-for-like runs).
    pub fused_momentum: f32,
    /// Optional JSONL output path.
    pub record_path: Option<PathBuf>,
}

impl TrainConfig {
    /// Reasonable defaults for `n_workers` over a synthetic workload.
    pub fn quick(n_workers: usize, epochs: usize) -> Self {
        TrainConfig {
            n_workers,
            epochs,
            seed: 42,
            lr: LrPolicy::Scaled {
                peak: 0.05,
                rule: ScalingRule::Linear,
                divisor: 256.0,
                warmup: 1.0,
            },
            shard: ShardStrategy::LabelSkew { alpha: 0.3 },
            test_frac: 0.15,
            eval_every_epochs: 1,
            metrics_every: 1,
            max_iters_per_epoch: None,
            track_layers: vec![0],
            central_momentum: 0.9,
            drop_prob: 0.0,
            threads: 0,
            fused: false,
            fused_momentum: 0.9,
            record_path: None,
        }
    }
}

/// Summary of one finished run.
#[derive(Debug, Clone)]
pub struct RunSummary {
    /// SGD implementation name.
    pub flavor: String,
    /// Final evaluation of the averaged model.
    pub final_eval: EvalResult,
    /// Whether any loss went non-finite (the paper's unconvergence cases).
    pub diverged: bool,
    /// Total bytes sent per node over the run.
    pub bytes_per_node: u64,
    /// Mean gini over the first 10% of iterations (early stage).
    pub early_gini: f64,
    /// Mean gini over the last 10% of iterations (late stage).
    pub late_gini: f64,
}

/// The coordinator: drives one run of one SGD flavor.
pub struct Trainer<'m> {
    model: &'m mut dyn LocalModel,
    config: TrainConfig,
}

impl<'m> Trainer<'m> {
    /// New trainer over `model` with `config`.
    pub fn new(model: &'m mut dyn LocalModel, config: TrainConfig) -> Self {
        Trainer { model, config }
    }

    /// Train `flavor` on `dataset`, returning the iteration records and a
    /// summary. Deterministic for a given `(config.seed, flavor)`.
    pub fn run(
        &mut self,
        dataset: &dyn Dataset,
        flavor: &SgdFlavor,
    ) -> Result<(RunRecorder, RunSummary)> {
        self.run_inner(dataset, flavor, None, 0)
    }

    /// Resume a run from a [`crate::coordinator::Checkpoint`]: replicas
    /// are restored and training continues at the saved epoch with the
    /// saved seed (so data order, LR schedule position and topology
    /// schedule all line up with the original run).
    pub fn resume(
        &mut self,
        dataset: &dyn Dataset,
        flavor: &SgdFlavor,
        ckpt: crate::coordinator::Checkpoint,
    ) -> Result<(RunRecorder, RunSummary)> {
        if ckpt.flavor != flavor.name() {
            return Err(AdaError::Coordinator(format!(
                "checkpoint was taken under {} but resuming {}",
                ckpt.flavor,
                flavor.name()
            )));
        }
        self.config.seed = ckpt.seed;
        let epoch = ckpt.epoch;
        self.run_inner(dataset, flavor, Some(ckpt.replicas), epoch)
    }

    fn run_inner(
        &mut self,
        dataset: &dyn Dataset,
        flavor: &SgdFlavor,
        initial_replicas: Option<Vec<Vec<f32>>>,
        start_epoch: usize,
    ) -> Result<(RunRecorder, RunSummary)> {
        let cfg = self.config.clone();
        let n = cfg.n_workers;
        if n < 2 {
            return Err(AdaError::Coordinator("need at least 2 workers".into()));
        }
        let (train_idx, test_idx) = train_test_split(dataset.len(), cfg.test_frac);
        // Shard the *positions within train_idx*, then map back.
        let train_labels: Option<Vec<u32>> = dataset
            .labels()
            .map(|ls| train_idx.iter().map(|&i| ls[i]).collect());
        let shards = shard_indices(
            train_idx.len(),
            train_labels.as_deref(),
            n,
            cfg.shard,
            cfg.seed,
        )?;
        let loaders: Vec<ShardLoader> = shards
            .into_iter()
            .enumerate()
            .map(|(w, s)| {
                let mapped: Vec<usize> = s.into_iter().map(|p| train_idx[p]).collect();
                ShardLoader::new(mapped, self.model.batch_size(), w, cfg.seed)
            })
            .collect();
        let min_batches = loaders
            .iter()
            .map(ShardLoader::batches_per_epoch)
            .min()
            .unwrap_or(0);
        if min_batches == 0 {
            return Err(AdaError::Coordinator(
                "a worker received an empty shard; reduce workers".into(),
            ));
        }
        let iters_per_epoch = cfg
            .max_iters_per_epoch
            .map_or(min_batches, |m| m.min(min_batches));

        let mut schedule = flavor.schedule(n)?;
        let lr_schedule =
            cfg.lr
                .build(flavor, n, self.model.batch_size(), cfg.epochs as f64);
        let p = self.model.param_count();
        let layer_ranges = self.model.layer_ranges();
        let tracked: Vec<std::ops::Range<usize>> = cfg
            .track_layers
            .iter()
            .filter_map(|&l| layer_ranges.get(l).map(|&(a, b)| a..b))
            .collect();

        // Identical initial replicas (§2.2's setup), or restored state.
        let mut replicas: Vec<Vec<f32>> = match initial_replicas {
            Some(reps) => {
                if reps.len() != n || reps.iter().any(|r| r.len() != p) {
                    return Err(AdaError::Coordinator(format!(
                        "checkpoint shape ({} replicas) does not match run \
                         (n={n}, P={p})",
                        reps.len()
                    )));
                }
                reps
            }
            None => {
                let init = self.model.init_params(cfg.seed as i32)?;
                vec![init; n]
            }
        };
        let mut engine = GossipEngine::with_threads(cfg.threads);
        // Centralized path state: one shared momentum buffer.
        let mut central_momentum = SgdState::new(p, cfg.central_momentum, 0.0);
        // Fused-path state: per-worker momentum buffers owned by the
        // trainer (the fused kernel updates them tile-by-tile) and the
        // iteration's gradient stash. Velocity restarts at zero on
        // resume, matching the models' internal momentum buffers.
        // Models without a raw-gradient interface (the HLO bundles)
        // fall back to the default adapt-then-combine path.
        let fused = cfg.fused && self.model.supports_loss_and_grad();
        let mut fused_states: Vec<SgdState> = if fused {
            (0..n).map(|_| SgdState::new(p, cfg.fused_momentum, 0.0)).collect()
        } else {
            Vec::new()
        };
        let mut fused_grads: Vec<Vec<f32>> = if fused { vec![Vec::new(); n] } else { Vec::new() };
        // Failure-injection stream (deterministic under the run seed).
        let mut drop_rng = crate::util::rng::Rng::seed_from_u64(cfg.seed ^ 0xD209);

        let mut recorder = match &cfg.record_path {
            Some(path) => RunRecorder::to_file(flavor.name(), path)?,
            None => RunRecorder::in_memory(flavor.name()),
        };
        let mut diverged = false;
        let mut iteration = 0usize;

        'epochs: for epoch in start_epoch..cfg.epochs {
            let graph = match &schedule {
                Some(s) => Some(s.graph_for_epoch(epoch)?),
                None => None,
            };
            let mut epoch_gini_sum = 0.0f64;
            let mut epoch_gini_count = 0usize;
            for b in 0..iters_per_epoch {
                let frac_epoch = epoch as f64 + b as f64 / iters_per_epoch as f64;
                let lr = lr_schedule.lr_at(frac_epoch) as f32;
                // --- local steps -------------------------------------
                let mut loss_sum = 0.0f64;
                if graph.is_none() {
                    // C_complete: gradient averaging, shared momentum.
                    let mut grad_acc = vec![0.0f32; p];
                    for (w, loader) in loaders.iter().enumerate() {
                        let batch = dataset.batch(&loader.batch_indices(epoch, b));
                        let (loss, g) = self.model.loss_and_grad(&replicas[w], &batch)?;
                        loss_sum += loss as f64;
                        for (a, &gi) in grad_acc.iter_mut().zip(&g) {
                            *a += gi;
                        }
                    }
                    let inv = 1.0 / n as f32;
                    for a in grad_acc.iter_mut() {
                        *a *= inv;
                    }
                    central_momentum.step(&mut replicas[0], &grad_acc, lr);
                    let (head, tail) = replicas.split_at_mut(1);
                    for r in tail {
                        r.copy_from_slice(&head[0]);
                    }
                } else if fused {
                    // Combine-then-adapt: gradients at θ_t now, parameter
                    // and momentum updates fused into the gossip pass below.
                    for (w, loader) in loaders.iter().enumerate() {
                        let batch = dataset.batch(&loader.batch_indices(epoch, b));
                        let (loss, g) = self.model.loss_and_grad(&replicas[w], &batch)?;
                        loss_sum += loss as f64;
                        fused_grads[w] = g;
                    }
                } else {
                    for (w, loader) in loaders.iter().enumerate() {
                        let batch = dataset.batch(&loader.batch_indices(epoch, b));
                        let loss =
                            self.model.local_step(w, &mut replicas[w], &batch, lr)?;
                        loss_sum += loss as f64;
                    }
                }
                let train_loss = loss_sum / n as f64;
                if !train_loss.is_finite() {
                    diverged = true;
                }

                // --- pre-averaging metric capture (DBench §3.1.2) ----
                // Pooled: the per-replica norms and per-tensor slices
                // fan out over the gossip engine's persistent workers
                // (deterministic tiled reductions — bit-identical for
                // any thread count), so monitoring costs no more than
                // one parallel pass where it used to be serial O(n·P).
                let capture = cfg.metrics_every > 0 && iteration % cfg.metrics_every == 0;
                let (variance, per_tensor) = if capture {
                    let norms = per_replica_l2_norms_pooled(engine.exec(), &replicas, 0..p);
                    let report = VarianceReport::of(&norms);
                    let per_tensor: Vec<f64> = tracked
                        .iter()
                        .map(|range| {
                            let tn = per_replica_l2_norms_pooled(
                                engine.exec(),
                                &replicas,
                                range.clone(),
                            );
                            crate::metrics::gini_coefficient(&tn)
                        })
                        .collect();
                    (report, per_tensor)
                } else {
                    (VarianceReport::of(&[]), Vec::new())
                };
                if capture {
                    epoch_gini_sum += variance.gini;
                    epoch_gini_count += 1;
                }

                // --- averaging ---------------------------------------
                let (degree, bytes) = if let Some(g) = &graph {
                    if cfg.drop_prob > 0.0 {
                        let active: Vec<bool> =
                            (0..n).map(|_| !drop_rng.bool(cfg.drop_prob)).collect();
                        if fused {
                            // Fused dropout round: renormalized mixing
                            // and the momentum update in one pass — a
                            // straggler misses the exchange but still
                            // applies its local gradient.
                            engine.mix_active_step(
                                g,
                                &mut replicas,
                                &fused_grads,
                                &mut fused_states,
                                lr,
                                &active,
                            );
                        } else {
                            engine.mix_active(g, &mut replicas, &active);
                        }
                    } else if fused {
                        engine.mix_step(g, &mut replicas, &fused_grads, &mut fused_states, lr);
                    } else {
                        engine.mix(g, &mut replicas);
                    }
                    (g.degree(), g.bytes_sent_per_node(p))
                } else {
                    // Ring allreduce of gradients: 2(n−1)/n · 4P per node.
                    (n - 1, (2 * (n - 1) * 4 * p / n) as u64)
                };

                // --- eval + record -----------------------------------
                let eval_now = b + 1 == iters_per_epoch
                    && (cfg.eval_every_epochs != 0
                        && (epoch + 1) % cfg.eval_every_epochs == 0
                        || epoch + 1 == cfg.epochs);
                let test_metric = if eval_now {
                    Some(
                        self.evaluate(dataset, &test_idx, &replicas, engine.exec())?
                            .metric,
                    )
                } else {
                    None
                };
                recorder.push(IterationRecord {
                    iteration,
                    epoch,
                    train_loss,
                    test_metric,
                    variance,
                    per_tensor_gini: per_tensor,
                    graph_degree: degree,
                    bytes_per_node: bytes,
                    lr: lr as f64,
                })?;
                iteration += 1;
                if diverged {
                    break 'epochs;
                }
            }
            if let (Some(s), true) = (&mut schedule, epoch_gini_count > 0) {
                s.observe(epoch, epoch_gini_sum / epoch_gini_count as f64);
            }
        }
        recorder.flush()?;

        let final_eval = self.evaluate(dataset, &test_idx, &replicas, engine.exec())?;
        let total_iters = recorder.records().len();
        let decile = (total_iters / 10).max(1);
        let summary = RunSummary {
            flavor: flavor.name(),
            final_eval,
            diverged,
            bytes_per_node: recorder.total_bytes_per_node(),
            early_gini: recorder.mean_gini(0..decile),
            late_gini: recorder.mean_gini(total_iters.saturating_sub(decile)..total_iters),
        };
        Ok((recorder, summary))
    }

    /// Evaluate the replica-averaged model (§2.2: "the trained model
    /// takes θ as the average over all θ_i") on the test split. The
    /// mean model is built over the run's persistent worker pool
    /// ([`mean_model`]) — previously a serial O(n·P) pass.
    fn evaluate(
        &self,
        dataset: &dyn Dataset,
        test_idx: &[usize],
        replicas: &[Vec<f32>],
        exec: &ExecEngine,
    ) -> Result<EvalResult> {
        let mean = mean_model(exec, replicas);
        self.evaluate_params(dataset, test_idx, &mean)
    }

    /// Evaluate explicit parameters on the test split.
    pub fn evaluate_params(
        &self,
        dataset: &dyn Dataset,
        test_idx: &[usize],
        params: &[f32],
    ) -> Result<EvalResult> {
        let eb = self.model.eval_batch_size();
        let mut loss_sum = 0.0f64;
        let mut metric_sum = 0.0f64;
        let mut count = 0.0f64;
        for chunk in test_idx.chunks(eb) {
            if chunk.len() < eb {
                break; // fixed-shape executables: drop the remainder
            }
            let batch = dataset.batch(chunk);
            let (ls, ms) = self.model.eval_sums(params, &batch)?;
            loss_sum += ls as f64;
            metric_sum += ms as f64;
            count += match self.model.kind() {
                ModelKind::Classification => eb as f64,
                ModelKind::Lm => 0.0, // token count comes back in ms
            };
        }
        Ok(match self.model.kind() {
            ModelKind::Classification => EvalResult {
                loss: if count > 0.0 { loss_sum / count } else { f64::NAN },
                metric: if count > 0.0 { metric_sum / count } else { 0.0 },
            },
            ModelKind::Lm => {
                let tokens = metric_sum;
                let nll = if tokens > 0.0 { loss_sum / tokens } else { f64::NAN };
                EvalResult {
                    loss: nll,
                    metric: nll.exp(), // perplexity
                }
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::surrogate::SoftmaxRegression;
    use crate::data::SyntheticClassification;

    fn quick_config(n: usize, epochs: usize) -> TrainConfig {
        let mut c = TrainConfig::quick(n, epochs);
        // Fixed LR across flavors: unit tests isolate the *averaging*
        // mechanism from Table 2's per-graph LR scaling (which the
        // figure benches exercise instead).
        c.lr = LrPolicy::Fixed {
            schedule: LrSchedule::Constant { lr: 0.05 },
        };
        c.shard = ShardStrategy::LabelSkew { alpha: 0.1 };
        c.metrics_every = 1;
        c
    }

    fn run_flavor(flavor: SgdFlavor, n: usize) -> RunSummary {
        let data = SyntheticClassification::generate(1024, 8, 4, 3.0, 21);
        let mut model = SoftmaxRegression::new(8, 4, 16, 32, n, 0.9);
        let mut t = Trainer::new(&mut model, quick_config(n, 8));
        let (_, summary) = t.run(&data, &flavor).unwrap();
        summary
    }

    #[test]
    fn all_flavors_train_without_divergence() {
        for flavor in [
            SgdFlavor::CentralizedComplete,
            SgdFlavor::DecentralizedComplete,
            SgdFlavor::DecentralizedRing,
            SgdFlavor::DecentralizedTorus,
            SgdFlavor::DecentralizedExponential,
            SgdFlavor::Ada { k0: 7, gamma_k: 2.0 },
            SgdFlavor::OnePeer,
            SgdFlavor::VarianceAdaptive {
                k0: 7,
                step: 2,
                threshold: 0.01,
                patience: 1,
            },
        ] {
            let s = run_flavor(flavor.clone(), 8);
            assert!(!s.diverged, "{} diverged", s.flavor);
            assert!(
                s.final_eval.metric > 0.5,
                "{} should beat chance (0.25): {}",
                s.flavor,
                s.final_eval.metric
            );
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = run_flavor(SgdFlavor::DecentralizedRing, 8);
        let b = run_flavor(SgdFlavor::DecentralizedRing, 8);
        assert_eq!(a.final_eval.metric, b.final_eval.metric);
        assert_eq!(a.bytes_per_node, b.bytes_per_node);
    }

    #[test]
    fn ring_sends_fewer_bytes_than_complete() {
        let ring = run_flavor(SgdFlavor::DecentralizedRing, 8);
        let complete = run_flavor(SgdFlavor::DecentralizedComplete, 8);
        assert!(ring.bytes_per_node < complete.bytes_per_node / 3);
    }

    #[test]
    fn ada_bytes_between_ring_and_complete() {
        let ring = run_flavor(SgdFlavor::DecentralizedRing, 8);
        let complete = run_flavor(SgdFlavor::DecentralizedComplete, 8);
        let ada = run_flavor(SgdFlavor::Ada { k0: 7, gamma_k: 2.0 }, 8);
        assert!(ada.bytes_per_node < complete.bytes_per_node);
        assert!(ada.bytes_per_node > ring.bytes_per_node);
    }

    #[test]
    fn ring_has_higher_early_variance_than_complete() {
        // Observation 4's mechanism at miniature scale: once replicas
        // have diverged (iteration ≥ 1), the sparser graph leaves more
        // cross-replica variance standing before each averaging step.
        let run = |flavor: SgdFlavor| {
            let data = SyntheticClassification::generate(1024, 8, 4, 3.0, 21);
            let mut model = SoftmaxRegression::new(8, 4, 16, 32, 8, 0.9);
            let mut t = Trainer::new(&mut model, quick_config(8, 8));
            let (rec, _) = t.run(&data, &flavor).unwrap();
            let n = rec.records().len();
            assert!(n > 4, "need a few iterations, got {n}");
            rec.mean_gini(1..n)
        };
        let ring = run(SgdFlavor::DecentralizedRing);
        let complete = run(SgdFlavor::DecentralizedComplete);
        assert!(
            ring > complete,
            "ring {ring} vs complete {complete}"
        );
    }

    #[test]
    fn centralized_and_decentralized_complete_are_close() {
        // With parameter averaging over the complete graph and fresh
        // momentum, D_complete tracks C_complete closely (§2.1 notes
        // they differ only in *what* is averaged).
        let c = run_flavor(SgdFlavor::CentralizedComplete, 8);
        let d = run_flavor(SgdFlavor::DecentralizedComplete, 8);
        assert!(
            (c.final_eval.metric - d.final_eval.metric).abs() < 0.15,
            "C {} vs D {}",
            c.final_eval.metric,
            d.final_eval.metric
        );
    }

    #[test]
    fn momentum_free_c_and_d_complete_coincide() {
        // §2.1/§2.2: for plain SGD (no momentum), averaging parameters
        // after identical-start local steps (D_complete) is algebraically
        // identical to averaging gradients (C_complete). With momentum
        // they diverge (per-worker vs shared buffers) — which is exactly
        // why the paper distinguishes the two.
        let run = |flavor: SgdFlavor, momentum: f32| {
            let data = SyntheticClassification::generate(512, 8, 4, 3.0, 31);
            let mut model = SoftmaxRegression::new(8, 4, 16, 32, 6, momentum);
            let mut cfg = quick_config(6, 3);
            cfg.shard = ShardStrategy::Iid;
            cfg.central_momentum = momentum;
            let mut t = Trainer::new(&mut model, cfg);
            let (rec, _) = t.run(&data, &flavor).unwrap();
            rec.records().iter().map(|r| r.train_loss).collect::<Vec<_>>()
        };
        let c = run(SgdFlavor::CentralizedComplete, 0.0);
        let d = run(SgdFlavor::DecentralizedComplete, 0.0);
        assert_eq!(c.len(), d.len());
        for (i, (a, b)) in c.iter().zip(&d).enumerate() {
            assert!(
                (a - b).abs() < 1e-4 * a.abs().max(1.0),
                "iter {i}: C {a} vs D {b} must coincide without momentum"
            );
        }
    }

    #[test]
    fn sqrt_scaling_rescues_sparse_graphs_at_scale() {
        // Observation 3: at larger scales the conventional linear rule
        // under-serves the sparse graphs; sqrt scaling lifts D_ring.
        let run = |rule: ScalingRule| {
            let data = SyntheticClassification::generate(2048, 8, 4, 3.0, 33);
            let mut model = SoftmaxRegression::new(8, 4, 16, 32, 16, 0.9);
            let mut cfg = TrainConfig::quick(16, 6);
            cfg.lr = LrPolicy::Scaled {
                peak: 0.05,
                rule,
                divisor: 256.0,
                warmup: 1.0,
            };
            let mut t = Trainer::new(&mut model, cfg);
            let (_, s) = t.run(&data, &SgdFlavor::DecentralizedRing).unwrap();
            s.final_eval.metric
        };
        let linear = run(ScalingRule::Linear);
        let sqrt = run(ScalingRule::Sqrt);
        assert!(
            sqrt > linear,
            "sqrt scaling must beat linear for the ring at scale: {sqrt} vs {linear}"
        );
    }

    #[test]
    fn survives_worker_dropout() {
        // Failure injection: 20% of workers miss each gossip exchange.
        // Training must stay stable (no divergence) and still learn —
        // the production-stability property the paper's intro motivates.
        let data = SyntheticClassification::generate(1024, 8, 4, 3.0, 23);
        let mut model = SoftmaxRegression::new(8, 4, 16, 32, 8, 0.9);
        let mut cfg = quick_config(8, 8);
        cfg.drop_prob = 0.2;
        let mut t = Trainer::new(&mut model, cfg);
        let (_, s) = t.run(&data, &SgdFlavor::DecentralizedTorus).unwrap();
        assert!(!s.diverged);
        assert!(
            s.final_eval.metric > 0.5,
            "dropout run must still learn: {}",
            s.final_eval.metric
        );
        // Deterministic under seed even with injected failures.
        let mut model2 = SoftmaxRegression::new(8, 4, 16, 32, 8, 0.9);
        let mut cfg2 = quick_config(8, 8);
        cfg2.drop_prob = 0.2;
        let (_, s2) = Trainer::new(&mut model2, cfg2)
            .run(&data, &SgdFlavor::DecentralizedTorus)
            .unwrap();
        assert_eq!(s.final_eval.metric, s2.final_eval.metric);
    }

    #[test]
    fn fused_flavors_train_without_divergence() {
        // The fused gossip+SGD path (combine-then-adapt) must learn on
        // every decentralized flavor.
        for flavor in [
            SgdFlavor::DecentralizedComplete,
            SgdFlavor::DecentralizedRing,
            SgdFlavor::DecentralizedTorus,
            SgdFlavor::DecentralizedExponential,
            SgdFlavor::Ada { k0: 7, gamma_k: 2.0 },
        ] {
            let data = SyntheticClassification::generate(1024, 8, 4, 3.0, 21);
            let mut model = SoftmaxRegression::new(8, 4, 16, 32, 8, 0.9);
            let mut cfg = quick_config(8, 8);
            cfg.fused = true;
            let mut t = Trainer::new(&mut model, cfg);
            let (_, s) = t.run(&data, &flavor).unwrap();
            assert!(!s.diverged, "{} diverged (fused)", s.flavor);
            assert!(
                s.final_eval.metric > 0.5,
                "fused {} should beat chance (0.25): {}",
                s.flavor,
                s.final_eval.metric
            );
        }
    }

    #[test]
    fn fused_is_bit_identical_across_thread_counts() {
        // The headline determinism guarantee, end to end: a full fused
        // training run produces the same floats at 1, 2, 4 threads.
        let run = |threads: usize| {
            let data = SyntheticClassification::generate(1024, 8, 4, 3.0, 21);
            let mut model = SoftmaxRegression::new(8, 4, 16, 32, 8, 0.9);
            let mut cfg = quick_config(8, 4);
            cfg.fused = true;
            cfg.threads = threads;
            let mut t = Trainer::new(&mut model, cfg);
            let (rec, s) = t.run(&data, &SgdFlavor::DecentralizedRing).unwrap();
            (
                rec.records().iter().map(|r| r.train_loss).collect::<Vec<_>>(),
                s.final_eval.metric,
            )
        };
        let (l1, m1) = run(1);
        for threads in [2, 4] {
            let (lt, mt) = run(threads);
            assert_eq!(l1, lt, "loss series differs at {threads} threads");
            assert_eq!(m1, mt, "metric differs at {threads} threads");
        }
    }

    #[test]
    fn fused_flag_does_not_change_centralized_sgd() {
        // C_complete averages gradients globally; the fused gossip path
        // never engages, so the flag must be a no-op there.
        let run = |fused: bool| {
            let data = SyntheticClassification::generate(512, 8, 4, 3.0, 31);
            let mut model = SoftmaxRegression::new(8, 4, 16, 32, 6, 0.9);
            let mut cfg = quick_config(6, 3);
            cfg.fused = fused;
            let mut t = Trainer::new(&mut model, cfg);
            let (rec, _) = t.run(&data, &SgdFlavor::CentralizedComplete).unwrap();
            rec.records().iter().map(|r| r.train_loss).collect::<Vec<_>>()
        };
        assert_eq!(run(false), run(true));
    }

    #[test]
    fn fused_survives_worker_dropout() {
        // Fused mode under failure injection takes the unfused
        // mix_active fallback but keeps the same semantics: stable,
        // learning, deterministic.
        let data = SyntheticClassification::generate(1024, 8, 4, 3.0, 23);
        let run = || {
            let mut model = SoftmaxRegression::new(8, 4, 16, 32, 8, 0.9);
            let mut cfg = quick_config(8, 8);
            cfg.drop_prob = 0.2;
            cfg.fused = true;
            let mut t = Trainer::new(&mut model, cfg);
            t.run(&data, &SgdFlavor::DecentralizedTorus).unwrap().1
        };
        let s = run();
        assert!(!s.diverged);
        assert!(s.final_eval.metric > 0.5, "must still learn: {}", s.final_eval.metric);
        assert_eq!(s.final_eval.metric, run().final_eval.metric, "deterministic");
    }

    #[test]
    fn threaded_split_path_matches_serial_exactly() {
        // The non-fused path through the parallel engine is the same
        // floats as the serial engine, end to end.
        let run = |threads: usize| {
            let data = SyntheticClassification::generate(1024, 8, 4, 3.0, 21);
            let mut model = SoftmaxRegression::new(8, 4, 16, 32, 8, 0.9);
            let mut cfg = quick_config(8, 4);
            cfg.threads = threads;
            let mut t = Trainer::new(&mut model, cfg);
            let (_, s) = t.run(&data, &SgdFlavor::DecentralizedExponential).unwrap();
            s.final_eval.metric
        };
        assert_eq!(run(1), run(4));
    }

    #[test]
    fn rejects_single_worker() {
        let data = SyntheticClassification::generate(64, 4, 2, 3.0, 1);
        let mut model = SoftmaxRegression::new(4, 2, 8, 8, 1, 0.0);
        let mut t = Trainer::new(&mut model, quick_config(1, 1));
        assert!(t.run(&data, &SgdFlavor::DecentralizedRing).is_err());
    }

    #[test]
    fn records_have_monotone_iterations_and_lr() {
        let data = SyntheticClassification::generate(512, 8, 4, 3.0, 5);
        let mut model = SoftmaxRegression::new(8, 4, 16, 32, 9, 0.9);
        let mut t = Trainer::new(&mut model, quick_config(9, 3));
        let (rec, _) = t.run(&data, &SgdFlavor::DecentralizedTorus).unwrap();
        let records = rec.records();
        assert!(!records.is_empty());
        for (i, r) in records.iter().enumerate() {
            assert_eq!(r.iteration, i);
            assert!(r.lr > 0.0);
            assert_eq!(r.graph_degree, 4, "torus degree");
        }
        assert!(rec.final_test_metric().is_some(), "must eval at end");
    }
}
