//! Run observers: the open instrumentation layer of
//! [`crate::coordinator::TrainSession`].
//!
//! The old trainer hard-wired its monitoring — records pushed straight
//! into a [`RunRecorder`], checkpointing bolted on by callers between
//! runs. [`Observer`] turns every consumer of training progress into a
//! plug-in with three hooks: `on_iteration` (after each iteration's
//! record is finalized), `on_epoch` (after each epoch's last gossip
//! round), and `on_complete` (after the final evaluation). The session
//! invokes its own recorder through the same trait — it is simply the
//! first observer — followed by user observers in registration order.

use super::trainer::RunSummary;
use super::Checkpoint;
use crate::error::Result;
use crate::metrics::{IterationRecord, RunRecorder};
use crate::util::matrix::ReplicaMatrix;
use std::path::PathBuf;

/// End-of-epoch context handed to [`Observer::on_epoch`].
pub struct EpochInfo<'a> {
    /// The 0-based epoch that just finished.
    pub epoch: usize,
    /// Mean captured gini over the epoch (`None` when the variance
    /// probe was off this epoch) — the same signal the topology
    /// schedule's `observe` consumes.
    pub mean_gini: Option<f64>,
    /// Current replica parameters (post-averaging), as the run's flat
    /// replica store.
    pub replicas: &'a ReplicaMatrix,
    /// Run label (`C_complete`, `D_ring`, …).
    pub label: &'a str,
    /// Run seed (checkpoint observers persist it for exact resume).
    pub seed: u64,
}

/// A training-progress consumer. All hooks default to no-ops so
/// implementations opt into the events they need; any hook may fail the
/// run by returning an error (e.g. a full disk under a checkpointer).
pub trait Observer: Send {
    /// One training iteration finished and its record is final.
    fn on_iteration(&mut self, _rec: &IterationRecord, _replicas: &ReplicaMatrix) -> Result<()> {
        Ok(())
    }

    /// One epoch finished (after its last combine round).
    fn on_epoch(&mut self, _info: &EpochInfo<'_>) -> Result<()> {
        Ok(())
    }

    /// The run finished and was evaluated.
    fn on_complete(&mut self, _summary: &RunSummary, _replicas: &ReplicaMatrix) -> Result<()> {
        Ok(())
    }
}

/// The recorder *is* an observer: it appends each finalized record
/// (streaming to JSONL when file-backed) and flushes its sink when the
/// run completes. The session drives it through this impl, so custom
/// observers and the built-in recording share one code path.
impl Observer for RunRecorder {
    fn on_iteration(&mut self, rec: &IterationRecord, _replicas: &ReplicaMatrix) -> Result<()> {
        self.push(rec.clone())
    }

    fn on_complete(&mut self, _summary: &RunSummary, _replicas: &ReplicaMatrix) -> Result<()> {
        self.flush()
    }
}

/// Periodic checkpointing as an observer: after every `every_epochs`-th
/// epoch the full replica state is written to
/// `dir/<label>_epoch<NNNN>.ckpt`, resumable via
/// [`crate::coordinator::Trainer::resume`]. Epochs off the cadence
/// (including a final epoch not divisible by it) are not checkpointed —
/// pick `every_epochs = 1` to keep every epoch.
pub struct CheckpointObserver {
    dir: PathBuf,
    every_epochs: usize,
    /// Paths written so far, in order.
    written: Vec<PathBuf>,
}

impl CheckpointObserver {
    /// Checkpoint into `dir` every `every_epochs` epochs (`0` is
    /// treated as 1 — every epoch).
    pub fn new(dir: impl Into<PathBuf>, every_epochs: usize) -> Self {
        CheckpointObserver {
            dir: dir.into(),
            every_epochs: every_epochs.max(1),
            written: Vec::new(),
        }
    }

    /// Checkpoint files written so far, in epoch order.
    pub fn written(&self) -> &[PathBuf] {
        &self.written
    }
}

impl Observer for CheckpointObserver {
    fn on_epoch(&mut self, info: &EpochInfo<'_>) -> Result<()> {
        if (info.epoch + 1) % self.every_epochs != 0 {
            return Ok(());
        }
        let ckpt = Checkpoint {
            epoch: info.epoch + 1,
            flavor: info.label.to_string(),
            seed: info.seed,
            replicas: info.replicas.clone(),
        };
        let path = self
            .dir
            .join(format!("{}_epoch{:04}.ckpt", info.label, info.epoch + 1));
        ckpt.save(&path)?;
        self.written.push(path);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::VarianceReport;

    fn rec(iteration: usize) -> IterationRecord {
        IterationRecord {
            iteration,
            epoch: 0,
            train_loss: 1.0,
            test_metric: None,
            variance: VarianceReport::of(&[]),
            per_tensor_gini: Vec::new(),
            graph_degree: 2,
            bytes_per_node: 8,
            lr: 0.1,
        }
    }

    #[test]
    fn recorder_observer_accumulates_records() {
        let mut r = RunRecorder::in_memory("D_ring");
        let replicas = ReplicaMatrix::zeros(2, 4);
        Observer::on_iteration(&mut r, &rec(0), &replicas).unwrap();
        Observer::on_iteration(&mut r, &rec(1), &replicas).unwrap();
        assert_eq!(r.records().len(), 2);
        assert_eq!(r.records()[1].iteration, 1);
    }

    #[test]
    fn checkpoint_observer_writes_on_cadence() {
        let dir = crate::util::scratch_dir("ckpt_obs").unwrap();
        let mut obs = CheckpointObserver::new(&dir, 2);
        let replicas = ReplicaMatrix::broadcast(3, &[1.0f32; 8]);
        for epoch in 0..4 {
            obs.on_epoch(&EpochInfo {
                epoch,
                mean_gini: None,
                replicas: &replicas,
                label: "D_torus",
                seed: 7,
            })
            .unwrap();
        }
        assert_eq!(obs.written().len(), 2, "epochs 2 and 4");
        let back = Checkpoint::load(&obs.written()[1]).unwrap();
        assert_eq!(back.epoch, 4);
        assert_eq!(back.flavor, "D_torus");
        assert_eq!(back.seed, 7);
        assert_eq!(back.replicas, replicas);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
