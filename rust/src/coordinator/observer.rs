//! Run observers: the open instrumentation **and control** layer of
//! [`crate::coordinator::TrainSession`].
//!
//! The old trainer hard-wired its monitoring — records pushed straight
//! into a [`RunRecorder`], checkpointing bolted on by callers between
//! runs. [`Observer`] turns every consumer of training progress into a
//! plug-in with three hooks: `on_iteration` (after each iteration's
//! record is finalized), `on_epoch` (after each epoch's last gossip
//! round), and `on_complete` (after the final evaluation). The session
//! invokes its own recorder through the same trait — it is simply the
//! first observer — followed by user observers in registration order.
//!
//! The channel is **bidirectional**: `on_iteration` and `on_epoch`
//! return a [`ControlFlow`], and the session honors [`ControlFlow::Stop`]
//! by ending the run early — final evaluation and `on_complete` still
//! run, so an early-stopped run produces a complete summary. The
//! built-in stoppers are [`TargetAccuracyStop`] (halt once the
//! evaluated metric reaches a target) and [`DivergenceStreakStop`]
//! (halt after a streak of worsening training losses).

use super::trainer::RunSummary;
use super::Checkpoint;
use crate::error::Result;
use crate::metrics::{IterationRecord, RunRecorder};
use crate::util::json::Value;
use crate::util::matrix::ReplicaMatrix;
use std::path::PathBuf;
use std::sync::mpsc::Sender;

/// What an observer asks the session to do next. Hooks combine across
/// observers with [`ControlFlow::merge`]: any `Stop` wins.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ControlFlow {
    /// Keep training (the default).
    #[default]
    Continue,
    /// End the run after this hook: skip the remaining iterations and
    /// epochs, then evaluate and fire `on_complete` as usual.
    Stop,
}

impl ControlFlow {
    /// Combine two verdicts: `Stop` dominates.
    pub fn merge(self, other: ControlFlow) -> ControlFlow {
        if self == ControlFlow::Stop || other == ControlFlow::Stop {
            ControlFlow::Stop
        } else {
            ControlFlow::Continue
        }
    }

    /// Whether this verdict ends the run.
    pub fn is_stop(&self) -> bool {
        *self == ControlFlow::Stop
    }
}

/// End-of-epoch context handed to [`Observer::on_epoch`].
pub struct EpochInfo<'a> {
    /// The 0-based epoch that just finished.
    pub epoch: usize,
    /// Mean captured gini over the epoch (`None` when the variance
    /// probe was off this epoch) — the same signal the topology
    /// policy's `observe` consumes.
    pub mean_gini: Option<f64>,
    /// Current replica parameters (post-averaging), as the run's flat
    /// replica store.
    pub replicas: &'a ReplicaMatrix,
    /// Run label (`C_complete`, `D_ring`, …).
    pub label: &'a str,
    /// Run seed (checkpoint observers persist it for exact resume).
    pub seed: u64,
}

/// A training-progress consumer (and, through [`ControlFlow`], a run
/// controller). All hooks default to no-ops so implementations opt into
/// the events they need; any hook may fail the run by returning an
/// error (e.g. a full disk under a checkpointer).
pub trait Observer: Send {
    /// One training iteration finished and its record is final. Return
    /// [`ControlFlow::Stop`] to end the run after this iteration.
    fn on_iteration(
        &mut self,
        _rec: &IterationRecord,
        _replicas: &ReplicaMatrix,
    ) -> Result<ControlFlow> {
        Ok(ControlFlow::Continue)
    }

    /// One epoch finished (after its last combine round). Return
    /// [`ControlFlow::Stop`] to end the run after this epoch.
    fn on_epoch(&mut self, _info: &EpochInfo<'_>) -> Result<ControlFlow> {
        Ok(ControlFlow::Continue)
    }

    /// The run finished (normally or by an early stop) and was
    /// evaluated.
    fn on_complete(&mut self, _summary: &RunSummary, _replicas: &ReplicaMatrix) -> Result<()> {
        Ok(())
    }
}

/// The recorder *is* an observer: it appends each finalized record
/// (streaming to JSONL when file-backed) and flushes its sink when the
/// run completes. The session drives it through this impl, so custom
/// observers and the built-in recording share one code path.
impl Observer for RunRecorder {
    fn on_iteration(
        &mut self,
        rec: &IterationRecord,
        _replicas: &ReplicaMatrix,
    ) -> Result<ControlFlow> {
        self.push(rec.clone())?;
        Ok(ControlFlow::Continue)
    }

    fn on_complete(&mut self, _summary: &RunSummary, _replicas: &ReplicaMatrix) -> Result<()> {
        self.flush()
    }
}

/// An **owned** training event. The observer hooks borrow run state
/// ([`EpochInfo`] holds the live replica matrix), so they cannot leave
/// the training thread; `TrainEvent` copies the scalar context out into
/// a value that can cross a channel — the shape behind
/// [`ChannelObserver`] and the serve layer's JSONL metric streams.
#[derive(Debug, Clone)]
pub enum TrainEvent {
    /// One iteration finished with this finalized record.
    Iteration(IterationRecord),
    /// One epoch finished.
    Epoch {
        /// The 0-based epoch that just finished.
        epoch: usize,
        /// Mean captured gini over the epoch (`None` = probe off).
        mean_gini: Option<f64>,
        /// Run label (`C_complete`, `D_ring`, …).
        label: String,
        /// Run seed.
        seed: u64,
    },
    /// The run finished (normally or by an early stop) and was
    /// evaluated.
    Complete(RunSummary),
}

impl TrainEvent {
    /// Capture an epoch hook's context by value.
    pub fn from_epoch(info: &EpochInfo<'_>) -> Self {
        TrainEvent::Epoch {
            epoch: info.epoch,
            mean_gini: info.mean_gini,
            label: info.label.to_string(),
            seed: info.seed,
        }
    }

    /// JSON encoding with a `type` discriminant — one line of the serve
    /// layer's JSONL stream. `Iteration` nests the full
    /// [`IterationRecord::to_json`] under `record` so stream consumers
    /// can parse it back with [`IterationRecord::from_json`].
    pub fn to_json(&self) -> Value {
        match self {
            TrainEvent::Iteration(rec) => Value::obj(vec![
                ("type", Value::Str("iteration".into())),
                ("record", rec.to_json()),
            ]),
            TrainEvent::Epoch { epoch, mean_gini, label, seed } => Value::obj(vec![
                ("type", Value::Str("epoch".into())),
                ("epoch", Value::Num(*epoch as f64)),
                (
                    "mean_gini",
                    match mean_gini {
                        Some(g) => Value::Num(*g),
                        None => Value::Null,
                    },
                ),
                ("label", Value::Str(label.clone())),
                ("seed", Value::Num(*seed as f64)),
            ]),
            TrainEvent::Complete(summary) => Value::obj(vec![
                ("type", Value::Str("complete".into())),
                ("summary", summary.to_json()),
            ]),
        }
    }
}

/// Forward every hook as an owned [`TrainEvent`] through an mpsc
/// channel: the training loop stays synchronous while any other thread
/// (a JSONL streamer, a progress UI) consumes events at its own pace.
/// A dropped receiver is **not** a training error — events are simply
/// discarded, so an abandoned stream never kills the run it watched.
pub struct ChannelObserver {
    tx: Sender<TrainEvent>,
}

impl ChannelObserver {
    /// Forward events into `tx`.
    pub fn new(tx: Sender<TrainEvent>) -> Self {
        ChannelObserver { tx }
    }
}

impl Observer for ChannelObserver {
    fn on_iteration(
        &mut self,
        rec: &IterationRecord,
        _replicas: &ReplicaMatrix,
    ) -> Result<ControlFlow> {
        let _ = self.tx.send(TrainEvent::Iteration(rec.clone()));
        Ok(ControlFlow::Continue)
    }

    fn on_epoch(&mut self, info: &EpochInfo<'_>) -> Result<ControlFlow> {
        let _ = self.tx.send(TrainEvent::from_epoch(info));
        Ok(ControlFlow::Continue)
    }

    fn on_complete(&mut self, summary: &RunSummary, _replicas: &ReplicaMatrix) -> Result<()> {
        let _ = self.tx.send(TrainEvent::Complete(summary.clone()));
        Ok(())
    }
}

/// Periodic checkpointing as an observer: after every `every_epochs`-th
/// epoch the full replica state is written to
/// `dir/<label>_epoch<NNNN>.ckpt`, resumable via
/// [`crate::coordinator::Trainer::resume`]. Epochs off the cadence
/// (including a final epoch not divisible by it) are not checkpointed —
/// pick `every_epochs = 1` to keep every epoch.
pub struct CheckpointObserver {
    dir: PathBuf,
    every_epochs: usize,
    /// Paths written so far, in order.
    written: Vec<PathBuf>,
}

impl CheckpointObserver {
    /// Checkpoint into `dir` every `every_epochs` epochs (`0` is
    /// treated as 1 — every epoch).
    pub fn new(dir: impl Into<PathBuf>, every_epochs: usize) -> Self {
        CheckpointObserver {
            dir: dir.into(),
            every_epochs: every_epochs.max(1),
            written: Vec::new(),
        }
    }

    /// Checkpoint files written so far, in epoch order.
    pub fn written(&self) -> &[PathBuf] {
        &self.written
    }
}

impl Observer for CheckpointObserver {
    fn on_epoch(&mut self, info: &EpochInfo<'_>) -> Result<ControlFlow> {
        if (info.epoch + 1) % self.every_epochs != 0 {
            return Ok(ControlFlow::Continue);
        }
        let ckpt = Checkpoint {
            epoch: info.epoch + 1,
            flavor: info.label.to_string(),
            seed: info.seed,
            replicas: info.replicas.clone(),
        };
        let path = self
            .dir
            .join(format!("{}_epoch{:04}.ckpt", info.label, info.epoch + 1));
        ckpt.save(&path)?;
        self.written.push(path);
        Ok(ControlFlow::Continue)
    }
}

/// Early stopping on a target evaluation metric: stop as soon as an
/// evaluated iteration reports `test_metric ≥ target` — the
/// "train to X% accuracy, then stop paying for communication" scenario
/// (classification metrics, where higher is better).
pub struct TargetAccuracyStop {
    target: f64,
    stopped_at: Option<usize>,
}

impl TargetAccuracyStop {
    /// Stop once an evaluation reaches `target`.
    pub fn new(target: f64) -> Self {
        TargetAccuracyStop { target, stopped_at: None }
    }

    /// The iteration the target was reached at, once stopped.
    pub fn stopped_at(&self) -> Option<usize> {
        self.stopped_at
    }
}

impl Observer for TargetAccuracyStop {
    fn on_iteration(
        &mut self,
        rec: &IterationRecord,
        _replicas: &ReplicaMatrix,
    ) -> Result<ControlFlow> {
        if let Some(metric) = rec.test_metric {
            if metric >= self.target {
                self.stopped_at.get_or_insert(rec.iteration);
                return Ok(ControlFlow::Stop);
            }
        }
        Ok(ControlFlow::Continue)
    }
}

/// Early stopping on a divergence streak: stop after `streak`
/// consecutive iterations whose training loss worsened (or immediately
/// on a non-finite loss) — cheaper than waiting for the session's
/// NaN-divergence break when a run is clearly running away.
pub struct DivergenceStreakStop {
    streak: usize,
    prev_loss: Option<f64>,
    run_length: usize,
    stopped_at: Option<usize>,
}

impl DivergenceStreakStop {
    /// Stop after `streak` consecutive worsening iterations (`0` is
    /// treated as 1).
    pub fn new(streak: usize) -> Self {
        DivergenceStreakStop {
            streak: streak.max(1),
            prev_loss: None,
            run_length: 0,
            stopped_at: None,
        }
    }

    /// The iteration the streak completed at, once stopped.
    pub fn stopped_at(&self) -> Option<usize> {
        self.stopped_at
    }
}

impl Observer for DivergenceStreakStop {
    fn on_iteration(
        &mut self,
        rec: &IterationRecord,
        _replicas: &ReplicaMatrix,
    ) -> Result<ControlFlow> {
        if !rec.train_loss.is_finite() {
            self.stopped_at.get_or_insert(rec.iteration);
            return Ok(ControlFlow::Stop);
        }
        if let Some(prev) = self.prev_loss {
            if rec.train_loss > prev {
                self.run_length += 1;
            } else {
                self.run_length = 0;
            }
        }
        self.prev_loss = Some(rec.train_loss);
        if self.run_length >= self.streak {
            self.stopped_at.get_or_insert(rec.iteration);
            return Ok(ControlFlow::Stop);
        }
        Ok(ControlFlow::Continue)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::VarianceReport;

    fn rec(iteration: usize) -> IterationRecord {
        IterationRecord {
            iteration,
            epoch: 0,
            train_loss: 1.0,
            test_metric: None,
            variance: VarianceReport::of(&[]),
            per_tensor_gini: Vec::new(),
            graph_degree: 2,
            bytes_per_node: 8,
            lr: 0.1,
        }
    }

    #[test]
    fn control_flow_merges_toward_stop() {
        use ControlFlow::{Continue, Stop};
        assert_eq!(Continue.merge(Continue), Continue);
        assert_eq!(Continue.merge(Stop), Stop);
        assert_eq!(Stop.merge(Continue), Stop);
        assert!(Stop.is_stop() && !Continue.is_stop());
        assert_eq!(ControlFlow::default(), Continue);
    }

    #[test]
    fn recorder_observer_accumulates_records() {
        let mut r = RunRecorder::in_memory("D_ring");
        let replicas = ReplicaMatrix::zeros(2, 4);
        assert_eq!(
            Observer::on_iteration(&mut r, &rec(0), &replicas).unwrap(),
            ControlFlow::Continue
        );
        Observer::on_iteration(&mut r, &rec(1), &replicas).unwrap();
        assert_eq!(r.records().len(), 2);
        assert_eq!(r.records()[1].iteration, 1);
    }

    #[test]
    fn checkpoint_observer_writes_on_cadence() {
        let dir = crate::util::scratch_dir("ckpt_obs").unwrap();
        let mut obs = CheckpointObserver::new(&dir, 2);
        let replicas = ReplicaMatrix::broadcast(3, &[1.0f32; 8]);
        for epoch in 0..4 {
            let flow = obs
                .on_epoch(&EpochInfo {
                    epoch,
                    mean_gini: None,
                    replicas: &replicas,
                    label: "D_torus",
                    seed: 7,
                })
                .unwrap();
            assert_eq!(flow, ControlFlow::Continue);
        }
        assert_eq!(obs.written().len(), 2, "epochs 2 and 4");
        let back = Checkpoint::load(&obs.written()[1]).unwrap();
        assert_eq!(back.epoch, 4);
        assert_eq!(back.flavor, "D_torus");
        assert_eq!(back.seed, 7);
        assert_eq!(back.replicas, replicas);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn target_accuracy_stops_only_on_evaluated_iterations() {
        let mut obs = TargetAccuracyStop::new(0.9);
        let replicas = ReplicaMatrix::zeros(2, 4);
        let mut r = rec(0);
        assert!(!obs.on_iteration(&r, &replicas).unwrap().is_stop(), "no eval yet");
        r.iteration = 1;
        r.test_metric = Some(0.5);
        assert!(!obs.on_iteration(&r, &replicas).unwrap().is_stop(), "below target");
        r.iteration = 2;
        r.test_metric = Some(0.95);
        assert!(obs.on_iteration(&r, &replicas).unwrap().is_stop());
        assert_eq!(obs.stopped_at(), Some(2));
    }

    #[test]
    fn divergence_streak_counts_consecutive_worsening() {
        let mut obs = DivergenceStreakStop::new(2);
        let replicas = ReplicaMatrix::zeros(2, 4);
        let losses = [1.0, 0.9, 1.1, 0.8, 0.9, 1.0];
        let mut stopped = None;
        for (i, &l) in losses.iter().enumerate() {
            let mut r = rec(i);
            r.train_loss = l;
            if obs.on_iteration(&r, &replicas).unwrap().is_stop() {
                stopped = Some(i);
                break;
            }
        }
        // 0.9→1.1 is one rise (reset by 0.8); 0.8→0.9→1.0 completes the
        // streak of two at index 5.
        assert_eq!(stopped, Some(5));
        assert_eq!(obs.stopped_at(), Some(5));
    }

    #[test]
    fn channel_observer_ships_owned_events_across_threads() {
        let (tx, rx) = std::sync::mpsc::channel();
        let mut obs = ChannelObserver::new(tx);
        let replicas = ReplicaMatrix::zeros(2, 4);
        obs.on_iteration(&rec(3), &replicas).unwrap();
        obs.on_epoch(&EpochInfo {
            epoch: 1,
            mean_gini: Some(0.25),
            replicas: &replicas,
            label: "D_ring",
            seed: 7,
        })
        .unwrap();
        // Receive on another thread: the events are fully owned.
        let events: Vec<TrainEvent> =
            std::thread::spawn(move || rx.iter().take(2).collect()).join().unwrap();
        match &events[0] {
            TrainEvent::Iteration(r) => assert_eq!(r.iteration, 3),
            other => panic!("expected iteration, got {other:?}"),
        }
        match &events[1] {
            TrainEvent::Epoch { epoch, mean_gini, label, seed } => {
                assert_eq!(*epoch, 1);
                assert_eq!(*mean_gini, Some(0.25));
                assert_eq!(label, "D_ring");
                assert_eq!(*seed, 7);
            }
            other => panic!("expected epoch, got {other:?}"),
        }
        // JSON lines carry the type discriminant, and iteration payloads
        // parse back into records.
        let line = events[0].to_json();
        assert_eq!(line.str_field("type").unwrap(), "iteration");
        let back = IterationRecord::from_json(line.get("record").unwrap()).unwrap();
        assert_eq!(back.iteration, 3);
        assert_eq!(events[1].to_json().str_field("type").unwrap(), "epoch");
    }

    #[test]
    fn channel_observer_survives_a_dropped_receiver() {
        let (tx, rx) = std::sync::mpsc::channel();
        drop(rx);
        let mut obs = ChannelObserver::new(tx);
        let replicas = ReplicaMatrix::zeros(2, 4);
        // An abandoned consumer must not fail (or stop) the run.
        assert!(!obs.on_iteration(&rec(0), &replicas).unwrap().is_stop());
        obs.on_complete(
            &RunSummary {
                flavor: "D_ring".into(),
                final_eval: crate::coordinator::EvalResult { loss: 1.0, metric: 0.5 },
                diverged: false,
                bytes_per_node: 8,
                early_gini: 0.0,
                late_gini: 0.0,
            },
            &replicas,
        )
        .unwrap();
    }

    #[test]
    fn divergence_streak_stops_immediately_on_nan() {
        let mut obs = DivergenceStreakStop::new(10);
        let replicas = ReplicaMatrix::zeros(2, 4);
        let mut r = rec(0);
        r.train_loss = f64::NAN;
        assert!(obs.on_iteration(&r, &replicas).unwrap().is_stop());
        assert_eq!(obs.stopped_at(), Some(0));
    }
}
