//! Checkpointing: persist and restore the full replica state of a
//! decentralized run (every worker's flat parameter vector plus the
//! training position), so long runs survive preemption — table stakes
//! for the production use the paper targets.
//!
//! Format: one JSON header line (versioned, self-describing), then the
//! replicas as raw little-endian f32, worker-major. A 12M-param × 64
//! worker checkpoint is ~3 GB, so the format is written streaming and
//! read with exact preallocation. The in-memory state is the flat
//! [`ReplicaMatrix`]; only the `p` live floats of each row hit the file
//! — the store's alignment padding is a memory-layout detail, never a
//! wire-format one, so checkpoints stay byte-compatible with the
//! pre-refactor `Vec<Vec<f32>>` writer.

use crate::error::{AdaError, Result};
use crate::util::json::Value;
use crate::util::matrix::ReplicaMatrix;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

const MAGIC: &str = "ada-checkpoint";
const VERSION: f64 = 1.0;

/// A restorable training state.
#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    /// Epoch to resume *from* (the next epoch to run).
    pub epoch: usize,
    /// SGD flavor name the run used (sanity-checked on resume).
    pub flavor: String,
    /// Run seed (resume must keep it for deterministic data order).
    pub seed: u64,
    /// The full replica state (equal parameter counts are structural).
    pub replicas: ReplicaMatrix,
}

impl Checkpoint {
    /// Write to `path` (parent directories created).
    pub fn save(&self, path: &Path) -> Result<()> {
        if self.replicas.is_empty() {
            return Err(AdaError::Coordinator("cannot checkpoint 0 replicas".into()));
        }
        let p = self.replicas.p();
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut w = BufWriter::new(std::fs::File::create(path)?);
        let header = Value::obj(vec![
            ("magic", Value::Str(MAGIC.into())),
            ("version", Value::Num(VERSION)),
            ("epoch", Value::Num(self.epoch as f64)),
            ("flavor", Value::Str(self.flavor.clone())),
            ("seed", Value::Num(self.seed as f64)),
            ("n_workers", Value::Num(self.replicas.n() as f64)),
            ("param_count", Value::Num(p as f64)),
        ]);
        writeln!(w, "{}", header.to_string())?;
        for r in self.replicas.rows() {
            // Bulk little-endian write, one replica row at a time (live
            // elements only — stride padding never reaches the file).
            let mut bytes = Vec::with_capacity(r.len() * 4);
            for &v in r {
                bytes.extend_from_slice(&v.to_le_bytes());
            }
            w.write_all(&bytes)?;
        }
        w.flush()?;
        Ok(())
    }

    /// Read back from `path`.
    pub fn load(path: &Path) -> Result<Checkpoint> {
        let mut r = BufReader::new(std::fs::File::open(path)?);
        // Header: up to the first newline.
        let mut header_bytes = Vec::new();
        loop {
            let mut b = [0u8; 1];
            r.read_exact(&mut b)
                .map_err(|_| AdaError::Coordinator("truncated checkpoint header".into()))?;
            if b[0] == b'\n' {
                break;
            }
            header_bytes.push(b[0]);
            if header_bytes.len() > 4096 {
                return Err(AdaError::Coordinator("oversized checkpoint header".into()));
            }
        }
        let header = Value::parse(
            std::str::from_utf8(&header_bytes)
                .map_err(|_| AdaError::Coordinator("non-utf8 checkpoint header".into()))?,
        )?;
        if header.str_field("magic")? != MAGIC {
            return Err(AdaError::Coordinator("not an ada checkpoint".into()));
        }
        if header.num_field("version")? > VERSION {
            return Err(AdaError::Coordinator(format!(
                "checkpoint version {} is newer than supported {VERSION}",
                header.num_field("version")?
            )));
        }
        let n = header.usize_field("n_workers")?;
        let p = header.usize_field("param_count")?;
        let mut replicas = ReplicaMatrix::zeros(n, p);
        let mut buf = vec![0u8; p * 4];
        for i in 0..n {
            r.read_exact(&mut buf).map_err(|_| {
                AdaError::Coordinator(format!("truncated checkpoint at replica {i}"))
            })?;
            for (dst, chunk) in replicas.row_mut(i).iter_mut().zip(buf.chunks_exact(4)) {
                *dst = f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
            }
        }
        Ok(Checkpoint {
            epoch: header.usize_field("epoch")?,
            flavor: header.str_field("flavor")?.to_string(),
            seed: header.num_field("seed")? as u64,
            replicas,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::scratch_dir;

    fn sample(n: usize, p: usize) -> Checkpoint {
        let mut rng = crate::util::rng::Rng::seed_from_u64(3);
        let rows: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..p).map(|_| rng.range_f32(-2.0, 2.0)).collect())
            .collect();
        Checkpoint {
            epoch: 7,
            flavor: "D_adaptive".into(),
            seed: 42,
            replicas: crate::util::matrix::ReplicaMatrix::from_rows(&rows),
        }
    }

    #[test]
    fn roundtrip_is_bit_exact() {
        let dir = scratch_dir("ckpt").unwrap();
        let path = dir.join("run.ckpt");
        let ck = sample(6, 1234);
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(ck, back);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn stride_padding_never_reaches_the_file() {
        // 1234 live floats pad to a 1248-float stride in memory; the
        // file must hold exactly header + n·p·4 bytes, byte-compatible
        // with the pre-refactor row-vector writer.
        let dir = scratch_dir("ckpt_pad").unwrap();
        let path = dir.join("run.ckpt");
        let (n, p) = (6usize, 1234usize);
        let ck = sample(n, p);
        assert!(ck.replicas.stride() > p, "test needs a padded stride");
        ck.save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        let header_len = bytes.iter().position(|&b| b == b'\n').unwrap() + 1;
        assert_eq!(bytes.len() - header_len, n * p * 4, "payload is live floats only");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn special_float_values_survive() {
        let dir = scratch_dir("ckpt2").unwrap();
        let path = dir.join("run.ckpt");
        let mut ck = sample(2, 8);
        ck.replicas[0][0] = f32::MIN_POSITIVE;
        ck.replicas[0][1] = -0.0;
        ck.replicas[1][7] = f32::MAX;
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(ck.replicas, back.replicas);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn rejects_garbage_and_truncation() {
        let dir = scratch_dir("ckpt3").unwrap();
        let bad = dir.join("bad.ckpt");
        std::fs::write(&bad, b"{\"magic\":\"nope\"}\n").unwrap();
        assert!(Checkpoint::load(&bad).is_err());

        let path = dir.join("trunc.ckpt");
        sample(4, 100).save(&path).unwrap();
        let full = std::fs::read(&path).unwrap();
        std::fs::write(&path, &full[..full.len() - 10]).unwrap();
        let err = Checkpoint::load(&path).unwrap_err();
        assert!(err.to_string().contains("truncated"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn trainer_resume_roundtrip() {
        use crate::coordinator::surrogate::SoftmaxRegression;
        use crate::coordinator::{SgdFlavor, TrainConfig, Trainer};
        use crate::data::SyntheticClassification;
        // Train 3 epochs; checkpoint after; resume for 3 more; the
        // resumed run must not diverge and must keep learning.
        let data = SyntheticClassification::generate(512, 8, 4, 3.0, 77);
        let flavor = SgdFlavor::DecentralizedTorus;
        let mut cfg = TrainConfig::quick(4, 3);
        cfg.max_iters_per_epoch = Some(5);
        let mut model = SoftmaxRegression::new(8, 4, 16, 32, 4, 0.9);
        let mut trainer = Trainer::new(&mut model, cfg.clone());
        let (_, s1) = trainer.run(&data, &flavor).unwrap();

        // Re-run the first 3 epochs to regenerate the replica state via
        // a recorded checkpoint (surrogates expose no replica handle, so
        // we reconstruct by resuming a fresh trainer from the saved
        // epoch with a synthetic checkpoint built from a fresh run that
        // records its final state through `resume`'s validation).
        let dir = scratch_dir("ckpt_resume").unwrap();
        let path = dir.join("t.ckpt");
        let ck = Checkpoint {
            epoch: 3,
            flavor: flavor.name(),
            seed: cfg.seed,
            replicas: crate::util::matrix::ReplicaMatrix::broadcast(
                4,
                &model_params(&data, 4, &cfg, &flavor),
            ),
        };
        ck.save(&path).unwrap();
        let loaded = Checkpoint::load(&path).unwrap();

        let mut cfg6 = cfg.clone();
        cfg6.epochs = 6;
        let mut model2 = SoftmaxRegression::new(8, 4, 16, 32, 4, 0.9);
        let mut trainer2 = Trainer::new(&mut model2, cfg6);
        let (rec, s2) = trainer2.resume(&data, &flavor, loaded).unwrap();
        assert!(!s2.diverged);
        assert!(
            rec.records().first().map(|r| r.epoch) == Some(3),
            "resume must start at the checkpoint epoch"
        );
        assert!(
            s2.final_eval.metric >= s1.final_eval.metric - 0.1,
            "resumed run must not regress: {} vs {}",
            s2.final_eval.metric,
            s1.final_eval.metric
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Mean replica parameters after a fresh 3-epoch run (stand-in for
    /// a live handle on the replica state).
    fn model_params(
        data: &crate::data::SyntheticClassification,
        n: usize,
        cfg: &crate::coordinator::TrainConfig,
        flavor: &crate::coordinator::SgdFlavor,
    ) -> Vec<f32> {
        use crate::coordinator::surrogate::SoftmaxRegression;
        use crate::coordinator::{LocalModel, Trainer};
        let mut model = SoftmaxRegression::new(8, 4, 16, 32, n, 0.9);
        let mut t = Trainer::new(&mut model, cfg.clone());
        let _ = t.run(data, flavor).unwrap();
        // The trainer does not expose replicas; use a fresh init as the
        // checkpointed state for the format/flow test.
        model.init_params(1).unwrap()
    }

    #[test]
    fn resume_rejects_flavor_mismatch() {
        use crate::coordinator::surrogate::SoftmaxRegression;
        use crate::coordinator::{SgdFlavor, TrainConfig, Trainer};
        use crate::data::SyntheticClassification;
        let data = SyntheticClassification::generate(128, 8, 4, 3.0, 1);
        let mut model = SoftmaxRegression::new(8, 4, 16, 32, 4, 0.9);
        let mut trainer = Trainer::new(&mut model, TrainConfig::quick(4, 2));
        let ck = Checkpoint {
            epoch: 1,
            flavor: "D_ring".into(),
            seed: 42,
            replicas: crate::util::matrix::ReplicaMatrix::zeros(4, 42),
        };
        assert!(trainer
            .resume(&data, &SgdFlavor::DecentralizedTorus, ck)
            .is_err());
    }

    #[test]
    fn rejects_empty_checkpoint() {
        // Raggedness is structurally impossible in the flat store; the
        // remaining invalid shape is the empty one.
        let dir = scratch_dir("ckpt4").unwrap();
        let ck = Checkpoint {
            epoch: 0,
            flavor: "D_ring".into(),
            seed: 1,
            replicas: crate::util::matrix::ReplicaMatrix::zeros(0, 0),
        };
        assert!(ck.save(&dir.join("x.ckpt")).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
