//! The decentralized combine strategies: split (adapt-then-combine) and
//! fused (combine-then-adapt) gossip.

use super::{CombineStrategy, StepCtx};
use crate::error::{AdaError, Result};
use crate::graph::CommGraph;
use crate::optim::SgdState;
use crate::util::matrix::ReplicaMatrix;

fn need_graph<'a>(ctx: &StepCtx<'a>, name: &str) -> Result<&'a CommGraph> {
    ctx.graph.ok_or_else(|| {
        AdaError::Coordinator(format!(
            "{name} needs a communication graph (decentralized strategies \
             require a topology schedule)"
        ))
    })
}

/// Adapt-then-combine (the paper's default order): each worker runs its
/// fused local step (fwd + bwd + momentum update inside the model),
/// then one gossip round averages parameters over the epoch's graph.
/// Partial-participation rounds renormalize over the present workers
/// ([`crate::gossip::GossipEngine::mix_active`]).
#[derive(Debug, Default)]
pub struct GossipCombine;

impl GossipCombine {
    /// New (stateless) strategy.
    pub fn new() -> Self {
        GossipCombine
    }
}

impl CombineStrategy for GossipCombine {
    fn name(&self) -> &str {
        "gossip"
    }

    fn local_phase(
        &mut self,
        ctx: &mut StepCtx<'_>,
        replicas: &mut ReplicaMatrix,
    ) -> Result<f64> {
        let mut loss_sum = 0.0f64;
        for (w, loader) in ctx.loaders.iter().enumerate() {
            let batch = ctx.dataset.batch(&loader.batch_indices(ctx.epoch, ctx.batch));
            let loss = ctx.model.local_step(w, replicas.row_mut(w), &batch, ctx.lr)?;
            loss_sum += loss as f64;
        }
        Ok(loss_sum / ctx.n as f64)
    }

    fn combine_phase(
        &mut self,
        ctx: &mut StepCtx<'_>,
        replicas: &mut ReplicaMatrix,
    ) -> Result<(usize, u64)> {
        let g = need_graph(ctx, "GossipCombine")?;
        match (ctx.staleness, ctx.active) {
            // Bounded-staleness route: average against last-delivered
            // peer rows (fault-injection mode; the session ingested
            // this round's deliveries before the capture point).
            (Some(bound), active) => ctx.engine.mix_stale(g, replicas, active, bound),
            (None, Some(active)) => ctx.engine.mix_active(g, replicas, active),
            (None, None) => ctx.engine.mix(g, replicas),
        }
        Ok((g.degree(), g.bytes_sent_per_node(ctx.param_count)))
    }

    fn supports_pipeline(&self) -> bool {
        true
    }

    fn local_phase_bucket(
        &mut self,
        ctx: &mut StepCtx<'_>,
        replicas: &mut ReplicaMatrix,
    ) -> Result<f64> {
        let g = need_graph(ctx, "GossipCombine")?;
        // Destructured so the producer closure can borrow the model and
        // loaders while the engine drives the overlapped round.
        let StepCtx { model, dataset, loaders, engine, active, epoch, batch, lr, n, .. } =
            &mut *ctx;
        let mut loss_sum = 0.0f64;
        engine.mix_overlapped(g, replicas, *active, |w, row| {
            let b = dataset.batch(&loaders[w].batch_indices(*epoch, *batch));
            loss_sum += model.local_step(w, row, &b, *lr)? as f64;
            Ok(())
        })?;
        Ok(loss_sum / *n as f64)
    }

    fn combine_phase_bucket(
        &mut self,
        ctx: &mut StepCtx<'_>,
        replicas: &mut ReplicaMatrix,
    ) -> Result<(usize, u64)> {
        let g = need_graph(ctx, "GossipCombine")?;
        ctx.engine.publish_overlapped(replicas);
        Ok((g.degree(), g.bytes_sent_per_node(ctx.param_count)))
    }
}

/// Combine-then-adapt (D-PSGD, Lian et al. 2017), executed through the
/// fused gossip+SGD kernels: the local phase computes gradients at θ_t
/// and stashes them; the combine phase applies
/// `θ_{t+1} = W θ_t − γ v` with the momentum update running inside the
/// gossip pass ([`crate::gossip::GossipEngine::mix_step`], or
/// [`crate::gossip::GossipEngine::mix_active_step`] under failure
/// injection), eliminating one O(nP) DRAM round-trip per iteration.
///
/// Requires [`crate::coordinator::LocalModel::loss_and_grad`]; the
/// session builder falls back to [`GossipCombine`] for models that only
/// expose a fused local step (the HLO bundles).
pub struct FusedGossipCombine {
    momentum: f32,
    states: Vec<SgdState>,
    /// Gradient stash as a flat store of the same shape as the
    /// replicas, so the fused tile streams three contiguous,
    /// identically-strided buffers (params, velocity, grads).
    grads: ReplicaMatrix,
}

impl FusedGossipCombine {
    /// New strategy; `momentum` is the coefficient of the per-worker
    /// buffers the fused kernel updates tile-by-tile (set equal to the
    /// model's momentum for like-for-like comparisons).
    pub fn new(momentum: f32) -> Self {
        FusedGossipCombine {
            momentum,
            states: Vec::new(),
            grads: ReplicaMatrix::default(),
        }
    }
}

impl CombineStrategy for FusedGossipCombine {
    fn name(&self) -> &str {
        "fused_gossip"
    }

    fn prepare(&mut self, n: usize, p: usize) -> Result<()> {
        // Velocity restarts at zero on every fresh run (and on resume),
        // matching the models' internal momentum buffers.
        self.states = (0..n).map(|_| SgdState::new(p, self.momentum, 0.0)).collect();
        self.grads = ReplicaMatrix::zeros(n, p);
        Ok(())
    }

    fn local_phase(
        &mut self,
        ctx: &mut StepCtx<'_>,
        replicas: &mut ReplicaMatrix,
    ) -> Result<f64> {
        let mut loss_sum = 0.0f64;
        for (w, loader) in ctx.loaders.iter().enumerate() {
            let batch = ctx.dataset.batch(&loader.batch_indices(ctx.epoch, ctx.batch));
            let (loss, g) = ctx.model.loss_and_grad(replicas.row(w), &batch)?;
            loss_sum += loss as f64;
            self.grads.row_mut(w).copy_from_slice(&g);
        }
        Ok(loss_sum / ctx.n as f64)
    }

    fn combine_phase(
        &mut self,
        ctx: &mut StepCtx<'_>,
        replicas: &mut ReplicaMatrix,
    ) -> Result<(usize, u64)> {
        let g = need_graph(ctx, "FusedGossipCombine")?;
        match (ctx.staleness, ctx.active) {
            // Bounded-staleness route, split back into combine-then-
            // adapt halves: the stale SpMM has no fused kernel, so mix
            // against the last-delivered rows first, then apply every
            // worker's stashed gradient (inactive rows included —
            // matching `mix_active_step`'s straggler model).
            (Some(bound), active) => {
                ctx.engine.mix_stale(g, replicas, active, bound);
                for (w, s) in self.states.iter_mut().enumerate() {
                    s.step(replicas.row_mut(w), self.grads.row(w), ctx.lr);
                }
            }
            (None, Some(active)) => ctx.engine.mix_active_step(
                g,
                replicas,
                &self.grads,
                &mut self.states,
                ctx.lr,
                active,
            ),
            (None, None) => {
                ctx.engine.mix_step(g, replicas, &self.grads, &mut self.states, ctx.lr)
            }
        }
        Ok((g.degree(), g.bytes_sent_per_node(ctx.param_count)))
    }

    fn supports_pipeline(&self) -> bool {
        true
    }

    fn local_phase_bucket(
        &mut self,
        ctx: &mut StepCtx<'_>,
        replicas: &mut ReplicaMatrix,
    ) -> Result<f64> {
        let g = need_graph(ctx, "FusedGossipCombine")?;
        let StepCtx { model, dataset, loaders, engine, active, epoch, batch, lr, n, .. } =
            &mut *ctx;
        let mut loss_sum = 0.0f64;
        // θ_t is frozen for the round, so every bucket's gossip SpMM
        // starts immediately; only the momentum application waits for
        // each gradient row (see `mix_step_overlapped`).
        engine.mix_step_overlapped(
            g,
            replicas,
            &mut self.grads,
            &mut self.states,
            *lr,
            *active,
            |w, theta, grad_out| {
                let b = dataset.batch(&loaders[w].batch_indices(*epoch, *batch));
                let (loss, gvec) = model.loss_and_grad(theta, &b)?;
                loss_sum += loss as f64;
                grad_out.copy_from_slice(&gvec);
                Ok(())
            },
        )?;
        Ok(loss_sum / *n as f64)
    }

    fn combine_phase_bucket(
        &mut self,
        ctx: &mut StepCtx<'_>,
        replicas: &mut ReplicaMatrix,
    ) -> Result<(usize, u64)> {
        let g = need_graph(ctx, "FusedGossipCombine")?;
        ctx.engine.publish_overlapped(replicas);
        Ok((g.degree(), g.bytes_sent_per_node(ctx.param_count)))
    }
}
